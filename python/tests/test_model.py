"""Layer-2 graph tests: fused ops shape/semantics + AOT pipeline smoke."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

B, D, K = 128, 16, 10


def test_query_topk_returns_sorted_smallest():
    rng = np.random.default_rng(0)
    q = rng.normal(size=D).astype(np.float32)
    c = rng.normal(size=(B, D)).astype(np.float32)
    fn = model.make_query_topk("euclidean", K)
    dists, vals, idx = fn(jnp.asarray(q), jnp.asarray(c))
    dists, vals, idx = map(np.asarray, (dists, vals, idx))
    assert dists.shape == (B,) and vals.shape == (K,) and idx.shape == (K,)
    # top-k are the K smallest distances, ascending
    assert (np.diff(vals) >= -1e-6).all()
    want = np.sort(dists)[:K]
    assert_allclose(vals, want, rtol=1e-5, atol=1e-5)
    assert_allclose(dists[idx], vals, rtol=1e-5, atol=1e-5)


def test_mreach_matches_reference():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(B, D)).astype(np.float32)
    y = rng.normal(size=(B, D)).astype(np.float32)
    cx = np.abs(rng.normal(size=B)).astype(np.float32)
    cy = np.abs(rng.normal(size=B)).astype(np.float32)
    fn = model.make_mreach("euclidean")
    (got,) = fn(jnp.asarray(x), jnp.asarray(y), jnp.asarray(cx), jnp.asarray(cy))
    d = ref.euclidean_pairwise(jnp.asarray(x), jnp.asarray(y))
    want = ref.mutual_reachability(d, jnp.asarray(np.concatenate([cx])))
    # reference: max over pairwise core distances of x-rows and y-rows
    want = np.maximum(np.asarray(d), np.maximum(cx[:, None], cy[None, :]))
    # kernel distance differs from the naive reference by matmul-form
    # rounding, so compare with a loose-but-meaningful tolerance.
    assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)
    # mreach >= raw distance (modulo the same rounding)
    assert (np.asarray(got) + 1e-2 >= np.asarray(d)).all()


def test_example_shapes_cover_all_ops():
    for op in ("query", "query_topk", "pairwise", "mreach"):
        shapes = model.example_shapes(op, 128, 8)
        assert all(s.dtype == jnp.float32 for s in shapes)
    with pytest.raises(ValueError):
        model.example_shapes("nope", 128, 8)


def test_aot_lowering_produces_parseable_hlo_text():
    cfg = dict(op="query_topk", metric="euclidean", b=128, d=8, k=5)
    text = aot.lower_one(cfg)
    assert "HloModule" in text
    assert "ENTRY" in text
    # deterministic: same config lowers to identical text
    assert aot.lower_one(cfg) == text


def test_aot_main_writes_manifest(tmp_path, monkeypatch):
    out = tmp_path / "artifacts"
    monkeypatch.setattr(
        "sys.argv",
        ["aot", "--out-dir", str(out), "--only", "pairwise_euclidean"],
    )
    aot.main()
    manifest = json.loads((out / "manifest.json").read_text())
    # d=16 and d=128 euclidean pairwise configs both match the filter
    assert len(manifest) >= 1
    for entry in manifest:
        assert entry["op"] == "pairwise" and entry["metric"] == "euclidean"
        assert entry["outputs"] == 1
        assert os.path.exists(out / entry["file"])


def test_no_unparseable_hlo_ops():
    # xla_extension 0.5.1's HLO text parser rejects the `topk` instruction
    # (and other newer ops); every default config must lower without them.
    for cfg in aot.DEFAULT_CONFIGS:
        small = dict(cfg, b=128, d=8)
        text = aot.lower_one(small)
        assert " topk(" not in text, f"{aot.cfg_name(cfg)} lowered to topk"


def test_cfg_names_unique():
    names = [aot.cfg_name(c) for c in aot.DEFAULT_CONFIGS]
    assert len(names) == len(set(names))
