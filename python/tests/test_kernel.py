"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes/dtypes/value regimes; numpy RNG drives the data.
This is the CORE build-time correctness signal for the kernels the rust
runtime executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import distances as k
from compile.kernels import ref

BLOCK = 32  # small Pallas block for fast interpret-mode testing


def rng_for(seed):
    return np.random.default_rng(seed)


dims = st.sampled_from([1, 3, 8, 17, 64, 256])
batches = st.sampled_from([BLOCK, 2 * BLOCK, 4 * BLOCK])
seeds = st.integers(min_value=0, max_value=2**31 - 1)
dtypes = st.sampled_from([np.float32, np.float64])


# ---------------------------------------------------------------- query ops
@settings(max_examples=15, deadline=None)
@given(b=batches, d=dims, seed=seeds, dtype=dtypes)
@pytest.mark.parametrize("metric", ["sqeuclidean", "euclidean", "cosine"])
def test_query_dense_metrics_match_ref(metric, b, d, seed, dtype):
    rng = rng_for(seed)
    q = rng.normal(size=d).astype(dtype)
    c = rng.normal(size=(b, d)).astype(dtype)
    got = k.query_dists(metric, jnp.asarray(q), jnp.asarray(c), block_b=BLOCK)
    want = ref.QUERY_REFS[metric](jnp.asarray(q), jnp.asarray(c))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(b=batches, d=dims, seed=seeds)
@pytest.mark.parametrize("metric", ["jaccard", "simpson"])
def test_query_set_metrics_match_ref(metric, b, d, seed):
    rng = rng_for(seed)
    q = (rng.random(d) < 0.3).astype(np.float32)
    c = (rng.random((b, d)) < 0.3).astype(np.float32)
    got = k.query_dists(metric, jnp.asarray(q), jnp.asarray(c), block_b=BLOCK)
    want = ref.QUERY_REFS[metric](jnp.asarray(q), jnp.asarray(c))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_query_rejects_misaligned_batch():
    q = jnp.zeros(4)
    c = jnp.zeros((BLOCK + 1, 4))
    with pytest.raises(ValueError):
        k.query_dists("euclidean", q, c, block_b=BLOCK)


def test_query_distance_to_self_is_zero():
    rng = rng_for(7)
    c = rng.normal(size=(BLOCK, 16)).astype(np.float32)
    q = c[3].copy()
    got = np.asarray(k.query_dists("euclidean", jnp.asarray(q), jnp.asarray(c), block_b=BLOCK))
    # matmul form loses ~sqrt(eps * ||x||^2) near zero (documented tradeoff:
    # MXU-friendly ||x||^2+||y||^2-2xy suffers cancellation at d(x,x)).
    assert got[3] == pytest.approx(0.0, abs=1e-2)
    assert (got >= 0).all()


def test_cosine_query_bounds():
    rng = rng_for(11)
    q = rng.normal(size=32).astype(np.float32)
    c = rng.normal(size=(2 * BLOCK, 32)).astype(np.float32)
    got = np.asarray(k.query_dists("cosine", jnp.asarray(q), jnp.asarray(c), block_b=BLOCK))
    assert (got >= -1e-5).all() and (got <= 2 + 1e-5).all()


def test_jaccard_identical_rows_zero_distance():
    rng = rng_for(13)
    c = (rng.random((BLOCK, 64)) < 0.4).astype(np.float32)
    q = c[5].copy()
    got = np.asarray(k.query_dists("jaccard", jnp.asarray(q), jnp.asarray(c), block_b=BLOCK))
    assert got[5] == pytest.approx(0.0, abs=1e-6)


def test_simpson_subset_is_zero_distance():
    # Simpson distance is 0 when one bitmap is a subset of the other.
    d = 64
    q = np.zeros(d, np.float32)
    q[:10] = 1
    c = np.zeros((BLOCK, d), np.float32)
    c[0, :20] = 1  # superset of q
    got = np.asarray(k.query_dists("simpson", jnp.asarray(q), jnp.asarray(c), block_b=BLOCK))
    assert got[0] == pytest.approx(0.0, abs=1e-6)


# ------------------------------------------------------------- pairwise ops
@settings(max_examples=10, deadline=None)
@given(d=dims, seed=seeds)
@pytest.mark.parametrize("metric", list(k.PAIRWISE_METRICS))
def test_pairwise_matches_ref(metric, d, seed):
    rng = rng_for(seed)
    if metric == "simpson":
        x = (rng.random((BLOCK, d)) < 0.3).astype(np.float32)
        y = (rng.random((2 * BLOCK, d)) < 0.3).astype(np.float32)
    else:
        x = rng.normal(size=(BLOCK, d)).astype(np.float32)
        y = rng.normal(size=(2 * BLOCK, d)).astype(np.float32)
    got = k.pairwise_dists(metric, jnp.asarray(x), jnp.asarray(y), block_b=BLOCK)
    want = ref.PAIRWISE_REFS[metric](jnp.asarray(x), jnp.asarray(y))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_pairwise_symmetry():
    rng = rng_for(3)
    x = rng.normal(size=(BLOCK, 8)).astype(np.float32)
    d1 = np.asarray(k.pairwise_dists("euclidean", jnp.asarray(x), jnp.asarray(x), block_b=BLOCK))
    assert_allclose(d1, d1.T, rtol=1e-5, atol=1e-5)
    # diag suffers matmul-form cancellation (see test_query_distance_to_self)
    assert_allclose(np.diag(d1), np.zeros(BLOCK), atol=1e-2)


def test_pairwise_agrees_with_query_rows():
    rng = rng_for(5)
    x = rng.normal(size=(BLOCK, 8)).astype(np.float32)
    y = rng.normal(size=(BLOCK, 8)).astype(np.float32)
    pw = np.asarray(k.pairwise_dists("euclidean", jnp.asarray(x), jnp.asarray(y), block_b=BLOCK))
    for i in [0, 7, BLOCK - 1]:
        row = np.asarray(k.query_dists("euclidean", jnp.asarray(x[i]), jnp.asarray(y), block_b=BLOCK))
        assert_allclose(pw[i], row, rtol=1e-4, atol=1e-4)
