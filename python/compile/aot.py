"""AOT pipeline: lower Layer-2 graphs to HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not ``serialize()``: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects with
``proto.id() <= INT_MAX``.  The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts

Outputs one ``<name>.hlo.txt`` per configuration plus ``manifest.json``
describing shapes so the rust runtime can pad/mask batches correctly.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the rust
    side can uniformly unwrap with to_tuple1/..N)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Default artifact set.  The rust native backend covers arbitrary dims; these
# fixed-shape modules serve the PJRT distance backend (vector datasets) and
# the kernel-vs-native ablation bench.  B must be a multiple of the Pallas
# block (128).
DEFAULT_CONFIGS = [
    # HNSW insertion hot path: fused distances + top-k.
    dict(op="query_topk", metric="euclidean", b=256, d=16, k=10),
    dict(op="query_topk", metric="euclidean", b=256, d=128, k=10),
    dict(op="query_topk", metric="euclidean", b=256, d=1024, k=10),
    dict(op="query_topk", metric="cosine", b=256, d=1024, k=10),
    dict(op="query_topk", metric="jaccard", b=256, d=1024, k=10),
    dict(op="query_topk", metric="simpson", b=256, d=256, k=10),
    # Plain query distances (no top-k) for bulk rescoring.
    dict(op="query", metric="euclidean", b=256, d=128),
    dict(op="query", metric="cosine", b=256, d=1024),
    # Exact-baseline path: pairwise + fused mutual-reachability blocks
    # (consumed by `hdbscan::exact_pjrt` — the compiled-kernel baseline).
    dict(op="pairwise", metric="euclidean", b=128, d=16),
    dict(op="pairwise", metric="euclidean", b=128, d=128),
    dict(op="pairwise", metric="cosine", b=128, d=1024),
    dict(op="mreach", metric="euclidean", b=128, d=16),
    dict(op="mreach", metric="euclidean", b=128, d=128),
    dict(op="mreach", metric="cosine", b=128, d=1024),
]


def build_fn(cfg):
    op, metric = cfg["op"], cfg["metric"]
    if op == "query_topk":
        return model.make_query_topk(metric, cfg["k"])
    if op == "query":
        return model.make_query(metric)
    if op == "pairwise":
        return model.make_pairwise(metric)
    if op == "mreach":
        return model.make_mreach(metric)
    raise ValueError(op)


def cfg_name(cfg) -> str:
    name = f"{cfg['op']}_{cfg['metric']}_b{cfg['b']}_d{cfg['d']}"
    if "k" in cfg:
        name += f"_k{cfg['k']}"
    return name


def lower_one(cfg) -> str:
    fn = build_fn(cfg)
    shapes = model.example_shapes(cfg["op"], cfg["b"], cfg["d"])
    lowered = jax.jit(fn).lower(*shapes)
    return to_hlo_text(lowered)


def out_arity(cfg) -> int:
    return {"query_topk": 3, "query": 1, "pairwise": 1, "mreach": 1}[cfg["op"]]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated substring filters on names"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for cfg in DEFAULT_CONFIGS:
        name = cfg_name(cfg)
        if args.only and not any(s in name for s in args.only.split(",")):
            continue
        text = lower_one(cfg)
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            dict(
                name=name,
                file=name + ".hlo.txt",
                outputs=out_arity(cfg),
                **cfg,
            )
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV twin for the rust runtime (kept dependency-free on purpose):
    # name, file, op, metric, b, d, k(-1 if absent), outputs
    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        for e in manifest:
            f.write(
                "\t".join(
                    str(x)
                    for x in (
                        e["name"], e["file"], e["op"], e["metric"],
                        e["b"], e["d"], e.get("k", -1), e["outputs"],
                    )
                )
                + "\n"
            )
    print(f"wrote manifest with {len(manifest)} modules")


if __name__ == "__main__":
    main()
