"""Layer-2 JAX compute graphs for FISHDBC (build-time only).

The paper's numeric hot-spot is batched distance evaluation; this module
composes the Layer-1 Pallas kernels into the jitted graphs the rust
coordinator executes via PJRT:

``query_topk(metric)``
    q[D] x C[B, D] -> (dists[B], topk_vals[K], topk_idx[K]).
    One fused graph for the HNSW insertion step: all candidate distances
    plus the K nearest among them (K = MinPts for the neighbors heaps,
    ef for the search frontier).  top-k is fused into the same HLO module
    so the rust side makes a single PJRT call per frontier batch.

``pairwise(metric)``
    X[Bx, D] x Y[By, D] -> [Bx, By] distance block (exact baseline path).

``mreach(metric)``
    X, Y, core_x[Bx], core_y[By] -> mutual-reachability block
    max(d(a,b), core(a), core(b)) — HDBSCAN*'s edge weights, fused with the
    distance computation.

All functions take/return fixed shapes: the AOT pipeline (aot.py) lowers one
HLO module per (op, metric, B, D[, K]) configuration and the rust runtime
pads + masks batches to fit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import distances as k


def make_query_topk(metric: str, kk: int):
    """Fused query-distances + top-k graph (smallest distances first)."""

    def fn(q, c):
        d = k.query_dists(metric, q, c)
        # NB: sort-based top-k, NOT jax.lax.top_k — top_k lowers to the
        # HLO `topk` instruction, which xla_extension 0.5.1's text parser
        # rejects; lax.sort lowers to plain `sort`, which round-trips.
        idx = jax.lax.iota(jnp.int32, d.shape[0])
        sd, si = jax.lax.sort((d, idx), num_keys=1)
        return d, sd[:kk], si[:kk]

    return fn


def make_query(metric: str):
    def fn(q, c):
        return (k.query_dists(metric, q, c),)

    return fn


def make_pairwise(metric: str):
    def fn(x, y):
        return (k.pairwise_dists(metric, x, y),)

    return fn


def make_mreach(metric: str):
    """Mutual-reachability block: distance kernel fused with the core-distance
    max.  This is the exact-HDBSCAN* baseline's inner loop."""

    def fn(x, y, core_x, core_y):
        d = k.pairwise_dists(metric, x, y)
        return (jnp.maximum(d, jnp.maximum(core_x[:, None], core_y[None, :])),)

    return fn


def example_shapes(op: str, b: int, d: int, bx: int | None = None):
    """ShapeDtypeStructs used to trace each op for AOT lowering."""
    f32 = jnp.float32
    if op in ("query", "query_topk"):
        return (
            jax.ShapeDtypeStruct((d,), f32),
            jax.ShapeDtypeStruct((b, d), f32),
        )
    if op == "pairwise":
        bx = bx or b
        return (
            jax.ShapeDtypeStruct((bx, d), f32),
            jax.ShapeDtypeStruct((b, d), f32),
        )
    if op == "mreach":
        bx = bx or b
        return (
            jax.ShapeDtypeStruct((bx, d), f32),
            jax.ShapeDtypeStruct((b, d), f32),
            jax.ShapeDtypeStruct((bx,), f32),
            jax.ShapeDtypeStruct((b,), f32),
        )
    raise ValueError(f"unknown op {op!r}")
