"""Pure-jnp reference oracle for the Pallas distance kernels.

Deliberately naive: elementwise broadcasting, no matmul tricks, no tiling.
If `distances.py` and this file agree across the hypothesis sweep, the
kernels are trusted.
"""

from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def sqeuclidean_query(q, c):
    diff = c.astype(jnp.float32) - q.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=1)


def euclidean_query(q, c):
    return jnp.sqrt(sqeuclidean_query(q, c))


def cosine_query(q, c):
    q = q.astype(jnp.float32)
    c = c.astype(jnp.float32)
    num = jnp.sum(c * q[None, :], axis=1)
    den = jnp.linalg.norm(c, axis=1) * jnp.linalg.norm(q) + _EPS
    return 1.0 - num / den


def jaccard_query(q, c):
    q = q.astype(jnp.float32)
    c = c.astype(jnp.float32)
    inter = jnp.sum(jnp.minimum(c, q[None, :]), axis=1)
    union = jnp.sum(jnp.maximum(c, q[None, :]), axis=1)
    return 1.0 - inter / jnp.maximum(union, _EPS)


def simpson_query(q, c):
    q = q.astype(jnp.float32)
    c = c.astype(jnp.float32)
    inter = jnp.sum(c * q[None, :], axis=1)
    den = jnp.maximum(jnp.minimum(jnp.sum(c, axis=1), jnp.sum(q)), 1.0)
    return 1.0 - inter / den


QUERY_REFS = {
    "sqeuclidean": sqeuclidean_query,
    "euclidean": euclidean_query,
    "cosine": cosine_query,
    "jaccard": jaccard_query,
    "simpson": simpson_query,
}


def sqeuclidean_pairwise(x, y):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sum(diff * diff, axis=2)


def euclidean_pairwise(x, y):
    return jnp.sqrt(sqeuclidean_pairwise(x, y))


def cosine_pairwise(x, y):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    num = jnp.sum(x[:, None, :] * y[None, :, :], axis=2)
    den = (
        jnp.linalg.norm(x, axis=1)[:, None] * jnp.linalg.norm(y, axis=1)[None, :]
        + _EPS
    )
    return 1.0 - num / den


def simpson_pairwise(x, y):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    inter = jnp.sum(x[:, None, :] * y[None, :, :], axis=2)
    den = jnp.maximum(
        jnp.minimum(jnp.sum(x, axis=1)[:, None], jnp.sum(y, axis=1)[None, :]), 1.0
    )
    return 1.0 - inter / den


PAIRWISE_REFS = {
    "sqeuclidean": sqeuclidean_pairwise,
    "euclidean": euclidean_pairwise,
    "cosine": cosine_pairwise,
    "simpson": simpson_pairwise,
}


def mutual_reachability(dists, core):
    """Mutual-reachability weights (HDBSCAN*): max(d(a,b), core(a), core(b))."""
    dists = dists.astype(jnp.float32)
    core = core.astype(jnp.float32)
    return jnp.maximum(dists, jnp.maximum(core[:, None], core[None, :]))
