"""Layer-1 Pallas distance kernels and their pure-jnp reference oracle.

Every kernel here is the compute hot-spot of FISHDBC's neighbor search:
batched distance evaluation between a query item and a block of candidate
items (HNSW insertion path), and tiled pairwise distance blocks (exact
HDBSCAN* baseline path).

Kernels are written in Pallas with BlockSpec tiling so the same source is
TPU-lowerable (VMEM tiles, MXU matmul form); on this CPU-only image they are
lowered with ``interpret=True`` (see DESIGN.md §Hardware-Adaptation).
"""

from .distances import (  # noqa: F401
    METRICS,
    PAIRWISE_METRICS,
    pairwise_dists,
    query_dists,
)
from . import ref  # noqa: F401
