"""Pallas batch-distance kernels (Layer 1).

Two kernel families:

``query_dists(metric, q, C)``
    distances from one query vector ``q[D]`` to a candidate block ``C[B, D]``
    -> ``[B]``.  This is the HNSW insertion hot path: every level-search step
    evaluates the distance from the inserted item to a frontier of candidates.

``pairwise_dists(metric, X, Y)``
    tiled pairwise block ``X[Bx, D] x Y[By, D] -> [Bx, By]``.  This is the
    exact-HDBSCAN* baseline hot path (full reachability matrix) and the bulk
    pre-scoring path of the coordinator.

TPU-minded structure (see DESIGN.md §Hardware-Adaptation):

* Euclidean / cosine distances use the matmul form (``X @ Y.T`` on the MXU)
  instead of elementwise subtract-square loops.
* ``BlockSpec`` tiles the candidate axis into VMEM-sized blocks; the grid
  walks candidate tiles so HBM->VMEM transfers are sequential and
  double-bufferable.
* Set-distances (Jaccard / Simpson) operate on {0,1}-valued float bitmaps so
  they stay vectorizable (VPU min/max + row reductions) with no integer
  bit-twiddling.

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (vs ``ref.py``) is the build-time signal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default candidate-tile height. 128 matches the MXU systolic dimension and,
# with D <= 4096 fp32, keeps each buffer (128 x 4096 x 4 B = 2 MiB) inside a
# VMEM budget with room for double buffering.
DEFAULT_BLOCK_B = 128

_EPS = 1e-12


# --------------------------------------------------------------------------
# query kernels: q[1, D] x C[Bb, D] -> o[Bb]
# --------------------------------------------------------------------------

def _sqeuclidean_query_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]  # [1, D]
    c = c_ref[...]  # [Bb, D]
    # MXU form: ||c||^2 - 2 c.q + ||q||^2 (dot is an [Bb,D]x[D,1] matmul).
    qq = jnp.sum(q * q)
    cc = jnp.sum(c * c, axis=1)
    cq = jnp.dot(c, q[0], preferred_element_type=jnp.float32)
    # Guard tiny negatives from cancellation so sqrt() downstream is safe.
    o_ref[...] = jnp.maximum(cc - 2.0 * cq + qq, 0.0)


def _euclidean_query_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]
    c = c_ref[...]
    qq = jnp.sum(q * q)
    cc = jnp.sum(c * c, axis=1)
    cq = jnp.dot(c, q[0], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.sqrt(jnp.maximum(cc - 2.0 * cq + qq, 0.0))


def _cosine_query_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]
    c = c_ref[...]
    qn = jnp.sqrt(jnp.sum(q * q))
    cn = jnp.sqrt(jnp.sum(c * c, axis=1))
    cq = jnp.dot(c, q[0], preferred_element_type=jnp.float32)
    o_ref[...] = 1.0 - cq / (cn * qn + _EPS)


def _jaccard_query_kernel(q_ref, c_ref, o_ref):
    # Inputs are {0,1} float bitmaps; jaccard dist = 1 - |x&y| / |x|y|.
    q = q_ref[...]
    c = c_ref[...]
    inter = jnp.sum(jnp.minimum(c, q), axis=1)
    union = jnp.sum(jnp.maximum(c, q), axis=1)
    o_ref[...] = 1.0 - inter / jnp.maximum(union, _EPS)


def _simpson_query_kernel(q_ref, c_ref, o_ref):
    # Simpson (overlap) distance: 1 - |x&y| / min(|x|, |y|). Paper §4.1 USPS.
    q = q_ref[...]
    c = c_ref[...]
    inter = jnp.dot(c, q[0], preferred_element_type=jnp.float32)
    cq = jnp.sum(q)
    cc = jnp.sum(c, axis=1)
    o_ref[...] = 1.0 - inter / jnp.maximum(jnp.minimum(cc, cq), 1.0)


_QUERY_KERNELS = {
    "sqeuclidean": _sqeuclidean_query_kernel,
    "euclidean": _euclidean_query_kernel,
    "cosine": _cosine_query_kernel,
    "jaccard": _jaccard_query_kernel,
    "simpson": _simpson_query_kernel,
}

METRICS = tuple(sorted(_QUERY_KERNELS))


@functools.partial(jax.jit, static_argnums=(0, 3))
def query_dists(metric: str, q, c, block_b: int = DEFAULT_BLOCK_B):
    """Distances from ``q[D]`` to every row of ``c[B, D]`` -> ``[B]``.

    ``B`` must be a multiple of ``block_b`` (the AOT pipeline pads batches;
    the rust runtime masks padded tail entries).
    """
    b, d = c.shape
    if b % block_b:
        raise ValueError(f"B={b} not a multiple of block_b={block_b}")
    kernel = _QUERY_KERNELS[metric]
    grid = (b // block_b,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),        # q: replicated
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),  # C: tile i
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(q.reshape(1, d).astype(jnp.float32), c.astype(jnp.float32))


# --------------------------------------------------------------------------
# pairwise kernels: X[Bx, D] x Y[By, D] -> o[Bx, By], tiled on both axes
# --------------------------------------------------------------------------

def _sqeuclidean_pair_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]  # [bx, D]
    y = y_ref[...]  # [by, D]
    xx = jnp.sum(x * x, axis=1)
    yy = jnp.sum(y * y, axis=1)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(xx[:, None] + yy[None, :] - 2.0 * xy, 0.0)


def _euclidean_pair_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    xx = jnp.sum(x * x, axis=1)
    yy = jnp.sum(y * y, axis=1)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.sqrt(jnp.maximum(xx[:, None] + yy[None, :] - 2.0 * xy, 0.0))


def _cosine_pair_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    xn = jnp.sqrt(jnp.sum(x * x, axis=1))
    yn = jnp.sqrt(jnp.sum(y * y, axis=1))
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    o_ref[...] = 1.0 - xy / (xn[:, None] * yn[None, :] + _EPS)


def _simpson_pair_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    inter = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    cx = jnp.sum(x, axis=1)
    cy = jnp.sum(y, axis=1)
    denom = jnp.maximum(jnp.minimum(cx[:, None], cy[None, :]), 1.0)
    o_ref[...] = 1.0 - inter / denom


_PAIR_KERNELS = {
    "sqeuclidean": _sqeuclidean_pair_kernel,
    "euclidean": _euclidean_pair_kernel,
    "cosine": _cosine_pair_kernel,
    "simpson": _simpson_pair_kernel,
}

PAIRWISE_METRICS = tuple(sorted(_PAIR_KERNELS))


@functools.partial(jax.jit, static_argnums=(0, 3))
def pairwise_dists(metric: str, x, y, block_b: int = DEFAULT_BLOCK_B):
    """Pairwise distance block ``X[Bx,D] x Y[By,D] -> [Bx,By]``.

    Jaccard is intentionally absent: its min/max row reduction cannot use the
    MXU matmul form, so pairwise-Jaccard blocks go through ``query_dists``
    row-at-a-time (and, on the rust side, the native backend).
    """
    bx, d = x.shape
    by, _ = y.shape
    if bx % block_b or by % block_b:
        raise ValueError(f"({bx},{by}) not multiples of block_b={block_b}")
    kernel = _PAIR_KERNELS[metric]
    grid = (bx // block_b, by // block_b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bx, by), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
