//! Sharded parallel ingest with the epoch-based serving loop: one stream
//! fanned out to S shard-local FISHDBC instances (content-hash routing), a
//! background auto-recluster thread publishing merged snapshots while the
//! stream is still flowing, and online `label_against()` queries served
//! from a pinned `latest()` epoch — the paper's *scalable, incremental*
//! pitch on all available cores, with recluster cost scaling in the delta
//! since the previous epoch rather than in total n.
//!
//! Run with:
//! ```text
//! cargo run --release --example sharded_ingest
//! ```

use std::time::Instant;

use fishdbc::datasets;
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::metrics::score_external;
use fishdbc::Item;

fn main() {
    let n = 12_000;
    let shards = 4;
    let ds = datasets::blobs::generate(n, 16, 4, 99);
    let truth = ds.primary_labels().expect("blobs is labeled").to_vec();

    let engine = Engine::spawn(ds.metric, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        shards,
        mcs: 10,
        // the serving loop: re-merge every 3000 items in the background;
        // each merge publishes an epoch and refreshes the frozen snapshots
        // that insert-time bridge discovery queries
        recluster_every: 3000,
        ..Default::default()
    });

    // ---- ingest: hash-routed, backpressured, S insertion lanes ----------
    // epochs appear via latest() while we are still streaming
    let t0 = Instant::now();
    let mut seen_epoch = 0u64;
    for chunk in ds.items.chunks(256) {
        engine.add_batch(chunk.to_vec());
        if let Some(snap) = engine.latest() {
            if snap.epoch > seen_epoch {
                seen_epoch = snap.epoch;
                println!(
                    "  epoch {}: n={:>6} clusters={:>3} merge={:.3}s \
                     (bridge search {:.3}s)",
                    snap.epoch,
                    snap.n_items,
                    snap.clustering.n_clusters,
                    snap.extract_secs,
                    snap.bridge_secs
                );
            }
        }
    }
    engine.flush();
    let ingest = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    println!(
        "ingested {n} items through {shards} shards in {ingest:.2}s \
         ({:.0} items/s; busiest shard {:.2}s; {} bridge edges found at \
         insert time)",
        n as f64 / ingest.max(1e-9),
        stats.build_secs,
        stats.bridge_insert_edges
    );
    for (i, s) in stats.shard_stats.iter().enumerate() {
        println!(
            "  shard {i}: {:>6} items {:>9} dist calls {:>6} MSF edges",
            s.items, s.dist_calls, s.msf_edges
        );
    }

    // ---- final merge: a *delta* epoch, not a from-scratch rebuild -------
    let snap = engine.cluster(10);
    println!(
        "final merge (epoch {}) in {:.3}s: {} forest edges ({} bridges \
         offered, {} shards changed) -> {} clusters, {} of {} clustered",
        snap.epoch,
        snap.extract_secs,
        snap.n_msf_edges,
        snap.n_bridge_edges,
        snap.n_changed_shards,
        snap.clustering.n_clusters,
        snap.clustering.n_clustered(),
        n
    );

    // global ids are arrival order, so the merged labels line up with the
    // generator's classes directly
    let quality = score_external(&snap.clustering.labels, &truth);
    println!(
        "quality vs generator classes: AMI* {:.3}  ARI* {:.3}",
        quality.ami_star, quality.ari_star
    );

    // ---- serve: pin the latest epoch, answer online label queries -------
    // (>=, not ==: the background loop may have squeezed in one more
    // cheap epoch after our explicit merge)
    let served = engine.latest().expect("an epoch is published");
    assert!(served.epoch >= snap.epoch, "latest() went backwards");
    let probes: Vec<Item> = ds.items[..8].to_vec();
    let t1 = Instant::now();
    let labels: Vec<i32> =
        probes.iter().map(|p| engine.label_against(p, &served, 10)).collect();
    println!(
        "labeled {} probes in {:.4}s (read-only, no state mutated): {:?}",
        probes.len(),
        t1.elapsed().as_secs_f64(),
        labels
    );
    let agree = labels
        .iter()
        .enumerate()
        .filter(|&(i, &l)| l == served.clustering.labels[i])
        .count();
    println!("{agree}/{} probes landed in their own stored cluster", probes.len());

    assert!(seen_epoch >= 1 || snap.epoch >= 1, "no epoch was ever published");
    assert!(snap.clustering.n_clusters >= 3, "blob structure must survive the merge");
    assert!(quality.ari_star > 0.8, "merged quality dropped: {:?}", quality);
    assert!(agree >= 6, "online labels disagree with the snapshot");
    engine.shutdown();
    println!("engine shut down cleanly");
}
