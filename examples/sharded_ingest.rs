//! Sharded parallel ingest: one stream fanned out to S shard-local FISHDBC
//! instances (content-hash routing), merged back into one global clustering
//! (per-shard MSFs + bounded cross-shard bridge edges, one Kruskal +
//! condense pass), and served through online `label()` queries — the
//! paper's *scalable, incremental* pitch on all available cores.
//!
//! Run with:
//! ```text
//! cargo run --release --example sharded_ingest
//! ```

use std::time::Instant;

use fishdbc::datasets;
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::metrics::score_external;
use fishdbc::Item;

fn main() {
    let n = 12_000;
    let shards = 4;
    let ds = datasets::blobs::generate(n, 16, 4, 99);
    let truth = ds.primary_labels().expect("blobs is labeled").to_vec();

    let engine = Engine::spawn(ds.metric, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        shards,
        mcs: 10,
        ..Default::default()
    });

    // ---- ingest: hash-routed, backpressured, S insertion lanes ----------
    let t0 = Instant::now();
    for chunk in ds.items.chunks(256) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();
    let ingest = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    println!(
        "ingested {n} items through {shards} shards in {ingest:.2}s \
         ({:.0} items/s; busiest shard {:.2}s)",
        n as f64 / ingest.max(1e-9),
        stats.build_secs
    );
    for (i, s) in stats.shard_stats.iter().enumerate() {
        println!(
            "  shard {i}: {:>6} items {:>9} dist calls {:>6} MSF edges",
            s.items, s.dist_calls, s.msf_edges
        );
    }

    // ---- merge: global forest from per-shard MSFs + bridges -------------
    let snap = engine.cluster(10);
    println!(
        "merge in {:.3}s: {} forest edges ({} bridges offered) -> {} clusters, \
         {} of {} clustered",
        snap.extract_secs,
        snap.n_msf_edges,
        snap.n_bridge_edges,
        snap.clustering.n_clusters,
        snap.clustering.n_clustered(),
        n
    );

    // global ids are arrival order, so the merged labels line up with the
    // generator's classes directly
    let quality = score_external(&snap.clustering.labels, &truth);
    println!(
        "quality vs generator classes: AMI* {:.3}  ARI* {:.3}",
        quality.ami_star, quality.ari_star
    );

    // ---- serve: online label queries against the pinned snapshot --------
    let probes: Vec<Item> = ds.items[..8].to_vec();
    let t0 = Instant::now();
    let labels: Vec<i32> =
        probes.iter().map(|p| engine.label_against(p, &snap, 10)).collect();
    println!(
        "labeled {} probes in {:.4}s (read-only, no state mutated): {:?}",
        probes.len(),
        t0.elapsed().as_secs_f64(),
        labels
    );
    let agree = labels
        .iter()
        .enumerate()
        .filter(|&(i, &l)| l == snap.clustering.labels[i])
        .count();
    println!("{agree}/{} probes landed in their own stored cluster", probes.len());

    assert!(snap.clustering.n_clusters >= 3, "blob structure must survive the merge");
    assert!(quality.ari_star > 0.8, "merged quality dropped: {:?}", quality);
    assert!(agree >= 6, "online labels disagree with the snapshot");
    engine.shutdown();
    println!("engine shut down cleanly");
}
