//! Regenerate the paper's *quality* tables (Tables 2, 4, 5, 6, 7) at a
//! configurable scale: for each dataset, run FISHDBC with ef ∈ {20, 50}
//! and the exact HDBSCAN* baseline, and print the same rows the paper
//! reports. Runtime tables/figures live in `rust/benches/` (`cargo bench`).
//!
//! Absolute numbers differ from the paper (synthetic data substitutes,
//! different hardware) — the *shape* is what must hold: FISHDBC ≈ exact on
//! quality, sometimes better via the regularization effect (§3), with far
//! fewer distance calls.
//!
//! Run with:
//! ```text
//! cargo run --release --example paper_tables [-- --scale 0.2]
//! ```

use fishdbc::cli;
use fishdbc::datasets::{self, Dataset};
use fishdbc::distances::{Item, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactParams};
use fishdbc::hdbscan::Clustering;
use fishdbc::metrics::{internal, score_external};

struct Run {
    who: String,
    clustering: Clustering,
    dist_calls: u64,
}

/// FISHDBC at a given ef, plus the exact baseline, on one dataset.
fn run_all(ds: &Dataset, min_pts: usize, efs: &[usize]) -> Vec<Run> {
    let mut out = Vec::new();
    for &ef in efs {
        let mut f: Fishdbc<Item, MetricKind> = Fishdbc::new(
            ds.metric,
            FishdbcParams { min_pts, ef, ..Default::default() },
        );
        for it in ds.items.iter().cloned() {
            f.add(it);
        }
        let clustering = f.cluster(min_pts);
        out.push(Run {
            who: format!("FISHDBC(ef={ef})"),
            clustering,
            dist_calls: f.dist_calls(),
        });
    }
    let exact = exact_hdbscan(
        &ds.items,
        &ds.metric,
        ExactParams { min_pts, mcs: min_pts, matrix_budget: None },
    )
    .expect("exact baseline");
    out.push(Run {
        who: "HDBSCAN*".into(),
        clustering: exact.clustering,
        dist_calls: exact.dist_calls,
    });
    out
}

/// Tables 2/4/5/6: external quality per label set.
fn external_table(ds: &Dataset, runs: &[Run]) {
    println!(
        "  {:<16} {:>9} | {}",
        "algorithm",
        "#clust.",
        ds.label_sets
            .iter()
            .map(|(n, _)| format!("{:<7}{:>6}{:>6}", n, "AMI", "AMI*"))
            .collect::<Vec<_>>()
            .join(" | ")
    );
    for r in runs {
        let mut cells = Vec::new();
        for (_, truth) in &ds.label_sets {
            let s = score_external(&r.clustering.labels, truth);
            cells.push(format!("       {:>6.2}{:>6.2}", s.ami, s.ami_star));
        }
        println!(
            "  {:<16} {:>9} | {}",
            r.who,
            r.clustering.n_clustered(),
            cells.join(" | ")
        );
    }
}

/// Table 7: internal quality (clusters, clustered, silhouette, intra/inter).
fn internal_table(ds: &Dataset, runs: &[Run], silhouette_max: usize) {
    println!(
        "  {:<16} {:>7} {:>7} {:>6} {:>6} {:>10} {:>7} {:>7}",
        "algorithm", "flat", "hier.", "flatC", "hierC", "silhouette", "intra", "inter"
    );
    for r in runs {
        let sc = internal::score_internal(
            &ds.items,
            &r.clustering.labels,
            &ds.metric,
            silhouette_max,
            99,
        );
        let sil = match sc.silhouette {
            Some(s) => format!("{s:>10.3}"),
            None => format!("{:>10}", "OOM"),
        };
        println!(
            "  {:<16} {:>7} {:>7} {:>6} {:>6} {} {:>7.3} {:>7.3}",
            r.who,
            r.clustering.n_clustered(),
            r.clustering.n_hierarchical_clustered(),
            r.clustering.n_clusters,
            r.clustering.n_hierarchical_clusters(),
            sil,
            sc.intra,
            sc.inter
        );
    }
}

fn dist_calls_line(n: usize, runs: &[Run]) {
    let cells: Vec<String> = runs
        .iter()
        .map(|r| {
            format!(
                "{} {:.1}%",
                r.who,
                100.0 * r.dist_calls as f64 / (n as f64 * n as f64)
            )
        })
        .collect();
    println!("  dist calls as % of n²: {}", cells.join(" | "));
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv, &["scale", "seed", "silhouette-max"]).expect("args");
    let scale = args.f64_or("scale", 0.15).expect("scale");
    let seed = args.u64_or("seed", 42).expect("seed");
    let sil_max = args.usize_or("silhouette-max", 3000).expect("silhouette-max");
    let sz = |paper_n: usize| ((paper_n as f64 * scale) as usize).max(300);

    println!("=== paper quality tables (scale={scale}, seed={seed}) ===\n");

    // ---- Table 2: fuzzy hashes, 5 label sets --------------------------------
    // The paper clusters 15 402 binary-file digests under lzjd/tlsh/sdhash.
    let ds = datasets::fuzzy::generate(sz(15402), seed);
    for metric in [MetricKind::Lzjd, MetricKind::Tlsh, MetricKind::Sdhash] {
        let mut d = ds.clone();
        d.metric = metric;
        println!("Table 2 — fuzzy hashes under {} (n={}):", metric.name(), d.n());
        let runs = run_all(&d, 10, &[20, 50]);
        external_table(&d, &runs);
        dist_calls_line(d.n(), &runs);
        println!();
    }

    // ---- Table 4: synth transactions, dim sweep ------------------------------
    for dim in [640, 1024, 2048] {
        let d = datasets::synth::generate(sz(10000), dim, 5, seed);
        println!("Table 4 — synth dim={dim} (n={}):", d.n());
        let runs = run_all(&d, 10, &[20, 50]);
        external_table(&d, &runs);
        println!();
    }

    // ---- Table 5: USPS bitmaps ----------------------------------------------
    let d = datasets::usps::generate(2196, seed);
    println!("Table 5 — USPS 0-vs-7 bitmaps, Simpson distance (n={}):", d.n());
    let runs = run_all(&d, 10, &[20, 50]);
    external_table(&d, &runs);
    println!();

    // ---- Table 6: blobs dimensionality sweep ---------------------------------
    for dim in [1000, 2000] {
        let d = datasets::blobs::generate(sz(10000), dim, 10, seed);
        println!("Table 6 — blobs dim={dim} (n={}):", d.n());
        let runs = run_all(&d, 10, &[20, 50]);
        external_table(&d, &runs);
        println!();
    }

    // ---- Table 7: internal metrics on unlabeled datasets ---------------------
    for (name, paper_n) in
        [("docword", 39861usize), ("reviews", 56846), ("household", 204928)]
    {
        let d = datasets::generate(name, sz(paper_n / 10), 512, seed).unwrap();
        println!("Table 7 — {} internal metrics (n={}):", d.name, d.n());
        let runs = run_all(&d, 10, &[20, 50]);
        internal_table(&d, &runs, sil_max);
        println!();
    }

    println!("done — compare shapes against the paper (see EXPERIMENTS.md)");
}
