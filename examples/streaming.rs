//! Streaming: ingest an unbounded feed through the [`Coordinator`] with
//! bounded-queue backpressure and periodic automatic re-clustering — the
//! paper's *incremental* axis made operational ("in a streaming context,
//! new data can be added as they arrive, and clustering can be computed
//! inexpensively", §1).
//!
//! A producer simulates a bursty event stream whose cluster structure
//! drifts over time (a new cluster appears mid-stream); the consumer
//! watches snapshots evolve without ever blocking ingestion.
//!
//! Run with:
//! ```text
//! cargo run --release --example streaming
//! ```

use std::time::Instant;

use fishdbc::coordinator::{Coordinator, CoordinatorConfig};
use fishdbc::distances::{Item, MetricKind};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::util::rng::Rng;

/// Synthesize one batch of events around the currently-active centers.
fn batch(rng: &mut Rng, centers: &[(f64, f64)], size: usize) -> Vec<Item> {
    (0..size)
        .map(|_| {
            let (cx, cy) = centers[rng.below(centers.len())];
            Item::Dense(vec![
                (cx + rng.normal() * 1.5) as f32,
                (cy + rng.normal() * 1.5) as f32,
            ])
        })
        .collect()
}

fn main() {
    let mut rng = Rng::new(7);

    let config = CoordinatorConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        mcs: 10,
        recluster_every: 500, // auto re-cluster every 500 ingested items
        queue_depth: 8,       // backpressure: producers block beyond this
    };
    let coord = Coordinator::spawn(MetricKind::Euclidean, config);

    // Phase 1: two clusters. Phase 2 (mid-stream): a third appears —
    // exactly the situation where non-incremental algorithms recompute
    // everything from scratch.
    let phase1: Vec<(f64, f64)> = vec![(0.0, 0.0), (40.0, 0.0)];
    let phase2: Vec<(f64, f64)> = vec![(0.0, 0.0), (40.0, 0.0), (20.0, 35.0)];

    let t0 = Instant::now();
    let mut last_seen = 0usize;
    println!("streaming 6000 events (cluster drift at event 3000)...");
    println!(
        "{:>8} {:>7} {:>9} {:>10} {:>12} {:>10}",
        "t(s)", "items", "clusters", "clustered", "extract(s)", "queue"
    );
    for step in 0..60 {
        let centers = if step < 30 { &phase1 } else { &phase2 };
        coord.add_batch(batch(&mut rng, centers, 100));
        if step % 5 == 4 {
            // periodic ingestion barrier: lets auto re-clusters land so the
            // live table below has fresh snapshots to show (a real deployment
            // would just poll `latest()` on its own schedule)
            let _ = coord.stats();
        }

        // Non-blocking: read the latest snapshot whenever one is fresh.
        if let Some(snap) = coord.latest() {
            if snap.n_items != last_seen {
                last_seen = snap.n_items;
                println!(
                    "{:>8.2} {:>7} {:>9} {:>10} {:>12.4} {:>10}",
                    t0.elapsed().as_secs_f64(),
                    snap.n_items,
                    snap.clustering.n_clusters,
                    snap.clustering.n_clustered(),
                    snap.extract_secs,
                    coord.queue_depth(),
                );
            }
        }
    }

    // Drain and take a final consistent snapshot.
    let final_snap = coord.cluster(10);
    let stats = coord.stats();
    println!("--------------------------------------------------------------");
    println!("final state after {:.2}s wall:", t0.elapsed().as_secs_f64());
    println!("  items ingested    : {}", final_snap.n_items);
    println!("  flat clusters     : {}", final_snap.clustering.n_clusters);
    println!("  clustered points  : {}", final_snap.clustering.n_clustered());
    println!("  batches processed : {}", stats.batches);
    println!("  auto re-clusters  : {}", stats.reclusters);
    println!("  build time        : {:.2}s", stats.build_secs);
    println!("  distance calls    : {}", stats.fishdbc.dist_calls);
    println!("  MST updates       : {}", stats.fishdbc.mst_updates);
    println!(
        "  dist calls / item : {:.1} (quadratic would be {})",
        stats.fishdbc.dist_calls as f64 / final_snap.n_items as f64,
        final_snap.n_items / 2
    );

    assert_eq!(final_snap.n_items, 6000);
    assert!(
        final_snap.clustering.n_clusters >= 3,
        "the drifted third cluster must be discovered"
    );
    coord.shutdown();
    println!("coordinator shut down cleanly");
}
