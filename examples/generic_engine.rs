//! The sharded engine over a **user-defined item type and a pure-closure
//! distance** — no `Item`, no `MetricKind` anywhere. This is the paper's
//! flexibility pitch ("arbitrary data and distance functions") running at
//! the production layer: hash-routed parallel ingest, incremental epoch
//! merges, online labels and generic persistence, all for a plain
//! `Vec<i64>` under a closure.
//!
//! Run with:
//! ```text
//! cargo run --release --example generic_engine
//! ```

use std::io;

use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::persist::{BinReader, BinWriter, ItemCodec};
use fishdbc::util::rng::Rng;

/// Items: integer activity profiles (say, hourly event counts of a user
/// session). `Vec<i64>` is `Hash`, so the engine routes it out of the box.
type Profile = Vec<i64>;

/// The whole persistence story for a custom type: how one item becomes
/// bytes and back.
struct ProfileCodec;

impl ItemCodec<Profile> for ProfileCodec {
    fn write_item<W: io::Write>(
        &self,
        w: &mut BinWriter<W>,
        item: &Profile,
    ) -> io::Result<()> {
        w.len(item.len())?;
        for &x in item {
            w.u64(x as u64)?;
        }
        Ok(())
    }

    fn read_item<R: io::Read>(&self, r: &mut BinReader<R>) -> io::Result<Profile> {
        let n = r.len()?;
        let mut v = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            v.push(r.u64()? as i64);
        }
        Ok(v)
    }
}

/// The metric is a named function only so the persistence resolver can
/// hand it back on load; a closure literal works the same for `spawn`.
fn manhattan(a: &Profile, b: &Profile) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum()
}

/// Three archetypal activity shapes + noise around them.
fn sessions(n: usize, seed: u64) -> (Vec<Profile>, Vec<usize>) {
    let archetypes: [[i64; 6]; 3] = [
        [40, 35, 5, 0, 0, 2],  // morning-heavy
        [0, 3, 8, 45, 38, 10], // evening-heavy
        [12, 12, 12, 12, 12, 12], // flat
    ];
    let mut rng = Rng::new(seed);
    let mut items = Vec::with_capacity(n);
    let mut truth = Vec::with_capacity(n);
    for _ in 0..n {
        let k = rng.below(3);
        items.push(
            archetypes[k]
                .iter()
                .map(|&c| (c + (rng.normal() * 2.0) as i64).max(0))
                .collect(),
        );
        truth.push(k);
    }
    (items, truth)
}

fn main() {
    let (items, truth) = sessions(6000, 7);
    type Metric = fn(&Profile, &Profile) -> f64;

    let engine: Engine<Profile, Metric> =
        Engine::spawn(manhattan as Metric, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 8, ef: 20, ..Default::default() },
            shards: 4,
            mcs: 8,
            recluster_every: 2000, // background epochs while streaming
            ..Default::default()
        });

    for chunk in items.chunks(256) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(8);
    let stats = engine.stats();
    println!(
        "clustered {} profiles across {} shards into {} clusters \
         (epoch {}, {} forest edges, {} cross-shard bridges)",
        snap.n_items,
        engine.n_shards(),
        snap.clustering.n_clusters,
        snap.epoch,
        snap.n_msf_edges,
        snap.n_bridge_edges,
    );
    println!(
        "distance calls: {} total through the closure ({} on the insert \
         path) — the paper's cost model, counted for ANY metric",
        stats.metric_calls, stats.dist_calls,
    );

    // majority-vote purity against the hidden archetypes
    let mut per: std::collections::HashMap<i32, std::collections::HashMap<usize, usize>> =
        std::collections::HashMap::new();
    for (l, t) in snap.clustering.labels.iter().zip(&truth) {
        if *l >= 0 {
            *per.entry(*l).or_default().entry(*t).or_default() += 1;
        }
    }
    let (good, total) = per.values().fold((0usize, 0usize), |(g, t), counts| {
        (
            g + counts.values().max().copied().unwrap_or(0),
            t + counts.values().sum::<usize>(),
        )
    });
    let purity = good as f64 / total.max(1) as f64;
    println!("purity vs hidden archetypes: {purity:.3} ({good}/{total})");

    // online serving: a fresh morning-heavy session joins its cluster
    let probe: Profile = vec![41, 33, 6, 1, 0, 1];
    let label = engine.label(&probe);
    println!("fresh morning-heavy probe -> cluster {label}");

    // generic persistence: custom codec + metric-name round trip
    let mut buf = Vec::new();
    engine.save_with("manhattan-profiles", &ProfileCodec, &mut buf).unwrap();
    engine.shutdown();
    let resumed: Engine<Profile, Metric> = Engine::load_with(
        &ProfileCodec,
        |name| {
            assert_eq!(name, "manhattan-profiles");
            Ok(manhattan as Metric)
        },
        buf.as_slice(),
    )
    .unwrap();
    let again = resumed.cluster(8);
    println!(
        "reloaded {} bytes -> {} items, labels identical: {}",
        buf.len(),
        resumed.len(),
        again.clustering.labels == snap.clustering.labels
    );

    assert!(snap.clustering.n_clusters >= 3, "three archetypes expected");
    assert!(purity > 0.9, "archetypes not recovered: {purity}");
    assert_eq!(again.clustering.labels, snap.clustering.labels);
    assert!(label >= 0, "probe must join a cluster");
    resumed.shutdown();
    println!("generic engine shut down cleanly");
}
