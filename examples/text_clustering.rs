//! Text clustering with *arbitrary user-defined distance functions* — the
//! paper's flexibility axis (§1: "domain experts can encode as much domain
//! knowledge as needed by defining any symmetric and possibly non-metric
//! distance function, no matter how complex").
//!
//! We cluster short log-like messages three ways:
//!  1. the framework path: `Item::Text` + the built-in Jaro-Winkler metric
//!     (what the paper uses on Finefoods);
//!  2. a hand-written token-level Jaccard closure — a *non-metric*,
//!     domain-specific distance mixing token overlap with a length prior;
//!  3. the same closure wrapped in `Counting` to expose the paper's cost
//!     model (distance calls ≪ n²).
//!
//! Run with:
//! ```text
//! cargo run --release --example text_clustering
//! ```

use std::collections::HashSet;

use fishdbc::distances::{text, Counting, Item, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::util::rng::Rng;

/// Generate synthetic log messages from a handful of templates, with
/// per-message mutations (ids, levels, jitter) — shaped like the short
/// user-generated text the paper clusters (Finefoods reviews).
fn generate_messages(rng: &mut Rng, per_template: usize) -> (Vec<String>, Vec<usize>) {
    let templates: &[(&str, &[&str])] = &[
        ("auth", &["user", "login", "failed", "for", "account", "from", "ip"]),
        ("disk", &["disk", "usage", "above", "threshold", "on", "volume", "server"]),
        ("net", &["connection", "timeout", "while", "contacting", "upstream", "service", "retrying"]),
        ("db", &["query", "exceeded", "slow", "log", "limit", "on", "table", "index"]),
        ("job", &["scheduled", "job", "completed", "with", "status", "after", "seconds"]),
    ];
    let mut msgs = Vec::new();
    let mut labels = Vec::new();
    for (t, (_, words)) in templates.iter().enumerate() {
        for _ in 0..per_template {
            let mut parts: Vec<String> =
                words.iter().map(|w| w.to_string()).collect();
            // mutate: drop a word, add a random id, shuffle a little
            if rng.bool(0.3) {
                let i = rng.below(parts.len());
                parts.remove(i);
            }
            parts.push(format!("{:04x}", rng.next_u64() & 0xffff));
            if rng.bool(0.2) {
                let i = rng.below(parts.len());
                let j = rng.below(parts.len());
                parts.swap(i, j);
            }
            msgs.push(parts.join(" "));
            labels.push(t);
        }
    }
    // interleave so arrival order doesn't mirror the labels
    let mut idx: Vec<usize> = (0..msgs.len()).collect();
    rng.shuffle(&mut idx);
    let msgs2 = idx.iter().map(|&i| msgs[i].clone()).collect();
    let labels2 = idx.iter().map(|&i| labels[i]).collect();
    (msgs2, labels2)
}

/// Purity of the flat clustering against generator templates.
fn purity(labels: &[i32], truth: &[usize]) -> f64 {
    use std::collections::HashMap;
    let mut per: HashMap<i32, HashMap<usize, usize>> = HashMap::new();
    for (l, t) in labels.iter().zip(truth) {
        if *l >= 0 {
            *per.entry(*l).or_default().entry(*t).or_default() += 1;
        }
    }
    let (mut good, mut total) = (0usize, 0usize);
    for (_, counts) in per {
        good += counts.values().max().copied().unwrap_or(0);
        total += counts.values().sum::<usize>();
    }
    if total == 0 { 0.0 } else { good as f64 / total as f64 }
}

fn report(
    name: &str,
    n: usize,
    dist_calls: u64,
    clustering: &fishdbc::Clustering,
    truth: &[usize],
) {
    println!(
        "  {name:<28} {:>3} clusters  {:>4}/{n} clustered  purity {:.3}  \
         {dist_calls:>7} dist calls ({:.1}% of n²)",
        clustering.n_clusters,
        clustering.n_clustered(),
        purity(&clustering.labels, truth),
        100.0 * dist_calls as f64 / (n * n) as f64,
    );
}

fn main() {
    let mut rng = Rng::new(2026);
    let (messages, truth) = generate_messages(&mut rng, 300);
    let n = messages.len();
    println!("clustering {n} synthetic log messages, e.g.:");
    for m in messages.iter().take(3) {
        println!("    \"{m}\"");
    }

    let params = FishdbcParams { min_pts: 8, ef: 30, ..Default::default() };

    // --- 1. Framework path: built-in Jaro-Winkler over Item::Text -------
    let mut f: Fishdbc<Item, MetricKind> =
        Fishdbc::new(MetricKind::JaroWinkler, params);
    for m in &messages {
        f.add(Item::Text(m.clone()));
    }
    let c = f.cluster(8);
    report("Jaro-Winkler (built-in)", n, f.dist_calls(), &c, &truth);

    // --- 2. Arbitrary closure: token Jaccard + length prior -------------
    // A domain expert writes *whatever* — here token-set Jaccard blended
    // with a relative-length penalty. Non-metric (triangle inequality can
    // fail); FISHDBC only needs symmetry.
    let token_jaccard = |a: &String, b: &String| -> f64 {
        let ta: HashSet<&str> = a.split_whitespace().collect();
        let tb: HashSet<&str> = b.split_whitespace().collect();
        let inter = ta.intersection(&tb).count() as f64;
        let union = (ta.len() + tb.len()) as f64 - inter;
        let jac = if union == 0.0 { 0.0 } else { 1.0 - inter / union };
        let len_penalty = (a.len() as f64 - b.len() as f64).abs()
            / (a.len() + b.len()).max(1) as f64;
        0.9 * jac + 0.1 * len_penalty
    };
    let mut f2 = Fishdbc::new(token_jaccard, params);
    for m in messages.iter().cloned() {
        f2.add(m);
    }
    let c2 = f2.cluster(8);
    report("token Jaccard (custom)", n, f2.dist_calls(), &c2, &truth);

    // --- 3. Counting wrapper: the paper's cost model ---------------------
    let counted = Counting::new(|a: &String, b: &String| {
        text::jaro_winkler(a, b)
    });
    let mut f3 = Fishdbc::new(counted, params);
    for m in messages.iter().cloned() {
        f3.add(m);
    }
    let c3 = f3.cluster(8);
    report("Jaro-Winkler (counted)", n, f3.metric().calls(), &c3, &truth);
    assert_eq!(f3.metric().calls(), f3.dist_calls());

    // Hierarchical view: drill into the condensed tree of run 2.
    println!("\nhierarchy (custom metric): {} condensed clusters, {} points in hierarchy",
        c2.n_hierarchical_clusters(),
        c2.n_hierarchical_clustered());

    let best = [&c, &c2, &c3]
        .iter()
        .map(|c| purity(&c.labels, &truth))
        .fold(0.0f64, f64::max);
    assert!(best > 0.9, "at least one metric should recover the templates");
    // Sub-quadratic cost on the well-resolved metric. (The token-Jaccard
    // closure has many tied distances — near-binary resolution — which
    // makes HNSW beams churn; a known worst case for graph indexes.)
    assert!(
        f.dist_calls() < (n * n / 2) as u64,
        "FISHDBC must stay below the pairwise-matrix cost ({} vs {})",
        f.dist_calls(),
        n * n / 2
    );
}
