//! Quickstart: cluster a small 2-D point cloud with FISHDBC using a plain
//! rust closure as the distance function — the paper's headline flexibility
//! ("our implementation accepts arbitrary Python functions as distance
//! measures"; here, arbitrary rust closures).
//!
//! Run with:
//! ```text
//! cargo run --release --example quickstart
//! ```

use fishdbc::distances::vector::euclidean;
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::util::rng::Rng;

fn main() {
    // Three Gaussian blobs plus some uniform background noise.
    let mut rng = Rng::new(42);
    let centers = [(0.0, 0.0), (25.0, 0.0), (12.0, 20.0)];
    let mut points: Vec<Vec<f32>> = Vec::new();
    for &(cx, cy) in &centers {
        for _ in 0..120 {
            points.push(vec![
                (cx + rng.normal() * 1.2) as f32,
                (cy + rng.normal() * 1.2) as f32,
            ]);
        }
    }
    for _ in 0..40 {
        // background noise spread over the bounding box
        points.push(vec![
            rng.range_f64(-8.0, 33.0) as f32,
            rng.range_f64(-8.0, 28.0) as f32,
        ]);
    }
    rng.shuffle(&mut points);

    // Any `Fn(&T, &T) -> f64` is a metric. Swap in *anything*: edit
    // distance over strings, Jaccard over sets, a domain-specific score...
    let metric = |a: &Vec<f32>, b: &Vec<f32>| euclidean(a, b);

    let params = FishdbcParams { min_pts: 10, ef: 20, ..Default::default() };
    let mut clusterer = Fishdbc::new(metric, params);

    // Incremental insertion: items can arrive one at a time, in any order.
    for p in points.iter().cloned() {
        clusterer.add(p);
    }

    // Extract a flat clustering (labels; -1 = noise) + the full hierarchy.
    let clustering = clusterer.cluster(10);

    println!("FISHDBC quickstart");
    println!("  items            : {}", clusterer.len());
    println!("  distance calls   : {} (vs n^2 = {})",
        clusterer.dist_calls(),
        clusterer.len() * clusterer.len());
    println!("  flat clusters    : {}", clustering.n_clusters);
    println!("  clustered points : {}", clustering.n_clustered());
    println!("  noise points     : {}",
        clustering.labels.len() - clustering.n_clustered());
    println!("  hierarchy        : {} condensed clusters",
        clustering.n_hierarchical_clusters());

    // Per-cluster summary with centroids (just for display).
    for (label, size) in clustering.cluster_sizes().iter().enumerate() {
        let members: Vec<&Vec<f32>> = points
            .iter()
            .zip(&clustering.labels)
            .filter(|(_, &l)| l == label as i32)
            .map(|(p, _)| p)
            .collect();
        let cx = members.iter().map(|p| p[0] as f64).sum::<f64>() / members.len() as f64;
        let cy = members.iter().map(|p| p[1] as f64).sum::<f64>() / members.len() as f64;
        println!("  cluster {label}: {size:4} points around ({cx:6.1}, {cy:6.1})");
    }

    // The same state keeps accepting new data: add a fourth blob and
    // re-cluster — this is the paper's *incremental* axis. Extraction is
    // orders of magnitude cheaper than building (paper Table 3).
    for _ in 0..120 {
        clusterer.add(vec![
            (40.0 + rng.normal() * 1.2) as f32,
            (20.0 + rng.normal() * 1.2) as f32,
        ]);
    }
    let t0 = std::time::Instant::now();
    let updated = clusterer.cluster(10);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "after streaming 120 more points: {} clusters ({} clustered) — \
         re-extraction took {dt:.4}s",
        updated.n_clusters,
        updated.n_clustered()
    );
    assert!(updated.n_clusters >= clustering.n_clusters);
}
