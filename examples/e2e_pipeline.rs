//! End-to-end pipeline: proves **all layers compose** on a real small
//! workload, and reports the paper's headline metrics.
//!
//! Stages:
//!   1. **L1/L2 via PJRT** — load the AOT-compiled JAX/Pallas distance
//!      artifacts (`make artifacts`) and cross-check the compiled kernels
//!      against the native rust metrics on real data batches. Python is
//!      *not* running: the HLO was lowered at build time.
//!   2. **L3 streaming build** — stream a labeled high-dimensional dataset
//!      (Blobs, Table 1) through the coordinator, with periodic
//!      re-clustering, exactly like `fishdbc stream`.
//!   3. **Baseline** — exact O(n²) HDBSCAN* on the same data.
//!   4. **Report** — the paper's headline claims, measured here:
//!      scalability (distance calls ≪ n², build ≫ cluster time) and
//!      quality (AMI*/ARI* close to the exact baseline; Tables 3, 6).
//!
//! Run with:
//! ```text
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::time::Instant;

use fishdbc::coordinator::{Coordinator, CoordinatorConfig};
use fishdbc::datasets;
#[cfg(feature = "xla")]
use fishdbc::distances::vector;
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactParams};
use fishdbc::metrics::score_external;
#[cfg(feature = "xla")]
use fishdbc::runtime::{default_artifacts_dir, Runtime};

/// Stage 1: cross-check the compiled PJRT kernels against the native rust
/// metrics on real data batches (needs the `xla` feature + `make artifacts`).
#[cfg(feature = "xla")]
fn stage_pjrt(ds: &datasets::Dataset, n: usize, dim: usize) {
    let arts = default_artifacts_dir();
    match Runtime::load(&arts) {
        Ok(rt) => {
            println!("  platform {:?}, {} modules", rt.platform(), rt.module_names().len());
            let module = rt
                .find_query_module("euclidean", dim)
                .expect("euclidean module covering dim");
            println!("  using {} (B={}, D={}, k={:?})", module.name, module.b, module.d, module.k);
            let name = module.name.clone();
            let b = module.b;

            // batch the first item against the next `b` as a real query
            let q = ds.items[0].as_dense();
            let cands: Vec<&[f32]> =
                ds.items[1..=b.min(n - 1)].iter().map(|it| it.as_dense()).collect();
            let t0 = Instant::now();
            let out = rt.query_topk(&name, q, &cands).expect("kernel exec");
            let kernel_t = t0.elapsed().as_secs_f64();

            // verify against native rust on every row
            let mut max_err = 0f64;
            for (i, c) in cands.iter().enumerate() {
                let want = vector::euclidean(q, c);
                max_err = max_err.max((out.dists[i] as f64 - want).abs());
            }
            println!(
                "  {} distances in {:.4}s via PJRT, max |kernel-native| = {:.2e}",
                cands.len(),
                kernel_t,
                max_err
            );
            assert!(max_err < 1e-2, "compiled kernel disagrees with native");
            println!("  nearest neighbors of item 0: {:?}", &out.topk[..3.min(out.topk.len())]);
        }
        Err(e) => {
            println!("  SKIPPED — artifacts not built ({e:#}); run `make artifacts`");
        }
    }
}

#[cfg(not(feature = "xla"))]
fn stage_pjrt(_ds: &datasets::Dataset, _n: usize, _dim: usize) {
    println!("  SKIPPED — rebuild with `--features xla` (and `make artifacts`)");
}

fn main() {
    let n = 3000;
    let dim = 128;
    println!("=== FISHDBC end-to-end pipeline ===");
    println!("workload: blobs n={n} dim={dim} (10 Gaussian centers, Table 1)\n");
    let ds = datasets::blobs::generate(n, dim, 10, 20260710);
    ds.validate().expect("generated dataset must be valid");
    let truth = ds.primary_labels().expect("blobs is labeled").to_vec();

    // ---- stage 1: PJRT kernels (L1/L2) ------------------------------------
    println!("[1/4] PJRT runtime: compiled JAX/Pallas distance kernels");
    stage_pjrt(&ds, n, dim);

    // ---- stage 2: streaming FISHDBC build (L3) -----------------------------
    println!("\n[2/4] streaming FISHDBC build (coordinator, chunked ingestion)");
    let params = FishdbcParams { min_pts: 10, ef: 20, ..Default::default() };
    let coord = Coordinator::spawn(ds.metric, CoordinatorConfig {
        fishdbc: params,
        mcs: 10,
        recluster_every: 1000,
        queue_depth: 8,
    });
    let t0 = Instant::now();
    for chunk in ds.items.chunks(250) {
        coord.add_batch(chunk.to_vec());
    }
    let snap = coord.cluster(10);
    let wall_build = t0.elapsed().as_secs_f64();
    let stats = coord.stats();
    println!(
        "  built in {wall_build:.2}s wall ({:.2}s cpu build, {} auto re-clusters)",
        stats.build_secs, stats.reclusters
    );
    println!(
        "  {} dist calls = {:.2}% of n² ; cluster extraction {:.4}s",
        stats.fishdbc.dist_calls,
        100.0 * stats.fishdbc.dist_calls as f64 / (n as f64 * n as f64),
        snap.extract_secs
    );
    let fish = snap.clustering.clone();
    coord.shutdown();

    // ---- stage 3: exact HDBSCAN* baseline ----------------------------------
    println!("\n[3/4] exact HDBSCAN* baseline (full O(n²) reachability)");
    let t0 = Instant::now();
    let exact = exact_hdbscan(
        &ds.items,
        &ds.metric,
        ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
    )
    .expect("exact baseline");
    let exact_t = t0.elapsed().as_secs_f64();
    println!(
        "  done in {exact_t:.2}s with {} dist calls ({}x FISHDBC's)",
        exact.dist_calls,
        exact.dist_calls / stats.fishdbc.dist_calls.max(1)
    );

    // ---- stage 4: headline report -------------------------------------------
    println!("\n[4/4] paper-vs-measured headline metrics");
    let sf = score_external(&fish.labels, &truth);
    let se = score_external(&exact.clustering.labels, &truth);
    println!("  {:<22} {:>8} {:>8} {:>8} {:>8} {:>9} {:>10}",
        "", "AMI", "AMI*", "ARI", "ARI*", "clusters", "clustered");
    println!(
        "  {:<22} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>9} {:>10}",
        "FISHDBC (ef=20)", sf.ami, sf.ami_star, sf.ari, sf.ari_star,
        fish.n_clusters, fish.n_clustered()
    );
    println!(
        "  {:<22} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>9} {:>10}",
        "HDBSCAN* (exact)", se.ami, se.ami_star, se.ari, se.ari_star,
        exact.clustering.n_clusters, exact.clustering.n_clustered()
    );

    let speedup = exact_t / wall_build;
    println!("\nheadline: build speedup {speedup:.1}x, dist-call reduction {:.0}x, \
              cluster-vs-build ratio {:.0}x cheaper",
        exact.dist_calls as f64 / stats.fishdbc.dist_calls as f64,
        stats.build_secs / snap.extract_secs.max(1e-9));

    // The paper's claims, asserted on this workload (Tables 3, 6, 8):
    assert!(
        stats.fishdbc.dist_calls * 4 < exact.dist_calls,
        "FISHDBC must compute far fewer distances than the exact baseline"
    );
    assert!(
        snap.extract_secs * 10.0 < stats.build_secs.max(1e-3),
        "cluster extraction must be much cheaper than the build"
    );
    assert!(sf.ami_star > 0.85, "quality must stay close to exact (AMI* {})", sf.ami_star);
    assert!(se.ami_star > 0.85, "exact baseline sanity");
    println!("\nall end-to-end assertions passed ✔");
}
