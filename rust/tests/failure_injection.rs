//! Failure injection: adversarial metrics, degenerate data, and hostile
//! inputs must never hang, corrupt state, or produce out-of-contract
//! output (labels outside [-1, k), missing points, broken forests).

use fishdbc::datasets;
use fishdbc::distances::{Item, Metric, MetricKind};
use fishdbc::engine::Engine;
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactParams};
use fishdbc::util::rng::Rng;

fn params(min_pts: usize, ef: usize) -> FishdbcParams {
    FishdbcParams { min_pts, ef, ..Default::default() }
}

fn assert_contract(labels: &[i32], n_clusters: usize, n: usize) {
    assert_eq!(labels.len(), n);
    for &l in labels {
        assert!(l >= -1 && (l as i64) < n_clusters as i64, "label {l}");
    }
}

/// All points identical: every distance is 0. Must terminate, never panic
/// on ties. With the paper's semantics (root excluded, Lemma 3.3) a single
/// uniform cluster is all noise; with `allow_single_cluster` (hdbscan's
/// escape hatch) it becomes one cluster.
#[test]
fn all_identical_points() {
    let mut f = Fishdbc::new(MetricKind::Euclidean, params(5, 20));
    for _ in 0..200 {
        f.add(Item::Dense(vec![1.0, 1.0, 1.0]));
    }
    let c = f.cluster(5);
    assert_contract(&c.labels, c.n_clusters, 200);
    assert_eq!(c.n_clusters, 0, "root is excluded by default (Lemma 3.3)");

    let c = f.cluster_opts(5, true);
    assert_contract(&c.labels, c.n_clusters, 200);
    assert_eq!(c.n_clusters, 1, "allow_single_cluster selects the root");
    assert_eq!(c.n_clustered(), 200);
}

/// A constant metric (everything equidistant) is a worst case for HNSW
/// navigation; it must still terminate with sane output.
#[test]
fn constant_metric() {
    let m = |_: &u32, _: &u32| 1.0f64;
    let mut f = Fishdbc::new(m, params(4, 10));
    for i in 0..150u32 {
        f.add(i);
    }
    let c = f.cluster_opts(4, true);
    assert_contract(&c.labels, c.n_clusters, 150);
    // every pair is reachable at the same density: one (root) cluster
    assert_eq!(c.n_clusters, 1);
    assert_eq!(c.n_clustered(), 150);
}

/// A metric returning NaN for some pairs (broken user code). We cannot
/// promise good clustering — only termination, contract-shaped output,
/// and no poisoned panic.
#[test]
fn nan_metric_does_not_hang_or_panic() {
    let m = |a: &Vec<f32>, b: &Vec<f32>| {
        let d = fishdbc::distances::vector::euclidean(a, b);
        if (a[0] * 1000.0) as i64 % 7 == 0 {
            f64::NAN
        } else {
            d
        }
    };
    let mut rng = Rng::new(3);
    let mut f = Fishdbc::new(m, params(4, 10));
    for _ in 0..120 {
        f.add(vec![rng.f32() * 10.0, rng.f32() * 10.0]);
    }
    let c = f.cluster(4);
    assert_contract(&c.labels, c.n_clusters, 120);
}

/// An asymmetric "metric" (violates the paper's symmetry requirement).
/// FISHDBC's output contract must still hold.
#[test]
fn asymmetric_metric_still_terminates() {
    let m = |a: &f64, b: &f64| if a < b { (b - a) * 2.0 } else { a - b };
    let mut rng = Rng::new(4);
    let mut f = Fishdbc::new(m, params(4, 10));
    for _ in 0..100 {
        f.add(rng.f64() * 50.0);
    }
    let c = f.cluster(4);
    assert_contract(&c.labels, c.n_clusters, 100);
}

/// Zero-dimensional / empty payloads.
#[test]
fn empty_vectors_and_strings() {
    let mut f = Fishdbc::new(MetricKind::Euclidean, params(3, 10));
    for _ in 0..30 {
        f.add(Item::Dense(vec![]));
    }
    let c = f.cluster(3);
    assert_contract(&c.labels, c.n_clusters, 30);

    let mut f = Fishdbc::new(MetricKind::JaroWinkler, params(3, 10));
    for i in 0..30 {
        f.add(Item::Text(if i % 2 == 0 { String::new() } else { "x".into() }));
    }
    let c = f.cluster(3);
    assert_contract(&c.labels, c.n_clusters, 30);
}

/// Huge coordinates / infinities in the data (not the metric).
#[test]
fn extreme_coordinates() {
    let mut f = Fishdbc::new(MetricKind::Euclidean, params(3, 10));
    let mut rng = Rng::new(5);
    for i in 0..80 {
        let base = if i % 2 == 0 { 1e30f32 } else { -1e30 };
        f.add(Item::Dense(vec![base + rng.f32(), rng.f32()]));
    }
    let c = f.cluster(3);
    assert_contract(&c.labels, c.n_clusters, 80);
    // two groups, astronomically separated: must not be merged
    assert!(c.n_clusters >= 2, "clusters: {}", c.n_clusters);
}

/// Duplicated items interleaved with unique ones (heavy distance ties).
#[test]
fn many_duplicates() {
    let mut rng = Rng::new(6);
    let mut f = Fishdbc::new(MetricKind::Euclidean, params(5, 20));
    for i in 0..300 {
        if i % 3 == 0 {
            f.add(Item::Dense(vec![5.0, 5.0]));
        } else {
            f.add(Item::Dense(vec![
                rng.f32() * 100.0,
                rng.f32() * 100.0,
            ]));
        }
    }
    let c = f.cluster(5);
    assert_contract(&c.labels, c.n_clusters, 300);
    // the 100 duplicates form a zero-radius ultra-dense cluster
    let dup_label = c.labels[0];
    assert!(dup_label >= 0, "duplicates must be clustered");
}

/// Exact baseline under the same adversarial conditions (default
/// semantics: uniform data ⇒ root only ⇒ all noise, like FISHDBC's).
#[test]
fn exact_baseline_handles_degenerate_input() {
    let items: Vec<Vec<f32>> = vec![vec![2.0, 2.0]; 60];
    let metric = |a: &Vec<f32>, b: &Vec<f32>| {
        fishdbc::distances::vector::euclidean(a, b)
    };
    let r = exact_hdbscan(
        &items,
        &metric,
        ExactParams { min_pts: 5, mcs: 5, matrix_budget: None },
    )
    .unwrap();
    assert_contract(&r.clustering.labels, r.clustering.n_clusters, 60);
    assert_eq!(r.clustering.n_clusters, 0, "uniform data = all noise by default");
}

/// MinPts larger than the dataset: every core distance stays infinite.
#[test]
fn min_pts_exceeds_dataset() {
    let mut f = Fishdbc::new(MetricKind::Euclidean, params(50, 20));
    for i in 0..20 {
        f.add(Item::Dense(vec![i as f32]));
    }
    let c = f.cluster(50);
    assert_contract(&c.labels, c.n_clusters, 20);
    assert_eq!(c.n_clusters, 0, "nothing can be dense enough");
}

/// Alternating add/cluster with pathological α (flush every add).
#[test]
fn tiny_alpha_flushes_constantly() {
    let mut rng = Rng::new(7);
    let p = FishdbcParams { min_pts: 4, ef: 10, alpha: 0.001, seed: 1 };
    let mut f = Fishdbc::new(MetricKind::Euclidean, p);
    for _ in 0..150 {
        f.add(Item::Dense(vec![rng.f32() * 10.0, rng.f32() * 10.0]));
    }
    assert!(f.stats().mst_updates >= 100, "α≈0 must flush constantly");
    let c = f.cluster(4);
    assert_contract(&c.labels, c.n_clusters, 150);
}

// ------------------------------------------------------ persisted state --
// Checked-in FISHENG fixtures (rust/tests/data/, regenerated by
// make_fixtures.py) pin the on-disk container formats: a v1 file from
// before the recluster pipeline existed, and a v2 file with bridge
// buffers, coverage watermarks and a cached global MSF. Hostile *and*
// merely old inputs must keep loading forever.

fn fixture(name: &str) -> Vec<u8> {
    let path =
        format!("{}/tests/data/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

/// v1 engine files (no pipeline state) must load with empty bridge
/// buffers and recluster from scratch, then keep ingesting normally.
#[test]
fn fisheng_v1_fixture_loads_and_reclusters() {
    let engine = Engine::load(fixture("fisheng_v1.bin").as_slice()).unwrap();
    assert_eq!(engine.len(), 8);
    assert_eq!(engine.n_shards(), 2);
    assert_eq!(engine.epoch(), 0, "v1 has no epoch counter");
    assert_eq!(engine.config().recluster_every, 0);

    let snap = engine.cluster(2);
    assert_eq!(snap.n_items, 8);
    assert_eq!(snap.epoch, 1);
    assert_eq!(snap.n_changed_shards, 2, "v1 resume merges from scratch");
    assert_contract(&snap.clustering.labels, snap.clustering.n_clusters, 8);

    // the resumed engine is fully live: ingest more, recluster, serve
    engine.add_batch(vec![
        Item::Dense(vec![0.5, 0.5]),
        Item::Dense(vec![2.5, 0.5]),
    ]);
    let snap = engine.cluster(2);
    assert_eq!(snap.n_items, 10);
    assert_contract(&snap.clustering.labels, snap.clustering.n_clusters, 10);
    let l = engine.label(&Item::Dense(vec![0.1, 0.1]));
    assert!(l >= -1 && (l as i64) < snap.clustering.n_clusters as i64);
    engine.shutdown();
}

/// v2 engine files carry the pipeline epoch state; a reloaded engine must
/// recluster *incrementally* (matching change stamps, no bridge re-search).
/// Saving it re-emits the state as a v3 container (the deletion-state
/// upgrade) whose own save → load → save cycle must be byte-stable —
/// proving the chunked copy-on-write stores (and the empty deletion
/// state) never leak their in-memory layout into the container format.
#[test]
fn fisheng_v2_fixture_reclusters_incrementally_and_upgrades_to_v3() {
    let bytes = fixture("fisheng_v2.bin");
    let engine = Engine::load(bytes.as_slice()).unwrap();
    assert_eq!(engine.len(), 8);
    assert_eq!(engine.epoch(), 3, "epoch counter resumes");

    // the upgrade rewrite: same state, v3 container
    let mut v3 = Vec::new();
    engine.save(&mut v3).unwrap();
    assert_eq!(v3[..8], bytes[..8], "container magic changed");
    assert_eq!(v3[8], 3, "save must emit the current (v3) container");
    let upgraded = Engine::load(v3.as_slice()).unwrap();
    assert_eq!(upgraded.len(), 8);
    assert_eq!(upgraded.epoch(), 3);
    assert!(upgraded.deleted_globals().is_empty());
    let mut again = Vec::new();
    upgraded.save(&mut again).unwrap();
    assert_eq!(again, v3, "v3 save(load(save)) changed the bytes");
    upgraded.shutdown();

    let snap = engine.cluster(2);
    assert_eq!(snap.epoch, 4);
    assert_eq!(snap.n_items, 8);
    assert_eq!(snap.n_changed_shards, 0, "stamps match: delta path");
    assert_eq!(snap.n_bridge_edges, 0, "no bridge re-search after resume");
    assert_contract(&snap.clustering.labels, snap.clustering.n_clusters, 8);
    let stats = engine.stats();
    assert_eq!(stats.bridge_covered, 8, "coverage watermarks resumed");
    assert!(stats.bridge_edges > 0, "bridge buffers resumed");
    engine.shutdown();
}

/// The chunked copy-on-write stores must serialize identically to the
/// dense layout: a FISHDBC whose chunks are pinned by live snapshots
/// (forcing the COW paths throughout construction) saves byte-for-byte
/// the same state as an undisturbed twin over the same stream.
#[test]
fn chunked_snapshot_state_serializes_identically_to_dense() {
    let ds = datasets::blobs::generate(300, 8, 3, 21);
    let p = FishdbcParams { min_pts: 5, ef: 15, ..Default::default() };
    let mut plain = Fishdbc::new(MetricKind::Euclidean, p);
    let mut cow = Fishdbc::new(MetricKind::Euclidean, p);
    let mut pinned = Vec::new();
    for (i, it) in ds.items.iter().enumerate() {
        plain.add(it.clone());
        cow.add(it.clone());
        if i % 40 == 0 {
            // pin the current chunks, exactly like a ShardSnap capture
            pinned.push((
                cow.items().clone(),
                cow.hnsw().clone(),
                cow.cores().clone(),
            ));
        }
    }
    let mut a = Vec::new();
    let mut b = Vec::new();
    plain.save(&mut a).unwrap();
    cow.save(&mut b).unwrap();
    assert_eq!(a, b, "held snapshots changed the serialized state");
    drop(pinned);

    // and a full save → load → save cycle is byte-stable
    let reloaded = Fishdbc::<Item, MetricKind>::load(b.as_slice()).unwrap();
    let mut c = Vec::new();
    reloaded.save(&mut c).unwrap();
    assert_eq!(b, c, "save/load/save drifted");
}

/// A metric that is extremely spiky (almost-zero distances mixed with huge
/// ones) stresses lambda computation (1/d capping).
#[test]
fn spiky_distances_do_not_break_lambdas() {
    let m = |a: &f64, b: &f64| {
        let d = (a - b).abs();
        if d < 0.5 {
            1e-300 // effectively zero: λ capping path
        } else {
            1e300
        }
    };
    let mut f = Fishdbc::new(m, params(3, 10));
    for i in 0..60 {
        f.add((i / 10) as f64); // ten groups of six identical values
    }
    let c = f.cluster(3);
    assert_contract(&c.labels, c.n_clusters, 60);
    assert!(c.n_clusters >= 2);
}
