//! End-to-end tests for the `fishdbc serve` network layer: the framed
//! protocol over real loopback sockets, conn-pool backpressure, the
//! graceful-drain durability contract (an acknowledged ingest is never
//! lost), and the serving-path starvation bound the ISSUE 8 satellite
//! pins (labels must keep completing under heavy concurrent ingest).

use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use fishdbc::datasets;
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::obs::CounterId;
use fishdbc::persist::{BinReader, BinWriter, FrameworkCodec, ItemCodec};
use fishdbc::serve::{frame, Client, IngestReply, ServeConfig, Server};
use fishdbc::util::rng::Rng;
use fishdbc::{Item, MetricKind};

fn blob_engine(n: usize, shards: usize) -> (Arc<Engine>, Vec<Item>) {
    let ds = datasets::blobs::generate(n, 8, 3, 42);
    let engine: Arc<Engine> =
        Arc::new(Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 10, ..Default::default() },
            shards,
            mcs: 5,
            ..Default::default()
        }));
    for chunk in ds.items.chunks(64) {
        engine.add_batch(chunk.to_vec());
    }
    engine.cluster(5);
    (engine, ds.items)
}

#[test]
fn framed_protocol_round_trip() {
    let (engine, items) = blob_engine(300, 2);
    let server = Server::start(
        Arc::clone(&engine),
        FrameworkCodec,
        "127.0.0.1:0",
        ServeConfig::default(),
    )
    .expect("server start");

    let mut client =
        Client::connect(server.addr(), FrameworkCodec).expect("connect");
    client.set_timeout(Some(Duration::from_secs(20))).unwrap();

    let (n0, epoch0) = client.ping().expect("ping");
    assert_eq!(n0, 300);
    assert!(epoch0 >= 1, "preload published an epoch");

    // Label answers must agree with the engine's own serving primitive
    // against the same pinned epoch
    let snap = engine.latest().expect("epoch");
    let got = client.label(&items[0], 5).expect("label");
    assert_eq!(got, engine.label_against(&items[0], &snap, 5));

    let batch = client.label_batch(&items[..10], 0).expect("label_batch");
    assert_eq!(batch.len(), 10);
    let k = engine.config().fishdbc.min_pts;
    for (item, &label) in items[..10].iter().zip(&batch) {
        assert_eq!(label, engine.label_against(item, &snap, k), "k=0 -> min_pts");
    }

    let extra = datasets::blobs::generate(20, 8, 3, 7).items;
    match client.ingest(&extra).expect("ingest") {
        IngestReply::Accepted(n) => assert_eq!(n, 20),
        IngestReply::Busy => panic!("idle engine must not be Busy"),
    }
    let removed = client.remove(&items[..2]).expect("remove");
    assert!(removed >= 2, "both stored copies tombstoned");

    let stats = client.stats_json().expect("stats");
    assert!(stats.contains("fishdbc-stats-v1"), "got: {stats:.80}");

    let (n1, _) = client.ping().expect("ping");
    assert_eq!(n1, 320, "ids are monotone: 300 preloaded + 20 ingested");

    let reg = engine.registry();
    assert!(reg.counter(CounterId::ServeRequests).get() >= 7);
    assert_eq!(reg.counter(CounterId::ServeLabelOps).get(), 11);
    assert_eq!(reg.counter(CounterId::ServeIngestOps).get(), 20);
    assert_eq!(reg.counter(CounterId::ServeConns).get(), 1);

    server.shutdown();
    assert!(client.at_eof(), "drained server closed the connection");
}

/// The durability contract: every `Ingest` the server acknowledged is in
/// the engine after a graceful drain, even when the drain lands in the
/// middle of active client streams. Acks are synchronous (the client has
/// the Ok frame in hand before counting), so after `shutdown()`'s flush
/// barrier the engine's id count must equal the sum of acked items.
#[test]
fn graceful_drain_loses_no_acknowledged_ingest() {
    let engine: Arc<Engine> =
        Arc::new(Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 10, ..Default::default() },
            shards: 2,
            mcs: 5,
            ..Default::default()
        }));
    let server = Server::start(
        Arc::clone(&engine),
        FrameworkCodec,
        "127.0.0.1:0",
        ServeConfig { threads: 3, ..Default::default() },
    )
    .expect("server start");
    let addr = server.addr();

    let clients: Vec<_> = (0..3)
        .map(|c| {
            std::thread::spawn(move || {
                let items =
                    datasets::blobs::generate(400, 8, 3, 100 + c).items;
                let mut client = match Client::connect(addr, FrameworkCodec) {
                    Ok(cl) => cl,
                    Err(_) => return 0u64, // refused mid-drain: 0 acked
                };
                client.set_timeout(Some(Duration::from_secs(10))).ok();
                let mut acked = 0u64;
                for chunk in items.chunks(20) {
                    match client.ingest(chunk) {
                        Ok(IngestReply::Accepted(n)) => acked += n,
                        Ok(IngestReply::Busy) => continue,
                        // server draining: stop, keep what was acked
                        Err(_) => break,
                    }
                }
                acked
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(50));
    server.shutdown(); // drain lands mid-stream for at least one client
    let total_acked: u64 =
        clients.into_iter().map(|h| h.join().expect("client")).sum();

    assert!(total_acked > 0, "drain landed before any ack — tune the sleep");
    assert_eq!(
        engine.len() as u64,
        total_acked,
        "acked ingests lost (or unacked ones counted) across the drain"
    );
}

/// `Vec<i64>` codec for the generic-engine tests below: the serve layer
/// must work for any item type with a codec, not just the dynamic `Item`.
struct I64VecCodec;

impl ItemCodec<Vec<i64>> for I64VecCodec {
    fn write_item<W: io::Write>(
        &self,
        w: &mut BinWriter<W>,
        item: &Vec<i64>,
    ) -> io::Result<()> {
        w.len(item.len())?;
        for &x in item {
            w.u64(x as u64)?;
        }
        Ok(())
    }

    fn read_item<R: io::Read>(
        &self,
        r: &mut BinReader<R>,
    ) -> io::Result<Vec<i64>> {
        let n = r.len()?;
        (0..n).map(|_| r.u64().map(|x| x as i64)).collect()
    }
}

/// A saturated engine answers `Ingest` with an explicit `Busy` frame (no
/// blocking, no partial admission), and the same batch succeeds once the
/// queues drain.
#[test]
fn busy_surfaces_through_the_wire_and_recovers() {
    // a gate the metric blocks on: wedges the single shard worker so its
    // bounded command queue fills deterministically
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let metric = {
        let gate = Arc::clone(&gate);
        move |a: &Vec<i64>, b: &Vec<i64>| {
            let (open, cv) = &*gate;
            let mut g = open.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
            a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum()
        }
    };
    let engine = Arc::new(Engine::spawn(metric, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 2, ef: 4, ..Default::default() },
        shards: 1,
        mcs: 2,
        queue_depth: 2,
        ..Default::default()
    }));
    let server = Server::start(
        Arc::clone(&engine),
        I64VecCodec,
        "127.0.0.1:0",
        ServeConfig::default(),
    )
    .expect("server start");
    let mut client =
        Client::connect(server.addr(), I64VecCodec).expect("connect");
    client.set_timeout(Some(Duration::from_secs(20))).unwrap();

    // first item computes no distances; the second blocks in the metric
    // with the worker wedged, so >= 2 of these 6 stay queued -> Busy
    let mut accepted = 0u64;
    let mut busy = Vec::new();
    for i in 0..6i64 {
        match client.ingest(&[vec![i, i]]).expect("ingest") {
            IngestReply::Accepted(n) => accepted += n,
            IngestReply::Busy => busy.push(vec![i, i]),
        }
    }
    assert!(!busy.is_empty(), "full queue must answer Busy");
    assert!(accepted >= 2, "the queue has room for queue_depth batches");

    // open the gate; the wedged worker drains and Busy items go through
    {
        let (open, cv) = &*gate;
        *open.lock().unwrap() = true;
        cv.notify_all();
    }
    engine.flush();
    for item in &busy {
        let n = client
            .ingest_retrying(
                std::slice::from_ref(item),
                Duration::from_millis(20),
                100,
            )
            .expect("retry after gate open");
        accepted += n;
    }
    engine.flush();
    assert_eq!(engine.len() as u64, accepted, "every ack is in the engine");
    assert_eq!(accepted, 6);
    server.shutdown();
}

/// ISSUE 8 satellite: `label_against` holds a shard `state.read()` for
/// its whole HNSW search, so heavy concurrent `add_batch` traffic (writer
/// threads taking the same lock) can delay it — but labels must keep
/// completing within a sane bound, never starve. A deliberately slow
/// metric (~20 us spin per call) makes every lock hold substantial.
#[test]
fn labels_complete_within_bound_under_heavy_ingest() {
    let metric = |a: &Vec<i64>, b: &Vec<i64>| {
        let t = Instant::now();
        while t.elapsed() < Duration::from_micros(20) {
            std::hint::spin_loop();
        }
        a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum()
    };
    let engine = Arc::new(Engine::spawn(metric, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 3, ef: 8, ..Default::default() },
        shards: 2,
        mcs: 3,
        ..Default::default()
    }));
    let mut rng = Rng::new(9);
    let preload: Vec<Vec<i64>> = (0..400)
        .map(|_| vec![rng.below(100) as i64, rng.below(100) as i64])
        .collect();
    for chunk in preload.chunks(64) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = {
        engine.flush();
        Arc::new(engine.cluster(3))
    };

    // writer threads hammer add_batch while labels are timed
    let stop = Arc::new(AtomicBool::new(false));
    let writers: Vec<_> = (0..2)
        .map(|w| {
            let engine = Arc::clone(&engine);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut rng = Rng::new(77 + w);
                while !stop.load(Ordering::Relaxed) {
                    let batch: Vec<Vec<i64>> = (0..32)
                        .map(|_| {
                            vec![
                                rng.below(100) as i64,
                                rng.below(100) as i64,
                            ]
                        })
                        .collect();
                    engine.add_batch(batch);
                }
            })
        })
        .collect();

    let mut max = Duration::ZERO;
    for i in 0..30 {
        let probe = &preload[i * 13 % preload.len()];
        let t0 = Instant::now();
        let _ = engine.label_against(probe, &snap, 3);
        max = max.max(t0.elapsed());
    }
    stop.store(true, Ordering::Relaxed);
    for w in writers {
        w.join().expect("writer");
    }
    println!("max label latency under ingest pressure: {max:?}");
    assert!(
        max < Duration::from_secs(10),
        "label starved behind ingest writers: {max:?}"
    );
}

/// ISSUE 9 acceptance: the hierarchy-as-a-service trio over the wire
/// matches the in-process calls exactly — `Tree` bit-for-bit (floats
/// travel as IEEE-754 bit patterns), `RelabelAt` label-for-label with a
/// non-representable eps round-tripping into the *same* extraction memo
/// key, and `LabelAt` agreeing with `Engine::label_at` (k = 0 resolving
/// to the server's min_pts).
#[test]
fn hierarchy_frames_match_in_process_bit_exactly() {
    use fishdbc::engine::{ExtractionMode, ExtractionParams};

    let (engine, items) = blob_engine(300, 2);
    let server = Server::start(
        Arc::clone(&engine),
        FrameworkCodec,
        "127.0.0.1:0",
        ServeConfig::default(),
    )
    .expect("server start");
    let mut client =
        Client::connect(server.addr(), FrameworkCodec).expect("connect");
    client.set_timeout(Some(Duration::from_secs(20))).unwrap();

    // Tree: the wire nodes equal the pinned snapshot's, bit for bit
    let snap = engine.latest().expect("epoch");
    let (epoch, got) = client.tree().expect("tree");
    assert_eq!(epoch, snap.epoch);
    let want = snap.tree();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.id, w.id);
        assert_eq!(g.parent, w.parent);
        assert_eq!(g.lambda_birth.to_bits(), w.lambda_birth.to_bits());
        assert_eq!(g.stability.to_bits(), w.stability.to_bits());
        assert_eq!(g.size, w.size);
    }

    // RelabelAt first over the wire (populating the memo), then the same
    // params in-process: a memo hit proves the wire-decoded key is
    // bit-identical (0.1 + 0.2 has no short decimal representation)
    let params = ExtractionParams {
        mcs: 10,
        eps: 0.1 + 0.2,
        mode: ExtractionMode::HybridEps,
    };
    let (re_epoch, n_clusters, labels) =
        client.relabel_at(params).expect("relabel_at");
    let again = engine.relabel_at(params);
    assert!(again.memo_hit, "wire eps decoded to a different memo key");
    assert_eq!(re_epoch, again.epoch);
    assert_eq!(n_clusters, again.clustering.n_clusters);
    assert_eq!(labels, again.clustering.labels);

    // LabelAt: agrees with the in-process probe; k = 0 -> server min_pts
    let leaf =
        ExtractionParams { mcs: 5, eps: 0.0, mode: ExtractionMode::Leaf };
    let got_l = client.label_at(&items[3], 0, leaf).expect("label_at");
    let k = engine.config().fishdbc.min_pts;
    assert_eq!(got_l, engine.label_at(&items[3], k, leaf));

    // counter semantics: Tree counts ops, Relabel counts labeled items
    // (the full relabeling plus the single probe), and requests 2..n on
    // one connection land in the keep-alive counter
    let reg = engine.registry();
    assert_eq!(reg.counter(CounterId::ServeTreeOps).get(), 1);
    assert_eq!(
        reg.counter(CounterId::ServeRelabelOps).get(),
        labels.len() as u64 + 1
    );
    assert_eq!(reg.counter(CounterId::ServeKeepaliveRequests).get(), 2);
    server.shutdown();
}

/// ISSUE 9 satellite: the response-write deadline. A client that floods
/// requests but never reads its responses ("stalled reader") eventually
/// blocks the handler's response write once the TCP buffers fill; the
/// read-side idle timeout never fires (the pipe stays full of queued
/// requests), so only `write_timeout` can free the pool thread. With one
/// handler thread, a second client's ping completing promptly proves it
/// did.
#[test]
fn stalled_reader_hits_write_deadline_and_frees_the_pool_thread() {
    let (engine, _) = blob_engine(300, 2);
    let server = Server::start(
        Arc::clone(&engine),
        FrameworkCodec,
        "127.0.0.1:0",
        ServeConfig {
            threads: 1,
            io_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_millis(200),
            ..Default::default()
        },
    )
    .expect("server start");

    // raw stalled reader: queue thousands of Stats requests (each answer
    // is a multi-KB document) and never read a byte back
    let mut stalled =
        std::net::TcpStream::connect(server.addr()).expect("connect");
    stalled
        .set_write_timeout(Some(Duration::from_millis(500)))
        .unwrap();
    let req = frame::encode_stats();
    for _ in 0..20_000 {
        // errors once the server drops the stalled connection — that is
        // the point of the test, keep going until then
        if frame::write_frame(&mut stalled, &req).is_err() {
            break;
        }
    }

    // the single pool thread must come back well before the 30 s read
    // timeout could possibly have freed it
    let t0 = Instant::now();
    let mut c2 =
        Client::connect(server.addr(), FrameworkCodec).expect("connect");
    c2.set_timeout(Some(Duration::from_secs(25))).unwrap();
    let (n, _) = c2.ping().expect("ping while the stalled conn is live");
    assert_eq!(n, 300);
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "write deadline did not free the handler: {:?}",
        t0.elapsed()
    );
    let reg = engine.registry();
    assert!(
        reg.counter(CounterId::ServeKeepaliveRequests).get() > 0,
        "the stalled connection served requests before wedging"
    );
    server.shutdown();
}

/// Protocol errors answer a well-formed `Err` frame, then the server
/// closes the connection (no resync guessing on a corrupt stream).
#[test]
fn unknown_op_answers_err_frame_and_closes() {
    let (engine, _) = blob_engine(50, 1);
    let server = Server::start(
        Arc::clone(&engine),
        FrameworkCodec,
        "127.0.0.1:0",
        ServeConfig::default(),
    )
    .expect("server start");

    let mut stream =
        std::net::TcpStream::connect(server.addr()).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    frame::write_frame(&mut stream, &[0xEE]).expect("send bogus op");
    let resp = frame::read_frame(&mut stream)
        .expect("read")
        .expect("server answered before closing");
    assert_eq!(resp[0], frame::ST_ERR);
    let mut r = BinReader::new(&resp[1..]);
    assert!(r.str().expect("err message").contains("unknown op"));
    assert!(
        frame::read_frame(&mut stream).expect("clean close").is_none(),
        "connection stays open after a protocol error"
    );
    assert_eq!(engine.registry().counter(CounterId::ServeErrors).get(), 1);
    server.shutdown();
}
