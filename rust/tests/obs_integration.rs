//! Telemetry integration: the observability layer must observe without
//! perturbing — label-path counters stay exact under concurrency with
//! merges, the `/metrics` endpoint serves while ingest and reclustering
//! are running, and a dropped engine degrades the endpoint gracefully
//! (metrics keep answering from the final totals; `/stats.json` turns
//! 404) instead of wedging scrapers.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use fishdbc::datasets;
use fishdbc::distances::MetricKind;
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::obs::{CounterId, HistId};

fn spawn_engine(shards: usize, n: usize, seed: u64) -> Engine {
    let items = datasets::blobs::generate(n, 16, 3, seed).items;
    let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
        shards,
        mcs: 5,
        ..Default::default()
    });
    for chunk in items.chunks(128) {
        engine.add_batch(chunk.to_vec());
    }
    engine
}

/// Plain-text HTTP GET against the metrics server; returns (status, body).
fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect to metrics server");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n")
        .expect("send request");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("malformed status line in {raw:?}"));
    let body = match raw.split_once("\r\n\r\n") {
        Some((_, b)) => b.to_string(),
        None => String::new(),
    };
    (status, body)
}

/// Acceptance: the label path's telemetry is O(1) lock-free atomics, so
/// hammering `label_against` from several threads *while merges run* must
/// lose no samples — the counter and histogram totals equal the number of
/// queries issued, exactly, at every shard count.
#[test]
fn label_telemetry_is_exact_under_concurrent_merges() {
    for shards in [1usize, 2, 4] {
        let engine = spawn_engine(shards, 600, 71);
        let snap = engine.cluster(5);
        let probes = datasets::blobs::generate(32, 16, 3, 99).items;
        let before = engine.registry().counter(CounterId::LabelQueries).get();

        const THREADS: usize = 4;
        const PER: usize = 50;
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let engine = &engine;
                let snap = &snap;
                let probes = &probes;
                s.spawn(move || {
                    for i in 0..PER {
                        let p = &probes[(t * PER + i) % probes.len()];
                        let _ = engine.label_against(p, snap, 5);
                    }
                });
            }
            // churn merges underneath the serving threads, on this thread
            // (the scope joins the probe threads only after it finishes,
            // so ingest+merge genuinely overlap the queries)
            let extra = datasets::blobs::generate(100, 16, 3, 101).items;
            for chunk in extra.chunks(20) {
                engine.add_batch(chunk.to_vec());
                let _ = engine.cluster(5);
            }
        });

        let issued = (THREADS * PER) as u64;
        let counted =
            engine.registry().counter(CounterId::LabelQueries).get() - before;
        assert_eq!(
            counted, issued,
            "S={shards}: label counter lost samples under concurrency"
        );
        let h = engine.registry().hist(HistId::Label).snapshot();
        assert!(
            h.count >= issued,
            "S={shards}: label histogram recorded {} < {issued} samples",
            h.count
        );
        engine.shutdown();
    }
}

/// `/metrics` and `/stats.json` serve concurrently with ingest and
/// reclustering: every scrape answers 200, the Prometheus text carries
/// the engine series, and the JSON document parses far enough to carry
/// its schema tag.
#[test]
fn metrics_endpoint_serves_during_ingest_and_merge() {
    let engine = spawn_engine(2, 400, 73);
    let _ = engine.cluster(5);
    let server = engine.serve_metrics("127.0.0.1:0").expect("bind");
    let addr = server.addr();

    std::thread::scope(|s| {
        // concurrent scrapers...
        let handles: Vec<_> = (0..3)
            .map(|_| {
                s.spawn(move || {
                    let mut ok = 0;
                    for _ in 0..10 {
                        let (code, body) = http_get(addr, "/metrics");
                        assert_eq!(code, 200);
                        assert!(
                            body.contains("fishdbc_merges_total"),
                            "scrape missing engine series"
                        );
                        ok += 1;
                    }
                    ok
                })
            })
            .collect();
        // ...while the engine keeps working
        let extra = datasets::blobs::generate(200, 16, 3, 103).items;
        for chunk in extra.chunks(50) {
            engine.add_batch(chunk.to_vec());
            let _ = engine.cluster(5);
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 10);
        }
    });

    let (code, body) = http_get(addr, "/metrics");
    assert_eq!(code, 200);
    assert!(body.contains("# TYPE fishdbc_label_queries_total counter"));
    assert!(body.contains("fishdbc_merge_seconds_bucket"));
    assert!(body.contains("fishdbc_live_items"));
    assert!(body.contains("fishdbc_uptime_seconds"));

    let (code, body) = http_get(addr, "/stats.json");
    assert_eq!(code, 200);
    assert!(body.starts_with('{') && body.trim_end().ends_with('}'));
    assert!(body.contains("\"schema\":\"fishdbc-stats-v1\""));
    assert!(body.contains("\"histograms\""));
    assert!(body.contains("\"journal\""));

    let (code, _) = http_get(addr, "/nope");
    assert_eq!(code, 404);

    drop(server);
    engine.shutdown();
}

/// Graceful degradation: dropping the engine must not wedge the endpoint.
/// `/metrics` keeps serving the registry's final totals (the server holds
/// the registry strongly); `/stats.json` needs the live engine and turns
/// 404 once it is gone.
#[test]
fn endpoint_outlives_engine_with_final_totals() {
    let engine = spawn_engine(2, 300, 79);
    let _ = engine.cluster(5);
    let server = engine.serve_metrics("127.0.0.1:0").expect("bind");
    let addr = server.addr();

    let (code, live) = http_get(addr, "/stats.json");
    assert_eq!(code, 200, "stats.json serves while the engine is alive");
    assert!(live.contains("fishdbc-stats-v1"));

    engine.shutdown(); // joins workers and drops the last strong inner ref

    let (code, body) = http_get(addr, "/metrics");
    assert_eq!(code, 200, "metrics must keep serving final totals");
    assert!(body.contains("fishdbc_merges_total"));

    let (code, _) = http_get(addr, "/stats.json");
    assert_eq!(code, 404, "stats.json needs the live engine");
    drop(server);
}
