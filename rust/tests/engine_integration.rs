//! Engine integration tests: the S-shard merged clustering must agree with
//! the single-shard reference (ARI ≥ 0.9 on blobs — ISSUE 1 acceptance),
//! multi-shard state must round-trip through persistence mid-stream, and
//! online label queries must serve without mutating anything.

use fishdbc::coordinator::{Coordinator, CoordinatorConfig};
use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::engine::{Engine, EngineConfig, ShardKey};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::metrics::{
    adjusted_rand_index, canonical_labels as canon, score_external,
};
use fishdbc::util::rng::Rng;

fn blobs(n: usize, seed: u64) -> datasets::Dataset {
    // dim 32 / 5 centers: decisively separated, so both the single-shard
    // and the merged clustering should recover the generator classes
    datasets::blobs::generate(n, 32, 5, seed)
}

fn params() -> FishdbcParams {
    FishdbcParams { min_pts: 10, ef: 20, ..Default::default() }
}

/// Noise gets its own "class" so ARI compares full label vectors.
fn to_pred(labels: &[i32]) -> Vec<usize> {
    labels.iter().map(|&l| (l + 1) as usize).collect()
}

fn spawn_engine(shards: usize) -> Engine {
    Engine::spawn(MetricKind::Euclidean, EngineConfig {
        fishdbc: params(),
        shards,
        mcs: 10,
        ..Default::default()
    })
}

#[test]
fn sharded_merge_matches_single_shard_ari() {
    let ds = blobs(2000, 11);
    let truth = ds.primary_labels().unwrap().to_vec();

    // single-shard reference: plain Fishdbc over the same stream
    let mut f = Fishdbc::new(MetricKind::Euclidean, params());
    for it in ds.items.iter().cloned() {
        f.add(it);
    }
    let want = f.cluster(10);

    // 4-shard engine over the same stream (global ids = arrival order, so
    // the label vectors are directly comparable)
    let engine = spawn_engine(4);
    for chunk in ds.items.chunks(256) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(10);
    assert_eq!(snap.n_items, 2000);
    assert_eq!(snap.clustering.labels.len(), want.labels.len());

    let ari = adjusted_rand_index(
        &to_pred(&want.labels),
        &to_pred(&snap.clustering.labels),
    );
    assert!(ari >= 0.9, "merged vs single-shard ARI {ari}");

    // both must also recover the generator structure
    let s_single = score_external(&want.labels, &truth);
    let s_merged = score_external(&snap.clustering.labels, &truth);
    assert!(s_single.ari >= 0.9, "single-shard vs truth ARI {}", s_single.ari);
    assert!(s_merged.ari >= 0.9, "merged vs truth ARI {}", s_merged.ari);
    engine.shutdown();
}

#[test]
fn two_shard_merge_is_also_consistent() {
    let ds = blobs(1000, 13);
    let mut f = Fishdbc::new(MetricKind::Euclidean, params());
    for it in ds.items.iter().cloned() {
        f.add(it);
    }
    let want = f.cluster(10);

    let engine = spawn_engine(2);
    for chunk in ds.items.chunks(128) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(10);
    let ari = adjusted_rand_index(
        &to_pred(&want.labels),
        &to_pred(&snap.clustering.labels),
    );
    assert!(ari >= 0.9, "2-shard vs single-shard ARI {ari}");
    engine.shutdown();
}

#[test]
fn single_shard_engine_is_exactly_the_coordinator_path() {
    // S=1 must reproduce the coordinator (the single-shard reference
    // deployment) label-for-label: ARI exactly 1.0 (ISSUE 2)
    let ds = blobs(800, 29);

    let c = Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig {
        fishdbc: params(),
        mcs: 10,
        ..Default::default()
    });
    for chunk in ds.items.chunks(100) {
        c.add_batch(chunk.to_vec());
    }
    let want = c.cluster(10);
    c.shutdown();

    let engine = spawn_engine(1);
    for chunk in ds.items.chunks(100) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(10);
    assert_eq!(
        snap.clustering.labels, want.clustering.labels,
        "S=1 engine diverged from the coordinator"
    );
    let ari = adjusted_rand_index(
        &to_pred(&want.clustering.labels),
        &to_pred(&snap.clustering.labels),
    );
    assert!((ari - 1.0).abs() < 1e-12, "S=1 vs coordinator ARI {ari}");
    engine.shutdown();
}

#[test]
fn incremental_recluster_stays_consistent() {
    // the epoch-based delta merge (cluster, ingest more, recluster) must
    // agree with a from-scratch engine over the same stream (ISSUE 2:
    // merged ARI >= 0.9)
    let ds = blobs(2000, 43);
    let truth = ds.primary_labels().unwrap().to_vec();

    let fresh = spawn_engine(4);
    for chunk in ds.items.chunks(256) {
        fresh.add_batch(chunk.to_vec());
    }
    let want = fresh.cluster(10);
    fresh.shutdown();

    let engine = spawn_engine(4);
    for chunk in ds.items[..1600].chunks(256) {
        engine.add_batch(chunk.to_vec());
    }
    let first = engine.cluster(10);
    for chunk in ds.items[1600..].chunks(100) {
        engine.add_batch(chunk.to_vec());
    }
    let second = engine.cluster(10);
    assert_eq!(second.n_items, 2000);
    assert!(second.epoch > first.epoch);

    let ari = adjusted_rand_index(
        &to_pred(&want.clustering.labels),
        &to_pred(&second.clustering.labels),
    );
    assert!(ari >= 0.9, "incremental vs from-scratch ARI {ari}");
    let s = score_external(&second.clustering.labels, &truth);
    assert!(s.ari >= 0.9, "incremental vs truth ARI {}", s.ari);
    engine.shutdown();
}

#[test]
fn chunking_schedule_is_irrelevant_per_shard() {
    // routing is content-hashed and ids are arrival-ordered, so batch size
    // must not change the merged clustering
    let ds = blobs(600, 17);
    let mut labels = Vec::new();
    for chunk in [1usize, 64, 600] {
        let engine = spawn_engine(3);
        for batch in ds.items.chunks(chunk) {
            engine.add_batch(batch.to_vec());
        }
        let snap = engine.cluster(10);
        labels.push(snap.clustering.labels);
        engine.shutdown();
    }
    assert_eq!(labels[0], labels[1], "batch size changed the clustering");
    assert_eq!(labels[0], labels[2], "batch size changed the clustering");
}

#[test]
fn persistence_roundtrip_resumes_mid_stream() {
    let ds = blobs(1200, 19);

    // uninterrupted engine over the whole stream
    let whole = spawn_engine(3);
    for chunk in ds.items.chunks(100) {
        whole.add_batch(chunk.to_vec());
    }
    let want = whole.cluster(10);
    whole.shutdown();

    // same stream split across a save/load boundary
    let first = spawn_engine(3);
    for chunk in ds.items[..700].chunks(100) {
        first.add_batch(chunk.to_vec());
    }
    let mut buf = Vec::new();
    first.save(&mut buf).unwrap();
    first.shutdown();

    let resumed = Engine::load(buf.as_slice()).unwrap();
    assert_eq!(resumed.len(), 700);
    assert_eq!(resumed.n_shards(), 3);
    for chunk in ds.items[700..].chunks(100) {
        resumed.add_batch(chunk.to_vec());
    }
    let got = resumed.cluster(10);
    assert_eq!(got.n_items, 1200);
    assert_eq!(
        got.clustering.labels, want.clustering.labels,
        "resume diverged from the uninterrupted run"
    );
    resumed.shutdown();
}

#[test]
fn online_labels_serve_and_do_not_mutate() {
    let ds = blobs(800, 23);
    let engine = spawn_engine(4);
    engine.add_batch(ds.items.clone());
    let snap = engine.cluster(10);
    assert!(snap.clustering.n_clusters >= 3);

    let calls_before: u64 = engine.stats().dist_calls;

    // copies of clustered items must land in their own cluster
    let mut agree = 0;
    let mut checked = 0;
    for (i, it) in ds.items.iter().enumerate().take(30) {
        let want = snap.clustering.labels[i];
        if want < 0 {
            continue;
        }
        checked += 1;
        if engine.label(it) == want {
            agree += 1;
        }
    }
    assert!(checked >= 20, "too many noise probes ({checked} clustered)");
    assert!(agree * 10 >= checked * 9, "labels agreed on {agree}/{checked}");

    // serving is read-only: nothing inserted, no distance-call drift
    let stats = engine.stats();
    assert_eq!(stats.items, 800);
    assert_eq!(stats.dist_calls, calls_before);
    engine.shutdown();
}

#[test]
fn snapshot_capture_after_one_percent_delta_copies_few_chunks() {
    // Tentpole acceptance (ISSUE 3): a `ShardSnap::capture` after +1% new
    // items must copy ≤ 10% of the chunks, sharing the rest by reference
    // with the previous capture. Ascending 1-D line data makes spatial
    // locality equal id locality, so the delta only rewires nodes (and
    // shifts cores) near the tail of each chunked store — the regime the
    // copy-on-write refactor optimizes. Asserted through the new
    // copied-vs-shared capture counters.
    let n = 4000usize;
    let delta = n / 100;
    let items: Vec<Item> = (0..n + delta)
        .map(|i| Item::Dense(vec![i as f32 * 0.25, 0.0]))
        .collect();
    let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 5, ef: 15, ..Default::default() },
        shards: 2,
        mcs: 5,
        ..Default::default()
    });
    for chunk in items[..n].chunks(256) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();
    engine.refresh_bridges(); // first capture: everything counts as copied
    let s1 = engine.stats().pipeline;
    assert!(s1.snapshot_captures >= 2, "one capture per shard");
    assert!(s1.snapshot_chunks_copied > 0);

    engine.add_batch(items[n..].to_vec()); // +1%
    engine.flush();
    engine.refresh_bridges(); // partial refresh: COW capture
    let s2 = engine.stats().pipeline;
    let copied = s2.snapshot_chunks_copied - s1.snapshot_chunks_copied;
    let shared = s2.snapshot_chunks_shared - s1.snapshot_chunks_shared;
    let total = copied + shared;
    assert!(total > 40, "chunk population too small to be meaningful");
    assert!(
        copied * 10 <= total,
        "capture after +1% copied {copied}/{total} chunks (> 10%)"
    );
    assert!(
        s2.snapshot_bytes_copied > s1.snapshot_bytes_copied,
        "dirty tail chunks must report copied bytes"
    );
    engine.shutdown();
}

#[test]
fn bridge_refresh_capture_preserves_coverage_watermark() {
    // Regression (ISSUE 3 satellite): a mid-epoch `bridge_refresh` capture
    // must never rewind a shard's bridge-coverage watermark — items
    // already searched at insert time (against an older snapshot) must not
    // be re-searched, and their pairs not re-offered, by the next merge's
    // catch-up. The invariant is exact: the insert-time walk and the
    // catch-up walk share each shard's ordered watermark, so covered ==
    // insert_items + catch_up_items at every flushed quiescent point.
    let ds = blobs(1200, 47);
    let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
        fishdbc: params(),
        shards: 3,
        mcs: 10,
        bridge_refresh: 100,
        ..Default::default()
    });
    engine.add_batch(ds.items[..800].to_vec());
    let first = engine.cluster(10); // epoch 1: full catch-up coverage
    assert_eq!(first.n_items, 800);
    let s0 = engine.stats();
    assert_eq!(s0.bridge_covered, 800, "first merge covers everything");
    // exactly-once from the start (how coverage split between the walks
    // depends on cadence-capture timing; the sum never does)
    assert_eq!(s0.bridge_insert_items + s0.bridge_catch_up_items, 800);

    // keep ingesting with plenty of mid-epoch captures: the cadence-driven
    // ones (bridge_refresh=100) plus explicit refreshes after every chunk
    let mut covered_floor = 800usize;
    for chunk in ds.items[800..].chunks(50) {
        engine.add_batch(chunk.to_vec());
        engine.flush();
        engine.refresh_bridges();
        let s = engine.stats();
        assert!(
            s.bridge_covered >= covered_floor,
            "coverage watermark rewound: {} < {covered_floor}",
            s.bridge_covered
        );
        covered_floor = s.bridge_covered;
        assert_eq!(
            s.bridge_covered as u64,
            s.bridge_insert_items + s.bridge_catch_up_items,
            "an item was bridge-searched twice"
        );
    }
    let before = engine.stats();
    assert!(
        before.bridge_insert_items > 0,
        "insert-time walk never ran despite fresh snapshots"
    );

    // the next merge's catch-up may only search what is still above the
    // watermarks — nothing that insert-time coverage already handled
    let second = engine.cluster(10);
    assert_eq!(second.n_items, 1200);
    let after = engine.stats();
    assert_eq!(after.bridge_covered, 1200, "catch-up completes coverage");
    let caught_up = after.bridge_catch_up_items - before.bridge_catch_up_items;
    assert!(
        caught_up as usize <= 1200 - before.bridge_covered,
        "merge re-searched covered items: caught up {caught_up}, only {} were \
         above the watermarks",
        1200 - before.bridge_covered
    );
    assert_eq!(
        after.bridge_covered as u64,
        after.bridge_insert_items + after.bridge_catch_up_items,
        "an item was bridge-searched twice"
    );
    engine.shutdown();
}

/// Regression for the (formerly documented) same-epoch approximation: a
/// cross-shard pair whose two endpoints both arrive inside one epoch
/// window, each insert-covered against a frozen snapshot that predates
/// the other, used to be skipped by the merge catch-up — silently losing
/// the only correct MSF links between the halves. The window re-search
/// closes it: the next merge re-searches every item insert-covered since
/// the previous one against the *live* states.
#[test]
fn same_epoch_cross_shard_pairs_are_bridged() {
    let p = FishdbcParams { min_pts: 4, ef: 20, ..Default::default() };
    let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
        fishdbc: p,
        shards: 2,
        mcs: 4,
        ..Default::default()
    });

    // epoch 1: a base blob at the origin (hash-splits across both shards)
    // gives every shard density, and the merge freezes the snapshots the
    // window items will insert-cover against
    let mut rng = Rng::new(4242);
    let base: Vec<Item> = (0..60)
        .map(|_| Item::Dense(vec![rng.normal() as f32, rng.normal() as f32]))
        .collect();
    engine.add_batch(base);
    let first = engine.cluster(4);
    assert_eq!(first.n_items, 60);

    // epoch window 2: a brand-new, far-away, very tight blob arrives
    // entirely inside the window, exactly 8 items per shard (rejection-
    // sampled on the routing hash so both halves get finite cores from
    // their own shard). Its only light MSF links cross the shard boundary
    // between items no frozen snapshot has seen.
    let mut cloud: Vec<Item> = Vec::new();
    let (mut s0, mut s1) = (0usize, 0usize);
    while s0 < 8 || s1 < 8 {
        let it = Item::Dense(vec![
            500.0 + (rng.normal() * 0.05) as f32,
            500.0 + (rng.normal() * 0.05) as f32,
        ]);
        match (it.shard_key() % 2) as usize {
            0 if s0 < 8 => {
                s0 += 1;
                cloud.push(it);
            }
            1 if s1 < 8 => {
                s1 += 1;
                cloud.push(it);
            }
            _ => {}
        }
    }
    engine.add_batch(cloud);
    engine.flush(); // insert-time walks cover the window against stale snaps
    let mid = engine.stats();
    assert_eq!(
        mid.bridge_covered, 76,
        "premise: both halves must be insert-covered before the merge \
         (otherwise this test is not exercising the same-epoch gap)"
    );

    let second = engine.cluster(4);
    assert_eq!(second.n_items, 76);
    let after = engine.stats();
    assert!(
        after.bridge_recheck_items > 0,
        "the window re-search never ran"
    );
    // the tight far blob is one spatial cluster; without the re-searched
    // cross-shard bridges its two 8-item halves (each >= mcs) extract as
    // two separate clusters
    let labels = &second.clustering.labels[60..];
    assert!(
        labels.iter().all(|&l| l >= 0),
        "window blob items must be clustered: {labels:?}"
    );
    assert!(
        labels.iter().all(|&l| l == labels[0]),
        "same-epoch cross-shard halves did not fuse into one cluster: \
         {labels:?}"
    );
    // and the published epoch still conforms to the from-scratch oracle
    let reference = engine.reference_cluster(4);
    assert_eq!(second.n_msf_edges, reference.n_msf_edges);
    engine.shutdown();
}

/// Table 1's Finefoods shape at engine scale: Text items under
/// Jaro-Winkler — an expensive, non-Euclidean string distance — ingested
/// through 2 shards with the background serving loop, merged into epochs,
/// and served online. The strong assertion is conformance: the published
/// epoch equals the from-scratch reference merge.
#[test]
fn text_jaro_winkler_engine_end_to_end() {
    let ds = datasets::reviews::generate(260, 71);
    let engine = Engine::spawn(ds.metric, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 4, ef: 10, ..Default::default() },
        shards: 2,
        mcs: 4,
        recluster_every: 100,
        ..Default::default()
    });
    for chunk in ds.items.chunks(52) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(4);
    assert_eq!(snap.n_items, 260);
    assert_eq!(snap.clustering.labels.len(), 260);
    assert!(snap.clustering.n_clusters >= 1, "text structure must survive");
    let reference = engine.reference_cluster(4);
    assert_eq!(
        snap.n_msf_edges, reference.n_msf_edges,
        "JW delta merge != from-scratch merge"
    );
    assert_eq!(
        canon(&snap.clustering.labels),
        canon(&reference.clustering.labels),
        "JW epoch labels diverge from the reference merge"
    );
    // online serving under the string metric
    let l = engine.label(&ds.items[0]);
    let latest = engine.latest().expect("epoch published");
    assert!(l >= -1 && (l as i64) < latest.clustering.n_clusters as i64);
    let stats = engine.stats();
    assert_eq!(stats.items, 260);
    assert!(stats.metric_calls > 0, "JW calls must land in the cost model");
    engine.shutdown();
}

/// The DW-* bag-of-words shape at engine scale: Sparse items under cosine
/// distance, same end-to-end path and conformance oracle.
#[test]
fn sparse_cosine_engine_end_to_end() {
    let ds = datasets::docword::generate(500, 512, 73);
    let engine = Engine::spawn(ds.metric, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 4, ef: 10, ..Default::default() },
        shards: 3,
        mcs: 4,
        ..Default::default()
    });
    for chunk in ds.items.chunks(125) {
        engine.add_batch(chunk.to_vec());
    }
    let first = engine.cluster(4);
    assert_eq!(first.n_items, 500);
    // incremental epoch on top (exercises the delta + window paths under
    // a sparse metric)
    engine.add_batch(ds.items[..60].to_vec());
    let second = engine.cluster(4);
    assert_eq!(second.n_items, 560);
    assert!(second.epoch > first.epoch);
    let reference = engine.reference_cluster(4);
    assert_eq!(second.n_msf_edges, reference.n_msf_edges);
    assert_eq!(
        canon(&second.clustering.labels),
        canon(&reference.clustering.labels),
        "sparse-cosine epoch labels diverge from the reference merge"
    );
    let l = engine.label(&ds.items[3]);
    assert!(l >= -1);
    assert!(engine.stats().metric_calls > 0);
    engine.shutdown();
}

/// ISSUE 5 acceptance: after ingesting n blob items and removing a 10%
/// id-scattered subset, the next `cluster()` epoch is partition-identical
/// to `Engine::reference_cluster` over the survivors, deleted ids label
/// -1, the survivors still recover the generator structure, and FISHENG
/// v3 round-trips the tombstone state.
#[test]
fn churn_ten_percent_removal_acceptance() {
    let ds = blobs(1500, 61);
    let truth = ds.primary_labels().unwrap().to_vec();
    let engine = spawn_engine(3);
    for chunk in ds.items.chunks(128) {
        engine.add_batch(chunk.to_vec());
    }
    let first = engine.cluster(10);
    assert_eq!(first.n_items, 1500);

    // a 10% id-scattered subset, removed by value
    let victims: Vec<Item> = ds.items.iter().step_by(10).cloned().collect();
    assert_eq!(engine.remove_batch(&victims), victims.len());

    let snap = engine.cluster(10);
    assert_eq!(snap.n_items, 1500 - victims.len());
    assert_eq!(snap.n_deleted, victims.len());
    assert_eq!(snap.clustering.labels.len(), 1500, "slots are stable");

    // deleted ids label -1, everywhere and forever
    let deleted = engine.deleted_globals();
    assert_eq!(deleted.len(), victims.len());
    for gid in &deleted {
        assert_eq!(snap.clustering.labels[*gid as usize], -1);
    }

    // partition-identical to the from-scratch reference over survivors
    let reference = engine.reference_cluster(10);
    assert_eq!(reference.n_items, snap.n_items);
    assert_eq!(snap.n_msf_edges, reference.n_msf_edges);
    assert_eq!(
        canon(&snap.clustering.labels),
        canon(&reference.clustering.labels),
        "churned delta merge != from-scratch reference merge"
    );

    // survivors still recover the generator structure
    let (mut pred, mut t) = (Vec::new(), Vec::new());
    for (i, &l) in snap.clustering.labels.iter().enumerate() {
        if i % 10 != 0 {
            pred.push((l + 1) as usize);
            t.push(truth[i]);
        }
    }
    let ari = adjusted_rand_index(&pred, &t);
    assert!(ari >= 0.9, "survivor ARI vs truth {ari}");

    // FISHENG v3 round-trips the tombstone state
    let mut buf = Vec::new();
    engine.save(&mut buf).unwrap();
    engine.shutdown();
    let reloaded = Engine::load(buf.as_slice()).unwrap();
    assert_eq!(reloaded.deleted_globals(), deleted);
    let got = reloaded.cluster(10);
    assert_eq!(got.clustering.labels, snap.clustering.labels);
    assert_eq!(got.n_changed_shards, 0, "reload keeps the delta path");
    reloaded.shutdown();
}

/// ISSUE 5 acceptance: only shards containing deletions pay the full
/// local re-derivation — the change-stamp counters prove the untouched
/// shards stayed on the cached path.
#[test]
fn deletions_flip_only_their_own_shards_stamp() {
    let ds = blobs(900, 63);
    let engine = spawn_engine(3);
    for chunk in ds.items.chunks(100) {
        engine.add_batch(chunk.to_vec());
    }
    let first = engine.cluster(10);
    assert_eq!(first.n_changed_shards, 3, "first merge is from-scratch");
    // a no-op merge proves the baseline: everything cached
    let idle = engine.cluster(10);
    assert_eq!(idle.n_changed_shards, 0);

    // removals confined to shard 0 by routing hash
    let victims: Vec<Item> = ds
        .items
        .iter()
        .filter(|it| it.shard_key() % 3 == 0)
        .step_by(7)
        .take(25)
        .cloned()
        .collect();
    assert!(!victims.is_empty());
    assert_eq!(engine.remove_batch(&victims), victims.len());

    let churn = engine.cluster(10);
    assert_eq!(
        churn.n_changed_shards, 1,
        "a deletion in one shard must not flip the other shards' stamps"
    );
    assert_eq!(churn.n_deleted, victims.len());
    // conformance holds on the churned epoch
    let reference = engine.reference_cluster(10);
    assert_eq!(churn.n_msf_edges, reference.n_msf_edges);
    assert_eq!(
        canon(&churn.clustering.labels),
        canon(&reference.clustering.labels)
    );
    // and the window after the churn is monotone again: cached path
    let after = engine.cluster(10);
    assert_eq!(after.n_changed_shards, 0, "churn must not poison the cache");
    assert_eq!(after.clustering.labels, churn.clustering.labels);
    engine.shutdown();
}

/// ISSUE 9 acceptance: an mcs sweep through `relabel_at` over the pinned
/// epoch's cached dendrogram is pure tree surgery — the metric-call
/// odometer must not move — repeating a sweep entry hits the extraction
/// memo bit-identically, and the merge's own flat cut is one of the memo
/// entries (so `stability(mcs)` at the merge's mcs costs a lookup).
#[test]
fn relabel_sweep_adds_zero_metric_calls_and_memo_hits() {
    use fishdbc::engine::{ExtractionMode, ExtractionParams};

    let ds = blobs(1200, 77);
    let engine = spawn_engine(3);
    for chunk in ds.items.chunks(200) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(10);
    let before = engine.stats();

    let sweep = [5usize, 10, 25];
    let mut first_pass = Vec::new();
    for &m in &sweep {
        let r = engine.relabel_at(ExtractionParams::stability(m));
        assert_eq!(r.epoch, snap.epoch, "sweep must pin the merge's epoch");
        assert_eq!(r.clustering.labels.len(), snap.clustering.labels.len());
        first_pass.push(r);
    }
    // the merge's own flat cut (stability at mcs 10) is already memoized
    assert!(first_pass[1].memo_hit, "merge params must hit the memo");
    assert_eq!(first_pass[1].clustering.labels, snap.clustering.labels);

    // second pass: every entry comes out of the memo, bit-identically
    for (r1, &m) in first_pass.iter().zip(&sweep) {
        let r2 = engine.relabel_at(ExtractionParams::stability(m));
        assert!(r2.memo_hit, "mcs {m} repeat missed the extraction memo");
        assert_eq!(r2.clustering.labels, r1.clustering.labels);
        assert_eq!(r2.clustering.n_clusters, r1.clustering.n_clusters);
    }

    // a different mode at the same mcs is its own memo entry
    let leaf =
        ExtractionParams { mcs: 10, eps: 0.0, mode: ExtractionMode::Leaf };
    let l1 = engine.relabel_at(leaf);
    assert!(!l1.memo_hit, "leaf at mcs 10 is a distinct memo key");
    let l2 = engine.relabel_at(leaf);
    assert!(l2.memo_hit);
    assert_eq!(l2.clustering.labels, l1.clustering.labels);

    // the acceptance proper: the whole sweep evaluated the metric zero
    // times, and the pipeline counters saw every extraction
    let after = engine.stats();
    assert_eq!(
        after.metric_calls, before.metric_calls,
        "re-extraction must be tree surgery only"
    );
    assert_eq!(
        after.pipeline.extractions,
        before.pipeline.extractions + 8,
        "every relabel_at lands in the extraction counter"
    );
    assert!(
        after.pipeline.extract_memo_hits >= before.pipeline.extract_memo_hits + 5,
        "memo hits: {} -> {}",
        before.pipeline.extract_memo_hits,
        after.pipeline.extract_memo_hits
    );
    engine.shutdown();
}

#[test]
fn incompatible_items_rejected_in_caller() {
    let engine = spawn_engine(2);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine.add_batch(vec![Item::Text("not a vector".into())]);
    }));
    assert!(result.is_err(), "type mismatch must panic in the caller");
    engine.shutdown();
}

/// ISSUE 6 acceptance: every published epoch leaves exactly one
/// `MergeEnd` journal entry whose fields match the snapshot it describes,
/// the cache-kind sequence walks Scratch → Reused → Delta → Rebuild
/// across a no-change / growth / deletion schedule, and the per-kind
/// registry counters agree with the journal (and with `stats().merges`).
#[test]
fn journal_records_one_merge_end_per_epoch_matching_counters() {
    use fishdbc::obs::{CacheKind, CounterId, JournalEvent};

    let ds = blobs(900, 17);
    let engine = spawn_engine(3);
    for chunk in ds.items[..600].chunks(200) {
        engine.add_batch(chunk.to_vec());
    }
    let s1 = engine.cluster(10); // first merge: no usable cache (Scratch)
    let s2 = engine.cluster(10); // nothing changed (Reused)
    engine.add_batch(ds.items[600..].to_vec()); // monotone growth (Delta)
    let s3 = engine.cluster(10);
    let removed = engine.remove_batch(&ds.items[..40]);
    assert!(removed > 0, "victims must exist");
    let s4 = engine.cluster(10); // non-monotone window (Rebuild)

    let journal = engine.journal();
    let ends: Vec<_> = journal
        .iter()
        .filter_map(|e| match e.event {
            JournalEvent::MergeEnd {
                epoch,
                n_changed_shards,
                cache,
                n_items,
                n_deleted,
                secs,
            } => Some((epoch, n_changed_shards, cache, n_items, n_deleted, secs)),
            _ => None,
        })
        .collect();
    let snaps = [&s1, &s2, &s3, &s4];
    assert_eq!(ends.len(), snaps.len(), "one MergeEnd per published epoch");
    for (got, snap) in ends.iter().zip(snaps) {
        assert_eq!(got.0, snap.epoch, "journal epoch matches the snapshot");
        assert_eq!(
            got.1, snap.n_changed_shards,
            "journal changed-shard count matches the snapshot"
        );
        assert_eq!(got.3, snap.n_items, "journal item count matches");
        assert!(got.5 >= 0.0, "merge duration is recorded");
    }
    let mut epochs: Vec<u64> = ends.iter().map(|e| e.0).collect();
    let before = epochs.len();
    epochs.dedup();
    assert_eq!(epochs.len(), before, "no duplicate MergeEnd epochs");
    assert_eq!(
        ends.iter().map(|e| e.2).collect::<Vec<_>>(),
        vec![
            CacheKind::Scratch,
            CacheKind::Reused,
            CacheKind::Delta,
            CacheKind::Rebuild
        ],
        "cache-kind walk across no-change / growth / deletion"
    );
    assert_eq!(ends[3].4, removed, "Rebuild entry reports the deletions");

    // registry counters and the legacy stats surface agree with the journal
    let reg = engine.registry();
    assert_eq!(reg.counter(CounterId::Merges).get(), 4);
    assert_eq!(reg.counter(CounterId::MergeScratch).get(), 1);
    assert_eq!(reg.counter(CounterId::MergeReused).get(), 1);
    assert_eq!(reg.counter(CounterId::MergeDelta).get(), 1);
    assert_eq!(reg.counter(CounterId::MergeRebuild).get(), 1);
    assert_eq!(engine.stats().merges, 4);
    let starts = journal
        .iter()
        .filter(|e| matches!(e.event, JournalEvent::MergeStart { .. }))
        .count();
    assert_eq!(starts, 4, "every MergeEnd has its MergeStart");
    engine.shutdown();
}
