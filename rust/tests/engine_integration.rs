//! Engine integration tests: the S-shard merged clustering must agree with
//! the single-shard reference (ARI ≥ 0.9 on blobs — ISSUE 1 acceptance),
//! multi-shard state must round-trip through persistence mid-stream, and
//! online label queries must serve without mutating anything.

use fishdbc::coordinator::{Coordinator, CoordinatorConfig};
use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::metrics::{adjusted_rand_index, score_external};

fn blobs(n: usize, seed: u64) -> datasets::Dataset {
    // dim 32 / 5 centers: decisively separated, so both the single-shard
    // and the merged clustering should recover the generator classes
    datasets::blobs::generate(n, 32, 5, seed)
}

fn params() -> FishdbcParams {
    FishdbcParams { min_pts: 10, ef: 20, ..Default::default() }
}

/// Noise gets its own "class" so ARI compares full label vectors.
fn to_pred(labels: &[i32]) -> Vec<usize> {
    labels.iter().map(|&l| (l + 1) as usize).collect()
}

fn spawn_engine(shards: usize) -> Engine {
    Engine::spawn(MetricKind::Euclidean, EngineConfig {
        fishdbc: params(),
        shards,
        mcs: 10,
        ..Default::default()
    })
}

#[test]
fn sharded_merge_matches_single_shard_ari() {
    let ds = blobs(2000, 11);
    let truth = ds.primary_labels().unwrap().to_vec();

    // single-shard reference: plain Fishdbc over the same stream
    let mut f = Fishdbc::new(MetricKind::Euclidean, params());
    for it in ds.items.iter().cloned() {
        f.add(it);
    }
    let want = f.cluster(10);

    // 4-shard engine over the same stream (global ids = arrival order, so
    // the label vectors are directly comparable)
    let engine = spawn_engine(4);
    for chunk in ds.items.chunks(256) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(10);
    assert_eq!(snap.n_items, 2000);
    assert_eq!(snap.clustering.labels.len(), want.labels.len());

    let ari = adjusted_rand_index(
        &to_pred(&want.labels),
        &to_pred(&snap.clustering.labels),
    );
    assert!(ari >= 0.9, "merged vs single-shard ARI {ari}");

    // both must also recover the generator structure
    let s_single = score_external(&want.labels, &truth);
    let s_merged = score_external(&snap.clustering.labels, &truth);
    assert!(s_single.ari >= 0.9, "single-shard vs truth ARI {}", s_single.ari);
    assert!(s_merged.ari >= 0.9, "merged vs truth ARI {}", s_merged.ari);
    engine.shutdown();
}

#[test]
fn two_shard_merge_is_also_consistent() {
    let ds = blobs(1000, 13);
    let mut f = Fishdbc::new(MetricKind::Euclidean, params());
    for it in ds.items.iter().cloned() {
        f.add(it);
    }
    let want = f.cluster(10);

    let engine = spawn_engine(2);
    for chunk in ds.items.chunks(128) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(10);
    let ari = adjusted_rand_index(
        &to_pred(&want.labels),
        &to_pred(&snap.clustering.labels),
    );
    assert!(ari >= 0.9, "2-shard vs single-shard ARI {ari}");
    engine.shutdown();
}

#[test]
fn single_shard_engine_is_exactly_the_coordinator_path() {
    // S=1 must reproduce the coordinator (the single-shard reference
    // deployment) label-for-label: ARI exactly 1.0 (ISSUE 2)
    let ds = blobs(800, 29);

    let c = Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig {
        fishdbc: params(),
        mcs: 10,
        ..Default::default()
    });
    for chunk in ds.items.chunks(100) {
        c.add_batch(chunk.to_vec());
    }
    let want = c.cluster(10);
    c.shutdown();

    let engine = spawn_engine(1);
    for chunk in ds.items.chunks(100) {
        engine.add_batch(chunk.to_vec());
    }
    let snap = engine.cluster(10);
    assert_eq!(
        snap.clustering.labels, want.clustering.labels,
        "S=1 engine diverged from the coordinator"
    );
    let ari = adjusted_rand_index(
        &to_pred(&want.clustering.labels),
        &to_pred(&snap.clustering.labels),
    );
    assert!((ari - 1.0).abs() < 1e-12, "S=1 vs coordinator ARI {ari}");
    engine.shutdown();
}

#[test]
fn incremental_recluster_stays_consistent() {
    // the epoch-based delta merge (cluster, ingest more, recluster) must
    // agree with a from-scratch engine over the same stream (ISSUE 2:
    // merged ARI >= 0.9)
    let ds = blobs(2000, 43);
    let truth = ds.primary_labels().unwrap().to_vec();

    let fresh = spawn_engine(4);
    for chunk in ds.items.chunks(256) {
        fresh.add_batch(chunk.to_vec());
    }
    let want = fresh.cluster(10);
    fresh.shutdown();

    let engine = spawn_engine(4);
    for chunk in ds.items[..1600].chunks(256) {
        engine.add_batch(chunk.to_vec());
    }
    let first = engine.cluster(10);
    for chunk in ds.items[1600..].chunks(100) {
        engine.add_batch(chunk.to_vec());
    }
    let second = engine.cluster(10);
    assert_eq!(second.n_items, 2000);
    assert!(second.epoch > first.epoch);

    let ari = adjusted_rand_index(
        &to_pred(&want.clustering.labels),
        &to_pred(&second.clustering.labels),
    );
    assert!(ari >= 0.9, "incremental vs from-scratch ARI {ari}");
    let s = score_external(&second.clustering.labels, &truth);
    assert!(s.ari >= 0.9, "incremental vs truth ARI {}", s.ari);
    engine.shutdown();
}

#[test]
fn chunking_schedule_is_irrelevant_per_shard() {
    // routing is content-hashed and ids are arrival-ordered, so batch size
    // must not change the merged clustering
    let ds = blobs(600, 17);
    let mut labels = Vec::new();
    for chunk in [1usize, 64, 600] {
        let engine = spawn_engine(3);
        for batch in ds.items.chunks(chunk) {
            engine.add_batch(batch.to_vec());
        }
        let snap = engine.cluster(10);
        labels.push(snap.clustering.labels);
        engine.shutdown();
    }
    assert_eq!(labels[0], labels[1], "batch size changed the clustering");
    assert_eq!(labels[0], labels[2], "batch size changed the clustering");
}

#[test]
fn persistence_roundtrip_resumes_mid_stream() {
    let ds = blobs(1200, 19);

    // uninterrupted engine over the whole stream
    let whole = spawn_engine(3);
    for chunk in ds.items.chunks(100) {
        whole.add_batch(chunk.to_vec());
    }
    let want = whole.cluster(10);
    whole.shutdown();

    // same stream split across a save/load boundary
    let first = spawn_engine(3);
    for chunk in ds.items[..700].chunks(100) {
        first.add_batch(chunk.to_vec());
    }
    let mut buf = Vec::new();
    first.save(&mut buf).unwrap();
    first.shutdown();

    let resumed = Engine::load(buf.as_slice()).unwrap();
    assert_eq!(resumed.len(), 700);
    assert_eq!(resumed.n_shards(), 3);
    for chunk in ds.items[700..].chunks(100) {
        resumed.add_batch(chunk.to_vec());
    }
    let got = resumed.cluster(10);
    assert_eq!(got.n_items, 1200);
    assert_eq!(
        got.clustering.labels, want.clustering.labels,
        "resume diverged from the uninterrupted run"
    );
    resumed.shutdown();
}

#[test]
fn online_labels_serve_and_do_not_mutate() {
    let ds = blobs(800, 23);
    let engine = spawn_engine(4);
    engine.add_batch(ds.items.clone());
    let snap = engine.cluster(10);
    assert!(snap.clustering.n_clusters >= 3);

    let calls_before: u64 = engine.stats().dist_calls;

    // copies of clustered items must land in their own cluster
    let mut agree = 0;
    let mut checked = 0;
    for (i, it) in ds.items.iter().enumerate().take(30) {
        let want = snap.clustering.labels[i];
        if want < 0 {
            continue;
        }
        checked += 1;
        if engine.label(it) == want {
            agree += 1;
        }
    }
    assert!(checked >= 20, "too many noise probes ({checked} clustered)");
    assert!(agree * 10 >= checked * 9, "labels agreed on {agree}/{checked}");

    // serving is read-only: nothing inserted, no distance-call drift
    let stats = engine.stats();
    assert_eq!(stats.items, 800);
    assert_eq!(stats.dist_calls, calls_before);
    engine.shutdown();
}

#[test]
fn incompatible_items_rejected_in_caller() {
    let engine = spawn_engine(2);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine.add_batch(vec![Item::Text("not a vector".into())]);
    }));
    assert!(result.is_err(), "type mismatch must panic in the caller");
    engine.shutdown();
}
