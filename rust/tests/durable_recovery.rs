//! Durable-persistence integration tests (ISSUE 10 acceptance): a
//! checkpoint plus WAL-suffix replay must rebuild an engine that is
//! partition-identical to `Engine::reference_cluster` over the surviving
//! set — including a deletion journaled *after* the checkpoint — with
//! O(Δ) replay cost witnessed by the `wal_replayed` counter, and the
//! pre-WAL FISHENG fixtures must keep loading byte-identically through
//! the new checkpoint reader.

use std::path::{Path, PathBuf};

use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::durable::{read_checkpoint_with, Durable, DurabilityConfig};
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::metrics::canonical_labels as canon;
use fishdbc::obs::CounterId;
use fishdbc::persist::FrameworkCodec;

fn blobs(n: usize, seed: u64) -> datasets::Dataset {
    datasets::blobs::generate(n, 32, 5, seed)
}

fn config(shards: usize) -> EngineConfig {
    EngineConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        shards,
        mcs: 10,
        ..Default::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fishdbc_recovery_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn open(dir: &Path) -> Durable {
    Durable::open_framework(
        MetricKind::Euclidean,
        config(3),
        DurabilityConfig::new(dir),
    )
    .unwrap()
}

fn fixture(name: &str) -> Vec<u8> {
    let path = format!("{}/tests/data/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

/// The tentpole acceptance: ingest, checkpoint mid-stream, keep
/// ingesting and delete a scattered subset (both journaled past the
/// cut), stop *without* a final checkpoint, reopen. Recovery must
/// replay exactly the post-checkpoint window (O(Δ), not O(n)), rebuild
/// the same surviving set, and the recovered merge must be
/// partition-identical to the from-scratch reference over survivors
/// with every deleted id labeling -1.
#[test]
fn checkpoint_plus_replay_matches_reference_with_mid_window_deletion() {
    let dir = tmp_dir("midwindow");
    let ds = blobs(900, 17);
    let victims: Vec<Item> = ds.items.iter().step_by(9).cloned().collect();

    let (labels_before, deleted) = {
        let d = open(&dir);
        let e = d.engine();
        for chunk in ds.items[..600].chunks(128) {
            e.add_batch(chunk.to_vec());
        }
        e.flush();
        let stats = d.checkpoint().unwrap();
        assert_eq!(stats.watermark, 600, "cut covers the journaled prefix");

        // the post-checkpoint window: more ingest + a deletion, living
        // only in the WAL suffix until the next checkpoint
        for chunk in ds.items[600..].chunks(128) {
            e.add_batch(chunk.to_vec());
        }
        assert_eq!(e.remove_batch(&victims), victims.len());
        d.sync().unwrap();

        let deleted = e.deleted_globals();
        let snap = e.cluster(10);
        let labels = snap.clustering.labels.clone();
        d.shutdown(); // deliberately no final checkpoint
        (labels, deleted)
    };

    let d = open(&dir);
    let e = d.engine();
    assert_eq!(e.len(), 900, "checkpoint + replayed suffix");
    assert_eq!(e.deleted_globals(), deleted, "the deletion replayed");

    // O(Δ): only the records past the cut replay — the post-checkpoint
    // ingest batches plus the one removal record
    let replayed = e.registry().counter(CounterId::WalReplayed).get();
    let suffix_batches = ds.items[600..].chunks(128).count() as u64 + 1;
    assert!(replayed >= 1, "the suffix must actually replay");
    assert!(
        replayed <= suffix_batches,
        "replayed {replayed} records, but only {suffix_batches} were \
         journaled after the checkpoint"
    );

    let snap = e.cluster(10);
    assert_eq!(snap.clustering.labels.len(), 900, "slots are stable");
    assert_eq!(snap.n_deleted, victims.len());
    for gid in &deleted {
        assert_eq!(snap.clustering.labels[*gid as usize], -1);
    }
    // conformance by construction: replay used the normal ingest path
    let reference = e.reference_cluster(10);
    assert_eq!(reference.n_items, snap.n_items);
    assert_eq!(snap.n_msf_edges, reference.n_msf_edges);
    assert_eq!(
        canon(&snap.clustering.labels),
        canon(&reference.clustering.labels),
        "recovered merge != from-scratch reference merge"
    );
    // and the recovered partition is the pre-crash partition
    assert_eq!(canon(&snap.clustering.labels), canon(&labels_before));
    d.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A WAL-only history (no checkpoint was ever taken) recovers purely by
/// replay, and a second reopen after a checkpoint replays nothing —
/// the two ends of the O(Δ) spectrum.
#[test]
fn full_replay_without_checkpoint_then_none_after_one() {
    let dir = tmp_dir("spectrum");
    let ds = blobs(300, 23);
    {
        let d = open(&dir);
        for chunk in ds.items.chunks(64) {
            d.engine().add_batch(chunk.to_vec());
        }
        d.sync().unwrap();
        d.shutdown();
    }
    {
        let d = open(&dir);
        assert_eq!(d.engine().len(), 300);
        let replayed =
            d.engine().registry().counter(CounterId::WalReplayed).get();
        assert_eq!(
            replayed,
            ds.items.chunks(64).count() as u64,
            "no checkpoint: every journaled batch replays"
        );
        d.checkpoint().unwrap();
        d.shutdown();
    }
    let d = open(&dir);
    assert_eq!(d.engine().len(), 300);
    assert_eq!(
        d.engine().registry().counter(CounterId::WalReplayed).get(),
        0,
        "everything is inside the checkpoint: nothing replays"
    );
    d.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The checked-in FISHENG v1/v2 fixtures (and a freshly saved v3
/// buffer) must read through `read_checkpoint_with` exactly as they do
/// through `Engine::load`: trailer-less files are "checkpoints covering
/// nothing in the WAL" (`cut_seq = 0`), and re-saving the engine loaded
/// either way produces the same bytes.
#[test]
fn legacy_fisheng_fixtures_read_byte_identically() {
    let resolve = |m: &str| {
        MetricKind::parse(m).ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("unknown metric {m:?}"),
            )
        })
    };
    // the two checked-in containers, plus a current (v3) save
    let mut cases: Vec<(String, Vec<u8>)> = vec![
        ("fisheng_v1.bin".into(), fixture("fisheng_v1.bin")),
        ("fisheng_v2.bin".into(), fixture("fisheng_v2.bin")),
    ];
    {
        let engine: Engine = Engine::spawn(MetricKind::Euclidean, config(2));
        engine.add_batch(blobs(40, 31).items);
        engine.flush();
        let mut v3 = Vec::new();
        engine.save(&mut v3).unwrap();
        engine.shutdown();
        cases.push(("fresh v3 save".into(), v3));
    }
    for (name, bytes) in cases {
        let via_load = Engine::load(bytes.as_slice()).unwrap();
        let n = via_load.len();
        let mut want = Vec::new();
        via_load.save(&mut want).unwrap();
        via_load.shutdown();

        let (via_ckpt, cut_seq, watermark): (Engine, u64, u64) =
            read_checkpoint_with(&FrameworkCodec, resolve, bytes.as_slice())
                .unwrap();
        assert_eq!(cut_seq, 0, "{name}: no trailer means cut 0");
        assert_eq!(watermark as usize, n, "{name}: watermark is the count");
        assert_eq!(via_ckpt.len(), n);
        let mut got = Vec::new();
        via_ckpt.save(&mut got).unwrap();
        via_ckpt.shutdown();
        assert_eq!(
            got, want,
            "{name}: the checkpoint reader changed the container bytes"
        );
    }
}
