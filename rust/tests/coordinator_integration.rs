//! Coordinator integration tests: concurrency, backpressure, snapshot
//! semantics, and equivalence with the single-threaded core under every
//! ingestion schedule.

use std::sync::Arc;
use std::thread;

use fishdbc::coordinator::{Coordinator, CoordinatorConfig, Snapshot};
use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};

fn blob_items(n: usize, seed: u64) -> Vec<Item> {
    datasets::blobs::generate(n, 8, 4, seed).items
}

fn default_coord() -> Coordinator {
    Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig::default())
}

/// Chunk size must not affect the final clustering (only arrival order
/// matters, and it is identical).
#[test]
fn chunking_schedule_is_irrelevant() {
    let items = blob_items(600, 1);
    let mut labels = Vec::new();
    for chunk in [1usize, 7, 64, 600] {
        let c = default_coord();
        for batch in items.chunks(chunk) {
            c.add_batch(batch.to_vec());
        }
        let snap = c.cluster(10);
        assert_eq!(snap.n_items, 600);
        labels.push(snap.clustering.labels);
        c.shutdown();
    }
    for l in &labels[1..] {
        assert_eq!(*l, labels[0], "clustering depends on chunking schedule");
    }
}

/// Multiple producer threads funneling into one coordinator: total item
/// count must be exact and the result well-formed (insert order is
/// nondeterministic across producers, so only structural checks).
#[test]
fn concurrent_producers_are_safe() {
    let coord = Arc::new(Coordinator::spawn(
        MetricKind::Euclidean,
        CoordinatorConfig { queue_depth: 4, ..Default::default() },
    ));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let c = Arc::clone(&coord);
        handles.push(thread::spawn(move || {
            let items = blob_items(300, 100 + t);
            for chunk in items.chunks(25) {
                c.add_batch(chunk.to_vec());
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = coord.stats();
    assert_eq!(stats.fishdbc.items, 1200);
    let snap = coord.cluster(10);
    assert_eq!(snap.n_items, 1200);
    assert_eq!(snap.clustering.labels.len(), 1200);
    assert!(snap.clustering.n_clusters >= 1);
}

/// Backpressure: with a tiny queue and a slow consumer the producer must
/// block rather than grow memory; after a barrier, the queue must be empty.
#[test]
fn backpressure_blocks_and_drains() {
    let c = Coordinator::spawn(
        MetricKind::Euclidean,
        CoordinatorConfig { queue_depth: 2, ..Default::default() },
    );
    for i in 0..10 {
        c.add_batch(blob_items(200, i));
        assert!(c.queue_depth() <= 3, "queue grew past depth+in-flight");
    }
    let stats = c.stats(); // barrier
    assert_eq!(stats.fishdbc.items, 2000);
    assert_eq!(c.queue_depth(), 0);
    c.shutdown();
}

/// Auto-reclustering cadence: snapshots must appear roughly every
/// `recluster_every` items and their `n_items` must be non-decreasing.
#[test]
fn auto_recluster_cadence_and_monotonicity() {
    let c = Coordinator::spawn(
        MetricKind::Euclidean,
        CoordinatorConfig { recluster_every: 150, ..Default::default() },
    );
    let items = blob_items(900, 2);
    let mut seen: Vec<usize> = Vec::new();
    for chunk in items.chunks(75) {
        c.add_batch(chunk.to_vec());
        let _ = c.stats(); // pace the stream
        if let Some(Snapshot { n_items, .. }) = c.latest() {
            seen.push(n_items);
        }
    }
    assert!(seen.windows(2).all(|w| w[0] <= w[1]), "snapshots regressed: {seen:?}");
    let stats = c.stats();
    assert!(
        stats.reclusters >= 4,
        "expected ≥4 auto reclusters over 900 items every 150, got {}",
        stats.reclusters
    );
    c.shutdown();
}

/// Explicit cluster() must reflect *all* items ingested before the call
/// (the command queue is FIFO, so a cluster command acts as a barrier).
#[test]
fn cluster_sees_all_prior_ingestion() {
    let c = default_coord();
    let items = blob_items(500, 3);
    for chunk in items.chunks(50) {
        c.add_batch(chunk.to_vec());
    }
    let snap = c.cluster(10);
    assert_eq!(snap.n_items, 500, "cluster() missed queued batches");
    c.shutdown();
}

/// Streamed result equals the single-threaded core (exact same arrival
/// order ⇒ exact same labels), independent of auto-reclustering noise.
#[test]
fn coordinator_equals_core_with_autorecluster() {
    let items = blob_items(400, 4);
    let params = FishdbcParams::default();

    let mut core = Fishdbc::new(MetricKind::Euclidean, params);
    for it in items.clone() {
        core.add(it);
    }
    let want = core.cluster(10);

    let c = Coordinator::spawn(MetricKind::Euclidean, CoordinatorConfig {
        fishdbc: params,
        recluster_every: 90, // interleaved extraction must not perturb
        ..Default::default()
    });
    for chunk in items.chunks(30) {
        c.add_batch(chunk.to_vec());
    }
    let got = c.cluster(10);
    assert_eq!(got.clustering.labels, want.labels);
    c.shutdown();
}

/// Build/extract time accounting feeds the paper's Table 8 "build" vs
/// "cluster" columns; both must be tracked and plausible.
#[test]
fn time_accounting_is_plausible() {
    let c = default_coord();
    c.add_batch(blob_items(800, 5));
    let snap = c.cluster(10);
    let stats = c.stats();
    assert!(stats.build_secs > 0.0);
    assert!(snap.extract_secs >= 0.0);
    // the paper's headline: extraction ≪ build
    assert!(
        snap.extract_secs < stats.build_secs,
        "extract {} !< build {}",
        snap.extract_secs,
        stats.build_secs
    );
    c.shutdown();
}

/// Stats must be internally consistent after an arbitrary workload.
#[test]
fn stats_consistency() {
    let c = default_coord();
    for i in 0..6 {
        c.add_batch(blob_items(100, 10 + i));
    }
    let _ = c.cluster(10);
    let _ = c.cluster(20);
    let s = c.stats();
    assert_eq!(s.fishdbc.items, 600);
    assert!(s.batches >= 1 && s.batches <= 6, "batches {}", s.batches);
    assert_eq!(s.reclusters, 2);
    assert!(s.fishdbc.dist_calls > 0);
    assert!(s.fishdbc.msf_edges > 0, "MSF should be materialized by cluster()");
    c.shutdown();
}

/// Dropping a coordinator mid-stream must not hang or crash even with a
/// full queue.
#[test]
fn drop_with_full_queue_is_clean() {
    for seed in 0..3 {
        let c = Coordinator::spawn(
            MetricKind::Euclidean,
            CoordinatorConfig { queue_depth: 1, ..Default::default() },
        );
        c.add_batch(blob_items(500, seed));
        c.add_batch(blob_items(500, seed + 50));
        drop(c); // must join cleanly while work is queued
    }
}

/// Mixed on-demand mcs values: each cluster() honours its own mcs without
/// poisoning the shared state.
#[test]
fn per_request_mcs_is_respected() {
    let c = default_coord();
    c.add_batch(blob_items(600, 6));
    let fine = c.cluster(5);
    let coarse = c.cluster(60);
    assert!(
        fine.clustering.n_clusters >= coarse.clustering.n_clusters,
        "smaller mcs must give at least as many clusters ({} vs {})",
        fine.clustering.n_clusters,
        coarse.clustering.n_clusters
    );
    // state unchanged: re-request the fine clustering, must be identical
    let fine2 = c.cluster(5);
    assert_eq!(fine.clustering.labels, fine2.clustering.labels);
    c.shutdown();
}
