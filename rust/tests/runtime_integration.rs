//! Integration tests: PJRT runtime executing the AOT artifacts must agree
//! with the native rust distance implementations. Requires the `xla` feature
//! and `make artifacts` (tests are skipped with a notice when artifacts are
//! absent; the whole file is compiled out without the feature).

#![cfg(feature = "xla")]

use fishdbc::distances::vector;
use fishdbc::runtime::{default_artifacts_dir, Runtime};
use fishdbc::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        // artifacts not built in this checkout: skip (CI runs `make
        // artifacts` first, so this only relaxes ad-hoc `cargo test` runs)
        eprintln!("SKIP runtime tests — run `make artifacts`");
        return None;
    }
    // artifacts exist: failing to load them is a real bug, not a skip
    Some(Runtime::load(&dir).expect("artifacts exist but failed to load"))
}

fn random_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
        .collect()
}

#[test]
fn manifest_modules_loaded() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.module_names().len() >= 5, "modules: {:?}", rt.module_names());
    assert!(rt.platform().to_lowercase().contains("cpu")
        || rt.platform().to_lowercase().contains("host"));
    let m = rt.meta("query_topk_euclidean_b256_d128_k10").expect("module");
    assert_eq!((m.b, m.d, m.k), (256, 128, Some(10)));
}

#[test]
fn query_topk_matches_native_euclidean() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(1);
    let d = 128;
    let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let cands = random_rows(&mut rng, 200, d); // padded 200 -> 256
    let refs: Vec<&[f32]> = cands.iter().map(|c| c.as_slice()).collect();
    let out = rt
        .query_topk("query_topk_euclidean_b256_d128_k10", &q, &refs)
        .unwrap();
    assert_eq!(out.dists.len(), 200);
    for (i, c) in cands.iter().enumerate() {
        let want = vector::euclidean(&q, c);
        assert!(
            (out.dists[i] as f64 - want).abs() < 1e-2,
            "dist[{i}] kernel {} vs native {want}",
            out.dists[i]
        );
    }
    // top-k correct and sorted
    assert_eq!(out.topk.len(), 10);
    let mut all: Vec<(u32, f32)> =
        out.dists.iter().copied().enumerate().map(|(i, d)| (i as u32, d)).collect();
    all.sort_by(|a, b| a.1.total_cmp(&b.1));
    for (got, want) in out.topk.iter().zip(&all[..10]) {
        assert_eq!(got.0, want.0);
    }
    // padding must not leak
    assert!(out.topk.iter().all(|&(i, _)| (i as usize) < 200));
}

#[test]
fn query_topk_dim_padding_is_exact() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(2);
    // dim 100 < module D=128: zero-padding must be exact for euclidean
    let q: Vec<f32> = (0..100).map(|_| rng.normal() as f32).collect();
    let cands = random_rows(&mut rng, 64, 100);
    let refs: Vec<&[f32]> = cands.iter().map(|c| c.as_slice()).collect();
    let out = rt
        .query_topk("query_topk_euclidean_b256_d128_k10", &q, &refs)
        .unwrap();
    for (i, c) in cands.iter().enumerate() {
        let want = vector::euclidean(&q, c);
        assert!((out.dists[i] as f64 - want).abs() < 1e-2);
    }
}

#[test]
fn cosine_and_jaccard_modules_match_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(3);
    let d = 1024;
    let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let cands = random_rows(&mut rng, 128, d);
    let refs: Vec<&[f32]> = cands.iter().map(|c| c.as_slice()).collect();
    let out = rt.query_topk("query_topk_cosine_b256_d1024_k10", &q, &refs).unwrap();
    for (i, c) in cands.iter().enumerate() {
        let want = vector::cosine(&q, c);
        assert!(
            (out.dists[i] as f64 - want).abs() < 1e-3,
            "cosine[{i}] {} vs {want}",
            out.dists[i]
        );
    }

    // jaccard over {0,1} vectors vs sparse-set native implementation
    let qb: Vec<f32> = (0..d).map(|_| f32::from(rng.bool(0.3))).collect();
    let cb: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..d).map(|_| f32::from(rng.bool(0.3))).collect())
        .collect();
    let refs: Vec<&[f32]> = cb.iter().map(|c| c.as_slice()).collect();
    let out = rt.query_topk("query_topk_jaccard_b256_d1024_k10", &qb, &refs).unwrap();
    let to_set = |v: &[f32]| -> Vec<u32> {
        v.iter().enumerate().filter(|(_, &x)| x > 0.5).map(|(i, _)| i as u32).collect()
    };
    let qset = to_set(&qb);
    for (i, c) in cb.iter().enumerate() {
        let want = fishdbc::distances::sparse::jaccard(&qset, &to_set(c));
        assert!(
            (out.dists[i] as f64 - want).abs() < 1e-4,
            "jaccard[{i}] {} vs {want}",
            out.dists[i]
        );
    }
}

#[test]
fn pairwise_and_mreach_match_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(4);
    let d = 16;
    let x = random_rows(&mut rng, 100, d);
    let y = random_rows(&mut rng, 80, d);
    let xr: Vec<&[f32]> = x.iter().map(|r| r.as_slice()).collect();
    let yr: Vec<&[f32]> = y.iter().map(|r| r.as_slice()).collect();
    let block = rt.pairwise("pairwise_euclidean_b128_d16", &xr, &yr).unwrap();
    assert_eq!(block.len(), 100);
    assert_eq!(block[0].len(), 80);
    for i in (0..100).step_by(17) {
        for j in (0..80).step_by(13) {
            let want = vector::euclidean(&x[i], &y[j]);
            assert!((block[i][j] as f64 - want).abs() < 1e-2);
        }
    }

    let core_x: Vec<f32> = (0..100).map(|_| rng.f32() * 3.0).collect();
    let core_y: Vec<f32> = (0..80).map(|_| rng.f32() * 3.0).collect();
    let mr = rt
        .mreach("mreach_euclidean_b128_d16", &xr, &yr, &core_x, &core_y)
        .unwrap();
    for i in (0..100).step_by(11) {
        for j in (0..80).step_by(7) {
            let want =
                (vector::euclidean(&x[i], &y[j])).max(core_x[i] as f64).max(core_y[j] as f64);
            assert!(
                (mr[i][j] as f64 - want).abs() < 1e-2,
                "mreach[{i}][{j}] {} vs {want}",
                mr[i][j]
            );
        }
    }
}

#[test]
fn oversize_batches_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let q = vec![0f32; 16];
    let big_row = vec![0f32; 16];
    let cands: Vec<&[f32]> = (0..300).map(|_| big_row.as_slice()).collect();
    assert!(rt.query_topk("query_topk_euclidean_b256_d16_k10", &q, &cands).is_err());
    let qd = vec![0f32; 4096];
    assert!(rt
        .query_topk("query_topk_euclidean_b256_d16_k10", &qd, &cands[..4])
        .is_err());
}

#[test]
fn find_query_module_picks_smallest_fit() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.find_query_module("euclidean", 100).unwrap();
    assert_eq!(m.d, 128);
    let m = rt.find_query_module("euclidean", 10).unwrap();
    assert_eq!(m.d, 16);
    assert!(rt.find_query_module("euclidean", 100_000).is_none());
}
