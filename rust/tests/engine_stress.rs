//! Deterministic engine stress harness: a seeded *virtual scheduler*
//! replays a reproducible interleaving of `add_batch`, `remove_batch`
//! churn (including remove-then-reinsert of an equal item and removals
//! landing mid-epoch-window), recluster epochs, online `label()` queries,
//! mid-epoch snapshot refreshes, and mid-stream save/load over
//! S ∈ {1, 2, 4} shards — on Euclidean blobs and on the paper's
//! non-Euclidean workloads (Jaro-Winkler text, sparse cosine), since the
//! generic engine must honor the conformance contract for any metric.
//! The conformance invariant, checked at **every** published epoch:
//!
//! * labels are index-aligned with the input stream (`labels.len()` ==
//!   global ids assigned so far, global ids = arrival order; deleted ids
//!   keep their slots and label -1), and
//! * the epoch's clustering is identical to a **from-scratch merge of the
//!   same surviving prefix state** (`Engine::reference_cluster`): one
//!   Kruskal over all current tombstone-filtered shard forests plus all
//!   current bridge sets (deleted endpoints dropped), bypassing the
//!   cached global MSF, the per-shard change stamps, and the memoizing
//!   extraction pipeline.
//!
//! The scheduler drives recluster epochs synchronously (the background
//! thread's merges are identical code, but their timing is not
//! reproducible, and an epoch can only be compared against a reference of
//! the *same* prefix when no ingest interleaves), and always flushes
//! before a snapshot refresh so captures see a deterministic state. Shard
//! *workers* still interleave freely — which bridge pairs insert-time
//! coverage finds can vary run to run — but every invariant below is
//! interleaving-independent, because the reference merge reads the same
//! quiesced engine state the epoch was published from. Label
//! equality is asserted up to cluster renumbering (`canon`): the delta and
//! reference paths must produce the same partition of the same prefix;
//! extraction numbers clusters by traversal order, which is not part of
//! the conformance contract when equal-weight edges tie.
//!
//! Short seeds run under plain `cargo test -q`; the `#[ignore]`d variants
//! are the longer nightly loops (`cargo test -q -- --ignored`).

use fishdbc::datasets;
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::metrics::canonical_labels as canon;
use fishdbc::util::rng::Rng;

/// One epoch's conformance check (call only with no ingest/churn since
/// the epoch was published). `assigned` is the global ids handed out so
/// far; `removed` the cumulative deletions — survivors = assigned −
/// removed.
fn check_epoch(
    engine: &Engine,
    assigned: usize,
    removed: usize,
    mcs: usize,
    ctx: &str,
) {
    let snap = engine.latest().expect("epoch published");
    assert_eq!(snap.n_items, assigned - removed, "{ctx}: epoch item count");
    assert_eq!(snap.n_deleted, removed, "{ctx}: epoch deletion count");
    if assigned > 0 {
        assert_eq!(
            snap.clustering.labels.len(),
            assigned,
            "{ctx}: labels not index-aligned with the stream"
        );
    }
    let deleted = engine.deleted_globals();
    assert_eq!(deleted.len(), removed, "{ctx}: deleted-id registry count");
    for gid in &deleted {
        assert_eq!(
            snap.clustering.labels[*gid as usize], -1,
            "{ctx}: deleted id {gid} kept a label"
        );
    }
    let reference = engine.reference_cluster(mcs);
    assert_eq!(
        reference.n_items,
        assigned - removed,
        "{ctx}: reference item count"
    );
    assert_eq!(
        snap.n_msf_edges, reference.n_msf_edges,
        "{ctx}: delta forest size != from-scratch forest size"
    );
    assert_eq!(
        canon(&snap.clustering.labels),
        canon(&reference.clustering.labels),
        "{ctx}: delta merge clustering != from-scratch merge clustering"
    );
    // telemetry rides the same contract: the newest MergeEnd journal
    // entry must describe exactly this epoch, and no epoch may journal
    // twice (the ring holds far more than one schedule's merges)
    let ends: Vec<(u64, usize)> = engine
        .journal()
        .iter()
        .filter_map(|e| match e.event {
            fishdbc::obs::JournalEvent::MergeEnd {
                epoch, n_changed_shards, ..
            } => Some((epoch, n_changed_shards)),
            _ => None,
        })
        .collect();
    let (end_epoch, end_changed) =
        *ends.last().expect("published epoch journals a MergeEnd");
    assert_eq!(end_epoch, snap.epoch, "{ctx}: newest MergeEnd epoch");
    assert_eq!(
        end_changed, snap.n_changed_shards,
        "{ctx}: newest MergeEnd changed-shard count"
    );
    let mut seen = std::collections::HashSet::new();
    for (e, _) in &ends {
        assert!(seen.insert(*e), "{ctx}: duplicate MergeEnd for epoch {e}");
    }
}

fn stress(shards: usize, rounds: usize, max_items: usize, seed: u64) {
    let ds = datasets::blobs::generate(max_items, 16, 4, seed);
    let params = FishdbcParams { min_pts: 5, ef: 15, ..Default::default() };
    stress_on(ds, shards, rounds, seed, params);
}

/// The harness proper, over any framework dataset (and therefore any of
/// the paper's metrics — the scheduler and the conformance contract are
/// metric-agnostic).
fn stress_on(
    ds: datasets::Dataset,
    shards: usize,
    rounds: usize,
    seed: u64,
    params: FishdbcParams,
) {
    let max_items = ds.n();
    let mcs = params.min_pts;
    let config = EngineConfig {
        fishdbc: params,
        shards,
        mcs,
        ..Default::default()
    };
    let mut engine = Engine::spawn(ds.metric, config);
    let mut rng = Rng::new(seed ^ 0x57E55);
    let mut cursor = 0usize; // dataset prefix ingested
    let mut assigned = 0usize; // global ids handed out (incl. reinserts)
    let mut removed = 0usize; // cumulative deletions (engine-confirmed)
    let mut last_epoch = 0u64;
    let mut clean = false; // no ingest/churn since the latest epoch
    let mut saves = 0usize;

    for round in 0..rounds {
        match rng.below(15) {
            // ingest a batch (the common action)
            0..=6 => {
                if cursor < max_items {
                    let take = (1 + rng.below(64)).min(max_items - cursor);
                    engine.add_batch(ds.items[cursor..cursor + take].to_vec());
                    cursor += take;
                    assigned += take;
                    clean = false;
                }
            }
            // recluster epoch (the scheduler's stand-in for the background
            // serving loop) + conformance check
            7 | 8 => {
                let snap = engine.cluster(mcs);
                assert!(snap.epoch > last_epoch, "epochs must be monotone");
                last_epoch = snap.epoch;
                clean = true;
                check_epoch(
                    &engine,
                    assigned,
                    removed,
                    mcs,
                    &format!("round {round}"),
                );
            }
            // online label query: read-only, contract-shaped. When no
            // epoch exists yet this lazily publishes one — deterministic,
            // since the scheduler is the only thread driving merges.
            9 => {
                if cursor > 0 {
                    let had_epoch = engine.latest().is_some();
                    let probe = &ds.items[rng.below(cursor)];
                    let l = engine.label(probe);
                    let snap = engine.latest().expect("label published an epoch");
                    assert!(
                        l >= -1 && (l as i64) < snap.clustering.n_clusters as i64,
                        "label {l} out of contract"
                    );
                    if !had_epoch {
                        last_epoch = snap.epoch;
                        clean = true;
                        check_epoch(
                            &engine,
                            assigned,
                            removed,
                            config.mcs,
                            &format!("round {round} (lazy label merge)"),
                        );
                    }
                }
            }
            // mid-epoch partial snapshot refresh (flush first so the
            // capture sees a deterministic state)
            10 => {
                engine.flush();
                engine.refresh_bridges();
            }
            // churn: remove a random handful of already-ingested values —
            // often mid-epoch-window, sometimes already-removed (no-op by
            // contract). The engine's return value is the ground truth
            // for how many actually died (duplicate values in text/sparse
            // datasets remove one live copy per match).
            11 | 12 => {
                if cursor > 0 {
                    let take = 1 + rng.below(8);
                    let victims: Vec<_> = (0..take)
                        .map(|_| ds.items[rng.below(cursor)].clone())
                        .collect();
                    let n = engine.remove_batch(&victims);
                    removed += n;
                    if n > 0 {
                        clean = false;
                    }
                }
            }
            // churn: remove-then-reinsert of an equal item — the old id
            // must stay deleted forever, the copy re-enters under a fresh
            // id
            13 => {
                if cursor > 0 {
                    let item = ds.items[rng.below(cursor)].clone();
                    let n = engine.remove_batch(std::slice::from_ref(&item));
                    removed += n;
                    engine.add_batch(vec![item]);
                    assigned += 1;
                    clean = false;
                }
            }
            // mid-stream save / load (bounded: checkpoints are the
            // expensive action)
            _ => {
                if saves < 3 {
                    saves += 1;
                    let mut buf = Vec::new();
                    engine.save(&mut buf).unwrap();
                    let reloaded = Engine::load(buf.as_slice()).unwrap();
                    let old = std::mem::replace(&mut engine, reloaded);
                    old.shutdown();
                    assert_eq!(engine.len(), assigned, "reload lost ids");
                    assert_eq!(
                        engine.deleted_globals().len(),
                        removed,
                        "reload lost deletions"
                    );
                    assert_eq!(engine.n_shards(), shards);
                    assert!(engine.epoch() >= last_epoch, "epoch counter rewound");
                    clean = false; // latest() is not persisted
                }
            }
        }
        // published epochs stay comparable only while nothing changed
        if clean {
            let snap = engine.latest().expect("clean implies epoch");
            assert_eq!(snap.epoch, last_epoch);
        }
    }

    // final barrier: one more epoch over everything, fully checked
    let snap = engine.cluster(mcs);
    assert_eq!(snap.n_items, assigned - removed);
    last_epoch = snap.epoch;
    check_epoch(&engine, assigned, removed, mcs, "final");
    // and an idle re-merge must short-circuit to the identical clustering
    let again = engine.cluster(mcs);
    assert_eq!(again.epoch, last_epoch + 1);
    assert_eq!(again.clustering.labels, snap.clustering.labels);
    engine.shutdown();
}

#[test]
fn stress_single_shard() {
    stress(1, 40, 900, 0xA11CE);
}

#[test]
fn stress_two_shards() {
    stress(2, 40, 900, 0xB0B);
}

#[test]
fn stress_four_shards() {
    stress(4, 40, 900, 0xCAFE);
}

/// Non-Euclidean conformance (tentpole acceptance): a sharded engine over
/// **Jaro-Winkler text** — the paper's Finefoods-shaped workload, an
/// expensive, non-metric string distance — must publish epochs identical
/// to the from-scratch reference merge under the same adversarial
/// schedule. Smaller n: each distance call is O(len²) on ~430-char texts.
#[test]
fn stress_text_jaro_winkler_two_shards() {
    stress_on(
        datasets::reviews::generate(220, 0x7E47),
        2,
        24,
        0x7E47,
        FishdbcParams { min_pts: 4, ef: 10, ..Default::default() },
    );
}

/// Non-Euclidean conformance over **sparse cosine** (the paper's DW-*
/// bag-of-words shape).
#[test]
fn stress_sparse_cosine_two_shards() {
    stress_on(
        datasets::docword::generate(400, 512, 0x51C0),
        2,
        30,
        0x51C0,
        FishdbcParams { min_pts: 4, ef: 10, ..Default::default() },
    );
}

/// S=1 admits a *stronger* oracle than the same-state reference merge:
/// with no bridges and no cross-shard anything, an engine that clustered
/// many times along the way must match, label for label, a fresh engine
/// fed the same stream and clustered once at the end.
#[test]
fn single_shard_incremental_equals_fresh_replay() {
    let ds = datasets::blobs::generate(700, 16, 4, 77);
    let config = EngineConfig {
        fishdbc: FishdbcParams { min_pts: 5, ef: 15, ..Default::default() },
        shards: 1,
        mcs: 5,
        ..Default::default()
    };

    let incremental = Engine::spawn(ds.metric, config);
    for (i, chunk) in ds.items.chunks(90).enumerate() {
        incremental.add_batch(chunk.to_vec());
        if i % 2 == 0 {
            let _ = incremental.cluster(5); // epochs along the way
        }
    }
    let got = incremental.cluster(5);

    let fresh = Engine::spawn(ds.metric, config);
    fresh.add_batch(ds.items.clone());
    let want = fresh.cluster(5);

    assert_eq!(got.n_items, want.n_items);
    assert_eq!(got.n_msf_edges, want.n_msf_edges);
    assert_eq!(
        canon(&got.clustering.labels),
        canon(&want.clustering.labels),
        "S=1 incremental epochs diverged from a fresh replay"
    );
    incremental.shutdown();
    fresh.shutdown();
}

// ------------------------------------------------- nightly-length loops --
// `cargo test -q -- --ignored` (CI runs these in the scheduled job).

#[test]
#[ignore]
fn stress_long_single_shard() {
    stress(1, 160, 4000, 0x1_0001);
}

#[test]
#[ignore]
fn stress_long_two_shards() {
    stress(2, 160, 4000, 0x1_0002);
}

#[test]
#[ignore]
fn stress_long_four_shards() {
    stress(4, 160, 4000, 0x1_0003);
}
