//! End-to-end integration tests: the full FISHDBC pipeline (HNSW → candidate
//! edges → incremental MSF → condensed tree → flat extraction) against the
//! exact HDBSCAN* baseline, across data types and distance functions, plus
//! the paper's analytical claims (Theorems 3.1-3.4) checked empirically.

use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactError, ExactParams};
use fishdbc::metrics::score_external;
use fishdbc::mst::Edge;
use fishdbc::util::rng::Rng;

fn build(ds: &datasets::Dataset, ef: usize, min_pts: usize) -> Fishdbc<Item, MetricKind> {
    let mut f = Fishdbc::new(
        ds.metric,
        FishdbcParams { min_pts, ef, ..Default::default() },
    );
    for it in ds.items.iter().cloned() {
        f.add(it);
    }
    f
}

/// FISHDBC must recover the labeled structure on every labeled generator,
/// under the dataset's own paper metric (Tables 2, 4, 5, 6 in miniature).
#[test]
fn all_labeled_datasets_recovered() {
    for (name, n, dim, min_ami_star) in [
        ("blobs", 800, 64, 0.9),
        ("synth", 800, 256, 0.9),
        // usps/fuzzy have overlapping, harder labels: lower bars
        ("usps", 800, 0, 0.25),
        ("fuzzy", 800, 0, 0.25),
    ] {
        let ds = datasets::generate(name, n, dim, 1234).unwrap();
        let mut f = build(&ds, 20, 10);
        let c = f.cluster(10);
        let truth = ds.primary_labels().unwrap();
        let s = score_external(&c.labels, truth);
        assert!(
            s.ami_star >= min_ami_star,
            "{name}: AMI* {} < {min_ami_star}",
            s.ami_star
        );
    }
}

/// FISHDBC vs the exact baseline: quality parity on separable data, with a
/// large reduction in distance evaluations (the paper's core trade).
#[test]
fn parity_with_exact_at_fraction_of_cost() {
    let ds = datasets::blobs::generate(1200, 32, 8, 99);
    let truth = ds.primary_labels().unwrap().to_vec();

    let mut f = build(&ds, 20, 10);
    let fish = f.cluster(10);
    let fish_calls = f.dist_calls();

    let exact = exact_hdbscan(
        &ds.items,
        &ds.metric,
        ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
    )
    .unwrap();

    let sf = score_external(&fish.labels, &truth);
    let se = score_external(&exact.clustering.labels, &truth);
    assert!(sf.ami_star > 0.9, "FISHDBC AMI* {}", sf.ami_star);
    assert!(se.ami_star > 0.9, "exact AMI* {}", se.ami_star);
    assert!((sf.ami_star - se.ami_star).abs() < 0.1, "quality gap too wide");
    assert!(
        fish_calls * 3 < exact.dist_calls,
        "fishdbc {} vs exact {} dist calls",
        fish_calls,
        exact.dist_calls
    );
}

/// Theorem 3.1 (state is O(n log n)): growing n by 4x must grow the state
/// by well under 16x (quadratic would be 16x); allow up to ~6x ≈ 4·log-ish.
#[test]
fn state_growth_is_subquadratic() {
    let small = datasets::blobs::generate(500, 16, 5, 7);
    let large = datasets::blobs::generate(2000, 16, 5, 7);
    let mut fs = build(&small, 20, 10);
    let mut fl = build(&large, 20, 10);
    fs.update_mst();
    fl.update_mst();
    let ratio = fl.approx_state_bytes() as f64 / fs.approx_state_bytes() as f64;
    assert!(
        ratio < 8.0,
        "state grew {ratio:.1}x for a 4x dataset — not O(n log n)"
    );
}

/// Theorem 3.2 empirically: distance calls per item must not explode as the
/// dataset grows (Fig 2's plateau).
#[test]
fn dist_calls_per_item_plateau() {
    let ds = datasets::blobs::generate(3000, 16, 5, 13);
    let mut f = Fishdbc::new(
        ds.metric,
        FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
    );
    let mut per_item = Vec::new();
    let mut last_calls = 0u64;
    for (i, it) in ds.items.iter().cloned().enumerate() {
        f.add(it);
        if (i + 1) % 1000 == 0 {
            let calls = f.dist_calls();
            per_item.push((calls - last_calls) as f64 / 1000.0);
            last_calls = calls;
        }
    }
    // the marginal cost of the 3rd thousand must be < 2.5x that of the 1st:
    // sub-linear growth per item (quadratic would give ~3x and keep rising)
    assert!(
        per_item[2] < per_item[0] * 2.5,
        "per-item cost rising too fast: {per_item:?}"
    );
}

/// Theorem 3.4 in the computable limit: with an exhaustive beam (ef ≥ n) the
/// HNSW computes enough pairs that FISHDBC's MSF total weight approaches the
/// exact reachability MST weight from above.
#[test]
fn msf_weight_approaches_exact_with_large_ef() {
    let ds = datasets::blobs::generate(250, 8, 3, 5);

    // exact MST weight over mutual reachability
    let exact = exact_hdbscan(
        &ds.items,
        &ds.metric,
        ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
    )
    .unwrap();
    let _ = exact; // exact result used for clustering parity below

    let mut loose = build(&ds, 10, 10);
    let mut tight = build(&ds, 300, 10); // ef > n: near-exhaustive search
    loose.update_mst();
    tight.update_mst();

    let wl = loose.msf().total_weight();
    let wt = tight.msf().total_weight();
    // monotone: more computed distances => lighter (better) spanning forest
    assert!(
        wt <= wl + 1e-9,
        "exhaustive ef produced a heavier MSF ({wt} > {wl})"
    );

    // and the clustering agrees with exact on this clean dataset
    let truth = ds.primary_labels().unwrap();
    let ct = tight.cluster(10);
    let s = score_external(&ct.labels, truth);
    assert!(s.ami > 0.95, "AMI {} with exhaustive ef", s.ami);
}

/// The paper's OOM behaviour (Tables 7-8): the exact baseline must fail
/// when the pairwise matrix exceeds the memory budget, while FISHDBC
/// handles the same dataset fine.
#[test]
fn exact_ooms_where_fishdbc_survives() {
    let ds = datasets::reviews::generate(1500, 3);
    let budget = 1024 * 1024; // 1 MiB: far below the 9 MB matrix
    let err = exact_hdbscan(
        &ds.items,
        &ds.metric,
        ExactParams { min_pts: 10, mcs: 10, matrix_budget: Some(budget) },
    )
    .unwrap_err();
    match err {
        ExactError::OutOfMemory { required, budget: b } => {
            assert!(required > b);
        }
    }

    let mut f = build(&ds, 20, 10);
    let c = f.cluster(10);
    assert!(c.labels.len() == ds.n());
    assert!(f.approx_state_bytes() < 64 * 1024 * 1024);
}

/// Every metric kind the paper evaluates runs end-to-end on its dataset.
#[test]
fn every_paper_metric_runs_end_to_end() {
    let cases: Vec<(datasets::Dataset, MetricKind)> = vec![
        (datasets::blobs::generate(300, 16, 4, 1), MetricKind::Euclidean),
        (datasets::blobs::generate(300, 16, 4, 1), MetricKind::Cosine),
        (datasets::docword::generate(300, 128, 2), MetricKind::SparseCosine),
        (datasets::synth::generate(300, 128, 4, 3), MetricKind::Jaccard),
        (datasets::reviews::generate(300, 4), MetricKind::JaroWinkler),
        (datasets::usps::generate(300, 5), MetricKind::Simpson),
        (datasets::fuzzy::generate(300, 6), MetricKind::Lzjd),
        (datasets::fuzzy::generate(300, 6), MetricKind::Tlsh),
        (datasets::fuzzy::generate(300, 6), MetricKind::Sdhash),
    ];
    for (mut ds, metric) in cases {
        ds.metric = metric;
        ds.validate().unwrap();
        let mut f = build(&ds, 20, 5);
        let c = f.cluster(5);
        assert_eq!(c.labels.len(), ds.n(), "{}", metric.name());
        assert!(
            c.n_clusters > 0,
            "{}: no clusters found at all",
            metric.name()
        );
        // hierarchy invariants
        assert!(c.n_hierarchical_clustered() >= c.n_clustered());
        assert!(c.n_hierarchical_clusters() >= c.n_clusters.saturating_sub(1));
    }
}

/// Incremental additions must never corrupt earlier structure: interleave
/// adds and clusterings and check the final result equals a fresh one-shot
/// build over the same data (same seed).
#[test]
fn interleaved_cluster_calls_do_not_corrupt() {
    let ds = datasets::blobs::generate(900, 8, 6, 21);
    let p = FishdbcParams { min_pts: 10, ef: 20, ..Default::default() };

    let mut inc = Fishdbc::new(ds.metric, p);
    for (i, it) in ds.items.iter().cloned().enumerate() {
        inc.add(it);
        if i % 150 == 149 {
            let _ = inc.cluster(10); // interleaved extraction
        }
    }
    let ci = inc.cluster(10);

    let mut oneshot = Fishdbc::new(ds.metric, p);
    for it in ds.items.iter().cloned() {
        oneshot.add(it);
    }
    let co = oneshot.cluster(10);

    assert_eq!(ci.labels, co.labels);
    assert_eq!(ci.n_clusters, co.n_clusters);
}

/// Noise handling: uniform background noise must mostly land in no cluster
/// while the dense blobs are recovered (density-based core property).
#[test]
fn background_noise_is_rejected() {
    let mut rng = Rng::new(31);
    let blobs = datasets::blobs::generate(600, 4, 3, 17);
    let mut items = blobs.items.clone();
    let n_noise = 120;
    for _ in 0..n_noise {
        items.push(Item::Dense(
            (0..4).map(|_| rng.range_f64(-60.0, 60.0) as f32).collect(),
        ));
    }
    let mut f = Fishdbc::new(
        MetricKind::Euclidean,
        FishdbcParams { min_pts: 10, ef: 30, ..Default::default() },
    );
    for it in items {
        f.add(it);
    }
    let c = f.cluster(10);
    let noise_labels = &c.labels[600..];
    let rejected = noise_labels.iter().filter(|&&l| l < 0).count();
    assert!(
        rejected * 2 > n_noise,
        "only {rejected}/{n_noise} uniform-noise points marked as noise"
    );
}

/// MSF structural invariants after a full build: acyclic (|E| < n), no
/// self-loops, no duplicate edges, weights all finite and non-negative.
#[test]
fn msf_invariants_hold_after_build() {
    let ds = datasets::synth::generate(700, 128, 5, 8);
    let mut f = build(&ds, 20, 10);
    f.update_mst();
    let edges: &[Edge] = f.msf().edges();
    assert!(edges.len() < ds.n());
    let mut seen = std::collections::HashSet::new();
    for e in edges {
        assert_ne!(e.a, e.b, "self-loop");
        assert!((e.a as usize) < ds.n() && (e.b as usize) < ds.n());
        assert!(e.w.is_finite() && e.w >= 0.0);
        assert!(seen.insert(Edge::key(e.a, e.b)), "duplicate edge");
    }
    // spanning forest over a connected-ish dataset: components must be few
    assert!(f.msf().components() <= 10);
}
