#!/usr/bin/env python3
"""Regenerate the checked-in FISHENG persistence fixtures.

Writes fisheng_v1.bin (the pre-pipeline engine container) and
fisheng_v2.bin (the pre-deletion container with bridge buffers, coverage
watermarks and the cached global MSF) byte-for-byte in the hand-rolled
little-endian format of rust/src/persist/mod.rs. The fixtures pin the
legacy on-disk layouts: `failure_injection.rs` loads both, re-clusters
them, and asserts that saving the reloaded v2 engine upgrades it to a
v3 container (the deletion-state format) whose own save/load/save cycle
is byte-stable — so any accidental format change (for example, the
chunked copy-on-write stores leaking their in-memory layout to disk)
fails CI. v3 bytes themselves are pinned by in-test round trips
(persist::tests::engine_v3_roundtrips_tombstones_and_compaction_state),
not by a checked-in fixture.

The v2 content is deliberately canonical where the format round-trips
through a re-sort on load: MSF edge lists are written in Kruskal's total
order (weight ascending, ties on the canonical (min, max) endpoint key)
and bridge buffers in (a, b) order, because that is what a save after a
load emits.

Run from rust/tests/data/:  python3 make_fixtures.py
"""

import struct

u8 = lambda x: struct.pack("<B", x)
u32 = lambda x: struct.pack("<I", x)
u64 = lambda x: struct.pack("<Q", x)
f32 = lambda x: struct.pack("<f", x)
f64 = lambda x: struct.pack("<d", x)


def s(text):
    b = text.encode()
    return u64(len(b)) + b


def u32s(xs):
    return u64(len(xs)) + b"".join(u32(x) for x in xs)


def f32s(xs):
    return u64(len(xs)) + b"".join(f32(x) for x in xs)


def edges(es):
    return u64(len(es)) + b"".join(u32(a) + u32(b) + f64(w) for a, b, w in es)


MIN_PTS, EF, ALPHA, SEED = 2, 4, 5.0, 99


def fishdbc_blob(xs, neighbor_sets, links, msf):
    """One shard's nested FISHDBC v1 snapshot (items on a line, y = const)."""
    out = b"FISHDBC\x00" + u8(1)
    out += s("euclidean")
    out += u64(MIN_PTS) + u64(EF) + f64(ALPHA) + u64(SEED)
    # items: Dense 2-D points
    out += u64(len(xs))
    for x, y in xs:
        out += u8(0) + f32s([x, y])
    # hnsw: params mirror the FISHDBC params (m = MinPts)
    out += u64(MIN_PTS) + u64(EF) + u64(SEED)
    out += u64(len(links))
    for node in links:
        out += u64(len(node))
        for level in node:
            out += u32s(level)
    out += u8(1) + u32(0)  # entry = Some(0)
    out += u64(1) + u64(2) + u64(3) + u64(4)  # rng state (any nonzero)
    out += u64(6)  # dist_calls
    # neighbor sets (sorted ascending, <= MinPts entries each)
    out += u64(len(neighbor_sets))
    for entries in neighbor_sets:
        out += u64(len(entries))
        for nid, d in entries:
            out += u32(nid) + f64(d)
    # local MSF (canonical order) + empty candidate buffer
    out += edges(msf)
    out += u64(0)
    out += u64(1)  # mst_updates
    return out


def shard(y, globals_):
    """A 4-item shard: a chain of unit-spaced points at height y."""
    xs = [(0.0, y), (1.0, y), (2.0, y), (3.0, y)]
    links = [[[1]], [[0, 2]], [[1, 3]], [[2]]]  # level-0 chain
    neighbor_sets = [
        [(1, 1.0), (2, 2.0)],
        [(0, 1.0), (2, 1.0)],
        [(1, 1.0), (3, 1.0)],
        [(2, 1.0), (1, 2.0)],
    ]
    msf = [(0, 1, 1.0), (1, 2, 2.0), (2, 3, 3.0)]
    return globals_, fishdbc_blob(xs, neighbor_sets, links, msf)


SHARDS = [shard(0.0, [0, 2, 4, 6]), shard(1.0, [1, 3, 5, 7])]
HEADER = (
    s("euclidean")
    + u64(2)  # shards
    + u64(8)  # next_global
    + u64(2)  # mcs
    + u64(2)  # bridge_k
    + u64(1)  # bridge_fanout
    + u64(4)  # queue_depth
)

# ------------------------------------------------------------------- v1 --
v1 = b"FISHENG\x00" + u8(1) + HEADER
for globals_, blob in SHARDS:
    v1 += u32s(globals_) + u64(1) + f64(0.0) + blob
open("fisheng_v1.bin", "wb").write(v1)

# ------------------------------------------------------------------- v2 --
v2 = b"FISHENG\x00" + u8(2) + HEADER
v2 += u64(0) + u64(0) + u64(3)  # recluster_every, bridge_refresh, epoch
BRIDGES = [  # (compacted bridge forest, live buffer) per shard, global ids
    ([(0, 1, 1.5)], [(2, 3, 1.8)]),
    ([(4, 5, 1.5)], [(6, 7, 1.9)]),
]
for (globals_, blob), (bmsf, bbuf) in zip(SHARDS, BRIDGES):
    v2 += u32s(globals_) + u64(1) + f64(0.0) + blob
    v2 += u64(4) + u64(1)  # covered, generation
    v2 += edges(bmsf) + edges(bbuf)
# cached global MSF + per-shard change stamps matching the shard states
v2 += u8(1) + u64(8)
for _ in SHARDS:
    v2 += u64(4) + u64(1) + u64(3) + u64(1)  # items, mst_updates, msf_len, gen
v2 += edges([
    (0, 2, 1.0),
    (1, 3, 1.0),
    (2, 4, 1.0),
    (3, 5, 1.0),
    (4, 6, 1.0),
    (5, 7, 1.0),
    (0, 1, 1.5),
])
open("fisheng_v2.bin", "wb").write(v2)

print(f"fisheng_v1.bin: {len(v1)} bytes, fisheng_v2.bin: {len(v2)} bytes")
