//! Figure 1 — fuzzy hashes: runtime comparison.
//!
//! The paper plots total runtime vs dataset size for the three fuzzy-hash
//! distances (lzjd, tlsh, sdhash): HDBSCAN* grows quadratically (cost is
//! dominated by distance calls on the full pairwise matrix) while FISHDBC
//! (ef = 20 / 50) "consistently scales much better".
//!
//! This harness regenerates the same series on the synthetic fuzzy-hash
//! corpus. Expect: exact rows ~4x when n doubles; FISHDBC rows well below,
//! growing near-linearly. Run: `cargo bench --bench fig1_fuzzy_runtime`.

use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactParams};
use fishdbc::util::bench::time_once;

fn fishdbc_total(items: &[Item], metric: MetricKind, ef: usize) -> (f64, u64) {
    let mut f = Fishdbc::new(
        metric,
        FishdbcParams { min_pts: 10, ef, ..Default::default() },
    );
    let (t, _) = time_once(|| {
        for it in items.iter().cloned() {
            f.add(it);
        }
        f.cluster(10)
    });
    (t, f.dist_calls())
}

fn exact_total(items: &[Item], metric: MetricKind) -> (f64, u64) {
    let mut calls = 0;
    let (t, _) = time_once(|| {
        let r = exact_hdbscan(
            items,
            &metric,
            ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
        )
        .expect("exact");
        calls = r.dist_calls;
        r.clustering
    });
    (t, calls)
}

fn main() {
    let sizes = [500usize, 1000, 2000, 3000];
    let metrics =
        [MetricKind::Lzjd, MetricKind::Tlsh, MetricKind::Sdhash];

    println!("# Figure 1: fuzzy hashes — total runtime (s) vs dataset size");
    println!(
        "{:<8} {:>6} {:>14} {:>14} {:>14} {:>16} {:>16}",
        "metric", "n", "FISHDBC ef=20", "FISHDBC ef=50", "HDBSCAN*",
        "calls(f,ef=20)", "calls(exact)"
    );
    for metric in metrics {
        for &n in &sizes {
            let ds = datasets::fuzzy::generate(n, 77);
            let items = &ds.items;
            let (t20, c20) = fishdbc_total(items, metric, 20);
            let (t50, _) = fishdbc_total(items, metric, 50);
            let (tex, cex) = exact_total(items, metric);
            println!(
                "{:<8} {:>6} {:>14.3} {:>14.3} {:>14.3} {:>16} {:>16}",
                metric.name(),
                n,
                t20,
                t50,
                tex,
                c20,
                cex
            );
        }
        println!();
    }
    println!("# paper shape: HDBSCAN* ~quadratic in n; FISHDBC much flatter,");
    println!("# with ef=50 costlier than ef=20 but both far below exact.");
}
