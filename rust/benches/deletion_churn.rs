//! Deletion churn — incremental removal vs rebuilding from survivors
//! (ISSUE 5 acceptance).
//!
//! Protocol: ingest n blob points into a 4-shard engine and publish an
//! epoch. Then remove a 10% id-scattered subset by value (`remove_batch`)
//! and time (a) the removal itself and (b) the churn `cluster()` that
//! folds it in — the non-monotone window pays one full re-fold of the
//! retained summaries, but no bridge re-search and no per-shard
//! recompute. Compare against the brute-force alternative a system
//! without incremental deletion would pay: a fresh engine over the
//! survivors, built and merged from scratch. Conformance is asserted,
//! not just printed: the churned epoch must be partition-identical to
//! `Engine::reference_cluster`, deleted ids must label -1, and the merge
//! after the churn must be back on the cached path.
//!
//! Run: `cargo bench --bench deletion_churn` (optional first arg
//! overrides n, e.g. `-- 2000` for the CI smoke pass).

use std::time::Instant;

use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::metrics::canonical_labels as canon;
use fishdbc::util::bench::emit_bench_json;
use fishdbc::{datasets, Item};

fn main() {
    let n: usize = std::env::args()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(50_000);
    let dim = 16;
    let ds = datasets::blobs::generate(n, dim, 10, 42);
    let config = EngineConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        shards: 4,
        mcs: 10,
        ..Default::default()
    };
    println!(
        "# deletion churn: blobs n={n}, dim={dim}, 4 shards, MinPts=10 \
         ef=20, compact_at={}",
        config.compact_at
    );

    let engine = Engine::spawn(ds.metric, config);
    let t0 = Instant::now();
    for chunk in ds.items.chunks(512) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();
    let ingest_secs = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let base = engine.cluster(10);
    let base_cluster_secs = t1.elapsed().as_secs_f64();
    println!(
        "ingest {ingest_secs:8.3}s | base cluster {base_cluster_secs:8.3}s \
         ({} clusters over {} items)",
        base.clustering.n_clusters, base.n_items
    );

    // 10% id-scattered churn, removed by value
    let victims: Vec<Item> = ds.items.iter().step_by(10).cloned().collect();
    let t2 = Instant::now();
    let removed = engine.remove_batch(&victims);
    let remove_secs = t2.elapsed().as_secs_f64();
    assert_eq!(removed, victims.len(), "every victim must be found");
    let t3 = Instant::now();
    let churn = engine.cluster(10);
    let churn_secs = t3.elapsed().as_secs_f64();
    let stats = engine.stats();
    println!(
        "remove {removed:6} items: {remove_secs:8.3}s ({:.0} removals/s) | \
         churn cluster {churn_secs:8.3}s | {} changed shards, {} \
         compactions, {} tombstones left",
        removed as f64 / remove_secs.max(1e-9),
        churn.n_changed_shards,
        stats.compactions,
        stats.tombstoned_items,
    );

    // conformance: partition-identical to the from-scratch reference over
    // the survivors, deleted ids -1
    let reference = engine.reference_cluster(10);
    assert_eq!(churn.n_msf_edges, reference.n_msf_edges);
    let conformant = canon(&churn.clustering.labels)
        == canon(&reference.clustering.labels);
    let deleted_ok = engine
        .deleted_globals()
        .iter()
        .all(|&g| churn.clustering.labels[g as usize] == -1);
    // post-churn window is monotone again: cached path
    let t4 = Instant::now();
    let after = engine.cluster(10);
    let idle_secs = t4.elapsed().as_secs_f64();
    println!(
        "idle  cluster {idle_secs:8.3}s | {} changed shards (cached path \
         restored: {})",
        after.n_changed_shards,
        after.n_changed_shards == 0,
    );

    // the brute-force alternative: rebuild from the survivors
    let survivors: Vec<Item> = ds
        .items
        .iter()
        .enumerate()
        .filter(|(i, _)| i % 10 != 0)
        .map(|(_, it)| it.clone())
        .collect();
    let fresh = Engine::spawn(ds.metric, config);
    let t5 = Instant::now();
    for chunk in survivors.chunks(512) {
        fresh.add_batch(chunk.to_vec());
    }
    fresh.flush();
    let _ = fresh.cluster(10);
    let rebuild_secs = t5.elapsed().as_secs_f64();
    fresh.shutdown();

    let churn_total = remove_secs + churn_secs;
    println!(
        "# churn handling (remove + recluster): {churn_total:.3}s vs \
         {rebuild_secs:.3}s rebuild-from-survivors ({:.1}% of rebuild)",
        churn_total / rebuild_secs.max(1e-9) * 100.0
    );
    let correct = conformant && deleted_ok && after.n_changed_shards == 0;
    let pass = correct && churn_total < rebuild_secs;
    println!(
        "# acceptance: {} (conformant={conformant} deleted-1={deleted_ok} \
         cached-after={} faster-than-rebuild={})",
        if pass { "PASS" } else { "FAIL" },
        after.n_changed_shards == 0,
        churn_total < rebuild_secs,
    );

    emit_bench_json("deletion_churn", |w| {
        w.usize("n", n)
            .usize("shards", 4)
            .usize("removed", removed)
            .f64("remove_secs", remove_secs)
            .f64("removals_per_sec", removed as f64 / remove_secs.max(1e-9))
            .f64("churn_cluster_secs", churn_secs)
            .f64("rebuild_secs", rebuild_secs)
            .f64("churn_over_rebuild", churn_total / rebuild_secs.max(1e-9))
            .u64("compactions", stats.compactions)
            .u64("metric_calls", stats.metric_calls)
            .str("acceptance", if pass { "PASS" } else { "FAIL" });
    });
    engine.shutdown();
    // the correctness conditions gate CI (the bench-smoke job runs this
    // binary); the timing comparison stays advisory — tiny-n CI boxes
    // are too noisy to gate on wall clock
    if !correct {
        std::process::exit(1);
    }
}
