//! Figure 3 — Blobs: runtime comparison vs dimensionality.
//!
//! The paper fixes n = 10 000 Gaussian-blob points and sweeps the number of
//! dimensions from 1 000 to 10 000 under Euclidean distance: HDBSCAN*'s
//! KD-tree acceleration degrades steeply with dimensionality ("the curse of
//! dimensionality") while FISHDBC's HNSW-guided search grows "definitely
//! slower".
//!
//! Our exact baseline has no KD-tree (it is the O(n²) generic path — the
//! regime the KD-tree degrades *to* at high dimensionality), so the series
//! to compare is the *growth* of each row as dim increases and the
//! FISHDBC/exact gap. Table 6's companion quality metrics are in
//! `examples/paper_tables.rs`. Run: `cargo bench --bench fig3_blobs_runtime`.

use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactParams};
use fishdbc::util::bench::time_once;

fn fishdbc_total(items: &[Item], ef: usize) -> f64 {
    let mut f = Fishdbc::new(
        MetricKind::Euclidean,
        FishdbcParams { min_pts: 10, ef, ..Default::default() },
    );
    time_once(|| {
        for it in items.iter().cloned() {
            f.add(it);
        }
        f.cluster(10)
    })
    .0
}

fn main() {
    // paper: n=10 000, dims 1 000..10 000; scaled to keep the bench minutes
    let n = 2000;
    let dims = [250usize, 500, 1000, 2000];

    println!("# Figure 3: blobs (n={n}) — total runtime (s) vs dimensionality");
    println!(
        "{:<8} {:>14} {:>14} {:>14} {:>12}",
        "dim", "FISHDBC ef=20", "FISHDBC ef=50", "HDBSCAN*", "exact/f20"
    );
    for &dim in &dims {
        let ds = datasets::blobs::generate(n, dim, 10, 2026);
        let t20 = fishdbc_total(&ds.items, 20);
        let t50 = fishdbc_total(&ds.items, 50);
        let (tex, _) = time_once(|| {
            exact_hdbscan(
                &ds.items,
                &MetricKind::Euclidean,
                ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
            )
            .expect("exact")
        });
        println!(
            "{:<8} {:>14.3} {:>14.3} {:>14.3} {:>12.1}",
            dim,
            t20,
            t50,
            tex,
            tex / t20
        );
    }
    println!("# paper shape: exact-row growth ≥ FISHDBC-row growth as dim rises;");
    println!("# the exact/f20 ratio should widen with dimensionality.");
}
