//! Table 8 — runtime (in seconds) across all datasets.
//!
//! The paper's grand runtime table: for each dataset, FISHDBC's "build" and
//! "cluster" columns at ef = 20 / 50, and the HDBSCAN* reference — which
//! goes **OOM** on DW-NYTimes (accelerated, but the lookup structures blow
//! memory) and Finefoods (no acceleration: the full pairwise matrix cannot
//! fit).
//!
//! Dataset sizes are scaled (factor ~1/10 to ~1/100) so the whole table
//! runs in minutes; the memory budget for the exact baseline is scaled by
//! the same logic so the paper's OOM rows reproduce *as OOM rows*.
//!
//! Run: `cargo bench --bench table8_runtime`.

use fishdbc::datasets::{self, Dataset};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactParams};
use fishdbc::util::bench::time_once;

struct Row {
    dataset: &'static str,
    ds: Dataset,
    /// Exact-baseline pairwise-matrix budget (bytes): models the paper's
    /// 128 GB box at our scaled n. Rows whose full matrix exceeds this
    /// print OOM exactly like the paper's NYTimes / Finefoods rows.
    exact_budget: usize,
}

fn build_and_cluster(ds: &Dataset, ef: usize) -> (f64, f64) {
    let mut f = Fishdbc::new(
        ds.metric,
        FishdbcParams { min_pts: 10, ef, ..Default::default() },
    );
    let (build, _) = time_once(|| {
        for it in ds.items.iter().cloned() {
            f.add(it);
        }
        f.update_mst();
    });
    let (cluster, _) = time_once(|| f.cluster(10));
    (build, cluster)
}

fn main() {
    // Budgets scale the paper's 128 GB box down in proportion to how much
    // we scaled each dataset: the paper's OOM rows (NYTimes ~1/50 scale,
    // Finefoods ~1/190) keep budgets that their scaled matrices still
    // exceed; the rows the paper's box *could* fit keep budgets that fit.
    let rows = vec![
        // paper n: DW-Kos 3 430 (kept ~1/2), DW-Enron 39 861, DW-NYTimes
        // 300 000, Finefoods 568 474, Household 2 049 280, USPS 2 197
        Row { dataset: "DW-Kos", ds: datasets::docword::generate(1500, 914, 1), exact_budget: 512 << 20 },
        Row { dataset: "DW-Enron", ds: datasets::docword::generate(3000, 2120, 2), exact_budget: 512 << 20 },
        Row { dataset: "DW-NYTimes", ds: datasets::docword::generate(6000, 4096, 3), exact_budget: 64 << 20 },
        Row { dataset: "Finefoods", ds: datasets::reviews::generate(3000, 4), exact_budget: 16 << 20 },
        Row { dataset: "Household", ds: datasets::household::generate(8000, 5), exact_budget: 512 << 20 },
        Row { dataset: "USPS", ds: datasets::usps::generate(2196, 6), exact_budget: 512 << 20 },
    ];

    println!("# Table 8: runtime (s); per-row exact budgets scale the paper's 128 GB box");
    println!(
        "{:<12} {:>6} | {:>9} {:>9} | {:>9} {:>9} | {:>12}",
        "dataset", "n", "b(ef=20)", "c(ef=20)", "b(ef=50)", "c(ef=50)", "HDBSCAN*"
    );
    for row in rows {
        let (b20, c20) = build_and_cluster(&row.ds, 20);
        let (b50, c50) = build_and_cluster(&row.ds, 50);
        let exact_cell = {
            let mut out = String::new();
            let (t, res) = time_once(|| {
                exact_hdbscan(
                    &row.ds.items,
                    &row.ds.metric,
                    ExactParams {
                        min_pts: 10,
                        mcs: 10,
                        matrix_budget: Some(row.exact_budget),
                    },
                )
            });
            match res {
                Ok(_) => out.push_str(&format!("{t:>12.2}")),
                Err(_) => out.push_str(&format!("{:>12}", "OOM")),
            }
            out
        };
        println!(
            "{:<12} {:>6} | {:>9.2} {:>9.4} | {:>9.2} {:>9.4} | {}",
            row.dataset,
            row.ds.n(),
            b20,
            c20,
            b50,
            c50,
            exact_cell
        );
    }
    println!("# paper shape: cluster ≪ build everywhere; ef=50 ≈ 1.4-1.7x ef=20 build;");
    println!("# the two largest datasets OOM the exact baseline but not FISHDBC.");
}
