//! Ablations over FISHDBC's design choices (DESIGN.md §Ablations):
//!
//!  A. **ef sweep** — the paper evaluates ef ∈ [10, 200] and reports that
//!     [20, 50] hits the best speed/quality trade-off, *lower* than the
//!     ef = 100 recommended for HNSW nearest-neighbor search (§4.1).
//!  B. **MinPts** — "MinPts has only a minor effect on final results".
//!  C. **α (candidate-buffer factor)** — "moderate impact on runtime";
//!     bounds the buffer at α·n, trading UPDATE_MST frequency vs memory.
//!  D. **candidate source** — full distance-call piggybacking (FISHDBC)
//!     vs the "simpler design" of an MST over the final kNN graph only,
//!     which the paper §3.1 argues breaks up clusters.
//!
//! Run: `cargo bench --bench ablations`.

use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::cluster_from_msf;
use fishdbc::metrics::score_external;
use fishdbc::util::bench::time_once;

fn build(
    items: &[Item],
    metric: MetricKind,
    p: FishdbcParams,
) -> (Fishdbc<Item, MetricKind>, f64) {
    let mut f = Fishdbc::new(metric, p);
    let (t, _) = time_once(|| {
        for it in items.iter().cloned() {
            f.add(it);
        }
        f.update_mst();
    });
    (f, t)
}

fn main() {
    // A hard-enough workload that quality differences are visible: blobs
    // with moderate separation + a labeled synth set.
    let n = 3000;
    let blobs = datasets::blobs::generate(n, 64, 10, 55);
    let truth = blobs.primary_labels().unwrap().to_vec();

    println!("# Ablation A: ef sweep (blobs n={n}, dim=64)");
    println!(
        "{:<6} {:>10} {:>12} {:>8} {:>8} {:>10}",
        "ef", "build(s)", "dist calls", "AMI*", "ARI*", "clusters"
    );
    for ef in [10usize, 20, 50, 100, 200] {
        let p = FishdbcParams { min_pts: 10, ef, ..Default::default() };
        let (mut f, t) = build(&blobs.items, blobs.metric, p);
        let c = f.cluster(10);
        let s = score_external(&c.labels, &truth);
        println!(
            "{:<6} {:>10.2} {:>12} {:>8.3} {:>8.3} {:>10}",
            ef,
            t,
            f.dist_calls(),
            s.ami_star,
            s.ari_star,
            c.n_clusters
        );
    }
    println!("# paper shape: quality saturates by ef≈20-50; cost keeps rising.\n");

    println!("# Ablation B: MinPts (blobs n={n})");
    println!(
        "{:<8} {:>10} {:>12} {:>8} {:>10}",
        "MinPts", "build(s)", "dist calls", "AMI*", "clusters"
    );
    for min_pts in [5usize, 10, 15, 25] {
        let p = FishdbcParams { min_pts, ef: 20, ..Default::default() };
        let (mut f, t) = build(&blobs.items, blobs.metric, p);
        let c = f.cluster(min_pts);
        let s = score_external(&c.labels, &truth);
        println!(
            "{:<8} {:>10.2} {:>12} {:>8.3} {:>10}",
            min_pts,
            t,
            f.dist_calls(),
            s.ami_star,
            c.n_clusters
        );
    }
    println!("# paper shape: minor quality effect; cost grows mildly with MinPts.\n");

    println!("# Ablation C: candidate-buffer factor α (blobs n={n})");
    println!(
        "{:<8} {:>10} {:>12} {:>14} {:>8}",
        "alpha", "build(s)", "MST updates", "peak buffer", "AMI*"
    );
    for alpha in [0.5f64, 2.0, 5.0, 20.0] {
        let p = FishdbcParams { min_pts: 10, ef: 20, alpha, seed: 0xF15D };
        let mut f = Fishdbc::new(blobs.metric, p);
        let mut peak = 0usize;
        let (t, _) = time_once(|| {
            for it in blobs.items.iter().cloned() {
                f.add(it);
                peak = peak.max(f.stats().candidate_edges_buffered);
            }
            f.update_mst();
        });
        let c = f.cluster(10);
        let s = score_external(&c.labels, &truth);
        println!(
            "{:<8} {:>10.2} {:>12} {:>14} {:>8.3}",
            alpha,
            t,
            f.stats().mst_updates,
            peak,
            s.ami_star
        );
    }
    println!("# shape: larger α ⇒ fewer Kruskal runs, bigger buffer, same quality.\n");

    println!("# Ablation D: full piggybacking vs kNN-graph-only MST (paper §3.1)");
    println!(
        "{:<22} {:>8} {:>8} {:>10} {:>12}",
        "candidate source", "AMI*", "ARI*", "clusters", "msf comps"
    );
    // run on datasets where local kNN graphs tend to fragment: elongated
    // low-dim blobs and the synth transaction set
    for (name, ds) in [
        ("blobs", datasets::blobs::generate(n, 8, 10, 77)),
        ("synth", datasets::synth::generate(2000, 512, 5, 78)),
    ] {
        let t = ds.primary_labels().unwrap().to_vec();
        let p = FishdbcParams { min_pts: 10, ef: 20, ..Default::default() };
        let (mut f, _) = build(&ds.items, ds.metric, p);

        let full = f.cluster(10);
        let sf = score_external(&full.labels, &t);

        let knn_msf = f.knn_only_msf();
        let knn = cluster_from_msf(knn_msf.edges(), ds.n(), 10);
        let sk = score_external(&knn.labels, &t);

        println!(
            "{:<22} {:>8.3} {:>8.3} {:>10} {:>12}",
            format!("{name}: full (paper)"),
            sf.ami_star,
            sf.ari_star,
            full.n_clusters,
            f.msf().components()
        );
        println!(
            "{:<22} {:>8.3} {:>8.3} {:>10} {:>12}",
            format!("{name}: kNN-only"),
            sk.ami_star,
            sk.ari_star,
            knn.n_clusters,
            knn_msf.components()
        );
    }
    println!("# paper claim: kNN-only fragments (more components / more, smaller");
    println!("# clusters / lower AMI*); full piggybacking keeps clusters connected.");
}
