//! Extraction sweep — hierarchy-as-a-service re-extraction cost vs the
//! merge that built the hierarchy (ISSUE 9 acceptance).
//!
//! Protocol: ingest n blob points and merge once (from scratch), then add
//! 1% more and merge again (the delta baseline). Then sweep `relabel_at`
//! over mcs {5, 10, 25} × {stability, leaf, hybrid-eps} twice. The sweep
//! runs entirely against the pinned epoch's cached dendrogram, so the
//! acceptance asserts: **zero** extra metric calls across the whole
//! sweep, every second-pass extraction hits the memo, and the slowest
//! single extraction is still cheaper than the from-scratch merge.
//!
//! Run: `cargo bench --bench extraction_sweep` (optional first arg
//! overrides n, e.g. `-- 2000` for the CI smoke pass).

use std::time::Instant;

use fishdbc::engine::{
    Engine, EngineConfig, ExtractionMode, ExtractionParams,
};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::util::bench::emit_bench_json;
use fishdbc::{datasets, Item, MetricKind};

fn main() {
    let n: usize = std::env::args()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(50_000);
    let dim = 16;
    let delta = (n / 100).max(1);
    let ds = datasets::blobs::generate(n + delta, dim, 10, 42);

    let engine: Engine<Item, MetricKind> =
        Engine::spawn(ds.metric, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
            shards: 4,
            mcs: 10,
            ..Default::default()
        });
    println!(
        "# extraction sweep: blobs n={n} (+{delta} = 1% delta), dim={dim}, \
         4 shards, MinPts=10 ef=20"
    );

    for chunk in ds.items[..n].chunks(512) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();
    let t0 = Instant::now();
    engine.cluster(10);
    let full_secs = t0.elapsed().as_secs_f64();

    for chunk in ds.items[n..].chunks(512) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();
    let t1 = Instant::now();
    let merged = engine.cluster(10);
    let inc_secs = t1.elapsed().as_secs_f64();
    println!(
        "full merge {full_secs:8.3}s | delta merge {inc_secs:8.3}s \
         (epoch {})",
        merged.epoch
    );

    // the sweep proper: every (mcs, mode) pair twice, pinned to the
    // delta merge's epoch, with the metric-call odometer watched
    let modes = [
        ExtractionMode::Stability,
        ExtractionMode::Leaf,
        ExtractionMode::HybridEps,
    ];
    let calls0 = engine.stats().metric_calls;
    let mut max_extract = 0.0f64;
    let mut repeats_hit = true;
    println!(
        "{:<10} {:<5} {:>8} {:>10} {:>9} {:>12}",
        "mode", "mcs", "clusters", "clustered", "memo_hit", "extract(s)"
    );
    for pass in 0..2 {
        for mode in modes {
            for mcs in [5usize, 10, 25] {
                let eps = match mode {
                    ExtractionMode::HybridEps => 0.5,
                    _ => 0.0,
                };
                let r = engine.relabel_at(ExtractionParams { mcs, eps, mode });
                max_extract = max_extract.max(r.secs);
                if pass == 1 && !r.memo_hit {
                    repeats_hit = false;
                }
                println!(
                    "{:<10} {:<5} {:>8} {:>10} {:>9} {:>12.6}{}",
                    mode.name(),
                    mcs,
                    r.clustering.n_clusters,
                    r.clustering.n_clustered(),
                    r.memo_hit,
                    r.secs,
                    if pass == 1 { "  (repeat)" } else { "" },
                );
            }
        }
    }
    let sweep_calls = engine.stats().metric_calls - calls0;
    let es = engine.stats();

    println!(
        "# sweep: {} extractions ({} memo hits), {sweep_calls} metric calls, \
         slowest {max_extract:.6}s vs from-scratch merge {full_secs:.3}s",
        es.pipeline.extractions, es.pipeline.extract_memo_hits,
    );
    let pass = sweep_calls == 0 && repeats_hit && max_extract < full_secs;
    println!("# acceptance: {}", if pass { "PASS" } else { "FAIL" });

    emit_bench_json("extraction_sweep", |w| {
        w.usize("n", n)
            .usize("shards", 4)
            .f64("full_secs", full_secs)
            .f64("delta_secs", inc_secs)
            .f64("max_extract_secs", max_extract)
            .u64("sweep_metric_calls", sweep_calls)
            .u64("extractions", es.pipeline.extractions)
            .u64("extract_memo_hits", es.pipeline.extract_memo_hits)
            .str("acceptance", if pass { "PASS" } else { "FAIL" });
    });
    engine.shutdown();
    if !pass {
        std::process::exit(1);
    }
}
