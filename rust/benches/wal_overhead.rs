//! WAL overhead — durable vs volatile ingest (ISSUE 10 acceptance).
//!
//! Protocol: ingest n blob points into a plain (volatile) engine, then
//! into a WAL-journaled engine with an fsync after every batch (the
//! serve layer's durable ack cadence — the worst case for the WAL).
//! Reports both throughputs, the overhead ratio, the fsync latency
//! quantiles, and the cost of one checkpoint over the full state.
//!
//! Run: `cargo bench --bench wal_overhead` (optional first arg
//! overrides n, e.g. `-- 2000` for the CI smoke pass).

use std::time::Instant;

use fishdbc::durable::{Durable, DurabilityConfig};
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::obs::{CounterId, HistId};
use fishdbc::util::bench::emit_bench_json;
use fishdbc::{datasets, MetricKind};

const CHUNK: usize = 256;

fn config(shards: usize) -> EngineConfig {
    EngineConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        shards,
        mcs: 10,
        ..Default::default()
    }
}

fn main() {
    let n: usize = std::env::args()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(20_000);
    let shards = 4;
    let dim = 16;
    let ds = datasets::blobs::generate(n, dim, 10, 42);
    println!("# wal overhead: blobs n={n}, dim={dim}, {shards} shards, fsync per {CHUNK}-item batch");

    // volatile baseline: the engine as it was before ISSUE 10
    let engine = Engine::spawn(MetricKind::Euclidean, config(shards));
    let t0 = Instant::now();
    for chunk in ds.items.chunks(CHUNK) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();
    let volatile_secs = t0.elapsed().as_secs_f64();
    let volatile_rate = n as f64 / volatile_secs.max(1e-9);
    engine.shutdown();
    println!("volatile ingest: {volatile_secs:8.3}s  ({volatile_rate:9.0} items/s)");

    // durable run: journal + fsync every batch before offering the next
    let dir = std::env::temp_dir()
        .join(format!("fishdbc_wal_overhead_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let d = Durable::open_framework(
        MetricKind::Euclidean,
        config(shards),
        DurabilityConfig::new(&dir),
    )
    .expect("open WAL");
    let t1 = Instant::now();
    for chunk in ds.items.chunks(CHUNK) {
        d.engine().add_batch(chunk.to_vec());
        d.sync().expect("WAL fsync");
    }
    d.engine().flush();
    let durable_secs = t1.elapsed().as_secs_f64();
    let durable_rate = n as f64 / durable_secs.max(1e-9);
    let overhead = durable_secs / volatile_secs.max(1e-9);

    let reg = d.engine().registry().snapshot();
    let fsyncs = reg.counter(CounterId::WalFsyncs);
    let appends = reg.counter(CounterId::WalAppends);
    let bytes = reg.counter(CounterId::WalBytes);
    let fsync = reg.hist(HistId::WalFsync);
    let p50_us = fsync.quantile_ns(0.50) as f64 / 1e3;
    let p99_us = fsync.quantile_ns(0.99) as f64 / 1e3;
    println!(
        "durable  ingest: {durable_secs:8.3}s  ({durable_rate:9.0} items/s)  \
         {overhead:5.2}x volatile"
    );
    println!(
        "wal: {appends} appends, {bytes} bytes, {fsyncs} fsyncs \
         (p50 {p50_us:.0}us p99 {p99_us:.0}us)"
    );

    let t2 = Instant::now();
    let stats = d.checkpoint().expect("checkpoint");
    let checkpoint_secs = t2.elapsed().as_secs_f64();
    println!(
        "checkpoint: {checkpoint_secs:8.3}s at watermark {} \
         ({} segments trimmed)",
        stats.watermark, stats.trimmed_segments
    );
    d.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    emit_bench_json("wal_overhead", |w| {
        w.usize("n", n)
            .usize("shards", shards)
            .f64("volatile_items_per_sec", volatile_rate)
            .f64("durable_items_per_sec", durable_rate)
            .f64("overhead_x", overhead)
            .u64("wal_appends", appends)
            .u64("wal_bytes", bytes)
            .u64("fsyncs", fsyncs)
            .f64("fsync_p50_us", p50_us)
            .f64("fsync_p99_us", p99_us)
            .f64("checkpoint_secs", checkpoint_secs);
    });
}
