//! Figure 2 — Finefoods: scalability as the dataset size increases.
//!
//! The paper streams the 568 474-review Finefoods corpus (Jaro-Winkler)
//! into FISHDBC and plots the **average number of distance calls per item**
//! in each 2%-of-dataset window: the curve grows at first, then plateaus —
//! the empirical signature of the O(log n)-calls-per-insert behaviour that
//! Theorem 3.2 relies on.
//!
//! Same series here on the synthetic review corpus (scaled n), plus a
//! cluster-extraction time per checkpoint (the paper notes clustering "can
//! be computed every time 2% of the dataset is added" cheaply).
//!
//! Run: `cargo bench --bench fig2_scalability`.

use fishdbc::datasets;
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::util::bench::time_once;

fn main() {
    let n = 5_000; // paper: 568 474; scaled to keep the bench minutes
    let checkpoints = 10; // every 10% (paper: every 2%)
    let ds = datasets::reviews::generate(n, 12);

    println!("# Figure 2: reviews (n={n}, Jaro-Winkler) — calls/item per window");
    println!(
        "{:<8} {:>10} {:>16} {:>14} {:>12}",
        "items", "calls", "calls/item(win)", "extract(s)", "clusters"
    );
    let mut f = Fishdbc::new(
        ds.metric,
        FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
    );
    let window = n / checkpoints;
    let mut last_calls = 0u64;
    let mut series = Vec::new();
    for (i, it) in ds.items.iter().cloned().enumerate() {
        f.add(it);
        if (i + 1) % window == 0 {
            let calls = f.dist_calls();
            let per_item = (calls - last_calls) as f64 / window as f64;
            let (extract, c) = time_once(|| f.cluster(10));
            println!(
                "{:<8} {:>10} {:>16.1} {:>14.4} {:>12}",
                i + 1,
                calls,
                per_item,
                extract,
                c.n_clusters
            );
            series.push(per_item);
            last_calls = calls;
        }
    }
    let first = series.first().copied().unwrap_or(0.0);
    let last = series.last().copied().unwrap_or(0.0);
    println!("# growth of window cost across the run: {:.2}x", last / first.max(1e-9));
    println!("# paper shape: early growth then plateau — the last windows should");
    println!("# cost little more than the middle ones (far from the ~{}x of O(n))",
        checkpoints);
}
