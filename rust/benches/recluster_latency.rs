//! Recluster latency — the epoch-based delta merge vs a from-scratch
//! merge (ISSUE 2 acceptance).
//!
//! Protocol: ingest n blob points, time the first `cluster()` (from
//! scratch: full bridge search + full Kruskal + condense). Then add 1%
//! more points and time the second `cluster()` — insert-time bridging and
//! the delta merge should make it cost **< 25%** of the from-scratch call
//! (printed as the acceptance line). A third `cluster()` with no new data
//! shows the short-circuit floor.
//!
//! Run: `cargo bench --bench recluster_latency` (optional first arg
//! overrides n, e.g. `-- 2000` for the CI smoke pass).

use std::time::Instant;

use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::obs::HistId;
use fishdbc::util::bench::emit_bench_json;
use fishdbc::{datasets, metrics::score_external};

fn main() {
    let n: usize = std::env::args()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(50_000);
    let dim = 16;
    let delta = (n / 100).max(1);
    let ds = datasets::blobs::generate(n + delta, dim, 10, 42);
    let truth: Vec<usize> = ds.primary_labels().unwrap().to_vec();

    let engine = Engine::spawn(ds.metric, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        shards: 4,
        mcs: 10,
        ..Default::default()
    });
    println!(
        "# recluster latency: blobs n={n} (+{delta} = 1% delta), dim={dim}, \
         4 shards, MinPts=10 ef=20"
    );

    for chunk in ds.items[..n].chunks(512) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();

    let t0 = Instant::now();
    let full = engine.cluster(10);
    let full_secs = t0.elapsed().as_secs_f64();
    println!(
        "full  cluster: {full_secs:8.3}s | bridge {:7.3}s kruskal {:7.3}s \
         dendro {:7.3}s condense {:7.3}s | {} forest edges, {} bridges, \
         {} changed shards",
        full.bridge_secs,
        full.kruskal_secs,
        full.stages.dendrogram_secs,
        full.stages.condense_secs + full.stages.extract_secs,
        full.n_msf_edges,
        full.n_bridge_edges,
        full.n_changed_shards,
    );

    // +1% of the stream, then the incremental recluster
    for chunk in ds.items[n..].chunks(512) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();
    let t1 = Instant::now();
    let inc = engine.cluster(10);
    let inc_secs = t1.elapsed().as_secs_f64();
    println!(
        "delta cluster: {inc_secs:8.3}s | bridge {:7.3}s kruskal {:7.3}s \
         dendro {:7.3}s condense {:7.3}s | {} forest edges, {} bridges, \
         {} changed shards",
        inc.bridge_secs,
        inc.kruskal_secs,
        inc.stages.dendrogram_secs,
        inc.stages.condense_secs + inc.stages.extract_secs,
        inc.n_msf_edges,
        inc.n_bridge_edges,
        inc.n_changed_shards,
    );

    // short-circuit floor: nothing changed
    let t2 = Instant::now();
    let idle = engine.cluster(10);
    let idle_secs = t2.elapsed().as_secs_f64();
    println!(
        "idle  cluster: {idle_secs:8.3}s | reused extraction: {}",
        idle.stages.reused_clustering
    );

    let quality = score_external(&inc.clustering.labels, &truth);
    let ratio = inc_secs / full_secs.max(1e-9);
    println!(
        "# incremental recluster after +1%: {:.1}% of from-scratch \
         (target < 25%), ARI* vs truth {:.3}",
        ratio * 100.0,
        quality.ari_star
    );
    println!(
        "# acceptance: {}",
        if ratio < 0.25 { "PASS" } else { "FAIL" }
    );

    let merge_hist = engine.registry().hist(HistId::Merge).snapshot();
    emit_bench_json("recluster_latency", |w| {
        w.usize("n", n)
            .usize("shards", 4)
            .f64("full_secs", full_secs)
            .f64("delta_secs", inc_secs)
            .f64("idle_secs", idle_secs)
            .f64("delta_over_full", ratio)
            .f64("ari_star", quality.ari_star)
            .u64("metric_calls", engine.stats().metric_calls)
            .f64("merge_p50_s", merge_hist.quantile_secs(0.5))
            .f64("merge_p99_s", merge_hist.quantile_secs(0.99))
            .str("acceptance", if ratio < 0.25 { "PASS" } else { "FAIL" });
    });
    engine.shutdown();
}
