//! Distance kernels — scalar `dist` loop vs one `Metric::distance_batch`
//! dispatch (ISSUE 7).
//!
//! Measures exactly what the HNSW rewire buys at the metric layer: the
//! batch entry hoists the query-side work (dense borrow, cosine query
//! norm) and drives the shared chunked kernels with the candidate loop
//! inside, instead of paying one virtual call + per-pair setup per
//! candidate. Dense metrics are timed at dim ∈ {16, 128}; Jaro-Winkler
//! rides the default scalar-loop fallback, so its row documents the
//! expected ~1× parity — the batch hook is an amortization, never a
//! different algorithm.
//!
//! Each configuration asserts bit-identity between the two paths before
//! timing (the conformance property from `distances::tests`, re-checked
//! on bench-sized data) and appends one LDJSON record to
//! `BENCH_distance_kernels.json`.
//!
//! Run: `cargo bench --bench distance_kernels` (optional numeric arg
//! overrides the candidate count, e.g. `-- 2000` for the CI smoke run).

use fishdbc::distances::{Item, Metric, MetricKind};
use fishdbc::util::bench::{emit_bench_json, time_n};
use fishdbc::util::rng::Rng;

/// One timed comparison: `cands.len()` pairs per iteration on both paths.
fn run_case(kind: MetricKind, label: &str, dim: usize, q: &Item, cands: &[Item]) {
    let refs: Vec<&Item> = cands.iter().collect();
    let mut out = vec![0.0f64; refs.len()];

    // conformance first: timing a wrong kernel is worse than useless
    kind.distance_batch(q, &refs, &mut out);
    for (c, &b) in refs.iter().zip(&out) {
        assert_eq!(
            kind.dist(q, c).to_bits(),
            b.to_bits(),
            "batch diverged from scalar for {label}"
        );
    }

    let iters = if refs.len() >= 100_000 { 20 } else { 50 };
    let scalar = time_n(&format!("{label} scalar"), 3, iters, || {
        let mut acc = 0.0f64;
        for c in &refs {
            acc += kind.dist(q, c);
        }
        acc
    });
    let batch = time_n(&format!("{label} batch"), 3, iters, || {
        kind.distance_batch(q, &refs, &mut out);
        out[0]
    });
    scalar.print();
    batch.print();
    let speedup = scalar.mean_s / batch.mean_s.max(1e-12);
    println!("#   {label}: batch speedup {speedup:.2}x");

    emit_bench_json("distance_kernels", |w| {
        w.str("kernel", label)
            .usize("dim", dim)
            .usize("n", refs.len())
            .f64("scalar_secs", scalar.mean_s)
            .f64("batch_secs", batch.mean_s)
            .f64("speedup", speedup)
            .f64("pairs_per_sec", refs.len() as f64 / batch.mean_s.max(1e-12));
    });
}

fn dense(rng: &mut Rng, dim: usize) -> Item {
    Item::Dense((0..dim).map(|_| rng.f32() - 0.5).collect())
}

fn word(rng: &mut Rng) -> Item {
    let len = 4 + rng.below(12);
    Item::Text(
        (0..len)
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect(),
    )
}

fn main() {
    let mut n: usize = 200_000;
    for a in std::env::args().skip(1) {
        if let Ok(v) = a.parse::<usize>() {
            n = v;
        }
    }
    let mut rng = Rng::new(7);
    println!("# distance kernels: scalar loop vs distance_batch, n={n} pairs");

    for dim in [16usize, 128] {
        let q = dense(&mut rng, dim);
        let cands: Vec<Item> = (0..n).map(|_| dense(&mut rng, dim)).collect();
        for (kind, name) in [
            (MetricKind::SqEuclidean, "sqeuclidean"),
            (MetricKind::Euclidean, "euclidean"),
            (MetricKind::Cosine, "cosine"),
        ] {
            run_case(kind, &format!("{name}/d{dim}"), dim, &q, &cands);
        }
    }

    // non-dense fallback: the default scalar-loop distance_batch — the
    // record documents parity (strings are far slower per pair, so cap n)
    let tn = n.min(20_000);
    let q = word(&mut rng);
    let cands: Vec<Item> = (0..tn).map(|_| word(&mut rng)).collect();
    run_case(MetricKind::JaroWinkler, "jaro_winkler/fallback", 0, &q, &cands);
}
