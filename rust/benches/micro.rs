//! Micro-benchmarks of every hot path, feeding the §Perf iteration log in
//! EXPERIMENTS.md: distance kernels (native vs PJRT-compiled), HNSW
//! insertion, candidate processing, incremental Kruskal, and hierarchy
//! extraction.
//!
//! Run: `cargo bench --bench micro`.

use fishdbc::datasets;
use fishdbc::distances::{bitmap, sparse, text, vector, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::{cluster_from_msf, CondensedTree, Dendrogram};
use fishdbc::mst::{Edge, Msf};
#[cfg(feature = "xla")]
use fishdbc::runtime::{default_artifacts_dir, Runtime};
use fishdbc::util::bench::time_n;
use fishdbc::util::rng::Rng;

fn rand_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.normal() as f32).collect()
}

fn bench_distances() {
    println!("## distance kernels (native rust)");
    let mut rng = Rng::new(1);
    let reps = 200_000;

    for d in [16usize, 128, 1024] {
        let a = rand_vec(&mut rng, d);
        let b = rand_vec(&mut rng, d);
        let s = time_n(&format!("euclidean d={d} x{reps}"), 1, 5, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += vector::euclidean(
                    std::hint::black_box(&a),
                    std::hint::black_box(&b),
                );
            }
            acc
        });
        println!(
            "  euclidean d={d:<5} {:>8.1} Mcalls/s",
            reps as f64 / s.min_s / 1e6
        );
    }
    let a = rand_vec(&mut rng, 1024);
    let b = rand_vec(&mut rng, 1024);
    let s = time_n("cosine d=1024", 1, 5, || {
        let mut acc = 0.0;
        for _ in 0..reps / 10 {
            acc += vector::cosine(std::hint::black_box(&a), std::hint::black_box(&b));
        }
        acc
    });
    println!("  cosine    d=1024 {:>8.1} Mcalls/s", (reps / 10) as f64 / s.min_s / 1e6);

    let sa: Vec<u32> = (0..200).map(|i| i * 3).collect();
    let sb: Vec<u32> = (0..200).map(|i| i * 4).collect();
    let s = time_n("jaccard |200|", 1, 5, || {
        let mut acc = 0.0;
        for _ in 0..reps / 10 {
            acc += sparse::jaccard(std::hint::black_box(&sa), std::hint::black_box(&sb));
        }
        acc
    });
    println!("  jaccard   |200|  {:>8.1} Mcalls/s", (reps / 10) as f64 / s.min_s / 1e6);

    let ta = "user login failed for account 4242 from ip 10.0.0.1".to_string();
    let tb = "user login failed for account 7777 from ip 10.9.8.7".to_string();
    let s = time_n("jaro-winkler ~50ch", 1, 5, || {
        let mut acc = 0.0;
        for _ in 0..reps / 10 {
            acc += text::jaro_winkler(std::hint::black_box(&ta), std::hint::black_box(&tb));
        }
        acc
    });
    println!("  jaro-winkler ~50c{:>8.1} Mcalls/s", (reps / 10) as f64 / s.min_s / 1e6);

    let ba = bitmap::Bitmap::from_bools(&(0..256).map(|i| i % 3 == 0).collect::<Vec<_>>());
    let bb = bitmap::Bitmap::from_bools(&(0..256).map(|i| i % 2 == 0).collect::<Vec<_>>());
    let s = time_n("simpson 256b", 1, 5, || {
        let mut acc = 0.0;
        for _ in 0..reps {
            acc += bitmap::simpson(std::hint::black_box(&ba), std::hint::black_box(&bb));
        }
        acc
    });
    println!("  simpson   256b   {:>8.1} Mcalls/s", reps as f64 / s.min_s / 1e6);
}

#[cfg(not(feature = "xla"))]
fn bench_pjrt() {
    println!("## PJRT compiled kernels vs native batch");
    println!("  SKIP — rebuild with `--features xla` and run `make artifacts`");
}

#[cfg(feature = "xla")]
fn bench_pjrt() {
    println!("## PJRT compiled kernels vs native batch");
    let dir = default_artifacts_dir();
    let Ok(rt) = Runtime::load(&dir) else {
        println!("  SKIP — run `make artifacts`");
        return;
    };
    let mut rng = Rng::new(2);
    let d = 128;
    let b = 256;
    let q = rand_vec(&mut rng, d);
    let cands: Vec<Vec<f32>> = (0..b).map(|_| rand_vec(&mut rng, d)).collect();
    let refs: Vec<&[f32]> = cands.iter().map(|c| c.as_slice()).collect();
    let name = "query_topk_euclidean_b256_d128_k10";

    let s = time_n("pjrt query_topk 256x128", 3, 20, || {
        rt.query_topk(name, &q, &refs).unwrap()
    });
    println!(
        "  pjrt  query+topk B={b} D={d}: {:>9.1} us/batch ({:.1} Mdist/s)",
        s.min_s * 1e6,
        b as f64 / s.min_s / 1e6
    );
    let s = time_n("native 256x128", 3, 20, || {
        let mut dists: Vec<(u32, f64)> = refs
            .iter()
            .enumerate()
            .map(|(i, c)| (i as u32, vector::euclidean(&q, c)))
            .collect();
        dists.sort_by(|a, b| a.1.total_cmp(&b.1));
        dists.truncate(10);
        dists
    });
    println!(
        "  native loop  B={b} D={d}: {:>9.1} us/batch ({:.1} Mdist/s)",
        s.min_s * 1e6,
        b as f64 / s.min_s / 1e6
    );
}

fn bench_hnsw_insert() {
    println!("## HNSW insertion (euclidean blobs, dim=32)");
    for n in [2000usize, 8000] {
        let ds = datasets::blobs::generate(n, 32, 10, 3);
        let mut f = Fishdbc::new(
            MetricKind::Euclidean,
            FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        );
        let t0 = std::time::Instant::now();
        for it in ds.items.iter().cloned() {
            f.add(it);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  n={n:<6} {:>8.1} us/insert  {:>6.0} dists/insert  {:>8.2} Mdist/s",
            dt / n as f64 * 1e6,
            f.dist_calls() as f64 / n as f64,
            f.dist_calls() as f64 / dt / 1e6
        );
    }
}

fn bench_mst() {
    println!("## incremental Kruskal (MSF update)");
    let mut rng = Rng::new(4);
    for (nodes, batch) in [(20_000usize, 100_000usize), (100_000, 500_000)] {
        let edges: Vec<Edge> = (0..batch)
            .map(|_| {
                Edge::new(
                    rng.below(nodes) as u32,
                    rng.below(nodes) as u32,
                    rng.f64(),
                )
            })
            .collect();
        let s = time_n(&format!("kruskal {nodes}n {batch}e"), 1, 5, || {
            let mut msf = Msf::new();
            msf.update(edges.clone(), nodes);
            msf
        });
        println!(
            "  {nodes:>7} nodes {batch:>7} edges: {:>8.1} ms  ({:.1} Medges/s)",
            s.min_s * 1e3,
            batch as f64 / s.min_s / 1e6
        );
    }
}

fn bench_extract() {
    println!("## hierarchy extraction (dendrogram → condense → flat)");
    let mut rng = Rng::new(5);
    for n in [20_000usize, 100_000] {
        // a realistic MSF: random spanning tree with mixed weights
        let edges: Vec<Edge> = (1..n)
            .map(|i| {
                let parent = rng.below(i) as u32;
                Edge::new(parent, i as u32, rng.f64() * 10.0)
            })
            .collect();
        let s = time_n(&format!("extract n={n}"), 1, 5, || {
            cluster_from_msf(&edges, n, 10)
        });
        println!(
            "  n={n:<7}: {:>8.1} ms  ({:.2} Mpoints/s)",
            s.min_s * 1e3,
            n as f64 / s.min_s / 1e6
        );
        // stage split
        let s1 = time_n("dendro", 1, 5, || Dendrogram::from_msf(&edges, n));
        let dendro = Dendrogram::from_msf(&edges, n);
        let s2 = time_n("condense", 1, 5, || {
            CondensedTree::from_dendrogram(&dendro, 10)
        });
        println!(
            "    dendrogram {:>8.1} ms | condense {:>8.1} ms",
            s1.min_s * 1e3,
            s2.min_s * 1e3
        );
    }
}

fn main() {
    println!("# micro-benchmarks (hot paths)");
    bench_distances();
    bench_pjrt();
    bench_hnsw_insert();
    bench_mst();
    bench_extract();
}
