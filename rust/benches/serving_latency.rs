//! Serving-path latency under load — `fishdbc serve` end to end (ISSUE 8
//! acceptance).
//!
//! Protocol: preload n blob points into a 4-shard engine, publish an
//! epoch, and put a real `serve::Server` (framed TCP, fixed worker pool)
//! in front of it. Six client threads then drive mixed traffic (mostly
//! `Label`, some single-item `Ingest`, occasional `Ping`) over loopback
//! in two timed phases:
//!
//! * **quiescent** — ingest budgets are capped below the background
//!   recluster threshold, so no merge runs while labels are measured;
//! * **merge-active** — a driver thread pumps `add_batch` fast enough to
//!   keep the background recluster pipeline continuously publishing
//!   epochs while the same client mix runs.
//!
//! The acceptance line asserts the label p99 degrades **<= 2x** between
//! the phases (and that at least one merge actually ran in the active
//! phase — otherwise the comparison is vacuous). This is the measured
//! cost of the label path's coupling: `label_against` holds a shard's
//! `state.read()` lock for the duration of its HNSW search, so merge
//! snapshot captures and ingest writers on the same shard can delay it.
//!
//! Run: `cargo bench --bench serving_latency` (optional first arg
//! overrides n, e.g. `-- 2000` for the CI smoke pass).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::obs::CounterId;
use fishdbc::persist::FrameworkCodec;
use fishdbc::serve::{Client, IngestReply, ServeConfig, Server};
use fishdbc::util::bench::emit_bench_json;
use fishdbc::util::rng::Rng;
use fishdbc::{datasets, Item, MetricKind};

const CLIENTS: usize = 6;
const DIM: usize = 16;

/// One phase: `CLIENTS` threads of mixed traffic against `addr` for
/// `secs`, each allowed at most `ingest_budget` single-item ingests.
/// Returns every label round-trip latency in nanoseconds, merged.
fn run_phase(
    addr: std::net::SocketAddr,
    pool: &Arc<Vec<Item>>,
    secs: f64,
    ingest_budget: usize,
    seed: u64,
) -> Vec<u64> {
    let deadline = Instant::now() + Duration::from_secs_f64(secs);
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let pool = Arc::clone(pool);
            std::thread::spawn(move || {
                let mut client = Client::connect(addr, FrameworkCodec)
                    .expect("connect");
                client
                    .set_timeout(Some(Duration::from_secs(30)))
                    .expect("timeout");
                let mut rng = Rng::new(seed ^ (c as u64).wrapping_mul(0x9e37));
                let mut lat = Vec::new();
                let mut budget = ingest_budget;
                while Instant::now() < deadline {
                    let roll = rng.below(100);
                    if roll < 85 || (roll < 95 && budget == 0) {
                        let item = &pool[rng.below(pool.len())];
                        let t0 = Instant::now();
                        client.label(item, 0).expect("label");
                        lat.push(t0.elapsed().as_nanos() as u64);
                    } else if roll < 95 {
                        budget -= 1;
                        let item = pool[rng.below(pool.len())].clone();
                        // Busy is a legal answer under merge pressure;
                        // drop the item rather than spin (the driver
                        // thread owns throughput in the active phase)
                        match client.ingest(&[item]).expect("ingest") {
                            IngestReply::Accepted(_) | IngestReply::Busy => {}
                        }
                    } else {
                        client.ping().expect("ping");
                    }
                }
                lat
            })
        })
        .collect();
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("client thread"));
    }
    all.sort_unstable();
    all
}

fn pctl(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    sorted[((sorted.len() - 1) as f64 * q) as usize]
}

fn main() {
    let n: usize = std::env::args()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(50_000);
    let recluster_every = (n / 10).max(500);
    let phase_secs = if n <= 5000 { 2.0 } else { 6.0 };

    // preload pool + a disjoint extra pool the merge driver pumps
    let ds = datasets::blobs::generate(n * 2, DIM, 10, 42);
    let (preload, extra) = ds.items.split_at(n);
    let pool = Arc::new(preload.to_vec());
    let extra: Vec<Item> = extra.to_vec();

    let engine: Arc<Engine> = Arc::new(Engine::spawn(MetricKind::Euclidean, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        shards: 4,
        mcs: 10,
        recluster_every,
        ..Default::default()
    }));
    for chunk in preload.chunks(512) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();
    engine.cluster(10);

    let server = Server::start(
        Arc::clone(&engine),
        FrameworkCodec,
        "127.0.0.1:0",
        ServeConfig { threads: CLIENTS.min(8), ..Default::default() },
    )
    .expect("server start");
    let addr = server.addr();
    println!(
        "# serving latency: blobs n={n} dim={DIM}, 4 shards, \
         recluster_every={recluster_every}, {CLIENTS} client threads x \
         {phase_secs}s per phase, server {addr}"
    );

    let merges = |e: &Engine| e.registry().counter(CounterId::Merges).get();

    // ---- phase 1: merge-quiescent ------------------------------------
    // total ingest across clients stays under recluster_every/2, so the
    // background recluster thread never fires mid-measurement
    let m0 = merges(&engine);
    let quiet = run_phase(
        addr,
        &pool,
        phase_secs,
        recluster_every / (2 * CLIENTS).max(1) / 2,
        7,
    );
    let merges_quiet = merges(&engine) - m0;

    // ---- phase 2: merge-active ---------------------------------------
    // a driver thread pumps ~2*recluster_every items/s straight into the
    // engine so background merges run continuously under the same mix
    let stop = Arc::new(AtomicBool::new(false));
    let driver = {
        let engine = Arc::clone(&engine);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let batch_gap = Duration::from_secs_f64(
                512.0 / (2.0 * recluster_every as f64),
            );
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let lo = (i * 512) % extra.len();
                let hi = (lo + 512).min(extra.len());
                engine.add_batch(extra[lo..hi].to_vec());
                i += 1;
                std::thread::sleep(batch_gap);
            }
        })
    };
    // let the first merge start before measuring
    std::thread::sleep(Duration::from_millis(300));
    let m1 = merges(&engine);
    let active = run_phase(addr, &pool, phase_secs, 64, 11);
    let merges_active = merges(&engine) - m1;
    stop.store(true, Ordering::Relaxed);
    driver.join().expect("driver thread");

    // ---- report ------------------------------------------------------
    let (q50, q99) = (pctl(&quiet, 0.5), pctl(&quiet, 0.99));
    let (a50, a99) = (pctl(&active, 0.5), pctl(&active, 0.99));
    println!(
        "quiescent  : {:7} labels | p50 {:9.3} ms  p99 {:9.3} ms | {} merges",
        quiet.len(),
        q50 as f64 / 1e6,
        q99 as f64 / 1e6,
        merges_quiet,
    );
    println!(
        "merge-active: {:7} labels | p50 {:9.3} ms  p99 {:9.3} ms | {} merges",
        active.len(),
        a50 as f64 / 1e6,
        a99 as f64 / 1e6,
        merges_active,
    );
    let ratio = a99 as f64 / (q99 as f64).max(1.0);
    println!(
        "# label p99 active/quiescent = {ratio:.2}x (target <= 2x, \
         {merges_active} merges ran during the active phase)"
    );
    println!(
        "# coupling: label_against holds a shard state.read() for its \
         whole HNSW search; merge snapshot captures + ingest writers on \
         that shard are what the active-phase p99 pays for (p50 ratio \
         {:.2}x)",
        a50 as f64 / (q50 as f64).max(1.0)
    );
    let pass = ratio <= 2.0
        && merges_active >= 1
        && !quiet.is_empty()
        && !active.is_empty();
    println!("# acceptance: {}", if pass { "PASS" } else { "FAIL" });

    emit_bench_json("serving_latency", |w| {
        w.usize("n", n)
            .usize("clients", CLIENTS)
            .usize("recluster_every", recluster_every)
            .u64("quiescent_labels", quiet.len() as u64)
            .u64("quiescent_p50_ns", q50)
            .u64("quiescent_p99_ns", q99)
            .u64("active_labels", active.len() as u64)
            .u64("active_p50_ns", a50)
            .u64("active_p99_ns", a99)
            .f64("p99_ratio", ratio)
            .u64("merges_active", merges_active)
            .str("acceptance", if pass { "PASS" } else { "FAIL" });
    });

    server.shutdown();
    drop(engine);
    if !pass {
        std::process::exit(1);
    }
}
