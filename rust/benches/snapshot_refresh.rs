//! Snapshot refresh cost — chunked copy-on-write capture vs the O(n) deep
//! clone it replaced (ISSUE 3 tentpole).
//!
//! Protocol: ingest n blob points into a 2-shard engine, publish a first
//! set of frozen shard snapshots (a full capture: every chunk counts as
//! copied), then repeatedly grow the stream by a dirty ratio — 10%, 1%,
//! 0.1% — and time `Engine::refresh_bridges()`, the partial refresh path
//! that `EngineConfig::bridge_refresh` drives mid-epoch. For each capture
//! the engine's chunk counters report how many chunks were physically
//! copied vs republished by reference, plus approximate bytes copied.
//!
//! Note the workload is adversarial for sharing: blob data hash-routes
//! arbitrarily, so a new item's HNSW rewires touch chunks all over the id
//! space. Copied bytes still scale with the delta (≈ Δ · M · CHUNK worst
//! case), not with n — append-only stores (items, id maps) stay almost
//! fully shared regardless. The `engine_integration` acceptance test pins
//! the ≤ 10%-of-chunks bound on an id-local stream.
//!
//! Run: `cargo bench --bench snapshot_refresh` (optional first arg
//! overrides n, e.g. `-- 2000` for the CI smoke pass).

use std::time::Instant;

use fishdbc::datasets;
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::obs::HistId;
use fishdbc::util::bench::emit_bench_json;

fn main() {
    let n: usize = std::env::args()
        .skip(1)
        .find_map(|a| a.parse().ok())
        .unwrap_or(50_000);
    let dim = 16;
    let ratios = [0.10f64, 0.01, 0.001];
    let extra: usize = ratios
        .iter()
        .map(|r| ((n as f64 * r) as usize).max(1))
        .sum();
    let ds = datasets::blobs::generate(n + extra, dim, 10, 42);

    let engine = Engine::spawn(ds.metric, EngineConfig {
        fishdbc: FishdbcParams { min_pts: 10, ef: 20, ..Default::default() },
        shards: 2,
        mcs: 10,
        ..Default::default()
    });
    println!(
        "# snapshot refresh: blobs n={n}, dim={dim}, 2 shards, MinPts=10 \
         ef=20, chunk={}",
        fishdbc::util::chunked::CHUNK
    );

    for chunk in ds.items[..n].chunks(512) {
        engine.add_batch(chunk.to_vec());
    }
    engine.flush();

    let t0 = Instant::now();
    engine.refresh_bridges();
    let full_ms = t0.elapsed().as_secs_f64() * 1e3;
    let s = engine.stats().pipeline;
    let full_bytes = s.snapshot_bytes_copied;
    println!(
        "full  capture: {full_ms:8.3}ms | {:>6} chunks copied, {:>6} shared \
         | {:8.2} MB copied",
        s.snapshot_chunks_copied,
        s.snapshot_chunks_shared,
        full_bytes as f64 / (1024.0 * 1024.0),
    );

    let mut cursor = n;
    let mut prev = s;
    let mut one_percent_bytes = full_bytes;
    for &ratio in &ratios {
        let delta = ((n as f64 * ratio) as usize).max(1);
        for chunk in ds.items[cursor..cursor + delta].chunks(512) {
            engine.add_batch(chunk.to_vec());
        }
        cursor += delta;
        engine.flush();

        let t = Instant::now();
        engine.refresh_bridges();
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let now = engine.stats().pipeline;
        let copied = now.snapshot_chunks_copied - prev.snapshot_chunks_copied;
        let shared = now.snapshot_chunks_shared - prev.snapshot_chunks_shared;
        let bytes = now.snapshot_bytes_copied - prev.snapshot_bytes_copied;
        let pct = 100.0 * copied as f64 / (copied + shared).max(1) as f64;
        println!(
            "dirty {:>5.1}%: capture {ms:8.3}ms | {copied:>6} chunks copied \
             ({pct:5.1}%), {shared:>6} shared | {:8.2} MB copied",
            ratio * 100.0,
            bytes as f64 / (1024.0 * 1024.0),
        );
        if (ratio - 0.01).abs() < 1e-9 {
            one_percent_bytes = bytes;
        }
        let cap = engine.registry().hist(HistId::SnapshotCapture).snapshot();
        emit_bench_json("snapshot_refresh", |w| {
            w.usize("n", n)
                .usize("shards", 2)
                .f64("dirty_ratio", ratio)
                .f64("capture_ms", ms)
                .u64("chunks_copied", copied)
                .u64("chunks_shared", shared)
                .u64("bytes_copied", bytes)
                .f64("capture_p50_ms", cap.quantile_secs(0.5) * 1e3)
                .f64("capture_p99_ms", cap.quantile_secs(0.99) * 1e3)
                .u64("metric_calls", engine.stats().metric_calls);
        });
        prev = now;
    }

    println!(
        "# capture after +1% copies {:.1}% of the bytes a full capture \
         publishes (chunked COW vs the deep clone it replaced)",
        100.0 * one_percent_bytes as f64 / full_bytes.max(1) as f64
    );
    engine.shutdown();
}
