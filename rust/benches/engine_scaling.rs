//! Engine scaling — ingest throughput vs shard count, plus merged-quality
//! parity with the single-shard path.
//!
//! Acceptance targets (ISSUE 1): on 50k blob points, 4 shards must ingest
//! at ≥ 2× the 1-shard rate, and the merged 4-shard clustering must score
//! ARI ≥ 0.9 against the single-shard clustering of the same stream.
//! Two effects compound toward the speedup: S insertion lanes run in
//! parallel, and each lane's HNSW holds n/S items, so every insert beams
//! through a smaller graph.
//!
//! The workload is selectable, so the same harness measures the paper's
//! non-Euclidean metrics at engine scale (ISSUE 4): any generator from
//! `datasets::DATASET_NAMES` — e.g. `reviews` (Jaro-Winkler text) or
//! `docword` (sparse cosine). Distance calls (the paper's cost model) are
//! reported per row from the engine's shared metric counter.
//!
//! Run: `cargo bench --bench engine_scaling` (optional args override n,
//! dim and the dataset: the first numeric arg is n, the second is dim —
//! e.g. `cargo bench --bench engine_scaling -- 10000` for a quick blobs
//! pass, `-- 50000 128` for the wide-vector row of the EXPERIMENTS.md
//! batching table, or `-- 600 reviews` for the text workload).

use std::time::Instant;

use fishdbc::datasets;
use fishdbc::engine::{Engine, EngineConfig};
use fishdbc::fishdbc::FishdbcParams;
use fishdbc::metrics::adjusted_rand_index;
use fishdbc::obs::HistId;
use fishdbc::util::bench::emit_bench_json;

fn to_pred(labels: &[i32]) -> Vec<usize> {
    labels.iter().map(|&l| (l + 1) as usize).collect()
}

fn main() {
    let mut n: usize = 50_000;
    let mut dim: usize = 16;
    let mut dataset = "blobs".to_string();
    let mut numerics = 0usize;
    for a in std::env::args().skip(1) {
        match a.parse::<usize>() {
            Ok(v) => {
                if numerics == 0 {
                    n = v;
                } else {
                    dim = v;
                }
                numerics += 1;
            }
            Err(_) => {
                if datasets::DATASET_NAMES.contains(&a.as_str()) {
                    dataset = a;
                }
            }
        }
    }
    let ds = datasets::generate(&dataset, n, dim, 42).expect("known dataset");
    let n = ds.n();
    let params = FishdbcParams { min_pts: 10, ef: 20, ..Default::default() };

    println!(
        "# engine scaling: {} n={n} dim={dim} metric={}, MinPts=10 ef=20",
        ds.name,
        ds.metric.name()
    );
    println!(
        "{:<8} {:>10} {:>12} {:>10} {:>14} {:>10} {:>10} {:>12}",
        "shards", "ingest(s)", "items/s", "merge(s)", "dist calls", "clusters",
        "bridges", "ARI vs S=1"
    );

    let mut base: Option<(f64, Vec<i32>)> = None;
    for shards in [1usize, 2, 4, 8] {
        let engine = Engine::spawn(ds.metric, EngineConfig {
            fishdbc: params,
            shards,
            mcs: 10,
            ..Default::default()
        });
        let t0 = Instant::now();
        for chunk in ds.items.chunks(512) {
            engine.add_batch(chunk.to_vec());
        }
        engine.flush();
        let ingest = t0.elapsed().as_secs_f64();

        let snap = engine.cluster(10);
        let stats = engine.stats();
        let calls = stats.metric_calls;
        let ari = match &base {
            None => 1.0,
            Some((_, labels)) => adjusted_rand_index(
                &to_pred(labels),
                &to_pred(&snap.clustering.labels),
            ),
        };
        println!(
            "{:<8} {:>10.2} {:>12.0} {:>10.2} {:>14} {:>10} {:>10} {:>12.3}",
            shards,
            ingest,
            n as f64 / ingest.max(1e-9),
            snap.extract_secs,
            calls,
            snap.clustering.n_clusters,
            snap.n_bridge_edges,
            ari
        );

        // one line-delimited record per configuration (BENCH_engine_scaling.json)
        let ingest_hist =
            engine.registry().hist(HistId::IngestBatch).snapshot();
        emit_bench_json("engine_scaling", |w| {
            w.str("dataset", &ds.name)
                .usize("n", n)
                .usize("dim", dim)
                .usize("shards", shards)
                .f64("ingest_secs", ingest)
                .f64("items_per_sec", n as f64 / ingest.max(1e-9))
                .f64("merge_secs", snap.extract_secs)
                .u64("metric_calls", calls)
                .u64("batch_evals", stats.batch_evals)
                .usize("clusters", snap.clustering.n_clusters)
                .usize("bridges", snap.n_bridge_edges)
                .f64("ari_vs_s1", ari)
                .f64(
                    "ingest_batch_p50_us",
                    ingest_hist.quantile_ns(0.5) as f64 / 1e3,
                )
                .f64(
                    "ingest_batch_p99_us",
                    ingest_hist.quantile_ns(0.99) as f64 / 1e3,
                );
        });

        if base.is_none() {
            base = Some((ingest, snap.clustering.labels.clone()));
        } else if shards == 4 {
            let t1 = base.as_ref().map(|(t, _)| *t).unwrap_or(ingest);
            let speedup = t1 / ingest.max(1e-9);
            println!(
                "# 4-shard ingest speedup over 1 shard: {speedup:.2}x \
                 (target >= 2x), merged ARI {ari:.3} (target >= 0.9)"
            );
        }
        engine.shutdown();
    }
}
