//! Table 3 — Synth: runtime (s), "build" vs "cluster" columns.
//!
//! The paper reports, for the 10 000-transaction Synth dataset (Jaccard
//! distance) at dims 640/1024/2048: FISHDBC's incremental *build* time
//! dominates while *cluster* extraction is more than two orders of
//! magnitude cheaper, and FISHDBC's total beats HDBSCAN* with a margin
//! growing with dimensionality (costlier distance function).
//!
//! Run: `cargo bench --bench table3_synth_runtime`.

use fishdbc::datasets;
use fishdbc::distances::{Item, MetricKind};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactParams};
use fishdbc::util::bench::time_once;

fn build_and_cluster(items: &[Item], ef: usize) -> (f64, f64) {
    let mut f = Fishdbc::new(
        MetricKind::Jaccard,
        FishdbcParams { min_pts: 10, ef, ..Default::default() },
    );
    let (build, _) = time_once(|| {
        for it in items.iter().cloned() {
            f.add(it);
        }
        f.update_mst();
    });
    let (cluster, _) = time_once(|| f.cluster(10));
    (build, cluster)
}

fn main() {
    let n = 2500; // paper: 10 000; scaled to keep the bench minutes
    let dims = [640usize, 1024, 2048];

    println!("# Table 3: synth (n={n}, Jaccard) — runtime (s)");
    println!(
        "{:<6} | {:>10} {:>9} | {:>10} {:>9} | {:>10} | {:>12}",
        "dim", "b(ef=20)", "c(ef=20)", "b(ef=50)", "c(ef=50)", "HDBSCAN*", "build/clust"
    );
    for &dim in &dims {
        let ds = datasets::synth::generate(n, dim, 5, 11);
        let (b20, c20) = build_and_cluster(&ds.items, 20);
        let (b50, c50) = build_and_cluster(&ds.items, 50);
        let (tex, _) = time_once(|| {
            exact_hdbscan(
                &ds.items,
                &MetricKind::Jaccard,
                ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
            )
            .expect("exact")
        });
        println!(
            "{:<6} | {:>10.2} {:>9.4} | {:>10.2} {:>9.4} | {:>10.2} | {:>12.0}",
            dim,
            b20,
            c20,
            b50,
            c50,
            tex,
            b20 / c20.max(1e-9)
        );
    }
    println!("# paper shape: cluster ≪ build (>100x); ef=50 build ≈ 1.5x ef=20;");
    println!("# FISHDBC total competitive with or beating exact, margin growing with dim.");
}
