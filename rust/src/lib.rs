//! # FISHDBC — Flexible, Incremental, Scalable, Hierarchical Density-Based Clustering
//!
//! A production-grade reproduction of Dell'Amico's FISHDBC (2019):
//! approximate, incremental HDBSCAN* for **arbitrary data and distance
//! functions**, built as a three-layer rust + JAX/Pallas stack:
//!
//! * **Layer 3 (this crate)** — the full algorithm and its substrates:
//!   [`hnsw`] (neighbor discovery with distance-call interception),
//!   [`mst`] (incremental minimum spanning forests), [`hdbscan`]
//!   (condensed-tree extraction + the exact O(n²) baseline), [`fishdbc`]
//!   (Algorithm 1), [`metrics`], [`datasets`], a streaming
//!   [`coordinator`] (single-shard reference path), and the sharded
//!   parallel [`engine`] (multi-core ingest + global merge + online
//!   label queries), watched end to end by the zero-dependency [`obs`]
//!   telemetry layer (latency histograms, epoch event journal, and a
//!   scrapeable Prometheus `/metrics` endpoint).
//! * **Layer 2/1 (python/, build-time only)** — JAX distance graphs with
//!   Pallas kernels, AOT-lowered to HLO text artifacts.
//! * **[`runtime`]** (feature `xla`, off by default) — loads those
//!   artifacts via the `xla` crate (PJRT) so vector-distance batches can
//!   run through the compiled kernels with Python never on the request
//!   path. The default build is fully offline with zero external crates.
//!
//! ## Quickstart
//!
//! ```no_run
//! use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
//! use fishdbc::distances::vector::euclidean;
//!
//! let metric = |a: &Vec<f32>, b: &Vec<f32>| euclidean(a, b);
//! let mut clusterer = Fishdbc::new(metric, FishdbcParams::default());
//! for point in vec![vec![0.0f32, 0.0], vec![0.1, 0.0], vec![9.0, 9.0]] {
//!     clusterer.add(point);
//! }
//! let clustering = clusterer.cluster(2);
//! println!("{:?}", clustering.labels);
//! ```
//!
//! ## Sharded parallel ingest ([`engine`])
//!
//! When one core is not enough, the engine hash-routes the stream across
//! `S` shard-local FISHDBC instances (one thread each) and recovers the
//! global clustering through an incremental, epoch-based recluster
//! pipeline ([`engine::pipeline`]): cross-shard *bridge edges* are
//! discovered at insert time against frozen remote snapshots, each
//! `cluster()` folds only the delta since the previous epoch into a
//! cached global forest, and an unchanged forest short-circuits
//! extraction entirely — so re-clustering costs O(Δn), not O(n). With
//! `EngineConfig::recluster_every` a background thread publishes fresh
//! epochs automatically, and [`engine::Engine::label`] answers "which
//! cluster would this item join?" against the latest epoch without
//! mutating any state — the serving loop of a production deployment.
//! Churn is first-class too: [`engine::Engine::remove_batch`] deletes
//! items incrementally (tombstoned in place, invisible to every search
//! at once, labeled -1 forever; shards compact past
//! `EngineConfig::compact_at`).
//!
//! The engine is as generic as the core: `Engine<T, M>` shards **any**
//! item type under **any** cloneable metric — a closure is enough — so
//! the paper's flexibility axis holds at production scale:
//!
//! ```no_run
//! use fishdbc::engine::{Engine, EngineConfig};
//!
//! let metric = |a: &Vec<i64>, b: &Vec<i64>| {
//!     a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum()
//! };
//! let engine = Engine::spawn(
//!     metric,
//!     EngineConfig { shards: 4, ..Default::default() },
//! );
//! engine.add_batch(vec![vec![0i64, 0], vec![1, 0], vec![90, 90]]);
//! let snapshot = engine.cluster(2);
//! println!("{:?}", snapshot.clustering.labels);
//! let label = engine.label(&vec![1i64, 1]);
//! println!("online query joins cluster {label}");
//! ```
//!
//! The dynamic [`Item`]/[`MetricKind`] pair the CLI and the framework
//! datasets use is simply the default instantiation (`Engine` with no
//! type arguments):
//!
//! ```no_run
//! use fishdbc::engine::{Engine, EngineConfig};
//! use fishdbc::{Item, MetricKind};
//!
//! let engine: Engine = Engine::spawn(
//!     MetricKind::Euclidean,
//!     EngineConfig { shards: 4, ..Default::default() },
//! );
//! engine.add_batch(vec![
//!     Item::Dense(vec![0.0, 0.0]),
//!     Item::Dense(vec![0.1, 0.0]),
//!     Item::Dense(vec![9.0, 9.0]),
//! ]);
//! let snapshot = engine.cluster(2);
//! println!("{:?}", snapshot.clustering.labels);
//! let label = engine.label(&Item::Dense(vec![0.05, 0.0]));
//! println!("online query joins cluster {label}");
//! ```

pub mod cli;
pub mod coordinator;
pub mod datasets;
pub mod distances;
pub mod durable;
pub mod engine;
pub mod fishdbc;
pub mod hdbscan;
pub mod hnsw;
pub mod metrics;
pub mod mst;
pub mod obs;
pub mod persist;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod util;

pub use distances::{Item, Metric, MetricKind};
pub use fishdbc::{Fishdbc, FishdbcParams};
pub use hdbscan::Clustering;
