//! # FISHDBC — Flexible, Incremental, Scalable, Hierarchical Density-Based Clustering
//!
//! A production-grade reproduction of Dell'Amico's FISHDBC (2019):
//! approximate, incremental HDBSCAN* for **arbitrary data and distance
//! functions**, built as a three-layer rust + JAX/Pallas stack:
//!
//! * **Layer 3 (this crate)** — the full algorithm and its substrates:
//!   [`hnsw`] (neighbor discovery with distance-call interception),
//!   [`mst`] (incremental minimum spanning forests), [`hdbscan`]
//!   (condensed-tree extraction + the exact O(n²) baseline), [`fishdbc`]
//!   (Algorithm 1), [`metrics`], [`datasets`], and a streaming
//!   [`coordinator`].
//! * **Layer 2/1 (python/, build-time only)** — JAX distance graphs with
//!   Pallas kernels, AOT-lowered to HLO text artifacts.
//! * **[`runtime`]** — loads those artifacts via the `xla` crate (PJRT)
//!   so vector-distance batches can run through the compiled kernels with
//!   Python never on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
//! use fishdbc::distances::vector::euclidean;
//!
//! let metric = |a: &Vec<f32>, b: &Vec<f32>| euclidean(a, b);
//! let mut clusterer = Fishdbc::new(metric, FishdbcParams::default());
//! for point in vec![vec![0.0f32, 0.0], vec![0.1, 0.0], vec![9.0, 9.0]] {
//!     clusterer.add(point);
//! }
//! let clustering = clusterer.cluster(2);
//! println!("{:?}", clustering.labels);
//! ```

pub mod cli;
pub mod coordinator;
pub mod datasets;
pub mod distances;
pub mod fishdbc;
pub mod hdbscan;
pub mod hnsw;
pub mod metrics;
pub mod mst;
pub mod persist;
pub mod runtime;
pub mod util;

pub use distances::{Item, Metric, MetricKind};
pub use fishdbc::{Fishdbc, FishdbcParams};
pub use hdbscan::Clustering;
