//! External clustering metrics: Adjusted Rand Index (Hubert & Arabie) and
//! Adjusted Mutual Information (Vinh et al.; Romano et al. \[35\] recommend
//! AMI for unbalanced datasets, which is why the paper always reports it).

/// The four scores reported across the paper's quality tables.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExternalScores {
    pub ami: f64,
    pub ami_star: f64,
    pub ari: f64,
    pub ari_star: f64,
}

/// Dense contingency table between two labelings.
struct Contingency {
    table: Vec<Vec<u64>>, // [pred][truth]
    a: Vec<u64>,          // pred marginals
    b: Vec<u64>,          // truth marginals
    n: u64,
}

fn contingency(pred: &[usize], truth: &[usize]) -> Contingency {
    assert_eq!(pred.len(), truth.len());
    let relabel = |xs: &[usize]| -> (Vec<usize>, usize) {
        let mut map = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(xs.len());
        for &x in xs {
            let next = map.len();
            out.push(*map.entry(x).or_insert(next));
        }
        (out, map.len())
    };
    let (p, kp) = relabel(pred);
    let (t, kt) = relabel(truth);
    let mut table = vec![vec![0u64; kt]; kp];
    for (&i, &j) in p.iter().zip(&t) {
        table[i][j] += 1;
    }
    let a: Vec<u64> = table.iter().map(|r| r.iter().sum()).collect();
    let mut b = vec![0u64; kt];
    for r in &table {
        for (j, &v) in r.iter().enumerate() {
            b[j] += v;
        }
    }
    Contingency { table, a, b, n: pred.len() as u64 }
}

fn comb2(x: u64) -> f64 {
    (x as f64) * (x as f64 - 1.0) / 2.0
}

/// Adjusted Rand Index ∈ [-1, 1]; 0 ≈ random, 1 = identical partitions.
pub fn adjusted_rand_index(pred: &[usize], truth: &[usize]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let c = contingency(pred, truth);
    let sum_ij: f64 = c.table.iter().flatten().map(|&v| comb2(v)).sum();
    let sum_a: f64 = c.a.iter().map(|&v| comb2(v)).sum();
    let sum_b: f64 = c.b.iter().map(|&v| comb2(v)).sum();
    let total = comb2(c.n);
    if total == 0.0 {
        return 0.0;
    }
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        // both partitions trivial (all-one-cluster or all-singletons)
        return if (sum_ij - expected).abs() < 1e-12 { 1.0 } else { 0.0 };
    }
    (sum_ij - expected) / (max_index - expected)
}

fn entropy(marginals: &[u64], n: u64) -> f64 {
    let n = n as f64;
    marginals
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        })
        .sum()
}

fn mutual_info(c: &Contingency) -> f64 {
    let n = c.n as f64;
    let mut mi = 0.0;
    for (i, row) in c.table.iter().enumerate() {
        for (j, &nij) in row.iter().enumerate() {
            if nij == 0 {
                continue;
            }
            let nij = nij as f64;
            mi += (nij / n) * ((nij * n) / (c.a[i] as f64 * c.b[j] as f64)).ln();
        }
    }
    mi.max(0.0)
}

/// Expected mutual information under the hypergeometric null model
/// (Vinh, Epps & Bailey 2010). O(Ka · Kb · n̄) — fine at our scales.
fn expected_mutual_info(c: &Contingency) -> f64 {
    let n = c.n;
    let nf = n as f64;
    // log-factorials up to n
    let mut lf = vec![0.0f64; (n + 1) as usize];
    for i in 1..=n as usize {
        lf[i] = lf[i - 1] + (i as f64).ln();
    }
    let mut emi = 0.0f64;
    for &ai in &c.a {
        for &bj in &c.b {
            let lo = (ai + bj).saturating_sub(n).max(1);
            let hi = ai.min(bj);
            let mut nij = lo;
            while nij <= hi {
                let x = nij as f64;
                let term1 = (x / nf) * ((nf * x) / (ai as f64 * bj as f64)).ln();
                // hypergeometric pmf via log-factorials
                let logp = lf[ai as usize] + lf[bj as usize]
                    + lf[(n - ai) as usize]
                    + lf[(n - bj) as usize]
                    - lf[n as usize]
                    - lf[nij as usize]
                    - lf[(ai - nij) as usize]
                    - lf[(bj - nij) as usize]
                    - lf[(n + nij - ai - bj) as usize]; // nij >= ai+bj-n
                emi += term1 * logp.exp();
                nij += 1;
            }
        }
    }
    emi
}

/// Adjusted Mutual Information ∈ [~0, 1] (max normalization, as sklearn).
pub fn adjusted_mutual_info(pred: &[usize], truth: &[usize]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let c = contingency(pred, truth);
    let hu = entropy(&c.a, c.n);
    let hv = entropy(&c.b, c.n);
    if hu == 0.0 && hv == 0.0 {
        return 1.0; // both trivial and identical
    }
    let mi = mutual_info(&c);
    let emi = expected_mutual_info(&c);
    let denom = hu.max(hv) - emi;
    if denom.abs() < 1e-12 {
        return 0.0;
    }
    ((mi - emi) / denom).clamp(-1.0, 1.0)
}

/// Homogeneity, completeness and V-measure (Rosenberg & Hirschberg 2007):
/// complementary views the paper's AMI/ARI tables do not expose — useful
/// when diagnosing *why* a clustering scores low (mixed clusters vs split
/// classes).
#[derive(Clone, Copy, Debug, Default)]
pub struct VMeasure {
    /// 1 iff every cluster contains members of a single class.
    pub homogeneity: f64,
    /// 1 iff every class is contained in a single cluster.
    pub completeness: f64,
    /// Harmonic mean of the two.
    pub v_measure: f64,
}

/// Compute homogeneity / completeness / V-measure.
pub fn v_measure(pred: &[usize], truth: &[usize]) -> VMeasure {
    if pred.is_empty() {
        return VMeasure::default();
    }
    let c = contingency(pred, truth);
    let h_truth = entropy(&c.b, c.n);
    let h_pred = entropy(&c.a, c.n);
    // conditional entropies H(truth|pred) and H(pred|truth)
    let n = c.n as f64;
    let mut h_t_given_p = 0.0;
    let mut h_p_given_t = 0.0;
    for (i, row) in c.table.iter().enumerate() {
        for (j, &nij) in row.iter().enumerate() {
            if nij == 0 {
                continue;
            }
            let nij = nij as f64;
            h_t_given_p -= (nij / n) * (nij / c.a[i] as f64).ln();
            h_p_given_t -= (nij / n) * (nij / c.b[j] as f64).ln();
        }
    }
    let homogeneity = if h_truth == 0.0 { 1.0 } else { 1.0 - h_t_given_p / h_truth };
    let completeness = if h_pred == 0.0 { 1.0 } else { 1.0 - h_p_given_t / h_pred };
    let v = if homogeneity + completeness == 0.0 {
        0.0
    } else {
        2.0 * homogeneity * completeness / (homogeneity + completeness)
    };
    VMeasure { homogeneity, completeness, v_measure: v }
}

/// Fowlkes–Mallows index: geometric mean of pairwise precision and recall.
pub fn fowlkes_mallows(pred: &[usize], truth: &[usize]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let c = contingency(pred, truth);
    let tp: f64 = c.table.iter().flatten().map(|&v| comb2(v)).sum();
    let p_pairs: f64 = c.a.iter().map(|&v| comb2(v)).sum();
    let t_pairs: f64 = c.b.iter().map(|&v| comb2(v)).sum();
    if p_pairs == 0.0 || t_pairs == 0.0 {
        return 0.0;
    }
    tp / (p_pairs * t_pairs).sqrt()
}

/// Purity: fraction of points whose cluster's majority class matches their
/// own. Biased toward many small clusters — report alongside AMI, never
/// instead of it.
pub fn purity(pred: &[usize], truth: &[usize]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let c = contingency(pred, truth);
    let good: u64 = c.table.iter().map(|row| row.iter().copied().max().unwrap_or(0)).sum();
    good as f64 / c.n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn ari_perfect_and_permuted() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![2, 2, 0, 0, 1, 1]; // same partition, renamed
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
        assert!((adjusted_mutual_info(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ari_known_value() {
        // sklearn doc example: ARI([0,0,1,1],[0,0,1,2]) = 0.5714...
        let a = vec![0, 0, 1, 1];
        let b = vec![0, 0, 1, 2];
        let ari = adjusted_rand_index(&a, &b);
        assert!((ari - 0.5714285714).abs() < 1e-6, "got {ari}");
    }

    #[test]
    fn ami_known_value() {
        // sklearn: AMI([0,0,1,1],[0,0,1,2]) ≈ 0.5563 (max normalization...)
        let a = vec![0, 0, 1, 1];
        let b = vec![0, 0, 1, 2];
        let ami = adjusted_mutual_info(&a, &b);
        assert!((0.4..0.75).contains(&ami), "got {ami}");
    }

    #[test]
    fn random_labelings_score_near_zero() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        let n = 600;
        let a: Vec<usize> = (0..n).map(|_| rng.below(5)).collect();
        let b: Vec<usize> = (0..n).map(|_| rng.below(5)).collect();
        assert!(adjusted_rand_index(&a, &b).abs() < 0.05);
        assert!(adjusted_mutual_info(&a, &b).abs() < 0.05);
    }

    #[test]
    fn prop_metric_invariances() {
        check("external-metric-invariances", 20, |rng, _| {
            let n = 10 + rng.below(120);
            let ka = 1 + rng.below(6);
            let kb = 1 + rng.below(6);
            let a: Vec<usize> = (0..n).map(|_| rng.below(ka)).collect();
            let b: Vec<usize> = (0..n).map(|_| rng.below(kb)).collect();
            // symmetry
            let ari_ab = adjusted_rand_index(&a, &b);
            let ari_ba = adjusted_rand_index(&b, &a);
            assert!((ari_ab - ari_ba).abs() < 1e-9);
            let ami_ab = adjusted_mutual_info(&a, &b);
            let ami_ba = adjusted_mutual_info(&b, &a);
            assert!((ami_ab - ami_ba).abs() < 1e-9);
            // bounds
            assert!(ari_ab <= 1.0 + 1e-9 && ari_ab >= -1.0 - 1e-9);
            assert!(ami_ab <= 1.0 + 1e-9);
            // label-permutation invariance
            let perm: Vec<usize> = a.iter().map(|&x| (x * 7 + 3) % 97).collect();
            assert!((adjusted_rand_index(&perm, &b) - ari_ab).abs() < 1e-9);
            assert!((adjusted_mutual_info(&perm, &b) - ami_ab).abs() < 1e-9);
            // self-comparison = 1 (unless single cluster against itself,
            // which is also 1 by convention)
            assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-9);
        });
    }

    #[test]
    fn v_measure_known_behaviour() {
        // perfect clustering
        let a = vec![0, 0, 1, 1];
        let v = v_measure(&a, &a);
        assert!((v.v_measure - 1.0).abs() < 1e-12);

        // homogeneous but incomplete: classes split across clusters
        let pred = vec![0, 1, 2, 3];
        let truth = vec![0, 0, 1, 1];
        let v = v_measure(&pred, &truth);
        assert!((v.homogeneity - 1.0).abs() < 1e-12, "{v:?}");
        // H(pred|truth) = ln2, H(pred) = 2ln2 ⇒ completeness = 0.5 exactly
        assert!((v.completeness - 0.5).abs() < 1e-12, "{v:?}");

        // complete but inhomogeneous: one big mixed cluster
        let pred = vec![0, 0, 0, 0];
        let v = v_measure(&pred, &truth);
        assert!((v.completeness - 1.0).abs() < 1e-12, "{v:?}");
        assert!(v.homogeneity < 1e-12, "{v:?}");
    }

    #[test]
    fn fowlkes_mallows_and_purity_behave() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((fowlkes_mallows(&a, &a) - 1.0).abs() < 1e-12);
        assert!((purity(&a, &a) - 1.0).abs() < 1e-12);

        let truth = vec![0, 0, 0, 1, 1, 1];
        let mixed = vec![0, 0, 1, 1, 2, 2];
        let fm = fowlkes_mallows(&mixed, &truth);
        assert!((0.0..1.0).contains(&fm), "{fm}");
        // purity of the mixed middle cluster: 5/6
        assert!((purity(&mixed, &truth) - 5.0 / 6.0).abs() < 1e-12);
        // purity rewards over-fragmentation (why we also report AMI):
        let singletons: Vec<usize> = (0..6).collect();
        assert!((purity(&singletons, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_extra_metrics_bounds_and_symmetry() {
        check("extra-metrics", 15, |rng, _| {
            let n = 8 + rng.below(80);
            let a: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
            let b: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
            let v = v_measure(&a, &b);
            for x in [v.homogeneity, v.completeness, v.v_measure] {
                assert!((-1e-9..=1.0 + 1e-9).contains(&x), "{v:?}");
            }
            // v-measure is symmetric in (h, c) swap under argument swap
            let w = v_measure(&b, &a);
            assert!((v.homogeneity - w.completeness).abs() < 1e-9);
            assert!((v.v_measure - w.v_measure).abs() < 1e-9);
            let fm = fowlkes_mallows(&a, &b);
            assert!((-1e-9..=1.0 + 1e-9).contains(&fm));
            assert!((fm - fowlkes_mallows(&b, &a)).abs() < 1e-9);
            assert!((0.0..=1.0).contains(&purity(&a, &b)));
        });
    }

    #[test]
    fn trivial_partitions() {
        let ones = vec![0usize; 8];
        let singl: Vec<usize> = (0..8).collect();
        // all-in-one vs all-singletons: no agreement beyond chance
        assert!(adjusted_rand_index(&ones, &singl).abs() < 1e-9);
        // identical trivial partitions
        assert!((adjusted_rand_index(&ones, &ones) - 1.0).abs() < 1e-9);
        assert!((adjusted_mutual_info(&ones, &ones) - 1.0).abs() < 1e-9);
    }
}
