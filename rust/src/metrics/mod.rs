//! Clustering quality metrics (paper §4.1): external (AMI, ARI and the
//! noise-penalizing AMI*/ARI* variants) and internal (silhouette, sampled
//! intra-/inter-cluster distance).

pub mod external;
pub mod internal;

pub use external::{
    adjusted_mutual_info, adjusted_rand_index, fowlkes_mallows, purity,
    v_measure, ExternalScores, VMeasure,
};
pub use internal::{silhouette, sampled_intra_inter, InternalScores};

/// The paper's treatment of noise for external metrics (§4.1):
/// * AMI/ARI — evaluate **only clustered elements** (noise dropped);
/// * AMI*/ARI* — **all noise items form one extra cluster**.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NoiseMode {
    /// Drop noise points from the comparison (AMI / ARI).
    DropNoise,
    /// Treat all noise as a single additional cluster (AMI* / ARI*).
    NoiseAsCluster,
}

/// Canonical relabeling: clusters numbered by first occurrence, noise
/// stays -1. Two label vectors describe the same **partition** iff their
/// canonical forms are equal — the comparison the engine's conformance
/// harness, the churn bench and the integration tests all share
/// (extraction numbers clusters by traversal order, which is not part of
/// the conformance contract when equal-weight edges tie).
pub fn canonical_labels(labels: &[i32]) -> Vec<i32> {
    let mut map = std::collections::HashMap::new();
    labels
        .iter()
        .map(|&l| {
            if l < 0 {
                -1
            } else {
                let next = map.len() as i32;
                *map.entry(l).or_insert(next)
            }
        })
        .collect()
}

/// Prepare (prediction, truth) pairs under a noise mode. `labels` uses -1
/// for noise; truth labels are arbitrary usize classes.
pub fn align_labels(
    labels: &[i32],
    truth: &[usize],
    mode: NoiseMode,
) -> (Vec<usize>, Vec<usize>) {
    assert_eq!(labels.len(), truth.len());
    let mut pred = Vec::with_capacity(labels.len());
    let mut gt = Vec::with_capacity(labels.len());
    let noise_label = labels.iter().map(|&l| l.max(0) as usize).max().unwrap_or(0) + 1;
    for (&l, &t) in labels.iter().zip(truth) {
        match (l, mode) {
            (l, _) if l >= 0 => {
                pred.push(l as usize);
                gt.push(t);
            }
            (_, NoiseMode::DropNoise) => {}
            (_, NoiseMode::NoiseAsCluster) => {
                pred.push(noise_label);
                gt.push(t);
            }
        }
    }
    (pred, gt)
}

/// Convenience: compute AMI, AMI*, ARI, ARI* in one call (the four columns
/// the paper reports in Tables 2, 4, 5, 6).
pub fn score_external(labels: &[i32], truth: &[usize]) -> ExternalScores {
    let (p, g) = align_labels(labels, truth, NoiseMode::DropNoise);
    let (ps, gs) = align_labels(labels, truth, NoiseMode::NoiseAsCluster);
    ExternalScores {
        ami: adjusted_mutual_info(&p, &g),
        ami_star: adjusted_mutual_info(&ps, &gs),
        ari: adjusted_rand_index(&p, &g),
        ari_star: adjusted_rand_index(&ps, &gs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_drop_noise() {
        let labels = vec![0, -1, 1, -1];
        let truth = vec![0, 1, 1, 0];
        let (p, g) = align_labels(&labels, &truth, NoiseMode::DropNoise);
        assert_eq!(p, vec![0, 1]);
        assert_eq!(g, vec![0, 1]);
    }

    #[test]
    fn align_noise_as_cluster() {
        let labels = vec![0, -1, 1, -1];
        let truth = vec![0, 1, 1, 0];
        let (p, g) = align_labels(&labels, &truth, NoiseMode::NoiseAsCluster);
        assert_eq!(p, vec![0, 2, 1, 2]); // noise becomes cluster 2
        assert_eq!(g, truth);
    }

    #[test]
    fn canonical_labels_compare_partitions() {
        // same partition under different numbering ⇒ same canonical form
        assert_eq!(
            canonical_labels(&[2, 2, 0, -1, 0]),
            canonical_labels(&[1, 1, 5, -1, 5])
        );
        // different partitions stay different
        assert_ne!(
            canonical_labels(&[0, 0, 1, 1]),
            canonical_labels(&[0, 1, 0, 1])
        );
        // noise is preserved, clusters numbered by first occurrence
        assert_eq!(canonical_labels(&[7, -1, 3, 7]), vec![0, -1, 1, 0]);
        assert_eq!(canonical_labels(&[]), Vec::<i32>::new());
    }

    #[test]
    fn perfect_clustering_scores_one() {
        let labels = vec![0, 0, 1, 1, 2, 2];
        let truth = vec![5, 5, 9, 9, 7, 7];
        let s = score_external(&labels, &truth);
        assert!((s.ami - 1.0).abs() < 1e-9);
        assert!((s.ari - 1.0).abs() < 1e-9);
        assert!((s.ami_star - 1.0).abs() < 1e-9);
        assert!((s.ari_star - 1.0).abs() < 1e-9);
    }

    #[test]
    fn noise_penalized_only_in_star_variants() {
        // perfect on clustered points, but half the data is noise
        let labels = vec![0, 0, 1, 1, -1, -1, -1, -1];
        let truth = vec![0, 0, 1, 1, 0, 0, 1, 1];
        let s = score_external(&labels, &truth);
        assert!((s.ami - 1.0).abs() < 1e-9, "AMI should ignore noise");
        assert!(s.ami_star < 0.8, "AMI* should penalize noise: {}", s.ami_star);
        assert!(s.ari_star < s.ari);
    }
}
