//! Internal clustering metrics (paper §4.1, Table 7): silhouette (full
//! O(n²), with a budget cap reproducing the paper's OOM markers) and
//! sampled intra-/inter-cluster average distances (sample size 10 000,
//! pair-uniform across clusters, exactly as the paper describes).

use crate::distances::Metric;
use crate::util::rng::Rng;

/// Internal metric bundle (Table 7's last three columns).
#[derive(Clone, Copy, Debug, Default)]
pub struct InternalScores {
    /// Mean silhouette over clustered points; None = exceeded budget (the
    /// paper reports OOM for silhouette on its larger datasets).
    pub silhouette: Option<f64>,
    /// Average distance of sampled same-cluster pairs (lower is better).
    pub intra: f64,
    /// Average distance of sampled cross-cluster pairs (higher is better).
    pub inter: f64,
}

/// Full silhouette over clustered points (noise excluded). Returns None if
/// the number of clustered points exceeds `max_points` — mirroring the
/// paper's out-of-memory behaviour on big datasets.
pub fn silhouette<T, M: Metric<T>>(
    items: &[T],
    labels: &[i32],
    metric: &M,
    max_points: usize,
) -> Option<f64> {
    let idx: Vec<usize> =
        (0..items.len()).filter(|&i| labels[i] >= 0).collect();
    if idx.len() < 2 {
        return None;
    }
    if idx.len() > max_points {
        return None; // "OOM"
    }
    let k = labels.iter().filter(|&&l| l >= 0).map(|&l| l as usize).max()? + 1;
    if k < 2 {
        return None;
    }
    let mut sizes = vec![0usize; k];
    for &i in &idx {
        sizes[labels[i] as usize] += 1;
    }

    let mut total = 0.0f64;
    let mut counted = 0usize;
    // per-point mean distance to each cluster
    for (pi, &i) in idx.iter().enumerate() {
        let li = labels[i] as usize;
        if sizes[li] < 2 {
            continue; // silhouette undefined for singleton clusters
        }
        let mut sums = vec![0.0f64; k];
        for (pj, &j) in idx.iter().enumerate() {
            if pi == pj {
                continue;
            }
            sums[labels[j] as usize] += metric.dist(&items[i], &items[j]);
        }
        let a = sums[li] / (sizes[li] - 1) as f64;
        let b = (0..k)
            .filter(|&c| c != li && sizes[c] > 0)
            .map(|c| sums[c] / sizes[c] as f64)
            .fold(f64::INFINITY, f64::min);
        if !b.is_finite() {
            continue;
        }
        total += (b - a) / a.max(b);
        counted += 1;
    }
    if counted == 0 {
        None
    } else {
        Some(total / counted as f64)
    }
}

/// Sampled intra-/inter-cluster distances (paper: sample size 10 000,
/// "normalizing the probability of choosing each cluster to ensure that
/// each pair has the same probability of being selected" — i.e. pairs are
/// uniform over valid pairs, which simple uniform member sampling with
/// rejection achieves).
pub fn sampled_intra_inter<T, M: Metric<T>>(
    items: &[T],
    labels: &[i32],
    metric: &M,
    samples: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let clustered: Vec<usize> =
        (0..items.len()).filter(|&i| labels[i] >= 0).collect();
    if clustered.len() < 2 {
        return (0.0, 0.0);
    }
    let mut intra_sum = 0.0;
    let mut intra_n = 0usize;
    let mut inter_sum = 0.0;
    let mut inter_n = 0usize;
    let max_tries = samples * 40;
    let mut tries = 0;
    while (intra_n < samples || inter_n < samples) && tries < max_tries {
        tries += 1;
        let i = clustered[rng.below(clustered.len())];
        let j = clustered[rng.below(clustered.len())];
        if i == j {
            continue;
        }
        if labels[i] == labels[j] {
            if intra_n < samples {
                intra_sum += metric.dist(&items[i], &items[j]);
                intra_n += 1;
            }
        } else if inter_n < samples {
            inter_sum += metric.dist(&items[i], &items[j]);
            inter_n += 1;
        }
    }
    (
        if intra_n > 0 { intra_sum / intra_n as f64 } else { 0.0 },
        if inter_n > 0 { inter_sum / inter_n as f64 } else { 0.0 },
    )
}

/// Compute the full internal bundle.
pub fn score_internal<T, M: Metric<T>>(
    items: &[T],
    labels: &[i32],
    metric: &M,
    silhouette_max_points: usize,
    seed: u64,
) -> InternalScores {
    let (intra, inter) =
        sampled_intra_inter(items, labels, metric, 10_000, seed);
    InternalScores {
        silhouette: silhouette(items, labels, metric, silhouette_max_points),
        intra,
        inter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::vector::euclidean;

    fn metric() -> impl Metric<Vec<f32>> {
        |a: &Vec<f32>, b: &Vec<f32>| euclidean(a, b)
    }

    fn two_blobs() -> (Vec<Vec<f32>>, Vec<i32>) {
        let mut items = Vec::new();
        let mut labels = Vec::new();
        for i in 0..20 {
            items.push(vec![(i % 5) as f32 * 0.1, (i / 5) as f32 * 0.1]);
            labels.push(0);
        }
        for i in 0..20 {
            items.push(vec![100.0 + (i % 5) as f32 * 0.1, (i / 5) as f32 * 0.1]);
            labels.push(1);
        }
        (items, labels)
    }

    #[test]
    fn silhouette_high_for_separated_blobs() {
        let (items, labels) = two_blobs();
        let s = silhouette(&items, &labels, &metric(), 10_000).unwrap();
        assert!(s > 0.95, "silhouette {s}");
    }

    #[test]
    fn silhouette_near_zero_for_random_split() {
        let (items, _) = two_blobs();
        // label by parity: clusters interleave both blobs
        let labels: Vec<i32> = (0..items.len()).map(|i| (i % 2) as i32).collect();
        let s = silhouette(&items, &labels, &metric(), 10_000).unwrap();
        assert!(s < 0.3, "silhouette {s}");
    }

    #[test]
    fn silhouette_oom_budget() {
        let (items, labels) = two_blobs();
        assert!(silhouette(&items, &labels, &metric(), 10).is_none());
    }

    #[test]
    fn silhouette_ignores_noise_and_degenerates() {
        let (items, mut labels) = two_blobs();
        for l in labels.iter_mut().skip(20) {
            *l = -1; // second blob all noise => one cluster left
        }
        assert!(silhouette(&items, &labels, &metric(), 10_000).is_none());
    }

    #[test]
    fn intra_lower_than_inter_for_separated() {
        let (items, labels) = two_blobs();
        let (intra, inter) =
            sampled_intra_inter(&items, &labels, &metric(), 2_000, 1);
        assert!(intra < 1.0, "intra {intra}");
        assert!(inter > 90.0, "inter {inter}");
    }

    #[test]
    fn sampling_deterministic_by_seed() {
        let (items, labels) = two_blobs();
        let a = sampled_intra_inter(&items, &labels, &metric(), 500, 9);
        let b = sampled_intra_inter(&items, &labels, &metric(), 500, 9);
        assert_eq!(a, b);
    }
}
