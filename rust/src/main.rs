//! `fishdbc` — launcher for the FISHDBC framework.
//!
//! Subcommands:
//!   run        cluster a generated dataset (FISHDBC and/or exact HDBSCAN*)
//!   stream     streaming-coordinator demo with periodic re-clustering
//!   engine     sharded parallel ingest + global merge + online labels
//!   serve      network front-end: framed TCP protocol over a live engine
//!   artifacts  list the AOT modules the PJRT runtime can load
//!   help       this text
//!
//! Examples:
//!   fishdbc run --dataset blobs --n 10000 --dim 1000 --ef 20 --quality
//!   fishdbc run --dataset usps --n 2196 --exact --quality
//!   fishdbc stream --dataset reviews --n 5000 --chunk 250 --recluster-every 1000
//!   fishdbc engine --dataset blobs --n 50000 --shards 4 --quality
//!   fishdbc serve --addr 127.0.0.1:7979 --shards 4 --recluster-every 1000
//!   fishdbc serve --client-probe --addr 127.0.0.1:7979 --probe-n 64
//!   fishdbc artifacts

use fishdbc::cli;
use fishdbc::coordinator::{Coordinator, CoordinatorConfig};
use fishdbc::datasets;
use fishdbc::durable::{Durable, DurabilityConfig};
use fishdbc::engine::{Engine, EngineConfig, ExtractionMode, ExtractionParams};
use fishdbc::fishdbc::{Fishdbc, FishdbcParams};
use fishdbc::hdbscan::exact::{exact_hdbscan, ExactParams};
use fishdbc::metrics::{internal, score_external};
use fishdbc::obs::CounterId;
use fishdbc::persist::FrameworkCodec;
#[cfg(feature = "xla")]
use fishdbc::runtime::{default_artifacts_dir, Runtime};
use fishdbc::serve::{Client, ServeConfig, Server};
use fishdbc::{Item, MetricKind};

const VALUE_KEYS: &[&str] = &[
    "dataset", "n", "dim", "ef", "min-pts", "mcs", "alpha", "seed", "chunk",
    "recluster-every", "metric", "silhouette-max", "input", "format", "save",
    "load", "out", "labels-out", "efs", "shards", "bridge-k", "bridge-fanout",
    "bridge-refresh", "churn", "compact-at", "metrics-addr", "stats-json",
    "hold-secs", "addr", "threads", "max-conns", "drain-secs", "preload",
    "probe-n", "queue-depth", "sweep-mcs", "write-timeout", "wal-dir",
    "checkpoint-every",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::parse(&argv, VALUE_KEYS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "run" => cmd_run(&args),
        "stream" => cmd_stream(&args),
        "engine" => cmd_engine(&args),
        "serve" => cmd_serve(&args),
        "export" => cmd_export(&args),
        "sweep" => cmd_sweep(&args),
        "artifacts" => cmd_artifacts(),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command {other:?} (try `fishdbc help`)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "fishdbc — flexible incremental scalable hierarchical density-based clustering

USAGE: fishdbc <run|stream|engine|serve|export|sweep|artifacts|help> [options]

Common options:
  --dataset NAME    one of {names}   (default blobs)
  --input PATH      load data from a file instead of a generator
  --format F        input format: csv | csv-labeled | text | docword
  --n N             dataset size (default 2000; generators only)
  --dim D           dimensionality / vocabulary (dataset-specific, default 64)
  --ef EF           HNSW beam width (default 20; paper evaluates 20 and 50)
  --min-pts K       MinPts (default 10)
  --mcs M           minimum cluster size (default = MinPts)
  --alpha A         candidate-buffer factor (default 5.0)
  --seed S          RNG seed (default 42)
  --metric M        override the dataset's distance function

run options:
  --exact           also run the exact O(n^2) HDBSCAN* baseline
  --quality         print external metrics (AMI/AMI*/ARI/ARI*)
  --internal        print internal metrics (silhouette, intra/inter)
  --silhouette-max P  silhouette budget in points (default 4000 ~ 'OOM' above)
  --save PATH       persist the FISHDBC state after building
  --load PATH       resume from a previously saved state (then add --input/
                    --dataset items on top, incrementally)
  --labels-out PATH write flat labels as CSV

export options (run + write the hierarchy):
  --out PATH        output file (default stdout)
  --format F        export format: json | dot | newick | tree (default json)

sweep options:
  --efs LIST        comma-separated ef values (default 10,20,50,100)

stream options:
  --chunk C            ingestion batch size (default 200)
  --recluster-every R  auto re-cluster period in items (default 1000)

engine options (sharded parallel ingest, incremental epoch merges, online
labels):
  --shards S        shard worker threads (default 4; 1 = single-core path)
  --chunk C         ingestion batch size (default 512)
  --bridge-k K      nearest remote neighbors per (item, shard) (default 3)
  --bridge-fanout F other shards sampled per item (default S-1)
  --recluster-every R  background auto-recluster period in items (default
                    0 = off); each merge publishes an epoch for latest()
  --bridge-refresh B   also refresh the frozen bridge snapshots every B
                    items (default 0 = only at merges; captures are
                    chunked copy-on-write, so refreshes cost O(delta))
  --churn P         after the merge, remove an id-scattered P% of the
                    stream (incremental deletion), re-cluster, and verify
                    the churned epoch serves: deleted ids label -1 and a
                    probe query still answers (exit 1 otherwise)
  --compact-at R    per-shard tombstone ratio that triggers compaction
                    (rebuild without tombstones; default 0.25, 0 = never)
  --sweep-mcs LIST  after the final merge, re-extract flat partitions at
                    each comma-separated minimum cluster size from the
                    pinned epoch's cached dendrogram (two passes; the
                    second hits the extraction memo). Self-checks that
                    the sweep adds zero metric calls and exits 1 if not
  --stats           print per-stage pipeline timings, cache counters,
                    snapshot copied-vs-shared chunk counts, churn
                    (removed/tombstoned/compactions) counters, and the
                    windowed rates/latency quantiles for the whole run
  --stats-json PATH write the machine-readable fishdbc-stats-v1 document
                    (counters, gauges, histogram quantiles, journal tail;
                    PATH '-' prints to stdout)
  --metrics-addr A  serve Prometheus text exposition on GET /metrics (and
                    the stats document on /stats.json) at A, e.g.
                    127.0.0.1:9100, concurrently with ingest and merges
  --journal         print the epoch event journal (merges with cache kind
                    and changed-shard counts, compactions, deletions,
                    snapshot refreshes) after the run
  --hold-secs N     keep the engine and /metrics endpoint alive N seconds
                    after the run (scrape smoke tests)
  --save PATH       persist the multi-shard engine state after building
                    (v3 container: bridge buffers + cached MSF +
                    tombstone state)
  --load PATH       resume a saved engine state (then add items on top)
  --wal-dir DIR     durable persistence: journal every batch to a
                    write-ahead log under DIR and recover automatically
                    on the next run (checkpoint + WAL-suffix replay); a
                    final checkpoint is taken before exit
  --checkpoint-every N  with --wal-dir, also checkpoint in the background
                    every N newly journaled items (default 0 = only the
                    final checkpoint)
  --durable         with --wal-dir, fsync the WAL after every ingest
                    batch (each batch is crash-durable before the next)
  --quality         external metrics vs the generator labels (fresh runs)

serve options (framed TCP protocol over a live engine; Label/LabelBatch/
Ingest/Remove/Stats/Ping plus the hierarchy surface Tree/LabelAt/
RelabelAt — see src/serve/frame.rs for the wire format):
  --addr A          listen address (default 127.0.0.1:7979; port 0 = any)
  --threads T       connection-handler pool size (default 4)
  --max-conns Q     accepted-but-unclaimed connection queue bound
                    (default 64; beyond it new connections get Busy)
  --drain-secs S    graceful-drain window on SIGTERM/SIGINT (default 2.0;
                    in-flight requests finish, acked ingests are flushed)
  --write-timeout S response-write deadline in seconds (default 5.0;
                    distinct from the read-side idle timeout — a stalled
                    reader can only pin a pool thread this long)
  --queue-depth D   per-shard ingest queue depth (default 16; full queues
                    answer Ingest with Busy instead of blocking)
  --preload N       generate + ingest N items from --dataset before
                    binding, then publish an initial epoch (labels work
                    from the first request; skipped when --wal-dir
                    recovered a non-empty engine)
  --wal-dir DIR     journal accepted writes to a WAL under DIR; on
                    restart the engine recovers (checkpoint + replay)
                    before binding
  --checkpoint-every N  background checkpoint period in items (0 = off)
  --durable         durable acks: Ingest/Remove OK frames are written
                    only after the batch's WAL record is fsynced — an
                    acked batch survives kill -9, not just SIGTERM
  --shards/--recluster-every/--metrics-addr/--hold-secs as for `engine`
  --client-probe    be a client instead: connect to --addr, ping, ingest
                    --probe-n items (default 64), label, remove, stats,
                    then walk the hierarchy surface (tree, relabel-at,
                    label-at); exit 0 iff every acked ingest is visible",
        names = datasets::DATASET_NAMES.join("|")
    );
}

fn params_from(args: &cli::Args) -> Result<(FishdbcParams, usize), String> {
    let min_pts = args.usize_or("min-pts", 10)?;
    let p = FishdbcParams {
        min_pts,
        ef: args.usize_or("ef", 20)?,
        alpha: args.f64_or("alpha", 5.0)?,
        seed: args.u64_or("seed", 42)?,
    };
    let mcs = args.usize_or("mcs", min_pts)?;
    Ok((p, mcs))
}

fn load_dataset(args: &cli::Args) -> Result<datasets::Dataset, String> {
    let ds = if let Some(path) = args.get("input") {
        let format = args.get_or("format", "csv");
        match format {
            "csv" => datasets::loaders::load_csv_vectors(path, false),
            "csv-labeled" => datasets::loaders::load_csv_vectors(path, true),
            "text" => datasets::loaders::load_text_lines(path),
            "docword" => datasets::loaders::load_uci_docword(path),
            other => return Err(format!("unknown input format {other:?}")),
        }
        .map_err(|e| format!("loading {path}: {e}"))?
    } else {
        let name = args.get_or("dataset", "blobs");
        let n = args.usize_or("n", 2000)?;
        let dim = args.usize_or("dim", 64)?;
        let seed = args.u64_or("seed", 42)?;
        datasets::generate(name, n, dim, seed)
            .ok_or_else(|| format!("unknown dataset {name:?}"))?
    };
    ds.validate()?;
    Ok(ds)
}

fn metric_override(
    args: &cli::Args,
    ds: &datasets::Dataset,
) -> Result<MetricKind, String> {
    match args.get("metric") {
        None => Ok(ds.metric),
        Some(m) => {
            MetricKind::parse(m).ok_or_else(|| format!("unknown metric {m:?}"))
        }
    }
}

fn cmd_run(args: &cli::Args) -> Result<(), String> {
    let ds = load_dataset(args)?;
    let (params, mcs) = params_from(args)?;
    let metric = metric_override(args, &ds)?;
    println!(
        "dataset {} ({} items), metric {}, ef={} MinPts={} mcs={mcs}",
        ds.name,
        ds.n(),
        metric.name(),
        params.ef,
        params.min_pts
    );

    // FISHDBC build + cluster, timed separately (paper's two columns).
    // `--load` resumes a saved state and adds this dataset on top.
    let t0 = std::time::Instant::now();
    let mut f: Fishdbc<Item, MetricKind> = match args.get("load") {
        Some(path) => {
            let f = Fishdbc::load_from_path(path)
                .map_err(|e| format!("loading state {path}: {e}"))?;
            println!("resumed state: {} items already indexed", f.len());
            f
        }
        None => Fishdbc::new(metric, params),
    };
    for it in ds.items.iter().cloned() {
        f.add(it);
    }
    let build = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let clustering = f.cluster(mcs);
    let cluster_t = t1.elapsed().as_secs_f64();
    println!(
        "FISHDBC: build {build:.3}s cluster {cluster_t:.3}s | {} dist calls | \
         {} flat clusters, {} clustered, {} hierarchical clusters",
        f.dist_calls(),
        clustering.n_clusters,
        clustering.n_clustered(),
        clustering.n_hierarchical_clusters(),
    );

    report_quality(args, &ds, metric, "FISHDBC", &clustering)?;

    if let Some(path) = args.get("save") {
        f.save_to_path(path).map_err(|e| format!("saving {path}: {e}"))?;
        println!("state saved to {path} ({} items)", f.len());
    }
    if let Some(path) = args.get("labels-out") {
        let file =
            std::fs::File::create(path).map_err(|e| format!("{path}: {e}"))?;
        datasets::loaders::write_labels_csv(file, &clustering.labels)
            .map_err(|e| format!("{path}: {e}"))?;
        println!("labels written to {path}");
    }

    if args.flag("exact") {
        let t0 = std::time::Instant::now();
        let r = exact_hdbscan(
            &ds.items,
            &metric,
            ExactParams { min_pts: params.min_pts, mcs, matrix_budget: None },
        )
        .map_err(|e| e.to_string())?;
        let total = t0.elapsed().as_secs_f64();
        println!(
            "HDBSCAN* (exact): {total:.3}s | {} dist calls | {} flat clusters, {} clustered",
            r.dist_calls,
            r.clustering.n_clusters,
            r.clustering.n_clustered(),
        );
        report_quality(args, &ds, metric, "HDBSCAN*", &r.clustering)?;
    }
    Ok(())
}

fn report_quality(
    args: &cli::Args,
    ds: &datasets::Dataset,
    metric: MetricKind,
    who: &str,
    clustering: &fishdbc::Clustering,
) -> Result<(), String> {
    if args.flag("quality") {
        for (label_name, truth) in &ds.label_sets {
            let s = score_external(&clustering.labels, truth);
            println!(
                "  {who} vs {label_name:<9} AMI {:.3}  AMI* {:.3}  ARI {:.3}  ARI* {:.3}",
                s.ami, s.ami_star, s.ari, s.ari_star
            );
        }
    }
    if args.flag("internal") {
        let max_pts = args.usize_or("silhouette-max", 4000)?;
        let scores = internal::score_internal(
            &ds.items,
            &clustering.labels,
            &metric,
            max_pts,
            args.u64_or("seed", 42)?,
        );
        match scores.silhouette {
            Some(s) => println!(
                "  {who} silhouette {s:.3}  intra {:.3}  inter {:.3}",
                scores.intra, scores.inter
            ),
            None => println!(
                "  {who} silhouette OOM  intra {:.3}  inter {:.3}",
                scores.intra, scores.inter
            ),
        }
    }
    Ok(())
}

fn cmd_stream(args: &cli::Args) -> Result<(), String> {
    let ds = load_dataset(args)?;
    let (params, mcs) = params_from(args)?;
    let metric = metric_override(args, &ds)?;
    let chunk = args.usize_or("chunk", 200)?;
    let every = args.usize_or("recluster-every", 1000)?;

    println!(
        "streaming {} ({} items) in chunks of {chunk}, re-cluster every {every}",
        ds.name,
        ds.n()
    );
    let c = Coordinator::spawn(metric, CoordinatorConfig {
        fishdbc: params,
        mcs,
        recluster_every: every,
        queue_depth: 8,
    });
    let t0 = std::time::Instant::now();
    for chunk_items in ds.items.chunks(chunk) {
        c.add_batch(chunk_items.to_vec());
        if let Some(snap) = c.latest() {
            println!(
                "  t={:7.2}s n={:6} clusters={:4} clustered={:6} extract={:.4}s",
                t0.elapsed().as_secs_f64(),
                snap.n_items,
                snap.clustering.n_clusters,
                snap.clustering.n_clustered(),
                snap.extract_secs
            );
        }
    }
    let snap = c.cluster(mcs);
    let stats = c.stats();
    println!(
        "final: n={} clusters={} clustered={} | build {:.2}s over {} batches, \
         {} reclusters, {} dist calls",
        snap.n_items,
        snap.clustering.n_clusters,
        snap.clustering.n_clustered(),
        stats.build_secs,
        stats.batches,
        stats.reclusters,
        stats.fishdbc.dist_calls
    );
    c.shutdown();
    Ok(())
}

/// `fishdbc engine`: sharded parallel ingest across S worker threads,
/// global MSF merge (per-shard forests + bridge edges), and an online
/// label-query demo against the merged snapshot.
fn cmd_engine(args: &cli::Args) -> Result<(), String> {
    let ds = load_dataset(args)?;
    let (params, mcs) = params_from(args)?;
    let metric = metric_override(args, &ds)?;
    let shards = args.usize_or("shards", 4)?;
    if shards == 0 {
        return Err("--shards must be at least 1".into());
    }
    let chunk = args.usize_or("chunk", 512)?;
    let bridge_k = args.usize_or("bridge-k", 3)?;
    let bridge_fanout =
        args.usize_or("bridge-fanout", shards.saturating_sub(1).max(1))?;
    let recluster_every = args.usize_or("recluster-every", 0)?;
    let bridge_refresh = args.usize_or("bridge-refresh", 0)?;
    let compact_at = args.f64_or("compact-at", EngineConfig::default().compact_at)?;
    let churn = args.f64_or("churn", 0.0)?;
    if !(0.0..=100.0).contains(&churn) {
        return Err("--churn expects a percentage in [0, 100]".into());
    }

    let econfig = EngineConfig {
        fishdbc: params,
        shards,
        mcs,
        bridge_k,
        bridge_fanout,
        queue_depth: 16,
        recluster_every,
        bridge_refresh,
        compact_at,
    };
    // three ways to an engine: durable (--wal-dir, with automatic
    // crash recovery), resumed (--load), or fresh
    let mut durable: Option<Durable> = None;
    let mut engine_owned: Option<Engine> = None;
    let mut resumed = false;
    if let Some(dir) = args.get("wal-dir") {
        if args.get("load").is_some() {
            return Err(
                "--wal-dir recovers from its own checkpoint + WAL; \
                 combining it with --load is ambiguous"
                    .into(),
            );
        }
        let mut dcfg = DurabilityConfig::new(dir);
        dcfg.checkpoint_every = args.u64_or("checkpoint-every", 0)?;
        let d = Durable::open_framework(metric, econfig, dcfg)
            .map_err(|e| format!("opening --wal-dir {dir}: {e}"))?;
        let recovered = d.engine().len();
        if recovered > 0 {
            let replayed = d
                .engine()
                .registry()
                .counter(CounterId::WalReplayed)
                .get();
            println!(
                "durable: recovered {recovered} items from {dir} \
                 ({replayed} WAL records replayed past the checkpoint)"
            );
            resumed = true;
        } else {
            println!("durable: fresh WAL at {dir}");
        }
        durable = Some(d);
    } else {
        match args.get("load") {
            Some(path) => {
                let e = Engine::load_from_path(path)
                    .map_err(|e| format!("loading engine state {path}: {e}"))?;
                if *e.metric() != metric {
                    return Err(format!(
                        "engine state {path} was built with metric {}, but the \
                         dataset/--metric selects {} — refusing to mix",
                        e.metric().name(),
                        metric.name()
                    ));
                }
                println!(
                    "resumed engine: {} shards, {} items already indexed \
                     (state fixes --shards/--ef/--min-pts/--bridge-k/\
                     --bridge-fanout; those flags are ignored)",
                    e.n_shards(),
                    e.len()
                );
                engine_owned = Some(e);
                resumed = true;
            }
            None => engine_owned = Some(Engine::spawn(metric, econfig)),
        }
    }
    let engine: &Engine = match &durable {
        Some(d) => d.engine().as_ref(),
        None => engine_owned.as_ref().expect("one handle is always set"),
    };
    // --durable: fsync the WAL after every ingest batch, so each batch
    // is crash-durable before the next is offered (the CLI analogue of
    // the serve layer's durable ack mode)
    let sync_every_batch = args.flag("durable");
    if sync_every_batch && durable.is_none() {
        return Err("--durable needs --wal-dir".into());
    }

    // serve /metrics before the first batch, so the endpoint is live
    // concurrently with ingest and recluster traffic from the start
    let metrics = match args.get("metrics-addr") {
        Some(addr) => {
            let srv = engine
                .serve_metrics(addr)
                .map_err(|e| format!("binding --metrics-addr {addr}: {e}"))?;
            println!("metrics: serving http://{}/metrics", srv.addr());
            Some(srv)
        }
        None => None,
    };

    // report the *effective* parameters (on --load they come from the
    // state file, not the CLI flags)
    let eff = engine.config().fishdbc;
    println!(
        "engine: {} shards, dataset {} ({} items), metric {}, ef={} MinPts={} \
         mcs={mcs} bridge_k={} fanout={}",
        engine.n_shards(),
        ds.name,
        ds.n(),
        metric.name(),
        eff.ef,
        eff.min_pts,
        engine.config().bridge_k,
        engine.config().bridge_fanout,
    );

    let t0 = std::time::Instant::now();
    let mut seen_epoch = 0u64;
    for batch in ds.items.chunks(chunk) {
        engine.add_batch(batch.to_vec());
        if sync_every_batch {
            if let Some(Err(e)) = engine.durability_sync() {
                return Err(format!("WAL fsync failed: {e}"));
            }
        }
        // the background serving loop publishes epochs while we ingest
        if engine.config().recluster_every > 0 {
            if let Some(snap) = engine.latest() {
                if snap.epoch > seen_epoch {
                    seen_epoch = snap.epoch;
                    println!(
                        "  epoch {:>3}: t={:6.2}s n={:>7} clusters={:>4} \
                         merge={:.3}s (bridge {:.3}s, reused extract: {})",
                        snap.epoch,
                        t0.elapsed().as_secs_f64(),
                        snap.n_items,
                        snap.clustering.n_clusters,
                        snap.extract_secs,
                        snap.bridge_secs,
                        snap.stages.reused_clustering,
                    );
                }
            }
        }
    }
    engine.flush();
    let ingest = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    println!(
        "ingest: {ingest:.3}s wall ({:.0} items/s) | busiest shard {:.3}s | \
         {} insert dist calls ({} total metric calls) across {} shards",
        ds.n() as f64 / ingest.max(1e-9),
        stats.build_secs,
        stats.dist_calls,
        stats.metric_calls,
        engine.n_shards(),
    );
    for (i, s) in stats.shard_stats.iter().enumerate() {
        println!(
            "  shard {i}: {:>7} items {:>10} dist calls {:>7} MSF edges",
            s.items, s.dist_calls, s.msf_edges
        );
    }

    let snap = engine.cluster(mcs);
    println!(
        "merge (epoch {}): {:.3}s | {} forest edges ({} bridges offered, \
         {} shards changed) | {} flat clusters, {} clustered",
        snap.epoch,
        snap.extract_secs,
        snap.n_msf_edges,
        snap.n_bridge_edges,
        snap.n_changed_shards,
        snap.clustering.n_clusters,
        snap.clustering.n_clustered(),
    );
    if args.flag("stats") {
        let es = engine.stats();
        println!(
            "pipeline: {} merges, {} runs ({} short-circuits, {} dendrogram \
             reuses)",
            es.merges,
            es.pipeline.runs,
            es.pipeline.short_circuits,
            es.pipeline.dendrogram_reuses,
        );
        println!(
            "  last merge stages: bridge {:.3}s kruskal {:.3}s dendrogram \
             {:.3}s condense {:.3}s extract {:.3}s",
            snap.bridge_secs,
            snap.kruskal_secs,
            snap.stages.dendrogram_secs,
            snap.stages.condense_secs,
            snap.stages.extract_secs,
        );
        println!(
            "  cumulative stages: dendrogram {:.3}s condense {:.3}s extract \
             {:.3}s",
            es.pipeline.dendrogram_secs,
            es.pipeline.condense_secs,
            es.pipeline.extract_secs,
        );
        println!(
            "  bridges: {} buffered edges ({} found at insert time, \
             {:.3}s), {} items covered ({} by merge catch-up, {} window \
             re-searches), {} compactions",
            es.bridge_edges,
            es.bridge_insert_edges,
            es.bridge_insert_secs,
            es.bridge_covered,
            es.bridge_catch_up_items,
            es.bridge_recheck_items,
            es.bridge_compactions,
        );
        println!(
            "  distance calls: {} total across every path ({} on the \
             insert path, via {} batched dispatches) — the paper's cost \
             model",
            es.metric_calls, es.dist_calls, es.batch_evals,
        );
        let chunks = es.pipeline.snapshot_chunks_copied
            + es.pipeline.snapshot_chunks_shared;
        println!(
            "  snapshots: {} captures, {} of {} chunks copied ({} shared \
             by reference), {:.2} MB copied",
            es.pipeline.snapshot_captures,
            es.pipeline.snapshot_chunks_copied,
            chunks,
            es.pipeline.snapshot_chunks_shared,
            es.pipeline.snapshot_bytes_copied as f64 / (1024.0 * 1024.0),
        );
        println!(
            "  churn: {} ids removed, {} tombstones live, {} shard \
             compactions (compact_at {})",
            es.removed_items,
            es.tombstoned_items,
            es.compactions,
            engine.config().compact_at,
        );
        // windowed view: rates + latency quantiles for everything since
        // spawn (or since the previous stats_delta call)
        let d = engine.stats_delta();
        println!(
            "  window ({:.2}s): {} items ({:.0}/s), {} metric calls \
             ({:.0}/s), {} merges, {} label queries",
            d.window_secs,
            d.items,
            d.items_per_sec,
            d.metric_calls,
            d.metric_calls_per_sec,
            d.merges,
            d.label_queries,
        );
        println!(
            "  window latencies: ingest p50 {:.1}us p99 {:.1}us | merge \
             p50 {:.3}s p99 {:.3}s | label p50 {:.1}us p99 {:.1}us",
            d.ingest_latency.quantile_ns(0.50) as f64 / 1e3,
            d.ingest_latency.quantile_ns(0.99) as f64 / 1e3,
            d.merge_latency.quantile_secs(0.50),
            d.merge_latency.quantile_secs(0.99),
            d.label_latency.quantile_ns(0.50) as f64 / 1e3,
            d.label_latency.quantile_ns(0.99) as f64 / 1e3,
        );
    }

    // --sweep-mcs LIST: hierarchy-as-a-service — re-extract flat
    // partitions at several minimum cluster sizes from the epoch pinned
    // by the merge above. Pure tree surgery over the cached dendrogram:
    // the whole sweep must not evaluate the metric once (self-checked,
    // exits 1 on violation; CI greps the OK line)
    if let Some(list) = args.get("sweep-mcs") {
        let sweep: Vec<usize> = list
            .split(',')
            .map(|s| s.trim().parse().map_err(|_| format!("bad mcs {s:?}")))
            .collect::<Result<_, _>>()?;
        let calls0 = engine.stats().metric_calls;
        println!(
            "mcs sweep (epoch {} pinned, two passes — the second hits the \
             extraction memo):",
            snap.epoch
        );
        println!(
            "  {:<6} {:>8} {:>10} {:>9} {:>12}",
            "mcs", "clusters", "clustered", "memo_hit", "extract(s)"
        );
        for pass in 0..2 {
            for &m in &sweep {
                let r = engine.relabel_at(ExtractionParams::stability(m));
                println!(
                    "  {:<6} {:>8} {:>10} {:>9} {:>12.6}{}",
                    m,
                    r.clustering.n_clusters,
                    r.clustering.n_clustered(),
                    r.memo_hit,
                    r.secs,
                    if pass == 1 { "  (repeat)" } else { "" },
                );
            }
        }
        let delta = engine.stats().metric_calls - calls0;
        if delta != 0 {
            return Err(format!(
                "sweep-mcs: {delta} metric calls during re-extraction \
                 (must be tree surgery only)"
            ));
        }
        println!("sweep-mcs: OK (0 metric calls across the sweep)");
    }

    // global ids are arrival order, so labels align with the dataset —
    // unless we resumed on top of pre-existing items
    if !resumed {
        report_quality(args, &ds, metric, "Engine", &snap.clustering)?;
    } else if args.flag("quality") {
        println!("  (skipping --quality: resumed state offsets the labels)");
    }

    // --churn P: incremental-deletion smoke — remove an id-scattered P%
    // of the stream by value, re-cluster, and verify the churned epoch
    // serves (deleted ids label -1; an online probe stays in contract)
    if churn > 0.0 && !resumed && ds.n() > 0 {
        let stride = ((100.0 / churn).round() as usize).max(1);
        let victims: Vec<Item> =
            ds.items.iter().step_by(stride).cloned().collect();
        let t = std::time::Instant::now();
        let removed = engine.remove_batch(&victims);
        let remove_secs = t.elapsed().as_secs_f64();
        let t = std::time::Instant::now();
        let churned = engine.cluster(mcs);
        let churn_secs = t.elapsed().as_secs_f64();
        let es = engine.stats();
        println!(
            "churn: removed {removed}/{} targets in {remove_secs:.3}s | \
             re-cluster {churn_secs:.3}s | epoch {}: {} survivors, {} \
             deleted, {} clusters, {} shards changed | {} tombstones \
             live, {} compactions",
            victims.len(),
            churned.epoch,
            churned.n_items,
            churned.n_deleted,
            churned.clustering.n_clusters,
            churned.n_changed_shards,
            es.tombstoned_items,
            es.compactions,
        );
        let leaked = engine
            .deleted_globals()
            .into_iter()
            .filter(|&gid| {
                churned.clustering.labels.get(gid as usize).copied()
                    != Some(-1)
            })
            .count();
        if leaked > 0 {
            return Err(format!("churn: {leaked} deleted ids kept labels"));
        }
        // a survivor when P < 100 (and the dataset has one)
        let probe = &ds.items[((stride > 1) as usize).min(ds.n() - 1)];
        let l = engine.label(probe);
        if (l as i64) >= churned.clustering.n_clusters as i64 {
            return Err(format!("churn: probe label {l} out of contract"));
        }
        println!("churn: OK (deleted ids label -1, probe label {l})");
    } else if churn > 0.0 {
        println!("churn: skipped (resumed state or empty dataset)");
    }

    if let Some(path) = args.get("save") {
        engine
            .save_to_path(path)
            .map_err(|e| format!("saving {path}: {e}"))?;
        println!("engine state saved to {path} ({} items)", engine.len());
    }

    // machine-readable stats document, written after churn/save so the
    // journal tail covers the whole run
    if let Some(path) = args.get("stats-json") {
        let doc = engine.stats_json();
        if path == "-" {
            println!("{doc}");
        } else {
            std::fs::write(path, &doc)
                .map_err(|e| format!("{path}: {e}"))?;
            println!("stats document written to {path} ({} bytes)", doc.len());
        }
    }

    if args.flag("journal") {
        let entries = engine.journal();
        println!("journal ({} entries):", entries.len());
        for e in entries {
            println!("  #{:<5} t={:9.3}s {:?}", e.seq, e.at_secs, e.event);
        }
    }

    // keep serving (e.g. /metrics scrape smoke tests) before shutdown
    let hold = args.f64_or("hold-secs", 0.0)?;
    if hold > 0.0 {
        println!("holding engine alive for {hold}s");
        std::thread::sleep(std::time::Duration::from_secs_f64(hold));
    }
    drop(metrics);
    match durable {
        Some(d) => {
            // final checkpoint: the next open replays only what lands
            // after this run (keeps recovery O(Δ) across CLI sessions)
            match d.checkpoint() {
                Ok(s) => println!(
                    "durable: checkpoint at watermark {} ({} WAL segments \
                     trimmed, {:.3}s)",
                    s.watermark, s.trimmed_segments, s.secs
                ),
                Err(e) => eprintln!("durable: final checkpoint failed: {e}"),
            }
            d.shutdown();
        }
        None => engine_owned
            .expect("owned when not durable")
            .shutdown(),
    }
    Ok(())
}

/// `fishdbc export`: cluster, then write the hierarchy in the requested
/// format (json | dot | newick | tree).
fn cmd_export(args: &cli::Args) -> Result<(), String> {
    use fishdbc::hdbscan::{export, Dendrogram};

    let ds = load_dataset(args)?;
    let (params, mcs) = params_from(args)?;
    let metric = metric_override(args, &ds)?;
    let mut f: Fishdbc<Item, MetricKind> = Fishdbc::new(metric, params);
    for it in ds.items.iter().cloned() {
        f.add(it);
    }
    let clustering = f.cluster(mcs);

    let format = args.get_or("format", "json");
    let body = match format {
        "json" => export::clustering_to_json(&clustering, &ds.name),
        "dot" => export::condensed_to_dot(&clustering),
        "newick" => {
            f.update_mst();
            let d = Dendrogram::from_msf(f.msf().edges(), f.len());
            export::dendrogram_to_newick(&d)
        }
        "tree" => export::report_to_text(&export::cluster_report(&clustering)),
        other => return Err(format!("unknown export format {other:?}")),
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &body).map_err(|e| format!("{path}: {e}"))?;
            eprintln!(
                "wrote {format} export ({} bytes, {} clusters) to {path}",
                body.len(),
                clustering.n_clusters
            );
        }
        None => print!("{body}"),
    }
    Ok(())
}

/// `fishdbc sweep`: the paper's ef trade-off (§4.1) on any dataset.
fn cmd_sweep(args: &cli::Args) -> Result<(), String> {
    let ds = load_dataset(args)?;
    let (base, mcs) = params_from(args)?;
    let metric = metric_override(args, &ds)?;
    let efs: Vec<usize> = args
        .get_or("efs", "10,20,50,100")
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| format!("bad ef {s:?}")))
        .collect::<Result<_, _>>()?;

    println!(
        "ef sweep on {} ({} items, metric {}):",
        ds.name,
        ds.n(),
        metric.name()
    );
    println!(
        "{:<6} {:>10} {:>12} {:>10} {:>10} {:>8}",
        "ef", "build(s)", "dist calls", "clusters", "clustered", "AMI*"
    );
    for ef in efs {
        let params = FishdbcParams { ef, ..base };
        let t0 = std::time::Instant::now();
        let mut f: Fishdbc<Item, MetricKind> = Fishdbc::new(metric, params);
        for it in ds.items.iter().cloned() {
            f.add(it);
        }
        let c = f.cluster(mcs);
        let build = t0.elapsed().as_secs_f64();
        let ami_star = ds
            .primary_labels()
            .map(|truth| format!("{:.3}", score_external(&c.labels, truth).ami_star))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<6} {:>10.2} {:>12} {:>10} {:>10} {:>8}",
            ef,
            build,
            f.dist_calls(),
            c.n_clusters,
            c.n_clustered(),
            ami_star
        );
    }
    Ok(())
}

/// `fishdbc serve`: bind the framed TCP protocol (src/serve) over a live
/// engine and run until SIGTERM/SIGINT (or `--hold-secs`), then drain
/// gracefully — in-flight requests finish and every acknowledged ingest
/// is flushed into the engine before the process exits 0.
fn cmd_serve(args: &cli::Args) -> Result<(), String> {
    if args.flag("client-probe") {
        return cmd_serve_probe(args);
    }
    let (params, mcs) = params_from(args)?;
    let shards = args.usize_or("shards", 4)?;
    if shards == 0 {
        return Err("--shards must be at least 1".into());
    }
    let preload = args.usize_or("preload", 0)?;

    // with --preload the dataset picks the metric (unless --metric
    // overrides it); a cold server defaults to Euclidean vectors
    let (metric, preload_items) = if preload > 0 {
        let name = args.get_or("dataset", "blobs");
        let dim = args.usize_or("dim", 64)?;
        let seed = args.u64_or("seed", 42)?;
        let ds = datasets::generate(name, preload, dim, seed)
            .ok_or_else(|| format!("unknown dataset {name:?}"))?;
        (metric_override(args, &ds)?, ds.items)
    } else {
        let metric = match args.get("metric") {
            None => MetricKind::Euclidean,
            Some(m) => MetricKind::parse(m)
                .ok_or_else(|| format!("unknown metric {m:?}"))?,
        };
        (metric, Vec::new())
    };

    let econfig = EngineConfig {
        fishdbc: params,
        shards,
        mcs,
        bridge_k: args.usize_or("bridge-k", 3)?,
        bridge_fanout: args
            .usize_or("bridge-fanout", shards.saturating_sub(1).max(1))?,
        queue_depth: args.usize_or("queue-depth", 16)?,
        recluster_every: args.usize_or("recluster-every", 0)?,
        bridge_refresh: args.usize_or("bridge-refresh", 0)?,
        compact_at: args
            .f64_or("compact-at", EngineConfig::default().compact_at)?,
    };
    // --wal-dir: recover (checkpoint + WAL replay) and journal every
    // accepted write from here on; the Durable handle must outlive the
    // server so the sink stays installed for the whole serving life
    let durable: Option<Durable> = match args.get("wal-dir") {
        Some(dir) => {
            let mut dcfg = DurabilityConfig::new(dir);
            dcfg.checkpoint_every = args.u64_or("checkpoint-every", 0)?;
            let d = Durable::open_framework(metric, econfig, dcfg)
                .map_err(|e| format!("opening --wal-dir {dir}: {e}"))?;
            let recovered = d.engine().len();
            if recovered > 0 {
                let replayed = d
                    .engine()
                    .registry()
                    .counter(CounterId::WalReplayed)
                    .get();
                println!(
                    "durable: recovered {recovered} items from {dir} \
                     ({replayed} WAL records replayed past the checkpoint)"
                );
            } else {
                println!("durable: fresh WAL at {dir}");
            }
            Some(d)
        }
        None => None,
    };
    if args.flag("durable") && durable.is_none() {
        return Err("--durable needs --wal-dir".into());
    }
    let engine: std::sync::Arc<Engine> = match &durable {
        Some(d) => std::sync::Arc::clone(d.engine()),
        None => std::sync::Arc::new(Engine::spawn(metric, econfig)),
    };

    // a recovered engine already has its items — re-preloading would
    // double-ingest them (and re-journal the duplicates)
    if !preload_items.is_empty() && engine.is_empty() {
        for chunk in preload_items.chunks(512) {
            engine.add_batch(chunk.to_vec());
        }
        let snap = engine.cluster(mcs);
        println!(
            "preload: {} items, epoch {} ({} clusters)",
            engine.len(),
            snap.epoch,
            snap.clustering.n_clusters
        );
    } else if !preload_items.is_empty() {
        println!(
            "preload: skipped ({} recovered items take precedence)",
            engine.len()
        );
    }

    let metrics = match args.get("metrics-addr") {
        Some(addr) => {
            let srv = engine
                .serve_metrics(addr)
                .map_err(|e| format!("binding --metrics-addr {addr}: {e}"))?;
            println!("metrics: serving http://{}/metrics", srv.addr());
            Some(srv)
        }
        None => None,
    };

    let addr = args.get_or("addr", "127.0.0.1:7979");
    let cfg = ServeConfig {
        threads: args.usize_or("threads", 4)?,
        max_pending_conns: args.usize_or("max-conns", 64)?,
        drain_timeout: std::time::Duration::from_secs_f64(
            args.f64_or("drain-secs", 2.0)?,
        ),
        write_timeout: std::time::Duration::from_secs_f64(
            args.f64_or("write-timeout", 5.0)?,
        ),
        durable: args.flag("durable"),
        ..ServeConfig::default()
    };
    let server =
        Server::start(std::sync::Arc::clone(&engine), FrameworkCodec, addr, cfg)
            .map_err(|e| format!("binding --addr {addr}: {e}"))?;
    println!(
        "serve: listening on {} ({} handler threads, metric {}, {} shards)",
        server.addr(),
        cfg.threads.max(1),
        engine.metric().name(),
        engine.n_shards()
    );

    sig::install();
    let hold = args.f64_or("hold-secs", 0.0)?;
    let t0 = std::time::Instant::now();
    loop {
        if sig::terminated() {
            println!("serve: signal received, draining");
            break;
        }
        if hold > 0.0 && t0.elapsed().as_secs_f64() >= hold {
            println!("serve: --hold-secs {hold} elapsed, draining");
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }

    let report = server.shutdown();
    // final WAL sync: whatever the drain flushed is also made durable
    // (errors surface on the exit line, not silently swallowed)
    if let Some(Err(e)) = engine.durability_sync() {
        eprintln!("serve: final WAL sync failed: {e}");
    }
    let es = engine.stats();
    let reg = engine.registry();
    let c = |id: CounterId| reg.counter(id).get();
    println!(
        "serve: drained cleanly | accepted_ids={} requests={} labels={} \
         ingested={} removed={} busy={} errors={} dropped_conns={} \
         wal_watermark={} wal_errors={}",
        engine.len(),
        c(CounterId::ServeRequests),
        c(CounterId::ServeLabelOps),
        c(CounterId::ServeIngestOps),
        c(CounterId::ServeRemoveOps),
        c(CounterId::ServeBusy),
        c(CounterId::ServeErrors),
        report.dropped_pending_conns,
        es.wal_watermark,
        es.wal_errors,
    );
    if let Some(err) = es.wal_last_error {
        eprintln!("serve: last WAL error: {err}");
    }
    drop(metrics);
    drop(engine);
    if let Some(d) = durable {
        d.shutdown();
    }
    Ok(())
}

/// `fishdbc serve --client-probe`: a self-checking client round trip used
/// by CI. Exits non-zero unless every acknowledged ingest is visible in
/// the server's item count — the client side of the durability contract.
fn cmd_serve_probe(args: &cli::Args) -> Result<(), String> {
    let addr = args.get_or("addr", "127.0.0.1:7979").to_string();
    let probe_n = args.usize_or("probe-n", 64)?.max(16);
    let dim = args.usize_or("dim", 8)?;
    let seed = args.u64_or("seed", 42)?;
    let items = datasets::generate("blobs", probe_n, dim, seed)
        .ok_or("blobs generator missing")?
        .items;

    // the server may still be binding (CI starts it in the background):
    // retry the connect for up to ~20 s before giving up
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(20);
    let mut client = loop {
        match Client::connect(addr.as_str(), FrameworkCodec) {
            Ok(c) => break c,
            Err(_) if std::time::Instant::now() < deadline => {
                std::thread::sleep(std::time::Duration::from_millis(250));
            }
            Err(e) => return Err(format!("connecting to {addr}: {e}")),
        }
    };
    client
        .set_timeout(Some(std::time::Duration::from_secs(10)))
        .map_err(|e| format!("{e}"))?;

    let (items0, epoch0) = client.ping().map_err(|e| format!("ping: {e}"))?;
    println!("probe: connected to {addr} (items={items0} epoch={epoch0})");

    let mut acked: u64 = 0;
    for chunk in items.chunks(16) {
        acked += client
            .ingest_retrying(
                chunk,
                std::time::Duration::from_millis(100),
                40,
            )
            .map_err(|e| format!("ingest: {e}"))?;
    }

    let k = 8.min(items.len());
    let labels = client
        .label_batch(&items[..k], 0)
        .map_err(|e| format!("label_batch: {e}"))?;
    if labels.len() != k {
        return Err(format!("label_batch: {k} items, {} labels", labels.len()));
    }
    let removed = client
        .remove(&items[..2])
        .map_err(|e| format!("remove: {e}"))?;
    let stats = client.stats_json().map_err(|e| format!("stats: {e}"))?;
    if !stats.contains("fishdbc-stats-v1") {
        return Err("stats response is not a fishdbc-stats-v1 document".into());
    }

    // hierarchy-as-a-service surface: Tree, RelabelAt, LabelAt — all
    // answered from the pinned epoch's cached dendrogram
    let (tree_epoch, tree) =
        client.tree().map_err(|e| format!("tree: {e}"))?;
    if tree.is_empty() {
        return Err("tree: empty hierarchy".into());
    }
    let (re_epoch, n_clusters, relabels) = client
        .relabel_at(ExtractionParams::stability(5))
        .map_err(|e| format!("relabel_at: {e}"))?;
    if relabels.is_empty() {
        return Err("relabel_at: empty labeling".into());
    }
    if relabels
        .iter()
        .any(|&l| l != -1 && (l as i64) >= n_clusters as i64)
    {
        return Err("relabel_at: label out of contract".into());
    }
    let leaf = ExtractionParams {
        mcs: 5,
        eps: 0.0,
        mode: ExtractionMode::Leaf,
    };
    let l_at = client
        .label_at(&items[2], 0, leaf)
        .map_err(|e| format!("label_at: {e}"))?;
    if l_at < -1 {
        return Err(format!("label_at: label {l_at} out of contract"));
    }
    println!(
        "probe: hierarchy OK (tree epoch {tree_epoch}: {} nodes | relabel \
         epoch {re_epoch}: {n_clusters} clusters over {} labels | leaf \
         label_at {l_at})",
        tree.len(),
        relabels.len()
    );

    // ids are monotone (removal tombstones, it never reuses ids), so the
    // durability check is a plain inequality
    let (items1, epoch1) = client.ping().map_err(|e| format!("ping: {e}"))?;
    if items1 < items0 + acked {
        return Err(format!(
            "server lost acked ingests: items {items0} -> {items1}, \
             but {acked} were acknowledged"
        ));
    }
    println!(
        "probe: OK acked={acked} labels={} removed={removed} \
         items={items1} epoch={epoch1}",
        labels.len()
    );
    Ok(())
}

/// SIGTERM/SIGINT notification without a signal-handling crate: the
/// classic `signal(2)` registration of a handler that only stores to an
/// atomic (async-signal-safe), polled by `cmd_serve`'s run loop.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_signum: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    extern "C" {
        // libc signal(2); handlers are passed as raw function addresses
        fn signal(signum: i32, handler: usize) -> usize;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        let h = on_term as extern "C" fn(i32) as usize;
        unsafe {
            signal(SIGTERM, h);
            signal(SIGINT, h);
        }
    }

    pub fn terminated() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

/// Non-unix fallback: no signal hook; `--hold-secs` (or ^C killing the
/// process outright) is the only way out of the serve loop.
#[cfg(not(unix))]
mod sig {
    pub fn install() {}
    pub fn terminated() -> bool {
        false
    }
}

#[cfg(feature = "xla")]
fn cmd_artifacts() -> Result<(), String> {
    let dir = default_artifacts_dir();
    let rt = Runtime::load(&dir).map_err(|e| format!("{e:#}"))?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {}", rt.artifacts_dir().display());
    for name in rt.module_names() {
        let m = rt.meta(name).unwrap();
        println!(
            "  {name:<40} op={:<10} metric={:<10} B={:<4} D={:<5} k={:?} outs={}",
            m.op, m.metric, m.b, m.d, m.k, m.outputs
        );
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts() -> Result<(), String> {
    Err("the `artifacts` command needs the PJRT runtime — rebuild with \
         `--features xla` in the accelerator image (see rust/Cargo.toml)"
        .into())
}
