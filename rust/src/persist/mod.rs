//! Persistence: save and reload the complete FISHDBC state (items, HNSW,
//! neighbor heaps, MSF, candidate buffer, RNG stream) in a small versioned
//! binary format, so a streaming deployment survives restarts and keeps
//! adding items **exactly** where it left off — same RNG levels, same
//! future clusterings (verified by round-trip tests).
//!
//! The format is hand-rolled (the offline image has no serde): little-endian
//! fixed-width scalars, length-prefixed sequences, a magic header and a
//! format version byte. All reads are bounds-checked; corrupt files produce
//! errors, never panics or unbounded allocations.
//!
//! Both containers are **generic over the item type** through an
//! [`ItemCodec`]: the single-instance `FISHDBC` blob and the multi-shard
//! `FISHENG` container serialize any `Fishdbc<T, M>` / `Engine<T, M>` given
//! a codec for `T` and a metric name string (generic metrics are code, not
//! data — the name is stored and handed back to a caller-supplied resolver
//! on load). The framework pair ([`Item`] via [`FrameworkCodec`],
//! [`MetricKind`] via its parse/name round trip) is the default
//! instantiation behind `save`/`load`, and its bytes are unchanged —
//! pinned by the checked-in `FISHENG` v1/v2 fixtures.

use std::io::{self, Read, Write};

use crate::distances::{bitmap::Bitmap, fuzzy::Digest, Counting, Item, Metric, MetricKind};
use crate::engine::merge::{MergeCache, MergeState, ShardStamp};
use crate::engine::shard::{BridgeState, ShardState};
use crate::engine::{Engine, EngineConfig, EngineItem};
use crate::fishdbc::{neighbors::NeighborStore, Fishdbc, FishdbcParams};
use crate::hnsw::{Hnsw, HnswExport, HnswParams};
use crate::mst::{Edge, Msf};

const MAGIC: &[u8; 8] = b"FISHDBC\0";
const VERSION: u8 = 1;
/// Single-instance files grow a trailing tombstone-id list when — and
/// only when — the instance has live tombstones. A clean instance keeps
/// writing byte-identical v1 (pinned by the checked-in fixtures), so the
/// version byte doubles as the "has tombstones" flag.
const VERSION_TOMBS: u8 = 2;
/// Multi-shard engine container: its own magic + version so single-instance
/// and engine state files are never confused.
const ENGINE_MAGIC: &[u8; 8] = b"FISHENG\0";
/// v1: per-shard FISHDBC blobs + id maps. v2 adds the recluster-pipeline
/// epoch state: per-shard bridge buffers/forests with coverage watermarks,
/// the serving-loop config knobs, and the cached global MSF with its
/// change stamps — so a restarted engine reclusters incrementally instead
/// of re-paying the full bridge search. v3 adds the deletion state:
/// `compact_at` in the header, each shard's cumulative removed-global-id
/// list (tombstones inside the nested FISHDBC blobs ride along as v2
/// single-instance blobs), and the per-shard removal count in the merge
/// stamps. v1/v2 files still load (with empty pipeline/deletion state
/// respectively).
const ENGINE_VERSION: u8 = 3;
const ENGINE_VERSION_V2: u8 = 2;
const ENGINE_VERSION_V1: u8 = 1;
/// Sanity cap on any single length prefix (guards corrupt files from
/// triggering huge allocations).
const MAX_LEN: u64 = 1 << 33;

// ---------------------------------------------------------------- writer --

/// Little-endian binary writer over any `io::Write`.
pub struct BinWriter<W: Write> {
    w: W,
}

impl<W: Write> BinWriter<W> {
    pub fn new(w: W) -> Self {
        BinWriter { w }
    }

    pub fn into_inner(self) -> W {
        self.w
    }

    pub fn u8(&mut self, x: u8) -> io::Result<()> {
        self.w.write_all(&[x])
    }

    pub fn u32(&mut self, x: u32) -> io::Result<()> {
        self.w.write_all(&x.to_le_bytes())
    }

    pub fn u64(&mut self, x: u64) -> io::Result<()> {
        self.w.write_all(&x.to_le_bytes())
    }

    pub fn f32(&mut self, x: f32) -> io::Result<()> {
        self.w.write_all(&x.to_le_bytes())
    }

    pub fn f64(&mut self, x: f64) -> io::Result<()> {
        self.w.write_all(&x.to_le_bytes())
    }

    pub fn len(&mut self, n: usize) -> io::Result<()> {
        self.u64(n as u64)
    }

    pub fn bytes(&mut self, b: &[u8]) -> io::Result<()> {
        self.len(b.len())?;
        self.w.write_all(b)
    }

    pub fn str(&mut self, s: &str) -> io::Result<()> {
        self.bytes(s.as_bytes())
    }

    pub fn u32s(&mut self, xs: &[u32]) -> io::Result<()> {
        self.len(xs.len())?;
        for &x in xs {
            self.u32(x)?;
        }
        Ok(())
    }

    pub fn f32s(&mut self, xs: &[f32]) -> io::Result<()> {
        self.len(xs.len())?;
        for &x in xs {
            self.f32(x)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- reader --

/// Little-endian binary reader with bounds checks.
pub struct BinReader<R: Read> {
    r: R,
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

impl<R: Read> BinReader<R> {
    pub fn new(r: R) -> Self {
        BinReader { r }
    }

    pub fn u8(&mut self) -> io::Result<u8> {
        let mut b = [0u8; 1];
        self.r.read_exact(&mut b)?;
        Ok(b[0])
    }

    pub fn u32(&mut self) -> io::Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn u64(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn f32(&mut self) -> io::Result<f32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }

    pub fn f64(&mut self) -> io::Result<f64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }

    pub fn len(&mut self) -> io::Result<usize> {
        let n = self.u64()?;
        if n > MAX_LEN {
            return Err(bad("length prefix exceeds sanity cap"));
        }
        Ok(n as usize)
    }

    pub fn bytes(&mut self) -> io::Result<Vec<u8>> {
        let n = self.len()?;
        let mut b = vec![0u8; n];
        self.r.read_exact(&mut b)?;
        Ok(b)
    }

    pub fn str(&mut self) -> io::Result<String> {
        String::from_utf8(self.bytes()?).map_err(|_| bad("invalid utf-8"))
    }

    pub fn u32s(&mut self) -> io::Result<Vec<u32>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    pub fn f32s(&mut self) -> io::Result<Vec<f32>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }
}

// ------------------------------------------------------------ item codec --

/// Byte codec for one stored item: how a `T` enters and leaves the
/// versioned containers. Implementations must be self-delimiting (read
/// exactly the bytes write produced) and deterministic (identical items
/// serialize identically — the fixture byte-identity tests rely on it).
pub trait ItemCodec<T> {
    fn write_item<W: Write>(&self, w: &mut BinWriter<W>, item: &T) -> io::Result<()>;
    fn read_item<R: Read>(&self, r: &mut BinReader<R>) -> io::Result<T>;
}

/// The framework codec for the dynamic [`Item`] type: a one-byte variant
/// tag followed by the payload. This is the original on-disk item format,
/// byte for byte — the `FISHENG`/`FISHDBC` fixtures pin it.
pub struct FrameworkCodec;

impl ItemCodec<Item> for FrameworkCodec {
    fn write_item<W: Write>(&self, w: &mut BinWriter<W>, item: &Item) -> io::Result<()> {
        match item {
            Item::Dense(v) => {
                w.u8(0)?;
                w.f32s(v)
            }
            Item::Sparse { idx, val } => {
                w.u8(1)?;
                w.u32s(idx)?;
                w.f32s(val)
            }
            Item::Set(s) => {
                w.u8(2)?;
                w.u32s(s)
            }
            Item::Text(t) => {
                w.u8(3)?;
                w.str(t)
            }
            Item::Bits(b) => {
                w.u8(4)?;
                w.len(b.len())?;
                w.len(b.words().len())?;
                for &word in b.words() {
                    w.u64(word)?;
                }
                Ok(())
            }
            Item::Digest(d) => {
                w.u8(5)?;
                w.len(d.minhashes.len())?;
                for &h in &d.minhashes {
                    w.u64(h)?;
                }
                w.bytes(&d.histogram)?;
                w.len(d.features.len())?;
                w.len(d.features.words().len())?;
                for &word in d.features.words() {
                    w.u64(word)?;
                }
                Ok(())
            }
        }
    }

    fn read_item<R: Read>(&self, r: &mut BinReader<R>) -> io::Result<Item> {
        Ok(match r.u8()? {
            0 => Item::Dense(r.f32s()?),
            1 => {
                let idx = r.u32s()?;
                let val = r.f32s()?;
                if idx.len() != val.len() {
                    return Err(bad("sparse idx/val length mismatch"));
                }
                Item::Sparse { idx, val }
            }
            2 => Item::Set(r.u32s()?),
            3 => Item::Text(r.str()?),
            4 => {
                let bits = r.len()?;
                let n_words = r.len()?;
                if n_words != bits.div_ceil(64) {
                    return Err(bad("bitmap word count mismatch"));
                }
                let mut words = Vec::with_capacity(n_words.min(1 << 20));
                for _ in 0..n_words {
                    words.push(r.u64()?);
                }
                Item::Bits(Bitmap::from_raw(bits, words))
            }
            5 => {
                let n_mh = r.len()?;
                let mut minhashes = Vec::with_capacity(n_mh.min(1 << 16));
                for _ in 0..n_mh {
                    minhashes.push(r.u64()?);
                }
                let histogram = r.bytes()?;
                let bits = r.len()?;
                let n_words = r.len()?;
                if n_words != bits.div_ceil(64) {
                    return Err(bad("digest bitmap word count mismatch"));
                }
                let mut words = Vec::with_capacity(n_words.min(1 << 20));
                for _ in 0..n_words {
                    words.push(r.u64()?);
                }
                Item::Digest(Digest {
                    minhashes,
                    histogram,
                    features: Bitmap::from_raw(bits, words),
                })
            }
            t => return Err(bad(&format!("unknown item tag {t}"))),
        })
    }
}

/// Resolver for the framework metric: the stored name parses back to a
/// [`MetricKind`].
fn parse_metric(name: &str) -> io::Result<MetricKind> {
    MetricKind::parse(name).ok_or_else(|| bad(&format!("unknown metric {name:?}")))
}

// --------------------------------------------------------- fishdbc codec --

/// Everything needed to resurrect a `Fishdbc<T, M>`. Metrics are code, not
/// data: only their *name* is stored, and the loader hands it to a
/// caller-supplied resolver (for the framework pair, `MetricKind::parse`).
pub struct SavedState<T = Item> {
    pub metric_name: String,
    pub params: FishdbcParams,
    pub items: Vec<T>,
    pub hnsw: HnswExport,
    pub neighbor_sets: Vec<Vec<(u32, f64)>>,
    pub msf_edges: Vec<Edge>,
    pub candidates: Vec<(u32, u32, f64)>,
    pub mst_updates: u64,
    /// Tombstoned local ids, ascending (empty ⇒ the file is written as
    /// plain v1, byte-identical to the pre-deletion format).
    pub tombstones: Vec<u32>,
}

/// Serialize a full state snapshot through `codec`.
pub fn write_state<T, C: ItemCodec<T>, W: Write>(
    w: W,
    codec: &C,
    s: &SavedState<T>,
) -> io::Result<()> {
    let mut w = BinWriter::new(w);
    w.w.write_all(MAGIC)?;
    w.u8(if s.tombstones.is_empty() { VERSION } else { VERSION_TOMBS })?;

    w.str(&s.metric_name)?;
    w.u64(s.params.min_pts as u64)?;
    w.u64(s.params.ef as u64)?;
    w.f64(s.params.alpha)?;
    w.u64(s.params.seed)?;

    w.len(s.items.len())?;
    for it in &s.items {
        codec.write_item(&mut w, it)?;
    }

    // hnsw
    w.u64(s.hnsw.params.m as u64)?;
    w.u64(s.hnsw.params.ef as u64)?;
    w.u64(s.hnsw.params.seed)?;
    w.len(s.hnsw.links.len())?;
    for node in &s.hnsw.links {
        w.len(node.len())?;
        for level in node {
            w.u32s(level)?;
        }
    }
    match s.hnsw.entry {
        None => w.u8(0)?,
        Some(e) => {
            w.u8(1)?;
            w.u32(e)?;
        }
    }
    for &x in &s.hnsw.rng_state {
        w.u64(x)?;
    }
    w.u64(s.hnsw.dist_calls)?;

    // neighbors
    w.len(s.neighbor_sets.len())?;
    for set in &s.neighbor_sets {
        w.len(set.len())?;
        for &(id, d) in set {
            w.u32(id)?;
            w.f64(d)?;
        }
    }

    // msf + candidates
    w.len(s.msf_edges.len())?;
    for e in &s.msf_edges {
        w.u32(e.a)?;
        w.u32(e.b)?;
        w.f64(e.w)?;
    }
    w.len(s.candidates.len())?;
    for &(a, b, d) in &s.candidates {
        w.u32(a)?;
        w.u32(b)?;
        w.f64(d)?;
    }
    w.u64(s.mst_updates)?;
    if !s.tombstones.is_empty() {
        w.u32s(&s.tombstones)?;
    }
    Ok(())
}

/// Deserialize a state snapshot (strict: trailing garbage is not checked,
/// wrong magic/version/structure is an error).
pub fn read_state<T, C: ItemCodec<T>, R: Read>(
    r: R,
    codec: &C,
) -> io::Result<SavedState<T>> {
    let mut r = BinReader::new(r);
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("not a FISHDBC state file"));
    }
    let version = r.u8()?;
    if version != VERSION && version != VERSION_TOMBS {
        return Err(bad("unsupported format version"));
    }

    let metric_name = r.str()?;
    let params = FishdbcParams {
        min_pts: r.u64()? as usize,
        ef: r.u64()? as usize,
        alpha: r.f64()?,
        seed: r.u64()?,
    };

    let n_items = r.len()?;
    let mut items = Vec::with_capacity(n_items.min(1 << 20));
    for _ in 0..n_items {
        items.push(codec.read_item(&mut r)?);
    }

    let hnsw_params = HnswParams {
        m: r.u64()? as usize,
        ef: r.u64()? as usize,
        seed: r.u64()?,
    };
    let n_nodes = r.len()?;
    if n_nodes != n_items {
        return Err(bad("hnsw node count != item count"));
    }
    let mut links = Vec::with_capacity(n_nodes.min(1 << 20));
    for _ in 0..n_nodes {
        let levels = r.len()?;
        let mut node = Vec::with_capacity(levels.min(64));
        for _ in 0..levels {
            node.push(r.u32s()?);
        }
        links.push(node);
    }
    let entry = match r.u8()? {
        0 => None,
        1 => Some(r.u32()?),
        _ => return Err(bad("bad entry tag")),
    };
    let rng_state = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    let dist_calls = r.u64()?;

    let n_sets = r.len()?;
    if n_sets != n_items {
        return Err(bad("neighbor set count != item count"));
    }
    let mut neighbor_sets = Vec::with_capacity(n_sets.min(1 << 20));
    for _ in 0..n_sets {
        let k = r.len()?;
        let mut set = Vec::with_capacity(k.min(1 << 10));
        for _ in 0..k {
            set.push((r.u32()?, r.f64()?));
        }
        neighbor_sets.push(set);
    }

    let n_edges = r.len()?;
    if n_edges >= n_items.max(1) {
        return Err(bad("msf has too many edges for a forest"));
    }
    let mut msf_edges = Vec::with_capacity(n_edges.min(1 << 20));
    for _ in 0..n_edges {
        msf_edges.push(Edge::new(r.u32()?, r.u32()?, r.f64()?));
    }
    let n_cand = r.len()?;
    let mut candidates = Vec::with_capacity(n_cand.min(1 << 20));
    for _ in 0..n_cand {
        candidates.push((r.u32()?, r.u32()?, r.f64()?));
    }
    let mst_updates = r.u64()?;
    let tombstones = if version >= VERSION_TOMBS {
        let t = r.u32s()?;
        if t.is_empty() {
            return Err(bad("v2 state without tombstones"));
        }
        if t.iter().any(|&id| id as usize >= n_items) {
            return Err(bad("tombstone id out of range"));
        }
        t
    } else {
        Vec::new()
    };

    Ok(SavedState {
        metric_name,
        params,
        items,
        hnsw: HnswExport { params: hnsw_params, links, entry, rng_state, dist_calls },
        neighbor_sets,
        msf_edges,
        candidates,
        mst_updates,
        tombstones,
    })
}

/// Rebuild a `Fishdbc` from a deserialized snapshot plus a live metric.
fn fishdbc_from_saved<T: Clone, M: Metric<T>>(
    metric: M,
    s: SavedState<T>,
) -> Fishdbc<T, M> {
    let n = s.items.len();
    let min_pts = s.params.min_pts;
    let mut f = Fishdbc::from_parts(
        metric,
        s.params,
        s.items,
        Hnsw::import(s.hnsw),
        NeighborStore::import(min_pts, s.neighbor_sets),
        Msf::from_parts(s.msf_edges, n),
        s.candidates,
        s.mst_updates,
    );
    // re-mark persisted tombstones (the neighbor sets / forest / buffer
    // were already purged when the removal originally ran)
    f.apply_tombstones(&s.tombstones);
    f
}

impl<T: Clone, M: Metric<T>> Fishdbc<T, M> {
    /// Serialize the complete state of any typed instance through `codec`,
    /// recording `metric_name` for the loader's resolver. The reloaded
    /// instance behaves identically for all future `add`/`cluster` calls.
    pub fn save_with<C: ItemCodec<T>, W: Write>(
        &self,
        metric_name: &str,
        codec: &C,
        w: W,
    ) -> io::Result<()> {
        write_state(w, codec, &SavedState {
            metric_name: metric_name.to_string(),
            params: *self.params(),
            items: self.items().to_vec(),
            hnsw: self.hnsw_export(),
            neighbor_sets: self.neighbors_export(),
            msf_edges: self.msf().edges().to_vec(),
            candidates: self.candidates_export(),
            mst_updates: self.stats().mst_updates,
            tombstones: self.tombs_export(),
        })
    }

    /// Reload a state previously written by [`Fishdbc::save_with`]:
    /// `resolve` turns the stored metric name back into a live metric (or
    /// rejects a file built under a different one).
    pub fn load_with<C: ItemCodec<T>, R: Read, F>(
        codec: &C,
        resolve: F,
        r: R,
    ) -> io::Result<Self>
    where
        F: FnOnce(&str) -> io::Result<M>,
    {
        let s = read_state(r, codec)?;
        let metric = resolve(&s.metric_name)?;
        Ok(fishdbc_from_saved(metric, s))
    }
}

impl Fishdbc<Item, MetricKind> {
    /// Serialize the complete state to a writer (framework instantiation:
    /// [`FrameworkCodec`] items, metric stored by name). The reloaded
    /// instance behaves identically for all future `add`/`cluster` calls.
    pub fn save<W: Write>(&self, w: W) -> io::Result<()> {
        self.save_with(self.metric().name(), &FrameworkCodec, w)
    }

    /// Reload a state previously written by [`Fishdbc::save`].
    pub fn load<R: Read>(r: R) -> io::Result<Self> {
        Self::load_with(&FrameworkCodec, parse_metric, r)
    }

    /// Save to a file path (convenience).
    pub fn save_to_path(&self, path: impl AsRef<std::path::Path>) -> io::Result<()> {
        let f = std::fs::File::create(path)?;
        self.save(io::BufWriter::new(f))
    }

    /// Load from a file path (convenience).
    pub fn load_from_path(path: impl AsRef<std::path::Path>) -> io::Result<Self> {
        let f = std::fs::File::open(path)?;
        Self::load(io::BufReader::new(f))
    }
}

// ---------------------------------------------------------- engine codec --

fn write_edges<W: Write>(w: &mut BinWriter<W>, edges: &[Edge]) -> io::Result<()> {
    w.len(edges.len())?;
    for e in edges {
        w.u32(e.a)?;
        w.u32(e.b)?;
        w.f64(e.w)?;
    }
    Ok(())
}

fn read_edge_triples<R: Read>(
    r: &mut BinReader<R>,
) -> io::Result<Vec<(u32, u32, f64)>> {
    let n = r.len()?;
    let mut v = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        v.push((r.u32()?, r.u32()?, r.f64()?));
    }
    Ok(v)
}

impl<T: EngineItem, M: Metric<T> + Clone + 'static> Engine<T, M> {
    /// Serialize the complete multi-shard engine state through `codec`: a
    /// versioned container holding every shard's full FISHDBC snapshot
    /// plus its local→global id map, the recluster-pipeline epoch state
    /// (bridge buffers, coverage watermarks, cached global MSF — since
    /// v2), and the deletion state (tombstone sets inside the nested
    /// blobs, each shard's cumulative removed-global-id record,
    /// `compact_at` — since v3), so a sharded deployment survives
    /// restarts and keeps ingesting **exactly** where it left off (same
    /// routing, same per-shard RNG streams, same future clusterings),
    /// reclustering incrementally instead of re-paying the full bridge
    /// search, with deleted ids staying deleted forever. Flushes first so
    /// no queued batch is lost.
    ///
    /// The persisted watermark is each shard's *merge-final* one: a
    /// checkpoint taken mid-epoch-window makes the next merge after reload
    /// re-run the (bounded) window search, so the same-epoch cross-shard
    /// guarantee survives save/load too.
    pub fn save_with<C: ItemCodec<T>, W: Write>(
        &self,
        metric_name: &str,
        codec: &C,
        w: W,
    ) -> io::Result<()> {
        self.save_cut_with(metric_name, codec, w, None, |_| ()).map(|_| ())
    }

    /// [`Engine::save_with`] with the cut protocol exposed — the seam the
    /// durability layer's checkpointer drives
    /// ([`write_checkpoint`](crate::durable::write_checkpoint)):
    ///
    /// * `required_watermark` — when `Some(w)`, the cut additionally
    ///   waits until the stored id space reaches exactly `w` ids. A
    ///   WAL-journaled batch that has reserved the *highest* ids but is
    ///   not yet enqueued leaves the stored prefix dense (the plain
    ///   `max_gid == total` check passes spuriously), and a checkpoint
    ///   cut there would exclude a batch the WAL places at or below its
    ///   cut sequence — lost forever after the post-checkpoint trim.
    ///   Pinning the cut to the caller's frozen watermark closes that
    ///   hole. The caller must guarantee no ids *past* `w` get assigned
    ///   until `on_cut` runs (the checkpointer holds the WAL mutex), or
    ///   the loop may never converge.
    /// * `on_cut(next_global)` — fired exactly once, after the shard
    ///   locks are pinned and the cut's id count is known but before any
    ///   bytes are written. The checkpointer uses it to record the cut's
    ///   WAL sequence and release the WAL freeze, so ingest resumes
    ///   while serialization streams out under the shard read locks.
    ///
    /// Returns the number of ids the written cut covers.
    pub fn save_cut_with<C: ItemCodec<T>, W: Write, F: FnOnce(u64)>(
        &self,
        metric_name: &str,
        codec: &C,
        w: W,
        required_watermark: Option<u64>,
        on_cut: F,
    ) -> io::Result<u64> {
        // Consistent cut under concurrent ingest: barrier, lock every
        // shard, then verify the locked states form a dense id space
        // 0..total (a batch routed between the barrier and the locks
        // leaves a gap in some shard); if one slipped in, re-barrier.
        // Items accepted after the locks are simply not in the checkpoint.
        let inner = self.inner();
        let guards = loop {
            self.flush();
            let guards: Vec<_> = inner
                .shard_handles()
                .iter()
                .map(|s| s.state.read().unwrap())
                .collect();
            // assigned ids = stored (live + tombstoned) + compacted-away
            // deletions (on the removed record but in no id map)
            let total: usize = guards
                .iter()
                .map(|g| {
                    g.f.len() + g.removed_globals.len() - g.f.n_tombstoned()
                })
                .sum();
            // true maximum, not .last(): interleaved add_batch callers can
            // leave a shard's globals non-monotone; the removed record
            // joins the scan (the max id may itself be deleted)
            let max_gid = guards
                .iter()
                .flat_map(|g| {
                    g.globals
                        .iter()
                        .copied()
                        .max()
                        .into_iter()
                        .chain(g.removed_globals.iter().copied().max())
                })
                .max()
                .map_or(0, |m| m as usize + 1);
            if max_gid == total
                && required_watermark.map_or(true, |r| total as u64 == r)
            {
                break guards;
            }
            drop(guards);
        };
        let next_global: u64 = guards
            .iter()
            .map(|g| {
                (g.f.len() + g.removed_globals.len() - g.f.n_tombstoned()) as u64
            })
            .sum();
        on_cut(next_global);

        let mut w = BinWriter::new(w);
        w.w.write_all(ENGINE_MAGIC)?;
        w.u8(ENGINE_VERSION)?;

        let cfg = *self.config();
        w.str(metric_name)?;
        w.u64(self.n_shards() as u64)?;
        w.u64(next_global)?;
        w.u64(cfg.mcs as u64)?;
        w.u64(cfg.bridge_k as u64)?;
        w.u64(cfg.bridge_fanout as u64)?;
        w.u64(cfg.queue_depth as u64)?;
        w.u64(cfg.recluster_every as u64)?;
        w.u64(cfg.bridge_refresh as u64)?;
        w.f64(cfg.compact_at)?;
        w.u64(self.epoch())?;

        // shards are quiescent behind the read guards, so their bridge
        // buffers are stable too (workers only touch them while holding
        // their state write lock)
        for (shard, st) in inner.shard_handles().iter().zip(&guards) {
            // dense export: the chunked in-memory layout never reaches disk
            w.u32s(&st.globals.to_vec())?;
            // cumulative removed global ids (deleted-forever record; the
            // live tombstone marks ride inside the nested blob)
            w.u32s(&st.removed_globals)?;
            w.u64(st.batches)?;
            w.f64(st.build_secs)?;
            // nested single-instance snapshot (own magic + version)
            st.f.save_with(metric_name, codec, &mut w.w)?;
            let br = shard.bridge.lock().unwrap();
            // the merge-final watermark (see the method docs): items
            // inside an unfinished epoch window re-run their window
            // search after reload instead of silently skipping it
            w.u64(br.merge_covered as u64)?;
            w.u64(br.generation)?;
            write_edges(&mut w, br.msf.edges())?;
            let buf = br.buf_export();
            w.len(buf.len())?;
            for &(a, b, wt) in &buf {
                w.u32(a)?;
                w.u32(b)?;
                w.f64(wt)?;
            }
        }

        // cached global MSF + change stamps (lock order matches the merge
        // path: states → merge → bridge, and the bridge guards above were
        // dropped per-shard)
        let ms = inner.merge.lock().unwrap();
        match &ms.cache {
            None => w.u8(0)?,
            Some(c) => {
                w.u8(1)?;
                w.u64(c.n as u64)?;
                for (s, st) in c.stamps.iter().zip(&guards) {
                    // A compaction after the cached merge can shrink a
                    // shard below its stamped item count; clamp so the
                    // loader's `items <= len` validation accepts the file.
                    // Sound: the compaction's removals also moved the
                    // removal stamp, so the shard still reads as changed
                    // (full re-fold) on the first post-load merge, and
                    // min() is idempotent across save/load/save cycles.
                    w.u64(s.items.min(st.f.len()) as u64)?;
                    w.u64(s.mst_updates)?;
                    w.u64(s.msf_len as u64)?;
                    w.u64(s.bridge_gen)?;
                    w.u64(s.removals as u64)?;
                }
                write_edges(&mut w, c.global.edges())?;
            }
        }
        let obs = inner.obs();
        obs.inc(crate::obs::CounterId::Saves);
        obs.journal.push(
            obs.uptime_secs(),
            crate::obs::JournalEvent::Save { items: next_global as usize },
        );
        Ok(next_global)
    }

    /// Reload an engine previously written by [`Engine::save_with`] (v2,
    /// or a pre-pipeline v1 file — the latter resumes with empty pipeline
    /// state, so its first recluster is a from-scratch merge). `resolve`
    /// turns the stored metric name back into a live metric (or rejects a
    /// file built under a different one). All reads are validated: shard
    /// counts, id-map lengths, global-id ranges and per-shard metric
    /// names must be mutually consistent or the load errors (never
    /// panics).
    pub fn load_with<C: ItemCodec<T>, R: Read, F>(
        codec: &C,
        resolve: F,
        r: R,
    ) -> io::Result<Engine<T, M>>
    where
        F: FnOnce(&str) -> io::Result<M>,
    {
        let mut r = BinReader::new(r);
        let mut magic = [0u8; 8];
        r.r.read_exact(&mut magic)?;
        if &magic != ENGINE_MAGIC {
            return Err(bad("not a FISHDBC engine state file"));
        }
        let version = r.u8()?;
        if version != ENGINE_VERSION
            && version != ENGINE_VERSION_V2
            && version != ENGINE_VERSION_V1
        {
            return Err(bad("unsupported engine format version"));
        }
        let v2 = version >= 2;
        let v3 = version >= 3;

        let metric_name = r.str()?;
        let metric = Counting::new(resolve(&metric_name)?);
        let n_shards = r.u64()? as usize;
        if n_shards == 0 || n_shards > 4096 {
            return Err(bad("implausible shard count"));
        }
        let next_global = r.u64()?;
        let mcs = r.u64()? as usize;
        let bridge_k = r.u64()? as usize;
        let bridge_fanout = r.u64()? as usize;
        let queue_depth = r.u64()? as usize;
        let (recluster_every, bridge_refresh) = if v2 {
            (r.u64()? as usize, r.u64()? as usize)
        } else {
            (0, 0)
        };
        let compact_at = if v3 {
            let ca = r.f64()?;
            if !ca.is_finite() || ca < 0.0 {
                return Err(bad("implausible compact_at"));
            }
            ca
        } else {
            EngineConfig::default().compact_at
        };
        let epoch = if v2 { r.u64()? } else { 0 };

        let mut parts: Vec<(ShardState<T, M>, BridgeState)> =
            Vec::with_capacity(n_shards);
        let mut total = 0u64;
        let mut params: Option<FishdbcParams> = None;
        for _ in 0..n_shards {
            let globals = r.u32s()?;
            let removed_globals = if v3 { r.u32s()? } else { Vec::new() };
            if removed_globals.iter().any(|&g| g as u64 >= next_global) {
                return Err(bad("removed global id out of range"));
            }
            let batches = r.u64()?;
            let build_secs = r.f64()?;
            let saved = read_state(&mut r.r, codec)?;
            if saved.metric_name != metric_name {
                return Err(bad("shard metric disagrees with engine header"));
            }
            let f = fishdbc_from_saved(metric.clone(), saved);
            if f.len() != globals.len() {
                return Err(bad("shard global-id map length mismatch"));
            }
            if globals.iter().any(|&g| g as u64 >= next_global) {
                return Err(bad("shard global id out of range"));
            }
            // every live tombstone must be on the permanent removed record
            if f.n_tombstoned() > 0 {
                let removed_set: std::collections::HashSet<u32> =
                    removed_globals.iter().copied().collect();
                for li in f.tombs_export() {
                    if !removed_set.contains(&globals[li as usize]) {
                        return Err(bad("tombstone missing from removed record"));
                    }
                }
            }
            let bridge = if v2 {
                let covered = r.u64()? as usize;
                if covered > f.len() {
                    return Err(bad("bridge coverage exceeds shard size"));
                }
                let generation = r.u64()?;
                let msf_edges = read_edge_triples(&mut r)?;
                let buf = read_edge_triples(&mut r)?;
                if msf_edges
                    .iter()
                    .chain(buf.iter())
                    .any(|&(a, b, _)| a as u64 >= next_global || b as u64 >= next_global)
                {
                    return Err(bad("bridge edge id out of range"));
                }
                BridgeState::from_parts(
                    covered,
                    generation,
                    msf_edges
                        .into_iter()
                        .map(|(a, b, wt)| Edge::new(a, b, wt))
                        .collect(),
                    buf,
                )
            } else {
                BridgeState::new()
            };
            total += globals.len() as u64 + removed_globals.len() as u64
                - f.n_tombstoned() as u64;
            if params.is_none() {
                params = Some(*f.params());
            }
            let inserts = f.len() as u64;
            parts.push((
                ShardState {
                    f,
                    globals: crate::util::chunked::ChunkedVec::from_vec(globals),
                    batches,
                    build_secs,
                    removed_globals,
                    inserts,
                    version: 0,
                    compactions: 0,
                },
                bridge,
            ));
        }
        if total != next_global {
            return Err(bad("shard item counts do not sum to the global count"));
        }
        // resume the shared distance-call counter from the persisted
        // insert-path totals so `metric_calls >= dist_calls` keeps holding
        // after a reload (prior search-path calls are not persisted)
        metric.add_calls(parts.iter().map(|(st, _)| st.f.dist_calls()).sum());

        let merge_state = if v2 && r.u8()? == 1 {
            let n = r.u64()? as usize;
            if n as u64 > next_global {
                return Err(bad("cached forest covers more items than exist"));
            }
            let mut stamps = Vec::with_capacity(n_shards);
            for (st, _bridge) in &parts {
                let items = r.u64()? as usize;
                if items > st.f.len() {
                    return Err(bad("stamp item count exceeds shard size"));
                }
                let mst_updates = r.u64()?;
                let msf_len = r.u64()? as usize;
                let bridge_gen = r.u64()?;
                let removals = if v3 { r.u64()? as usize } else { 0 };
                if removals > st.removed_globals.len() {
                    return Err(bad("stamp removals exceed the removed record"));
                }
                stamps.push(ShardStamp {
                    items,
                    mst_updates,
                    msf_len,
                    bridge_gen,
                    removals,
                });
            }
            let global = read_edge_triples(&mut r)?;
            if global.len() >= n.max(1) {
                return Err(bad("cached forest has too many edges"));
            }
            if global
                .iter()
                .any(|&(a, b, _)| a as usize >= n || b as usize >= n)
            {
                return Err(bad("cached forest edge id out of range"));
            }
            MergeState::resumed(Some(MergeCache {
                global: Msf::from_parts(
                    global
                        .into_iter()
                        .map(|(a, b, wt)| Edge::new(a, b, wt))
                        .collect(),
                    n,
                ),
                n,
                stamps,
            }))
        } else {
            MergeState::new()
        };

        let config = EngineConfig {
            fishdbc: params.unwrap_or_default(),
            shards: n_shards,
            mcs,
            bridge_k,
            bridge_fanout,
            queue_depth,
            recluster_every,
            bridge_refresh,
            compact_at,
        };
        Ok(Engine::from_resumed(
            metric,
            config,
            parts,
            next_global,
            merge_state,
            epoch,
        ))
    }
}

impl Engine {
    /// [`Engine::save_with`] for the framework instantiation
    /// ([`FrameworkCodec`] items, metric stored by name). Bytes are
    /// unchanged from before the generic refactor — pinned by the
    /// checked-in `FISHENG` fixtures.
    pub fn save<W: Write>(&self, w: W) -> io::Result<()> {
        self.save_with(self.metric().name(), &FrameworkCodec, w)
    }

    /// Reload an engine previously written by [`Engine::save`] (v2, or a
    /// pre-pipeline v1 file).
    pub fn load<R: Read>(r: R) -> io::Result<Engine> {
        Self::load_with(&FrameworkCodec, parse_metric, r)
    }

    /// Save to a file path (convenience).
    pub fn save_to_path(&self, path: impl AsRef<std::path::Path>) -> io::Result<()> {
        let f = std::fs::File::create(path)?;
        self.save(io::BufWriter::new(f))
    }

    /// Load from a file path (convenience).
    pub fn load_from_path(path: impl AsRef<std::path::Path>) -> io::Result<Engine> {
        let f = std::fs::File::open(path)?;
        Self::load(io::BufReader::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::engine::ShardKey;

    fn build(n: usize, seed: u64) -> Fishdbc<Item, MetricKind> {
        let ds = datasets::blobs::generate(n, 8, 4, seed);
        let mut f = Fishdbc::new(
            MetricKind::Euclidean,
            FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
        );
        for it in ds.items {
            f.add(it);
        }
        f
    }

    #[test]
    fn roundtrip_preserves_clustering_and_counters() {
        let mut f = build(300, 1);
        let mut buf = Vec::new();
        f.save(&mut buf).unwrap();
        let mut g = Fishdbc::load(buf.as_slice()).unwrap();

        assert_eq!(g.len(), f.len());
        assert_eq!(g.dist_calls(), f.dist_calls());
        let cf = f.cluster(5);
        let cg = g.cluster(5);
        assert_eq!(cf.labels, cg.labels);
        assert_eq!(cf.n_clusters, cg.n_clusters);
    }

    #[test]
    fn resumed_adds_match_uninterrupted_run() {
        // split a stream across a save/load boundary: the result must be
        // byte-identical to never having stopped (same RNG stream, same
        // candidate buffer)
        let ds = datasets::blobs::generate(400, 8, 4, 2);
        let p = FishdbcParams { min_pts: 5, ef: 20, ..Default::default() };

        let mut whole = Fishdbc::new(MetricKind::Euclidean, p);
        for it in ds.items.iter().cloned() {
            whole.add(it);
        }
        let want = whole.cluster(5);

        let mut first = Fishdbc::new(MetricKind::Euclidean, p);
        for it in ds.items[..200].iter().cloned() {
            first.add(it);
        }
        let mut buf = Vec::new();
        first.save(&mut buf).unwrap();
        let mut resumed = Fishdbc::load(buf.as_slice()).unwrap();
        for it in ds.items[200..].iter().cloned() {
            resumed.add(it);
        }
        let got = resumed.cluster(5);

        assert_eq!(got.labels, want.labels);
        assert!((resumed.msf().total_weight() - whole.msf().total_weight()).abs() < 1e-9);
        assert_eq!(resumed.dist_calls(), whole.dist_calls());
    }

    #[test]
    fn every_item_variant_roundtrips() {
        use crate::distances::{bitmap::Bitmap, fuzzy::Digest};
        let items = vec![
            Item::Dense(vec![1.5, -2.0, 0.0]),
            Item::Sparse { idx: vec![3, 9, 100], val: vec![0.1, 2.0, -1.0] },
            Item::Set(vec![1, 5, 9]),
            Item::Text("héllo \"world\"\n".into()),
            Item::Bits(Bitmap::from_bools(&[true, false, true, true])),
            Item::Digest(Digest::from_bytes(b"some binary-ish content 123")),
        ];
        let mut buf = Vec::new();
        let mut w = BinWriter::new(&mut buf);
        for it in &items {
            FrameworkCodec.write_item(&mut w, it).unwrap();
        }
        let mut r = BinReader::new(buf.as_slice());
        for it in &items {
            let got = FrameworkCodec.read_item(&mut r).unwrap();
            assert_eq!(&got, it);
        }
    }

    #[test]
    fn corrupt_and_truncated_inputs_error_cleanly() {
        let f = build(50, 3);
        let mut buf = Vec::new();
        f.save(&mut buf).unwrap();

        // wrong magic
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(Fishdbc::load(bad.as_slice()).is_err());

        // wrong version
        let mut bad = buf.clone();
        bad[8] = 99;
        assert!(Fishdbc::load(bad.as_slice()).is_err());

        // truncations at many offsets must error, never panic
        for cut in [9, 20, buf.len() / 2, buf.len() - 1] {
            assert!(
                Fishdbc::load(&buf[..cut]).is_err(),
                "truncation at {cut} did not error"
            );
        }
    }

    #[test]
    fn save_load_file_path() {
        let f = build(80, 4);
        let path = std::env::temp_dir().join("fishdbc_persist_test.bin");
        f.save_to_path(&path).unwrap();
        let g = Fishdbc::<Item, MetricKind>::load_from_path(&path).unwrap();
        assert_eq!(g.len(), 80);
        let _ = std::fs::remove_file(&path);
    }

    fn build_engine(n: usize, shards: usize, seed: u64) -> Engine {
        let ds = datasets::blobs::generate(n, 8, 4, seed);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards,
            mcs: 5,
            ..Default::default()
        });
        for chunk in ds.items.chunks(50) {
            engine.add_batch(chunk.to_vec());
        }
        engine
    }

    #[test]
    fn engine_roundtrip_preserves_clustering() {
        let engine = build_engine(300, 3, 8);
        let want = engine.cluster(5);

        let mut buf = Vec::new();
        engine.save(&mut buf).unwrap();
        let reloaded = Engine::load(buf.as_slice()).unwrap();
        assert_eq!(reloaded.n_shards(), 3);
        assert_eq!(reloaded.len(), 300);
        let got = reloaded.cluster(5);
        assert_eq!(got.clustering.labels, want.clustering.labels);
        assert_eq!(got.n_msf_edges, want.n_msf_edges);
        engine.shutdown();
        reloaded.shutdown();
    }

    #[test]
    fn engine_v2_roundtrip_preserves_pipeline_state() {
        let engine = build_engine(300, 3, 12);
        let want = engine.cluster(5); // populates bridge buffers + cache
        let mut buf = Vec::new();
        engine.save(&mut buf).unwrap();
        engine.shutdown();

        let reloaded = Engine::load(buf.as_slice()).unwrap();
        let got = reloaded.cluster(5);
        assert_eq!(got.clustering.labels, want.clustering.labels);
        assert_eq!(got.epoch, want.epoch + 1, "epoch counter resumes");
        // the resumed merge must take the delta path: stamps match, so no
        // shard re-offers and no bridge search re-runs
        assert_eq!(got.n_changed_shards, 0);
        assert_eq!(got.n_bridge_edges, 0, "no bridge re-search after resume");
        let stats = reloaded.stats();
        assert_eq!(stats.bridge_covered, 300, "coverage watermarks resumed");
        assert!(stats.bridge_edges > 0, "bridge buffers resumed");
        assert!(
            stats.metric_calls >= stats.dist_calls,
            "reload must re-seed the shared counter from the persisted \
             insert-path totals: {} < {}",
            stats.metric_calls,
            stats.dist_calls
        );
        reloaded.shutdown();
    }

    #[test]
    fn generic_engine_persists_through_custom_codec() {
        // the FISHENG container is generic: a typed engine over Vec<u32>
        // items under a plain function metric round-trips through a
        // five-line caller-supplied codec, pipeline state included
        struct U32VecCodec;
        impl ItemCodec<Vec<u32>> for U32VecCodec {
            fn write_item<W: Write>(
                &self,
                w: &mut BinWriter<W>,
                item: &Vec<u32>,
            ) -> io::Result<()> {
                w.u32s(item)
            }
            fn read_item<R: Read>(
                &self,
                r: &mut BinReader<R>,
            ) -> io::Result<Vec<u32>> {
                r.u32s()
            }
        }
        type L1 = fn(&Vec<u32>, &Vec<u32>) -> f64;
        // &Vec (not &[u32]) is forced by the Metric<Vec<u32>> signature
        #[allow(clippy::ptr_arg)]
        fn l1(a: &Vec<u32>, b: &Vec<u32>) -> f64 {
            a.iter()
                .zip(b)
                .map(|(&x, &y)| (x as f64 - y as f64).abs())
                .sum()
        }

        // two well-separated integer clusters
        let items: Vec<Vec<u32>> = (0..120u32)
            .map(|i| vec![i % 10 + (i % 2) * 500, i / 10])
            .collect();
        let engine: Engine<Vec<u32>, L1> =
            Engine::spawn(l1 as L1, EngineConfig {
                fishdbc: FishdbcParams { min_pts: 4, ef: 15, ..Default::default() },
                shards: 2,
                mcs: 4,
                ..Default::default()
            });
        engine.add_batch(items.clone());
        let want = engine.cluster(4);
        let mut buf = Vec::new();
        engine.save_with("l1-u32", &U32VecCodec, &mut buf).unwrap();
        engine.shutdown();

        // the resolver validates the stored metric name
        let wrong: io::Result<Engine<Vec<u32>, L1>> = Engine::load_with(
            &U32VecCodec,
            |name| {
                if name == "other" {
                    Ok(l1 as L1)
                } else {
                    Err(bad("metric mismatch"))
                }
            },
            buf.as_slice(),
        );
        assert!(wrong.is_err(), "resolver rejection must fail the load");

        let resumed: Engine<Vec<u32>, L1> = Engine::load_with(
            &U32VecCodec,
            |name| {
                assert_eq!(name, "l1-u32");
                Ok(l1 as L1)
            },
            buf.as_slice(),
        )
        .unwrap();
        assert_eq!(resumed.len(), 120);
        assert_eq!(resumed.n_shards(), 2);
        let got = resumed.cluster(4);
        assert_eq!(got.clustering.labels, want.clustering.labels);
        assert_eq!(
            got.n_changed_shards, 0,
            "pipeline state resumed through the custom codec"
        );
        resumed.shutdown();
    }

    #[test]
    fn single_instance_tombstones_roundtrip_and_clean_saves_stay_v1() {
        let mut f = build(200, 31);
        let mut clean = Vec::new();
        f.save(&mut clean).unwrap();
        assert_eq!(clean[8], 1, "clean instance must stay format v1");

        let victims: Vec<u32> = (0..200).step_by(7).collect();
        f.remove_batch_ids(&victims);
        let mut dirty = Vec::new();
        f.save(&mut dirty).unwrap();
        assert_eq!(dirty[8], 2, "tombstoned instance must write v2");

        let mut g = Fishdbc::<Item, MetricKind>::load(dirty.as_slice()).unwrap();
        assert_eq!(g.n_tombstoned(), victims.len());
        assert_eq!(g.tombs_export(), f.tombs_export());
        // save → load → save is byte-stable (checked before cluster():
        // extraction folds the candidate buffer, legitimately changing
        // the state)
        let mut again = Vec::new();
        g.save(&mut again).unwrap();
        assert_eq!(dirty, again, "tombstoned save/load/save drifted");
        let cf = f.cluster(5);
        let cg = g.cluster(5);
        assert_eq!(cf.labels, cg.labels);
        for &v in &victims {
            assert_eq!(cg.labels[v as usize], -1);
        }
    }

    #[test]
    fn engine_v3_roundtrips_tombstones_and_compaction_state() {
        let ds = datasets::blobs::generate(400, 8, 4, 23);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards: 3,
            mcs: 5,
            compact_at: 0.0, // keep tombstones in the saved state
            ..Default::default()
        });
        for chunk in ds.items.chunks(64) {
            engine.add_batch(chunk.to_vec());
        }
        let victims: Vec<Item> = ds.items.iter().step_by(9).cloned().collect();
        assert_eq!(engine.remove_batch(&victims), victims.len());
        let want = engine.cluster(5);
        assert_eq!(want.n_deleted, victims.len());

        let mut buf = Vec::new();
        engine.save(&mut buf).unwrap();
        assert_eq!(buf[8], 3, "engine container must be v3");
        let deleted = engine.deleted_globals();
        engine.shutdown();

        let reloaded = Engine::load(buf.as_slice()).unwrap();
        assert_eq!(reloaded.len(), 400, "assigned ids survive");
        assert_eq!(reloaded.deleted_globals(), deleted);
        let stats = reloaded.stats();
        assert_eq!(stats.removed_items, victims.len());
        assert_eq!(stats.tombstoned_items, victims.len());
        // save → load → save byte-stability for the v3 container (checked
        // before the merge below advances the persisted epoch counter)
        let mut again = Vec::new();
        reloaded.save(&mut again).unwrap();
        assert_eq!(buf, again, "v3 save/load/save drifted");
        let got = reloaded.cluster(5);
        assert_eq!(got.n_items, want.n_items);
        assert_eq!(got.n_deleted, want.n_deleted);
        assert_eq!(got.clustering.labels, want.clustering.labels);
        assert_eq!(
            got.n_changed_shards, 0,
            "resume keeps the delta path under tombstones"
        );
        for gid in &deleted {
            assert_eq!(got.clustering.labels[*gid as usize], -1);
        }
        reloaded.shutdown();

        // and the same through a *compacted* engine: compaction erases
        // tombstones but the removed record persists
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards: 2,
            mcs: 5,
            compact_at: 0.05,
            ..Default::default()
        });
        engine.add_batch(ds.items.clone());
        let victims: Vec<Item> = ds.items.iter().step_by(4).cloned().collect();
        assert_eq!(engine.remove_batch(&victims), victims.len());
        assert!(engine.stats().compactions >= 1, "25% churn must compact");
        let want = engine.cluster(5);
        let mut buf = Vec::new();
        engine.save(&mut buf).unwrap();
        let deleted = engine.deleted_globals();
        engine.shutdown();
        let reloaded = Engine::load(buf.as_slice()).unwrap();
        assert_eq!(reloaded.deleted_globals(), deleted);
        assert_eq!(reloaded.stats().tombstoned_items, 0);
        assert_eq!(reloaded.len(), 400, "assigned id space survives compaction");
        let got = reloaded.cluster(5);
        assert_eq!(got.clustering.labels, want.clustering.labels);
        reloaded.shutdown();
    }

    /// Regression (code review): a checkpoint taken after a compaction
    /// but *before* any new merge used to write the cached merge stamps
    /// verbatim — with pre-compaction item counts exceeding the shrunken
    /// shard — and the loader rejected its own file ("stamp item count
    /// exceeds shard size"). The writer now clamps; the removal stamps
    /// still force the full re-fold on the first post-load merge.
    #[test]
    fn save_right_after_compaction_reloads() {
        let ds = datasets::blobs::generate(300, 8, 4, 29);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards: 2,
            mcs: 5,
            compact_at: 0.05,
            ..Default::default()
        });
        engine.add_batch(ds.items.clone());
        let _ = engine.cluster(5); // builds the cache with full lens
        let victims: Vec<Item> = ds.items.iter().step_by(3).cloned().collect();
        assert_eq!(engine.remove_batch(&victims), victims.len());
        assert!(engine.stats().compactions >= 1, "33% churn must compact");
        // checkpoint with the cache still stamped pre-compaction
        let mut buf = Vec::new();
        engine.save(&mut buf).unwrap();
        let want = engine.cluster(5);
        engine.shutdown();

        let reloaded = Engine::load(buf.as_slice())
            .expect("post-compaction checkpoint must reload");
        let got = reloaded.cluster(5);
        assert_eq!(got.n_deleted, victims.len());
        assert_eq!(got.clustering.labels, want.clustering.labels);
        reloaded.shutdown();
    }

    #[test]
    fn engine_v1_files_still_load() {
        // emit the pre-pipeline v1 layout by hand; it must load with empty
        // pipeline state and recluster from scratch
        let ds = datasets::blobs::generate(120, 8, 4, 13);
        let p = FishdbcParams { min_pts: 5, ef: 20, ..Default::default() };
        let mut shards: Vec<(Fishdbc<Item, MetricKind>, Vec<u32>)> = (0..2)
            .map(|_| (Fishdbc::new(MetricKind::Euclidean, p), Vec::new()))
            .collect();
        for (gid, it) in ds.items.iter().enumerate() {
            let s = (it.shard_key() % 2) as usize;
            shards[s].0.add(it.clone());
            shards[s].1.push(gid as u32);
        }
        let mut buf = Vec::new();
        {
            let mut w = BinWriter::new(&mut buf);
            w.w.write_all(b"FISHENG\0").unwrap();
            w.u8(1).unwrap(); // ENGINE_VERSION_V1
            w.str(MetricKind::Euclidean.name()).unwrap();
            w.u64(2).unwrap(); // shards
            w.u64(120).unwrap(); // next_global
            w.u64(5).unwrap(); // mcs
            w.u64(3).unwrap(); // bridge_k
            w.u64(1).unwrap(); // bridge_fanout
            w.u64(16).unwrap(); // queue_depth
            for (f, globals) in &shards {
                w.u32s(globals).unwrap();
                w.u64(1).unwrap(); // batches
                w.f64(0.0).unwrap(); // build_secs
                f.save(&mut w.w).unwrap();
            }
        }
        let engine = Engine::load(buf.as_slice()).unwrap();
        assert_eq!(engine.len(), 120);
        assert_eq!(engine.n_shards(), 2);
        assert_eq!(engine.config().recluster_every, 0);
        assert_eq!(engine.epoch(), 0);
        let snap = engine.cluster(5);
        assert_eq!(snap.n_items, 120);
        assert_eq!(snap.n_changed_shards, 2, "v1 resume merges from scratch");
        engine.shutdown();
    }

    #[test]
    fn engine_and_single_instance_files_are_distinct() {
        let engine = build_engine(60, 2, 9);
        let mut ebuf = Vec::new();
        engine.save(&mut ebuf).unwrap();
        engine.shutdown();
        // engine file is not a valid single-instance file and vice versa
        assert!(Fishdbc::load(ebuf.as_slice()).is_err());
        let f = build(60, 9);
        let mut fbuf = Vec::new();
        f.save(&mut fbuf).unwrap();
        assert!(Engine::load(fbuf.as_slice()).is_err());
    }

    #[test]
    fn corrupt_engine_inputs_error_cleanly() {
        let engine = build_engine(80, 2, 10);
        let mut buf = Vec::new();
        engine.save(&mut buf).unwrap();
        engine.shutdown();

        // wrong magic / version
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(Engine::load(bad.as_slice()).is_err());
        let mut bad = buf.clone();
        bad[8] = 99;
        assert!(Engine::load(bad.as_slice()).is_err());

        // truncations at many offsets must error, never panic
        for cut in [9, 25, buf.len() / 3, buf.len() / 2, buf.len() - 1] {
            assert!(
                Engine::load(&buf[..cut]).is_err(),
                "truncation at {cut} did not error"
            );
        }
    }
}
