//! Shared clustering pipeline: MSF edges → single-linkage dendrogram →
//! condensed tree → flat extraction, with per-stage caching and timings.
//!
//! Both serving layers run the exact same back half of the algorithm —
//! the [`coordinator`](crate::coordinator) over its single FISHDBC forest
//! and the sharded [`Engine`](crate::engine::Engine) over the merged
//! global forest — so that back half lives here once, instead of as two
//! parallel code paths. The pipeline is *memoizing*: every stage is keyed
//! by a content hash of its input, so a re-cluster whose inputs did not
//! change is (nearly) free.
//!
//! ## Epoch / freshness model
//!
//! The engine's recluster path is **epoch-based**. An *epoch* is one
//! published [`EngineSnapshot`](crate::engine::EngineSnapshot): a merge
//! folds everything that happened since the previous epoch (new per-shard
//! MSF edges, new bridge candidates) into the cached global forest, then
//! re-extracts only the stages whose inputs actually changed:
//!
//! 1. **Bridge delta** — each shard maintains a coverage watermark: items
//!    below it already queried their remote shards (at insert time,
//!    against the frozen snapshots taken at the previous epoch); the merge
//!    first-covers only the items above it, plus one bounded re-search of
//!    the items insert-covered *inside* the closing window (whose frozen
//!    snapshots could predate same-window remote items — see
//!    `engine::merge`). Cross-shard candidate discovery is therefore
//!    *incremental* — O(Δn · k · fanout) HNSW searches, not
//!    O(n · k · fanout) — and **complete**: by the time an epoch closes,
//!    every item has searched remote states containing every item that
//!    existed at the barrier, so no cross-shard pair is ever silently
//!    dropped, regardless of how the window interleaved.
//! 2. **Kruskal delta** — every shard reports a stamp (item count, MSF
//!    generation, bridge generation). Kruskal re-runs over the cached
//!    global MSF ∪ the forests of *changed* shards ∪ the bridge sets of
//!    *bridge-changed* shards. Correct by the cycle property: the union
//!    graph only ever grows, so an edge once evicted from the global MSF
//!    (maximal on some cycle) can never re-enter it — the cached forest
//!    is a lossless summary of all unchanged parts.
//! 3. **Extraction short-circuit** — if the resulting global forest hashes
//!    identically to the previous epoch's (same `n`, same `mcs`), the
//!    dendrogram → condense → extract stages are skipped entirely and the
//!    cached clustering is republished.
//! 4. **Deletion (non-monotone) windows** — removals tombstone in place
//!    (`Engine::remove_batch`): the deleted item leaves every search and
//!    every vote immediately, its global id labels -1 in all future
//!    epochs, and the deleting shard's stamp flips (stamps carry the
//!    cumulative removal count). Because the cached-global-MSF lemma in
//!    step 2 *requires* monotone growth, a window containing any deletion
//!    drops the cached forest and re-folds all retained structures —
//!    collection-only work: untouched shards re-run no searches and
//!    recompute nothing, and the following deletion-free window is back
//!    on the cached path. Tombstone lifecycle details (tombstone → stamp
//!    invalidation → compaction at `EngineConfig::compact_at`) live in
//!    `engine::shard`; the non-monotone caveat is spelled out in
//!    `engine::merge`.
//! 5. **Chunked snapshot capture** — the frozen `ShardSnap`s that
//!    insert-time bridging queries are captured copy-on-write from the
//!    shards' chunked stores (items, HNSW nodes, cores, id maps — see the
//!    snapshot-lifecycle notes in `engine::shard`): a capture republishes
//!    every chunk untouched since the previous epoch by reference and the
//!    writer copies a chunk at most once per epoch window, so refreshes —
//!    including mid-epoch `bridge_refresh` captures — cost O(Δ), not O(n).
//!    Captures never touch bridge state, so coverage watermarks survive
//!    every refresh; an item's only second search is the bounded window
//!    re-search above. Per-capture copied-vs-shared chunk counts land in
//!    [`PipelineStats`] (`snapshot_*`; printed by `fishdbc engine
//!    --stats`, measured by the `snapshot_refresh` bench).
//!
//! The *epoch labels themselves* are conformance-tested: the seeded stress
//! harness (`tests/engine_stress.rs`) replays deterministic schedules of
//! ingest / merge / query / save-load — over Euclidean blobs and over
//! non-Euclidean workloads (Jaro-Winkler text, sparse cosine) — and
//! asserts every published epoch equals `Engine::reference_cluster`: a
//! from-scratch merge of the same state that bypasses every cache above.
//!
//! ## Extraction lifecycle (hierarchy as a service)
//!
//! The back half above the forest — dendrogram → condense → extract — is
//! *parameterized*: the paper's whole point is that the hierarchy "can be
//! expanded to a tree structure", so one cached dendrogram should serve
//! **every** granularity, not the single `(mcs, eps)` the engine was
//! configured with. The unit of request is [`ExtractionParams`]: a
//! minimum cluster size `mcs`, an eps threshold, and an
//! [`ExtractionMode`] (EoM stability, leaf, or Malzer & Baum's hybrid
//! eps+stability selection). Every extraction flows through one memo
//! chain, keyed by content hashes so the caches can never serve stale
//! structure:
//!
//! 1. **forest hash** (`edges_hash`) — identifies the epoch's global MSF;
//! 2. **dendrogram cache** (1 entry) — keyed by forest hash; survives
//!    across every `(mcs, eps, mode)` so a parameter sweep re-runs
//!    condense/extract only;
//! 3. **condensed-tree LRU** (keyed `(forest, mcs)`) — an eps/mode sweep
//!    at fixed `mcs` re-runs selection only;
//! 4. **extraction memo** (bounded LRU keyed
//!    `(forest, mcs, eps, mode, allow_single)`) — a repeated request is a
//!    pure cache hit returning a bit-identical [`Clustering`].
//!
//! None of these stages ever evaluates the user metric: re-extraction at
//! new parameters adds **zero** `metric_calls` by construction (the
//! paper's cost model — only searches pay distance calls). The engine
//! merge path ([`Pipeline::run`]) and the on-demand path
//! ([`Pipeline::extract_at`], serving `Engine::relabel_at` /
//! `Engine::label_at` and the `Tree`/`LabelAt`/`RelabelAt` wire ops) are
//! the same code; they differ only in which counters they bump
//! ([`CounterId::PipelineRuns`]/[`CounterId::PipelineShortCircuits`] vs
//! [`CounterId::Extractions`]/[`CounterId::ExtractMemoHits`], with
//! [`HistId::ExtractCall`] timing every request end to end).

use std::hash::Hasher;
use std::sync::Arc;
use std::time::Instant;

use crate::hdbscan::{
    extract, Clustering, CondensedTree, Dendrogram, ExtractionMode,
};
use crate::mst::Edge;
use crate::obs::{CounterId, HistId, Registry};
use crate::util::fasthash::FastHasher;

/// One parameterized extraction request: everything the back half of the
/// algorithm needs beyond the forest itself. See the module-level
/// *extraction lifecycle* notes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExtractionParams {
    /// Minimum cluster size for the condensed tree.
    pub mcs: usize,
    /// Eps threshold for [`ExtractionMode::HybridEps`]; ignored by the
    /// other modes (conventionally 0 there, which hybrid treats as "no
    /// threshold").
    pub eps: f64,
    /// Flat-selection policy.
    pub mode: ExtractionMode,
}

impl ExtractionParams {
    /// The engine merge path's defaults: pure EoM stability at `mcs`.
    pub fn stability(mcs: usize) -> ExtractionParams {
        ExtractionParams { mcs, eps: 0.0, mode: ExtractionMode::Stability }
    }
}

/// Full memo key of one extraction. `eps` is keyed by bit pattern so the
/// key stays `Eq` (and `NaN` probes memoize like any other value).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct MemoKey {
    forest: u64,
    mcs: usize,
    eps_bits: u64,
    mode: ExtractionMode,
    allow_single: bool,
}

impl MemoKey {
    fn new(forest: u64, p: ExtractionParams, allow_single: bool) -> MemoKey {
        MemoKey {
            forest,
            mcs: p.mcs,
            eps_bits: p.eps.to_bits(),
            mode: p.mode,
            allow_single,
        }
    }
}

/// Bounded LRU of memoized extractions: a small sweep (a handful of
/// tenants at different resolutions — e.g. the `extraction_sweep` bench's
/// 3 modes × 3 mcs values plus the merge's own cut) stays fully cached
/// without letting a parameter scan hold every labeling of every epoch
/// alive.
const EXTRACT_MEMO_CAP: usize = 16;
/// Bounded LRU of condensed trees (keyed `(forest, mcs)`): an eps/mode
/// sweep at fixed `mcs` re-runs selection only.
const CONDENSED_CACHE_CAP: usize = 4;

/// Content hash of an MSF edge list (plus the node count): the cache key
/// for every downstream stage. Edges are hashed in order, which is stable
/// because forests are kept weight-sorted by construction.
pub fn edges_hash(edges: &[Edge], n_points: usize) -> u64 {
    let mut h = FastHasher::default();
    h.write_u64(n_points as u64);
    h.write_u64(edges.len() as u64);
    for e in edges {
        h.write_u32(e.a);
        h.write_u32(e.b);
        h.write_u64(e.w.to_bits());
    }
    h.finish()
}

/// Cumulative pipeline counters (exposed through engine and coordinator
/// stats; the CLI prints them under `--stats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineStats {
    /// Total `run` calls.
    pub runs: u64,
    /// Runs answered entirely from the clustering cache (identical forest,
    /// `n`, `mcs`): condense/extract skipped.
    pub short_circuits: u64,
    /// Runs that reused the cached dendrogram (identical forest, new
    /// `mcs`): only condense/extract re-ran.
    pub dendrogram_reuses: u64,
    /// Parameterized extraction requests through the memo chain — both
    /// merge-path [`Pipeline::run`]s and on-demand
    /// [`Pipeline::extract_at`]s.
    pub extractions: u64,
    /// Extraction requests answered bit-identically from the bounded
    /// memo (no condense, no extract, zero metric calls).
    pub extract_memo_hits: u64,
    /// Cumulative seconds spent building dendrograms.
    pub dendrogram_secs: f64,
    /// Cumulative seconds spent condensing.
    pub condense_secs: f64,
    /// Cumulative seconds spent extracting flat clusterings.
    pub extract_secs: f64,
    /// Chunked copy-on-write snapshot captures (engine only; the
    /// coordinator path never captures, so these stay 0 there).
    pub snapshot_captures: u64,
    /// Chunks physically copied across all captures (i.e. dirty since the
    /// previous capture of the same shard, or first-time captures).
    pub snapshot_chunks_copied: u64,
    /// Chunks republished by reference across all captures — the O(n)
    /// clone work the chunked refactor avoids.
    pub snapshot_chunks_shared: u64,
    /// Approximate heap bytes in the copied chunks.
    pub snapshot_bytes_copied: u64,
    /// Every evaluation of the user metric across the whole engine —
    /// insertion, bridge searches, catch-up, online labels — from the
    /// shared [`Counting`](crate::distances::Counting) wrapper (engine
    /// only; the coordinator path leaves it 0). The paper's cost model:
    /// Figs 1–2 measure runtime in distance calls.
    pub metric_calls: u64,
}

/// Per-run stage breakdown returned alongside the clustering.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineRun {
    pub dendrogram_secs: f64,
    pub condense_secs: f64,
    pub extract_secs: f64,
    /// The dendrogram stage was served from cache.
    pub reused_dendrogram: bool,
    /// The whole run was served from cache (nothing recomputed).
    pub reused_clustering: bool,
}

/// Memoizing MSF → clustering pipeline (one instance per serving loop;
/// the dendrogram cache holds the previous epoch, the condensed and
/// extraction caches are small bounded LRUs over recent parameters — see
/// the module-level *extraction lifecycle* notes).
///
/// All counters and stage timings land in an [`obs::Registry`]
/// (span histograms [`HistId::Dendrogram`] / [`HistId::Condense`] /
/// [`HistId::Extract`], counters [`CounterId::PipelineRuns`] etc.);
/// [`Pipeline::stats`] assembles the legacy [`PipelineStats`] view from
/// the registry, so the public stats surface is unchanged while the
/// telemetry layer sees per-stage latency *distributions*, not just
/// cumulative sums.
///
/// [`obs::Registry`]: crate::obs::Registry
pub struct Pipeline {
    /// Shared telemetry sink (the owning engine's registry; standalone
    /// pipelines — the coordinator path, unit tests — get a private one).
    obs: Arc<Registry>,
    /// `(input hash, dendrogram)` of the last non-cached run.
    dendro: Option<(u64, Dendrogram)>,
    /// LRU (front = oldest) of condensed trees keyed `(forest, mcs)`.
    condensed: Vec<((u64, usize), CondensedTree)>,
    /// LRU (front = oldest) of finished extractions, full-key memoized.
    memo: Vec<(MemoKey, Clustering)>,
}

impl Default for Pipeline {
    fn default() -> Pipeline {
        Pipeline::new()
    }
}

impl Pipeline {
    /// A standalone pipeline with its own private registry (coordinator
    /// and test path).
    pub fn new() -> Pipeline {
        Pipeline::with_registry(Arc::new(Registry::new(0)))
    }

    /// A pipeline recording into a shared registry (the engine path).
    pub fn with_registry(obs: Arc<Registry>) -> Pipeline {
        Pipeline {
            obs,
            dendro: None,
            condensed: Vec::new(),
            memo: Vec::new(),
        }
    }

    /// Legacy cumulative counters, assembled as a thin view over the
    /// registry. The engine-level fields (`snapshot_*`, `metric_calls`)
    /// are filled in by `Engine::stats` — they live outside the
    /// pipeline.
    pub fn stats(&self) -> PipelineStats {
        PipelineStats {
            runs: self.obs.counter(CounterId::PipelineRuns).get(),
            short_circuits: self
                .obs
                .counter(CounterId::PipelineShortCircuits)
                .get(),
            dendrogram_reuses: self
                .obs
                .counter(CounterId::DendrogramReuses)
                .get(),
            extractions: self.obs.counter(CounterId::Extractions).get(),
            extract_memo_hits: self
                .obs
                .counter(CounterId::ExtractMemoHits)
                .get(),
            dendrogram_secs: self.obs.hist(HistId::Dendrogram).sum_ns() as f64
                / 1e9,
            condense_secs: self.obs.hist(HistId::Condense).sum_ns() as f64
                / 1e9,
            extract_secs: self.obs.hist(HistId::Extract).sum_ns() as f64 / 1e9,
            ..Default::default()
        }
    }

    /// Run (or short-circuit) the back half of the algorithm over a
    /// minimum spanning forest — the engine/coordinator *merge* path,
    /// always pure stability selection at the configured `mcs`. `edges`
    /// must be the complete forest, weight-ascending (both `Msf::edges`
    /// producers guarantee this).
    pub fn run(
        &mut self,
        edges: &[Edge],
        n_points: usize,
        mcs: usize,
        allow_single_cluster: bool,
    ) -> (Clustering, PipelineRun) {
        self.obs.inc(CounterId::PipelineRuns);
        let params = ExtractionParams::stability(mcs);
        let (clustering, run, hit) =
            self.extract_impl(edges, n_points, params, allow_single_cluster);
        if hit {
            self.obs.inc(CounterId::PipelineShortCircuits);
        }
        (clustering, run)
    }

    /// On-demand parameterized extraction over the same memo chain — the
    /// `Engine::relabel_at` / `Tree` / `RelabelAt` path. Does **not**
    /// count as a pipeline run (the merge-cadence counters stay
    /// meaningful); every call bumps [`CounterId::Extractions`] and, when
    /// served from the memo, [`CounterId::ExtractMemoHits`]. Never
    /// evaluates the user metric.
    pub fn extract_at(
        &mut self,
        edges: &[Edge],
        n_points: usize,
        params: ExtractionParams,
        allow_single_cluster: bool,
    ) -> (Clustering, PipelineRun) {
        let (clustering, run, _) =
            self.extract_impl(edges, n_points, params, allow_single_cluster);
        (clustering, run)
    }

    /// The shared memo chain (see the module-level lifecycle notes):
    /// extraction memo → dendrogram cache → condensed LRU → mode
    /// dispatch. Returns `(clustering, stage timings, memo_hit)`.
    fn extract_impl(
        &mut self,
        edges: &[Edge],
        n_points: usize,
        params: ExtractionParams,
        allow_single_cluster: bool,
    ) -> (Clustering, PipelineRun, bool) {
        let wall = Instant::now();
        let n = n_points.max(1);
        let key = MemoKey::new(edges_hash(edges, n), params, allow_single_cluster);
        self.obs.inc(CounterId::Extractions);

        if let Some(c) = self.memo_lookup(&key) {
            self.obs.inc(CounterId::ExtractMemoHits);
            self.obs.record(HistId::ExtractCall, wall.elapsed());
            return (
                c,
                PipelineRun {
                    reused_clustering: true,
                    reused_dendrogram: true,
                    ..Default::default()
                },
                true,
            );
        }

        let mut run = PipelineRun::default();

        // dendrogram: reusable across every (mcs, eps, mode) on the same
        // forest
        let reuse_dendro =
            matches!(&self.dendro, Some((k, _)) if *k == key.forest);
        if reuse_dendro {
            self.obs.inc(CounterId::DendrogramReuses);
            run.reused_dendrogram = true;
        } else {
            let t = Instant::now();
            let d = Dendrogram::from_msf(edges, n);
            let el = t.elapsed();
            run.dendrogram_secs = el.as_secs_f64();
            self.obs.record(HistId::Dendrogram, el);
            self.dendro = Some((key.forest, d));
        }
        let dendro = &self.dendro.as_ref().expect("dendrogram cached").1;

        // condensed tree: reusable across eps/mode sweeps at fixed mcs
        let ckey = (key.forest, key.mcs);
        let condensed = match self.condensed.iter().position(|(k, _)| *k == ckey)
        {
            Some(i) => {
                let entry = self.condensed.remove(i);
                self.condensed.push(entry);
                self.condensed.last().expect("just pushed").1.clone()
            }
            None => {
                let t = Instant::now();
                let tree = CondensedTree::from_dendrogram(dendro, params.mcs);
                let el = t.elapsed();
                run.condense_secs = el.as_secs_f64();
                self.obs.record(HistId::Condense, el);
                if self.condensed.len() >= CONDENSED_CACHE_CAP {
                    self.condensed.remove(0);
                }
                self.condensed.push((ckey, tree.clone()));
                tree
            }
        };

        let t = Instant::now();
        let clustering = match params.mode {
            ExtractionMode::Stability => {
                extract::extract_flat_opts(&condensed, allow_single_cluster)
            }
            ExtractionMode::Leaf => extract::extract_leaf(&condensed),
            ExtractionMode::HybridEps => {
                extract::extract_hybrid(&condensed, params.eps, allow_single_cluster)
            }
        };
        let el = t.elapsed();
        run.extract_secs = el.as_secs_f64();
        self.obs.record(HistId::Extract, el);

        if self.memo.len() >= EXTRACT_MEMO_CAP {
            self.memo.remove(0);
        }
        self.memo.push((key, clustering.clone()));
        self.obs.record(HistId::ExtractCall, wall.elapsed());
        (clustering, run, false)
    }

    /// Linear-scan LRU lookup (the cap is single-digit; a map would cost
    /// more in constants than it saves): hit moves the entry to the back.
    fn memo_lookup(&mut self, key: &MemoKey) -> Option<Clustering> {
        let i = self.memo.iter().position(|(k, _)| k == key)?;
        let entry = self.memo.remove(i);
        self.memo.push(entry);
        Some(self.memo.last().expect("just pushed").1.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdbscan::cluster_from_msf_opts;

    /// Two 5-point chains joined by one weak bridge (same fixture as the
    /// hdbscan module tests).
    fn forest() -> (Vec<Edge>, usize) {
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(5 + i, 5 + i + 1, 1.0));
        }
        edges.push(Edge::new(4, 5, 50.0));
        edges.sort_unstable_by(|x, y| x.w.total_cmp(&y.w));
        (edges, 10)
    }

    #[test]
    fn matches_reference_extraction() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        let (got, run) = p.run(&edges, n, 3, false);
        let want = cluster_from_msf_opts(&edges, n, 3, false);
        assert_eq!(got.labels, want.labels);
        assert_eq!(got.n_clusters, want.n_clusters);
        assert!(!run.reused_clustering);
        assert!(!run.reused_dendrogram);
    }

    #[test]
    fn identical_input_short_circuits() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        let (a, _) = p.run(&edges, n, 3, false);
        let (b, run) = p.run(&edges, n, 3, false);
        assert!(run.reused_clustering, "second run must be cached");
        assert_eq!(a.labels, b.labels);
        let s = p.stats();
        assert_eq!(s.runs, 2);
        assert_eq!(s.short_circuits, 1);
    }

    #[test]
    fn mcs_change_reuses_dendrogram_only() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        let _ = p.run(&edges, n, 3, false);
        let (c, run) = p.run(&edges, n, 6, false);
        assert!(run.reused_dendrogram);
        assert!(!run.reused_clustering);
        let want = cluster_from_msf_opts(&edges, n, 6, false);
        assert_eq!(c.labels, want.labels);
        assert_eq!(p.stats().dendrogram_reuses, 1);
    }

    #[test]
    fn changed_forest_recomputes() {
        let (mut edges, n) = forest();
        let mut p = Pipeline::new();
        let (a, _) = p.run(&edges, n, 3, false);
        edges.pop(); // drop the weak bridge: different forest
        let (b, run) = p.run(&edges, n, 3, false);
        assert!(!run.reused_clustering);
        assert!(!run.reused_dendrogram);
        // both forests split the chains into the same two flat clusters,
        // but the second run must have recomputed them
        assert_eq!(a.n_clusters, b.n_clusters);
        assert_eq!(p.stats().short_circuits, 0);
    }

    #[test]
    fn empty_forest_on_empty_input() {
        let mut p = Pipeline::new();
        let (c, _) = p.run(&[], 0, 5, false);
        assert_eq!(c.n_clusters, 0);
    }

    /// Satellite contract: re-extraction at an already-seen
    /// `(mcs, eps, mode)` is a memo hit returning a **bit-identical**
    /// labeling — across random forests and all three modes.
    #[test]
    fn prop_extract_at_memo_hit_is_bit_identical() {
        use crate::util::proptest::check;
        check("extract-memo-hit", 20, |rng, _| {
            let n = 6 + rng.below(80);
            let mut edges = Vec::new();
            for i in 1..n as u32 {
                let parent = rng.below(i as usize) as u32;
                edges.push(Edge::new(parent, i, rng.f64() * 5.0 + 0.01));
            }
            edges.sort_unstable_by(|x, y| x.w.total_cmp(&y.w));
            let mut p = Pipeline::new();
            let mode = match rng.below(3) {
                0 => ExtractionMode::Stability,
                1 => ExtractionMode::Leaf,
                _ => ExtractionMode::HybridEps,
            };
            let params = ExtractionParams {
                mcs: 2 + rng.below(5),
                eps: rng.f64() * 4.0,
                mode,
            };
            let (a, first) = p.extract_at(&edges, n, params, false);
            assert!(!first.reused_clustering);
            let hits0 = p.stats().extract_memo_hits;
            let (b, again) = p.extract_at(&edges, n, params, false);
            assert!(again.reused_clustering, "second request must memo-hit");
            assert_eq!(p.stats().extract_memo_hits, hits0 + 1);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.n_clusters, b.n_clusters);
            assert_eq!(a.selected, b.selected);
        });
    }

    #[test]
    fn eps_mode_sweep_reuses_dendrogram_and_condensed_tree() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        let _ = p.extract_at(&edges, n, ExtractionParams::stability(3), false);
        // same mcs, different mode: condense must be skipped entirely
        let (_, run) = p.extract_at(
            &edges,
            n,
            ExtractionParams { mcs: 3, eps: 0.0, mode: ExtractionMode::Leaf },
            false,
        );
        assert!(run.reused_dendrogram);
        assert!(!run.reused_clustering);
        assert_eq!(run.condense_secs, 0.0, "condensed tree was rebuilt");
        // different mcs: condense re-runs, dendrogram still cached
        let (_, run) = p.extract_at(
            &edges,
            n,
            ExtractionParams::stability(4),
            false,
        );
        assert!(run.reused_dendrogram);
        assert!(run.condense_secs > 0.0);
    }

    #[test]
    fn extract_at_modes_match_direct_extraction() {
        let (edges, n) = forest();
        let d = Dendrogram::from_msf(&edges, n);
        let t = CondensedTree::from_dendrogram(&d, 3);
        let mut p = Pipeline::new();
        let (stab, _) =
            p.extract_at(&edges, n, ExtractionParams::stability(3), false);
        assert_eq!(stab.labels, extract::extract_flat_opts(&t, false).labels);
        let (leaf, _) = p.extract_at(
            &edges,
            n,
            ExtractionParams { mcs: 3, eps: 0.0, mode: ExtractionMode::Leaf },
            false,
        );
        assert_eq!(leaf.labels, extract::extract_leaf(&t).labels);
        let (hyb, _) = p.extract_at(
            &edges,
            n,
            ExtractionParams {
                mcs: 3,
                eps: 2.0,
                mode: ExtractionMode::HybridEps,
            },
            false,
        );
        assert_eq!(hyb.labels, extract::extract_hybrid(&t, 2.0, false).labels);
    }

    #[test]
    fn memo_and_condensed_caches_stay_bounded() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        for mcs in 2..2 + 2 * EXTRACT_MEMO_CAP {
            let _ = p.extract_at(&edges, n, ExtractionParams::stability(mcs), false);
        }
        assert!(p.memo.len() <= EXTRACT_MEMO_CAP);
        assert!(p.condensed.len() <= CONDENSED_CACHE_CAP);
        // the most recent entries are retained: the last mcs still hits
        let last = 2 * EXTRACT_MEMO_CAP + 1;
        let hits0 = p.stats().extract_memo_hits;
        let (_, run) =
            p.extract_at(&edges, n, ExtractionParams::stability(last), false);
        assert!(run.reused_clustering);
        assert_eq!(p.stats().extract_memo_hits, hits0 + 1);
    }

    /// `run` (the merge path) and `extract_at` share one memo: a merge
    /// at the engine's configured mcs pre-populates the sweep's first
    /// probe, and a repeated `run` still reports its legacy
    /// short-circuit counter.
    #[test]
    fn run_and_extract_at_share_the_memo() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        let (a, _) = p.run(&edges, n, 3, false);
        let (b, run) =
            p.extract_at(&edges, n, ExtractionParams::stability(3), false);
        assert!(run.reused_clustering);
        assert_eq!(a.labels, b.labels);
        // extract_at must NOT count as a pipeline run / short-circuit
        let s = p.stats();
        assert_eq!(s.runs, 1);
        assert_eq!(s.short_circuits, 0);
        assert_eq!(s.extractions, 2);
        assert_eq!(s.extract_memo_hits, 1);
    }

    #[test]
    fn hash_is_sensitive_to_weights_and_order() {
        let e1 = [Edge::new(0, 1, 1.0), Edge::new(1, 2, 2.0)];
        let e2 = [Edge::new(0, 1, 1.0), Edge::new(1, 2, 2.5)];
        assert_ne!(edges_hash(&e1, 3), edges_hash(&e2, 3));
        assert_ne!(edges_hash(&e1, 3), edges_hash(&e1, 4));
        assert_eq!(edges_hash(&e1, 3), edges_hash(&e1, 3));
    }
}
