//! Shared clustering pipeline: MSF edges → single-linkage dendrogram →
//! condensed tree → flat extraction, with per-stage caching and timings.
//!
//! Both serving layers run the exact same back half of the algorithm —
//! the [`coordinator`](crate::coordinator) over its single FISHDBC forest
//! and the sharded [`Engine`](crate::engine::Engine) over the merged
//! global forest — so that back half lives here once, instead of as two
//! parallel code paths. The pipeline is *memoizing*: every stage is keyed
//! by a content hash of its input, so a re-cluster whose inputs did not
//! change is (nearly) free.
//!
//! ## Epoch / freshness model
//!
//! The engine's recluster path is **epoch-based**. An *epoch* is one
//! published [`EngineSnapshot`](crate::engine::EngineSnapshot): a merge
//! folds everything that happened since the previous epoch (new per-shard
//! MSF edges, new bridge candidates) into the cached global forest, then
//! re-extracts only the stages whose inputs actually changed:
//!
//! 1. **Bridge delta** — each shard maintains a coverage watermark: items
//!    below it already queried their remote shards (at insert time,
//!    against the frozen snapshots taken at the previous epoch); the merge
//!    first-covers only the items above it, plus one bounded re-search of
//!    the items insert-covered *inside* the closing window (whose frozen
//!    snapshots could predate same-window remote items — see
//!    `engine::merge`). Cross-shard candidate discovery is therefore
//!    *incremental* — O(Δn · k · fanout) HNSW searches, not
//!    O(n · k · fanout) — and **complete**: by the time an epoch closes,
//!    every item has searched remote states containing every item that
//!    existed at the barrier, so no cross-shard pair is ever silently
//!    dropped, regardless of how the window interleaved.
//! 2. **Kruskal delta** — every shard reports a stamp (item count, MSF
//!    generation, bridge generation). Kruskal re-runs over the cached
//!    global MSF ∪ the forests of *changed* shards ∪ the bridge sets of
//!    *bridge-changed* shards. Correct by the cycle property: the union
//!    graph only ever grows, so an edge once evicted from the global MSF
//!    (maximal on some cycle) can never re-enter it — the cached forest
//!    is a lossless summary of all unchanged parts.
//! 3. **Extraction short-circuit** — if the resulting global forest hashes
//!    identically to the previous epoch's (same `n`, same `mcs`), the
//!    dendrogram → condense → extract stages are skipped entirely and the
//!    cached clustering is republished.
//! 4. **Deletion (non-monotone) windows** — removals tombstone in place
//!    (`Engine::remove_batch`): the deleted item leaves every search and
//!    every vote immediately, its global id labels -1 in all future
//!    epochs, and the deleting shard's stamp flips (stamps carry the
//!    cumulative removal count). Because the cached-global-MSF lemma in
//!    step 2 *requires* monotone growth, a window containing any deletion
//!    drops the cached forest and re-folds all retained structures —
//!    collection-only work: untouched shards re-run no searches and
//!    recompute nothing, and the following deletion-free window is back
//!    on the cached path. Tombstone lifecycle details (tombstone → stamp
//!    invalidation → compaction at `EngineConfig::compact_at`) live in
//!    `engine::shard`; the non-monotone caveat is spelled out in
//!    `engine::merge`.
//! 5. **Chunked snapshot capture** — the frozen `ShardSnap`s that
//!    insert-time bridging queries are captured copy-on-write from the
//!    shards' chunked stores (items, HNSW nodes, cores, id maps — see the
//!    snapshot-lifecycle notes in `engine::shard`): a capture republishes
//!    every chunk untouched since the previous epoch by reference and the
//!    writer copies a chunk at most once per epoch window, so refreshes —
//!    including mid-epoch `bridge_refresh` captures — cost O(Δ), not O(n).
//!    Captures never touch bridge state, so coverage watermarks survive
//!    every refresh; an item's only second search is the bounded window
//!    re-search above. Per-capture copied-vs-shared chunk counts land in
//!    [`PipelineStats`] (`snapshot_*`; printed by `fishdbc engine
//!    --stats`, measured by the `snapshot_refresh` bench).
//!
//! The *epoch labels themselves* are conformance-tested: the seeded stress
//! harness (`tests/engine_stress.rs`) replays deterministic schedules of
//! ingest / merge / query / save-load — over Euclidean blobs and over
//! non-Euclidean workloads (Jaro-Winkler text, sparse cosine) — and
//! asserts every published epoch equals `Engine::reference_cluster`: a
//! from-scratch merge of the same state that bypasses every cache above.

use std::hash::Hasher;
use std::sync::Arc;
use std::time::Instant;

use crate::hdbscan::{extract, Clustering, CondensedTree, Dendrogram};
use crate::mst::Edge;
use crate::obs::{CounterId, HistId, Registry};
use crate::util::fasthash::FastHasher;

/// Content hash of an MSF edge list (plus the node count): the cache key
/// for every downstream stage. Edges are hashed in order, which is stable
/// because forests are kept weight-sorted by construction.
pub fn edges_hash(edges: &[Edge], n_points: usize) -> u64 {
    let mut h = FastHasher::default();
    h.write_u64(n_points as u64);
    h.write_u64(edges.len() as u64);
    for e in edges {
        h.write_u32(e.a);
        h.write_u32(e.b);
        h.write_u64(e.w.to_bits());
    }
    h.finish()
}

/// Cumulative pipeline counters (exposed through engine and coordinator
/// stats; the CLI prints them under `--stats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineStats {
    /// Total `run` calls.
    pub runs: u64,
    /// Runs answered entirely from the clustering cache (identical forest,
    /// `n`, `mcs`): condense/extract skipped.
    pub short_circuits: u64,
    /// Runs that reused the cached dendrogram (identical forest, new
    /// `mcs`): only condense/extract re-ran.
    pub dendrogram_reuses: u64,
    /// Cumulative seconds spent building dendrograms.
    pub dendrogram_secs: f64,
    /// Cumulative seconds spent condensing.
    pub condense_secs: f64,
    /// Cumulative seconds spent extracting flat clusterings.
    pub extract_secs: f64,
    /// Chunked copy-on-write snapshot captures (engine only; the
    /// coordinator path never captures, so these stay 0 there).
    pub snapshot_captures: u64,
    /// Chunks physically copied across all captures (i.e. dirty since the
    /// previous capture of the same shard, or first-time captures).
    pub snapshot_chunks_copied: u64,
    /// Chunks republished by reference across all captures — the O(n)
    /// clone work the chunked refactor avoids.
    pub snapshot_chunks_shared: u64,
    /// Approximate heap bytes in the copied chunks.
    pub snapshot_bytes_copied: u64,
    /// Every evaluation of the user metric across the whole engine —
    /// insertion, bridge searches, catch-up, online labels — from the
    /// shared [`Counting`](crate::distances::Counting) wrapper (engine
    /// only; the coordinator path leaves it 0). The paper's cost model:
    /// Figs 1–2 measure runtime in distance calls.
    pub metric_calls: u64,
}

/// Per-run stage breakdown returned alongside the clustering.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineRun {
    pub dendrogram_secs: f64,
    pub condense_secs: f64,
    pub extract_secs: f64,
    /// The dendrogram stage was served from cache.
    pub reused_dendrogram: bool,
    /// The whole run was served from cache (nothing recomputed).
    pub reused_clustering: bool,
}

/// Memoizing MSF → clustering pipeline (one instance per serving loop;
/// the caches hold exactly one entry — the previous epoch).
///
/// All counters and stage timings land in an [`obs::Registry`]
/// (span histograms [`HistId::Dendrogram`] / [`HistId::Condense`] /
/// [`HistId::Extract`], counters [`CounterId::PipelineRuns`] etc.);
/// [`Pipeline::stats`] assembles the legacy [`PipelineStats`] view from
/// the registry, so the public stats surface is unchanged while the
/// telemetry layer sees per-stage latency *distributions*, not just
/// cumulative sums.
///
/// [`obs::Registry`]: crate::obs::Registry
pub struct Pipeline {
    /// Shared telemetry sink (the owning engine's registry; standalone
    /// pipelines — the coordinator path, unit tests — get a private one).
    obs: Arc<Registry>,
    /// `(input hash, dendrogram)` of the last non-cached run.
    dendro: Option<(u64, Dendrogram)>,
    /// `(input hash, mcs, allow_single_cluster, clustering)` of the last
    /// non-cached run.
    out: Option<(u64, usize, bool, Clustering)>,
}

impl Default for Pipeline {
    fn default() -> Pipeline {
        Pipeline::new()
    }
}

impl Pipeline {
    /// A standalone pipeline with its own private registry (coordinator
    /// and test path).
    pub fn new() -> Pipeline {
        Pipeline::with_registry(Arc::new(Registry::new(0)))
    }

    /// A pipeline recording into a shared registry (the engine path).
    pub fn with_registry(obs: Arc<Registry>) -> Pipeline {
        Pipeline { obs, dendro: None, out: None }
    }

    /// Legacy cumulative counters, assembled as a thin view over the
    /// registry. The engine-level fields (`snapshot_*`, `metric_calls`)
    /// are filled in by `Engine::stats` — they live outside the
    /// pipeline.
    pub fn stats(&self) -> PipelineStats {
        PipelineStats {
            runs: self.obs.counter(CounterId::PipelineRuns).get(),
            short_circuits: self
                .obs
                .counter(CounterId::PipelineShortCircuits)
                .get(),
            dendrogram_reuses: self
                .obs
                .counter(CounterId::DendrogramReuses)
                .get(),
            dendrogram_secs: self.obs.hist(HistId::Dendrogram).sum_ns() as f64
                / 1e9,
            condense_secs: self.obs.hist(HistId::Condense).sum_ns() as f64
                / 1e9,
            extract_secs: self.obs.hist(HistId::Extract).sum_ns() as f64 / 1e9,
            ..Default::default()
        }
    }

    /// Run (or short-circuit) the back half of the algorithm over a
    /// minimum spanning forest. `edges` must be the complete forest,
    /// weight-ascending (both `Msf::edges` producers guarantee this).
    pub fn run(
        &mut self,
        edges: &[Edge],
        n_points: usize,
        mcs: usize,
        allow_single_cluster: bool,
    ) -> (Clustering, PipelineRun) {
        let n = n_points.max(1);
        let key = edges_hash(edges, n);
        self.obs.inc(CounterId::PipelineRuns);

        if let Some((k, m, a, c)) = &self.out {
            if *k == key && *m == mcs && *a == allow_single_cluster {
                self.obs.inc(CounterId::PipelineShortCircuits);
                return (
                    c.clone(),
                    PipelineRun {
                        reused_clustering: true,
                        reused_dendrogram: true,
                        ..Default::default()
                    },
                );
            }
        }

        let mut run = PipelineRun::default();

        // dendrogram: reusable across mcs changes on the same forest
        let reuse_dendro = matches!(&self.dendro, Some((k, _)) if *k == key);
        if reuse_dendro {
            self.obs.inc(CounterId::DendrogramReuses);
            run.reused_dendrogram = true;
        } else {
            let t = Instant::now();
            let d = Dendrogram::from_msf(edges, n);
            let el = t.elapsed();
            run.dendrogram_secs = el.as_secs_f64();
            self.obs.record(HistId::Dendrogram, el);
            self.dendro = Some((key, d));
        }
        let dendro = &self.dendro.as_ref().expect("dendrogram cached").1;

        let t = Instant::now();
        let condensed = CondensedTree::from_dendrogram(dendro, mcs);
        let el = t.elapsed();
        run.condense_secs = el.as_secs_f64();
        self.obs.record(HistId::Condense, el);

        let t = Instant::now();
        let clustering = extract::extract_flat_opts(&condensed, allow_single_cluster);
        let el = t.elapsed();
        run.extract_secs = el.as_secs_f64();
        self.obs.record(HistId::Extract, el);

        self.out = Some((key, mcs, allow_single_cluster, clustering.clone()));
        (clustering, run)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdbscan::cluster_from_msf_opts;

    /// Two 5-point chains joined by one weak bridge (same fixture as the
    /// hdbscan module tests).
    fn forest() -> (Vec<Edge>, usize) {
        let mut edges = Vec::new();
        for i in 0..4u32 {
            edges.push(Edge::new(i, i + 1, 1.0));
            edges.push(Edge::new(5 + i, 5 + i + 1, 1.0));
        }
        edges.push(Edge::new(4, 5, 50.0));
        edges.sort_unstable_by(|x, y| x.w.total_cmp(&y.w));
        (edges, 10)
    }

    #[test]
    fn matches_reference_extraction() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        let (got, run) = p.run(&edges, n, 3, false);
        let want = cluster_from_msf_opts(&edges, n, 3, false);
        assert_eq!(got.labels, want.labels);
        assert_eq!(got.n_clusters, want.n_clusters);
        assert!(!run.reused_clustering);
        assert!(!run.reused_dendrogram);
    }

    #[test]
    fn identical_input_short_circuits() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        let (a, _) = p.run(&edges, n, 3, false);
        let (b, run) = p.run(&edges, n, 3, false);
        assert!(run.reused_clustering, "second run must be cached");
        assert_eq!(a.labels, b.labels);
        let s = p.stats();
        assert_eq!(s.runs, 2);
        assert_eq!(s.short_circuits, 1);
    }

    #[test]
    fn mcs_change_reuses_dendrogram_only() {
        let (edges, n) = forest();
        let mut p = Pipeline::new();
        let _ = p.run(&edges, n, 3, false);
        let (c, run) = p.run(&edges, n, 6, false);
        assert!(run.reused_dendrogram);
        assert!(!run.reused_clustering);
        let want = cluster_from_msf_opts(&edges, n, 6, false);
        assert_eq!(c.labels, want.labels);
        assert_eq!(p.stats().dendrogram_reuses, 1);
    }

    #[test]
    fn changed_forest_recomputes() {
        let (mut edges, n) = forest();
        let mut p = Pipeline::new();
        let (a, _) = p.run(&edges, n, 3, false);
        edges.pop(); // drop the weak bridge: different forest
        let (b, run) = p.run(&edges, n, 3, false);
        assert!(!run.reused_clustering);
        assert!(!run.reused_dendrogram);
        // both forests split the chains into the same two flat clusters,
        // but the second run must have recomputed them
        assert_eq!(a.n_clusters, b.n_clusters);
        assert_eq!(p.stats().short_circuits, 0);
    }

    #[test]
    fn empty_forest_on_empty_input() {
        let mut p = Pipeline::new();
        let (c, _) = p.run(&[], 0, 5, false);
        assert_eq!(c.n_clusters, 0);
    }

    #[test]
    fn hash_is_sensitive_to_weights_and_order() {
        let e1 = [Edge::new(0, 1, 1.0), Edge::new(1, 2, 2.0)];
        let e2 = [Edge::new(0, 1, 1.0), Edge::new(1, 2, 2.5)];
        assert_ne!(edges_hash(&e1, 3), edges_hash(&e2, 3));
        assert_ne!(edges_hash(&e1, 3), edges_hash(&e1, 4));
        assert_eq!(edges_hash(&e1, 3), edges_hash(&e1, 3));
    }
}
