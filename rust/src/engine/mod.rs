//! Sharded parallel ingest engine: FISHDBC at multi-core throughput, for
//! **arbitrary data and distance functions**.
//!
//! The [`coordinator`](crate::coordinator) makes FISHDBC *streaming*, but
//! its single worker caps ingest at one core of HNSW insertion. This engine
//! removes that cap with **S independent shards** — each a worker thread
//! owning a [`Fishdbc`](crate::fishdbc::Fishdbc) over a hash-partitioned
//! slice of the item space — and recovers a **global clustering** through an
//! incremental, epoch-based recluster pipeline (see [`pipeline`]).
//!
//! Like the core [`Fishdbc<T, M>`](crate::fishdbc::Fishdbc), the engine is
//! generic: [`Engine<T, M>`] shards any [`EngineItem`] type under any
//! cloneable [`Metric<T>`] — a closure is enough — so the paper's
//! flexibility axis (Table 1's text, sparse, set and fuzzy-hash workloads,
//! or your own types) holds at production scale, not just in the library
//! core. The dynamic [`Item`]/[`MetricKind`] pair used by the CLI and the
//! framework datasets is simply the default instantiation (`Engine` with no
//! type arguments). Every distance evaluation, on every path — insertion,
//! bridge search, catch-up, online labels — flows through one shared
//! [`Counting`] wrapper, surfacing the paper's cost model (Figs 1–2 measure
//! work in distance calls) as `EngineStats::metric_calls`.
//!
//! ## Architecture
//!
//! * **Routing** ([`Engine::add_batch`]): every arriving item gets the next
//!   dense global id (arrival order — labels stay index-aligned with the
//!   input stream) and is hash-routed by *content* ([`ShardKey`]) to one
//!   shard, so each shard holds a uniform random subsample and mirrors the
//!   global density structure. Bounded queues give backpressure, exactly
//!   like the coordinator.
//! * **Insert-time bridges** (`engine/shard.rs`): each shard discovers
//!   cross-shard candidate edges *as items arrive*, querying frozen
//!   read-only snapshots of the other shards' HNSWs (refreshed at every
//!   merge epoch, and optionally every `bridge_refresh` items). Candidates
//!   are buffered per shard under the same α·n flush discipline as
//!   FISHDBC's local candidate buffer.
//! * **Delta merge** ([`Engine::cluster`], `engine/merge.rs`): after a
//!   flush barrier, a *catch-up* pass bridges the items no shard could
//!   cover at insert time and re-searches the bounded same-epoch window
//!   (so a pair whose two endpoints arrived inside one epoch window is
//!   still found — see `engine/merge.rs`), then Kruskal re-runs over the
//!   cached global forest ∪ the forests of changed shards ∪ changed bridge
//!   sets. The shared [`pipeline::Pipeline`] turns the forest into the
//!   global clustering, short-circuiting condense/extract when the forest
//!   is unchanged. Recluster cost therefore scales with the *delta* since
//!   the previous epoch, not with total n — the paper's "lightweight
//!   computation to update the clustering when few items are added".
//! * **Merge invariants**: (1) each shard's forest is an MSF of its local
//!   candidate graph (Algorithm 1, per shard); (2) Kruskal over the union
//!   of part-MSFs plus extra edges is an MSF of the union graph (the same
//!   lemma that justifies UPDATE_MST), and the cached global MSF is a
//!   lossless summary of every part that did not change (cycle property on
//!   a monotonically growing union graph); (3) the bridge set is bounded by
//!   `n · bridge_k · bridge_fanout` offers, deduplicated on canonical
//!   `(min, max)` endpoint keys and compacted to O(n) by Kruskal.
//! * **Incremental deletion** ([`Engine::remove_batch`]): removals are
//!   hash-routed like ingest and tombstone their item in place — the HNSW
//!   node stays routable but is never returned from any search, its core
//!   is invalidated, affected neighbor cores are recomputed, and the
//!   deleted global id labels `-1` in every epoch from then on. A shard
//!   with deletions in the window flips its change stamp (the cached-MSF
//!   lemma assumes monotone growth — see `engine/merge.rs`), and crossing
//!   [`EngineConfig::compact_at`] rebuilds the shard without tombstones.
//! * **Serving** ([`Engine::label`], `engine/query.rs`): answer "which
//!   cluster would this item join?" against the latest published epoch via
//!   HNSW search across all shards, without mutating any state.
//!   [`Engine::latest`] hands out the current epoch as an immutable
//!   `Arc<EngineSnapshot>` — the slot's mutex is held only for the Arc
//!   clone, never while merging, so serving never blocks behind a merge.
//! * **Auto-recluster**: with `EngineConfig::recluster_every > 0` a
//!   background thread re-merges after that many new items — the engine
//!   analog of the coordinator's `recluster_every` — so `latest()` is a
//!   complete serving loop: ingest keeps streaming, epochs keep
//!   publishing, queries never wait.
//! * **Persistence**: `Engine::save`/`Engine::load` (implemented in
//!   [`crate::persist`]) write a versioned container of every shard's full
//!   FISHDBC state plus the global id maps, and — since v2 — the pipeline
//!   epoch state (bridge buffers, coverage watermarks, cached global MSF),
//!   so a restarted engine reclusters incrementally instead of from
//!   scratch. Generic engines persist through the same container via
//!   [`Engine::save_with`]/[`Engine::load_with`] and a caller-supplied
//!   item codec.

pub mod merge;
pub mod pipeline;
pub mod query;
pub(crate) mod shard;

use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::distances::{Counting, Item, Metric, MetricKind};
use crate::durable::DurabilitySink;
use crate::fishdbc::{FishdbcParams, FishdbcStats};
use crate::hdbscan::Clustering;
use crate::obs::{
    export, CounterId, GaugeId, HistId, HistSnapshot, JournalEntry,
    JournalEvent, MetricsServer, Registry, RegistrySnapshot,
};
use crate::util::fasthash::{FastHasher, FastMap, FastSet};
use merge::{mask_deleted, MergeState};
use pipeline::{PipelineRun, PipelineStats};
use shard::{
    compact_shard, BridgeCtxSeed, BridgeState, Shard, ShardCmd, ShardSnap,
    ShardState, Snaps,
};

pub use crate::hdbscan::ExtractionMode;
pub use pipeline::ExtractionParams;

/// Deterministic content hash for shard routing: the same item always
/// hashes to the same value, across threads, processes and restarts (the
/// hasher is seed-free), so the same stream is always partitioned the same
/// way — including when it resumes on top of a persisted engine.
///
/// Implemented for every `T: Hash` via a blanket impl (user types get it
/// with `#[derive(Hash)]`; element vectors like `Vec<u32>` and `String`
/// already qualify). [`Item`] routes through its manual `Hash`
/// impl, whose write sequence is frozen for persisted-engine
/// compatibility.
///
/// Routing is a partitioning heuristic: *which* shard an item lands in
/// never affects correctness, only that identical streams partition
/// identically (determinism, tests) and that the partition is uniform
/// (per-shard density estimates mirror the global ones).
pub trait ShardKey {
    /// The routing hash (shard = `shard_key() % S`).
    fn shard_key(&self) -> u64;
}

impl<T: Hash + ?Sized> ShardKey for T {
    fn shard_key(&self) -> u64 {
        let mut h = FastHasher::default();
        self.hash(&mut h);
        h.finish()
    }
}

/// Items the sharded engine can ingest: cloneable (the copy-on-write
/// snapshot machinery), sendable across shard threads, and content-hash
/// routable. `approx_heap_bytes` only feeds the snapshot bytes-copied
/// accounting (`--stats`, the `snapshot_refresh` bench) — the default 0 is
/// always safe.
///
/// Implement it with an empty body for any `Hash + Clone + Send + Sync`
/// type:
///
/// ```
/// # use fishdbc::engine::EngineItem;
/// #[derive(Clone, Hash)]
/// struct Fingerprint(Vec<u64>);
/// impl EngineItem for Fingerprint {}
/// ```
pub trait EngineItem: Clone + Send + Sync + ShardKey + 'static {
    /// Approximate heap bytes of one item (snapshot accounting only).
    fn approx_heap_bytes(&self) -> usize {
        0
    }
}

impl EngineItem for Item {
    fn approx_heap_bytes(&self) -> usize {
        self.approx_bytes()
    }
}

impl EngineItem for String {
    fn approx_heap_bytes(&self) -> usize {
        self.len()
    }
}

impl<X: Hash + Clone + Send + Sync + 'static> EngineItem for Vec<X> {
    fn approx_heap_bytes(&self) -> usize {
        self.len() * std::mem::size_of::<X>()
    }
}

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Per-shard FISHDBC parameters (shared by every shard).
    pub fishdbc: FishdbcParams,
    /// Number of shards S (worker threads); 1 reproduces the single-core
    /// path exactly.
    pub shards: usize,
    /// Minimum cluster size for automatic snapshots (auto-recluster and
    /// the lazy extraction [`Engine::label`] runs when none exists yet).
    pub mcs: usize,
    /// Nearest remote neighbors per (item, remote shard) in the bridge
    /// search.
    pub bridge_k: usize,
    /// How many *other* shards each item is bridged against (clamped to
    /// S-1; rotated per item so all shard pairs are covered).
    pub bridge_fanout: usize,
    /// Per-shard command-queue bound (backpressure depth), in batches.
    pub queue_depth: usize,
    /// Re-merge automatically after this many new items (0 = never): the
    /// engine's serving loop. Each auto merge publishes a new epoch for
    /// [`Engine::latest`] and refreshes the frozen bridge snapshots.
    pub recluster_every: usize,
    /// Additionally refresh the frozen remote snapshots every this many
    /// accepted items (0 = only at merges). This is a true *partial*
    /// refresh: captures are chunked copy-on-write (see `engine::shard`'s
    /// snapshot-lifecycle notes), republishing every chunk untouched since
    /// the previous capture and copying only the dirty ones — O(Δ), not
    /// O(n) — so small values are affordable mid-epoch. Smaller values
    /// tighten the insert-time bridge freshness window.
    pub bridge_refresh: usize,
    /// Per-shard compaction threshold for incremental deletion: when a
    /// shard's tombstone ratio (`tombstoned / stored`) exceeds this after
    /// a removal, the shard is rebuilt without its tombstones (survivors
    /// replayed through a fresh HNSW; global ids stay stable, local ids
    /// remap — see the deletion-lifecycle notes in `engine::shard`).
    /// 0 disables compaction (tombstones accumulate; searches then route
    /// through ever more dead nodes, so only disable it for tests).
    pub compact_at: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            fishdbc: FishdbcParams::default(),
            shards: 4,
            mcs: 10,
            bridge_k: 3,
            bridge_fanout: 3,
            queue_depth: 16,
            recluster_every: 0,
            bridge_refresh: 0,
            compact_at: 0.25,
        }
    }
}

/// A merged global clustering with provenance: one published *epoch* of
/// the recluster pipeline. Immutable; shared as `Arc` by the serving loop.
/// Item-type agnostic — the same struct serves every `Engine<T, M>`.
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    /// Merge epoch (monotone; 1 = first merge).
    pub epoch: u64,
    /// Global clustering; labels are indexed by global id = arrival order.
    pub clustering: Clustering,
    /// Items covered by this snapshot.
    pub n_items: usize,
    /// Shards merged.
    pub n_shards: usize,
    /// Global ids deleted so far (cumulative): every one of them labels
    /// `-1` in this and every later epoch. `n_items` counts survivors
    /// only, so `labels.len()` can exceed `n_items` — deleted ids keep
    /// their (noise) label slots, preserving index alignment with the
    /// input stream.
    pub n_deleted: usize,
    /// Cross-shard bridge edges offered to *this* merge (deduplicated;
    /// delta merges only offer changed shards' bridge sets).
    pub n_bridge_edges: usize,
    /// Edges in the merged global forest.
    pub n_msf_edges: usize,
    /// Shards whose forest or bridge set changed since the previous epoch
    /// (== `n_shards` on a from-scratch merge).
    pub n_changed_shards: usize,
    /// Seconds of catch-up bridge search in this merge.
    pub bridge_secs: f64,
    /// Seconds of the global Kruskal pass.
    pub kruskal_secs: f64,
    /// Back-half stage breakdown (dendrogram/condense/extract + cache
    /// hits) from the shared pipeline.
    pub stages: PipelineRun,
    /// Seconds spent on the whole merge + extraction.
    pub extract_secs: f64,
}

/// One node of the condensed cluster hierarchy, in the flat form the
/// hierarchy-as-a-service surface exports ([`EngineSnapshot::tree`], the
/// `Tree` wire frame). Node ids are the condensed tree's own cluster ids
/// (`n_points` = root, children ascending), so they are stable for the
/// lifetime of the epoch: every extraction of the same epoch selects
/// among exactly these ids.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TreeNode {
    /// Cluster id (`>= n_points`; `n_points` itself is the root).
    pub id: u32,
    /// Parent cluster id; the root points at itself.
    pub parent: u32,
    /// Density λ at which this cluster is born (0 for the root).
    pub lambda_birth: f64,
    /// Excess-of-Mass stability (the flat-cut selection score).
    pub stability: f64,
    /// Points under the node at birth (root: the label-space size, which
    /// includes deleted slots).
    pub size: u32,
}

impl EngineSnapshot {
    /// The epoch's condensed hierarchy as flat nodes with stable ids —
    /// the read side of hierarchy-as-a-service. Derived entirely from the
    /// snapshot's cached condensed tree: no locks, no distance calls.
    pub fn tree(&self) -> Vec<TreeNode> {
        let t = &self.clustering.condensed;
        let root = t.root();
        let mut nodes: Vec<TreeNode> = (0..t.n_cluster_ids as u32)
            .map(|i| TreeNode {
                id: root + i,
                parent: root + i,
                lambda_birth: 0.0,
                stability: 0.0,
                size: 0,
            })
            .collect();
        if nodes.is_empty() {
            return nodes;
        }
        nodes[0].size = t.n_points as u32;
        for r in &t.rows {
            if (r.child as usize) >= t.n_points {
                let i = (r.child - root) as usize;
                nodes[i].parent = r.parent;
                nodes[i].size = r.size;
            }
        }
        let birth = t.birth_lambdas();
        let stab = t.stabilities();
        for (i, node) in nodes.iter_mut().enumerate() {
            node.lambda_birth = birth[i];
            node.stability = stab[i];
        }
        nodes
    }
}

/// The result of one parameterized extraction ([`Engine::relabel_at`]):
/// a full labeling pinned to one published epoch's cached forest.
#[derive(Clone, Debug)]
pub struct Relabeling {
    /// Epoch (= cached global forest) the labeling was extracted from.
    pub epoch: u64,
    /// The extraction parameters that produced it.
    pub params: ExtractionParams,
    /// Global labeling under `params` (same label-space alignment as
    /// [`EngineSnapshot::clustering`]; deleted ids stay `-1`).
    pub clustering: Clustering,
    /// Whether the bounded extraction memo answered without recomputing.
    pub memo_hit: bool,
    /// End-to-end wall seconds (memo lookup included).
    pub secs: f64,
}

/// Counters aggregated across shards.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Items stored (sum over shards; includes live tombstones, excludes
    /// compacted-away deletions).
    pub items: usize,
    /// Global ids removed so far (cumulative across the engine's life,
    /// survives compaction and persistence).
    pub removed_items: usize,
    /// Tombstones still physically present (removed but not yet
    /// compacted; `items - tombstoned_items` is the live count).
    pub tombstoned_items: usize,
    /// Shard compactions run (tombstone ratio crossed
    /// [`EngineConfig::compact_at`]).
    pub compactions: u64,
    /// Distance evaluations on the *insert* path (sum of the shards' HNSW
    /// construction counters — the subset of [`EngineStats::metric_calls`]
    /// the paper's build columns report).
    pub dist_calls: u64,
    /// Every evaluation of the user metric, on every path — insertion,
    /// insert-time bridge search, merge catch-up, online labels — from the
    /// engine-wide shared [`Counting`] wrapper. The paper's cost model
    /// (Figs 1–2): runtimes are dominated by, and measured in, distance
    /// calls. Always ≥ `dist_calls`: a reloaded engine resumes this
    /// counter from the persisted insert-path totals (prior search-path
    /// calls are not persisted).
    pub metric_calls: u64,
    /// Batched distance dispatches on the insert path (sum of the shards'
    /// HNSW counters). Each dispatch covered many of the `dist_calls`
    /// pairwise evaluations via [`Metric::distance_batch`]
    /// (`crate::distances::Metric::distance_batch`); CI asserts this stays
    /// > 0 so the batch hot path cannot silently regress to scalar.
    pub batch_evals: u64,
    /// Batches processed (sum over shards).
    pub batches: u64,
    /// Critical-path build time: the busiest shard's insert wall time.
    pub build_secs: f64,
    /// Per-shard FISHDBC counters.
    pub shard_stats: Vec<FishdbcStats>,
    /// Bridge edges currently buffered (compacted forests + live buffers).
    pub bridge_edges: usize,
    /// Bridge edges discovered at insert time (vs merge catch-up).
    pub bridge_insert_edges: u64,
    /// Items whose bridge queries already ran (sum of coverage watermarks).
    pub bridge_covered: usize,
    /// Items covered by the insert-time walk (this process).
    pub bridge_insert_items: u64,
    /// Items the merge catch-up first-covered (this process). The two
    /// walks share each shard's ordered watermark, so for an engine that
    /// was not reloaded mid-run **and saw no compaction**, `bridge_covered
    /// == bridge_insert_items + bridge_catch_up_items` at any flushed
    /// quiescent point — first-pass coverage happens exactly once (a
    /// snapshot refresh that rewound a watermark would break it).
    /// Compaction remaps each watermark down to its surviving prefix
    /// count without rescaling these historical counters, so after churn
    /// the sum can legitimately exceed `bridge_covered`.
    pub bridge_catch_up_items: u64,
    /// Items the merge catch-up re-searched to close the same-epoch
    /// window: an item insert-covered against frozen snapshots is searched
    /// once more, against live states, at the next merge — so cross-shard
    /// pairs that both arrived inside one epoch window are never missed.
    /// Bounded per merge by the items inserted since the previous one.
    pub bridge_recheck_items: u64,
    /// α·n bridge-buffer compactions run.
    pub bridge_compactions: u64,
    /// Wall seconds shards spent on insert-time bridge queries.
    pub bridge_insert_secs: f64,
    /// Global merges run (published epochs).
    pub merges: u64,
    /// Shared pipeline counters (runs, short-circuits, stage seconds).
    pub pipeline: PipelineStats,
    /// WAL append/fsync/checkpoint failures so far (0 when no durability
    /// sink is installed). Non-zero means at least one batch was *not*
    /// made durable — see [`EngineStats::wal_last_error`].
    pub wal_errors: u64,
    /// Highest ingest watermark the WAL has journaled (0 when volatile).
    /// After a [`crate::durable::DurabilitySink::sync`] this is the
    /// crash-recovery floor: every id below it replays on restart.
    pub wal_watermark: u64,
    /// The most recent WAL/checkpoint error message, if any — surfaced
    /// here instead of being swallowed so drains and operators see it.
    pub wal_last_error: Option<String>,
}

/// Shared engine internals: everything the public handle, the shard
/// workers, and the background recluster thread need to see.
pub(crate) struct EngineInner<T, M> {
    config: EngineConfig,
    /// The user metric behind the engine-wide distance-call counter;
    /// every shard and every frozen snapshot holds a clone sharing the
    /// same counter cell.
    metric: Counting<M>,
    shards: Vec<Shard<T, M>>,
    snaps: Arc<Snaps<T, M>>,
    /// Engine-wide registry of deleted global ids (cumulative; shared with
    /// every shard worker for bridge-forest compaction). Lock order:
    /// shard state → bridge → deleted; always taken as a leaf.
    deleted: Arc<Mutex<FastSet<u32>>>,
    /// Next global id to assign (== items accepted so far).
    next_global: AtomicU64,
    /// Items covered by the most recent merge (auto-recluster trigger).
    merged_items: AtomicU64,
    /// Published merge epochs.
    epoch: AtomicU64,
    latest: Mutex<Option<Arc<EngineSnapshot>>>,
    pub(crate) merge: Mutex<MergeState>,
    /// Per-engine telemetry: counters, gauges, latency histograms, and
    /// the lifecycle journal (see [`crate::obs`]). Never global — each
    /// engine owns its own registry, so concurrent tests stay isolated.
    obs: Arc<Registry>,
    /// Baseline for [`Engine::stats_delta`]'s snapshot-and-diff window.
    window: Mutex<StatsWindow>,
    /// Write-ahead journaling seam (see [`crate::durable`]): when
    /// installed, every `add_batch` reserves its ids *through* the sink
    /// (so WAL order equals id order) and every `remove_batch` applies
    /// under the sink's mutex. `None` runs the historical volatile path.
    durability: Mutex<Option<Arc<dyn DurabilitySink<T>>>>,
    /// Shutdown flag + wakeup for the recluster thread.
    stop: Mutex<bool>,
    wake: Condvar,
}

/// Windowed-stats baseline: the registry snapshot (plus the out-of-
/// registry absolute counters) captured at the previous
/// [`Engine::stats_delta`] call.
struct StatsWindow {
    reg: RegistrySnapshot,
    metric_calls: u64,
}

/// Handle to a running sharded engine over items of type `T` under metric
/// `M`. Dropping it shuts the workers down.
///
/// The defaults are the framework instantiation — `Engine` with no type
/// arguments is `Engine<Item, MetricKind>`, the dynamic path the CLI,
/// datasets and persistence fixtures use. Typed users pass their own `T`
/// and any cloneable [`Metric<T>`] (a plain closure works):
///
/// ```no_run
/// use fishdbc::engine::{Engine, EngineConfig};
///
/// let metric = |a: &Vec<i64>, b: &Vec<i64>| {
///     a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum()
/// };
/// let engine = Engine::spawn(metric, EngineConfig::default());
/// engine.add_batch(vec![vec![0i64, 0], vec![1, 0], vec![90, 90]]);
/// let snap = engine.cluster(2);
/// println!("{:?}", snap.clustering.labels);
/// ```
pub struct Engine<T = Item, M = MetricKind> {
    inner: Arc<EngineInner<T, M>>,
    recluster: Option<JoinHandle<()>>,
}

impl<T: EngineItem, M: Metric<T> + Clone + 'static> Engine<T, M> {
    /// Spawn `config.shards` shard workers clustering items of type `T`
    /// under `metric`. The metric is cloned into every shard; wrap shared
    /// state in `Arc` if cloning it is expensive.
    pub fn spawn(metric: M, config: EngineConfig) -> Engine<T, M> {
        assert!(config.shards >= 1, "engine needs at least one shard");
        let metric = Counting::new(metric);
        let obs = Arc::new(Registry::new(config.shards));
        let snaps = Arc::new(Snaps::new(config.shards));
        let deleted = Arc::new(Mutex::new(FastSet::default()));
        let shards = (0..config.shards)
            .map(|id| {
                Shard::spawn(
                    id,
                    metric.clone(),
                    config.fishdbc,
                    config.queue_depth,
                    seed_ctx(&config, &snaps, &deleted, &obs),
                )
            })
            .collect();
        let mut merge_state = MergeState::new();
        merge_state.attach_registry(Arc::clone(&obs));
        let window = Mutex::new(StatsWindow {
            reg: obs.snapshot(),
            metric_calls: metric.calls(),
        });
        Engine::assemble(EngineInner {
            config,
            metric,
            shards,
            snaps,
            deleted,
            next_global: AtomicU64::new(0),
            merged_items: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
            latest: Mutex::new(None),
            merge: Mutex::new(merge_state),
            obs,
            window,
            durability: Mutex::new(None),
            stop: Mutex::new(false),
            wake: Condvar::new(),
        })
    }

    /// Reassemble an engine from reloaded shard states and pipeline epoch
    /// state (see [`Engine::load`](crate::persist)).
    pub(crate) fn from_resumed(
        metric: Counting<M>,
        config: EngineConfig,
        parts: Vec<(ShardState<T, M>, BridgeState)>,
        next_global: u64,
        mut merge_state: MergeState,
        epoch: u64,
    ) -> Engine<T, M> {
        let obs = Arc::new(Registry::new(config.shards));
        let snaps = Arc::new(Snaps::new(config.shards));
        let deleted: FastSet<u32> = parts
            .iter()
            .flat_map(|(st, _)| st.removed_globals.iter().copied())
            .collect();
        let deleted = Arc::new(Mutex::new(deleted));
        let shards = parts
            .into_iter()
            .enumerate()
            .map(|(id, (st, br))| {
                Shard::resume(
                    id,
                    st,
                    br,
                    config.queue_depth,
                    seed_ctx(&config, &snaps, &deleted, &obs),
                )
            })
            .collect();
        merge_state.attach_registry(Arc::clone(&obs));
        obs.inc(CounterId::Loads);
        obs.journal.push(
            obs.uptime_secs(),
            JournalEvent::Load { items: next_global as usize },
        );
        let window = Mutex::new(StatsWindow {
            reg: obs.snapshot(),
            metric_calls: metric.calls(),
        });
        Engine::assemble(EngineInner {
            config,
            metric,
            shards,
            snaps,
            deleted,
            next_global: AtomicU64::new(next_global),
            merged_items: AtomicU64::new(0),
            epoch: AtomicU64::new(epoch),
            latest: Mutex::new(None),
            merge: Mutex::new(merge_state),
            obs,
            window,
            durability: Mutex::new(None),
            stop: Mutex::new(false),
            wake: Condvar::new(),
        })
    }

    /// Wrap the inner state and start the background recluster thread when
    /// the serving loop is enabled.
    fn assemble(inner: EngineInner<T, M>) -> Engine<T, M> {
        let inner = Arc::new(inner);
        let recluster = if inner.config.recluster_every > 0 {
            let worker = Arc::clone(&inner);
            Some(
                std::thread::Builder::new()
                    .name("fishdbc-recluster".into())
                    .spawn(move || recluster_loop(&worker))
                    .expect("spawn recluster thread"),
            )
        } else {
            None
        };
        Engine { inner, recluster }
    }

    /// Hash-route a batch: assign dense global ids in arrival order, group
    /// by content hash ([`ShardKey`]), enqueue per shard (blocking when a
    /// shard's queue is full — backpressure). Items the metric rejects
    /// ([`Metric::check_item`], e.g. a dynamic [`MetricKind`] mismatch)
    /// panic here, in the caller, before touching any shard.
    pub fn add_batch(&self, items: Vec<T>) {
        self.inner.add_batch(items)
    }

    /// Non-blocking [`Engine::add_batch`]: admission-checked against each
    /// target shard's bounded command queue. When every routed shard has
    /// a free batch slot the whole batch is accepted exactly like
    /// `add_batch` (ids assigned, enqueued, recluster wake-up); when any
    /// queue is full the batch is rejected atomically — no ids consumed,
    /// nothing enqueued anywhere — and the items come back in `Err` so
    /// the caller can retry or shed load. This is the `Busy` path of
    /// `fishdbc serve`: a saturated engine answers immediately instead of
    /// wedging a connection-handler thread on backpressure.
    pub fn try_add_batch(&self, items: Vec<T>) -> Result<(), Vec<T>> {
        self.inner.try_add_batch(items)
    }

    /// Refresh the frozen remote snapshots the shards bridge against at
    /// insert time (also happens automatically at every merge and, when
    /// `bridge_refresh > 0`, on that item cadence).
    pub fn refresh_bridges(&self) {
        self.inner.refresh_snaps();
    }

    /// RELABEL: extract a full labeling under arbitrary [`ExtractionParams`]
    /// from the latest epoch's cached global forest — hierarchy-as-a-service.
    /// The hierarchy is built once per epoch; this call only re-runs the
    /// cheap selection stages (dendrogram and condensed-tree caches keyed by
    /// forest content, bounded extraction memo keyed by the full parameter
    /// tuple — see `engine::pipeline`'s extraction-lifecycle notes), so
    /// sweeping `mcs`/`eps`/mode over a pinned epoch adds **zero** distance
    /// calls: `EngineStats::metric_calls` is provably unchanged, because no
    /// stage downstream of the forest ever evaluates the metric.
    ///
    /// If no epoch exists yet, one merge runs first (same lazy-bootstrap
    /// rule as [`Engine::label`]). The result is pinned to the epoch whose
    /// forest answered it, which a concurrent merge cannot disturb:
    /// extraction runs under the merge lock against that epoch's cached
    /// forest and deletion mask.
    pub fn relabel_at(&self, params: ExtractionParams) -> Relabeling {
        self.inner.relabel_at(params)
    }

    /// Aggregated counters. Flushes first, so this doubles as an ingestion
    /// barrier (mirrors [`Coordinator::stats`](crate::coordinator)).
    pub fn stats(&self) -> EngineStats {
        self.inner.stats()
    }

    /// Windowed stats: everything that happened since the previous
    /// `stats_delta` call (or since spawn, on the first call), as rates
    /// plus per-window latency quantiles. Cumulative totals
    /// ([`Engine::stats`]) are useless for a long-running serving
    /// process — after hours of uptime they average over everything that
    /// ever happened; this is the per-window view. Flushes first, like
    /// [`Engine::stats`], then advances the baseline.
    pub fn stats_delta(&self) -> StatsDelta {
        self.inner.flush();
        self.inner.refresh_gauges();
        let reg = self.inner.obs.snapshot();
        let metric_calls = self.inner.metric.calls();
        let mut base = self.inner.window.lock().unwrap();
        let window = reg.since(&base.reg);
        let secs = window.uptime_secs.max(1e-9);
        let delta = StatsDelta {
            window_secs: window.uptime_secs,
            items: window.counter(CounterId::IngestItems),
            items_per_sec: window.counter(CounterId::IngestItems) as f64
                / secs,
            metric_calls: metric_calls.saturating_sub(base.metric_calls),
            metric_calls_per_sec: metric_calls
                .saturating_sub(base.metric_calls)
                as f64
                / secs,
            merges: window.counter(CounterId::Merges),
            label_queries: window.counter(CounterId::LabelQueries),
            label_latency: *window.hist(HistId::Label),
            ingest_latency: *window.hist(HistId::IngestBatch),
            merge_latency: *window.hist(HistId::Merge),
            window,
        };
        *base = StatsWindow { reg, metric_calls };
        delta
    }

    /// The engine lifecycle journal: the most recent structured events
    /// (merges with cache kind and changed-shard counts, compactions,
    /// deletion windows, snapshot refreshes, save/load), oldest first.
    /// Bounded ring — see [`crate::obs::journal`].
    pub fn journal(&self) -> Vec<JournalEntry> {
        self.inner.obs.journal.entries()
    }

    /// The engine's telemetry registry (counters, gauges, histograms).
    pub fn registry(&self) -> &Registry {
        &self.inner.obs
    }

    /// The full machine-readable stats document (schema
    /// `fishdbc-stats-v1`; see EXPERIMENTS.md): engine counters, bridge
    /// and pipeline totals, every registry histogram's quantiles, and
    /// the journal tail. Flushes first. The CLI writes this via
    /// `--stats-json`.
    pub fn stats_json(&self) -> String {
        self.inner.stats_json(true)
    }

    /// Serve Prometheus text exposition (`GET /metrics`) and the JSON
    /// stats document (`GET /stats.json`) on `addr` (e.g.
    /// `127.0.0.1:9100`; port 0 picks a free port) until the returned
    /// server is dropped. Scrapes never take the flush barrier — they
    /// read the lock-free registry plus brief per-shard gauge reads — so
    /// scraping cannot stall ingest or merges. The server holds only a
    /// weak engine reference: after the engine is dropped, `/metrics`
    /// keeps answering from the registry's final totals and
    /// `/stats.json` turns 404.
    pub fn serve_metrics(
        &self,
        addr: &str,
    ) -> std::io::Result<MetricsServer> {
        let obs = Arc::clone(&self.inner.obs);
        let weak = Arc::downgrade(&self.inner);
        MetricsServer::serve(
            addr,
            Arc::new(move |path: &str| match path {
                "/metrics" => {
                    let mut extra_counters: Vec<(&str, &str, u64)> =
                        Vec::new();
                    if let Some(inner) = weak.upgrade() {
                        inner.refresh_gauges();
                        extra_counters.push((
                            "metric_calls",
                            "Distance metric evaluations on every path \
                             (the paper's cost model)",
                            inner.metric.calls(),
                        ));
                        extra_counters.push((
                            "items_accepted",
                            "Global ids assigned so far",
                            inner.next_global.load(Ordering::Relaxed),
                        ));
                    }
                    let extra_gauges = [(
                        "uptime_seconds",
                        "Seconds since the engine was spawned",
                        obs.uptime_secs(),
                    )];
                    Some((
                        export::render_prometheus(
                            &obs.snapshot(),
                            &extra_counters,
                            &extra_gauges,
                        ),
                        "text/plain; version=0.0.4",
                    ))
                }
                "/stats.json" => weak.upgrade().map(|inner| {
                    // relaxed read: no flush barrier on the scrape path
                    (inner.stats_json(false), "application/json")
                }),
                _ => None,
            }),
        )
    }
}

/// One [`Engine::stats_delta`] window: counts, rates, and latency
/// distributions for everything since the previous call.
#[derive(Clone, Debug)]
pub struct StatsDelta {
    /// Wall seconds the window spans.
    pub window_secs: f64,
    /// Items accepted in the window.
    pub items: u64,
    pub items_per_sec: f64,
    /// Distance metric evaluations in the window (the paper's cost
    /// model, windowed).
    pub metric_calls: u64,
    pub metric_calls_per_sec: f64,
    /// Epochs published in the window.
    pub merges: u64,
    /// `label()` queries served in the window.
    pub label_queries: u64,
    /// Windowed `label()` latency distribution.
    pub label_latency: HistSnapshot,
    /// Windowed `add_batch` latency distribution.
    pub ingest_latency: HistSnapshot,
    /// Windowed end-to-end merge latency distribution.
    pub merge_latency: HistSnapshot,
    /// The full windowed registry, for consumers that need more than the
    /// named fields above.
    pub window: RegistrySnapshot,
}

/// Incremental deletion (removal is keyed by item *value*, so it needs
/// `T: PartialEq` on top of the [`EngineItem`] ingest bounds).
impl<T: EngineItem + PartialEq, M: Metric<T> + Clone + 'static> Engine<T, M> {
    /// Remove one item by value. Returns whether a stored live copy was
    /// found (and tombstoned). See [`Engine::remove_batch`].
    pub fn remove(&self, item: &T) -> bool {
        self.remove_batch(std::slice::from_ref(item)) == 1
    }

    /// REMOVE: incrementally delete items by value — the churn half of the
    /// paper's incremental axis (sliding windows, TTL expiry, erasure
    /// requests). Targets are hash-routed to their shard exactly like
    /// ingest, then matched against the stored live items (full 64-bit
    /// [`ShardKey`] prefilter, `PartialEq` confirm); each target
    /// tombstones at most one live copy, duplicates in the batch remove
    /// one copy each. Returns how many items were actually removed
    /// (absent or already-removed targets are no-ops).
    ///
    /// Effects are immediate on the serving path: a removed item stops
    /// being returned from [`Engine::label`]'s neighbor searches at once
    /// (its HNSW node stays routable but filtered), and its global id
    /// labels `-1` in every epoch published from now on. The clustering
    /// itself updates at the next [`Engine::cluster`] merge, where shards
    /// with deletions in the window pay a full local re-derivation while
    /// untouched shards keep the O(Δ) cached path; shards whose tombstone
    /// ratio crosses [`EngineConfig::compact_at`] are rebuilt without
    /// their tombstones (see the deletion-lifecycle notes in
    /// `engine::shard`).
    ///
    /// Flushes first, so every item from an `add_batch` that returned
    /// before this call is a candidate for matching.
    pub fn remove_batch(&self, items: &[T]) -> usize {
        self.inner.remove_batch(items)
    }
}

// No bounds on this impl (or on `Drop`): shutdown and the cheap accessors
// work for every instantiation, which is what lets `Drop` be unbounded.
impl<T, M> Engine<T, M> {
    pub fn config(&self) -> &EngineConfig {
        &self.inner.config
    }

    /// The user metric (unwrapped from the engine's counting layer).
    pub fn metric(&self) -> &M {
        self.inner.metric.inner()
    }

    pub fn n_shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Items accepted so far (including any still queued behind a shard).
    pub fn len(&self) -> usize {
        self.inner.next_global.load(Ordering::Relaxed) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Published merge epochs so far (0 = nothing merged yet).
    pub fn epoch(&self) -> u64 {
        self.inner.epoch.load(Ordering::Relaxed)
    }

    pub(crate) fn inner(&self) -> &EngineInner<T, M> {
        &self.inner
    }

    /// Ingestion barrier: wait until every shard has drained its queue and
    /// folded buffered candidate edges into its local MSF.
    pub fn flush(&self) {
        self.inner.flush()
    }

    /// Latest published epoch, non-blocking: the slot mutex is held only
    /// for an `Arc` clone, so serving threads never wait behind a merge.
    pub fn latest(&self) -> Option<Arc<EngineSnapshot>> {
        self.inner.latest()
    }

    /// Every global id ever deleted, ascending. Deleted ids label `-1`
    /// in all published epochs, forever.
    #[doc(hidden)]
    pub fn deleted_globals(&self) -> Vec<u32> {
        self.inner.deleted_globals()
    }

    /// Attach a durability sink (the WAL): from now on every accepted
    /// `add_batch`/`remove_batch` is journaled *before* it becomes
    /// visible to the pipeline. Installed once by
    /// [`crate::durable::Durable::open`] **after** recovery replay has
    /// finished, so replayed batches are never re-journaled. The sink is
    /// handed this engine's registry so WAL metrics land in the same
    /// scrape.
    pub fn install_durability(
        &self,
        sink: Arc<dyn crate::durable::DurabilitySink<T>>,
    ) {
        sink.bind_registry(Arc::clone(&self.inner.obs));
        *self.inner.durability.lock().unwrap() = Some(sink);
    }

    /// Fsync the attached WAL, returning the ingest watermark that is
    /// now durable. `None` when no durability sink is installed (the
    /// volatile engine); `Some(Err)` when the fsync — or any append
    /// since the previous sync — failed, meaning the most recent batches
    /// must NOT be acked as durable.
    pub fn durability_sync(&self) -> Option<std::io::Result<u64>> {
        let sink = self.inner.durability.lock().unwrap().clone();
        sink.map(|s| s.sync())
    }

    /// Shut down, waiting for the recluster thread and every shard worker
    /// to finish outstanding work.
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    /// Signal + join every background thread. Runs from both `shutdown`
    /// and `Drop` — including during a panic unwind — so it must tolerate
    /// poisoned locks (a panicking test must not leak the recluster
    /// thread, and must not abort on a poisoned-lock double panic).
    fn stop_threads(&mut self) {
        {
            let mut stop =
                self.inner.stop.lock().unwrap_or_else(|e| e.into_inner());
            *stop = true;
        }
        self.inner.wake.notify_all();
        if let Some(h) = self.recluster.take() {
            let _ = h.join();
        }
        for shard in &self.inner.shards {
            shard.shutdown();
        }
    }
}

impl<T, M> Drop for Engine<T, M> {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

fn seed_ctx<T, M>(
    config: &EngineConfig,
    snaps: &Arc<Snaps<T, M>>,
    deleted: &Arc<Mutex<FastSet<u32>>>,
    obs: &Arc<Registry>,
) -> BridgeCtxSeed<T, M> {
    // Staleness bound for insert-time coverage: with a refresh cadence
    // configured, tolerate up to two refresh windows of remote growth;
    // otherwise (manual reclustering at unknown cadence) keep it tight so
    // long gaps between merges fall back to the catch-up search instead of
    // piling re-search debt onto the next merge.
    let cadence = config.recluster_every.max(config.bridge_refresh);
    let lag_limit = if cadence > 0 {
        cadence.saturating_mul(2)
    } else {
        config.fishdbc.min_pts.max(1) * 8
    };
    BridgeCtxSeed {
        n_shards: config.shards,
        bridge_k: config.bridge_k,
        bridge_fanout: config.bridge_fanout,
        alpha: config.fishdbc.alpha,
        lag_limit,
        snaps: Arc::clone(snaps),
        deleted: Arc::clone(deleted),
        obs: Arc::clone(obs),
    }
}

/// The background serving loop: re-merge whenever `recluster_every` new
/// items have arrived since the last published epoch. Woken eagerly by
/// `add_batch` and on shutdown; polls as a fallback so a missed wakeup
/// only delays an epoch, never loses one.
fn recluster_loop<T: EngineItem, M: Metric<T> + Clone + 'static>(
    inner: &EngineInner<T, M>,
) {
    let every = inner.config.recluster_every as u64;
    loop {
        {
            let guard = inner.stop.lock().unwrap();
            if *guard {
                break;
            }
            let (guard, _) = inner
                .wake
                .wait_timeout(guard, Duration::from_millis(25))
                .unwrap();
            if *guard {
                break;
            }
        }
        let n = inner.next_global.load(Ordering::Relaxed);
        let merged = inner.merged_items.load(Ordering::Relaxed);
        if n >= merged + every {
            inner.cluster(inner.config.mcs);
        }
    }
}

impl<T, M> EngineInner<T, M> {
    pub(crate) fn shard_handles(&self) -> &[Shard<T, M>] {
        &self.shards
    }

    pub(crate) fn config(&self) -> &EngineConfig {
        &self.config
    }

    pub(crate) fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub(crate) fn latest(&self) -> Option<Arc<EngineSnapshot>> {
        self.latest.lock().unwrap().clone()
    }

    /// Install a snapshot unless a fresher epoch is already published —
    /// two racing `cluster()` calls must not let the slower, older merge
    /// win.
    pub(crate) fn set_latest(&self, snap: Arc<EngineSnapshot>) {
        // accepted ids covered by this epoch (survivors + deleted slots):
        // the auto-recluster trigger compares against ids *assigned*
        self.merged_items
            .fetch_max((snap.n_items + snap.n_deleted) as u64, Ordering::Relaxed);
        let mut slot = self.latest.lock().unwrap();
        if slot.as_ref().map_or(true, |old| old.epoch <= snap.epoch) {
            *slot = Some(snap);
            self.obs.mark_publish();
        }
    }

    /// The engine's telemetry registry.
    pub(crate) fn obs(&self) -> &Registry {
        &self.obs
    }

    /// Claim the next merge epoch number.
    pub(crate) fn next_epoch(&self) -> u64 {
        self.epoch.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub(crate) fn flush(&self) {
        let (tx, rx) = std::sync::mpsc::sync_channel(self.shards.len());
        for shard in &self.shards {
            shard.send(ShardCmd::Flush(tx.clone()));
        }
        drop(tx);
        for _ in 0..self.shards.len() {
            let _ = rx.recv();
        }
    }

    /// Every deleted global id, ascending (tests and the conformance
    /// oracle; cheap relative to any merge).
    pub(crate) fn deleted_globals(&self) -> Vec<u32> {
        let mut v: Vec<u32> =
            self.deleted.lock().unwrap().iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// The deleted-global-id registry (leaf lock; see the field docs).
    pub(crate) fn deleted_registry(&self) -> &Mutex<FastSet<u32>> {
        &self.deleted
    }

    /// Atomically reserve `n` consecutive global ids, returning the base.
    /// Panics (without consuming ids) when the u32 id space would
    /// overflow — the dense-id invariant persistence relies on.
    fn reserve_ids(&self, n: usize) -> u64 {
        self.next_global
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                cur.checked_add(n as u64)
                    .filter(|&next| next <= u32::MAX as u64)
            })
            .expect("engine capacity (u32 item ids) exceeded")
    }
}

impl<T: EngineItem, M: Metric<T> + Clone + 'static> EngineInner<T, M> {
    pub(crate) fn add_batch(&self, items: Vec<T>) {
        if items.is_empty() {
            return;
        }
        // ingest latency as the caller experiences it: routing, enqueue,
        // and any backpressure blocking, but not the async shard insert
        let t_ingest = Instant::now();
        // validate before assigning ids: a rejected batch must not leak
        // global ids (persistence requires ids to be dense)
        for item in &items {
            self.metric.check_item(item);
        }
        self.commit_batch(items, false, t_ingest);
    }

    /// Non-blocking admission twin of [`EngineInner::add_batch`]: accept
    /// the batch only if every routed shard has a free slot in its
    /// bounded command queue, otherwise hand the items back untouched.
    /// All-or-nothing — on `Err` no global ids were consumed and nothing
    /// was enqueued anywhere, so the dense-id invariant persistence
    /// relies on survives rejected batches.
    pub(crate) fn try_add_batch(&self, items: Vec<T>) -> Result<(), Vec<T>> {
        if items.is_empty() {
            return Ok(());
        }
        let t_ingest = Instant::now();
        for item in &items {
            self.metric.check_item(item);
        }
        let s = self.shards.len();
        let mut touched = vec![false; s];
        for item in &items {
            let si =
                if s == 1 { 0 } else { (item.shard_key() % s as u64) as usize };
            touched[si] = true;
        }
        // reserve a queue slot on every target shard, backing already
        // taken ones out again on the first refusal (atomic admission)
        let mut reserved: Vec<usize> = Vec::new();
        for (si, hit) in touched.iter().enumerate() {
            if !hit {
                continue;
            }
            if self.shards[si].try_reserve_batch_slot(self.config.queue_depth)
            {
                reserved.push(si);
            } else {
                for &r in &reserved {
                    self.shards[r].release_batch_slot();
                }
                return Err(items);
            }
        }
        self.commit_batch(items, true, t_ingest);
        Ok(())
    }

    /// Shared commit tail for both ingest paths: id assignment, routing,
    /// enqueue, recluster wake-up, bridge refresh, telemetry. With
    /// `slots_reserved` the per-shard queue slots were already taken by
    /// the non-blocking admission check; otherwise [`Shard::send`] takes
    /// them itself and blocks on a full queue (backpressure).
    fn commit_batch(&self, items: Vec<T>, slots_reserved: bool, t_ingest: Instant) {
        let s = self.shards.len();
        // With a durability sink installed, the id reservation runs
        // inside `log_add`, under the sink's mutex and before the record
        // append — WAL order provably equals global-id order, which is
        // what makes replay-in-file-order correct.
        let sink = self.durability.lock().unwrap().clone();
        let mut reserve = |n: usize| self.reserve_ids(n);
        let base = match &sink {
            Some(sink) => sink.log_add(&items, &mut reserve),
            None => reserve(items.len()),
        };
        let n_items = items.len() as u64;
        let mut routed: Vec<Vec<(u32, T)>> = (0..s).map(|_| Vec::new()).collect();
        for (i, item) in items.into_iter().enumerate() {
            let shard =
                if s == 1 { 0 } else { (item.shard_key() % s as u64) as usize };
            routed[shard].push((base as u32 + i as u32, item));
        }
        for (shard, batch) in self.shards.iter().zip(routed) {
            if batch.is_empty() {
                continue;
            }
            if slots_reserved {
                shard.send_reserved(batch);
            } else {
                shard.send(ShardCmd::AddBatch(batch));
            }
        }
        // wake the serving loop when an epoch is due
        let next = base + n_items;
        if self.config.recluster_every > 0
            && next >= self.merged_items.load(Ordering::Relaxed)
                + self.config.recluster_every as u64
        {
            self.wake.notify_all();
        }
        // optional mid-epoch snapshot refresh for insert-time bridging
        let refresh = self.config.bridge_refresh as u64;
        if refresh > 0 && base / refresh != next / refresh {
            self.refresh_snaps();
        }
        self.obs.inc(CounterId::IngestBatches);
        self.obs.counter(CounterId::IngestItems).add(n_items);
        self.obs.record(HistId::IngestBatch, t_ingest.elapsed());
    }

    /// Parameterized extraction against the latest epoch's cached forest
    /// (see [`Engine::relabel_at`] for the contract). Bootstraps the first
    /// epoch if none exists; after that the whole call runs under the
    /// merge lock, touching only the pipeline's tree caches — never a
    /// shard, never the metric.
    pub(crate) fn relabel_at(&self, params: ExtractionParams) -> Relabeling {
        // extraction needs a published forest: bootstrap the first epoch
        // (fresh engine), or re-stamp one on a resumed engine whose
        // persisted cache predates this process's epoch bookkeeping
        if self.merge.lock().unwrap().last_epoch == 0 {
            self.cluster(self.config.mcs);
        }
        let t0 = Instant::now();
        let mut ms = self.merge.lock().unwrap();
        let MergeState { pipeline, cache, last_epoch, last_removed, .. } =
            &mut *ms;
        let cache = cache.as_ref().expect("cluster() always leaves a cache");
        let (mut clustering, run) =
            pipeline.extract_at(cache.global.edges(), cache.n, params, false);
        let epoch = *last_epoch;
        let memo_hit = run.reused_clustering;
        mask_deleted(&mut clustering.labels, last_removed);
        drop(ms);
        let secs = t0.elapsed().as_secs_f64();
        self.obs.journal.push(
            self.obs.uptime_secs(),
            JournalEvent::ExtractionEnd {
                epoch,
                mcs: params.mcs,
                eps: params.eps,
                mode: params.mode.name(),
                cache_hit: memo_hit,
            },
        );
        Relabeling { epoch, params, clustering, memo_hit, secs }
    }

    /// Refresh every shard's frozen snapshot from its live state (taking
    /// each read lock briefly, one shard at a time).
    pub(crate) fn refresh_snaps(&self) {
        let t0 = Instant::now();
        let mut refreshed = 0usize;
        for (t, shard) in self.shards.iter().enumerate() {
            let snap = {
                let st = shard.state.read().unwrap();
                if self.snap_is_current(t, &st) {
                    continue;
                }
                ShardSnap::capture(&st)
            };
            self.snaps.set(t, Arc::new(snap));
            refreshed += 1;
        }
        if refreshed > 0 {
            self.obs.record(HistId::SnapshotCapture, t0.elapsed());
            self.obs.inc(CounterId::SnapshotRefreshes);
            self.obs.journal.push(
                self.obs.uptime_secs(),
                JournalEvent::SnapshotRefresh { shards: refreshed },
            );
        }
    }

    /// Refresh snapshots from already-held state views (the merge path,
    /// which holds every read guard anyway). No journal entry: the
    /// enclosing merge records its own `MergeEnd` event.
    pub(crate) fn refresh_snaps_from(&self, states: &[&ShardState<T, M>]) {
        let t0 = Instant::now();
        let mut refreshed = 0usize;
        for (t, st) in states.iter().enumerate() {
            if self.snap_is_current(t, st) {
                continue;
            }
            self.snaps.set(t, Arc::new(ShardSnap::capture(st)));
            refreshed += 1;
        }
        if refreshed > 0 {
            self.obs.record(HistId::SnapshotCapture, t0.elapsed());
        }
    }

    /// A shard snapshot carrying the state's current version stamp is
    /// content-identical to it, so re-capturing would only burn the
    /// pointer clones. (Comparing item *counts* was enough while the
    /// stores only grew; a removal changes content without changing the
    /// count, so the stamp is explicit now.)
    fn snap_is_current(&self, t: usize, st: &ShardState<T, M>) -> bool {
        self.snaps.get(t).is_some_and(|sn| sn.version == st.version)
    }

    pub(crate) fn stats(&self) -> EngineStats {
        self.stats_with(true)
    }

    /// Aggregate counters; `flush` gates the ingestion barrier. The
    /// metrics scrape path passes `false` so an HTTP scrape can never
    /// stall behind a busy shard queue.
    pub(crate) fn stats_with(&self, flush: bool) -> EngineStats {
        if flush {
            self.flush();
        }
        self.refresh_gauges();
        let mut stats = EngineStats::default();
        for shard in &self.shards {
            {
                let st = shard.state.read().unwrap();
                let fs = st.f.stats();
                stats.items += fs.items;
                stats.removed_items += st.removed_globals.len();
                stats.tombstoned_items += fs.tombstoned;
                stats.compactions += st.compactions;
                stats.dist_calls += fs.dist_calls;
                stats.batch_evals += fs.batch_evals;
                stats.batches += st.batches;
                stats.build_secs = stats.build_secs.max(st.build_secs);
                stats.shard_stats.push(fs);
            }
            let br = shard.bridge.lock().unwrap();
            stats.bridge_edges += br.n_edges();
            stats.bridge_insert_edges += br.insert_edges;
            stats.bridge_covered += br.covered;
            stats.bridge_insert_items += br.insert_items;
            stats.bridge_catch_up_items += br.catch_up_items;
            stats.bridge_recheck_items += br.recheck_items;
            stats.bridge_compactions += br.compactions;
            stats.bridge_insert_secs += br.insert_secs;
        }
        let ms = self.merge.lock().unwrap();
        stats.merges = ms.merges;
        stats.pipeline = ms.pipeline.stats();
        drop(ms);
        // fold the engine-wide counters into the stats views: the chunked
        // capture counters and the shared distance-call total
        let (captures, copied, shared, bytes) = self.snaps.capture_stats();
        stats.pipeline.snapshot_captures = captures;
        stats.pipeline.snapshot_chunks_copied = copied;
        stats.pipeline.snapshot_chunks_shared = shared;
        stats.pipeline.snapshot_bytes_copied = bytes;
        stats.metric_calls = self.metric.calls();
        stats.pipeline.metric_calls = stats.metric_calls;
        stats.wal_errors = self.obs.counter(CounterId::WalErrors).get();
        if let Some(sink) = self.durability.lock().unwrap().clone() {
            stats.wal_watermark = sink.watermark();
            stats.wal_last_error = sink.last_error();
        }
        stats
    }

    /// Refresh the point-in-time gauges from live engine state: bridge
    /// coverage lag, per-shard tombstone ratios, live item count, epoch,
    /// epoch age. Takes each shard's read lock and bridge mutex briefly
    /// (same order as every other path); never the flush barrier.
    pub(crate) fn refresh_gauges(&self) {
        let mut stored = 0usize;
        let mut tombstoned = 0usize;
        let mut covered = 0usize;
        for (si, shard) in self.shards.iter().enumerate() {
            let (len, tombs) = {
                let st = shard.state.read().unwrap();
                (st.f.len(), st.f.n_tombstoned())
            };
            stored += len;
            tombstoned += tombs;
            let ratio = if len == 0 { 0.0 } else { tombs as f64 / len as f64 };
            self.obs.shard_tombstone_gauge(si).set(ratio);
            let br = shard.bridge.lock().unwrap();
            covered += br.covered.min(len);
        }
        self.obs
            .gauge(GaugeId::BridgeCoverageLag)
            .set(stored.saturating_sub(covered) as f64);
        self.obs
            .gauge(GaugeId::LiveItems)
            .set(stored.saturating_sub(tombstoned) as f64);
        self.obs
            .gauge(GaugeId::Epoch)
            .set(self.epoch.load(Ordering::Relaxed) as f64);
        self.obs
            .gauge(GaugeId::EpochAgeSecs)
            .set(self.obs.epoch_age_secs().unwrap_or(0.0));
    }

    /// Render the `fishdbc-stats-v1` JSON document (see EXPERIMENTS.md
    /// for the schema). `flush` gates the ingestion barrier: the CLI
    /// passes `true`, the HTTP scrape path `false`.
    pub(crate) fn stats_json(&self, flush: bool) -> String {
        let stats = self.stats_with(flush);
        let reg = self.obs.snapshot();
        let mut w = export::JsonW::new();
        w.obj(None)
            .str("schema", "fishdbc-stats-v1")
            .f64("uptime_secs", reg.uptime_secs)
            .u64("epoch", self.epoch.load(Ordering::Relaxed))
            .usize("items", stats.items)
            .usize("removed_items", stats.removed_items)
            .usize("tombstoned_items", stats.tombstoned_items)
            .u64("compactions", stats.compactions)
            .u64("metric_calls", stats.metric_calls)
            .u64("dist_calls", stats.dist_calls)
            .u64("batch_evals", stats.batch_evals)
            .u64("batches", stats.batches)
            .u64("merges", stats.merges)
            .f64("build_secs", stats.build_secs)
            .u64("wal_watermark", stats.wal_watermark);
        w.obj(Some("bridges"))
            .usize("edges", stats.bridge_edges)
            .u64("insert_edges", stats.bridge_insert_edges)
            .usize("covered", stats.bridge_covered)
            .u64("insert_items", stats.bridge_insert_items)
            .u64("catch_up_items", stats.bridge_catch_up_items)
            .u64("recheck_items", stats.bridge_recheck_items)
            .u64("compactions", stats.bridge_compactions)
            .f64("insert_secs", stats.bridge_insert_secs)
            .end_obj();
        w.obj(Some("pipeline"))
            .u64("runs", stats.pipeline.runs)
            .u64("short_circuits", stats.pipeline.short_circuits)
            .u64("extractions", stats.pipeline.extractions)
            .u64("extract_memo_hits", stats.pipeline.extract_memo_hits)
            .u64("dendrogram_reuses", stats.pipeline.dendrogram_reuses)
            .f64("dendrogram_secs", stats.pipeline.dendrogram_secs)
            .f64("condense_secs", stats.pipeline.condense_secs)
            .f64("extract_secs", stats.pipeline.extract_secs)
            .end_obj();
        w.obj(Some("snapshots"))
            .u64("captures", stats.pipeline.snapshot_captures)
            .u64("chunks_copied", stats.pipeline.snapshot_chunks_copied)
            .u64("chunks_shared", stats.pipeline.snapshot_chunks_shared)
            .u64("bytes_copied", stats.pipeline.snapshot_bytes_copied)
            .end_obj();
        w.obj(Some("counters"));
        for &id in CounterId::ALL {
            w.u64(id.name(), reg.counter(id));
        }
        w.end_obj();
        w.obj(Some("gauges"));
        for &id in GaugeId::ALL {
            w.f64(id.name(), reg.gauge(id));
        }
        w.arr(Some("tombstone_ratio"));
        for si in 0..reg.n_shards() {
            w.obj(None)
                .usize("shard", si)
                .f64("ratio", reg.shard_tombstone(si))
                .end_obj();
        }
        w.end_arr().end_obj();
        w.obj(Some("histograms"));
        for &id in HistId::ALL {
            export::json_hist(&mut w, id.name(), reg.hist(id));
        }
        w.end_obj();
        w.arr(Some("journal"));
        for e in self.obs.journal.entries() {
            journal_entry_json(&mut w, &e);
        }
        w.end_arr();
        w.end_obj();
        w.finish()
    }
}

/// One journal entry as a flat JSON object (stable `event` names, typed
/// payload fields).
fn journal_entry_json(w: &mut export::JsonW, e: &JournalEntry) {
    w.obj(None)
        .u64("seq", e.seq)
        .f64("at_secs", e.at_secs)
        .str("event", e.event.name());
    match &e.event {
        JournalEvent::MergeStart { n_items } => {
            w.usize("n_items", *n_items);
        }
        JournalEvent::MergeEnd {
            epoch,
            n_changed_shards,
            cache,
            n_items,
            n_deleted,
            secs,
        } => {
            w.u64("epoch", *epoch)
                .usize("n_changed_shards", *n_changed_shards)
                .str("cache", cache.name())
                .usize("n_items", *n_items)
                .usize("n_deleted", *n_deleted)
                .f64("secs", *secs);
        }
        JournalEvent::ExtractionEnd { epoch, mcs, eps, mode, cache_hit } => {
            w.u64("epoch", *epoch)
                .usize("mcs", *mcs)
                .f64("eps", *eps)
                .str("mode", mode)
                .bool("cache_hit", *cache_hit);
        }
        JournalEvent::Compaction { shard, survivors } => {
            w.usize("shard", *shard).usize("survivors", *survivors);
        }
        JournalEvent::DeletionWindow { removed } => {
            w.usize("removed", *removed);
        }
        JournalEvent::SnapshotRefresh { shards } => {
            w.usize("shards", *shards);
        }
        JournalEvent::Save { items } | JournalEvent::Load { items } => {
            w.usize("items", *items);
        }
        JournalEvent::CheckpointEnd {
            items,
            watermark,
            secs,
            trimmed_segments,
        } => {
            w.usize("items", *items)
                .u64("watermark", *watermark)
                .f64("secs", *secs)
                .usize("trimmed_segments", *trimmed_segments);
        }
        JournalEvent::Recovery {
            checkpoint_items,
            replayed_batches,
            replayed_items,
        } => {
            w.usize("checkpoint_items", *checkpoint_items)
                .usize("replayed_batches", *replayed_batches)
                .usize("replayed_items", *replayed_items);
        }
    }
    w.end_obj();
}

impl<T: EngineItem + PartialEq, M: Metric<T> + Clone + 'static> EngineInner<T, M> {
    pub(crate) fn remove_batch(&self, items: &[T]) -> usize {
        if items.is_empty() {
            return 0;
        }
        // queued inserts become visible to value matching (remove-after-add
        // within one thread always finds its target); the flush runs
        // *before* the WAL lock so a journaled-but-queued ingest can
        // drain (workers never touch the WAL — no lock cycle)
        self.flush();
        let sink = self.durability.lock().unwrap().clone();
        let total = match &sink {
            Some(sink) => {
                let mut apply = || self.apply_remove(items);
                sink.log_remove(items, &mut apply)
            }
            None => self.apply_remove(items),
        };
        if total > 0 {
            self.obs.inc(CounterId::DeletionWindows);
            self.obs.journal.push(
                self.obs.uptime_secs(),
                JournalEvent::DeletionWindow { removed: total },
            );
        }
        total
    }

    /// Route `items` to their shards and tombstone matches — the
    /// journal-free body of [`EngineInner::remove_batch`], run under the
    /// WAL lock when a durability sink is installed so the tombstones
    /// land in WAL order.
    fn apply_remove(&self, items: &[T]) -> usize {
        let s = self.shards.len();
        let mut routed: Vec<Vec<&T>> = (0..s).map(|_| Vec::new()).collect();
        for item in items {
            let shard =
                if s == 1 { 0 } else { (item.shard_key() % s as u64) as usize };
            routed[shard].push(item);
        }
        let mut total = 0;
        for (si, (shard, targets)) in
            self.shards.iter().zip(&routed).enumerate()
        {
            if !targets.is_empty() {
                total += self.remove_from_shard(si, shard, targets);
            }
        }
        total
    }

    /// Match and tombstone `targets` inside one shard, under its write
    /// lock (the worker is paused for the duration — removal is the rare
    /// op, ingest the hot one). Matching is a single pass over the stored
    /// items: 64-bit [`ShardKey`] prefilter, `PartialEq` confirm, first
    /// live match consumes the target. Lock order: state → bridge →
    /// deleted, same as every other path.
    fn remove_from_shard(
        &self,
        si: usize,
        shard: &Shard<T, M>,
        targets: &[&T],
    ) -> usize {
        let mut st = shard.state.write().unwrap();
        let mut by_key: FastMap<u64, Vec<usize>> = FastMap::default();
        for (ti, t) in targets.iter().enumerate() {
            by_key.entry(t.shard_key()).or_default().push(ti);
        }
        let mut consumed = vec![false; targets.len()];
        let mut remaining = targets.len();
        let mut victims: Vec<u32> = Vec::new();
        for li in 0..st.f.len() as u32 {
            if remaining == 0 {
                break; // all targets matched: stop hashing stored items
            }
            if !st.f.alive(li) {
                continue;
            }
            let Some(tis) = by_key.get(&st.f.items()[li as usize].shard_key())
            else {
                continue;
            };
            for &ti in tis {
                if !consumed[ti] && st.f.items()[li as usize] == *targets[ti] {
                    consumed[ti] = true;
                    remaining -= 1;
                    victims.push(li);
                    break;
                }
            }
        }
        if victims.is_empty() {
            return 0;
        }
        let removed = st.f.remove_batch_ids(&victims);
        debug_assert_eq!(removed, victims.len(), "victims were live and unique");
        let gids: Vec<u32> =
            victims.iter().map(|&li| st.globals[li as usize]).collect();
        st.removed_globals.extend(gids.iter().copied());
        st.version += 1;
        let mut br = shard.bridge.lock().unwrap();
        self.deleted.lock().unwrap().extend(gids);
        // compaction past the tombstone-ratio threshold
        let ca = self.config.compact_at;
        if ca > 0.0 && (st.f.n_tombstoned() as f64) > ca * st.f.len() as f64 {
            let t0 = Instant::now();
            compact_shard(&mut st, &mut br);
            // the live count legitimately shrank; peers' staleness checks
            // must see it (store under the held state lock)
            self.snaps.set_len(si, st.f.len());
            self.obs.record(HistId::Compaction, t0.elapsed());
            self.obs.inc(CounterId::Compactions);
            self.obs.journal.push(
                self.obs.uptime_secs(),
                JournalEvent::Compaction { shard: si, survivors: st.f.len() },
            );
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::fishdbc::Fishdbc;

    fn blob_items(n: usize, seed: u64) -> Vec<Item> {
        datasets::blobs::generate(n, 16, 4, seed).items
    }

    #[test]
    fn routing_is_deterministic_and_spread() {
        let items = blob_items(400, 3);
        let s = 4u64;
        let mut counts = [0usize; 4];
        for it in &items {
            let a = it.shard_key() % s;
            let b = it.shard_key() % s;
            assert_eq!(a, b, "routing not deterministic");
            counts[a as usize] += 1;
        }
        // each shard gets a non-degenerate share (uniform would be 100)
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 40, "shard {i} starved: {counts:?}");
        }
    }

    /// Pins `Item`'s routing hash to its documented write sequence — a
    /// `u64` variant tag then the raw fields, no length prefixes, no
    /// string terminators. This is exactly what the pre-`ShardKey`
    /// `item_hash` function hashed, so a change here (e.g. switching to a
    /// derived `Hash`, which writes length prefixes) would silently
    /// re-partition every persisted engine's stream. The golden values are
    /// recomputed structurally, not hard-coded, so the test is
    /// platform-independent but still locks the byte sequence.
    #[test]
    fn shard_key_write_sequence_is_frozen() {
        use crate::distances::{bitmap::Bitmap, fuzzy::Digest};
        use std::hash::Hasher;

        let bits = Bitmap::from_bools(&[true, false, true, true]);
        let digest = Digest::from_bytes(b"fixture digest content");
        let cases: Vec<(Item, Box<dyn Fn(&mut FastHasher)>)> = vec![
            (Item::Dense(vec![1.5, -2.0]), {
                Box::new(|h: &mut FastHasher| {
                    h.write_u64(0);
                    h.write_u32(1.5f32.to_bits());
                    h.write_u32((-2.0f32).to_bits());
                })
            }),
            (Item::Sparse { idx: vec![3, 9], val: vec![0.5, 2.0] }, {
                Box::new(|h: &mut FastHasher| {
                    h.write_u64(1);
                    h.write_u32(3);
                    h.write_u32(9);
                    h.write_u32(0.5f32.to_bits());
                    h.write_u32(2.0f32.to_bits());
                })
            }),
            (Item::Set(vec![1, 5, 9]), {
                Box::new(|h: &mut FastHasher| {
                    h.write_u64(2);
                    h.write_u32(1);
                    h.write_u32(5);
                    h.write_u32(9);
                })
            }),
            (Item::Text("héllo".into()), {
                Box::new(|h: &mut FastHasher| {
                    h.write_u64(3);
                    h.write("héllo".as_bytes());
                })
            }),
            (Item::Bits(bits.clone()), {
                let b = bits.clone();
                Box::new(move |h: &mut FastHasher| {
                    h.write_u64(4);
                    for &w in b.words() {
                        h.write_u64(w);
                    }
                })
            }),
            (Item::Digest(digest.clone()), {
                let d = digest.clone();
                Box::new(move |h: &mut FastHasher| {
                    h.write_u64(5);
                    for &m in &d.minhashes {
                        h.write_u64(m);
                    }
                    h.write(&d.histogram);
                    for &w in d.features.words() {
                        h.write_u64(w);
                    }
                })
            }),
        ];
        for (item, write) in &cases {
            let mut h = FastHasher::default();
            write(&mut h);
            assert_eq!(
                item.shard_key(),
                h.finish(),
                "routing write sequence drifted for {item:?}"
            );
        }
    }

    /// Routing stability across engine instances, restarts-in-spirit
    /// (fresh hasher state per call) and save/load: the same stream always
    /// lands in the same shard partition, and the router provably uses the
    /// public [`ShardKey`] contract — the guard that keeps the `ShardKey`
    /// refactor (and any future one) from silently re-partitioning
    /// persisted engines.
    #[test]
    fn routing_stable_across_instances_and_save_load() {
        let items = blob_items(240, 13);
        let s = 3usize;

        let placement = |engine: &Engine| -> Vec<(u32, usize)> {
            engine.flush();
            let mut v = Vec::new();
            for (si, shard) in engine.inner().shard_handles().iter().enumerate() {
                let st = shard.state.read().unwrap();
                for gid in st.globals.iter() {
                    v.push((*gid, si));
                }
            }
            v.sort_unstable();
            v
        };

        let spawn = || -> Engine {
            Engine::spawn(MetricKind::Euclidean, EngineConfig {
                shards: s,
                ..Default::default()
            })
        };
        let a = spawn();
        a.add_batch(items.clone());
        let pa = placement(&a);

        // the router must implement exactly the public ShardKey contract
        for &(gid, si) in &pa {
            let expect = (items[gid as usize].shard_key() % s as u64) as usize;
            assert_eq!(si, expect, "router diverged from ShardKey for id {gid}");
        }

        // a second engine over the same stream partitions identically
        let b = spawn();
        for chunk in items.chunks(17) {
            b.add_batch(chunk.to_vec());
        }
        assert_eq!(placement(&b), pa, "batch schedule changed the partition");

        // and a persisted engine resumes on the same partition: new copies
        // of the same items join the shards that hold their originals
        let mut buf = Vec::new();
        a.save(&mut buf).unwrap();
        a.shutdown();
        let resumed = Engine::load(buf.as_slice()).unwrap();
        resumed.add_batch(items.clone());
        let pr = placement(&resumed);
        for &(gid, si) in &pr[items.len()..] {
            let expect =
                (items[gid as usize - items.len()].shard_key() % s as u64) as usize;
            assert_eq!(si, expect, "resumed routing diverged for id {gid}");
        }
        b.shutdown();
        resumed.shutdown();
    }

    #[test]
    fn single_shard_engine_matches_fishdbc_exactly() {
        let items = blob_items(300, 5);
        let p = FishdbcParams { min_pts: 5, ef: 20, ..Default::default() };

        let mut f = Fishdbc::new(MetricKind::Euclidean, p);
        for it in items.iter().cloned() {
            f.add(it);
        }
        let want = f.cluster(5);

        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: p,
            shards: 1,
            mcs: 5,
            ..Default::default()
        });
        for chunk in items.chunks(37) {
            engine.add_batch(chunk.to_vec());
        }
        let snap = engine.cluster(5);
        assert_eq!(snap.n_items, 300);
        assert_eq!(snap.n_bridge_edges, 0, "no bridges with one shard");
        assert_eq!(snap.clustering.labels, want.labels);
        engine.shutdown();
    }

    #[test]
    fn stats_aggregate_across_shards() {
        let items = blob_items(240, 7);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            shards: 3,
            ..Default::default()
        });
        engine.add_batch(items);
        let s = engine.stats();
        assert_eq!(s.items, 240);
        assert_eq!(s.shard_stats.len(), 3);
        assert!(s.dist_calls > 0);
        assert!(
            s.metric_calls >= s.dist_calls,
            "the shared Counting wrapper sees at least every insert-path \
             call: {} < {}",
            s.metric_calls,
            s.dist_calls
        );
        assert_eq!(
            s.pipeline.metric_calls, s.metric_calls,
            "pipeline stats mirror the engine-wide counter"
        );
        assert!(s.batches >= 3, "every non-empty shard saw its sub-batch");
        assert!(
            s.batch_evals > 0,
            "the batched distance hot path must be exercised"
        );
        assert!(
            s.batch_evals < s.dist_calls,
            "each batch dispatch covers many pairwise evals"
        );
        assert_eq!(
            s.batch_evals,
            s.shard_stats.iter().map(|fs| fs.batch_evals).sum::<u64>(),
            "engine total is the sum of the shard counters"
        );
        let json = engine.stats_json(true);
        assert!(
            json.contains("\"batch_evals\":"),
            "fishdbc-stats-v1 must export batch_evals"
        );
        assert_eq!(engine.len(), 240);
        engine.shutdown();
    }

    #[test]
    fn generic_engine_with_closure_metric() {
        // the tentpole in one test: a typed engine over a user type with a
        // pure-closure distance — no Item, no MetricKind — sharded, merged,
        // served, counted
        let metric = |a: &Vec<i64>, b: &Vec<i64>| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>()
        };
        let mut items: Vec<Vec<i64>> = Vec::new();
        for i in 0..60i64 {
            items.push(vec![i % 8, i / 8]); // lattice blob at the origin
            items.push(vec![1000 + i % 8, i / 8]); // far-away twin
        }
        let engine = Engine::spawn(metric, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 4, ef: 15, ..Default::default() },
            shards: 2,
            mcs: 4,
            ..Default::default()
        });
        engine.add_batch(items.clone());
        let snap = engine.cluster(4);
        assert_eq!(snap.clustering.labels.len(), 120);
        assert!(snap.clustering.n_clusters >= 2, "two lattices, two clusters");
        let l = engine.label(&vec![2i64, 2]);
        assert!(l >= -1 && (l as i64) < snap.clustering.n_clusters as i64);
        let stats = engine.stats();
        assert!(stats.metric_calls > 0, "closure calls must be counted");
        assert_eq!(stats.items, 120);
        engine.shutdown();
    }

    #[test]
    fn empty_batches_and_empty_cluster() {
        let engine: Engine =
            Engine::spawn(MetricKind::Euclidean, EngineConfig::default());
        engine.add_batch(vec![]);
        assert!(engine.is_empty());
        assert_eq!(engine.epoch(), 0);
        let snap = engine.cluster(5);
        assert_eq!(snap.n_items, 0);
        assert_eq!(snap.clustering.n_clusters, 0);
        assert_eq!(snap.epoch, 1, "even an empty merge publishes an epoch");
        assert!(engine.latest().is_some());
        engine.shutdown();
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let items = blob_items(80, 9);
        {
            let engine =
                Engine::spawn(MetricKind::Euclidean, EngineConfig::default());
            engine.add_batch(items);
        } // drop must join all workers without deadlock
    }

    /// Regression (ISSUE 5 satellite): dropping the engine — including
    /// from a panic unwind — must join the recluster thread and every
    /// shard worker, not leak them. Each worker holds a clone of the
    /// metric; a closure capturing an `Arc` makes the join observable:
    /// after drop, ours is the only strong reference left.
    #[test]
    fn drop_joins_all_threads_no_leak() {
        let probe = Arc::new(());
        {
            let held = Arc::clone(&probe);
            let metric = move |a: &Vec<i64>, b: &Vec<i64>| {
                let _ = &held;
                a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>()
            };
            let engine = Engine::spawn(metric, EngineConfig {
                shards: 3,
                recluster_every: 10,
                ..Default::default()
            });
            engine.add_batch((0..60i64).map(|i| vec![i, i]).collect());
        } // drop: signal + join recluster thread and 3 workers
        assert_eq!(
            Arc::strong_count(&probe),
            1,
            "a background thread (holding a metric clone) outlived drop"
        );

        // the same holds when drop runs during a panic unwind
        let probe = Arc::new(());
        let held = Arc::clone(&probe);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let metric = move |a: &Vec<i64>, b: &Vec<i64>| {
                let _ = &held;
                a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>()
            };
            let engine = Engine::spawn(metric, EngineConfig {
                shards: 2,
                recluster_every: 10,
                ..Default::default()
            });
            engine.add_batch(vec![vec![0i64], vec![1]]);
            panic!("simulated test failure");
        }));
        assert!(result.is_err());
        assert_eq!(
            Arc::strong_count(&probe),
            1,
            "a panicking caller leaked an engine thread"
        );
    }

    /// `try_add_batch` must answer `Busy` without blocking once a shard's
    /// bounded queue is full, consume no global ids doing so, and accept
    /// again after the queue drains. A gated metric wedges the single
    /// shard worker mid-insert so the queue state is deterministic: after
    /// four accepted single-item batches at `queue_depth = 2`, at most
    /// two were dequeued (the worker is stuck inside the second item's
    /// distance evaluation), so pending ≥ 2 = depth and admission must
    /// refuse.
    #[test]
    fn try_add_batch_refuses_when_full_and_recovers() {
        let gate = Arc::new((Mutex::new(true), Condvar::new()));
        let g2 = Arc::clone(&gate);
        let metric = move |a: &Vec<i64>, b: &Vec<i64>| {
            let (closed, cv) = &*g2;
            let mut closed = closed.lock().unwrap();
            while *closed {
                closed = cv.wait(closed).unwrap();
            }
            drop(closed);
            a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>()
        };
        let engine = Engine::spawn(metric, EngineConfig {
            shards: 1,
            queue_depth: 2,
            ..Default::default()
        });
        // first item inserts with no distance call; the second wedges the
        // worker inside the gated metric; the rest pile up in the queue
        for i in 0..4i64 {
            engine.add_batch(vec![vec![i]]);
        }
        let back = engine
            .try_add_batch(vec![vec![9i64]])
            .expect_err("queue full, admission must refuse");
        assert_eq!(back, vec![vec![9i64]], "rejected items must come back");
        // rejection consumed no ids: the id counter still reads 4
        assert_eq!(engine.len(), 4);
        // open the gate; once the queue drains, admission accepts again
        {
            let (closed, cv) = &*gate;
            *closed.lock().unwrap() = false;
            cv.notify_all();
        }
        engine.flush();
        engine
            .try_add_batch(vec![vec![9i64]])
            .expect("drained queue must accept");
        engine.flush();
        assert_eq!(engine.len(), 5);
        engine.shutdown();
    }

    /// Drop must tolerate poisoned locks: a thread that panicked while
    /// holding a shard's state lock poisons it, and the subsequent drop
    /// (often during the same unwind) must neither double-panic/abort nor
    /// hang on the join.
    #[test]
    fn drop_survives_poisoned_state_lock() {
        let items = blob_items(60, 15);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            shards: 2,
            recluster_every: 25,
            ..Default::default()
        });
        engine.add_batch(items);
        engine.flush();
        // poison shard 0's state lock from a scratch thread
        let state = Arc::clone(&engine.inner().shard_handles()[0].state);
        let _ = std::thread::spawn(move || {
            let _guard = state.write().unwrap();
            panic!("poison the lock");
        })
        .join();
        drop(engine); // must not panic, must not hang
    }

    #[test]
    fn remove_batch_tombstones_and_recluster_drops_items() {
        let items = blob_items(400, 51);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards: 3,
            mcs: 5,
            compact_at: 0.0, // keep tombstones visible for the assertions
            ..Default::default()
        });
        engine.add_batch(items.clone());
        let first = engine.cluster(5);
        assert_eq!(first.n_items, 400);
        assert_eq!(first.n_deleted, 0);

        // remove a scattered tenth by value
        let victims: Vec<Item> =
            items.iter().step_by(10).cloned().collect();
        assert_eq!(engine.remove_batch(&victims), victims.len());
        // absent and already-removed targets are no-ops
        assert_eq!(engine.remove_batch(&victims), 0);
        assert_eq!(
            engine.remove_batch(&[Item::Dense(vec![9e9, 9e9])]),
            0,
            "absent item must not remove anything"
        );

        let stats = engine.stats();
        assert_eq!(stats.removed_items, victims.len());
        assert_eq!(stats.tombstoned_items, victims.len());
        assert_eq!(stats.compactions, 0);
        assert_eq!(engine.deleted_globals().len(), victims.len());

        let snap = engine.cluster(5);
        assert_eq!(snap.n_items, 400 - victims.len());
        assert_eq!(snap.n_deleted, victims.len());
        assert_eq!(snap.clustering.labels.len(), 400, "slots are stable");
        for gid in engine.deleted_globals() {
            assert_eq!(
                snap.clustering.labels[gid as usize], -1,
                "deleted id {gid} kept a label"
            );
        }
        assert!(
            snap.clustering.n_clusters >= 2,
            "survivors must still cluster"
        );
        engine.shutdown();
    }

    #[test]
    fn remove_then_reinsert_gets_a_fresh_id() {
        let ds = datasets::blobs::generate(200, 16, 4, 53);
        let truth = ds.primary_labels().unwrap().to_vec();
        let items = ds.items;
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 4, ef: 15, ..Default::default() },
            shards: 2,
            mcs: 4,
            ..Default::default()
        });
        engine.add_batch(items.clone());
        assert!(engine.remove(&items[7]));
        let old_gid = {
            let d = engine.deleted_globals();
            assert_eq!(d, vec![7]);
            d[0]
        };
        // an equal item re-enters under a brand-new global id; the old
        // id stays deleted forever
        engine.add_batch(vec![items[7].clone()]);
        let snap = engine.cluster(4);
        assert_eq!(snap.n_items, 200, "one out, one in");
        assert_eq!(snap.n_deleted, 1);
        assert_eq!(snap.clustering.labels.len(), 201);
        assert_eq!(snap.clustering.labels[old_gid as usize], -1);
        // the reinserted copy rejoins its generator blob (guarded: skip
        // if either side extracted as noise)
        let reborn = snap.clustering.labels[200];
        if reborn >= 0 {
            // nearest clustered blob-mate of the original value
            let mate = (0..200)
                .filter(|&j| {
                    j != 7
                        && truth[j] == truth[7]
                        && snap.clustering.labels[j] >= 0
                })
                .min_by(|&a, &b| {
                    MetricKind::Euclidean
                        .dist(&items[7], &items[a])
                        .total_cmp(&MetricKind::Euclidean.dist(&items[7], &items[b]))
                });
            if let Some(j) = mate {
                assert_eq!(
                    reborn, snap.clustering.labels[j],
                    "reinserted copy left its blob"
                );
            }
        }
        // removing the value again removes the *reinserted* copy
        assert!(engine.remove(&items[7]));
        assert_eq!(engine.deleted_globals(), vec![7, 200]);
        engine.shutdown();
    }

    #[test]
    fn compaction_rebuilds_past_threshold_and_keeps_global_ids() {
        let items = blob_items(300, 57);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 4, ef: 15, ..Default::default() },
            shards: 2,
            mcs: 4,
            compact_at: 0.2,
            ..Default::default()
        });
        engine.add_batch(items.clone());
        let _ = engine.cluster(4);
        // remove ~40% — every shard must cross the 20% threshold
        let victims: Vec<Item> =
            items.iter().enumerate().filter(|(i, _)| i % 5 < 2).map(|(_, it)| it.clone()).collect();
        let removed = engine.remove_batch(&victims);
        assert_eq!(removed, victims.len());
        let stats = engine.stats();
        assert!(stats.compactions >= 1, "no shard compacted at 40% churn");
        assert_eq!(
            stats.tombstoned_items, 0,
            "compaction must erase the tombstones it covers"
        );
        assert_eq!(stats.items, 300 - victims.len(), "survivors only");
        assert_eq!(stats.removed_items, victims.len(), "history is permanent");

        let snap = engine.cluster(4);
        assert_eq!(snap.n_items, 300 - victims.len());
        assert_eq!(snap.clustering.labels.len(), 300, "slots survive compaction");
        for gid in engine.deleted_globals() {
            assert_eq!(snap.clustering.labels[gid as usize], -1);
        }
        // survivors keep their original global ids: spot-check via label
        // alignment — a surviving item (2 % 5 == 2 escapes the victim
        // stride) and its stored copy agree
        let l = engine.label(&items[2]);
        if snap.clustering.labels[2] >= 0 {
            assert_eq!(l, snap.clustering.labels[2]);
        }
        engine.shutdown();
    }

    #[test]
    fn auto_recluster_publishes_epochs() {
        let items = blob_items(600, 31);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            shards: 2,
            recluster_every: 150,
            ..Default::default()
        });
        for chunk in items.chunks(75) {
            engine.add_batch(chunk.to_vec());
        }
        // the serving loop runs in the background: wait (bounded) for it
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        let snap = loop {
            if let Some(s) = engine.latest() {
                if s.n_items >= 150 {
                    break s;
                }
            }
            assert!(
                std::time::Instant::now() < deadline,
                "auto-recluster never published a snapshot"
            );
            std::thread::sleep(Duration::from_millis(10));
        };
        assert!(snap.epoch >= 1);
        assert!(snap.n_items >= 150);
        // explicit cluster still works alongside the loop and is fresher
        let fin = engine.cluster(10);
        assert_eq!(fin.n_items, 600);
        assert!(fin.epoch > 0);
        engine.shutdown();
    }

    #[test]
    fn drop_with_recluster_thread_does_not_hang() {
        let items = blob_items(200, 33);
        {
            let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
                shards: 2,
                recluster_every: 50,
                ..Default::default()
            });
            engine.add_batch(items);
        } // drop must stop the serving loop and join all workers
    }

    #[test]
    fn insert_time_bridging_covers_items_after_first_epoch() {
        let items = blob_items(800, 37);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            shards: 3,
            ..Default::default()
        });
        engine.add_batch(items[..600].to_vec());
        let first = engine.cluster(10); // publishes epoch 1 + snapshots
        assert_eq!(first.n_changed_shards, 3, "first merge is from-scratch");
        let base = engine.stats();
        assert_eq!(
            base.bridge_covered, 600,
            "merge catch-up must cover every item"
        );
        // new items now bridge at insert time against the frozen snapshots
        engine.add_batch(items[600..].to_vec());
        let stats = engine.stats(); // flush barrier included
        // the watermark may stall on an item whose core distance is not
        // finite yet (covered by the next catch-up), but it must not move
        // backwards and should have advanced for most items
        assert!(
            stats.bridge_covered >= 600 && stats.bridge_covered <= 800,
            "coverage watermark out of range: {}",
            stats.bridge_covered
        );
        assert!(
            stats.bridge_insert_edges > 0,
            "insert-time bridging found no edges"
        );
        let second = engine.cluster(10);
        assert_eq!(second.epoch, first.epoch + 1);
        assert_eq!(second.n_items, 800);
        let after = engine.stats();
        assert_eq!(
            after.bridge_covered, 800,
            "second catch-up completes coverage"
        );
        assert_eq!(
            after.bridge_covered as u64,
            after.bridge_insert_items + after.bridge_catch_up_items,
            "first-pass coverage must happen exactly once per item"
        );
        engine.shutdown();
    }

    #[test]
    fn recluster_without_new_items_short_circuits() {
        let items = blob_items(400, 41);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            shards: 2,
            ..Default::default()
        });
        engine.add_batch(items);
        let a = engine.cluster(10);
        let b = engine.cluster(10);
        assert_eq!(b.epoch, a.epoch + 1);
        assert_eq!(a.clustering.labels, b.clustering.labels);
        assert_eq!(b.n_changed_shards, 0, "nothing changed between merges");
        assert!(
            b.stages.reused_clustering,
            "unchanged forest must skip condense/extract"
        );
        let stats = engine.stats();
        assert_eq!(stats.merges, 2);
        assert_eq!(stats.pipeline.short_circuits, 1);
        engine.shutdown();
    }

    /// Tentpole: `relabel_at` serves arbitrary extraction parameters from
    /// the pinned epoch's cached forest — the merge-mcs request is bit-
    /// identical to the published snapshot, repeat requests hit the memo,
    /// and the whole exchange adds zero distance calls.
    #[test]
    fn relabel_at_pins_epoch_and_adds_no_metric_calls() {
        let items = blob_items(400, 43);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards: 2,
            mcs: 5,
            ..Default::default()
        });
        engine.add_batch(items);
        let snap = engine.cluster(5);
        let calls_before = engine.stats().metric_calls;

        // merge-mcs request: answered from the memo the merge populated,
        // bit-identical to the published labeling
        let same = engine.relabel_at(ExtractionParams::stability(5));
        assert_eq!(same.epoch, snap.epoch);
        assert!(same.memo_hit, "merge at mcs 5 pre-populated the memo");
        assert_eq!(same.clustering.labels, snap.clustering.labels);

        // a parameter sweep over the pinned epoch: fresh params compute
        // (memo miss), repeats hit, and the epoch never moves
        for params in [
            ExtractionParams::stability(10),
            ExtractionParams { mcs: 5, eps: 0.0, mode: ExtractionMode::Leaf },
            ExtractionParams {
                mcs: 5,
                eps: 0.5,
                mode: ExtractionMode::HybridEps,
            },
        ] {
            let first = engine.relabel_at(params);
            assert_eq!(first.epoch, snap.epoch);
            assert_eq!(
                first.clustering.labels.len(),
                snap.clustering.labels.len()
            );
            let again = engine.relabel_at(params);
            assert!(again.memo_hit, "repeat of {params:?} must memo-hit");
            assert_eq!(again.clustering.labels, first.clustering.labels);
        }
        assert_eq!(
            engine.stats().metric_calls,
            calls_before,
            "extraction is tree-only: the sweep must not touch the metric"
        );

        // the hierarchy surface: root present, children well-formed
        let tree = snap.tree();
        assert!(!tree.is_empty());
        let root = tree[0];
        assert_eq!(root.id, root.parent, "root parents itself");
        assert_eq!(root.size as usize, snap.clustering.labels.len());
        for node in &tree[1..] {
            assert!(node.parent >= root.id && node.parent < node.id);
            assert!(node.lambda_birth >= 0.0 && node.size >= 2);
        }
        assert!(
            tree.len() > snap.clustering.n_clusters,
            "hierarchy holds more nodes than any flat cut selects"
        );
        engine.shutdown();
    }
}
