//! Sharded parallel ingest engine: FISHDBC at multi-core throughput.
//!
//! The [`coordinator`](crate::coordinator) makes FISHDBC *streaming*, but
//! its single worker caps ingest at one core of HNSW insertion. This engine
//! removes that cap with **S independent shards** — each a worker thread
//! owning a [`Fishdbc`](crate::fishdbc::Fishdbc) over a hash-partitioned
//! slice of the item space — and recovers a **global clustering** with one
//! cheap merge pass, following the decomposition HDBSCAN* itself suggests
//! (McInnes & Healy: spanning forest construction dominates; the hierarchy
//! is a cheap postprocess).
//!
//! ## Architecture
//!
//! * **Routing** ([`Engine::add_batch`]): every arriving item gets the next
//!   dense global id (arrival order — labels stay index-aligned with the
//!   input stream) and is hash-routed by *content* to one shard, so each
//!   shard holds a uniform random subsample and mirrors the global density
//!   structure. Bounded queues give backpressure, exactly like the
//!   coordinator.
//! * **Merge** ([`Engine::cluster`], `engine/merge.rs`): after a flush
//!   barrier, the per-shard minimum spanning forests are relabeled into the
//!   global id space and unioned with a bounded set of **bridge edges** —
//!   each item queried (read-only) against the HNSWs of up to
//!   `bridge_fanout` other shards for its `bridge_k` nearest remote
//!   neighbors, weighted by mutual reachability under the two shards' core
//!   distances. One Kruskal pass (`Msf::from_edge_lists`) + condense +
//!   extract produce the global clustering.
//! * **Merge invariants**: (1) each shard's forest is an MSF of its local
//!   candidate graph (Algorithm 1, per shard); (2) Kruskal over the union of
//!   part-MSFs plus extra edges is an MSF of the union graph (the same
//!   lemma that justifies UPDATE_MST); (3) the bridge set is bounded by
//!   `n · bridge_k · bridge_fanout` edges, so merge stays O(n log n).
//! * **Serving** ([`Engine::label`], `engine/query.rs`): answer "which
//!   cluster would this item join?" against the latest snapshot via HNSW
//!   search across all shards, without mutating any state.
//! * **Persistence**: `Engine::save`/`Engine::load` (implemented in
//!   [`crate::persist`]) write a versioned container of every shard's full
//!   FISHDBC state plus the global id maps.

pub mod merge;
pub mod query;
pub(crate) mod shard;

use std::hash::Hasher;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::distances::{Item, MetricKind};
use crate::fishdbc::{FishdbcParams, FishdbcStats};
use crate::hdbscan::Clustering;
use crate::util::fasthash::FastHasher;
use shard::{Shard, ShardCmd, ShardState};

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Per-shard FISHDBC parameters (shared by every shard).
    pub fishdbc: FishdbcParams,
    /// Number of shards S (worker threads); 1 reproduces the single-core
    /// path exactly.
    pub shards: usize,
    /// Minimum cluster size for automatic snapshots ([`Engine::label`]
    /// extracts one lazily when none exists yet).
    pub mcs: usize,
    /// Nearest remote neighbors per (item, remote shard) in the bridge
    /// search.
    pub bridge_k: usize,
    /// How many *other* shards each item is bridged against (clamped to
    /// S-1; rotated per item so all shard pairs are covered).
    pub bridge_fanout: usize,
    /// Per-shard command-queue bound (backpressure depth), in batches.
    pub queue_depth: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            fishdbc: FishdbcParams::default(),
            shards: 4,
            mcs: 10,
            bridge_k: 3,
            bridge_fanout: 3,
            queue_depth: 16,
        }
    }
}

/// A merged global clustering with provenance.
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    /// Global clustering; labels are indexed by global id = arrival order.
    pub clustering: Clustering,
    /// Items covered by this snapshot.
    pub n_items: usize,
    /// Shards merged.
    pub n_shards: usize,
    /// Cross-shard bridge edges offered to the merge.
    pub n_bridge_edges: usize,
    /// Edges in the merged global forest.
    pub n_msf_edges: usize,
    /// Seconds spent on the whole merge + extraction.
    pub extract_secs: f64,
}

/// Counters aggregated across shards.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Items inserted (sum over shards).
    pub items: usize,
    /// Distance evaluations (sum over shards).
    pub dist_calls: u64,
    /// Batches processed (sum over shards).
    pub batches: u64,
    /// Critical-path build time: the busiest shard's insert wall time.
    pub build_secs: f64,
    /// Per-shard FISHDBC counters.
    pub shard_stats: Vec<FishdbcStats>,
}

/// Handle to a running sharded engine. Dropping it shuts the workers down.
pub struct Engine {
    config: EngineConfig,
    metric: MetricKind,
    shards: Vec<Shard>,
    /// Next global id to assign (== items accepted so far).
    next_global: AtomicU64,
    latest: Mutex<Option<EngineSnapshot>>,
}

impl Engine {
    /// Spawn `config.shards` shard workers clustering [`Item`]s under
    /// `metric`.
    pub fn spawn(metric: MetricKind, config: EngineConfig) -> Engine {
        assert!(config.shards >= 1, "engine needs at least one shard");
        let shards = (0..config.shards)
            .map(|id| Shard::spawn(id, metric, config.fishdbc, config.queue_depth))
            .collect();
        Engine {
            config,
            metric,
            shards,
            next_global: AtomicU64::new(0),
            latest: Mutex::new(None),
        }
    }

    /// Reassemble an engine from reloaded shard states (see
    /// [`Engine::load`](crate::persist)).
    pub(crate) fn from_resumed(
        metric: MetricKind,
        config: EngineConfig,
        states: Vec<ShardState>,
        next_global: u64,
    ) -> Engine {
        let shards = states
            .into_iter()
            .enumerate()
            .map(|(id, st)| Shard::resume(id, st, config.queue_depth))
            .collect();
        Engine {
            config,
            metric,
            shards,
            next_global: AtomicU64::new(next_global),
            latest: Mutex::new(None),
        }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    pub fn metric(&self) -> MetricKind {
        self.metric
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Items accepted so far (including any still queued behind a shard).
    pub fn len(&self) -> usize {
        self.next_global.load(Ordering::Relaxed) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub(crate) fn shard_handles(&self) -> &[Shard] {
        &self.shards
    }

    /// Install a snapshot unless a fresher one (more items) is already
    /// cached — two racing `cluster()` calls must not let the slower,
    /// older merge win.
    pub(crate) fn set_latest(&self, snap: EngineSnapshot) {
        let mut slot = self.latest.lock().unwrap();
        if slot.as_ref().map_or(true, |old| old.n_items <= snap.n_items) {
            *slot = Some(snap);
        }
    }

    /// Hash-route a batch: assign dense global ids in arrival order, group
    /// by content hash, enqueue per shard (blocking when a shard's queue is
    /// full — backpressure). Items incompatible with the engine's metric
    /// panic here, in the caller, before touching any shard.
    pub fn add_batch(&self, items: Vec<Item>) {
        if items.is_empty() {
            return;
        }
        // validate before assigning ids: a rejected batch must not leak
        // global ids (persistence requires ids to be dense)
        for item in &items {
            assert!(
                self.metric.compatible(item),
                "item incompatible with metric {}",
                self.metric.name()
            );
        }
        let s = self.shards.len();
        // reserve the id range atomically, rejecting before committing: a
        // panic here must not consume ids (dense-id invariant)
        let base = self
            .next_global
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                cur.checked_add(items.len() as u64)
                    .filter(|&next| next <= u32::MAX as u64)
            })
            .expect("engine capacity (u32 item ids) exceeded");
        let mut routed: Vec<Vec<(u32, Item)>> = (0..s).map(|_| Vec::new()).collect();
        for (i, item) in items.into_iter().enumerate() {
            let shard = if s == 1 { 0 } else { (item_hash(&item) % s as u64) as usize };
            routed[shard].push((base as u32 + i as u32, item));
        }
        for (shard, batch) in self.shards.iter().zip(routed) {
            if !batch.is_empty() {
                shard.send(ShardCmd::AddBatch(batch));
            }
        }
    }

    /// Ingestion barrier: wait until every shard has drained its queue and
    /// folded buffered candidate edges into its local MSF.
    pub fn flush(&self) {
        let (tx, rx) = std::sync::mpsc::sync_channel(self.shards.len());
        for shard in &self.shards {
            shard.send(ShardCmd::Flush(tx.clone()));
        }
        drop(tx);
        for _ in 0..self.shards.len() {
            let _ = rx.recv();
        }
    }

    /// Latest merged snapshot, non-blocking.
    pub fn latest(&self) -> Option<EngineSnapshot> {
        self.latest.lock().unwrap().clone()
    }

    /// Aggregated counters. Flushes first, so this doubles as an ingestion
    /// barrier (mirrors [`Coordinator::stats`](crate::coordinator)).
    pub fn stats(&self) -> EngineStats {
        self.flush();
        let mut stats = EngineStats::default();
        for shard in &self.shards {
            let st = shard.state.read().unwrap();
            let fs = st.f.stats();
            stats.items += fs.items;
            stats.dist_calls += fs.dist_calls;
            stats.batches += st.batches;
            stats.build_secs = stats.build_secs.max(st.build_secs);
            stats.shard_stats.push(fs);
        }
        stats
    }

    /// Shut down, waiting for every shard worker to finish outstanding
    /// work.
    pub fn shutdown(mut self) {
        for shard in &mut self.shards {
            shard.shutdown();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for shard in &mut self.shards {
            shard.shutdown();
        }
    }
}

/// Deterministic content hash used for shard routing: the same stream is
/// always partitioned the same way, across processes and restarts.
pub(crate) fn item_hash(item: &Item) -> u64 {
    let mut h = FastHasher::default();
    match item {
        Item::Dense(v) => {
            h.write_u64(0);
            for &x in v {
                h.write_u32(x.to_bits());
            }
        }
        Item::Sparse { idx, val } => {
            h.write_u64(1);
            for &i in idx {
                h.write_u32(i);
            }
            for &x in val {
                h.write_u32(x.to_bits());
            }
        }
        Item::Set(s) => {
            h.write_u64(2);
            for &i in s {
                h.write_u32(i);
            }
        }
        Item::Text(t) => {
            h.write_u64(3);
            h.write(t.as_bytes());
        }
        Item::Bits(b) => {
            h.write_u64(4);
            for &w in b.words() {
                h.write_u64(w);
            }
        }
        Item::Digest(d) => {
            h.write_u64(5);
            for &m in &d.minhashes {
                h.write_u64(m);
            }
            h.write(&d.histogram);
            for &w in d.features.words() {
                h.write_u64(w);
            }
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::fishdbc::Fishdbc;

    fn blob_items(n: usize, seed: u64) -> Vec<Item> {
        datasets::blobs::generate(n, 16, 4, seed).items
    }

    #[test]
    fn routing_is_deterministic_and_spread() {
        let items = blob_items(400, 3);
        let s = 4u64;
        let mut counts = [0usize; 4];
        for it in &items {
            let a = item_hash(it) % s;
            let b = item_hash(it) % s;
            assert_eq!(a, b, "routing not deterministic");
            counts[a as usize] += 1;
        }
        // each shard gets a non-degenerate share (uniform would be 100)
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 40, "shard {i} starved: {counts:?}");
        }
    }

    #[test]
    fn single_shard_engine_matches_fishdbc_exactly() {
        let items = blob_items(300, 5);
        let p = FishdbcParams { min_pts: 5, ef: 20, ..Default::default() };

        let mut f = Fishdbc::new(MetricKind::Euclidean, p);
        for it in items.iter().cloned() {
            f.add(it);
        }
        let want = f.cluster(5);

        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: p,
            shards: 1,
            mcs: 5,
            ..Default::default()
        });
        for chunk in items.chunks(37) {
            engine.add_batch(chunk.to_vec());
        }
        let snap = engine.cluster(5);
        assert_eq!(snap.n_items, 300);
        assert_eq!(snap.n_bridge_edges, 0, "no bridges with one shard");
        assert_eq!(snap.clustering.labels, want.labels);
        engine.shutdown();
    }

    #[test]
    fn stats_aggregate_across_shards() {
        let items = blob_items(240, 7);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            shards: 3,
            ..Default::default()
        });
        engine.add_batch(items);
        let s = engine.stats();
        assert_eq!(s.items, 240);
        assert_eq!(s.shard_stats.len(), 3);
        assert!(s.dist_calls > 0);
        assert!(s.batches >= 3, "every non-empty shard saw its sub-batch");
        assert_eq!(engine.len(), 240);
        engine.shutdown();
    }

    #[test]
    fn empty_batches_and_empty_cluster() {
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig::default());
        engine.add_batch(vec![]);
        assert!(engine.is_empty());
        let snap = engine.cluster(5);
        assert_eq!(snap.n_items, 0);
        assert_eq!(snap.clustering.n_clusters, 0);
        engine.shutdown();
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let items = blob_items(80, 9);
        {
            let engine =
                Engine::spawn(MetricKind::Euclidean, EngineConfig::default());
            engine.add_batch(items);
        } // drop must join all workers without deadlock
    }
}
