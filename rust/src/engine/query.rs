//! Online label queries: "which cluster would this item join?" answered
//! against the latest published epoch via read-only HNSW search across all
//! shards — the serving primitive a production deployment puts behind its
//! API. Works for any `Engine<T, M>` — the probe is a plain `&T`. No state
//! is mutated; the searches do evaluate the user metric, so they show up
//! in the engine-wide `metric_calls` counter (but never in the shards'
//! insert-path `dist_calls`).

use std::time::Instant;

use crate::distances::Metric;
use crate::fishdbc::majority_vote;
use crate::obs::{CounterId, HistId};

use super::{Engine, EngineItem, EngineSnapshot, ExtractionParams};

impl<T: EngineItem, M: Metric<T> + Clone + 'static> Engine<T, M> {
    /// Label an external item against the latest snapshot (extracting one
    /// with `config.mcs` only when none exists yet), using MinPts nearest
    /// neighbors as voters. Returns -1 for noise/unknown.
    ///
    /// Serving is **staleness-bounded**, like the coordinator's `latest()`:
    /// items ingested since the last published epoch are searched (the
    /// HNSWs are live) but vote as noise until the next merge. With
    /// `EngineConfig::recluster_every > 0` the background serving loop
    /// bounds that staleness automatically; otherwise callers control
    /// freshness by calling [`Engine::cluster`] on their own threshold or
    /// timer.
    pub fn label(&self, item: &T) -> i32 {
        self.label_with(item, self.config().fishdbc.min_pts)
    }

    /// [`Engine::label`] with an explicit voter count `k`.
    pub fn label_with(&self, item: &T, k: usize) -> i32 {
        let snap = match self.latest() {
            Some(s) => s,
            None => self.inner().cluster(self.config().mcs),
        };
        self.label_against(item, &snap, k)
    }

    /// Label against a caller-held snapshot: the serving path pins one
    /// epoch and answers many queries against it while ingestion (and
    /// even re-merging) continues. Majority vote among the `k` globally
    /// nearest clustered neighbors (noise neighbors abstain; ties break
    /// toward the smaller label for determinism — pinned by the
    /// `majority_vote` unit tests in [`crate::fishdbc`]).
    ///
    /// Voter slots are reserved for items the pinned epoch *knows*: a
    /// neighbor ingested after the epoch was published has no label yet
    /// and is skipped before the `k` budget is spent — it must not crowd
    /// out labeled voters and flip a probe to noise mid-window (it used
    /// to: the old path let unknown-global neighbors consume slots and
    /// then abstain). Tombstoned neighbors never appear at all — the
    /// shard searches filter them — so churn cannot crowd the vote
    /// either. Noise-labeled voters still occupy slots: "my neighborhood
    /// is noise" is information; "my neighborhood is too new to say" is
    /// not.
    ///
    /// Telemetry on this path is **O(1) lock-free atomics only** (one
    /// counter bump, one histogram sample into [`HistId::Label`]) — the
    /// serving loop never blocks on observability, even while `/metrics`
    /// is being scraped concurrently (pinned by `tests/obs_integration`).
    pub fn label_against(
        &self,
        item: &T,
        snap: &EngineSnapshot,
        k: usize,
    ) -> i32 {
        self.vote_against(item, &snap.clustering.labels, k)
    }

    /// Online probe under arbitrary [`ExtractionParams`] — the
    /// hierarchy-as-a-service twin of [`Engine::label`]: "which cluster
    /// would this item join *at this mcs/eps/mode*?" The labeling comes
    /// from [`Engine::relabel_at`] (pinned to the latest epoch's cached
    /// forest, memoized, zero extra distance calls), then the probe's own
    /// HNSW search runs exactly like `label_against` — that one search
    /// does evaluate the metric, like every online label query.
    pub fn label_at(
        &self,
        item: &T,
        k: usize,
        params: ExtractionParams,
    ) -> i32 {
        let relabeling = self.inner().relabel_at(params);
        self.vote_against(item, &relabeling.clustering.labels, k)
    }

    /// Shared serving tail: k nearest per shard, merged to the global k
    /// nearest, majority vote through the supplied labeling.
    fn vote_against(&self, item: &T, labels: &[i32], k: usize) -> i32 {
        let t0 = Instant::now();
        let k = k.max(1);
        // k nearest per shard, then merge to the global k nearest
        let mut hits: Vec<(f64, u32)> = Vec::new();
        for shard in self.inner().shard_handles() {
            let st = shard.state.read().unwrap();
            for (id, d) in st.f.nearest(item, k, None) {
                hits.push((d, st.globals[id as usize]));
            }
        }
        hits.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
        let label = majority_vote(
            hits.iter()
                .filter_map(|&(_, gid)| labels.get(gid as usize).copied())
                .take(k),
        );
        let obs = self.inner().obs();
        obs.inc(CounterId::LabelQueries);
        obs.record(HistId::Label, t0.elapsed());
        label
    }
}

#[cfg(test)]
mod tests {
    use crate::datasets;
    use crate::distances::{Item, MetricKind};
    use crate::engine::{Engine, EngineConfig};
    use crate::fishdbc::FishdbcParams;

    fn engine_on_blobs(n: usize, shards: usize, seed: u64) -> (Engine, Vec<Item>) {
        let items = datasets::blobs::generate(n, 16, 3, seed).items;
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards,
            mcs: 5,
            ..Default::default()
        });
        for chunk in items.chunks(64) {
            engine.add_batch(chunk.to_vec());
        }
        (engine, items)
    }

    #[test]
    fn label_matches_stored_item_and_does_not_mutate() {
        let (engine, items) = engine_on_blobs(450, 3, 31);
        let snap = engine.cluster(5);
        assert!(snap.clustering.n_clusters >= 2);

        // probe copies of stored items: they must land in their own cluster
        let mut agree = 0;
        let mut checked = 0;
        for (i, it) in items.iter().enumerate().take(20) {
            let want = snap.clustering.labels[i];
            if want < 0 {
                continue; // noise points may legitimately vote elsewhere
            }
            checked += 1;
            if engine.label(it) == want {
                agree += 1;
            }
        }
        assert!(checked > 10, "too many noise probes to test");
        assert!(agree * 10 >= checked * 9, "label agreed on {agree}/{checked}");

        // queries must not have inserted or recounted anything on the
        // insert-path counters (the shared metric counter does move)
        let stats = engine.stats();
        assert_eq!(stats.items, 450);
        engine.shutdown();
    }

    #[test]
    fn label_queries_count_metric_calls_but_not_insert_calls() {
        let (engine, items) = engine_on_blobs(300, 2, 33);
        let _ = engine.cluster(5);
        let before = engine.stats();
        let _ = engine.label(&items[0]);
        let after = engine.stats();
        assert_eq!(
            after.dist_calls, before.dist_calls,
            "labels must not move the insert-path counters"
        );
        assert!(
            after.metric_calls > before.metric_calls,
            "labels evaluate the metric and must show up in the cost model"
        );
        engine.shutdown();
    }

    #[test]
    fn label_on_empty_engine_is_noise() {
        let engine: Engine =
            Engine::spawn(MetricKind::Euclidean, EngineConfig::default());
        assert_eq!(engine.label(&Item::Dense(vec![0.0, 0.0])), -1);
        engine.shutdown();
    }

    #[test]
    fn label_before_first_snapshot_extracts_lazily() {
        // a label query on a populated engine with no published epoch must
        // trigger one lazy merge, then serve from it
        let (engine, items) = engine_on_blobs(300, 2, 35);
        assert!(engine.latest().is_none(), "no epoch published yet");
        let l = engine.label(&items[0]);
        assert!(l >= -1);
        let snap = engine.latest().expect("lazy merge published an epoch");
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.n_items, 300);
        assert!((l as i64) < snap.clustering.n_clusters as i64);
        engine.shutdown();
    }

    #[test]
    fn label_with_pinned_snapshot() {
        let (engine, items) = engine_on_blobs(300, 2, 37);
        let snap = engine.cluster(5);
        // serving path: pin the snapshot, keep ingesting, queries still work
        engine.add_batch(items[..32].to_vec());
        let l = engine.label_against(&items[0], &snap, 5);
        assert!(l >= -1);
        assert!((l as i64) < snap.clustering.n_clusters as i64);
        engine.shutdown();
    }

    /// Regression (ISSUE 5 headline satellite): items ingested after the
    /// pinned epoch used to consume voter slots — `take(k)` ran before
    /// the label lookup, so a burst of fresh neighbors ate the whole k
    /// budget, every one abstained, and the probe flipped to noise
    /// mid-window. Unknown-global voters are now skipped before `k` is
    /// spent.
    #[test]
    fn fresh_inserts_do_not_eat_voter_slots_on_pinned_snapshot() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(41);
        let mut items = Vec::new();
        for _ in 0..150 {
            items.push(Item::Dense(vec![rng.normal() as f32, rng.normal() as f32]));
        }
        for _ in 0..150 {
            items.push(Item::Dense(vec![
                100.0 + rng.normal() as f32,
                100.0 + rng.normal() as f32,
            ]));
        }
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards: 2,
            mcs: 5,
            ..Default::default()
        });
        engine.add_batch(items);
        let snap = engine.cluster(5);
        assert!(snap.clustering.n_clusters >= 2);
        let probe = Item::Dense(vec![0.0, 0.0]);
        let want = engine.label_against(&probe, &snap, 5);
        assert!(want >= 0, "probe at a blob center must label");

        // a burst of fresh items swarming the probe: strictly closer than
        // any stored neighbor, but unknown to the pinned epoch
        let burst: Vec<Item> = (0..8)
            .map(|_| {
                Item::Dense(vec![
                    (rng.normal() * 0.001) as f32,
                    (rng.normal() * 0.001) as f32,
                ])
            })
            .collect();
        engine.add_batch(burst);
        engine.flush();
        let got = engine.label_against(&probe, &snap, 5);
        assert_eq!(
            got, want,
            "fresh unknown neighbors ate the voter budget and flipped the \
             probe"
        );
        engine.shutdown();
    }

    /// Churn-proof serving: removed neighbors vanish from the vote
    /// immediately (the shard searches filter tombstones), so a probe
    /// keeps labeling into its surviving cluster against a pinned epoch.
    #[test]
    fn removed_neighbors_do_not_flip_pinned_labels() {
        let (engine, items) = engine_on_blobs(450, 2, 43);
        let snap = engine.cluster(5);
        let probe = &items[0];
        let want = engine.label_against(probe, &snap, 5);
        if want < 0 {
            engine.shutdown();
            return; // noise probe: nothing to defend
        }
        // remove half the probe's cluster-mates (every second item of the
        // same generator blob — ids stride by the 3 centers)
        let victims: Vec<Item> = items
            .iter()
            .enumerate()
            .filter(|(i, _)| i % 6 == 3)
            .map(|(_, it)| it.clone())
            .collect();
        let removed = engine.remove_batch(&victims);
        assert!(removed > 0, "victims must exist");
        let got = engine.label_against(probe, &snap, 5);
        assert_eq!(got, want, "churn flipped a pinned-label probe");
        engine.shutdown();
    }

    /// `label_at` with the merge's own parameters reproduces `label`
    /// exactly (same labeling via the memo), and other parameter tuples
    /// answer within their own labeling's range.
    #[test]
    fn label_at_matches_label_at_merge_params() {
        use crate::engine::{ExtractionMode, ExtractionParams};
        let (engine, items) = engine_on_blobs(300, 2, 45);
        let snap = engine.cluster(5);
        let want = engine.label_against(&items[0], &snap, 5);
        let got =
            engine.label_at(&items[0], 5, ExtractionParams::stability(5));
        assert_eq!(got, want, "merge-params probe must match label()");
        let leaf = ExtractionParams {
            mcs: 5,
            eps: 0.0,
            mode: ExtractionMode::Leaf,
        };
        let relabeling = engine.relabel_at(leaf);
        let l = engine.label_at(&items[0], 5, leaf);
        assert!(l >= -1);
        assert!((l as i64) < relabeling.clustering.n_clusters as i64);
        engine.shutdown();
    }

    #[test]
    fn latest_is_cheap_and_pinnable_across_epochs() {
        let (engine, items) = engine_on_blobs(300, 2, 39);
        let first = engine.cluster(5);
        let pinned = engine.latest().expect("epoch 1 published");
        assert_eq!(pinned.epoch, first.epoch);
        // a later epoch must not invalidate the pinned Arc
        engine.add_batch(items[..48].to_vec());
        let second = engine.cluster(5);
        assert!(second.epoch > first.epoch);
        assert_eq!(pinned.n_items, 300, "pinned epoch is immutable");
        let l = engine.label_against(&items[0], &pinned, 5);
        assert!(l >= -1);
        engine.shutdown();
    }
}
