//! One shard of the parallel ingest engine: a worker thread owning a
//! [`Fishdbc`] over a hash-partitioned slice of the item space, plus the
//! local→global id map that lets the merge relabel its MSF edges, plus the
//! shard's half of the incremental bridge pipeline — a buffer of
//! cross-shard candidate edges discovered **at insert time** against
//! frozen snapshots of the other shards' HNSWs. Snapshots are captured
//! copy-on-write from the shard's chunked stores in O(Δ), not deep-cloned
//! in O(n) — see the snapshot-lifecycle notes at the `snapshots` section
//! below.
//!
//! Everything here is generic over the item type `T` and the user metric
//! `M` (see [`EngineItem`](super::EngineItem)); the shard's `Fishdbc` and
//! its frozen snapshots hold [`Counting<M>`] clones sharing one engine-wide
//! distance-call counter, the paper's cost model.
//!
//! The FISHDBC state sits behind an `RwLock` so the merge and the online
//! query path can read it concurrently; only the shard's own worker ever
//! writes it. The bridge buffer sits behind its own `Mutex`, written by
//! the worker (insert-time discovery) and by the merge (catch-up for
//! items the worker could not cover yet). Lock order is always
//! `state → bridge` and `state → snaps`, never the reverse, and no thread
//! ever takes another shard's *write* lock — no lock-ordering cycles
//! exist. Crucially, insert-time bridging queries only frozen
//! [`ShardSnap`]s (plain `Arc`s), never another shard's live `RwLock`:
//! two workers bridging against each other's live state would deadlock.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::distances::{Counting, Metric};
use crate::fishdbc::{Fishdbc, FishdbcParams};
use crate::hnsw::Hnsw;
use crate::mst::{Edge, Msf};
use crate::obs::{HistId, Registry};
use crate::util::chunked::{ChunkDelta, ChunkedVec};
use crate::util::fasthash::{FastMap, FastSet};

use super::EngineItem;

/// Commands a shard worker processes in FIFO order.
pub(crate) enum ShardCmd<T> {
    /// Insert `(global id, item)` pairs (ids were assigned by the router).
    AddBatch(Vec<(u32, T)>),
    /// Drain the queue up to this point, fold buffered candidate edges into
    /// the local MSF, then ack — the engine's barrier primitive.
    Flush(SyncSender<()>),
    Shutdown,
}

/// Shard-local state: the FISHDBC instance plus bookkeeping.
///
/// ## Deletion lifecycle (tombstone → stamp invalidation → compaction)
///
/// `Engine::remove_batch` routes removals by content hash exactly like
/// ingest, then applies them here under the shard's *write* lock:
///
/// 1. **Tombstone** — the matched local ids are tombstoned inside the
///    shard's `Fishdbc` (HNSW node kept for routability, core invalidated,
///    neighbor cores recomputed, forest/candidate edges dropped), the
///    matching global ids are appended to [`ShardState::removed_globals`]
///    (the permanent record — deleted global ids label `-1` forever), and
///    [`ShardState::version`] is bumped so stale frozen snapshots stop
///    counting as current.
/// 2. **Stamp invalidation** — the per-shard merge stamp includes the
///    cumulative removal count, so the next merge re-derives this shard's
///    whole contribution (filtered forest + bridge set) from scratch:
///    deletion breaks the monotone-growth premise behind the cached
///    global MSF, so the O(Δ) cached path is only sound for shards with
///    no deletions in the window. Untouched shards keep it.
/// 3. **Compaction** — once the tombstone ratio crosses
///    `EngineConfig::compact_at`, [`compact_shard`] rebuilds the shard's
///    FISHDBC by replaying the survivors (fresh HNSW with no dead nodes).
///    Global ids are stable (survivors keep theirs through the rebuilt
///    id map); local ids remap, so the bridge coverage watermarks are
///    remapped to the surviving prefix counts — coverage already earned
///    is kept, order is preserved.
pub(crate) struct ShardState<T, M> {
    pub f: Fishdbc<T, Counting<M>>,
    /// `globals[local_id] = global_id` (dense, append-only between
    /// compactions, chunked so snapshots capture it copy-on-write).
    pub globals: ChunkedVec<u32>,
    pub batches: u64,
    /// Wall time this shard spent inserting (its lane of the build).
    pub build_secs: f64,
    /// Every global id ever removed from this shard, in removal order —
    /// cumulative: survives compaction (which erases the tombstones
    /// themselves) and persists in FISHENG v3. The merge filters the
    /// cached global forest and stale bridge offers against the union of
    /// these, and masks their labels to -1 in every epoch.
    pub removed_globals: Vec<u32>,
    /// Monotone count of items ever *inserted* (never decremented — not
    /// by removal, not by compaction). The same-epoch window bookkeeping
    /// compares remote growth against this, because snapshot *lengths*
    /// stop being monotone once compaction can shrink them.
    pub inserts: u64,
    /// Monotone mutation stamp: bumped on every applied batch, removal
    /// and compaction. A frozen [`ShardSnap`] carrying the same version is
    /// content-identical to the live state (the "same length ⇒ same
    /// content" shortcut is unsound under deletion: a removal leaves the
    /// length unchanged).
    pub version: u64,
    /// Compactions run (stats).
    pub compactions: u64,
}

impl<T: EngineItem, M: Metric<T> + Clone> ShardState<T, M> {
    pub fn new(metric: Counting<M>, params: FishdbcParams) -> ShardState<T, M> {
        ShardState {
            f: Fishdbc::new(metric, params),
            globals: ChunkedVec::new(),
            batches: 0,
            build_secs: 0.0,
            removed_globals: Vec::new(),
            inserts: 0,
            version: 0,
            compactions: 0,
        }
    }
}

/// Rebuild a shard without its tombstones: replay the survivors through a
/// fresh FISHDBC (new HNSW, new neighborhoods, new forest — the from-
/// scratch state the deletion approximations documented at
/// `Fishdbc::remove_batch_ids` converge back to). Global ids are stable;
/// local ids remap by surviving order, and the bridge coverage watermarks
/// remap to the surviving prefix counts so first-pass coverage is neither
/// lost nor repeated. Bridge buffers/forests are keyed by global ids and
/// survive as-is (edges to deleted ids are filtered at every merge).
pub(crate) fn compact_shard<T: EngineItem, M: Metric<T> + Clone>(
    st: &mut ShardState<T, M>,
    br: &mut BridgeState,
) {
    let old_len = st.f.len();
    let old_covered = br.covered.min(old_len);
    let old_merge_covered = br.merge_covered.min(old_covered);
    let mut f = Fishdbc::new(st.f.metric().clone(), *st.f.params());
    let mut globals = ChunkedVec::new();
    let (mut covered, mut merge_covered) = (0usize, 0usize);
    for li in 0..old_len {
        if !st.f.alive(li as u32) {
            continue;
        }
        f.add(st.f.items()[li].clone());
        globals.push(st.globals[li]);
        if li < old_covered {
            covered += 1;
        }
        if li < old_merge_covered {
            merge_covered += 1;
        }
    }
    st.f = f;
    st.globals = globals;
    st.compactions += 1;
    st.version += 1;
    br.covered = covered;
    br.merge_covered = merge_covered;
}

// ------------------------------------------------------------- snapshots --
//
// ## Snapshot lifecycle (chunked copy-on-write capture)
//
// Every store a snapshot needs — the item store, the HNSW node chunks, the
// core-distance mirror, and the local→global id map — lives in chunked
// `Arc`-shared storage ([`ChunkedVec`]). [`ShardSnap::capture`] is
// therefore just four O(n / CHUNK) pointer clones taken under the shard's
// *read* lock; no element is copied at capture time. The cost moved to the
// writer side, where it belongs: the first time the shard worker rewires a
// node (or shifts a core, or appends into the tail) of a chunk that some
// frozen snapshot still references, `Arc::make_mut` copies that one chunk.
// Chunks untouched since the previous capture stay physically shared by
// the live shard and every snapshot that saw them, so a capture after a
// small delta republishes almost everything and copies only the dirty
// tail — the "partial snapshot refresh" that makes
// `EngineConfig::bridge_refresh` cheap enough to run mid-epoch.
//
// Captures never touch `BridgeState`: in particular the coverage watermark
// (`BridgeState::covered`) survives every mid-epoch refresh, so items
// already bridged at insert time keep their first-pass coverage across
// refreshes (regression-tested in
// `engine_integration::bridge_refresh_capture_preserves_coverage_watermark`);
// the only second look any item ever gets is the bounded same-epoch
// re-search of the next merge's catch-up (see `BridgeState::merge_covered`).
//
// [`Snaps::set`] compares each new snapshot's chunk pointers against the
// snapshot it replaces and accumulates copied-vs-shared chunk counts (plus
// approximate bytes copied), surfaced through `PipelineStats` /
// `fishdbc engine --stats` and asserted on by the tentpole acceptance test.

/// Frozen, read-only view of one shard's index at some epoch: everything a
/// *remote* shard needs to run bridge queries against it without touching
/// its `RwLock`. Immutable once built; shared as `Arc<ShardSnap<T, M>>`.
/// All four stores are chunked and physically share every chunk that did
/// not change since the previous capture (see the lifecycle notes above).
pub(crate) struct ShardSnap<T, M> {
    pub metric: Counting<M>,
    /// HNSW beam width used for bridge queries.
    pub ef: usize,
    pub items: ChunkedVec<T>,
    pub hnsw: Hnsw,
    /// Core distances at snapshot time (+∞ while < MinPts neighbors).
    pub cores: ChunkedVec<f64>,
    /// local → global id map at snapshot time.
    pub globals: ChunkedVec<u32>,
    /// Tombstone marks at snapshot time: bridge searches route through
    /// tombstoned nodes but never return them. (Items deleted *after*
    /// capture can still be offered; the merge filters those edges against
    /// the global deleted set.)
    pub tombs: ChunkedVec<bool>,
    /// Capture-time [`ShardState::version`] — the content-identity stamp.
    pub version: u64,
    /// Capture-time [`ShardState::inserts`] (same-epoch window bookkeeping).
    pub inserts: u64,
    /// Live tombstone count at capture (search-degradation guard).
    pub n_tombs: usize,
}

/// Approximate bytes of one stored item (bytes-copied accounting), built
/// on [`EngineItem::approx_heap_bytes`].
fn item_bytes<T: EngineItem>(item: &T) -> usize {
    std::mem::size_of::<T>() + item.approx_heap_bytes()
}

impl<T: EngineItem, M: Metric<T> + Clone> ShardSnap<T, M> {
    /// O(Δ) capture: five chunk-pointer clones under the shard's read
    /// lock. See the snapshot-lifecycle notes at the top of this section.
    pub fn capture(st: &ShardState<T, M>) -> ShardSnap<T, M> {
        ShardSnap {
            metric: st.f.metric().clone(),
            ef: st.f.params().ef,
            items: st.f.items().clone(),
            hnsw: st.f.hnsw().clone(),
            cores: st.f.cores().clone(),
            globals: st.globals.clone(),
            tombs: st.f.tombs().clone(),
            version: st.version,
            inserts: st.inserts,
            n_tombs: st.f.n_tombstoned(),
        }
    }

    /// Approximate k nearest stored items to `query`, ascending distance.
    /// Tombstoned nodes are traversed but never returned.
    pub fn nearest(&self, query: &T, k: usize) -> Vec<(u32, f64)> {
        if self.n_tombs == 0 {
            self.hnsw.search(&self.items, &self.metric, query, k, self.ef)
        } else {
            self.hnsw.search_filtered(
                &self.items,
                &self.metric,
                query,
                k,
                self.ef,
                |id| !self.tombs[id as usize],
            )
        }
    }

    /// Copied-vs-shared chunk accounting against the snapshot this one
    /// replaces (everything counts as copied when there is none).
    pub fn chunk_delta_vs(&self, prev: Option<&ShardSnap<T, M>>) -> ChunkDelta {
        let mut d = self.items.chunk_delta(prev.map(|p| &p.items), |c| {
            c.iter().map(item_bytes).sum()
        });
        d.add(self.cores.chunk_delta(prev.map(|p| &p.cores), |c| c.len() * 8));
        d.add(self.globals.chunk_delta(prev.map(|p| &p.globals), |c| c.len() * 4));
        d.add(self.tombs.chunk_delta(prev.map(|p| &p.tombs), |c| c.len()));
        d.add(self.hnsw.node_chunk_delta(prev.map(|p| &p.hnsw)));
        d
    }
}

/// One published snapshot slot per shard, plus each shard's *live* item
/// count (so peers can judge snapshot staleness without touching its
/// `RwLock`). Each slot's mutex is held only long enough to clone or
/// replace an `Arc`. Also the home of the engine-wide capture counters
/// (captures, chunks copied/shared, approx bytes copied).
pub(crate) struct Snaps<T, M> {
    slots: Vec<Mutex<Option<Arc<ShardSnap<T, M>>>>>,
    lens: Vec<AtomicU64>,
    captures: AtomicU64,
    chunks_copied: AtomicU64,
    chunks_shared: AtomicU64,
    bytes_copied: AtomicU64,
}

impl<T: EngineItem, M: Metric<T> + Clone> Snaps<T, M> {
    pub fn new(n_shards: usize) -> Snaps<T, M> {
        Snaps {
            slots: (0..n_shards).map(|_| Mutex::new(None)).collect(),
            lens: (0..n_shards).map(|_| AtomicU64::new(0)).collect(),
            captures: AtomicU64::new(0),
            chunks_copied: AtomicU64::new(0),
            chunks_shared: AtomicU64::new(0),
            bytes_copied: AtomicU64::new(0),
        }
    }

    pub fn get(&self, shard: usize) -> Option<Arc<ShardSnap<T, M>>> {
        self.slots[shard].lock().unwrap().clone()
    }

    pub fn set(&self, shard: usize, snap: Arc<ShardSnap<T, M>>) {
        // (`lens` is NOT updated here: captures run outside the state
        // lock, and a stale capture racing a compaction could re-raise a
        // length that legitimately shrank. `set_len` under the state lock
        // is the single writer.)
        // The delta walk is stats-only work, and bridge workers read this
        // slot on their hot path, so it runs with the slot lock released.
        // Captures of the same shard can race (cadence refresh vs merge
        // refresh): a newer-or-equal incumbent always wins — equal-version
        // snapshots are content-identical (the version stamp bumps on
        // every mutation, including removals, which item *counts* cannot
        // see) — and the counter delta is only applied when the publish
        // replaces exactly the snapshot it was computed against, so no
        // copied chunk is ever counted twice.
        let mut prev = self.slots[shard].lock().unwrap().clone();
        loop {
            if prev.as_ref().is_some_and(|p| p.version >= snap.version) {
                return;
            }
            let delta = snap.chunk_delta_vs(prev.as_deref());
            let mut slot = self.slots[shard].lock().unwrap();
            let unchanged = match (slot.as_ref(), prev.as_ref()) {
                (None, None) => true,
                (Some(a), Some(b)) => Arc::ptr_eq(a, b),
                _ => false,
            };
            if unchanged {
                self.captures.fetch_add(1, Ordering::Relaxed);
                self.chunks_copied.fetch_add(delta.copied, Ordering::Relaxed);
                self.chunks_shared.fetch_add(delta.shared, Ordering::Relaxed);
                self.bytes_copied
                    .fetch_add(delta.bytes_copied, Ordering::Relaxed);
                *slot = Some(snap);
                return;
            }
            // someone published while we were counting: retry against the
            // fresher incumbent (races are between at most a handful of
            // refresh paths, so this converges immediately in practice)
            prev = slot.clone();
        }
    }

    /// Cumulative capture counters: (captures, chunks copied, chunks
    /// shared, approx bytes copied).
    pub fn capture_stats(&self) -> (u64, u64, u64, u64) {
        (
            self.captures.load(Ordering::Relaxed),
            self.chunks_copied.load(Ordering::Relaxed),
            self.chunks_shared.load(Ordering::Relaxed),
            self.bytes_copied.load(Ordering::Relaxed),
        )
    }

    /// Publish a shard's live item count. Callers hold the shard's state
    /// lock (worker after a batch, engine thread after a removal or
    /// compaction), so writes are serialized and a plain store is right —
    /// compaction legitimately *shrinks* the count, which a max would
    /// never let drop.
    pub fn set_len(&self, shard: usize, len: usize) {
        self.lens[shard].store(len as u64, Ordering::Relaxed);
    }

    pub fn live_len(&self, shard: usize) -> usize {
        self.lens[shard].load(Ordering::Relaxed) as usize
    }
}

// ---------------------------------------------------------- bridge state --

/// The shard's buffer of cross-shard candidate edges, in global ids,
/// weighted by mutual reachability under the two shards' core distances.
///
/// Edges are keyed canonically ([`Edge::key`]) keeping the smaller weight,
/// so the two orientations of the same pair — item *a* in shard S1
/// discovering *b* in S2 at insert time, and *b* later discovering *a* —
/// collapse into one offer. The buffer obeys the same α·n flush discipline
/// as FISHDBC's local candidate buffer: when it outgrows `α · len(shard)`,
/// it is folded through Kruskal into `msf`, the shard's *bridge forest*.
/// That compaction is lossless for the global merge by the same lemma that
/// justifies UPDATE_MST: an MSF of a union graph only draws edges from the
/// MSFs of its parts.
pub(crate) struct BridgeState {
    /// Canonical-keyed candidate buffer (global id pair → min weight).
    pub buf: FastMap<(u32, u32), f64>,
    /// Compacted bridge forest over all flushed candidates.
    pub msf: Msf,
    /// Coverage watermark: local items `[0, covered)` have already queried
    /// all their rotation targets (at insert time or in a merge catch-up).
    pub covered: usize,
    /// Merge-final watermark: local items `[0, merge_covered)` had their
    /// last bridge search at a merge barrier, against states containing
    /// every remote item that existed then. Items in
    /// `[merge_covered, covered)` were insert-covered *inside* the current
    /// epoch window, against frozen snapshots that may predate remote
    /// items of the same window — the next merge's catch-up re-searches
    /// exactly that suffix (against live states) before advancing both
    /// watermarks, closing the same-epoch cross-shard pair gap.
    /// Persisted as the v2 `covered` field, so a reloaded engine re-runs
    /// the (bounded) window re-search instead of silently dropping it.
    pub merge_covered: usize,
    /// Per remote shard: the smallest frozen-snapshot **insert watermark**
    /// ([`ShardState::inserts`]) any insert-time walk of the current
    /// window queried (`usize::MAX` = none). Lets the catch-up skip the
    /// window re-search for remotes that did not grow past what every
    /// window item already saw. Insert watermarks, not snapshot lengths:
    /// lengths stop being monotone once compaction can shrink a remote,
    /// which would make "remote grew" undetectable.
    pub window_seen: Vec<usize>,
    /// Bumped whenever the edge set changes (the merge's change detector).
    pub generation: u64,
    /// α·n compactions run.
    pub compactions: u64,
    /// Edges discovered at insert time (vs merge catch-up), for stats.
    pub insert_edges: u64,
    /// Items covered by the insert-time walk (this process).
    pub insert_items: u64,
    /// Items the merge catch-up first-covered (this process). The two
    /// walks share each shard's ordered watermark, so for an engine that
    /// was not reloaded mid-run and saw no compaction, `covered ==
    /// insert_items + catch_up_items` at any flushed quiescent point —
    /// first-pass coverage happens exactly once (a snapshot refresh that
    /// rewound a watermark would break the equality). Regression-tested
    /// in `engine_integration` (deletion-free). (Counters restart at 0 on
    /// engine reload, and compaction remaps `covered` down to the
    /// surviving prefix without rescaling the historical counters; the
    /// watermark itself is persisted.)
    pub catch_up_items: u64,
    /// Items the merge catch-up *re-searched* to close the same-epoch
    /// window (bounded by the items inserted since the previous merge;
    /// not part of the first-pass equality above).
    pub recheck_items: u64,
    /// Wall seconds spent on insert-time bridge queries.
    pub insert_secs: f64,
}

impl Default for BridgeState {
    fn default() -> Self {
        BridgeState::new()
    }
}

impl BridgeState {
    pub fn new() -> BridgeState {
        BridgeState {
            buf: FastMap::default(),
            msf: Msf::new(),
            covered: 0,
            merge_covered: 0,
            window_seen: Vec::new(),
            generation: 0,
            compactions: 0,
            insert_edges: 0,
            insert_items: 0,
            catch_up_items: 0,
            recheck_items: 0,
            insert_secs: 0.0,
        }
    }

    /// Reassemble from persisted parts (FISHENG v2). The persisted
    /// watermark is the merge-final one, so both watermarks resume equal:
    /// anything that was inside an unfinished epoch window at save time is
    /// simply re-covered (first-pass) by the next merge's catch-up.
    pub fn from_parts(
        covered: usize,
        generation: u64,
        msf_edges: Vec<Edge>,
        buf: Vec<(u32, u32, f64)>,
    ) -> BridgeState {
        let n = msf_edges
            .iter()
            .map(|e| e.a.max(e.b) as usize + 1)
            .max()
            .unwrap_or(0);
        BridgeState {
            buf: buf.into_iter().map(|(a, b, w)| ((a, b), w)).collect(),
            msf: Msf::from_parts(msf_edges, n),
            covered,
            merge_covered: covered,
            window_seen: Vec::new(),
            generation,
            compactions: 0,
            insert_edges: 0,
            insert_items: 0,
            catch_up_items: 0,
            recheck_items: 0,
            insert_secs: 0.0,
        }
    }

    /// Offer a candidate bridge edge (canonical key, keep the min weight).
    /// Returns true when the edge set changed. Non-finite weights (a core
    /// distance still unknown on either side) are legal, mirroring the
    /// local candidate path: the min-weight discipline replaces them as
    /// soon as a finite offer for the pair arrives.
    pub fn offer(&mut self, a: u32, b: u32, w: f64) -> bool {
        if a == b {
            return false;
        }
        let key = Edge::key(a, b);
        match self.buf.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                if w < *e.get() {
                    *e.get_mut() = w;
                    true
                } else {
                    false
                }
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(w);
                true
            }
        }
    }

    /// Record that an insert-time walk of the current epoch window queried
    /// remote shard `t` through a frozen snapshot captured at insert
    /// watermark `snap_inserts`.
    pub fn note_window_snap(&mut self, t: usize, snap_inserts: usize) {
        if self.window_seen.len() <= t {
            self.window_seen.resize(t + 1, usize::MAX);
        }
        self.window_seen[t] = self.window_seen[t].min(snap_inserts);
    }

    /// Smallest insert watermark of shard `t` any window item's insert-
    /// time search saw (`usize::MAX` when no window item queried `t`).
    pub fn window_seen(&self, t: usize) -> usize {
        self.window_seen.get(t).copied().unwrap_or(usize::MAX)
    }

    /// Close the epoch window after a merge catch-up: everything covered
    /// so far is now merge-final.
    pub fn finish_window(&mut self) {
        self.merge_covered = self.covered;
        self.window_seen.clear();
    }

    /// α·n flush discipline: fold the buffer into the bridge forest when
    /// it outgrows `alpha * local_len`. `deleted` is the engine-wide
    /// deleted-global-id registry: edges touching a deleted id must not
    /// enter this Kruskal pass — a dead edge winning a cycle here would
    /// evict a *live* edge from the bridge forest even though the cycle
    /// does not exist in the survivors' graph (the dead endpoint is
    /// filtered from every merge), silently losing cross-shard
    /// connectivity. Offers already buffered before a deletion are purged
    /// on the same occasion.
    pub fn maybe_compact(
        &mut self,
        alpha: f64,
        local_len: usize,
        deleted: &Mutex<FastSet<u32>>,
    ) {
        if (self.buf.len() as f64) <= alpha * local_len.max(1) as f64 {
            return;
        }
        let dead = deleted.lock().unwrap();
        if !dead.is_empty() {
            self.msf.retain_nodes(|id| !dead.contains(&id));
        }
        let edges: Vec<Edge> = self
            .buf
            .drain()
            .filter(|&((a, b), _)| !dead.contains(&a) && !dead.contains(&b))
            .map(|((a, b), w)| Edge::new(a, b, w))
            .collect();
        drop(dead);
        let n = edges
            .iter()
            .map(|e| e.a.max(e.b) as usize + 1)
            .max()
            .unwrap_or(0);
        self.msf.update(edges, n);
        self.compactions += 1;
        self.generation += 1;
    }

    /// All current bridge edges (compacted forest + live buffer).
    pub fn edges(&self) -> impl Iterator<Item = Edge> + '_ {
        self.msf
            .edges()
            .iter()
            .copied()
            .chain(self.buf.iter().map(|(&(a, b), &w)| Edge::new(a, b, w)))
    }

    pub fn n_edges(&self) -> usize {
        self.msf.edges().len() + self.buf.len()
    }

    /// Sorted buffer export (persistence; deterministic byte stream).
    pub fn buf_export(&self) -> Vec<(u32, u32, f64)> {
        let mut v: Vec<(u32, u32, f64)> =
            self.buf.iter().map(|(&(a, b), &w)| (a, b, w)).collect();
        v.sort_unstable_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
        v
    }
}

/// Which remote shard the j-th fanout query of local item `li` in shard
/// `si` targets. Offset in `[1, s-1]`: never self, distinct per j, rotated
/// per item so all shard pairs are covered even at fanout 1. Shared by
/// insert-time bridging and the merge catch-up so coverage watermarks mean
/// the same thing on both paths.
#[inline]
pub(crate) fn rotation_target(si: usize, li: usize, j: usize, s: usize) -> usize {
    (si + 1 + (li + j) % (s - 1)) % s
}

/// Everything a worker needs for insert-time bridge discovery.
pub(crate) struct BridgeCtx<T, M> {
    pub si: usize,
    pub n_shards: usize,
    pub bridge_k: usize,
    pub bridge_fanout: usize,
    pub alpha: f64,
    /// Maximum items a remote shard may have grown past its frozen
    /// snapshot before insert-time coverage stalls (falling back to the
    /// merge catch-up, which searches live state). Bounds how much same-
    /// epoch window the catch-up's re-search has to make up: without it, a
    /// long gap between merges would let items mark themselves covered
    /// against arbitrarily stale views.
    pub lag_limit: usize,
    pub snaps: Arc<Snaps<T, M>>,
    pub bridge: Arc<Mutex<BridgeState>>,
    /// Engine-wide deleted-global-id registry (bridge-forest compaction
    /// must not let dead edges win Kruskal cycles). Lock order:
    /// state → bridge → deleted, and `deleted` is only ever taken as a
    /// leaf.
    pub deleted: Arc<Mutex<FastSet<u32>>>,
    /// Engine-wide telemetry registry: the worker records a
    /// [`HistId::ShardInsert`] span per applied batch (lock-free atomics,
    /// so the hot ingest loop never blocks on observability).
    pub obs: Arc<Registry>,
}

/// Insert-time bridge maintenance: advance this shard's coverage watermark
/// by querying the frozen remote snapshots for every new local item. Runs
/// inside the worker, after a batch of inserts, while it still holds its
/// own write guard (so core distances are current). Items are covered in
/// order; the walk stops early when the local core distance is still +∞
/// (fewer than MinPts neighbors known — retried next batch, or picked up
/// by the merge catch-up) or when any remote snapshot is missing. Each
/// covered item records the snapshot lengths it saw, so the next merge's
/// catch-up can re-search exactly the pairs this window could not see.
fn bridge_new_items<T: EngineItem, M: Metric<T> + Clone>(
    st: &ShardState<T, M>,
    ctx: &BridgeCtx<T, M>,
) {
    let s = ctx.n_shards;
    if s < 2 || ctx.bridge_k == 0 || ctx.bridge_fanout == 0 {
        return;
    }
    let len = st.f.len();
    {
        // cheap pre-check without cloning any snapshot Arcs
        let br = ctx.bridge.lock().unwrap();
        if br.covered >= len {
            return;
        }
    }
    // frozen remote views; bail if any shard has not published one yet
    // (first refresh happens at the first merge) or has grown too far past
    // its snapshot — the merge catch-up covers those items against live
    // state instead
    let mut snaps: Vec<Option<Arc<ShardSnap<T, M>>>> = Vec::with_capacity(s);
    for t in 0..s {
        if t == ctx.si {
            snaps.push(None);
        } else {
            match ctx.snaps.get(t) {
                Some(sn) => {
                    // stale in absolute terms (grew past the lag budget) or
                    // in relative terms (more than doubled — catches the
                    // empty/tiny snapshot a premature merge publishes):
                    // covering against such a view would push too much work
                    // into the catch-up's re-search, so leave those items
                    // uncovered instead
                    let snap_len = sn.items.len();
                    let live = ctx.snaps.live_len(t);
                    if live.saturating_sub(snap_len) > ctx.lag_limit
                        || snap_len * 2 < live
                    {
                        return;
                    }
                    snaps.push(Some(sn));
                }
                None => return,
            }
        }
    }

    let t0 = Instant::now();
    let fanout = ctx.bridge_fanout.min(s - 1);
    let mut br = ctx.bridge.lock().unwrap();
    let mut changed = false;
    while br.covered < len {
        let li = br.covered;
        // tombstoned mid-window: nothing to bridge, and its +∞ core must
        // not stall the watermark forever — count it covered and move on
        if !st.f.alive(li as u32) {
            br.covered = li + 1;
            br.insert_items += 1;
            continue;
        }
        let ci = st.f.core_distance(li as u32);
        if !ci.is_finite() {
            break; // too few neighbors yet; retry once the shard has grown
        }
        let gi = st.globals[li];
        let item = &st.f.items()[li];
        for j in 0..fanout {
            let t = rotation_target(ctx.si, li, j, s);
            let snap = snaps[t].as_ref().expect("remote snapshot present");
            for (rj, d) in snap.nearest(item, ctx.bridge_k) {
                let w = d.max(ci).max(snap.cores[rj as usize]);
                if br.offer(gi, snap.globals[rj as usize], w) {
                    br.insert_edges += 1;
                    changed = true;
                }
            }
            br.note_window_snap(t, snap.inserts as usize);
        }
        br.covered = li + 1;
        br.insert_items += 1;
    }
    br.maybe_compact(ctx.alpha, len, &ctx.deleted);
    if changed {
        br.generation += 1;
    }
    br.insert_secs += t0.elapsed().as_secs_f64();
}

// ------------------------------------------------------------- the shard --

/// Handle to one running shard worker.
pub(crate) struct Shard<T, M> {
    pub state: Arc<RwLock<ShardState<T, M>>>,
    /// The shard's bridge buffer (shared with its worker).
    pub bridge: Arc<Mutex<BridgeState>>,
    tx: SyncSender<ShardCmd<T>>,
    /// `AddBatch` commands sent but not yet dequeued by the worker.
    /// `sync_channel` has no capacity introspection, so this shadow count
    /// is what the non-blocking admission path ([`Engine::try_add_batch`])
    /// checks against `queue_depth`: slots are reserved here *before*
    /// sending, and released by the worker at dequeue. The blocking
    /// [`Shard::send`] path bumps it too, so both paths see one coherent
    /// queue picture.
    ///
    /// [`Engine::try_add_batch`]: crate::engine::Engine::try_add_batch
    pending: Arc<AtomicUsize>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl<T: EngineItem, M: Metric<T> + Clone + 'static> Shard<T, M> {
    /// Spawn a fresh, empty shard.
    pub fn spawn(
        id: usize,
        metric: Counting<M>,
        params: FishdbcParams,
        queue_depth: usize,
        ctx: BridgeCtxSeed<T, M>,
    ) -> Shard<T, M> {
        Shard::resume(
            id,
            ShardState::new(metric, params),
            BridgeState::new(),
            queue_depth,
            ctx,
        )
    }

    /// Spawn a worker around pre-existing state (engine reload).
    pub fn resume(
        id: usize,
        state: ShardState<T, M>,
        bridge: BridgeState,
        queue_depth: usize,
        ctx: BridgeCtxSeed<T, M>,
    ) -> Shard<T, M> {
        let (tx, rx) = sync_channel(queue_depth.max(1));
        let state = Arc::new(RwLock::new(state));
        let bridge = Arc::new(Mutex::new(bridge));
        let worker_state = Arc::clone(&state);
        ctx.snaps.set_len(id, state.read().unwrap().f.len());
        let worker_ctx = BridgeCtx {
            si: id,
            n_shards: ctx.n_shards,
            bridge_k: ctx.bridge_k,
            bridge_fanout: ctx.bridge_fanout,
            alpha: ctx.alpha,
            lag_limit: ctx.lag_limit,
            snaps: ctx.snaps,
            bridge: Arc::clone(&bridge),
            deleted: ctx.deleted,
            obs: ctx.obs,
        };
        let pending = Arc::new(AtomicUsize::new(0));
        let worker_pending = Arc::clone(&pending);
        let handle = std::thread::Builder::new()
            .name(format!("fishdbc-shard-{id}"))
            .spawn(move || run(worker_state, rx, worker_ctx, worker_pending))
            .expect("spawn shard worker");
        Shard { state, bridge, tx, pending, handle: Mutex::new(Some(handle)) }
    }
}

// No bounds: `Engine`'s `Drop` (also unbounded) shuts workers down through
// these for every instantiation.
impl<T, M> Shard<T, M> {
    /// Enqueue a command (blocks when the queue is full — backpressure).
    pub fn send(&self, cmd: ShardCmd<T>) {
        if matches!(cmd, ShardCmd::AddBatch(_)) {
            self.pending.fetch_add(1, Ordering::Relaxed);
        }
        self.tx.send(cmd).expect("shard worker gone");
    }

    /// Reserve one `AddBatch` queue slot iff fewer than `depth` batches
    /// are pending, without blocking. The caller must follow up with
    /// either [`Shard::send_reserved`] or [`Shard::release_batch_slot`].
    pub fn try_reserve_batch_slot(&self, depth: usize) -> bool {
        self.pending
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |p| {
                (p < depth.max(1)).then_some(p + 1)
            })
            .is_ok()
    }

    /// Give back a slot taken by [`Shard::try_reserve_batch_slot`]
    /// without sending anything (the all-or-nothing admission path backs
    /// out reservations on sibling shards when one shard is full).
    pub fn release_batch_slot(&self) {
        self.pending.fetch_sub(1, Ordering::Relaxed);
    }

    /// Send an `AddBatch` whose queue slot was already reserved. The
    /// channel itself can only momentarily block behind a `Flush` or
    /// `Shutdown` command (those do not take batch slots); batch-vs-batch
    /// backpressure was settled at reservation time.
    pub fn send_reserved(&self, batch: Vec<(u32, T)>) {
        self.tx.send(ShardCmd::AddBatch(batch)).expect("shard worker gone");
    }

    /// Idempotent: safe to call from both `Engine::shutdown` and `Drop` —
    /// including during a panic unwind with poisoned locks (a worker that
    /// died holding its state lock must not turn drop into a double
    /// panic/abort; its handle is still joined).
    pub fn shutdown(&self) {
        let _ = self.tx.send(ShardCmd::Shutdown);
        let mut guard = self.handle.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(h) = guard.take() {
            let _ = h.join();
        }
    }
}

/// The engine-owned parts of a worker's bridge context (the per-shard
/// pieces — id and buffer — are filled in by [`Shard::resume`]).
pub(crate) struct BridgeCtxSeed<T, M> {
    pub n_shards: usize,
    pub bridge_k: usize,
    pub bridge_fanout: usize,
    pub alpha: f64,
    pub lag_limit: usize,
    pub snaps: Arc<Snaps<T, M>>,
    pub deleted: Arc<Mutex<FastSet<u32>>>,
    pub obs: Arc<Registry>,
}

fn run<T: EngineItem, M: Metric<T> + Clone>(
    state: Arc<RwLock<ShardState<T, M>>>,
    rx: Receiver<ShardCmd<T>>,
    ctx: BridgeCtx<T, M>,
    pending: Arc<AtomicUsize>,
) {
    loop {
        match rx.recv() {
            Err(_) => break, // engine dropped without Shutdown
            Ok(ShardCmd::AddBatch(batch)) => {
                // slot freed at dequeue: the batch being *applied* no
                // longer counts against the admission depth
                pending.fetch_sub(1, Ordering::Relaxed);
                let t0 = Instant::now();
                let mut st = state.write().unwrap();
                st.inserts += batch.len() as u64;
                for (gid, item) in batch {
                    st.f.add(item);
                    st.globals.push(gid);
                }
                st.batches += 1;
                st.version += 1;
                let applied = t0.elapsed();
                st.build_secs += applied.as_secs_f64();
                ctx.obs.record(HistId::ShardInsert, applied);
                ctx.snaps.set_len(ctx.si, st.f.len());
                // insert-time bridge discovery against frozen snapshots
                // (lock order: own state write guard → own bridge mutex)
                bridge_new_items(&st, &ctx);
            }
            Ok(ShardCmd::Flush(reply)) => {
                {
                    let mut st = state.write().unwrap();
                    st.f.update_mst();
                    bridge_new_items(&st, &ctx);
                }
                let _ = reply.send(());
            }
            Ok(ShardCmd::Shutdown) => break,
        }
    }
}
