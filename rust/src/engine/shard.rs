//! One shard of the parallel ingest engine: a worker thread owning a
//! [`Fishdbc`] over a hash-partitioned slice of the item space, plus the
//! local→global id map that lets the merge relabel its MSF edges.
//!
//! The state sits behind an `RwLock` so the merge and the online query path
//! can read it concurrently; only the shard's own worker ever writes, and it
//! never takes another shard's lock — no lock-ordering cycles exist.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::distances::{Item, MetricKind};
use crate::fishdbc::{Fishdbc, FishdbcParams};

/// Commands a shard worker processes in FIFO order.
pub(crate) enum ShardCmd {
    /// Insert `(global id, item)` pairs (ids were assigned by the router).
    AddBatch(Vec<(u32, Item)>),
    /// Drain the queue up to this point, fold buffered candidate edges into
    /// the local MSF, then ack — the engine's barrier primitive.
    Flush(SyncSender<()>),
    Shutdown,
}

/// Shard-local state: the FISHDBC instance plus bookkeeping.
pub(crate) struct ShardState {
    pub f: Fishdbc<Item, MetricKind>,
    /// `globals[local_id] = global_id` (dense, append-only).
    pub globals: Vec<u32>,
    pub batches: u64,
    /// Wall time this shard spent inserting (its lane of the build).
    pub build_secs: f64,
}

impl ShardState {
    pub fn new(metric: MetricKind, params: FishdbcParams) -> ShardState {
        ShardState {
            f: Fishdbc::new(metric, params),
            globals: Vec::new(),
            batches: 0,
            build_secs: 0.0,
        }
    }
}

/// Handle to one running shard worker.
pub(crate) struct Shard {
    pub state: Arc<RwLock<ShardState>>,
    tx: SyncSender<ShardCmd>,
    handle: Option<JoinHandle<()>>,
}

impl Shard {
    /// Spawn a fresh, empty shard.
    pub fn spawn(
        id: usize,
        metric: MetricKind,
        params: FishdbcParams,
        queue_depth: usize,
    ) -> Shard {
        Shard::resume(id, ShardState::new(metric, params), queue_depth)
    }

    /// Spawn a worker around pre-existing state (engine reload).
    pub fn resume(id: usize, state: ShardState, queue_depth: usize) -> Shard {
        let (tx, rx) = sync_channel(queue_depth.max(1));
        let state = Arc::new(RwLock::new(state));
        let worker_state = Arc::clone(&state);
        let handle = std::thread::Builder::new()
            .name(format!("fishdbc-shard-{id}"))
            .spawn(move || run(worker_state, rx))
            .expect("spawn shard worker");
        Shard { state, tx, handle: Some(handle) }
    }

    /// Enqueue a command (blocks when the queue is full — backpressure).
    pub fn send(&self, cmd: ShardCmd) {
        self.tx.send(cmd).expect("shard worker gone");
    }

    /// Idempotent: safe to call from both `Engine::shutdown` and `Drop`.
    pub fn shutdown(&mut self) {
        let _ = self.tx.send(ShardCmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run(state: Arc<RwLock<ShardState>>, rx: Receiver<ShardCmd>) {
    loop {
        match rx.recv() {
            Err(_) => break, // engine dropped without Shutdown
            Ok(ShardCmd::AddBatch(batch)) => {
                let t0 = Instant::now();
                let mut st = state.write().unwrap();
                for (gid, item) in batch {
                    st.f.add(item);
                    st.globals.push(gid);
                }
                st.batches += 1;
                st.build_secs += t0.elapsed().as_secs_f64();
            }
            Ok(ShardCmd::Flush(reply)) => {
                state.write().unwrap().f.update_mst();
                let _ = reply.send(());
            }
            Ok(ShardCmd::Shutdown) => break,
        }
    }
}
