//! Global merge: per-shard minimum spanning forests plus a bounded set of
//! cross-shard bridge edges, folded by one edge-union Kruskal pass and
//! condensed into the global clustering.
//!
//! Correctness rests on the same lemma as Algorithm 1's UPDATE_MST: an MSF
//! of a union graph only draws edges from the MSFs of its parts plus the
//! extra edges offered alongside them. The parts here are the shard-local
//! candidate graphs; the extra edges are the bridges. Bridges use mutual
//! reachability max(d, core_s(x), core_t(y)) with each endpoint's core
//! distance taken from its own shard — shard-local cores are computed from a
//! uniform subsample (hash routing), so they estimate the same densities the
//! single-shard run sees, at 1/S the sample rate.

use std::time::Instant;

use crate::hdbscan::cluster_from_msf_opts;
use crate::mst::{Edge, Msf};

use super::shard::ShardState;
use super::{Engine, EngineSnapshot};

impl Engine {
    /// CLUSTER across all shards: flush, relabel per-shard MSFs into the
    /// global id space, add bridge edges, run one Kruskal + condense +
    /// extract pass. The snapshot is also cached for [`Engine::latest`] and
    /// the online query path.
    pub fn cluster(&self, mcs: usize) -> EngineSnapshot {
        self.flush();
        let t0 = Instant::now();
        let guards: Vec<_> = self
            .shard_handles()
            .iter()
            .map(|s| s.state.read().unwrap())
            .collect();
        let states: Vec<&ShardState> = guards.iter().map(|g| &**g).collect();
        let n_items: usize = states.iter().map(|st| st.f.len()).sum();
        // the label space must cover every *applied* global id — with
        // concurrent ingestion a shard can have applied ids whose batch
        // siblings are still queued elsewhere, and interleaved add_batch
        // callers can even make a shard's globals non-monotone, so scan
        // for the true maximum
        let n = states
            .iter()
            .filter_map(|st| st.globals.iter().copied().max())
            .max()
            .map_or(0, |m| m as usize + 1)
            .max(n_items);

        // per-shard MSF edges, relabeled local → global
        let mut lists: Vec<Vec<Edge>> = Vec::with_capacity(states.len() + 1);
        for st in &states {
            lists.push(
                st.f.msf_edges()
                    .iter()
                    .map(|e| {
                        Edge::new(
                            st.globals[e.a as usize],
                            st.globals[e.b as usize],
                            e.w,
                        )
                    })
                    .collect(),
            );
        }
        let bridges = bridge_edges(
            &states,
            self.config().bridge_k,
            self.config().bridge_fanout,
        );
        let n_bridge_edges = bridges.len();
        lists.push(bridges);
        // edge lists are owned from here on: release the shards before the
        // (potentially long) global Kruskal + condense pass so ingest never
        // stalls behind extraction
        drop(states);
        drop(guards);

        let refs: Vec<&[Edge]> = lists.iter().map(|l| l.as_slice()).collect();
        let msf = Msf::from_edge_lists(&refs, n.max(1));
        let clustering = cluster_from_msf_opts(msf.edges(), n.max(1), mcs, false);

        let snap = EngineSnapshot {
            n_items,
            n_shards: self.n_shards(),
            n_bridge_edges,
            n_msf_edges: msf.edges().len(),
            extract_secs: t0.elapsed().as_secs_f64(),
            clustering,
        };
        self.set_latest(snap.clone());
        snap
    }
}

/// Bounded cross-shard candidate edges. Every item queries the HNSWs of up
/// to `fanout` *other* shards (rotating per item so all shard pairs are
/// covered even at fanout 1) for its `k` nearest remote neighbors; each hit
/// becomes an edge weighted by mutual reachability under the two shards'
/// core distances. Read-only and embarrassingly parallel: one scoped thread
/// per source shard, no locks taken (the caller holds read guards).
pub(crate) fn bridge_edges(
    states: &[&ShardState],
    k: usize,
    fanout: usize,
) -> Vec<Edge> {
    let s = states.len();
    if s < 2 || k == 0 || fanout == 0 {
        return Vec::new();
    }
    let fanout = fanout.min(s - 1);
    // remote core distances, fetched in bulk once per shard
    let cores: Vec<Vec<f64>> =
        states.iter().map(|st| st.f.core_distances()).collect();
    let cores = &cores;

    let mut per_shard: Vec<Vec<Edge>> = Vec::with_capacity(s);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(s);
        for (si, st) in states.iter().enumerate() {
            let states = &*states;
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                for (li, item) in st.f.items().iter().enumerate() {
                    let gi = st.globals[li];
                    let ci = cores[si][li];
                    for j in 0..fanout {
                        // offset in [1, s-1]: never self, distinct per j
                        let t = (si + 1 + (li + j) % (s - 1)) % s;
                        let remote = states[t];
                        for (rj, d) in remote.f.nearest(item, k, None) {
                            let w = d.max(ci).max(cores[t][rj as usize]);
                            out.push(Edge::new(
                                gi,
                                remote.globals[rj as usize],
                                w,
                            ));
                        }
                    }
                }
                out
            }));
        }
        for h in handles {
            per_shard.push(h.join().expect("bridge worker panicked"));
        }
    });
    per_shard.concat()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::distances::{Item, MetricKind};
    use crate::engine::EngineConfig;
    use crate::fishdbc::FishdbcParams;

    fn blob_items(n: usize, seed: u64) -> Vec<Item> {
        datasets::blobs::generate(n, 16, 4, seed).items
    }

    #[test]
    fn bridges_connect_the_global_forest() {
        // Without bridges, S shards yield >= S components; with them, the
        // merged forest must be as connected as the data (blobs: finite
        // metric => one component per merge of everything discovered).
        let items = blob_items(600, 21);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards: 4,
            mcs: 5,
            ..Default::default()
        });
        for chunk in items.chunks(100) {
            engine.add_batch(chunk.to_vec());
        }
        let snap = engine.cluster(5);
        assert_eq!(snap.n_items, 600);
        assert!(snap.n_bridge_edges > 0, "4 shards must produce bridges");
        // a spanning structure over 600 points from 4 partial forests
        assert!(
            snap.n_msf_edges >= 590,
            "merged forest too fragmented: {} edges",
            snap.n_msf_edges
        );
        // labels cover the whole global id space
        assert_eq!(snap.clustering.labels.len(), 600);
        assert!(snap.clustering.n_clusters >= 2);
        engine.shutdown();
    }

    #[test]
    fn bridge_fanout_rotation_covers_pairs() {
        // with fanout 1 the rotation must still bridge every ordered pair
        // eventually; verify the target formula stays in range and != self
        let s = 5usize;
        for si in 0..s {
            let mut seen = std::collections::HashSet::new();
            for li in 0..64 {
                let t = (si + 1 + (li % (s - 1))) % s;
                assert_ne!(t, si);
                assert!(t < s);
                seen.insert(t);
            }
            assert_eq!(seen.len(), s - 1, "rotation misses shards");
        }
    }

    #[test]
    fn snapshot_cached_for_latest() {
        let items = blob_items(200, 23);
        let engine =
            Engine::spawn(MetricKind::Euclidean, EngineConfig::default());
        engine.add_batch(items);
        assert!(engine.latest().is_none());
        let snap = engine.cluster(10);
        let cached = engine.latest().expect("snapshot cached");
        assert_eq!(cached.n_items, snap.n_items);
        assert_eq!(cached.clustering.labels, snap.clustering.labels);
        engine.shutdown();
    }
}
