//! Global merge, delta-aware: per-shard minimum spanning forests plus the
//! buffered cross-shard bridge edges, folded into the cached global forest
//! by one edge-union Kruskal pass, then run through the shared
//! [`Pipeline`](super::pipeline::Pipeline).
//!
//! Correctness rests on the same lemma as Algorithm 1's UPDATE_MST: an MSF
//! of a union graph only draws edges from the MSFs of its parts plus the
//! extra edges offered alongside them. The parts here are the shard-local
//! candidate graphs and the previous epoch's union graph — summarized
//! losslessly by the cached global MSF, because the union graph only ever
//! grows and the cycle property means an edge once evicted can never
//! re-enter any MSF. So Kruskal re-runs only over (cached global forest ∪
//! changed shards' forests ∪ changed shards' bridge sets), and a merge
//! where nothing changed reuses the cached forest outright.
//!
//! **The cached-MSF lemma is explicitly *non-monotone-unsafe*.** Deletion
//! removes nodes, so the union graph can shrink — and then "an evicted
//! edge can never re-enter" stops being true: an edge that lost a Kruskal
//! cycle *through a now-deleted node* would belong in the new MSF, but
//! nobody retained it. The engine handles this in two layers. (1) A
//! shard's merge **stamp includes its cumulative removal count**, so any
//! deletion flips that shard to "changed" and its whole surviving
//! contribution (tombstone-filtered forest + bridge set) is re-derived
//! from live state. (2) A window that saw any deletion **drops the cached
//! global forest outright** and re-folds every retained structure — all
//! current forests plus all bridge sets, filtered of deleted endpoints.
//! Merely filtering the cache would not do: it can neither resurrect an
//! edge it evicted through a dead cycle nor notice an edge its source
//! structure dropped inside the same window (see `merge_forest`). The
//! O(Δ) cached path is therefore only ever taken across *monotone*
//! windows, where the lemma holds unconditionally. What deletion can
//! still lose — inside retained per-shard structures — are candidate
//! edges evicted in earlier epochs by Kruskal cycles through the deleted
//! node (they were never recorded anywhere); that residual approximation
//! is shared with the reference oracle (which reads the same retained
//! structures) and erased by compaction, which replays the shard's
//! survivors from scratch once the tombstone ratio crosses
//! `EngineConfig::compact_at`. The conformance contract is unaffected:
//! [`Engine::reference_cluster`] merges the same surviving state from
//! scratch, and the stress harness holds every epoch to it.
//!
//! Bridges use mutual reachability max(d, core_s(x), core_t(y)) with each
//! endpoint's core distance taken from its own shard — shard-local cores
//! are computed from a uniform subsample (hash routing), so they estimate
//! the same densities the single-shard run sees, at 1/S the sample rate.
//! Most bridge candidates are discovered at insert time (see
//! `engine/shard.rs`); the merge's *catch-up* pass below does two bounded
//! jobs: it first-covers the items above each shard's coverage watermark,
//! and it **re-searches the same-epoch window** — items insert-covered
//! since the previous merge queried frozen snapshots that can predate
//! remote items of the same window, so the catch-up searches them once
//! more against the live post-flush states (skipping remote shards that
//! did not grow past what the window already saw). A cross-shard pair
//! whose two endpoints arrived inside one epoch window is therefore found
//! at the window-closing merge, from whichever side re-searches first;
//! no pair is ever silently dropped. Both jobs scale with the delta since
//! the previous epoch, never with total n.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::distances::Metric;
use crate::hdbscan::ExtractionMode;
use crate::mst::{Edge, Msf};
use crate::obs::{CacheKind, CounterId, HistId, JournalEvent, Registry};
use crate::util::fasthash::{FastMap, FastSet};

use super::pipeline::Pipeline;
use super::shard::{rotation_target, BridgeState, ShardState};
use super::{Engine, EngineInner, EngineItem, EngineSnapshot};

/// Per-shard change stamp recorded at each merge: a shard whose stamp is
/// unchanged contributed nothing new since the cached merge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct ShardStamp {
    pub items: usize,
    pub mst_updates: u64,
    pub msf_len: usize,
    pub bridge_gen: u64,
    /// Cumulative removals ([`ShardState::removed_globals`] length): any
    /// deletion must flip the shard to "changed" — the cached-path lemma
    /// assumes monotone growth (see the module docs) — even when it
    /// happens to leave the item count and forest length untouched.
    pub removals: usize,
}

/// The previous epoch's merge result (the "cached global MSF").
pub(crate) struct MergeCache {
    pub global: Msf,
    pub n: usize,
    pub stamps: Vec<ShardStamp>,
}

/// Engine-side pipeline state: the shared back-half pipeline plus the
/// Kruskal-level merge cache. Guarded by `EngineInner::merge`.
pub(crate) struct MergeState {
    pub pipeline: Pipeline,
    pub cache: Option<MergeCache>,
    pub merges: u64,
    /// Epoch number the cached forest was published under. Kept here
    /// (not in [`MergeCache`], which persistence rebuilds with no epoch
    /// memory) so on-demand extraction (`Engine::relabel_at`) can pin
    /// its result to the exact epoch of the forest it reads — `latest()`
    /// can lag this by a moment, since snapshots publish after the merge
    /// lock drops.
    pub last_epoch: u64,
    /// Cumulative deleted-gid list of that epoch, for label masking on
    /// the on-demand extraction path (same mask the merge applied).
    pub last_removed: Vec<u32>,
}

impl Default for MergeState {
    fn default() -> Self {
        MergeState::new()
    }
}

impl MergeState {
    pub fn new() -> MergeState {
        MergeState {
            pipeline: Pipeline::new(),
            cache: None,
            merges: 0,
            last_epoch: 0,
            last_removed: Vec::new(),
        }
    }

    /// Rebuild from persisted epoch state (FISHENG v2).
    pub fn resumed(cache: Option<MergeCache>) -> MergeState {
        MergeState {
            pipeline: Pipeline::new(),
            cache,
            merges: 0,
            last_epoch: 0,
            last_removed: Vec::new(),
        }
    }

    /// Re-home the back-half pipeline onto the engine's shared telemetry
    /// registry, so pipeline spans and counters land in the same
    /// [`Registry`] every other engine metric uses. Safe any time before
    /// the first merge: the pipeline's memo caches are empty at
    /// construction and at load, so swapping the instance loses nothing.
    pub fn attach_registry(&mut self, obs: Arc<Registry>) {
        self.pipeline = Pipeline::with_registry(obs);
    }
}

impl<T: EngineItem, M: Metric<T> + Clone + 'static> Engine<T, M> {
    /// CLUSTER across all shards: flush, catch up bridge coverage, fold
    /// the deltas into the cached global forest with one Kruskal pass, and
    /// re-extract (or short-circuit) the clustering through the shared
    /// pipeline. Publishes the result as the next epoch for
    /// [`Engine::latest`] and the online query path, and refreshes the
    /// frozen snapshots that insert-time bridging queries.
    pub fn cluster(&self, mcs: usize) -> EngineSnapshot {
        (*self.inner().cluster(mcs)).clone()
    }
}

impl<T: EngineItem, M: Metric<T> + Clone + 'static> EngineInner<T, M> {
    pub(crate) fn cluster(&self, mcs: usize) -> Arc<EngineSnapshot> {
        self.flush();
        let t0 = Instant::now();
        let guards: Vec<_> = self
            .shard_handles()
            .iter()
            .map(|s| s.state.read().unwrap())
            .collect();
        let states: Vec<&ShardState<T, M>> = guards.iter().map(|g| &**g).collect();
        let bridges: Vec<&Arc<Mutex<BridgeState>>> =
            self.shard_handles().iter().map(|s| &s.bridge).collect();
        let (n_items, removed, n) = survivor_space(&states);
        let obs = self.obs();
        obs.journal
            .push(obs.uptime_secs(), JournalEvent::MergeStart { n_items });

        // 1. bridge catch-up: first-cover above each coverage watermark,
        //    re-search the closing same-epoch window below it
        let tb = Instant::now();
        catch_up_bridges(
            &states,
            &bridges,
            self.config().bridge_k,
            self.config().bridge_fanout,
            self.config().fishdbc.alpha,
            self.deleted_registry(),
            obs,
        );
        let bridge_elapsed = tb.elapsed();
        let bridge_secs = bridge_elapsed.as_secs_f64();
        obs.record(HistId::BridgeCatchUp, bridge_elapsed);

        // 2. delta Kruskal under the merge lock (serializes merges; the
        //    serving path never takes this lock)
        let mut ms = self.merge.lock().unwrap();
        let stamps: Vec<ShardStamp> = states
            .iter()
            .zip(&bridges)
            .map(|(st, br)| {
                let b = br.lock().unwrap();
                ShardStamp {
                    items: st.f.len(),
                    mst_updates: st.f.stats().mst_updates,
                    msf_len: st.f.msf_edges().len(),
                    bridge_gen: b.generation,
                    removals: st.removed_globals.len(),
                }
            })
            .collect();
        let tk = Instant::now();
        let (msf, n_bridge_edges, n_changed_shards, cache_kind) =
            merge_forest(ms.cache.as_ref(), &states, &bridges, &stamps, n, &removed);
        let kruskal_elapsed = tk.elapsed();
        let kruskal_secs = kruskal_elapsed.as_secs_f64();
        obs.record(HistId::Kruskal, kruskal_elapsed);

        // 3. next epoch's frozen snapshots, while the read guards are
        //    still held (so they capture exactly the merged state)
        self.refresh_snaps_from(&states);
        let epoch = self.next_epoch();
        // edge lists are owned from here on: release the shards before the
        // (potentially long) condense/extract pass so ingest never stalls
        // behind extraction
        drop(states);
        drop(guards);

        // 4. back half through the shared pipeline (content-hash cached)
        let (mut clustering, stages) = ms.pipeline.run(msf.edges(), n, mcs, false);
        let n_msf_edges = msf.edges().len();
        ms.cache = Some(MergeCache { global: msf, n, stamps });
        ms.merges += 1;
        ms.last_epoch = epoch;
        ms.last_removed = removed.clone();
        drop(ms);

        // deleted ids label -1 in every epoch (they are edge-free
        // singletons already; the mask pins the contract)
        mask_deleted(&mut clustering.labels, &removed);

        let snap = Arc::new(EngineSnapshot {
            epoch,
            n_items,
            n_deleted: removed.len(),
            n_shards: self.n_shards(),
            n_bridge_edges,
            n_msf_edges,
            n_changed_shards,
            bridge_secs,
            kruskal_secs,
            stages,
            extract_secs: t0.elapsed().as_secs_f64(),
            clustering,
        });
        self.set_latest(Arc::clone(&snap));

        // exactly one MergeEnd per published epoch — the journal entry's
        // shard-change count and cache kind are cross-checked against the
        // registry counters by `tests/engine_integration.rs`
        let total = t0.elapsed();
        obs.inc(CounterId::Merges);
        obs.inc(match cache_kind {
            CacheKind::Reused => CounterId::MergeReused,
            CacheKind::Delta => CounterId::MergeDelta,
            CacheKind::Rebuild => CounterId::MergeRebuild,
            CacheKind::Scratch => CounterId::MergeScratch,
        });
        obs.record(HistId::Merge, total);
        // the merge's own flat cut is an extraction like any other: it
        // gets the same audit-trail event the parameterized paths push
        obs.journal.push(
            obs.uptime_secs(),
            JournalEvent::ExtractionEnd {
                epoch,
                mcs,
                eps: 0.0,
                mode: ExtractionMode::Stability.name(),
                cache_hit: stages.reused_clustering,
            },
        );
        obs.journal.push(
            obs.uptime_secs(),
            JournalEvent::MergeEnd {
                epoch,
                n_changed_shards,
                cache: cache_kind,
                n_items,
                n_deleted: removed.len(),
                secs: total.as_secs_f64(),
            },
        );
        snap
    }
}

/// Force every deleted global id to the noise label (shared by the delta
/// merge, the reference merge, and the parameterized extraction path
/// `Engine::relabel_at`, so the three cannot drift).
pub(crate) fn mask_deleted(labels: &mut [i32], removed: &[u32]) {
    for &gid in removed {
        if let Some(l) = labels.get_mut(gid as usize) {
            *l = -1;
        }
    }
}

/// Survivor accounting, shared verbatim by the delta merge and the
/// reference merge (the conformance contract depends on both paths
/// computing the identical id space): `(live item count, cumulative
/// deleted-gid list, label-space size n)`.
///
/// `n_items` counts survivors only — tombstones occupy label slots but
/// are not items. The label space must cover every *applied* global id:
/// with concurrent ingestion a shard can have applied ids whose batch
/// siblings are still queued elsewhere, and interleaved `add_batch`
/// callers can even make a shard's globals non-monotone, so scan for the
/// true maximum. Deleted ids keep their (noise) slots even after
/// compaction erases them from the id maps — the stream stays
/// index-aligned — so the removed list joins the scan.
fn survivor_space<T: EngineItem, M: Metric<T> + Clone>(
    states: &[&ShardState<T, M>],
) -> (usize, Vec<u32>, usize) {
    let n_items: usize = states.iter().map(|st| st.f.n_alive()).sum();
    let removed: Vec<u32> = states
        .iter()
        .flat_map(|st| st.removed_globals.iter().copied())
        .collect();
    let n = states
        .iter()
        .filter_map(|st| st.globals.iter().copied().max())
        .chain(removed.iter().copied())
        .max()
        .map_or(0, |m| m as usize + 1)
        .max(n_items);
    (n_items, removed, n)
}

/// Delta bridge search, two bounded jobs per source shard (one scoped
/// thread each, read-only against every shard state, locking only its own
/// bridge buffer — the caller holds read guards on every state):
///
/// 1. **Window re-search** (`[merge_covered, covered)`): items that were
///    insert-covered since the previous merge queried *frozen* snapshots,
///    which can predate remote items of the same epoch window — so a pair
///    whose two endpoints both arrived inside the window could have been
///    missed from both sides. Re-searching the window suffix against the
///    live post-flush states closes that gap exactly; remote shards that
///    did not grow past the smallest snapshot the window saw are skipped
///    (nothing new to find there).
/// 2. **First-pass coverage** (`[covered, len)`): the items insert-time
///    bridging could not reach (no snapshot yet, or snapshot too stale),
///    searched against the live states. Like the insert-time path, this
///    walk stops at an item whose core distance is still +∞ (fewer than
///    MinPts neighbors known): covering it now would pin infinite-weight
///    edges that nothing ever re-searches, so it waits for the next merge.
///
/// Both jobs then advance the merge-final watermark (`finish_window`), so
/// every item below `covered` has, at this barrier, searched remotes
/// containing every item that existed — which is what makes the
/// approximation gap *closed* rather than merely narrowed.
///
/// On a first merge every watermark is 0, so this degenerates to the full
/// O(n·k·fanout) search; afterwards it costs O(Δn·k·fanout).
pub(crate) fn catch_up_bridges<T: EngineItem, M: Metric<T> + Clone>(
    states: &[&ShardState<T, M>],
    bridges: &[&Arc<Mutex<BridgeState>>],
    k: usize,
    fanout: usize,
    alpha: f64,
    deleted: &Mutex<FastSet<u32>>,
    obs: &Registry,
) {
    let s = states.len();
    if s < 2 || k == 0 || fanout == 0 {
        return;
    }
    // nothing above any watermark and no window pending: skip spawning
    let idle = states.iter().zip(bridges).all(|(st, br)| {
        let b = br.lock().unwrap();
        b.covered >= st.f.len() && b.merge_covered >= b.covered
    });
    if idle {
        return;
    }
    let fanout = fanout.min(s - 1);

    std::thread::scope(|scope| {
        for (si, st) in states.iter().enumerate() {
            let states = &*states;
            let bridge = bridges[si];
            scope.spawn(move || {
                let mut br = bridge.lock().unwrap();
                let len = st.f.len();
                let mut changed = false;
                // One shared live-search body for both walks below, so the
                // bridge-weight formula (and therefore the conformance
                // contract) cannot silently diverge between them.
                let search_remote = |br: &mut BridgeState,
                                     changed: &mut bool,
                                     li: usize,
                                     ci: f64,
                                     t: usize| {
                    let gi = st.globals[li];
                    let item = &st.f.items()[li];
                    let remote = states[t];
                    for (rj, d) in remote.f.nearest(item, k, None) {
                        let w = d.max(ci).max(remote.f.cores()[rj as usize]);
                        if br.offer(gi, remote.globals[rj as usize], w) {
                            *changed = true;
                        }
                    }
                };
                // 1. same-epoch window re-search against live states
                // (per-shard span: the registry is Sync, so each scoped
                // thread records its own sample lock-free)
                let recheck_end = br.covered.min(len);
                let tw = Instant::now();
                let rechecking = br.merge_covered < recheck_end;
                for li in br.merge_covered..recheck_end {
                    // tombstoned inside the window: nothing left to bridge
                    if !st.f.alive(li as u32) {
                        continue;
                    }
                    // covered implies the core was finite when first
                    // searched — but a *deletion* can push it back to +∞
                    // (fewer known neighbors), so the guard is load-bearing
                    let ci = st.f.cores()[li];
                    if !ci.is_finite() {
                        continue;
                    }
                    let mut searched = false;
                    for j in 0..fanout {
                        let t = rotation_target(si, li, j, s);
                        // growth is judged on the monotone insert
                        // watermark, not the length (compaction shrinks
                        // lengths without shrinking content the window
                        // has not seen)
                        if states[t].inserts as usize <= br.window_seen(t) {
                            continue; // remote has nothing the window missed
                        }
                        searched = true;
                        search_remote(&mut br, &mut changed, li, ci, t);
                    }
                    if searched {
                        br.recheck_items += 1;
                    }
                }
                if rechecking {
                    obs.record(HistId::WindowResearch, tw.elapsed());
                }
                // 2. first-pass coverage above the watermark
                while br.covered < len {
                    let li = br.covered;
                    // tombstoned before ever being covered: count it
                    // covered (its +∞ core must not stall the walk)
                    if !st.f.alive(li as u32) {
                        br.covered = li + 1;
                        br.catch_up_items += 1;
                        continue;
                    }
                    // O(1) chunked reads (no O(n) bulk core fetch per merge)
                    let ci = st.f.cores()[li];
                    if !ci.is_finite() {
                        break; // retried at the next merge, once known
                    }
                    for j in 0..fanout {
                        let t = rotation_target(si, li, j, s);
                        search_remote(&mut br, &mut changed, li, ci, t);
                    }
                    br.covered = li + 1;
                    br.catch_up_items += 1;
                }
                br.maybe_compact(alpha, len, deleted);
                if changed {
                    br.generation += 1;
                }
                br.finish_window();
            });
        }
    });
}

/// Fold the deltas into a new global forest. Returns the forest, the
/// number of (deduplicated) bridge edges offered to this merge, the
/// number of stamp-changed shards, and which [`CacheKind`] path the fold
/// took (journaled per epoch and counted per kind by the telemetry
/// registry).
///
/// `removed` is the cumulative deleted-gid list. A window that saw any
/// deletion (detected on the removal stamps) **drops the cached global
/// forest entirely** and re-folds every retained structure — all current
/// shard forests plus all bridge sets, filtered of deleted endpoints.
/// Merely *filtering* the cached forest would be wrong in both
/// directions: an edge evicted from it by a Kruskal cycle through a
/// now-deleted node could never re-enter (the cycle no longer exists in
/// the survivors' graph), and a cached edge whose source structure
/// dropped it inside the same window would linger. Re-collection costs
/// one O(n)-edge Kruskal — no per-shard recompute and no bridge
/// re-search happen for untouched shards, whose stamps stay unchanged
/// (`n_changed_shards` proves it), and the next deletion-free window is
/// back on the cached path against the rebuilt cache.
fn merge_forest<T: EngineItem, M: Metric<T> + Clone>(
    cache: Option<&MergeCache>,
    states: &[&ShardState<T, M>],
    bridges: &[&Arc<Mutex<BridgeState>>],
    stamps: &[ShardStamp],
    n: usize,
    removed: &[u32],
) -> (Msf, usize, usize, CacheKind) {
    let valid = cache
        .map_or(false, |c| c.stamps.len() == stamps.len() && c.n <= n);
    let changed: Vec<bool> = if valid {
        let c = cache.expect("valid implies cache");
        stamps.iter().zip(&c.stamps).map(|(now, then)| now != then).collect()
    } else {
        vec![true; states.len()]
    };
    let n_changed = changed.iter().filter(|&&c| c).count();

    if valid && n_changed == 0 {
        // nothing moved since the previous epoch: reuse the cached forest
        // verbatim — skipping even the Kruskal pass keeps its edge order
        // (and therefore the pipeline's content hash) byte-stable, so the
        // back half short-circuits too. Sound under deletion because the
        // stamps include removal counts: n_changed == 0 implies no
        // deletion since the cache, and the cache was rebuilt clean at
        // the deletion's own merge.
        let c = cache.expect("valid implies cache");
        return (c.global.clone(), 0, 0, CacheKind::Reused);
    }

    // monotone window ⇔ no removal stamp moved: only then is the cached
    // forest a lossless summary (see the module docs)
    let monotone = valid && {
        let c = cache.expect("valid implies cache");
        stamps
            .iter()
            .zip(&c.stamps)
            .all(|(now, then)| now.removals == then.removals)
    };
    let select: Vec<bool> =
        if monotone { changed } else { vec![true; states.len()] };

    let deleted: FastSet<u32> = removed.iter().copied().collect();

    // selected shards' forests, relabeled local → global (tombstone-free
    // by construction: removal filters the local forest eagerly)
    let mut lists: Vec<Vec<Edge>> = Vec::with_capacity(states.len() + 1);
    for (si, st) in states.iter().enumerate() {
        if select[si] {
            lists.push(relabel_forest(st));
        }
    }
    // selected shards' bridge sets, deduplicated across shards: when item
    // a in S1 discovered b in S2 and b later discovered a, both buffers
    // hold the pair — offer one edge on the canonical (min, max) key with
    // the smaller weight. Buffers can still hold offers to since-deleted
    // remote items (frozen snapshots lag); those are dropped here.
    let bridge_list = dedup_bridges(bridges, &select, &deleted);
    let n_bridge_edges = bridge_list.len();
    lists.push(bridge_list);

    let mut refs: Vec<&[Edge]> = Vec::with_capacity(lists.len() + 1);
    if monotone {
        refs.push(cache.expect("monotone implies cache").global.edges());
    }
    refs.extend(lists.iter().map(|l| l.as_slice()));
    let msf = Msf::from_edge_lists(&refs, n.max(1));
    let kind = if !valid {
        CacheKind::Scratch
    } else if monotone {
        CacheKind::Delta
    } else {
        CacheKind::Rebuild
    };
    (msf, n_bridge_edges, n_changed, kind)
}

/// One shard's local forest relabeled into global ids (shared by the
/// delta merge and the reference merge so the two paths can never drift).
fn relabel_forest<T: EngineItem, M: Metric<T> + Clone>(
    st: &ShardState<T, M>,
) -> Vec<Edge> {
    st.f.msf_edges()
        .iter()
        .map(|e| {
            Edge::new(st.globals[e.a as usize], st.globals[e.b as usize], e.w)
        })
        .collect()
}

/// Canonical-key min-weight deduplication of the selected shards' bridge
/// sets, dropping edges to deleted endpoints (shared by the delta merge
/// and the reference merge).
fn dedup_bridges(
    bridges: &[&Arc<Mutex<BridgeState>>],
    selected: &[bool],
    deleted: &FastSet<u32>,
) -> Vec<Edge> {
    let mut dedup: FastMap<(u32, u32), f64> = FastMap::default();
    for (si, br) in bridges.iter().enumerate() {
        if selected[si] {
            let b = br.lock().unwrap();
            for e in b.edges() {
                if deleted.contains(&e.a) || deleted.contains(&e.b) {
                    continue;
                }
                dedup
                    .entry(Edge::key(e.a, e.b))
                    .and_modify(|w| {
                        if e.w < *w {
                            *w = e.w;
                        }
                    })
                    .or_insert(e.w);
            }
        }
    }
    dedup.into_iter().map(|((a, b), w)| Edge::new(a, b, w)).collect()
}

/// Result of [`Engine::reference_cluster`]: the conformance oracle the
/// deterministic stress harness compares every published epoch against.
#[derive(Clone, Debug)]
pub struct ReferenceMerge {
    /// Flat clustering extracted from the reference forest (no pipeline
    /// caches involved).
    pub clustering: crate::hdbscan::Clustering,
    /// Items covered.
    pub n_items: usize,
    /// Edges in the reference forest.
    pub n_msf_edges: usize,
    /// Total weight of the reference forest.
    pub msf_weight: f64,
}

impl<T: EngineItem, M: Metric<T> + Clone + 'static> Engine<T, M> {
    /// From-scratch **reference merge** for conformance testing: fold every
    /// shard's current forest plus every shard's current bridge set with
    /// one Kruskal pass — ignoring the cached global MSF, the per-shard
    /// change stamps, and the memoizing pipeline — and extract the
    /// clustering through the stage functions directly.
    ///
    /// By the merge invariants (module docs above) this must produce the
    /// same forest, and therefore the same labels, as the delta path; the
    /// deterministic stress harness (`tests/engine_stress.rs`) asserts
    /// exactly that after every published epoch — for the framework
    /// instantiation *and* for non-Euclidean typed engines. Under churn
    /// the oracle covers the **surviving set**: the reference replays the
    /// surviving state from scratch (tombstone-filtered forests, bridge
    /// sets filtered of deleted endpoints, no cached global MSF, no
    /// stamps), and deleted ids mask to -1 exactly as published epochs
    /// do. Read-only: no catch-up search runs, no epoch is published, no
    /// cache is touched — call it right after [`Engine::cluster`] (with
    /// no interleaved ingest) so both paths see identical shard state.
    #[doc(hidden)]
    pub fn reference_cluster(&self, mcs: usize) -> ReferenceMerge {
        let inner = self.inner();
        inner.flush();
        let guards: Vec<_> = inner
            .shard_handles()
            .iter()
            .map(|s| s.state.read().unwrap())
            .collect();
        let states: Vec<&ShardState<T, M>> = guards.iter().map(|g| &**g).collect();
        let bridges: Vec<&Arc<Mutex<BridgeState>>> =
            inner.shard_handles().iter().map(|s| &s.bridge).collect();
        let (n_items, removed, n) = survivor_space(&states);

        let deleted: FastSet<u32> = removed.iter().copied().collect();
        let lists: Vec<Vec<Edge>> =
            states.iter().map(|st| relabel_forest(st)).collect();
        let all = vec![true; states.len()];
        let bridge_list = dedup_bridges(&bridges, &all, &deleted);
        let mut refs: Vec<&[Edge]> =
            lists.iter().map(|l| l.as_slice()).collect();
        refs.push(&bridge_list);
        let msf = Msf::from_edge_lists(&refs, n.max(1));
        let mut clustering = crate::hdbscan::cluster_from_msf_opts(
            msf.edges(),
            n.max(1),
            mcs,
            false,
        );
        mask_deleted(&mut clustering.labels, &removed);
        ReferenceMerge {
            clustering,
            n_items,
            n_msf_edges: msf.edges().len(),
            msf_weight: msf.total_weight(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::distances::{Item, MetricKind};
    use crate::engine::EngineConfig;
    use crate::fishdbc::FishdbcParams;

    fn blob_items(n: usize, seed: u64) -> Vec<Item> {
        datasets::blobs::generate(n, 16, 4, seed).items
    }

    #[test]
    fn bridges_connect_the_global_forest() {
        // Without bridges, S shards yield >= S components; with them, the
        // merged forest must be as connected as the data (blobs: finite
        // metric => one component per merge of everything discovered).
        let items = blob_items(600, 21);
        let engine = Engine::spawn(MetricKind::Euclidean, EngineConfig {
            fishdbc: FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
            shards: 4,
            mcs: 5,
            ..Default::default()
        });
        for chunk in items.chunks(100) {
            engine.add_batch(chunk.to_vec());
        }
        let snap = engine.cluster(5);
        assert_eq!(snap.n_items, 600);
        assert!(snap.n_bridge_edges > 0, "4 shards must produce bridges");
        assert_eq!(snap.n_changed_shards, 4, "first merge sees all shards");
        // a spanning structure over 600 points from 4 partial forests
        assert!(
            snap.n_msf_edges >= 590,
            "merged forest too fragmented: {} edges",
            snap.n_msf_edges
        );
        // labels cover the whole global id space
        assert_eq!(snap.clustering.labels.len(), 600);
        assert!(snap.clustering.n_clusters >= 2);
        engine.shutdown();
    }

    #[test]
    fn bridge_fanout_rotation_covers_pairs() {
        // with fanout 1 the rotation must still bridge every ordered pair
        // eventually; verify the target stays in range and != self
        let s = 5usize;
        for si in 0..s {
            let mut seen = std::collections::HashSet::new();
            for li in 0..64 {
                let t = rotation_target(si, li, 0, s);
                assert_ne!(t, si);
                assert!(t < s);
                seen.insert(t);
            }
            assert_eq!(seen.len(), s - 1, "rotation misses shards");
        }
    }

    #[test]
    fn snapshot_cached_for_latest() {
        let items = blob_items(200, 23);
        let engine =
            Engine::spawn(MetricKind::Euclidean, EngineConfig::default());
        engine.add_batch(items);
        assert!(engine.latest().is_none());
        let snap = engine.cluster(10);
        let cached = engine.latest().expect("snapshot cached");
        assert_eq!(cached.n_items, snap.n_items);
        assert_eq!(cached.epoch, snap.epoch);
        assert_eq!(cached.clustering.labels, snap.clustering.labels);
        engine.shutdown();
    }

    #[test]
    fn duplicate_bridge_orientations_collapse() {
        // both orientations of a cross-shard pair must fold into one offer
        // on the canonical key, keeping the smaller weight
        let mut br = BridgeState::new();
        assert!(br.offer(7, 3, 2.5));
        assert!(!br.offer(3, 7, 2.5), "same pair, same weight: no change");
        assert!(br.offer(3, 7, 1.5), "smaller weight must win");
        assert!(!br.offer(7, 3, 9.0), "larger weight must not regress");
        assert_eq!(br.n_edges(), 1);
        let edges: Vec<Edge> = br.edges().collect();
        assert_eq!(edges.len(), 1);
        assert_eq!(Edge::key(edges[0].a, edges[0].b), (3, 7));
        assert_eq!(edges[0].w, 1.5);
        // self-loops are rejected outright
        assert!(!br.offer(4, 4, 0.1));
        assert_eq!(br.n_edges(), 1);
    }

    #[test]
    fn bridge_window_bookkeeping() {
        // the same-epoch window state: note/min semantics, query fallback,
        // and the close operation the merge catch-up runs
        let mut br = BridgeState::new();
        assert_eq!(br.window_seen(2), usize::MAX, "unqueried remote");
        br.note_window_snap(2, 50);
        br.note_window_snap(2, 40);
        br.note_window_snap(2, 60);
        assert_eq!(br.window_seen(2), 40, "min insert watermark wins");
        assert_eq!(br.window_seen(0), usize::MAX);
        br.covered = 7;
        br.finish_window();
        assert_eq!(br.merge_covered, 7);
        assert_eq!(br.window_seen(2), usize::MAX, "window cleared");
    }

    #[test]
    fn bridge_compaction_preserves_merge_result() {
        // α·n compaction folds the buffer through Kruskal; by the
        // UPDATE_MST lemma the merged forest must be unaffected
        let mut a = BridgeState::new();
        let mut b = BridgeState::new();
        let none = Mutex::new(FastSet::default());
        let mut rng = crate::util::rng::Rng::new(99);
        let mut offers = Vec::new();
        for _ in 0..200 {
            let x = rng.below(30) as u32;
            let mut y = rng.below(30) as u32;
            if x == y {
                y = (y + 1) % 30;
            }
            offers.push((x, y, (rng.f64() * 50.0).round() / 4.0));
        }
        for &(x, y, w) in &offers {
            a.offer(x, y, w);
            b.offer(x, y, w);
            b.maybe_compact(0.1, 10, &none); // aggressively compact b
        }
        assert!(b.compactions > 0, "compaction never triggered");
        let ea: Vec<Edge> = a.edges().collect();
        let eb: Vec<Edge> = b.edges().collect();
        let ma = Msf::from_edges(ea, 30);
        let mb = Msf::from_edges(eb, 30);
        assert!(
            (ma.total_weight() - mb.total_weight()).abs() < 1e-9,
            "compacted {} vs buffered {}",
            mb.total_weight(),
            ma.total_weight()
        );
        assert_eq!(ma.edges().len(), mb.edges().len());
    }

    #[test]
    fn bridge_compaction_filters_dead_edges() {
        // A dead edge must not win a Kruskal cycle during bridge-buffer
        // compaction: node 1 is deleted, so (0,1,1.0)+(1,2,1.0) must not
        // evict the live (0,2,5.0) — the only real link between 0 and 2.
        let mut br = BridgeState::new();
        br.offer(0, 1, 1.0);
        br.offer(1, 2, 1.0);
        br.offer(0, 2, 5.0);
        let dead: Mutex<FastSet<u32>> =
            Mutex::new(std::iter::once(1u32).collect());
        br.maybe_compact(0.0, 1, &dead); // force compaction
        assert!(br.compactions > 0);
        let edges: Vec<Edge> = br.edges().collect();
        assert_eq!(edges.len(), 1, "dead edges survived: {edges:?}");
        assert_eq!(Edge::key(edges[0].a, edges[0].b), (0, 2));
        assert_eq!(edges[0].w, 5.0);

        // and an already-compacted forest is re-filtered once its
        // endpoints die
        let mut br = BridgeState::new();
        br.offer(3, 4, 1.0);
        let none = Mutex::new(FastSet::default());
        br.maybe_compact(0.0, 1, &none);
        assert_eq!(br.n_edges(), 1);
        br.offer(5, 6, 2.0);
        let dead: Mutex<FastSet<u32>> =
            Mutex::new(std::iter::once(4u32).collect());
        br.maybe_compact(0.0, 1, &dead);
        let edges: Vec<Edge> = br.edges().collect();
        assert!(
            edges.iter().all(|e| e.a != 4 && e.b != 4),
            "forest kept a dead endpoint: {edges:?}"
        );
        assert_eq!(edges.len(), 1);
    }
}
