//! PJRT runtime: loads the AOT-compiled HLO artifacts (Layer 1/2 Pallas/JAX
//! distance kernels, lowered by `python/compile/aot.py`) and executes them
//! from the rust hot path. Python is never involved at runtime.
//!
//! Wiring (see /opt/xla-example and DESIGN.md): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` (HLO **text** is the interchange
//! format — serialized protos from jax ≥ 0.5 are rejected by xla_extension
//! 0.5.1) → `client.compile` → `execute`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Metadata of one compiled module (a row of `artifacts/manifest.tsv`).
#[derive(Clone, Debug)]
pub struct ModuleMeta {
    pub name: String,
    pub file: String,
    pub op: String,
    pub metric: String,
    pub b: usize,
    pub d: usize,
    /// top-k size for `query_topk` modules; None otherwise.
    pub k: Option<usize>,
    pub outputs: usize,
}

/// One loaded + compiled executable.
struct LoadedModule {
    meta: ModuleMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// Result of a fused query_topk kernel invocation.
#[derive(Clone, Debug)]
pub struct QueryTopk {
    /// Distance from the query to every (non-padding) candidate.
    pub dists: Vec<f32>,
    /// (candidate index, distance), ascending distance, padding filtered.
    pub topk: Vec<(u32, f32)>,
}

/// The PJRT runtime: a CPU client plus an executable cache keyed by module
/// name. Executables are compiled once at load and reused for every batch.
pub struct Runtime {
    client: xla::PjRtClient,
    modules: HashMap<String, LoadedModule>,
    dir: PathBuf,
    exec_count: std::cell::Cell<u64>,
}

impl Runtime {
    /// Load every module listed in `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest.display()
            )
        })?;
        let client = xla::PjRtClient::cpu()?;
        let mut modules = HashMap::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 8 {
                bail!("malformed manifest line: {line:?}");
            }
            let meta = ModuleMeta {
                name: f[0].to_string(),
                file: f[1].to_string(),
                op: f[2].to_string(),
                metric: f[3].to_string(),
                b: f[4].parse()?,
                d: f[5].parse()?,
                k: match f[6].parse::<i64>()? {
                    x if x < 0 => None,
                    x => Some(x as usize),
                },
                outputs: f[7].parse()?,
            };
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            modules.insert(meta.name.clone(), LoadedModule { meta, exe });
        }
        if modules.is_empty() {
            bail!("no modules in {}", manifest.display());
        }
        Ok(Runtime { client, modules, dir, exec_count: std::cell::Cell::new(0) })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn module_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.modules.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ModuleMeta> {
        self.modules.get(name).map(|m| &m.meta)
    }

    /// Number of PJRT executions performed (perf accounting).
    pub fn exec_count(&self) -> u64 {
        self.exec_count.get()
    }

    /// Find the best `query_topk` module for (metric, dim): the loaded
    /// module with the smallest D >= dim.
    pub fn find_query_module(&self, metric: &str, dim: usize) -> Option<&ModuleMeta> {
        self.find_module("query_topk", metric, dim)
    }

    /// Find the best module of any op kind for (metric, dim): smallest
    /// loaded D >= dim.
    pub fn find_module(&self, op: &str, metric: &str, dim: usize) -> Option<&ModuleMeta> {
        self.modules
            .values()
            .map(|m| &m.meta)
            .filter(|m| m.op == op && m.metric == metric && m.d >= dim)
            .min_by_key(|m| m.d)
    }

    fn get(&self, name: &str) -> Result<&LoadedModule> {
        self.modules
            .get(name)
            .ok_or_else(|| anyhow!("module {name:?} not loaded"))
    }

    /// Execute a `query_topk` module: distances from `q` to `cands` plus
    /// the k nearest. Inputs are padded to the module's fixed (B, D):
    /// `cands.len() <= B`, `q.len() <= D`. Zero-padding extra dims is
    /// exact for every supported metric; padding *rows* are dropped from
    /// `dists` and the top-k is re-derived in rust over real candidates
    /// (k is tiny), so padded rows can never leak into results.
    pub fn query_topk(&self, name: &str, q: &[f32], cands: &[&[f32]]) -> Result<QueryTopk> {
        let module = self.get(name)?;
        let (b, d) = (module.meta.b, module.meta.d);
        let k = module.meta.k.ok_or_else(|| anyhow!("{name} has no k"))?;
        if cands.is_empty() {
            return Ok(QueryTopk { dists: vec![], topk: vec![] });
        }
        if cands.len() > b {
            bail!("batch {} exceeds module B={b}", cands.len());
        }
        if q.len() > d {
            bail!("dim {} exceeds module D={d}", q.len());
        }

        let mut qbuf = vec![0f32; d];
        qbuf[..q.len()].copy_from_slice(q);
        let mut cbuf = vec![0f32; b * d];
        for (i, c) in cands.iter().enumerate() {
            cbuf[i * d..i * d + c.len()].copy_from_slice(c);
        }

        let ql = xla::Literal::vec1(&qbuf);
        let cl = xla::Literal::vec1(&cbuf).reshape(&[b as i64, d as i64])?;
        let result = module.exe.execute::<xla::Literal>(&[ql, cl])?[0][0]
            .to_literal_sync()?;
        self.exec_count.set(self.exec_count.get() + 1);
        let (dl, _vals, _idx) = result.to_tuple3()?;
        let mut dists = dl.to_vec::<f32>()?;
        dists.truncate(cands.len());

        let kk = k.min(cands.len());
        let mut order: Vec<u32> = (0..cands.len() as u32).collect();
        if kk < order.len() {
            order.select_nth_unstable_by(kk - 1, |&x, &y| {
                dists[x as usize].total_cmp(&dists[y as usize])
            });
            order.truncate(kk);
        }
        order.sort_unstable_by(|&x, &y| {
            dists[x as usize].total_cmp(&dists[y as usize])
        });
        let topk = order.into_iter().map(|i| (i, dists[i as usize])).collect();
        Ok(QueryTopk { dists, topk })
    }

    /// Execute a `pairwise` module on row-major blocks, returning the
    /// `x.len() × y.len()` distance block (padding trimmed).
    pub fn pairwise(
        &self,
        name: &str,
        x: &[&[f32]],
        y: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let module = self.get(name)?;
        let (b, d) = (module.meta.b, module.meta.d);
        if x.len() > b || y.len() > b {
            bail!("block ({}, {}) exceeds module B={b}", x.len(), y.len());
        }
        let pack = |rows: &[&[f32]]| -> Result<xla::Literal> {
            let mut buf = vec![0f32; b * d];
            for (i, r) in rows.iter().enumerate() {
                if r.len() > d {
                    bail!("dim {} exceeds module D={d}", r.len());
                }
                buf[i * d..i * d + r.len()].copy_from_slice(r);
            }
            Ok(xla::Literal::vec1(&buf).reshape(&[b as i64, d as i64])?)
        };
        let xl = pack(x)?;
        let yl = pack(y)?;
        let result = module.exe.execute::<xla::Literal>(&[xl, yl])?[0][0]
            .to_literal_sync()?;
        self.exec_count.set(self.exec_count.get() + 1);
        let flat = result.to_tuple1()?.to_vec::<f32>()?;
        let mut out = Vec::with_capacity(x.len());
        for i in 0..x.len() {
            out.push(flat[i * b..i * b + y.len()].to_vec());
        }
        Ok(out)
    }

    /// Execute an `mreach` module: fused pairwise distance + mutual
    /// reachability (max with the rows'/columns' core distances).
    pub fn mreach(
        &self,
        name: &str,
        x: &[&[f32]],
        y: &[&[f32]],
        core_x: &[f32],
        core_y: &[f32],
    ) -> Result<Vec<Vec<f32>>> {
        let module = self.get(name)?;
        let (b, d) = (module.meta.b, module.meta.d);
        if x.len() > b || y.len() > b {
            bail!("block ({}, {}) exceeds module B={b}", x.len(), y.len());
        }
        let pack_rows = |rows: &[&[f32]]| -> Result<xla::Literal> {
            let mut buf = vec![0f32; b * d];
            for (i, r) in rows.iter().enumerate() {
                if r.len() > d {
                    bail!("dim {} exceeds module D={d}", r.len());
                }
                buf[i * d..i * d + r.len()].copy_from_slice(r);
            }
            Ok(xla::Literal::vec1(&buf).reshape(&[b as i64, d as i64])?)
        };
        let pack_core = |c: &[f32]| -> xla::Literal {
            let mut buf = vec![0f32; b];
            buf[..c.len()].copy_from_slice(c);
            xla::Literal::vec1(&buf)
        };
        let result = module
            .exe
            .execute::<xla::Literal>(&[
                pack_rows(x)?,
                pack_rows(y)?,
                pack_core(core_x),
                pack_core(core_y),
            ])?[0][0]
            .to_literal_sync()?;
        self.exec_count.set(self.exec_count.get() + 1);
        let flat = result.to_tuple1()?.to_vec::<f32>()?;
        let mut out = Vec::with_capacity(x.len());
        for i in 0..x.len() {
            out.push(flat[i * b..i * b + y.len()].to_vec());
        }
        Ok(out)
    }
}

/// Locate the artifacts directory: `$FISHDBC_ARTIFACTS`, else `artifacts/`
/// relative to the current directory (the workspace root in `make` runs).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("FISHDBC_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from("artifacts")
}
