//! Per-node bounded nearest-neighbor stores ("neighbors" in Algorithm 1).
//!
//! Each node keeps its `MinPts` closest *discovered* neighbors; the core
//! distance (distance of the MinPts-th closest known neighbor) is O(1) to
//! read. The paper uses max-heaps; since MinPts is small (≈10) we use
//! sorted fixed-capacity vectors, which are faster and give ordered
//! iteration for the reachability-decrease loop (Algorithm 1 lines 19-23).
//!
//! Core distances are additionally mirrored into a chunked copy-on-write
//! [`ChunkedVec`] (written through only when a node's core actually
//! changes), so the engine's frozen shard snapshots can capture all cores
//! as an O(n / CHUNK) clone that physically shares every chunk whose
//! cores did not move since the previous capture.

use crate::util::chunked::ChunkedVec;

/// Nearest-neighbor set of one node: entries sorted by distance ascending,
/// at most `k` of them, no duplicate neighbor ids.
#[derive(Clone, Debug, Default)]
pub struct KBest {
    entries: Vec<(u32, f64)>,
}

impl KBest {
    /// Offer neighbor `y` at distance `d`; keeps the k best. Returns true
    /// if the set changed (y entered or improved the top-k).
    pub fn offer(&mut self, k: usize, y: u32, d: f64) -> bool {
        if let Some(pos) = self.entries.iter().position(|&(id, _)| id == y) {
            // distances are deterministic; only replace if strictly better
            if d < self.entries[pos].1 {
                self.entries.remove(pos);
            } else {
                return false;
            }
        } else if self.entries.len() >= k {
            if d >= self.entries[k - 1].1 {
                return false;
            }
            self.entries.pop();
        }
        let ins = self.entries.partition_point(|&(_, e)| e <= d);
        self.entries.insert(ins, (y, d));
        true
    }

    /// Core distance: distance of the k-th closest known neighbor, or +∞
    /// while fewer than k neighbors are known (unknown distances are +∞ in
    /// the paper's model, Theorem 3.4).
    pub fn core(&self, k: usize) -> f64 {
        if self.entries.len() >= k {
            self.entries[k - 1].1
        } else {
            f64::INFINITY
        }
    }

    /// Neighbors at distance strictly less than `v`, ascending.
    pub fn closer_than(&self, v: f64) -> impl Iterator<Item = (u32, f64)> + '_ {
        self.entries.iter().copied().take_while(move |&(_, d)| d < v)
    }

    pub fn iter(&self) -> impl Iterator<Item = (u32, f64)> + '_ {
        self.entries.iter().copied()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop every entry whose neighbor id fails `keep` (incremental
    /// deletion: a removed item must stop counting toward anyone's
    /// MinPts neighborhood). Returns true when the set changed — the
    /// node's core distance can only have *increased*.
    pub fn purge(&mut self, keep: impl Fn(u32) -> bool) -> bool {
        let before = self.entries.len();
        self.entries.retain(|&(id, _)| keep(id));
        self.entries.len() != before
    }

    /// Drop all entries (the removed node itself: its neighborhood is
    /// meaningless once tombstoned).
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

/// All nodes' neighbor sets.
#[derive(Clone, Debug)]
pub struct NeighborStore {
    k: usize,
    sets: Vec<KBest>,
    /// Copy-on-write mirror of every node's core distance, kept exactly in
    /// sync with `sets` (written only when a core actually changes, so old
    /// chunks stay physically shared with frozen snapshots).
    cores: ChunkedVec<f64>,
}

impl NeighborStore {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        NeighborStore { k, sets: Vec::new(), cores: ChunkedVec::new() }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn ensure_len(&mut self, n: usize) {
        if self.sets.len() < n {
            self.sets.resize_with(n, KBest::default);
        }
        while self.cores.len() < n {
            self.cores.push(f64::INFINITY);
        }
    }

    #[inline]
    pub fn offer(&mut self, x: u32, y: u32, d: f64) -> bool {
        let changed = self.sets[x as usize].offer(self.k, y, d);
        if changed {
            let c = self.sets[x as usize].core(self.k);
            // write-through only on a real change (bitwise, so ∞ == ∞ and
            // even NaN cores from broken metrics cannot re-dirty forever):
            // untouched chunks stay shared with frozen snapshots
            if self.cores[x as usize].to_bits() != c.to_bits() {
                *self.cores.get_mut(x as usize) = c;
            }
        }
        changed
    }

    /// O(1) core-distance lookup (top of the paper's max-heap).
    #[inline]
    pub fn core(&self, x: u32) -> f64 {
        self.cores[x as usize]
    }

    /// All core distances as the chunked copy-on-write store — cloning the
    /// return value is the snapshot operation (O(n / CHUNK), shares every
    /// chunk whose cores did not change since the previous clone).
    pub fn cores(&self) -> &ChunkedVec<f64> {
        &self.cores
    }

    pub fn get(&self, x: u32) -> &KBest {
        &self.sets[x as usize]
    }

    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Incremental deletion: remove the ids in `removed` from every
    /// neighbor set (cores can only increase — fewer neighbors are known),
    /// clear the removed nodes' own sets, and re-sync the chunked core
    /// mirror for every node whose set changed. One pass over all sets:
    /// O(n · k) per *batch*, not per removed id.
    pub fn purge(&mut self, removed: &crate::util::fasthash::FastSet<u32>) {
        if removed.is_empty() {
            return;
        }
        for x in 0..self.sets.len() {
            let changed = if removed.contains(&(x as u32)) {
                let had = !self.sets[x].is_empty();
                self.sets[x].clear();
                had
            } else {
                self.sets[x].purge(|id| !removed.contains(&id))
            };
            if changed {
                let c = self.sets[x].core(self.k);
                if self.cores[x].to_bits() != c.to_bits() {
                    *self.cores.get_mut(x) = c;
                }
            }
        }
    }

    /// Export all neighbor sets (persistence): per node, the sorted
    /// `(neighbor, distance)` entries.
    pub fn export(&self) -> Vec<Vec<(u32, f64)>> {
        self.sets.iter().map(|s| s.iter().collect()).collect()
    }

    /// Rebuild from [`NeighborStore::export`]ed entries.
    pub fn import(k: usize, sets: Vec<Vec<(u32, f64)>>) -> Self {
        let mut store = NeighborStore::new(k);
        store.ensure_len(sets.len());
        for (x, entries) in sets.into_iter().enumerate() {
            for (y, d) in entries {
                store.offer(x as u32, y, d);
            }
        }
        store
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn kbest_keeps_k_smallest() {
        let mut kb = KBest::default();
        for (i, d) in [5.0, 3.0, 8.0, 1.0, 4.0].iter().enumerate() {
            kb.offer(3, i as u32, *d);
        }
        let got: Vec<f64> = kb.iter().map(|(_, d)| d).collect();
        assert_eq!(got, vec![1.0, 3.0, 4.0]);
    }

    #[test]
    fn core_is_kth_or_infinity() {
        let mut kb = KBest::default();
        assert_eq!(kb.core(2), f64::INFINITY);
        kb.offer(2, 0, 1.0);
        assert_eq!(kb.core(2), f64::INFINITY);
        kb.offer(2, 1, 3.0);
        assert_eq!(kb.core(2), 3.0);
        kb.offer(2, 2, 2.0);
        assert_eq!(kb.core(2), 2.0);
    }

    #[test]
    fn duplicate_offers_ignored() {
        let mut kb = KBest::default();
        assert!(kb.offer(3, 7, 2.0));
        assert!(!kb.offer(3, 7, 2.0));
        assert!(!kb.offer(3, 7, 5.0)); // worse duplicate
        assert!(kb.offer(3, 7, 1.0)); // better duplicate replaces
        assert_eq!(kb.len(), 1);
        assert_eq!(kb.iter().next(), Some((7, 1.0)));
    }

    #[test]
    fn closer_than_filters() {
        let mut kb = KBest::default();
        for (i, d) in [1.0, 2.0, 3.0, 4.0].iter().enumerate() {
            kb.offer(4, i as u32, *d);
        }
        let close: Vec<u32> = kb.closer_than(3.0).map(|(id, _)| id).collect();
        assert_eq!(close, vec![0, 1]);
    }

    #[test]
    fn prop_kbest_matches_sort() {
        check("kbest-vs-sort", 40, |rng, _| {
            let k = 1 + rng.below(8);
            let n = rng.below(50);
            let mut kb = KBest::default();
            let mut all: Vec<(u32, f64)> = Vec::new();
            for i in 0..n {
                let d = (rng.f64() * 100.0).round(); // ties likely
                kb.offer(k, i as u32, d);
                all.push((i as u32, d));
            }
            all.sort_by(|a, b| a.1.total_cmp(&b.1));
            let want_dists: Vec<f64> =
                all.iter().take(k).map(|&(_, d)| d).collect();
            let got_dists: Vec<f64> = kb.iter().map(|(_, d)| d).collect();
            assert_eq!(got_dists, want_dists, "k={k} n={n}");
            // core matches
            let want_core =
                if n >= k { want_dists[k - 1] } else { f64::INFINITY };
            assert_eq!(kb.core(k), want_core);
        });
    }

    #[test]
    fn store_grows() {
        let mut ns = NeighborStore::new(2);
        ns.ensure_len(3);
        assert!(ns.offer(0, 1, 1.0));
        assert!(ns.offer(2, 0, 4.0));
        assert_eq!(ns.core(0), f64::INFINITY);
        ns.offer(0, 2, 2.0);
        assert_eq!(ns.core(0), 2.0);
        assert_eq!(ns.len(), 3);
    }

    #[test]
    fn purge_raises_cores_and_clears_removed() {
        use crate::util::fasthash::FastSet;
        let mut ns = NeighborStore::new(2);
        ns.ensure_len(4);
        // node 0 knows {1 @ 1.0, 2 @ 2.0, 3 @ 3.0} (k=2 keeps 1.0, 2.0)
        ns.offer(0, 1, 1.0);
        ns.offer(0, 2, 2.0);
        ns.offer(0, 3, 3.0);
        ns.offer(1, 0, 1.0);
        ns.offer(1, 2, 1.5);
        ns.offer(2, 0, 4.0);
        ns.offer(2, 3, 4.5);
        assert_eq!(ns.core(0), 2.0);
        assert_eq!(ns.core(1), 1.5);
        assert_eq!(ns.core(2), 4.5);

        let removed: FastSet<u32> = std::iter::once(2u32).collect();
        ns.purge(&removed);
        // node 0 lost its 2nd-closest: core rises to +inf (only 1 known —
        // the dropped 3.0 entry is not resurrected, it was never kept)
        assert_eq!(ns.core(0), f64::INFINITY);
        assert!(ns.get(0).iter().all(|(id, _)| id != 2), "purged id survives");
        // node 1 lost one of two: core back to +inf
        assert_eq!(ns.core(1), f64::INFINITY);
        // the removed node's own set is cleared and its core invalidated
        assert!(ns.get(2).is_empty());
        assert_eq!(ns.core(2), f64::INFINITY);
        // purge is idempotent
        ns.purge(&removed);
        assert_eq!(ns.core(0), f64::INFINITY);
    }

    #[test]
    fn prop_chunked_core_mirror_stays_in_sync() {
        // the copy-on-write core mirror must always agree with the KBest
        // sets it shadows, and frozen clones of it must never move
        check("cores-mirror", 20, |rng, _| {
            let k = 1 + rng.below(6);
            let n = 2 + rng.below(120);
            let mut ns = NeighborStore::new(k);
            ns.ensure_len(n);
            let mut frozen: Vec<(ChunkedVec<f64>, Vec<f64>)> = Vec::new();
            for step in 0..600 {
                let x = rng.below(n) as u32;
                let mut y = rng.below(n) as u32;
                if x == y {
                    y = (y + 1) % n as u32;
                }
                ns.offer(x, y, (rng.f64() * 50.0).round());
                if step % 97 == 0 {
                    let snap = ns.cores().clone();
                    frozen.push((snap, ns.cores().to_vec()));
                }
            }
            for x in 0..n as u32 {
                assert_eq!(
                    ns.core(x).to_bits(),
                    ns.get(x).core(k).to_bits(),
                    "core mirror out of sync at {x}"
                );
            }
            assert_eq!(ns.cores().len(), n);
            for (snap, want) in &frozen {
                let got: Vec<f64> = snap.to_vec();
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "frozen cores moved");
                }
            }
        });
    }
}
