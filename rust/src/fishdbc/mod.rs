//! The FISHDBC algorithm (paper Algorithm 1): incremental approximate
//! HDBSCAN* for arbitrary data and distance functions.
//!
//! State (paper §3.1): (1) the HNSW; (2) `neighbors` — each node's MinPts
//! closest discovered neighbors (core distances in O(1)); (3) the current
//! approximate MSF with reachability-distance weights; (4) `candidates` —
//! a bounded buffer of candidate MSF edges, flushed through Kruskal
//! whenever it exceeds α·n (guaranteeing O(n) size).
//!
//! [`Fishdbc::add`] piggybacks on every distance computed by the HNSW
//! insertion, turning each `(a, b, d)` triple into a candidate edge
//! weighted by reachability distance, and re-offering edges whose
//! reachability decreased because a core distance shrank (lines 19-23).

pub mod neighbors;

use std::collections::HashMap;

use crate::util::chunked::ChunkedVec;
use crate::util::fasthash::FastMap;

use crate::distances::Metric;
use crate::hdbscan::{cluster_from_msf_opts, Clustering};
use crate::hnsw::{DistLog, Hnsw, HnswParams};
use crate::mst::{Edge, Msf};
use neighbors::NeighborStore;

/// FISHDBC parameters (paper §4.1 defaults).
#[derive(Clone, Copy, Debug)]
pub struct FishdbcParams {
    /// MinPts: neighborhood size defining density (paper default: 10,
    /// following Schubert et al.'s advice).
    pub min_pts: usize,
    /// HNSW construction beam width (paper evaluates 20 and 50).
    pub ef: usize,
    /// Candidate-buffer factor: UPDATE_MST runs when |candidates| > α·n.
    /// "α has a moderate impact on runtime, and should be chosen as large
    /// as possible while guaranteeing that state fits in memory" (§3.1).
    pub alpha: f64,
    /// RNG seed (HNSW level assignment).
    pub seed: u64,
}

impl Default for FishdbcParams {
    fn default() -> Self {
        FishdbcParams { min_pts: 10, ef: 20, alpha: 5.0, seed: 0xF15D }
    }
}

/// Cost/health counters exposed for the benches and the coordinator.
#[derive(Clone, Copy, Debug, Default)]
pub struct FishdbcStats {
    pub items: usize,
    pub dist_calls: u64,
    /// Batched distance dispatches on the insert path (each covering many
    /// of the `dist_calls` pairwise evaluations) — the "is the batch hot
    /// path live" telemetry CI asserts on.
    pub batch_evals: u64,
    pub mst_updates: u64,
    pub candidate_edges_buffered: usize,
    pub msf_edges: usize,
    /// Items tombstoned by [`Fishdbc::remove`] and still physically
    /// present (the engine compacts them away past
    /// `EngineConfig::compact_at`).
    pub tombstoned: usize,
}

/// Incremental FISHDBC clusterer over items of type `T` under metric `M`.
///
/// Item storage is a chunked copy-on-write [`ChunkedVec`] (as are the HNSW
/// node store and the core-distance mirror underneath), so cloning any of
/// the three — the engine's frozen shard snapshot — is O(n / CHUNK) `Arc`
/// copies that physically share every chunk untouched since the previous
/// clone. `T: Clone` is required for exactly that copy-on-write machinery.
pub struct Fishdbc<T, M> {
    params: FishdbcParams,
    metric: M,
    items: ChunkedVec<T>,
    hnsw: Hnsw,
    neighbors: NeighborStore,
    msf: Msf,
    candidates: FastMap<(u32, u32), f64>,
    mst_updates: u64,
    log_buf: DistLog,
    /// Tombstone marks, index-aligned with `items` (chunked so the
    /// engine's frozen snapshots capture them copy-on-write). A tombstoned
    /// item stays in the HNSW for routability but is invisible to
    /// `nearest`, contributes to nobody's core distance, and carries no
    /// forest or candidate edges.
    tombs: ChunkedVec<bool>,
    /// Live tombstone count (`tombs.iter().filter(|t| **t).count()`).
    n_tombs: usize,
}

impl<T: Clone, M: Metric<T>> Fishdbc<T, M> {
    /// SETUP (Algorithm 1): create empty state.
    pub fn new(metric: M, params: FishdbcParams) -> Self {
        Fishdbc {
            metric,
            hnsw: Hnsw::new(HnswParams {
                m: params.min_pts,
                ef: params.ef,
                seed: params.seed,
            }),
            neighbors: NeighborStore::new(params.min_pts),
            msf: Msf::new(),
            candidates: FastMap::default(),
            mst_updates: 0,
            log_buf: DistLog::new(),
            params,
            items: ChunkedVec::new(),
            tombs: ChunkedVec::new(),
            n_tombs: 0,
        }
    }

    pub fn params(&self) -> &FishdbcParams {
        &self.params
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The chunked copy-on-write item store. Indexable (`items()[i]`) and
    /// iterable; cloning it is the O(n / CHUNK) snapshot operation the
    /// engine's frozen [`ShardSnap`](crate::engine)s are built on.
    pub fn items(&self) -> &ChunkedVec<T> {
        &self.items
    }

    pub fn metric(&self) -> &M {
        &self.metric
    }

    /// Total distance-function evaluations so far (the paper's cost model;
    /// Fig 2 plots this per item).
    pub fn dist_calls(&self) -> u64 {
        self.hnsw.dist_calls()
    }

    pub fn stats(&self) -> FishdbcStats {
        FishdbcStats {
            items: self.items.len(),
            dist_calls: self.dist_calls(),
            batch_evals: self.hnsw.batch_evals(),
            mst_updates: self.mst_updates,
            candidate_edges_buffered: self.candidates.len(),
            msf_edges: self.msf.edges().len(),
            tombstoned: self.n_tombs,
        }
    }

    /// Core distance of an item (+∞ until MinPts neighbors are known, and
    /// permanently +∞ once the item is tombstoned).
    pub fn core_distance(&self, id: u32) -> f64 {
        self.neighbors.core(id)
    }

    /// Whether item `id` is stored and not tombstoned.
    #[inline]
    pub fn alive(&self, id: u32) -> bool {
        (id as usize) < self.items.len() && !self.tombs[id as usize]
    }

    /// Live tombstone count (items removed but not yet compacted away).
    pub fn n_tombstoned(&self) -> usize {
        self.n_tombs
    }

    /// Items alive (stored minus tombstoned).
    pub fn n_alive(&self) -> usize {
        self.items.len() - self.n_tombs
    }

    /// The chunked tombstone marks (the engine's frozen snapshots clone
    /// this alongside the other stores).
    pub fn tombs(&self) -> &ChunkedVec<bool> {
        &self.tombs
    }

    /// ADD (Algorithm 1): incrementally insert one item. Returns its id.
    pub fn add(&mut self, item: T) -> u32 {
        let id = self.items.len() as u32;
        self.items.push(item);
        self.tombs.push(false);
        self.neighbors.ensure_len(self.items.len());

        // HNSW insertion; every d() call lands in log_buf (piggybacking)
        let mut log = std::mem::take(&mut self.log_buf);
        log.clear();
        self.hnsw.add(&self.items, &self.metric, id, &mut log);

        // Tombstoned nodes stay routable (they appear in the log), but
        // must not re-enter anyone's neighborhood or the candidate graph.
        if self.n_tombs > 0 {
            let tombs = &self.tombs;
            log.retain(|&(a, b, _)| !tombs[a as usize] && !tombs[b as usize]);
        }

        // First update all neighbor sets so core distances reflect
        // everything this insertion discovered, remembering whose top-k
        // changed (their reachability distances may have decreased).
        let mut changed: Vec<(u32, f64)> = Vec::new();
        for &(a, b, d) in &log {
            if self.neighbors.offer(a, b, d) {
                changed.push((a, d));
            }
            if self.neighbors.offer(b, a, d) {
                changed.push((b, d));
            }
        }

        // Candidate edges from every computed distance, weighted by
        // reachability distance rd = max(d, core(a), core(b)) (line 16).
        for &(a, b, d) in &log {
            let rd = d.max(self.neighbors.core(a)).max(self.neighbors.core(b));
            Self::offer_candidate(&mut self.candidates, a, b, rd);
        }

        // Lines 19-23: when y's top-MinPts changed (its core distance may
        // have dropped), re-offer edges to y's known neighbors closer than
        // the triggering distance v — their reachability may have shrunk.
        for &(y, v) in &changed {
            let cy = self.neighbors.core(y);
            // collect to avoid holding a borrow on neighbors during offers
            let close: Vec<(u32, f64)> =
                self.neighbors.get(y).closer_than(v).collect();
            for (z, w) in close {
                let cz = self.neighbors.core(z);
                if cz < v {
                    let rd = w.max(cy).max(cz);
                    Self::offer_candidate(&mut self.candidates, y, z, rd);
                }
            }
        }

        self.log_buf = log;

        // Bound the buffer: |candidates| ≤ α·n (line 24).
        if self.candidates.len() as f64
            > self.params.alpha * self.items.len() as f64
        {
            self.update_mst();
        }
        id
    }

    /// Add many items (streaming batch path).
    pub fn add_batch(&mut self, items: impl IntoIterator<Item = T>) {
        for it in items {
            self.add(it);
        }
    }

    /// REMOVE: incrementally delete one item by id. See
    /// [`Fishdbc::remove_batch_ids`]; returns false when the id is out of
    /// range or already tombstoned.
    pub fn remove(&mut self, id: u32) -> bool {
        self.remove_batch_ids(&[id]) == 1
    }

    /// Incremental deletion (the engine's churn path): tombstone the given
    /// local ids. For each removed item x:
    ///
    /// * its HNSW node **stays** (removing nodes would tear routing holes
    ///   in the navigable graph); it is skipped by [`Fishdbc::nearest`]
    ///   and never re-enters a neighborhood or the candidate graph,
    /// * its core distance is invalidated (+∞) and every neighbor whose
    ///   MinPts-neighborhood contained x gets its core recomputed — cores
    ///   can only *increase*, matching the paper's "distance to the
    ///   MinPts-th closest **known** neighbor" model with x unknown again,
    /// * buffered candidate edges touching x are dropped, and the forest
    ///   keeps only edges between survivors (a subsequence of a sorted
    ///   forest is still a sorted forest).
    ///
    /// Deletion breaks UPDATE_MST's monotone-growth premise: an edge that
    /// earlier lost a Kruskal cycle *through x* is not resurrected (it was
    /// never retained), so the surviving forest is an MSF of the recorded
    /// (forest ∪ buffer) graph minus x — not necessarily of everything
    /// ever offered minus x. Surviving edge weights likewise keep their
    /// discovery-time reachability (cores only rose, so they are lower
    /// bounds). Both approximations disappear at the next compaction,
    /// which replays the survivors from scratch.
    ///
    /// Returns how many ids were newly tombstoned (out-of-range and
    /// already-tombstoned ids are skipped). O(batch + n·MinPts).
    pub fn remove_batch_ids(&mut self, ids: &[u32]) -> usize {
        let mut removed = crate::util::fasthash::FastSet::default();
        for &id in ids {
            if self.alive(id) && removed.insert(id) {
                *self.tombs.get_mut(id as usize) = true;
            }
        }
        if removed.is_empty() {
            return 0;
        }
        self.n_tombs += removed.len();
        self.neighbors.purge(&removed);
        self.candidates
            .retain(|&(a, b), _| !removed.contains(&a) && !removed.contains(&b));
        self.msf.retain_nodes(|id| !removed.contains(&id));
        removed.len()
    }

    #[inline]
    fn offer_candidate(
        candidates: &mut FastMap<(u32, u32), f64>,
        a: u32,
        b: u32,
        rd: f64,
    ) {
        if a == b {
            return;
        }
        let key = Edge::key(a, b);
        candidates
            .entry(key)
            .and_modify(|w| {
                if rd < *w {
                    *w = rd;
                }
            })
            .or_insert(rd);
    }

    /// UPDATE_MST (Algorithm 1): fold buffered candidates into the MSF
    /// (Kruskal over forest ∪ candidates; correct by Eppstein's lemma).
    pub fn update_mst(&mut self) {
        if self.candidates.is_empty() {
            return;
        }
        // tombstoned endpoints cannot enter the forest (belt: the add and
        // remove paths already keep them out of the buffer)
        let tombs = &self.tombs;
        let edges: Vec<Edge> = self
            .candidates
            .drain()
            .filter(|&((a, b), _)| !tombs[a as usize] && !tombs[b as usize])
            .map(|((a, b), w)| Edge::new(a, b, w))
            .collect();
        self.msf.update(edges, self.items.len());
        self.mst_updates += 1;
    }

    /// CLUSTER (Algorithm 1): flush candidates and extract the clustering
    /// with minimum cluster size `mcs` (paper suggests mcs = MinPts).
    pub fn cluster(&mut self, mcs: usize) -> Clustering {
        self.cluster_opts(mcs, false)
    }

    /// [`Fishdbc::cluster`] with hdbscan's `allow_single_cluster` option:
    /// when the whole dataset is one uniform cluster the default (paper)
    /// semantics return all-noise; with this flag the root may be selected.
    pub fn cluster_opts(&mut self, mcs: usize, allow_single_cluster: bool) -> Clustering {
        self.update_mst();
        if self.items.is_empty() {
            return cluster_from_msf_opts(&[], 1, mcs, allow_single_cluster);
        }
        let mut c = cluster_from_msf_opts(
            self.msf.edges(),
            self.items.len(),
            mcs,
            allow_single_cluster,
        );
        // tombstoned items are noise in every clustering (they are already
        // edge-free singletons; the explicit mask pins the contract even
        // for degenerate mcs / allow_single_cluster combinations)
        if self.n_tombs > 0 {
            for (i, &t) in self.tombs.iter().enumerate() {
                if t {
                    c.labels[i] = -1;
                }
            }
        }
        c
    }

    /// Current approximate MSF (introspection / tests).
    pub fn msf(&self) -> &Msf {
        &self.msf
    }

    /// Current MSF edges (weight ascending). Call [`Fishdbc::update_mst`]
    /// first if buffered candidates must be included — the engine's flush
    /// barrier does exactly that before collecting per-shard forests.
    pub fn msf_edges(&self) -> &[Edge] {
        self.msf.edges()
    }

    /// All core distances, indexed by item id (+∞ while fewer than MinPts
    /// neighbors are known), as the chunked copy-on-write mirror. The
    /// engine's cross-shard merge indexes it directly and its snapshots
    /// clone it in O(n / CHUNK); chunks whose cores did not change since
    /// the previous clone stay physically shared.
    pub fn cores(&self) -> &ChunkedVec<f64> {
        self.neighbors.cores()
    }

    /// Build an MSF from the *final k-nearest-neighbor graph only* — the
    /// "simpler design" the paper argues against in §3.1 ("computing the
    /// MST based on the nearest neighbor distances in the bottom graph …
    /// is not optimal as information about farther away items is important
    /// to avoid breaking up large clusters"). Used by the ablation bench to
    /// quantify exactly that: the paper's full piggyback keeps candidate
    /// edges from *every* distance call, not just the surviving top-k.
    pub fn knn_only_msf(&self) -> Msf {
        let mut edges = FastMap::default();
        for x in 0..self.items.len() as u32 {
            if !self.alive(x) {
                continue; // purge already emptied its set; belt
            }
            for (y, d) in self.neighbors.get(x).iter() {
                let rd =
                    d.max(self.neighbors.core(x)).max(self.neighbors.core(y));
                Self::offer_candidate(&mut edges, x, y, rd);
            }
        }
        Msf::from_edges(
            edges.into_iter().map(|((a, b), w)| Edge::new(a, b, w)).collect(),
            self.items.len(),
        )
    }

    /// Read-only view of the underlying HNSW (the engine clones it into
    /// the frozen snapshots that insert-time bridge queries run against).
    pub fn hnsw(&self) -> &Hnsw {
        &self.hnsw
    }

    /// HNSW state export (persistence; see the `persist` module).
    pub fn hnsw_export(&self) -> crate::hnsw::HnswExport {
        self.hnsw.export()
    }

    /// Neighbor-store export (persistence).
    pub fn neighbors_export(&self) -> Vec<Vec<(u32, f64)>> {
        self.neighbors.export()
    }

    /// Candidate-buffer export (persistence).
    pub fn candidates_export(&self) -> Vec<(u32, u32, f64)> {
        let mut v: Vec<(u32, u32, f64)> = self
            .candidates
            .iter()
            .map(|(&(a, b), &w)| (a, b, w))
            .collect();
        v.sort_unstable_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
        v
    }

    /// Reassemble an instance from persisted parts (see `persist`).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        metric: M,
        params: FishdbcParams,
        items: Vec<T>,
        hnsw: Hnsw,
        neighbors: NeighborStore,
        msf: Msf,
        candidates: Vec<(u32, u32, f64)>,
        mst_updates: u64,
    ) -> Self {
        let n = items.len();
        Fishdbc {
            params,
            metric,
            items: ChunkedVec::from_vec(items),
            hnsw,
            neighbors,
            msf,
            candidates: candidates
                .into_iter()
                .map(|(a, b, w)| ((a, b), w))
                .collect(),
            mst_updates,
            log_buf: DistLog::new(),
            tombs: ChunkedVec::from_vec(vec![false; n]),
            n_tombs: 0,
        }
    }

    /// Re-mark persisted tombstones on a freshly rebuilt instance (see
    /// `persist`). The persisted neighbor sets, candidate buffer and
    /// forest were already purged when the removal originally ran, so only
    /// the marks themselves need restoring. Out-of-range ids are ignored
    /// (the loader validates them first); duplicate ids count once.
    pub fn apply_tombstones(&mut self, ids: &[u32]) {
        for &id in ids {
            if (id as usize) < self.items.len() && !self.tombs[id as usize] {
                *self.tombs.get_mut(id as usize) = true;
                self.n_tombs += 1;
            }
        }
    }

    /// Tombstoned local ids, ascending (persistence export).
    pub fn tombs_export(&self) -> Vec<u32> {
        self.tombs
            .iter()
            .enumerate()
            .filter_map(|(i, &t)| t.then_some(i as u32))
            .collect()
    }

    /// Approximate k-nearest neighbors of an *external* query item (no
    /// insertion, no state mutation, not counted in [`Self::dist_calls`]).
    /// Ascending distance. `ef` defaults to the construction beam width.
    /// Tombstoned items are traversed (routability) but never returned.
    pub fn nearest(&self, query: &T, k: usize, ef: Option<usize>) -> Vec<(u32, f64)> {
        let ef = ef.unwrap_or(self.params.ef);
        if self.n_tombs == 0 {
            self.hnsw.search(&self.items, &self.metric, query, k, ef)
        } else {
            self.hnsw.search_filtered(
                &self.items,
                &self.metric,
                query,
                k,
                ef,
                |id| !self.tombs[id as usize],
            )
        }
    }

    /// Classify an external item against an existing clustering: the label
    /// of the majority vote among its `k` nearest clustered neighbors
    /// (noise neighbors abstain; returns -1 when all abstain or the index
    /// is empty). This is how a streaming deployment labels fresh events
    /// between (cheap) re-clusterings.
    pub fn classify(&self, query: &T, labels: &[i32], k: usize) -> i32 {
        majority_vote(
            self.nearest(query, k, None)
                .into_iter()
                .map(|(id, _)| labels.get(id as usize).copied().unwrap_or(-1)),
        )
    }

    /// Approximate state size in bytes (Theorem 3.1's O(n log n) claim is
    /// checked against this in the integration tests).
    pub fn approx_state_bytes(&self) -> usize {
        let edges = self.msf.edges().len() + self.candidates.len();
        let heap_entries: usize = self.items.len() * self.params.min_pts;
        // HNSW: levels sum ~ n * (1 + 1/m + ...) lists of ~m u32s
        let hnsw_links = self.items.len() * (self.params.min_pts * 2 + 8);
        edges * 24 + heap_entries * 12 + hnsw_links * 4
    }
}

/// Majority vote over neighbor labels: noise (-1) abstains, ties break
/// toward the smaller label so serving is deterministic. Shared by
/// [`Fishdbc::classify`] and the engine's online label queries
/// (`crate::engine::Engine::label`); -1 when every voter abstains.
pub fn majority_vote(labels: impl IntoIterator<Item = i32>) -> i32 {
    let mut votes: HashMap<i32, usize> = HashMap::new();
    for l in labels {
        if l >= 0 {
            *votes.entry(l).or_default() += 1;
        }
    }
    votes
        .into_iter()
        .max_by_key(|&(l, c)| (c, -l))
        .map(|(l, _)| l)
        .unwrap_or(-1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::vector::euclidean;
    use crate::hdbscan::exact::{exact_hdbscan, ExactParams};
    use crate::util::rng::Rng;

    fn metric() -> impl Metric<Vec<f32>> {
        |a: &Vec<f32>, b: &Vec<f32>| euclidean(a, b)
    }

    fn blobs(
        rng: &mut Rng,
        per: usize,
        centers: &[(f64, f64)],
        spread: f64,
    ) -> Vec<Vec<f32>> {
        let mut pts = Vec::new();
        for &(cx, cy) in centers {
            for _ in 0..per {
                pts.push(vec![
                    (cx + rng.normal() * spread) as f32,
                    (cy + rng.normal() * spread) as f32,
                ]);
            }
        }
        pts
    }

    fn purity(labels: &[i32], truth: &[usize]) -> f64 {
        // fraction of clustered points whose cluster's majority truth-label
        // matches their own
        use std::collections::HashMap;
        let mut per: HashMap<i32, HashMap<usize, usize>> = HashMap::new();
        for (l, t) in labels.iter().zip(truth) {
            if *l >= 0 {
                *per.entry(*l).or_default().entry(*t).or_default() += 1;
            }
        }
        let mut good = 0usize;
        let mut total = 0usize;
        for (_, counts) in per {
            let max = counts.values().max().copied().unwrap_or(0);
            good += max;
            total += counts.values().sum::<usize>();
        }
        if total == 0 {
            0.0
        } else {
            good as f64 / total as f64
        }
    }

    #[test]
    fn finds_well_separated_blobs() {
        let mut rng = Rng::new(1);
        let centers = [(0.0, 0.0), (100.0, 0.0), (0.0, 100.0)];
        let items = blobs(&mut rng, 60, &centers, 1.5);
        let truth: Vec<usize> = (0..items.len()).map(|i| i / 60).collect();

        let mut f = Fishdbc::new(
            metric(),
            FishdbcParams { min_pts: 5, ef: 20, ..Default::default() },
        );
        for it in items {
            f.add(it);
        }
        let c = f.cluster(5);
        assert_eq!(c.n_clusters, 3, "labels {:?}", c.labels);
        assert!(purity(&c.labels, &truth) > 0.99);
        // at least 90% clustered on such clean data
        assert!(c.n_clustered() as f64 / c.labels.len() as f64 > 0.9);
    }

    #[test]
    fn incremental_equals_oneshot_same_seed() {
        // clustering after adding all items must not depend on how often
        // UPDATE_MST ran in between (Eppstein incrementality)
        let mut rng = Rng::new(2);
        let items = blobs(&mut rng, 40, &[(0.0, 0.0), (60.0, 60.0)], 2.0);

        let p = FishdbcParams { min_pts: 5, ef: 20, alpha: 5.0, seed: 9 };
        let mut a = Fishdbc::new(metric(), p);
        let mut b = Fishdbc::new(metric(), p);
        for (i, it) in items.iter().enumerate() {
            a.add(it.clone());
            b.add(it.clone());
            if i % 7 == 0 {
                b.update_mst(); // force frequent flushes on b
            }
        }
        let ca = a.cluster(5);
        let cb = b.cluster(5);
        assert_eq!(ca.labels, cb.labels);
        assert!((a.msf().total_weight() - b.msf().total_weight()).abs() < 1e-9);
    }

    #[test]
    fn cluster_is_cheap_after_build() {
        // paper Table 3: extracting a clustering is orders of magnitude
        // cheaper than building. Verify it does no distance calls.
        let mut rng = Rng::new(3);
        let items = blobs(&mut rng, 50, &[(0.0, 0.0), (50.0, 0.0)], 1.0);
        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 5,
            ef: 20,
            ..Default::default()
        });
        for it in items {
            f.add(it);
        }
        let calls_before = f.dist_calls();
        let _ = f.cluster(5);
        let _ = f.cluster(10);
        assert_eq!(f.dist_calls(), calls_before, "cluster() must not call d()");
    }

    #[test]
    fn subquadratic_distance_calls() {
        let mut rng = Rng::new(4);
        let items = blobs(&mut rng, 400, &[(0.0, 0.0), (80.0, 0.0)], 3.0);
        let n = items.len() as u64;
        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 5,
            ef: 10,
            ..Default::default()
        });
        for it in items {
            f.add(it);
        }
        assert!(
            f.dist_calls() < n * n / 4,
            "{} calls for n={n} looks quadratic",
            f.dist_calls()
        );
    }

    #[test]
    fn candidates_bounded_by_alpha_n() {
        let mut rng = Rng::new(5);
        let items = blobs(&mut rng, 200, &[(0.0, 0.0)], 5.0);
        let alpha = 3.0;
        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 5,
            ef: 20,
            alpha,
            seed: 0,
        });
        for it in items {
            f.add(it);
            let bound = (alpha * f.len() as f64) as usize + f.len();
            assert!(
                f.stats().candidate_edges_buffered <= bound.max(64),
                "candidate buffer exceeded α·n + slack"
            );
        }
        assert!(f.stats().mst_updates > 0, "UPDATE_MST never triggered");
    }

    #[test]
    fn matches_exact_hdbscan_reasonably() {
        // On clean separated data FISHDBC should agree with the exact
        // baseline about the macro structure.
        let mut rng = Rng::new(6);
        let items = blobs(&mut rng, 70, &[(0.0, 0.0), (90.0, 90.0)], 2.0);
        let truth: Vec<usize> = (0..items.len()).map(|i| i / 70).collect();

        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 10,
            ef: 50,
            ..Default::default()
        });
        for it in items.iter().cloned() {
            f.add(it);
        }
        let approx = f.cluster(10);
        let exact = exact_hdbscan(
            &items,
            &metric(),
            ExactParams { min_pts: 10, mcs: 10, matrix_budget: None },
        )
        .unwrap()
        .clustering;

        assert_eq!(approx.n_clusters, exact.n_clusters);
        assert!(purity(&approx.labels, &truth) > 0.99);
        assert!(purity(&exact.labels, &truth) > 0.99);
    }

    #[test]
    fn empty_and_tiny() {
        let mut f = Fishdbc::new(metric(), FishdbcParams::default());
        let c = f.cluster(2);
        assert_eq!(c.n_clusters, 0);
        f.add(vec![0.0]);
        f.add(vec![1.0]);
        let c = f.cluster(2);
        assert_eq!(c.labels.len(), 2);
    }

    #[test]
    fn nearest_and_classify_work() {
        let mut rng = Rng::new(8);
        let centers = [(0.0, 0.0), (50.0, 50.0)];
        let items = blobs(&mut rng, 60, &centers, 1.0);
        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 5,
            ef: 20,
            ..Default::default()
        });
        for it in items.iter().cloned() {
            f.add(it);
        }
        let c = f.cluster(5);
        assert_eq!(c.n_clusters, 2);

        // a probe near the first center must hit cluster of item 0
        let probe = vec![0.5f32, -0.5];
        let nn = f.nearest(&probe, 3, None);
        assert_eq!(nn.len(), 3);
        assert!(nn[0].1 < 5.0, "nearest {:?}", nn);
        let label = f.classify(&probe, &c.labels, 5);
        assert_eq!(label, c.labels[nn[0].0 as usize]);

        // queries must not mutate the cost model or state
        let calls = f.dist_calls();
        let _ = f.nearest(&probe, 5, Some(40));
        assert_eq!(f.dist_calls(), calls);
        assert_eq!(f.len(), 120);

        // far-away probe with all-noise labels abstains
        let all_noise = vec![-1i32; 120];
        assert_eq!(f.classify(&probe, &all_noise, 5), -1);
    }

    #[test]
    fn knn_only_msf_is_heavier_or_fragmented() {
        // paper §3.1: the kNN-only "simpler design" loses long-range edges;
        // its forest can only have MORE components and >= total weight per
        // component count.
        let mut rng = Rng::new(12);
        let items = blobs(&mut rng, 80, &[(0.0, 0.0), (30.0, 0.0)], 2.0);
        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 5,
            ef: 20,
            ..Default::default()
        });
        for it in items {
            f.add(it);
        }
        f.update_mst();
        let knn = f.knn_only_msf();
        assert!(
            knn.components() >= f.msf().components(),
            "kNN-only cannot be better connected: {} vs {}",
            knn.components(),
            f.msf().components()
        );
    }

    #[test]
    fn hostile_metric_distances_are_sanitized_at_the_choke_point() {
        // An arbitrary user metric may return NaN or -inf. Both must be
        // mapped to +inf at the hnsw choke point before they can reach the
        // neighbor heaps, the core-distance mirror, or Kruskal's total_cmp
        // order (where NaN sorts *greatest* and would silently demote real
        // edges, and -inf would win every min-weight dedup).
        let hostile = |a: &Vec<f32>, b: &Vec<f32>| {
            let d = euclidean(a, b);
            // poison a deterministic subset of pairs both ways
            let key = (a[0] + b[0] * 7.0) as i64;
            match key.rem_euclid(5) {
                0 => f64::NAN,
                1 => f64::NEG_INFINITY,
                _ => d,
            }
        };
        let mut rng = Rng::new(21);
        let mut f = Fishdbc::new(hostile, FishdbcParams {
            min_pts: 4,
            ef: 10,
            ..Default::default()
        });
        for _ in 0..150 {
            f.add(vec![rng.f32() * 10.0, rng.f32() * 10.0]);
        }
        f.update_mst();
        // no poisoned value may survive anywhere distances are stored
        for id in 0..f.len() as u32 {
            let c = f.core_distance(id);
            assert!(!c.is_nan() && c > f64::NEG_INFINITY, "core {c} for {id}");
        }
        for e in f.msf_edges() {
            assert!(
                !e.w.is_nan() && e.w > f64::NEG_INFINITY,
                "forest edge {}-{} carries weight {}",
                e.a,
                e.b,
                e.w
            );
        }
        // weights are ascending under total_cmp — a NaN would sort last
        // and break this ordering invariant the pipeline relies on
        assert!(f.msf_edges().windows(2).all(|w| w[0].w <= w[1].w));
        let c = f.cluster(4);
        assert_eq!(c.labels.len(), 150);
        // query path flows through the same choke point
        let nn = f.nearest(&vec![5.0f32, 5.0], 3, None);
        assert!(nn.iter().all(|&(_, d)| !d.is_nan() && d > f64::NEG_INFINITY));
    }

    #[test]
    fn majority_vote_ties_break_toward_smaller_label() {
        // the documented serving determinism contract, tested directly
        assert_eq!(majority_vote([2, 1, 1, 2]), 1, "2-2 tie → smaller label");
        assert_eq!(majority_vote([5, 3, 5, 3, 0]), 3, "2-2 tie among 3/5");
        assert_eq!(majority_vote([7, 7, 2]), 7, "majority beats smaller");
        assert_eq!(majority_vote([0, 1, 2]), 0, "all-singleton tie → smallest");
        // noise abstains: it never outvotes a real label, at any count
        assert_eq!(majority_vote([-1, -1, -1, 4]), 4);
        assert_eq!(majority_vote([-1, 3, -1, 2]), 2, "tie after abstentions");
        // the all-abstain path returns noise
        assert_eq!(majority_vote([-1, -1, -1]), -1);
        assert_eq!(majority_vote(std::iter::empty::<i32>()), -1, "no voters");
    }

    #[test]
    fn classify_with_short_and_empty_label_vectors() {
        // labels shorter than the item count must abstain (treated as -1)
        // rather than panic or vote garbage — the contract `classify`
        // documents and the engine's label path shares
        let mut rng = Rng::new(9);
        let items = blobs(&mut rng, 30, &[(0.0, 0.0), (50.0, 50.0)], 1.0);
        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 4,
            ef: 15,
            ..Default::default()
        });
        for it in items.iter().cloned() {
            f.add(it);
        }
        let c = f.cluster(4);
        assert_eq!(c.n_clusters, 2);
        let probe = vec![0.2f32, 0.1];

        // full labels: the probe lands in the first blob's cluster
        let full = f.classify(&probe, &c.labels, 5);
        assert!(full >= 0);

        // empty labels: every voter abstains
        assert_eq!(f.classify(&probe, &[], 5), -1);

        // labels covering only the first blob (ids 0..30): the probe's
        // neighbors are all in that range, so the vote still works, and
        // ids above the vector abstain instead of panicking
        let partial = &c.labels[..30];
        assert_eq!(f.classify(&probe, partial, 5), full);
        // a far probe whose neighbors are all above the range abstains
        assert_eq!(f.classify(&vec![50.0f32, 50.0], partial, 5), -1);
    }

    #[test]
    fn remove_tombstones_and_recomputes_cores() {
        let mut rng = Rng::new(31);
        let items = blobs(&mut rng, 60, &[(0.0, 0.0), (80.0, 80.0)], 1.5);
        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 4,
            ef: 20,
            ..Default::default()
        });
        for it in items.iter().cloned() {
            f.add(it);
        }
        let c0 = f.cluster(4);
        assert_eq!(c0.n_clusters, 2);

        // remove a scattered third of the first blob
        let victims: Vec<u32> = (0..60).step_by(3).collect();
        assert_eq!(f.remove_batch_ids(&victims), victims.len());
        assert_eq!(f.n_tombstoned(), victims.len());
        assert_eq!(f.n_alive(), 120 - victims.len());
        // idempotent: removing again is a no-op
        assert_eq!(f.remove_batch_ids(&victims), 0);
        // out-of-range ids are ignored
        assert_eq!(f.remove_batch_ids(&[999]), 0);

        for &v in &victims {
            assert!(!f.alive(v));
            assert_eq!(f.core_distance(v), f64::INFINITY, "core not invalidated");
        }
        // no forest edge or neighbor entry touches a tombstone
        for e in f.msf_edges() {
            assert!(f.alive(e.a) && f.alive(e.b), "forest kept a dead edge");
        }
        let dead: std::collections::HashSet<u32> =
            victims.iter().copied().collect();
        let sets = f.neighbors_export();
        for (x, set) in sets.iter().enumerate() {
            assert!(
                set.iter().all(|&(y, _)| !dead.contains(&y)),
                "node {x} still lists a removed neighbor"
            );
        }

        // deleted ids label -1; survivors still form two clusters
        let c = f.cluster(4);
        assert_eq!(c.labels.len(), 120);
        for &v in &victims {
            assert_eq!(c.labels[v as usize], -1, "removed item got a label");
        }
        assert_eq!(c.n_clusters, 2, "survivors must keep both blobs");

        // nearest never returns tombstones, but still finds survivors
        let nn = f.nearest(&vec![0.0f32, 0.0], 5, Some(40));
        assert_eq!(nn.len(), 5);
        assert!(nn.iter().all(|&(id, _)| f.alive(id)), "nearest leaked: {nn:?}");
    }

    #[test]
    fn removed_items_do_not_reenter_neighborhoods_on_later_adds() {
        // after a removal, new inserts route *through* the tombstone but
        // must not offer edges to it or count it as a neighbor
        let mut rng = Rng::new(33);
        let mut f = Fishdbc::new(metric(), FishdbcParams {
            min_pts: 3,
            ef: 15,
            ..Default::default()
        });
        for _ in 0..50 {
            f.add(vec![rng.f32() * 5.0, rng.f32() * 5.0]);
        }
        let victims: Vec<u32> = (0..50).step_by(5).collect();
        f.remove_batch_ids(&victims);
        for _ in 0..50 {
            f.add(vec![rng.f32() * 5.0, rng.f32() * 5.0]);
        }
        f.update_mst();
        let dead: std::collections::HashSet<u32> =
            victims.iter().copied().collect();
        for e in f.msf_edges() {
            assert!(
                !dead.contains(&e.a) && !dead.contains(&e.b),
                "a post-removal insert re-linked a tombstone into the forest"
            );
        }
        for set in f.neighbors_export() {
            assert!(set.iter().all(|&(y, _)| !dead.contains(&y)));
        }
        let c = f.cluster(3);
        for &v in &victims {
            assert_eq!(c.labels[v as usize], -1);
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut rng = Rng::new(7);
        let items = blobs(&mut rng, 50, &[(0.0, 0.0), (40.0, 0.0)], 1.0);
        let p = FishdbcParams { min_pts: 5, ef: 20, alpha: 4.0, seed: 77 };
        let run = |items: &[Vec<f32>]| {
            let mut f = Fishdbc::new(metric(), p);
            for it in items.iter().cloned() {
                f.add(it);
            }
            f.cluster(5).labels
        };
        assert_eq!(run(&items), run(&items));
    }
}
