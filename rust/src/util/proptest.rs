//! Tiny property-testing driver (the `proptest` crate is unavailable
//! offline). Runs a property over many seeded random cases and reports the
//! first failing seed so failures are reproducible.
//!
//! Case counts scale with the `FISHDBC_PROPTEST_CASES` environment
//! variable (an integer multiplier, default 1): the nightly CI job can
//! run the same properties much harder without a second copy of the
//! suite, and a reported failing seed stays valid at any multiplier
//! because case seeds depend only on the case index.

use super::rng::Rng;

/// Multiplier applied to every `check` call's case count
/// (`FISHDBC_PROPTEST_CASES`, default 1, clamped to [1, 1000]).
pub fn case_multiplier() -> usize {
    std::env::var("FISHDBC_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(1)
        .clamp(1, 1000)
}

/// Run `prop(rng, case_index)` for `cases` deterministic cases (scaled by
/// [`case_multiplier`]). The property should panic (assert!) on failure.
/// On failure we re-raise with the seed.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Rng, usize)) {
    let cases = cases.saturating_mul(case_multiplier());
    for case in 0..cases {
        let seed = 0xF15D_0000u64 ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case)
        }));
        if let Err(e) = result {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {:?}",
                e.downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 32, |rng, _| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always-fails", 4, |_, _| {
            assert!(false, "boom");
        });
    }
}
