//! Tiny property-testing driver (the `proptest` crate is unavailable
//! offline). Runs a property over many seeded random cases and reports the
//! first failing seed so failures are reproducible.

use super::rng::Rng;

/// Run `prop(rng, case_index)` for `cases` deterministic cases. The property
/// should panic (assert!) on failure. On failure we re-raise with the seed.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Rng, usize)) {
    for case in 0..cases {
        let seed = 0xF15D_0000u64 ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case)
        }));
        if let Err(e) = result {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {:?}",
                e.downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 32, |rng, _| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always-fails", 4, |_, _| {
            assert!(false, "boom");
        });
    }
}
