//! Chunked copy-on-write storage for the engine's O(Δ) snapshots.
//!
//! [`ChunkedVec`] is an append-mostly vector whose elements live in
//! fixed-size chunks held behind `Arc`s. Cloning one is O(n / CHUNK)
//! pointer copies — that clone *is* the snapshot operation — and the clone
//! keeps every chunk alive by reference. Afterwards, mutating the live
//! side goes through [`Arc::make_mut`]: the first write into a chunk that
//! a snapshot still references copies just that chunk (≤ [`CHUNK`]
//! elements); chunks nobody rewrote since the last snapshot stay
//! physically shared between all snapshots and the live store. There is
//! no explicit dirty-set to maintain — the `Arc` strong counts *are* the
//! dirty tracking, which makes the scheme safe to capture from any thread
//! that can see the store behind a read lock.
//!
//! The chunk layout is a pure function of the element sequence (fill each
//! chunk to [`CHUNK`], then start the next), so two stores built from the
//! same stream — or one rebuilt via [`ChunkedVec::from_vec`] after a
//! persistence round-trip — chunk identically. Persistence never sees the
//! chunking at all: exports go through [`ChunkedVec::to_vec`] /
//! [`ChunkedVec::iter`], so on-disk formats are byte-identical to the
//! dense layout they replaced.
//!
//! [`ItemStore`] abstracts "indexable item storage" so the HNSW can read
//! items out of either a plain slice (tests, the exact baseline) or a
//! `ChunkedVec` (FISHDBC and the engine's frozen shard snapshots) without
//! caring which.

use std::sync::Arc;

/// Parse a decimal chunk-bits override at compile time (const context:
/// no `str::parse`). Rejects non-digits and out-of-range values with a
/// compile error rather than silently falling back.
const fn parse_chunk_bits(env: Option<&str>) -> usize {
    match env {
        None => 5,
        Some(s) => {
            let bytes = s.as_bytes();
            assert!(!bytes.is_empty(), "FISHDBC_CHUNK_BITS must not be empty");
            let mut v = 0usize;
            let mut i = 0;
            while i < bytes.len() {
                assert!(
                    bytes[i].is_ascii_digit(),
                    "FISHDBC_CHUNK_BITS must be a decimal integer"
                );
                v = v * 10 + (bytes[i] - b'0') as usize;
                i += 1;
            }
            assert!(v != 0, "FISHDBC_CHUNK_BITS must be in 1..=16");
            assert!(v <= 16, "FISHDBC_CHUNK_BITS must be in 1..=16");
            v
        }
    }
}

/// log2 of the default chunk size, compile-time overridable: build with
/// `FISHDBC_CHUNK_BITS=6 cargo build` to try other granularities without
/// touching code (ROADMAP open item 6 wants this tuned on real hardware).
///
/// The tradeoff being tuned is **rewire write-amplification vs per-chunk
/// overhead**: after a snapshot, the first rewire into a shared chunk
/// copies the whole chunk, and HNSW insertion rewires ~MinPts scattered
/// neighbors per item — so the copy-on-write cost of one insert is up to
/// MinPts·CHUNK element copies in the worst case. Bigger chunks amortize
/// `Arc` bookkeeping and help sequential scans (the flat HNSW link layout
/// walks chunk-contiguous nodes) but inflate that per-insert copy bill;
/// smaller chunks invert both. 32 elements (bits = 5) balances the two on
/// the workloads measured so far; see the `snapshot_refresh` bench for
/// copied-vs-shared ratios. The chunk layout is never persisted, so
/// builds with different values read each other's files fine.
pub const CHUNK_BITS: usize = parse_chunk_bits(option_env!("FISHDBC_CHUNK_BITS"));
/// Elements per chunk (at the default [`CHUNK_BITS`]).
pub const CHUNK: usize = 1 << CHUNK_BITS;

/// Append-mostly vector in `Arc`-shared fixed-size chunks (see the module
/// docs for the copy-on-write sharing model). The chunk size is a const
/// generic (`1 << BITS`) so the property suite can exercise a second
/// granularity; every production user takes the default, which is
/// [`CHUNK_BITS`] and therefore `FISHDBC_CHUNK_BITS`-overridable at
/// compile time.
#[derive(Debug)]
pub struct ChunkedVec<T, const BITS: usize = CHUNK_BITS> {
    chunks: Vec<Arc<Vec<T>>>,
    len: usize,
}

/// Manual (not derived) so an empty store exists for every `T` — the
/// derive would demand a spurious `T: Default`.
impl<T, const BITS: usize> Default for ChunkedVec<T, BITS> {
    fn default() -> Self {
        ChunkedVec::new()
    }
}

impl<T, const BITS: usize> Clone for ChunkedVec<T, BITS> {
    /// O(n / CHUNK): clones the chunk *pointers*, not the elements. This
    /// is the snapshot operation.
    fn clone(&self) -> Self {
        ChunkedVec { chunks: self.chunks.clone(), len: self.len }
    }
}

impl<T, const BITS: usize> ChunkedVec<T, BITS> {
    /// Elements per chunk for this instantiation.
    pub const CHUNK: usize = 1 << BITS;
    const MASK: usize = (1 << BITS) - 1;

    pub fn new() -> Self {
        ChunkedVec { chunks: Vec::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of chunks currently backing the store.
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// The elements of chunk `ci` (all chunks except the last hold exactly
    /// [`CHUNK`] elements).
    pub fn chunk(&self, ci: usize) -> &[T] {
        &self.chunks[ci]
    }

    /// Whether chunk `ci` is physically shared with the same-index chunk
    /// of `other` (i.e. untouched since the clone that separated them).
    pub fn chunk_shared_with(&self, other: &Self, ci: usize) -> bool {
        ci < self.chunks.len()
            && ci < other.chunks.len()
            && Arc::ptr_eq(&self.chunks[ci], &other.chunks[ci])
    }

    /// How many of `self`'s chunks are physically shared with `prev`.
    pub fn shared_chunks_with(&self, prev: &Self) -> usize {
        (0..self.chunks.len())
            .filter(|&ci| self.chunk_shared_with(prev, ci))
            .count()
    }

    /// Copied-vs-shared accounting against an earlier clone: every chunk
    /// not pointer-shared with `prev` counts as copied (everything, when
    /// there is no `prev`), with `bytes_of` estimating a copied chunk's
    /// heap footprint. This is the single source of truth for the
    /// engine's snapshot capture counters.
    pub fn chunk_delta(
        &self,
        prev: Option<&Self>,
        bytes_of: impl Fn(&[T]) -> usize,
    ) -> ChunkDelta {
        let mut d = ChunkDelta::default();
        for ci in 0..self.chunks.len() {
            if prev.is_some_and(|p| self.chunk_shared_with(p, ci)) {
                d.shared += 1;
            } else {
                d.copied += 1;
                d.bytes_copied += bytes_of(self.chunk(ci)) as u64;
            }
        }
        d
    }

    #[inline]
    pub fn get(&self, i: usize) -> &T {
        debug_assert!(i < self.len, "index {i} out of bounds (len {})", self.len);
        &self.chunks[i >> BITS][i & Self::MASK]
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> + '_ {
        self.chunks.iter().flat_map(|c| c.iter())
    }
}

impl<T: Clone, const BITS: usize> ChunkedVec<T, BITS> {
    /// Build from a dense vector. The layout is identical to pushing the
    /// elements one by one (determinism: reloads chunk exactly like the
    /// original run).
    pub fn from_vec(v: Vec<T>) -> Self {
        let len = v.len();
        let mut chunks = Vec::with_capacity(len.div_ceil(Self::CHUNK));
        let mut it = v.into_iter();
        loop {
            let chunk: Vec<T> = it.by_ref().take(Self::CHUNK).collect();
            if chunk.is_empty() {
                break;
            }
            chunks.push(Arc::new(chunk));
        }
        ChunkedVec { chunks, len }
    }

    /// Dense copy (persistence export; the on-disk format never sees the
    /// chunking).
    pub fn to_vec(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }

    /// Append. Copy-on-write: if a snapshot still references the tail
    /// chunk, that chunk (≤ [`CHUNK`] elements) is copied first.
    pub fn push(&mut self, v: T) {
        if self.len & Self::MASK == 0 {
            self.chunks.push(Arc::new(Vec::with_capacity(Self::CHUNK)));
        }
        let tail = self.chunks.last_mut().expect("tail chunk present");
        Arc::make_mut(tail).push(v);
        self.len += 1;
    }

    /// Mutable access. Copy-on-write: if a snapshot still references the
    /// containing chunk, it is copied first; otherwise this is a plain
    /// in-place write.
    #[inline]
    pub fn get_mut(&mut self, i: usize) -> &mut T {
        debug_assert!(i < self.len, "index {i} out of bounds (len {})", self.len);
        &mut Arc::make_mut(&mut self.chunks[i >> BITS])[i & Self::MASK]
    }
}

impl<T, const BITS: usize> std::ops::Index<usize> for ChunkedVec<T, BITS> {
    type Output = T;

    #[inline]
    fn index(&self, i: usize) -> &T {
        self.get(i)
    }
}

impl<T: PartialEq, const BITS: usize> PartialEq for ChunkedVec<T, BITS> {
    fn eq(&self, other: &Self) -> bool {
        self.len == other.len && self.iter().eq(other.iter())
    }
}

/// Copied-vs-shared accounting for one snapshot capture (see
/// [`ChunkedVec::chunk_delta`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkDelta {
    /// Chunks physically copied since the previous capture.
    pub copied: u64,
    /// Chunks republished by reference.
    pub shared: u64,
    /// Approximate heap bytes in the copied chunks.
    pub bytes_copied: u64,
}

impl ChunkDelta {
    /// Fold another store's tally into this one.
    pub fn add(&mut self, other: ChunkDelta) {
        self.copied += other.copied;
        self.shared += other.shared;
        self.bytes_copied += other.bytes_copied;
    }
}

// ------------------------------------------------------------ item store --

/// Read-only indexable item storage: what the HNSW needs from the caller-
/// owned item store. Implemented for plain slices (tests, baselines) and
/// [`ChunkedVec`] (FISHDBC's copy-on-write store).
pub trait ItemStore<T> {
    fn len(&self) -> usize;
    fn get(&self, i: usize) -> &T;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> ItemStore<T> for [T] {
    #[inline]
    fn len(&self) -> usize {
        <[T]>::len(self)
    }

    #[inline]
    fn get(&self, i: usize) -> &T {
        &self[i]
    }
}

impl<T> ItemStore<T> for Vec<T> {
    #[inline]
    fn len(&self) -> usize {
        <[T]>::len(self)
    }

    #[inline]
    fn get(&self, i: usize) -> &T {
        &self[i]
    }
}

impl<T, const BITS: usize> ItemStore<T> for ChunkedVec<T, BITS> {
    #[inline]
    fn len(&self) -> usize {
        ChunkedVec::len(self)
    }

    #[inline]
    fn get(&self, i: usize) -> &T {
        ChunkedVec::get(self, i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn chunk_bits_parser_accepts_defaults_and_overrides() {
        assert_eq!(parse_chunk_bits(None), 5);
        assert_eq!(parse_chunk_bits(Some("5")), 5);
        assert_eq!(parse_chunk_bits(Some("2")), 2);
        assert_eq!(parse_chunk_bits(Some("16")), 16);
        assert_eq!(CHUNK, 1 << CHUNK_BITS);
    }

    #[test]
    fn push_index_iter_match_dense() {
        let mut cv = ChunkedVec::new();
        let mut dense = Vec::new();
        for i in 0..(CHUNK * 3 + 7) {
            cv.push(i);
            dense.push(i);
        }
        assert_eq!(cv.len(), dense.len());
        assert!(!cv.is_empty());
        for (i, want) in dense.iter().enumerate() {
            assert_eq!(cv[i], *want);
        }
        let got: Vec<usize> = cv.iter().copied().collect();
        assert_eq!(got, dense);
        assert_eq!(cv.to_vec(), dense);
        assert_eq!(cv.n_chunks(), 4);
    }

    #[test]
    fn from_vec_layout_matches_pushes() {
        for n in [0, 1, CHUNK - 1, CHUNK, CHUNK + 1, CHUNK * 5 + 3] {
            let dense: Vec<u32> = (0..n as u32).collect();
            let a = ChunkedVec::from_vec(dense.clone());
            let mut b = ChunkedVec::new();
            for x in &dense {
                b.push(*x);
            }
            assert_eq!(a.n_chunks(), b.n_chunks(), "n={n}");
            assert_eq!(a, b, "n={n}");
            assert_eq!(a.to_vec(), dense);
        }
    }

    #[test]
    fn clone_is_immutable_snapshot() {
        let mut live = ChunkedVec::new();
        for i in 0..(CHUNK * 2 + 5) {
            live.push(i as u32);
        }
        let snap = live.clone();
        let frozen = snap.to_vec();
        // mutate old elements and append: the snapshot must not move
        *live.get_mut(0) = 999;
        *live.get_mut(CHUNK) = 888;
        for i in 0..CHUNK {
            live.push(1000 + i as u32);
        }
        assert_eq!(snap.to_vec(), frozen, "snapshot mutated");
        assert_eq!(live[0], 999);
        assert_eq!(live[CHUNK], 888);
        assert_eq!(live.len(), frozen.len() + CHUNK);
    }

    #[test]
    fn sharing_accounting_tracks_dirty_chunks() {
        let mut live = ChunkedVec::new();
        for i in 0..(CHUNK * 4) {
            live.push(i as u32);
        }
        let snap = live.clone();
        assert_eq!(live.shared_chunks_with(&snap), 4, "clone shares all");
        // dirty exactly one interior chunk
        *live.get_mut(CHUNK + 1) = 7;
        assert_eq!(live.shared_chunks_with(&snap), 3);
        assert!(live.chunk_shared_with(&snap, 0));
        assert!(!live.chunk_shared_with(&snap, 1));
        // appending opens a new tail chunk: snap has no counterpart for it
        live.push(42);
        assert_eq!(live.n_chunks(), 5);
        assert_eq!(live.shared_chunks_with(&snap), 3);
        // a second snapshot shares everything again
        let snap2 = live.clone();
        assert_eq!(live.shared_chunks_with(&snap2), 5);
    }

    #[test]
    fn partial_tail_chunk_copy_on_append() {
        // appending into a shared, partially-filled tail chunk must copy it
        let mut live = ChunkedVec::new();
        for i in 0..(CHUNK + 3) {
            live.push(i as u32);
        }
        let snap = live.clone();
        live.push(77);
        assert_eq!(snap.len(), CHUNK + 3);
        assert_eq!(live.len(), CHUNK + 4);
        assert_eq!(live[CHUNK + 3], 77);
        assert!(live.chunk_shared_with(&snap, 0), "full chunk still shared");
        assert!(!live.chunk_shared_with(&snap, 1), "tail was copied");
    }

    /// The random-op equivalence body, generic over chunk size so the
    /// property runs at the production granularity *and* a deliberately
    /// tiny one (more chunk boundaries per op — the regime where an
    /// off-by-one in the `BITS`/`MASK` arithmetic would actually bite).
    fn chunked_equals_dense_under_random_ops<const BITS: usize>(
        rng: &mut crate::util::rng::Rng,
    ) {
        let mut cv: ChunkedVec<u64, BITS> = ChunkedVec::new();
        let mut dense: Vec<u64> = Vec::new();
        let mut snaps: Vec<(ChunkedVec<u64, BITS>, Vec<u64>)> = Vec::new();
        for step in 0..400 {
            match rng.below(10) {
                0..=5 => {
                    let v = rng.next_u64();
                    cv.push(v);
                    dense.push(v);
                }
                6 | 7 if !dense.is_empty() => {
                    let i = rng.below(dense.len());
                    let v = rng.next_u64();
                    *cv.get_mut(i) = v;
                    dense[i] = v;
                }
                8 => snaps.push((cv.clone(), dense.clone())),
                _ => {}
            }
            if step % 37 == 0 {
                assert_eq!(cv.to_vec(), dense);
            }
        }
        assert_eq!(cv.to_vec(), dense);
        for (snap, want) in &snaps {
            assert_eq!(&snap.to_vec(), want, "snapshot drifted");
        }
    }

    #[test]
    fn prop_chunked_equals_dense_under_random_ops() {
        // random interleavings of push / overwrite / snapshot: the live
        // store must always read like the dense mirror, and every snapshot
        // must stay frozen at its capture state
        check("chunked-vs-dense", 20, |rng, _| {
            chunked_equals_dense_under_random_ops::<CHUNK_BITS>(rng);
        });
    }

    #[test]
    fn prop_chunked_equals_dense_at_second_chunk_size() {
        // same property at 4-element chunks: every behavior must be
        // chunk-size-independent (the compile-time override relies on it)
        check("chunked-vs-dense-alt-size", 20, |rng, _| {
            chunked_equals_dense_under_random_ops::<2>(rng);
        });
    }

    #[test]
    fn from_vec_layout_matches_pushes_at_second_chunk_size() {
        type Tiny = ChunkedVec<u32, 2>;
        assert_eq!(Tiny::CHUNK, 4);
        for n in [0, 3, 4, 5, 23] {
            let dense: Vec<u32> = (0..n as u32).collect();
            let a = Tiny::from_vec(dense.clone());
            let mut b = Tiny::new();
            for x in &dense {
                b.push(*x);
            }
            assert_eq!(a.n_chunks(), b.n_chunks(), "n={n}");
            assert_eq!(a.n_chunks(), n.div_ceil(4), "n={n}");
            assert_eq!(a, b, "n={n}");
            assert_eq!(a.to_vec(), dense);
        }
    }

    #[test]
    fn item_store_works_for_slices_and_chunked() {
        fn second<T, S: ItemStore<T> + ?Sized>(s: &S) -> &T {
            assert!(!s.is_empty());
            s.get(1)
        }
        let v = vec![10u32, 20, 30];
        assert_eq!(*second(&v[..]), 20);
        let cv = ChunkedVec::from_vec(v);
        assert_eq!(*second(&cv), 20);
        assert_eq!(ItemStore::len(&cv), 3);
    }
}
