//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` targets (`harness = false`): each bench is
//! a plain binary that times closures with warmup + repeated samples and
//! prints mean / stddev / min, plus CSV-ish rows the paper-table harness
//! consumes. [`emit_bench_json`] additionally appends one line-delimited
//! JSON record per configuration to `BENCH_<name>.json` so runs can be
//! diffed across commits without scraping the human-readable tables.

use std::io::Write;
use std::time::Instant;

use crate::obs::export::JsonW;

/// Result of a timed run.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

impl Sample {
    pub fn print(&self) {
        println!(
            "bench {:<48} mean {:>10.4}s  std {:>8.4}s  min {:>10.4}s  (n={})",
            self.name, self.mean_s, self.std_s, self.min_s, self.iters
        );
    }
}

/// Time `f` `iters` times after `warmup` warmup runs. `f` should return some
/// value to defeat dead-code elimination; we black-box it via `std::hint`.
pub fn time_n<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / times.len() as f64;
    Sample {
        name: name.to_string(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        iters,
    }
}

/// Time a single run (for expensive end-to-end benches).
pub fn time_once<T>(mut f: impl FnMut() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed().as_secs_f64(), v)
}

/// Append one machine-readable record to `BENCH_<bench>.json` in the
/// current directory (line-delimited JSON — one self-contained object per
/// line, each parseable by `python3 -m json.tool`) and echo the same line
/// to stdout prefixed with `BENCH_JSON `. The record always carries a
/// `"bench"` field; `fill` adds the rest (n, shards, items/s, quantiles,
/// metric_calls, …) through the same hand-rolled [`JsonW`] writer the
/// `/metrics` endpoint uses, so non-finite floats serialize as `null`
/// here too. File-IO failures are reported to stderr but never fail the
/// bench — the stdout echo is the fallback record.
pub fn emit_bench_json(bench: &str, fill: impl FnOnce(&mut JsonW)) {
    let line = bench_json_line(bench, fill);
    println!("BENCH_JSON {line}");
    let path = format!("BENCH_{bench}.json");
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = appended {
        eprintln!("bench: could not append to {path}: {e}");
    }
}

/// The single-line JSON record [`emit_bench_json`] writes (split out so
/// the format is unit-testable without touching the filesystem).
pub fn bench_json_line(bench: &str, fill: impl FnOnce(&mut JsonW)) -> String {
    let mut w = JsonW::new();
    w.obj(None).str("bench", bench);
    fill(&mut w);
    w.end_obj();
    let line = w.finish();
    debug_assert!(!line.contains('\n'), "records must stay line-delimited");
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_n_reports_sane_numbers() {
        let s = time_n("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.mean_s > 0.0 && s.min_s > 0.0 && s.min_s <= s.mean_s);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn time_once_returns_value() {
        let (t, v) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn bench_json_line_is_one_parseable_object() {
        let line = bench_json_line("engine_scaling", |w| {
            w.usize("n", 50_000)
                .usize("shards", 4)
                .f64("items_per_sec", 12_345.6)
                .f64("nan_field", f64::NAN)
                .u64("metric_calls", 987);
        });
        assert!(line.starts_with('{') && line.ends_with('}'));
        assert!(!line.contains('\n'));
        assert!(line.contains("\"bench\":\"engine_scaling\""));
        assert!(line.contains("\"shards\":4"));
        assert!(line.contains("\"nan_field\":null"), "non-finite -> null");
    }
}
