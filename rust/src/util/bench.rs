//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` targets (`harness = false`): each bench is
//! a plain binary that times closures with warmup + repeated samples and
//! prints mean / stddev / min, plus CSV-ish rows the paper-table harness
//! consumes.

use std::time::Instant;

/// Result of a timed run.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

impl Sample {
    pub fn print(&self) {
        println!(
            "bench {:<48} mean {:>10.4}s  std {:>8.4}s  min {:>10.4}s  (n={})",
            self.name, self.mean_s, self.std_s, self.min_s, self.iters
        );
    }
}

/// Time `f` `iters` times after `warmup` warmup runs. `f` should return some
/// value to defeat dead-code elimination; we black-box it via `std::hint`.
pub fn time_n<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / times.len() as f64;
    Sample {
        name: name.to_string(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        iters,
    }
}

/// Time a single run (for expensive end-to-end benches).
pub fn time_once<T>(mut f: impl FnMut() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed().as_secs_f64(), v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_n_reports_sane_numbers() {
        let s = time_n("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.mean_s > 0.0 && s.min_s > 0.0 && s.min_s <= s.mean_s);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn time_once_returns_value() {
        let (t, v) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
