//! Small self-contained utilities: deterministic RNG, property-test driver,
//! simple timing helpers. The build is fully offline (see DESIGN.md
//! §Dependency-policy), so these replace `rand`, `proptest` and `criterion`.

pub mod fasthash;
pub mod bench;
pub mod chunked;
pub mod rng;

#[cfg(test)]
pub mod proptest;
