//! Deterministic pseudo-random number generation (xoshiro256++ seeded via
//! SplitMix64). All dataset generators and samplers take an explicit seed so
//! every experiment in EXPERIMENTS.md is exactly reproducible.

/// xoshiro256++ PRNG. Fast, high-quality, tiny; not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for parallel/substream use).
    pub fn fork(&mut self) -> Self {
        Rng::new(self.next_u64())
    }

    /// Raw generator state (persistence: lets a saved FISHDBC continue the
    /// exact same HNSW level sequence after reload).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // bias is < 2^-32 for all n we use.
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; generators are not on any hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Poisson(lambda) via Knuth for small lambda, normal approx for large.
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent s (approximate inverse
    /// CDF sampling; used by the docword/text generators).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on the continuous Zipf approximation
        let u = self.f64().max(1e-12);
        if (s - 1.0).abs() < 1e-9 {
            let h = (n as f64).ln();
            return ((u * h).exp() - 1.0).min(n as f64 - 1.0) as usize;
        }
        let e = 1.0 - s;
        let h = ((n as f64).powf(e) - 1.0) / e;
        let x = (1.0 + u * h * e).powf(1.0 / e) - 1.0;
        (x as usize).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k << n assumed; rejection).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.below(n);
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(13);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..20_000 {
            let k = r.zipf(n, 1.1);
            assert!(k < n);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[n / 2].max(1) * 5);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for &(n, k) in &[(100, 10), (10, 10), (50, 40)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(23);
        for &lam in &[0.5, 3.0, 50.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.05, "lam={lam} mean={mean}");
        }
    }
}
