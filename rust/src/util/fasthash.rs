//! Minimal fast hasher for small integer keys (the candidate-edge map's
//! `(u32, u32)` keys). SipHash's per-key cost shows up in the ADD hot loop
//! (§Perf); a Fibonacci-multiply mix is plenty for edge keys, which are
//! already well-distributed node-id pairs. NOT DoS-resistant — use only
//! for internal, non-adversarial keys.

use std::hash::{BuildHasherDefault, Hasher};

/// Multiplicative hasher: fold every written chunk into one u64, then
/// Fibonacci-multiply + xor-shift finalize.
#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

const K: u64 = 0x9E37_79B9_7F4A_7C15; // 2^64 / φ

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        let mut x = self.state.wrapping_mul(K);
        x ^= x >> 32;
        x = x.wrapping_mul(K);
        x ^ (x >> 29)
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = self.state.rotate_left(8) ^ b as u64;
        }
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.state = self.state.rotate_left(32) ^ i as u64;
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.state = self.state.rotate_left(31) ^ i;
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.write_u64(i as u64);
    }
}

/// `BuildHasher` for [`FastHasher`].
pub type FastBuild = BuildHasherDefault<FastHasher>;

/// HashMap with the fast hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, FastBuild>;

/// HashSet with the fast hasher (e.g. the engine's deleted-id registry).
pub type FastSet<K> = std::collections::HashSet<K, FastBuild>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_and_distributes() {
        let mut m: FastMap<(u32, u32), f64> = FastMap::default();
        for a in 0..100u32 {
            for b in 0..100u32 {
                m.insert((a, b), (a + b) as f64);
            }
        }
        assert_eq!(m.len(), 10_000);
        assert_eq!(m[&(3, 7)], 10.0);
        assert_eq!(m.get(&(999, 999)), None);
    }

    #[test]
    fn finish_spreads_sequential_keys() {
        // consecutive keys must not collide in the low bits (bucket index)
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u32 {
            let mut h = FastHasher::default();
            h.write_u32(i);
            h.write_u32(i + 1);
            seen.insert(h.finish() & 0x3FFF); // 14-bit buckets
        }
        // with 16384 buckets and 10k keys, expect mostly distinct
        assert!(seen.len() > 7000, "poor low-bit spread: {}", seen.len());
    }
}
