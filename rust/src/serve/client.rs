//! Blocking client for the `fishdbc serve` framed protocol — used by the
//! CLI `--client-probe` mode, the `serving_latency` bench's traffic
//! threads, and the integration tests. One request in flight per
//! connection (the protocol has no stream multiplexing; open more
//! connections for more concurrency, that is what the server's pool is
//! for).

use std::io::{self, Read};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::engine::{ExtractionParams, TreeNode};
use crate::persist::{BinReader, ItemCodec};

use super::frame;

/// Outcome of an `Ingest` request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IngestReply {
    /// The whole batch was admitted; ids are assigned and the insert is
    /// queued (an `Engine::flush` barrier on the server makes it
    /// searchable). This acknowledgment is durable across a graceful
    /// server drain.
    Accepted(u64),
    /// The engine's bounded queues were full; nothing was admitted.
    /// Resend the same batch later.
    Busy,
}

/// A connected protocol client. `T` is inferred per call from the codec.
pub struct Client<C> {
    stream: TcpStream,
    codec: C,
}

impl<C> Client<C> {
    /// Connect and disable Nagle (the protocol is request/response; 40 ms
    /// delayed-ACK stalls would dominate every latency measurement).
    pub fn connect<A: ToSocketAddrs>(addr: A, codec: C) -> io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream, codec })
    }

    /// Optional client-side guard against a wedged server.
    pub fn set_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        self.stream.set_read_timeout(t)?;
        self.stream.set_write_timeout(t)
    }

    /// One round-trip: send a request payload, read the response payload.
    fn rpc(&mut self, payload: &[u8]) -> io::Result<Vec<u8>> {
        frame::write_frame(&mut self.stream, payload)?;
        frame::read_frame(&mut self.stream)?.ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )
        })
    }

    /// Split a response into (status, body), surfacing `Err` frames as
    /// `io::Error` and leaving `Busy` to the caller.
    fn split(resp: Vec<u8>) -> io::Result<(u8, Vec<u8>)> {
        let Some((&status, body)) = resp.split_first() else {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "empty response frame",
            ));
        };
        if status == frame::ST_ERR {
            let mut r = BinReader::new(body);
            let msg = r.str().unwrap_or_else(|_| "malformed Err frame".into());
            return Err(io::Error::other(format!("server error: {msg}")));
        }
        Ok((status, body.to_vec()))
    }

    fn expect_ok(resp: Vec<u8>) -> io::Result<Vec<u8>> {
        let (status, body) = Self::split(resp)?;
        if status != frame::ST_OK {
            return Err(io::Error::other(format!(
                "unexpected response status 0x{status:02x}"
            )));
        }
        Ok(body)
    }

    /// `Ping`: (items accepted so far, latest published epoch).
    pub fn ping(&mut self) -> io::Result<(u64, u64)> {
        let body = Self::expect_ok(self.rpc(&frame::encode_ping())?)?;
        let mut r = BinReader::new(&body[..]);
        Ok((r.u64()?, r.u64()?))
    }

    /// `Stats`: the engine's `fishdbc-stats-v1` JSON document.
    pub fn stats_json(&mut self) -> io::Result<String> {
        let body = Self::expect_ok(self.rpc(&frame::encode_stats())?)?;
        let mut r = BinReader::new(&body[..]);
        r.str()
    }

    /// `Label` one item with `k` voters (`k = 0`: server `min_pts`).
    pub fn label<T>(&mut self, item: &T, k: usize) -> io::Result<i32>
    where
        C: ItemCodec<T>,
    {
        let req = frame::encode_label(&self.codec, item, k)?;
        let body = Self::expect_ok(self.rpc(&req)?)?;
        let mut r = BinReader::new(&body[..]);
        Ok(r.u32()? as i32)
    }

    /// `LabelBatch`: one label per item, in order.
    pub fn label_batch<T>(
        &mut self,
        items: &[T],
        k: usize,
    ) -> io::Result<Vec<i32>>
    where
        C: ItemCodec<T>,
    {
        let req = frame::encode_label_batch(&self.codec, items, k)?;
        let body = Self::expect_ok(self.rpc(&req)?)?;
        let mut r = BinReader::new(&body[..]);
        let n = r.u32()? as usize;
        let mut labels = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            labels.push(r.u32()? as i32);
        }
        Ok(labels)
    }

    /// `Ingest`: all-or-nothing batch admission; [`IngestReply::Busy`]
    /// means resend later.
    pub fn ingest<T>(&mut self, items: &[T]) -> io::Result<IngestReply>
    where
        C: ItemCodec<T>,
    {
        let req = frame::encode_ingest(&self.codec, items)?;
        let (status, body) = Self::split(self.rpc(&req)?)?;
        match status {
            frame::ST_BUSY => Ok(IngestReply::Busy),
            frame::ST_OK => {
                let mut r = BinReader::new(&body[..]);
                Ok(IngestReply::Accepted(r.u64()?))
            }
            other => Err(io::Error::other(format!(
                "unexpected ingest status 0x{other:02x}"
            ))),
        }
    }

    /// `Ingest` with bounded retry on `Busy`; returns the accepted count.
    pub fn ingest_retrying<T>(
        &mut self,
        items: &[T],
        backoff: Duration,
        attempts: usize,
    ) -> io::Result<u64>
    where
        C: ItemCodec<T>,
    {
        for _ in 0..attempts.max(1) {
            match self.ingest(items)? {
                IngestReply::Accepted(n) => return Ok(n),
                IngestReply::Busy => std::thread::sleep(backoff),
            }
        }
        Err(io::Error::other("server still Busy after retries"))
    }

    /// `Remove`: tombstone every stored item equal to one of `items`;
    /// returns how many were removed.
    pub fn remove<T>(&mut self, items: &[T]) -> io::Result<u64>
    where
        C: ItemCodec<T>,
    {
        let req = frame::encode_remove(&self.codec, items)?;
        let body = Self::expect_ok(self.rpc(&req)?)?;
        let mut r = BinReader::new(&body[..]);
        r.u64()
    }

    /// `Tree`: the latest epoch's condensed hierarchy as flat nodes with
    /// stable ids — `(epoch, nodes)`. Floats travel as IEEE-754 bits, so
    /// the nodes compare bit-identically to the in-process
    /// [`EngineSnapshot::tree`](crate::engine::EngineSnapshot::tree).
    pub fn tree(&mut self) -> io::Result<(u64, Vec<TreeNode>)> {
        let body = Self::expect_ok(self.rpc(&frame::encode_tree())?)?;
        let mut r = BinReader::new(&body[..]);
        let epoch = r.u64()?;
        let n = r.u32()? as usize;
        let mut nodes = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            nodes.push(TreeNode {
                id: r.u32()?,
                parent: r.u32()?,
                lambda_birth: r.f64()?,
                stability: r.f64()?,
                size: r.u32()?,
            });
        }
        Ok((epoch, nodes))
    }

    /// `LabelAt`: label one item under arbitrary extraction parameters
    /// (`k = 0`: server `min_pts`).
    pub fn label_at<T>(
        &mut self,
        item: &T,
        k: usize,
        params: ExtractionParams,
    ) -> io::Result<i32>
    where
        C: ItemCodec<T>,
    {
        let req = frame::encode_label_at(&self.codec, item, k, params)?;
        let body = Self::expect_ok(self.rpc(&req)?)?;
        let mut r = BinReader::new(&body[..]);
        Ok(r.u32()? as i32)
    }

    /// `RelabelAt`: a full labeling of the latest epoch under arbitrary
    /// extraction parameters — `(epoch, n_clusters, labels)`.
    pub fn relabel_at(
        &mut self,
        params: ExtractionParams,
    ) -> io::Result<(u64, usize, Vec<i32>)> {
        let req = frame::encode_relabel_at(params)?;
        let body = Self::expect_ok(self.rpc(&req)?)?;
        let mut r = BinReader::new(&body[..]);
        let epoch = r.u64()?;
        let n_clusters = r.u32()? as usize;
        let n = r.u32()? as usize;
        let mut labels = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            labels.push(r.u32()? as i32);
        }
        Ok((epoch, n_clusters, labels))
    }

    /// True once the server has closed the connection (half-duplex
    /// check used by drain tests; consumes nothing on an open stream).
    pub fn at_eof(&mut self) -> bool {
        let mut b = [0u8; 1];
        self.stream
            .set_read_timeout(Some(Duration::from_millis(50)))
            .ok();
        matches!(self.stream.read(&mut b), Ok(0))
    }
}
