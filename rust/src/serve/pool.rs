//! Bounded hand-off queue between the accept loop and the fixed worker
//! pool. Thread-per-connection is exactly what `fishdbc serve` avoids —
//! under fan-in the pool size bounds CPU and the queue bound bounds
//! memory; past both, the accept loop refuses with a `Busy` frame
//! instead of letting connections pile up unobserved.

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::{Condvar, Mutex};

pub(crate) struct ConnQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    cap: usize,
}

struct QueueState {
    conns: VecDeque<TcpStream>,
    stopping: bool,
}

impl ConnQueue {
    pub fn new(cap: usize) -> ConnQueue {
        ConnQueue {
            state: Mutex::new(QueueState {
                conns: VecDeque::new(),
                stopping: false,
            }),
            cv: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Offer an accepted connection to the pool; hands the stream back
    /// when the queue is full or the server is stopping (the accept loop
    /// then refuses it with a `Busy` frame).
    pub fn push(&self, s: TcpStream) -> Result<(), TcpStream> {
        let mut q = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if q.stopping || q.conns.len() >= self.cap {
            return Err(s);
        }
        q.conns.push_back(s);
        self.cv.notify_one();
        Ok(())
    }

    /// Block until a connection is available; `None` once the queue is
    /// stopping (workers exit). After stop, queued-but-unclaimed
    /// connections are *not* handed out — nothing was read from them, so
    /// nothing was acknowledged, and dropping them loses no admitted
    /// work.
    pub fn pop(&self) -> Option<TcpStream> {
        let mut q = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if q.stopping {
                return None;
            }
            if let Some(s) = q.conns.pop_front() {
                return Some(s);
            }
            q = self.cv.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Flip to stopping, wake every waiter, and drop whatever was still
    /// queued; returns how many unclaimed connections were discarded.
    pub fn stop(&self) -> usize {
        let mut q = self.state.lock().unwrap_or_else(|e| e.into_inner());
        q.stopping = true;
        let dropped = q.conns.len();
        q.conns.clear();
        self.cv.notify_all();
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn stream_pair() -> TcpStream {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        TcpStream::connect(l.local_addr().unwrap()).unwrap()
    }

    #[test]
    fn queue_bounds_and_stop_drop_unclaimed() {
        let q = ConnQueue::new(2);
        assert!(q.push(stream_pair()).is_ok());
        assert!(q.push(stream_pair()).is_ok());
        assert!(q.push(stream_pair()).is_err(), "third must bounce");
        assert!(q.pop().is_some());
        assert!(q.push(stream_pair()).is_ok(), "slot freed by pop");
        assert_eq!(q.stop(), 2, "both queued conns discarded on stop");
        assert!(q.pop().is_none(), "stopped queue releases workers");
        assert!(q.push(stream_pair()).is_err(), "stopped queue refuses");
    }

    #[test]
    fn stop_wakes_blocked_workers() {
        let q = std::sync::Arc::new(ConnQueue::new(1));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.stop();
        assert!(h.join().unwrap().is_none());
    }
}
