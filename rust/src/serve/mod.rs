//! `fishdbc serve` — the zero-dependency network front-end that turns
//! the embedded engine into a deployable system.
//!
//! The ROADMAP's north star is serving labels to a live workload while
//! ingestion and background re-merges run; until now every caller had to
//! live inside the engine's process. This module grows the networking
//! out of [`obs::server`](crate::obs::server)'s responder brick into a
//! real request path, still on nothing but [`std::net`]:
//!
//! * **Framing** ([`frame`]) — length-prefixed binary request/response
//!   frames carrying `Ping`, `Stats`, `Label`, `LabelBatch`, `Ingest`,
//!   `Remove` and the hierarchy-as-a-service trio `Tree`/`LabelAt`/
//!   `RelabelAt`, items encoded through the persistence layer's
//!   [`ItemCodec`] seam (one codec definition covers checkpoints *and*
//!   the wire).
//! * **A fixed handler pool** ([`pool`]) — `threads` workers multiplex
//!   every connection; accepted-but-unclaimed connections wait in a
//!   bounded queue and overflow is refused with a `Busy` frame. No
//!   thread-per-connection: fan-in cannot grow the process.
//! * **Engine mapping** — label ops pin the engine's current
//!   [`latest()`](crate::engine::Engine::latest) epoch (lock-free `Arc`
//!   clone) and run the read-only query path, so a background merge
//!   never pauses serving; ingest goes through the non-blocking
//!   [`try_add_batch`](crate::engine::Engine::try_add_batch) and a full
//!   queue answers `Busy` instead of wedging a pool thread on
//!   backpressure.
//! * **Graceful drain** — [`Server::shutdown`] (also run by `Drop`,
//!   poison-tolerant like the engine teardown it reuses) stops
//!   accepting, lets each worker finish the request it is serving,
//!   drops never-read queued connections, joins everything, then runs
//!   an [`Engine::flush`](crate::engine::Engine::flush) barrier — so
//!   every *acknowledged* ingest is applied before the process exits.
//!   A SIGTERM'd `fishdbc serve` loses nothing it acked.
//!
//! Request handling is panic-isolated: a poisoned request (e.g. an item
//! the engine's metric rejects) gets an `Err` frame and costs one
//! connection, never a pool thread.

pub mod client;
pub mod frame;
mod pool;

pub use client::{Client, IngestReply};

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::distances::Metric;
use crate::engine::{Engine, EngineItem, EngineSnapshot};
use crate::obs::{CounterId, HistId};
use crate::persist::{BinWriter, ItemCodec};

use frame::Request;
use pool::ConnQueue;

/// Accept-loop poll interval while idle (mirrors `obs::server`).
const ACCEPT_POLL: Duration = Duration::from_millis(5);
/// Between-request poll slice: how long a worker waits for the next
/// frame's first byte before re-checking the stop flag. Bounds how long
/// shutdown waits on idle connections without dropping slow ones.
const FRAME_POLL: Duration = Duration::from_millis(100);

/// Tuning for the framed TCP front-end.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Fixed connection-handler pool size.
    pub threads: usize,
    /// Bound on accepted-but-unclaimed connections; overflow is refused
    /// with a `Busy` frame instead of piling up.
    pub max_pending_conns: usize,
    /// Socket timeout for reading the rest of a started frame (a client
    /// that stalls mid-frame cannot hold a pool thread longer than this).
    pub io_timeout: Duration,
    /// Per-connection **write** deadline, distinct from the read-side
    /// `io_timeout`: a client that stops *reading* (stalled reader, full
    /// receive window) blocks the server's response write once the TCP
    /// buffers fill, and only this deadline frees the pool thread. Reads
    /// and writes stall for different reasons — a slow sender deserves
    /// the full frame-read window, while a response to a reader that has
    /// gone away is already lost — so the two bounds are tuned apart.
    pub write_timeout: Duration,
    /// Graceful-drain bound: on shutdown, the rest-of-frame read for an
    /// in-flight request is capped by the remaining drain window.
    pub drain_timeout: Duration,
    /// Durable ack mode: when true (and the engine has a WAL installed
    /// via [`crate::durable::Durable::open`]), an `Ingest`/`Remove` `OK`
    /// frame is written only after the batch's WAL record is fsynced —
    /// an acked batch then survives `kill -9`, not just graceful drain.
    /// A failed fsync answers `Err` instead of a hollow `OK`. No-op on a
    /// volatile engine.
    pub durable: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            threads: 4,
            max_pending_conns: 64,
            io_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            drain_timeout: Duration::from_secs(2),
            durable: false,
        }
    }
}

/// What a graceful drain observed (printed by the CLI's exit line).
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainReport {
    /// Accepted connections discarded unclaimed — nothing was ever read
    /// from them, so nothing was acknowledged on them.
    pub dropped_pending_conns: usize,
}

struct Shared<T, M, C> {
    engine: Arc<Engine<T, M>>,
    codec: C,
    cfg: ServeConfig,
    queue: ConnQueue,
    stop: AtomicBool,
    /// Set (before `stop`) by the drain path: in-flight rest-of-frame
    /// reads are capped by the time remaining to this deadline.
    deadline: Mutex<Option<Instant>>,
}

/// A running `fishdbc serve` front-end. Dropping it runs the same
/// graceful drain as [`Server::shutdown`].
pub struct Server<T, M, C> {
    addr: SocketAddr,
    shared: Arc<Shared<T, M, C>>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    drained: bool,
}

impl<T, M, C> Server<T, M, C>
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T> + Send + Sync + 'static,
{
    /// Bind `addr` (port 0 picks a free port — read it back from
    /// [`Server::addr`]) and serve the engine until shutdown/drop.
    pub fn start(
        engine: Arc<Engine<T, M>>,
        codec: C,
        addr: &str,
        cfg: ServeConfig,
    ) -> io::Result<Server<T, M, C>> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            engine,
            codec,
            cfg,
            queue: ConnQueue::new(cfg.max_pending_conns),
            stop: AtomicBool::new(false),
            deadline: Mutex::new(None),
        });
        let accept_shared = Arc::clone(&shared);
        // propagate spawn failure like any other bind error (same fix as
        // MetricsServer::serve)
        let accept = std::thread::Builder::new()
            .name("fishdbc-serve-accept".into())
            .spawn(move || accept_loop(listener, &accept_shared))?;
        let mut workers = Vec::with_capacity(cfg.threads.max(1));
        for i in 0..cfg.threads.max(1) {
            let worker_shared = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("fishdbc-serve-{i}"))
                .spawn(move || worker_loop(&worker_shared));
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // partial pool: tear down what started, then report
                    shared.stop.store(true, Ordering::SeqCst);
                    shared.queue.stop();
                    let _ = accept.join();
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(Server { addr, shared, accept: Some(accept), workers, drained: false })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful drain: stop accepting, discard never-read queued
    /// connections, let every worker finish its in-flight request
    /// (bounded by `drain_timeout`), join all threads, then run an
    /// ingest flush barrier — after this returns, every acknowledged
    /// ingest batch is applied to the engine.
    pub fn shutdown(mut self) -> DrainReport {
        self.drain()
    }

    fn drain(&mut self) -> DrainReport {
        if self.drained {
            return DrainReport::default();
        }
        self.drained = true;
        // deadline first, then the stop flag: a worker that observes
        // `stop` must always find the drain window already armed
        *self.shared.deadline.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(Instant::now() + self.shared.cfg.drain_timeout);
        self.shared.stop.store(true, Ordering::SeqCst);
        let dropped = self.shared.queue.stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // the durability barrier: acknowledged == enqueued, and the
        // shard channels are FIFO, so a flush applies everything acked
        self.shared.engine.flush();
        DrainReport { dropped_pending_conns: dropped }
    }
}

impl<T, M, C> Drop for Server<T, M, C> {
    fn drop(&mut self) {
        if self.drained {
            return;
        }
        self.drained = true;
        *self.shared.deadline.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(Instant::now() + self.shared.cfg.drain_timeout);
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.queue.stop();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // no flush here: `Drop` is unbounded (it must run for every
        // instantiation, poisoned or not) and the engine's own drop /
        // shutdown performs the final drain of its queues anyway
    }
}

fn accept_loop<T, M, C>(listener: TcpListener, shared: &Shared<T, M, C>)
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
{
    while !shared.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                if let Err(refused) = shared.queue.push(stream) {
                    // saturated pool: tell the client, don't queue
                    refuse_busy(refused);
                    shared.engine.registry().inc(CounterId::ServeBusy);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Best-effort `Busy` frame to a connection the pool cannot take.
fn refuse_busy(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    let _ = frame::write_frame(&mut stream, &[frame::ST_BUSY]);
}

fn worker_loop<T, M, C>(shared: &Shared<T, M, C>)
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T>,
{
    while let Some(stream) = shared.queue.pop() {
        shared.engine.registry().inc(CounterId::ServeConns);
        let _ = handle_conn(shared, stream);
    }
}

/// True for the error kinds a timed-out socket read produces.
fn timed_out(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

/// Serve one connection until clean EOF, error, or shutdown. Between
/// requests the worker polls for the next frame's first byte in
/// `FRAME_POLL` slices so it notices `stop` promptly; once a frame has
/// started, it is read to completion (bounded by `io_timeout`, and
/// during a drain by the remaining drain window) and answered — the
/// in-flight request always gets its acknowledgment.
fn handle_conn<T, M, C>(
    shared: &Shared<T, M, C>,
    mut stream: TcpStream,
) -> io::Result<()>
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T>,
{
    stream.set_nodelay(true).ok();
    stream.set_write_timeout(Some(shared.cfg.write_timeout))?;
    let mut served = 0u64;
    loop {
        // poll for the next request
        stream.set_read_timeout(Some(FRAME_POLL))?;
        let first = loop {
            match frame::read_byte(&mut stream) {
                Ok(None) => return Ok(()), // client closed cleanly
                Ok(Some(b)) => break b,
                Err(e) if timed_out(&e) => {
                    if shared.stop.load(Ordering::Relaxed) {
                        // idle at shutdown: no request in flight, close
                        return Ok(());
                    }
                }
                Err(_) => return Ok(()), // reset/teardown: drop conn
            }
        };
        // a frame has started: read the rest under the io timeout,
        // tightened to the drain window while shutting down
        let mut rest_timeout = shared.cfg.io_timeout;
        if shared.stop.load(Ordering::Relaxed) {
            let deadline =
                *shared.deadline.lock().unwrap_or_else(|e| e.into_inner());
            match deadline.and_then(|d| d.checked_duration_since(Instant::now()))
            {
                Some(left) => rest_timeout = rest_timeout.min(left),
                None => return Ok(()), // drain window exhausted
            }
        }
        stream
            .set_read_timeout(Some(rest_timeout.max(Duration::from_millis(1))))?;
        let payload = match frame::read_frame_rest(first, &mut stream) {
            Ok(p) => p,
            Err(_) => return Ok(()), // stalled or hostile: drop conn
        };

        let t0 = Instant::now();
        let (resp, close_after) = handle_request(shared, &payload);
        let obs = shared.engine.registry();
        obs.inc(CounterId::ServeRequests);
        if served > 0 {
            // connection reuse actually happening (vs one-shot clients)
            obs.inc(CounterId::ServeKeepaliveRequests);
        }
        served += 1;
        obs.record(HistId::Serve, t0.elapsed());
        frame::write_frame(&mut stream, &resp)?;
        if close_after {
            return Ok(());
        }
    }
}

/// Decode + execute one request, panic-isolated. Returns the response
/// payload and whether the connection must close afterwards (protocol
/// errors poison stream state — re-sync is not attempted).
fn handle_request<T, M, C>(
    shared: &Shared<T, M, C>,
    payload: &[u8],
) -> (Vec<u8>, bool)
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T>,
{
    let outcome =
        catch_unwind(AssertUnwindSafe(|| run_request(shared, payload)));
    let obs = shared.engine.registry();
    match outcome {
        Ok(Ok(resp)) => (resp, false),
        Ok(Err(e)) => {
            obs.inc(CounterId::ServeErrors);
            (err_payload(&e.to_string()), true)
        }
        // a panicking request (e.g. Metric::check_item on a mismatched
        // item) costs this connection, never the pool thread
        Err(_) => {
            obs.inc(CounterId::ServeErrors);
            (err_payload("internal error: request handler panicked"), true)
        }
    }
}

fn err_payload(msg: &str) -> Vec<u8> {
    let mut w = BinWriter::new(vec![frame::ST_ERR]);
    // writes into a Vec cannot fail
    w.str(msg).expect("in-memory write");
    w.into_inner()
}

/// The label ops' epoch pin: the latest published snapshot, extracting
/// one lazily on a never-merged engine (same semantics as
/// [`Engine::label`](crate::engine::Engine::label)).
fn pinned_snapshot<T, M, C>(shared: &Shared<T, M, C>) -> Arc<EngineSnapshot>
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
{
    match shared.engine.latest() {
        Some(snap) => snap,
        None => shared.engine.inner().cluster(shared.engine.config().mcs),
    }
}

/// The durable-ack gate for mutating ops: when [`ServeConfig::durable`]
/// is set and the engine carries a WAL, fsync it before the `OK` frame
/// goes out. An engine without a sink (volatile deployment) passes
/// through — `durable: true` then degrades to the graceful-drain
/// guarantee, exactly as documented on the flag.
fn durable_barrier<T, M, C>(shared: &Shared<T, M, C>) -> io::Result<()>
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
{
    if !shared.cfg.durable {
        return Ok(());
    }
    match shared.engine.durability_sync() {
        None | Some(Ok(_)) => Ok(()),
        Some(Err(e)) => Err(e),
    }
}

fn run_request<T, M, C>(
    shared: &Shared<T, M, C>,
    payload: &[u8],
) -> io::Result<Vec<u8>>
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T>,
{
    let engine = &shared.engine;
    let obs = engine.registry();
    let min_pts = engine.config().fishdbc.min_pts;
    match frame::decode_request(payload, &shared.codec)? {
        Request::Ping => {
            obs.inc(CounterId::ServePings);
            let mut w = BinWriter::new(vec![frame::ST_OK]);
            w.u64(engine.len() as u64)?;
            w.u64(engine.epoch())?;
            Ok(w.into_inner())
        }
        Request::Stats => {
            obs.inc(CounterId::ServeStatsOps);
            // non-flushing: a stats scrape must not become an ingest
            // barrier on the serving path
            let doc = engine.inner().stats_json(false);
            let mut w = BinWriter::new(vec![frame::ST_OK]);
            w.str(&doc)?;
            Ok(w.into_inner())
        }
        Request::Label { k, item } => {
            let k = if k == 0 { min_pts } else { k };
            let snap = pinned_snapshot(shared);
            let label = engine.label_against(&item, &snap, k);
            obs.inc(CounterId::ServeLabelOps);
            let mut w = BinWriter::new(vec![frame::ST_OK]);
            w.u32(label as u32)?;
            Ok(w.into_inner())
        }
        Request::LabelBatch { k, items } => {
            let k = if k == 0 { min_pts } else { k };
            // pin one epoch for the whole batch: consistent answers
            // even if a merge publishes mid-request
            let snap = pinned_snapshot(shared);
            let mut w = BinWriter::new(vec![frame::ST_OK]);
            w.u32(items.len() as u32)?;
            for item in &items {
                w.u32(engine.label_against(item, &snap, k) as u32)?;
            }
            obs.counter(CounterId::ServeLabelOps).add(items.len() as u64);
            Ok(w.into_inner())
        }
        Request::Ingest { items } => {
            let n = items.len() as u64;
            match engine.try_add_batch(items) {
                Ok(()) => {
                    // durable mode: the OK frame is the fsync receipt —
                    // a failed sync surfaces as an Err frame, never a
                    // hollow ack (the record may exist but is not known
                    // durable, so the client must retry/alert)
                    durable_barrier(shared)?;
                    obs.counter(CounterId::ServeIngestOps).add(n);
                    let mut w = BinWriter::new(vec![frame::ST_OK]);
                    w.u64(n)?;
                    Ok(w.into_inner())
                }
                Err(_rejected) => {
                    obs.inc(CounterId::ServeBusy);
                    Ok(vec![frame::ST_BUSY])
                }
            }
        }
        Request::Remove { items } => {
            let removed = engine.remove_batch(&items) as u64;
            durable_barrier(shared)?;
            obs.counter(CounterId::ServeRemoveOps).add(removed);
            let mut w = BinWriter::new(vec![frame::ST_OK]);
            w.u64(removed)?;
            Ok(w.into_inner())
        }
        Request::Tree => {
            // same epoch pin as Label: the nodes returned are exactly the
            // ids any LabelAt/RelabelAt of this epoch selects among
            obs.inc(CounterId::ServeTreeOps);
            let snap = pinned_snapshot(shared);
            let tree = snap.tree();
            let mut w = BinWriter::new(vec![frame::ST_OK]);
            w.u64(snap.epoch)?;
            w.u32(tree.len() as u32)?;
            for node in &tree {
                w.u32(node.id)?;
                w.u32(node.parent)?;
                w.f64(node.lambda_birth)?;
                w.f64(node.stability)?;
                w.u32(node.size)?;
            }
            Ok(w.into_inner())
        }
        Request::LabelAt { k, params, item } => {
            let k = if k == 0 { min_pts } else { k };
            let label = engine.label_at(&item, k, params);
            obs.inc(CounterId::ServeRelabelOps);
            let mut w = BinWriter::new(vec![frame::ST_OK]);
            w.u32(label as u32)?;
            Ok(w.into_inner())
        }
        Request::RelabelAt { params } => {
            let relabeling = engine.relabel_at(params);
            obs.counter(CounterId::ServeRelabelOps)
                .add(relabeling.clustering.labels.len() as u64);
            let mut w = BinWriter::new(vec![frame::ST_OK]);
            w.u64(relabeling.epoch)?;
            w.u32(relabeling.clustering.n_clusters as u32)?;
            w.u32(relabeling.clustering.labels.len() as u32)?;
            for &l in &relabeling.clustering.labels {
                w.u32(l as u32)?;
            }
            Ok(w.into_inner())
        }
    }
}
