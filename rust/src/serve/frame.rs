//! Length-prefixed binary frames — the `fishdbc serve` wire protocol.
//!
//! One frame per request, one frame per response, over a plain TCP
//! stream:
//!
//! ```text
//! frame            := len:u32-LE payload[len]
//! request payload  := op:u8 body
//!   0x01 Ping        (empty)
//!   0x02 Stats       (empty)
//!   0x03 Label       k:u32 item
//!   0x04 LabelBatch  k:u32 count:u32 item*count
//!   0x05 Ingest      count:u32 item*count
//!   0x06 Remove      count:u32 item*count
//! response payload := status:u8 body
//!   0x00 Ok          Ping   -> items:u64 epoch:u64
//!                    Stats  -> json:str
//!                    Label  -> label:i32 (two's-complement u32)
//!                    LabelBatch -> count:u32 label:i32*count
//!                    Ingest -> accepted:u64
//!                    Remove -> removed:u64
//!   0x01 Busy        (empty — resend later; ingest backpressure, or the
//!                     whole connection was refused by a saturated pool)
//!   0x02 Err         msg:str (the server closes the connection after)
//! ```
//!
//! All integers are little-endian; `str` is the [`BinWriter::str`]
//! encoding (`u64` length + UTF-8 bytes). Items are encoded through the
//! same [`ItemCodec`] seam the persistence layer uses, so anything an
//! engine can checkpoint it can also serve over the network, with one
//! codec definition. A `Label` response of `-1` means noise/unknown,
//! exactly like [`Engine::label`](crate::engine::Engine::label).
//!
//! `k = 0` in `Label`/`LabelBatch` means "use the server's configured
//! `min_pts`" — clients need not know the engine's parameters.

use std::io::{self, Read, Write};

use crate::persist::{BinReader, BinWriter, ItemCodec};

/// Hard cap on a single frame's payload; larger lengths are a protocol
/// error (defends the server against hostile 4 GiB allocations).
pub const MAX_FRAME: usize = 64 << 20;
/// Hard cap on the item count in one batched request.
pub const MAX_BATCH: usize = 1 << 20;

pub const OP_PING: u8 = 0x01;
pub const OP_STATS: u8 = 0x02;
pub const OP_LABEL: u8 = 0x03;
pub const OP_LABEL_BATCH: u8 = 0x04;
pub const OP_INGEST: u8 = 0x05;
pub const OP_REMOVE: u8 = 0x06;

pub const ST_OK: u8 = 0x00;
pub const ST_BUSY: u8 = 0x01;
pub const ST_ERR: u8 = 0x02;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Write one `len + payload` frame and flush it.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(bad("frame exceeds MAX_FRAME"));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one byte, distinguishing clean EOF (`Ok(None)`) from errors.
/// The serve loop uses this to poll for the next frame's first length
/// byte in short timeout slices without losing stream sync.
pub fn read_byte<R: Read>(r: &mut R) -> io::Result<Option<u8>> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Read the rest of a frame whose first length byte was already consumed
/// by [`read_byte`].
pub fn read_frame_rest<R: Read>(
    first: u8,
    r: &mut R,
) -> io::Result<Vec<u8>> {
    let mut len = [first, 0, 0, 0];
    r.read_exact(&mut len[1..])?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(bad("frame exceeds MAX_FRAME"));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Read one whole frame; `Ok(None)` on clean EOF before any length byte.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Vec<u8>>> {
    match read_byte(r)? {
        None => Ok(None),
        Some(first) => read_frame_rest(first, r).map(Some),
    }
}

/// A decoded request, server side.
#[derive(Debug)]
pub enum Request<T> {
    Ping,
    Stats,
    Label { k: usize, item: T },
    LabelBatch { k: usize, items: Vec<T> },
    Ingest { items: Vec<T> },
    Remove { items: Vec<T> },
}

fn read_items<T, C: ItemCodec<T>>(
    r: &mut BinReader<&[u8]>,
    codec: &C,
) -> io::Result<Vec<T>> {
    let n = r.u32()? as usize;
    if n > MAX_BATCH {
        return Err(bad("batch exceeds MAX_BATCH"));
    }
    let mut items = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        items.push(codec.read_item(r)?);
    }
    Ok(items)
}

/// Decode a request payload (everything after the length prefix).
pub fn decode_request<T, C: ItemCodec<T>>(
    payload: &[u8],
    codec: &C,
) -> io::Result<Request<T>> {
    let mut r = BinReader::new(payload);
    match r.u8()? {
        OP_PING => Ok(Request::Ping),
        OP_STATS => Ok(Request::Stats),
        OP_LABEL => {
            let k = r.u32()? as usize;
            let item = codec.read_item(&mut r)?;
            Ok(Request::Label { k, item })
        }
        OP_LABEL_BATCH => {
            let k = r.u32()? as usize;
            let items = read_items(&mut r, codec)?;
            Ok(Request::LabelBatch { k, items })
        }
        OP_INGEST => {
            let items = read_items(&mut r, codec)?;
            Ok(Request::Ingest { items })
        }
        OP_REMOVE => {
            let items = read_items(&mut r, codec)?;
            Ok(Request::Remove { items })
        }
        op => Err(bad(&format!("unknown op 0x{op:02x}"))),
    }
}

fn write_items<T, C: ItemCodec<T>>(
    w: &mut BinWriter<Vec<u8>>,
    codec: &C,
    items: &[T],
) -> io::Result<()> {
    if items.len() > MAX_BATCH {
        return Err(bad("batch exceeds MAX_BATCH"));
    }
    w.u32(items.len() as u32)?;
    for item in items {
        codec.write_item(w, item)?;
    }
    Ok(())
}

/// Encode a `Ping` request payload.
pub fn encode_ping() -> Vec<u8> {
    vec![OP_PING]
}

/// Encode a `Stats` request payload.
pub fn encode_stats() -> Vec<u8> {
    vec![OP_STATS]
}

/// Encode a `Label` request payload (`k = 0`: server-side `min_pts`).
pub fn encode_label<T, C: ItemCodec<T>>(
    codec: &C,
    item: &T,
    k: usize,
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_LABEL]);
    w.u32(k as u32)?;
    codec.write_item(&mut w, item)?;
    Ok(w.into_inner())
}

/// Encode a `LabelBatch` request payload.
pub fn encode_label_batch<T, C: ItemCodec<T>>(
    codec: &C,
    items: &[T],
    k: usize,
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_LABEL_BATCH]);
    w.u32(k as u32)?;
    write_items(&mut w, codec, items)?;
    Ok(w.into_inner())
}

/// Encode an `Ingest` request payload.
pub fn encode_ingest<T, C: ItemCodec<T>>(
    codec: &C,
    items: &[T],
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_INGEST]);
    write_items(&mut w, codec, items)?;
    Ok(w.into_inner())
}

/// Encode a `Remove` request payload.
pub fn encode_remove<T, C: ItemCodec<T>>(
    codec: &C,
    items: &[T],
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_REMOVE]);
    write_items(&mut w, codec, items)?;
    Ok(w.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::Item;
    use crate::persist::FrameworkCodec;

    #[test]
    fn frames_round_trip_through_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn oversized_length_prefix_is_rejected_not_allocated() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn requests_decode_back_to_what_was_encoded() {
        let codec = FrameworkCodec;
        let items =
            vec![Item::Dense(vec![1.0, 2.0]), Item::Dense(vec![3.0, 4.0])];

        match decode_request::<Item, _>(&encode_ping(), &codec).unwrap() {
            Request::Ping => {}
            other => panic!("got {other:?}"),
        }
        let p = encode_label(&codec, &items[0], 7).unwrap();
        match decode_request(&p, &codec).unwrap() {
            Request::Label { k: 7, item } => assert_eq!(item, items[0]),
            other => panic!("got {other:?}"),
        }
        let p = encode_ingest(&codec, &items).unwrap();
        match decode_request(&p, &codec).unwrap() {
            Request::Ingest { items: got } => assert_eq!(got, items),
            other => panic!("got {other:?}"),
        }
        let p = encode_label_batch(&codec, &items, 0).unwrap();
        match decode_request(&p, &codec).unwrap() {
            Request::LabelBatch { k: 0, items: got } => {
                assert_eq!(got, items)
            }
            other => panic!("got {other:?}"),
        }
    }

    #[test]
    fn unknown_op_and_truncated_payloads_error() {
        let codec = FrameworkCodec;
        assert!(decode_request::<Item, _>(&[0xEE], &codec).is_err());
        assert!(decode_request::<Item, _>(&[], &codec).is_err());
        // a Label header with no item bytes behind it
        assert!(
            decode_request::<Item, _>(&[OP_LABEL, 1, 0, 0, 0], &codec)
                .is_err()
        );
    }
}
