//! Length-prefixed binary frames — the `fishdbc serve` wire protocol.
//!
//! One frame per request, one frame per response, over a plain TCP
//! stream:
//!
//! ```text
//! frame            := len:u32-LE payload[len]
//! request payload  := op:u8 body
//!   0x01 Ping        (empty)
//!   0x02 Stats       (empty)
//!   0x03 Label       k:u32 item
//!   0x04 LabelBatch  k:u32 count:u32 item*count
//!   0x05 Ingest      count:u32 item*count
//!   0x06 Remove      count:u32 item*count
//!   0x07 Tree        (empty)
//!   0x08 LabelAt     k:u32 params item
//!   0x09 RelabelAt   params
//! params           := mcs:u32 eps:f64-bits mode:u8
//!   mode 0x00 stability | 0x01 leaf | 0x02 hybrid_eps
//! response payload := status:u8 body
//!   0x00 Ok          Ping   -> items:u64 epoch:u64
//!                    Stats  -> json:str
//!                    Label  -> label:i32 (two's-complement u32)
//!                    LabelBatch -> count:u32 label:i32*count
//!                    Ingest -> accepted:u64
//!                    Remove -> removed:u64
//!                    Tree   -> epoch:u64 count:u32 node*count
//!                      node := id:u32 parent:u32 lambda:f64-bits
//!                              stability:f64-bits size:u32
//!                    LabelAt -> label:i32 (two's-complement u32)
//!                    RelabelAt -> epoch:u64 n_clusters:u32 count:u32
//!                                 label:i32*count
//!   0x01 Busy        (empty — resend later; ingest backpressure, or the
//!                     whole connection was refused by a saturated pool)
//!   0x02 Err         msg:str (the server closes the connection after)
//! ```
//!
//! All integers are little-endian; `str` is the [`BinWriter::str`]
//! encoding (`u64` length + UTF-8 bytes); `f64-bits` is the IEEE-754
//! bit pattern as `u64` (bit-exact, so an `eps` round-trips into the
//! server's extraction memo key unchanged). Items are encoded through
//! the same [`ItemCodec`] seam the persistence layer uses, so anything
//! an engine can checkpoint it can also serve over the network, with
//! one codec definition. A `Label` response of `-1` means noise/unknown,
//! exactly like [`Engine::label`](crate::engine::Engine::label).
//!
//! `k = 0` in `Label`/`LabelBatch`/`LabelAt` means "use the server's
//! configured `min_pts`" — clients need not know the engine's
//! parameters. `Tree`/`LabelAt`/`RelabelAt` are the wire surface of
//! hierarchy-as-a-service: all three pin the latest epoch exactly like
//! `Label`, and `Tree`/`RelabelAt` never evaluate the metric.

use std::io::{self, Read, Write};

use crate::engine::{ExtractionMode, ExtractionParams};
use crate::persist::{BinReader, BinWriter, ItemCodec};

/// Hard cap on a single frame's payload; larger lengths are a protocol
/// error (defends the server against hostile 4 GiB allocations).
pub const MAX_FRAME: usize = 64 << 20;
/// Hard cap on the item count in one batched request.
pub const MAX_BATCH: usize = 1 << 20;

pub const OP_PING: u8 = 0x01;
pub const OP_STATS: u8 = 0x02;
pub const OP_LABEL: u8 = 0x03;
pub const OP_LABEL_BATCH: u8 = 0x04;
pub const OP_INGEST: u8 = 0x05;
pub const OP_REMOVE: u8 = 0x06;
pub const OP_TREE: u8 = 0x07;
pub const OP_LABEL_AT: u8 = 0x08;
pub const OP_RELABEL_AT: u8 = 0x09;

pub const ST_OK: u8 = 0x00;
pub const ST_BUSY: u8 = 0x01;
pub const ST_ERR: u8 = 0x02;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Wire code for an extraction mode (see the grammar above).
pub fn mode_code(mode: ExtractionMode) -> u8 {
    match mode {
        ExtractionMode::Stability => 0x00,
        ExtractionMode::Leaf => 0x01,
        ExtractionMode::HybridEps => 0x02,
    }
}

/// Decode a wire mode code; unknown codes are a protocol error.
pub fn mode_from_code(code: u8) -> io::Result<ExtractionMode> {
    match code {
        0x00 => Ok(ExtractionMode::Stability),
        0x01 => Ok(ExtractionMode::Leaf),
        0x02 => Ok(ExtractionMode::HybridEps),
        c => Err(bad(&format!("unknown extraction mode 0x{c:02x}"))),
    }
}

fn write_params(
    w: &mut BinWriter<Vec<u8>>,
    params: ExtractionParams,
) -> io::Result<()> {
    w.u32(params.mcs as u32)?;
    w.f64(params.eps)?;
    w.u8(mode_code(params.mode))
}

fn read_params(r: &mut BinReader<&[u8]>) -> io::Result<ExtractionParams> {
    let mcs = r.u32()? as usize;
    let eps = r.f64()?;
    let mode = mode_from_code(r.u8()?)?;
    Ok(ExtractionParams { mcs, eps, mode })
}

/// Write one `len + payload` frame and flush it.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(bad("frame exceeds MAX_FRAME"));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one byte, distinguishing clean EOF (`Ok(None)`) from errors.
/// The serve loop uses this to poll for the next frame's first length
/// byte in short timeout slices without losing stream sync.
pub fn read_byte<R: Read>(r: &mut R) -> io::Result<Option<u8>> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Read the rest of a frame whose first length byte was already consumed
/// by [`read_byte`].
pub fn read_frame_rest<R: Read>(
    first: u8,
    r: &mut R,
) -> io::Result<Vec<u8>> {
    let mut len = [first, 0, 0, 0];
    r.read_exact(&mut len[1..])?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(bad("frame exceeds MAX_FRAME"));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Read one whole frame; `Ok(None)` on clean EOF before any length byte.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Vec<u8>>> {
    match read_byte(r)? {
        None => Ok(None),
        Some(first) => read_frame_rest(first, r).map(Some),
    }
}

/// A decoded request, server side.
#[derive(Debug)]
pub enum Request<T> {
    Ping,
    Stats,
    Label { k: usize, item: T },
    LabelBatch { k: usize, items: Vec<T> },
    Ingest { items: Vec<T> },
    Remove { items: Vec<T> },
    Tree,
    LabelAt { k: usize, params: ExtractionParams, item: T },
    RelabelAt { params: ExtractionParams },
}

fn read_items<T, C: ItemCodec<T>>(
    r: &mut BinReader<&[u8]>,
    codec: &C,
) -> io::Result<Vec<T>> {
    let n = r.u32()? as usize;
    if n > MAX_BATCH {
        return Err(bad("batch exceeds MAX_BATCH"));
    }
    let mut items = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        items.push(codec.read_item(r)?);
    }
    Ok(items)
}

/// Decode a request payload (everything after the length prefix).
pub fn decode_request<T, C: ItemCodec<T>>(
    payload: &[u8],
    codec: &C,
) -> io::Result<Request<T>> {
    let mut r = BinReader::new(payload);
    match r.u8()? {
        OP_PING => Ok(Request::Ping),
        OP_STATS => Ok(Request::Stats),
        OP_LABEL => {
            let k = r.u32()? as usize;
            let item = codec.read_item(&mut r)?;
            Ok(Request::Label { k, item })
        }
        OP_LABEL_BATCH => {
            let k = r.u32()? as usize;
            let items = read_items(&mut r, codec)?;
            Ok(Request::LabelBatch { k, items })
        }
        OP_INGEST => {
            let items = read_items(&mut r, codec)?;
            Ok(Request::Ingest { items })
        }
        OP_REMOVE => {
            let items = read_items(&mut r, codec)?;
            Ok(Request::Remove { items })
        }
        OP_TREE => Ok(Request::Tree),
        OP_LABEL_AT => {
            let k = r.u32()? as usize;
            let params = read_params(&mut r)?;
            let item = codec.read_item(&mut r)?;
            Ok(Request::LabelAt { k, params, item })
        }
        OP_RELABEL_AT => {
            let params = read_params(&mut r)?;
            Ok(Request::RelabelAt { params })
        }
        op => Err(bad(&format!("unknown op 0x{op:02x}"))),
    }
}

fn write_items<T, C: ItemCodec<T>>(
    w: &mut BinWriter<Vec<u8>>,
    codec: &C,
    items: &[T],
) -> io::Result<()> {
    if items.len() > MAX_BATCH {
        return Err(bad("batch exceeds MAX_BATCH"));
    }
    w.u32(items.len() as u32)?;
    for item in items {
        codec.write_item(w, item)?;
    }
    Ok(())
}

/// Encode a `Ping` request payload.
pub fn encode_ping() -> Vec<u8> {
    vec![OP_PING]
}

/// Encode a `Stats` request payload.
pub fn encode_stats() -> Vec<u8> {
    vec![OP_STATS]
}

/// Encode a `Label` request payload (`k = 0`: server-side `min_pts`).
pub fn encode_label<T, C: ItemCodec<T>>(
    codec: &C,
    item: &T,
    k: usize,
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_LABEL]);
    w.u32(k as u32)?;
    codec.write_item(&mut w, item)?;
    Ok(w.into_inner())
}

/// Encode a `LabelBatch` request payload.
pub fn encode_label_batch<T, C: ItemCodec<T>>(
    codec: &C,
    items: &[T],
    k: usize,
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_LABEL_BATCH]);
    w.u32(k as u32)?;
    write_items(&mut w, codec, items)?;
    Ok(w.into_inner())
}

/// Encode an `Ingest` request payload.
pub fn encode_ingest<T, C: ItemCodec<T>>(
    codec: &C,
    items: &[T],
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_INGEST]);
    write_items(&mut w, codec, items)?;
    Ok(w.into_inner())
}

/// Encode a `Remove` request payload.
pub fn encode_remove<T, C: ItemCodec<T>>(
    codec: &C,
    items: &[T],
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_REMOVE]);
    write_items(&mut w, codec, items)?;
    Ok(w.into_inner())
}

/// Encode a `Tree` request payload.
pub fn encode_tree() -> Vec<u8> {
    vec![OP_TREE]
}

/// Encode a `LabelAt` request payload (`k = 0`: server-side `min_pts`).
pub fn encode_label_at<T, C: ItemCodec<T>>(
    codec: &C,
    item: &T,
    k: usize,
    params: ExtractionParams,
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_LABEL_AT]);
    w.u32(k as u32)?;
    write_params(&mut w, params)?;
    codec.write_item(&mut w, item)?;
    Ok(w.into_inner())
}

/// Encode a `RelabelAt` request payload.
pub fn encode_relabel_at(params: ExtractionParams) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(vec![OP_RELABEL_AT]);
    write_params(&mut w, params)?;
    Ok(w.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::Item;
    use crate::persist::FrameworkCodec;

    #[test]
    fn frames_round_trip_through_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn oversized_length_prefix_is_rejected_not_allocated() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn requests_decode_back_to_what_was_encoded() {
        let codec = FrameworkCodec;
        let items =
            vec![Item::Dense(vec![1.0, 2.0]), Item::Dense(vec![3.0, 4.0])];

        match decode_request::<Item, _>(&encode_ping(), &codec).unwrap() {
            Request::Ping => {}
            other => panic!("got {other:?}"),
        }
        let p = encode_label(&codec, &items[0], 7).unwrap();
        match decode_request(&p, &codec).unwrap() {
            Request::Label { k: 7, item } => assert_eq!(item, items[0]),
            other => panic!("got {other:?}"),
        }
        let p = encode_ingest(&codec, &items).unwrap();
        match decode_request(&p, &codec).unwrap() {
            Request::Ingest { items: got } => assert_eq!(got, items),
            other => panic!("got {other:?}"),
        }
        let p = encode_label_batch(&codec, &items, 0).unwrap();
        match decode_request(&p, &codec).unwrap() {
            Request::LabelBatch { k: 0, items: got } => {
                assert_eq!(got, items)
            }
            other => panic!("got {other:?}"),
        }
    }

    #[test]
    fn unknown_op_and_truncated_payloads_error() {
        let codec = FrameworkCodec;
        assert!(decode_request::<Item, _>(&[0xEE], &codec).is_err());
        assert!(decode_request::<Item, _>(&[], &codec).is_err());
        // a Label header with no item bytes behind it
        assert!(
            decode_request::<Item, _>(&[OP_LABEL, 1, 0, 0, 0], &codec)
                .is_err()
        );
    }

    /// Hierarchy-as-a-service frames: parameters round-trip bit-exactly
    /// (eps travels as IEEE-754 bits, so the server's memo key sees the
    /// client's exact float), and unknown mode codes are rejected.
    #[test]
    fn extraction_frames_round_trip_params_bit_exactly() {
        let codec = FrameworkCodec;
        let item = Item::Dense(vec![1.0, 2.0]);

        match decode_request::<Item, _>(&encode_tree(), &codec).unwrap() {
            Request::Tree => {}
            other => panic!("got {other:?}"),
        }

        for mode in [
            ExtractionMode::Stability,
            ExtractionMode::Leaf,
            ExtractionMode::HybridEps,
        ] {
            assert_eq!(mode_from_code(mode_code(mode)).unwrap(), mode);
            // an eps that is not exactly representable in decimal: the
            // bit pattern must survive the wire unchanged
            let params = ExtractionParams { mcs: 25, eps: 0.1 + 0.2, mode };
            let p = encode_label_at(&codec, &item, 4, params).unwrap();
            match decode_request(&p, &codec).unwrap() {
                Request::LabelAt { k: 4, params: got, item: it } => {
                    assert_eq!(got.mcs, params.mcs);
                    assert_eq!(got.eps.to_bits(), params.eps.to_bits());
                    assert_eq!(got.mode, mode);
                    assert_eq!(it, item);
                }
                other => panic!("got {other:?}"),
            }
            let p = encode_relabel_at(params).unwrap();
            match decode_request::<Item, _>(&p, &codec).unwrap() {
                Request::RelabelAt { params: got } => {
                    assert_eq!(got.eps.to_bits(), params.eps.to_bits());
                    assert_eq!(got.mode, mode);
                }
                other => panic!("got {other:?}"),
            }
        }
        assert!(mode_from_code(0x7F).is_err(), "unknown mode must error");
        // a RelabelAt header with a bad mode byte behind valid mcs/eps
        let mut p = encode_relabel_at(ExtractionParams::stability(5)).unwrap();
        *p.last_mut().unwrap() = 0x7F;
        assert!(decode_request::<Item, _>(&p, &codec).is_err());
    }
}
