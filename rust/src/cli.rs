//! Minimal command-line argument parsing (the offline image has no clap;
//! see DESIGN.md §Dependency-policy). Supports `--key value`, `--flag`,
//! and positional arguments.

use std::collections::{HashMap, HashSet};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: HashSet<String>,
}

/// Option keys that take a value; everything else starting with `--` is a
/// boolean flag.
pub fn parse(argv: &[String], value_keys: &[&str]) -> Result<Args, String> {
    let mut out = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(key) = a.strip_prefix("--") {
            if let Some((k, v)) = key.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if value_keys.contains(&key) {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| format!("--{key} expects a value"))?;
                out.options.insert(key.to_string(), v.clone());
            } else {
                out.flags.insert(key.to_string());
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad float {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = parse(
            &sv(&["run", "--n", "100", "--exact", "--ef=50", "extra"]),
            &["n", "ef"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 100);
        assert_eq!(a.usize_or("ef", 0).unwrap(), 50);
        assert!(a.flag("exact"));
        assert!(!a.flag("quality"));
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&sv(&["--n"]), &["n"]).is_err());
        let a = parse(&sv(&["--n", "abc"]), &["n"]).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }
}
