//! Union-find (disjoint set union) with union by rank and path halving.
//! Substrate for Kruskal's algorithm and the single-linkage builder.

#[derive(Clone, Debug)]
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind {
            parent: (0..n as u32).collect(),
            rank: vec![0; n],
            components: n,
        }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint components.
    pub fn components(&self) -> usize {
        self.components
    }

    /// Find with path halving.
    #[inline]
    pub fn find(&mut self, mut x: u32) -> u32 {
        loop {
            let p = self.parent[x as usize];
            if p == x {
                return x;
            }
            let gp = self.parent[p as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
    }

    /// Union by rank; returns false if already in the same component.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        self.components -= 1;
        let (ra, rb) = match self.rank[ra as usize].cmp(&self.rank[rb as usize]) {
            std::cmp::Ordering::Less => (rb, ra),
            std::cmp::Ordering::Greater => (ra, rb),
            std::cmp::Ordering::Equal => {
                self.rank[ra as usize] += 1;
                (ra, rb)
            }
        };
        self.parent[rb as usize] = ra;
        true
    }

    /// Whether a and b are currently connected.
    pub fn connected(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn basic_union_find() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.components(), 5);
        assert!(uf.union(0, 1));
        assert!(uf.union(2, 3));
        assert!(!uf.union(1, 0)); // already joined
        assert_eq!(uf.components(), 3);
        assert!(uf.connected(0, 1));
        assert!(!uf.connected(0, 2));
        assert!(uf.union(1, 3));
        assert!(uf.connected(0, 2));
        assert_eq!(uf.components(), 2);
    }

    #[test]
    fn prop_union_is_idempotent_and_transitive() {
        check("uf-invariants", 50, |rng, _| {
            let n = 2 + rng.below(60);
            let mut uf = UnionFind::new(n);
            let mut naive: Vec<usize> = (0..n).collect(); // naive labels
            for _ in 0..n * 2 {
                let a = rng.below(n);
                let b = rng.below(n);
                uf.union(a as u32, b as u32);
                // naive relabel
                let (la, lb) = (naive[a], naive[b]);
                if la != lb {
                    for l in naive.iter_mut() {
                        if *l == lb {
                            *l = la;
                        }
                    }
                }
                // spot-check equivalence on a few pairs
                for _ in 0..8 {
                    let x = rng.below(n);
                    let y = rng.below(n);
                    assert_eq!(
                        uf.connected(x as u32, y as u32),
                        naive[x] == naive[y],
                        "uf disagrees with naive on ({x},{y})"
                    );
                }
            }
            let distinct: std::collections::HashSet<_> = naive.iter().collect();
            assert_eq!(uf.components(), distinct.len());
        });
    }
}
