//! Incremental minimum-spanning-forest maintenance (Algorithm 1's
//! UPDATE_MST), justified by Eppstein's offline dynamic MSF lemma
//! (Theorem 3.4 in the paper): folding candidate edges into the current
//! forest with Kruskal yields a correct MSF of the union graph.

pub mod union_find;

pub use union_find::UnionFind;

/// A weighted undirected edge between item ids.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub a: u32,
    pub b: u32,
    pub w: f64,
}

impl Edge {
    pub fn new(a: u32, b: u32, w: f64) -> Self {
        Edge { a, b, w }
    }

    /// Canonical (min, max) endpoint ordering for use as a map key.
    #[inline]
    pub fn key(a: u32, b: u32) -> (u32, u32) {
        if a < b {
            (a, b)
        } else {
            (b, a)
        }
    }
}

/// Incrementally-maintained minimum spanning forest.
///
/// Invariant: `edges` is a minimum spanning forest (sorted by weight
/// ascending) of the union of all edges ever passed to [`Msf::update`].
#[derive(Clone, Debug, Default)]
pub struct Msf {
    edges: Vec<Edge>,
    n: usize,
}

impl Msf {
    pub fn new() -> Self {
        Msf::default()
    }

    /// Current forest edges, sorted by weight ascending.
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Number of nodes the forest spans (max id seen + 1).
    pub fn n_nodes(&self) -> usize {
        self.n
    }

    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|e| e.w).sum()
    }

    /// Total order used by Kruskal: weight ascending, ties broken on the
    /// canonical (min, max) endpoint key. The tie-break makes the kept
    /// forest a *canonical* MSF of the offered edge set — the engine's
    /// conformance harness relies on a delta merge and a from-scratch
    /// merge of the same state ordering tied edges identically.
    #[inline]
    fn cmp_edges(x: &Edge, y: &Edge) -> std::cmp::Ordering {
        x.w.total_cmp(&y.w)
            .then_with(|| Edge::key(x.a, x.b).cmp(&Edge::key(y.a, y.b)))
    }

    /// Fold a batch of candidate edges into the forest (Kruskal over the
    /// union of current forest + candidates). `n_nodes` is the current
    /// number of items. Candidates need not be sorted or deduplicated.
    ///
    /// Complexity: O(E log E) with E = |forest| + |candidates| = O(n + |c|).
    pub fn update(&mut self, mut candidates: Vec<Edge>, n_nodes: usize) {
        self.n = self.n.max(n_nodes);
        if candidates.is_empty() {
            return;
        }
        // The forest is already sorted; sort only the new candidates, then
        // merge the two sorted runs (perf: avoids re-sorting O(n) edges).
        candidates.sort_unstable_by(Self::cmp_edges);
        let mut merged = Vec::with_capacity(self.edges.len() + candidates.len());
        {
            let (mut i, mut j) = (0usize, 0usize);
            let old = &self.edges;
            while i < old.len() && j < candidates.len() {
                if Self::cmp_edges(&old[i], &candidates[j]).is_le() {
                    merged.push(old[i]);
                    i += 1;
                } else {
                    merged.push(candidates[j]);
                    j += 1;
                }
            }
            merged.extend_from_slice(&old[i..]);
            merged.extend_from_slice(&candidates[j..]);
        }
        let mut uf = UnionFind::new(self.n);
        let mut kept = Vec::with_capacity(self.n.saturating_sub(1));
        for e in merged {
            if uf.union(e.a, e.b) {
                kept.push(e);
                if kept.len() + 1 == self.n {
                    break; // spanning tree complete
                }
            }
        }
        self.edges = kept;
    }

    /// Batch Kruskal from scratch (reference implementation for tests and
    /// the exact baseline).
    pub fn from_edges(edges: Vec<Edge>, n_nodes: usize) -> Self {
        let mut msf = Msf::new();
        msf.update(edges, n_nodes);
        msf
    }

    /// Rebuild from edges known to already form a minimum spanning forest
    /// (persistence). Re-runs Kruskal as a cheap validity filter: for a
    /// genuine forest the result is identical.
    pub fn from_parts(edges: Vec<Edge>, n_nodes: usize) -> Self {
        Msf::from_edges(edges, n_nodes)
    }

    /// Edge-union Kruskal: one pass over the concatenation of several edge
    /// lists — the engine's global merge (per-shard MSFs + bridge edges).
    /// Correct by the same lemma as [`Msf::update`]: an MSF of a union graph
    /// only ever uses edges drawn from the MSFs of its parts plus the extra
    /// (bridge) edges offered alongside them.
    pub fn from_edge_lists(lists: &[&[Edge]], n_nodes: usize) -> Self {
        let total = lists.iter().map(|l| l.len()).sum();
        let mut edges = Vec::with_capacity(total);
        for l in lists {
            edges.extend_from_slice(l);
        }
        Msf::from_edges(edges, n_nodes)
    }

    /// Number of connected components among `n` nodes given this forest.
    pub fn components(&self) -> usize {
        self.n - self.edges.len()
    }

    /// Incremental deletion support: drop every edge with an endpoint
    /// failing `keep`. Removing edges from a forest leaves a forest, and a
    /// subsequence of a weight-sorted list stays sorted, so the invariant
    /// holds without re-running Kruskal. Note the *caveat* documented at
    /// `Fishdbc::remove_batch_ids`: an edge evicted earlier by a cycle
    /// through a now-removed node is not resurrected (it was never
    /// retained), so this is an MSF of the recorded graph minus the nodes,
    /// not of everything ever offered minus the nodes.
    pub fn retain_nodes(&mut self, keep: impl Fn(u32) -> bool) {
        self.edges.retain(|e| keep(e.a) && keep(e.b));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn random_graph(rng: &mut Rng, n: usize, m: usize) -> Vec<Edge> {
        (0..m)
            .map(|_| {
                let a = rng.below(n) as u32;
                let mut b = rng.below(n) as u32;
                if a == b {
                    b = (b + 1) % n as u32;
                }
                Edge::new(a, b, (rng.f64() * 100.0).round() / 8.0)
            })
            .collect()
    }

    /// O(2^E)-free brute force: Kruskal is the reference, so instead verify
    /// forest properties + weight against matrix-Prim on small dense graphs.
    fn prim_weight(n: usize, edges: &[Edge]) -> f64 {
        let inf = f64::INFINITY;
        let mut w = vec![vec![inf; n]; n];
        for e in edges {
            let (a, b) = (e.a as usize, e.b as usize);
            if e.w < w[a][b] {
                w[a][b] = e.w;
                w[b][a] = e.w;
            }
        }
        let mut total = 0.0;
        let mut in_tree = vec![false; n];
        let mut dist = vec![inf; n];
        // handle forests: restart Prim from every unreached node
        for start in 0..n {
            if in_tree[start] {
                continue;
            }
            dist[start] = 0.0;
            loop {
                let mut best = None;
                for v in 0..n {
                    if !in_tree[v] && dist[v] < inf {
                        if best.map_or(true, |b: usize| dist[v] < dist[b]) {
                            best = Some(v);
                        }
                    }
                }
                let Some(u) = best else { break };
                in_tree[u] = true;
                total += dist[u];
                dist[u] = inf;
                for v in 0..n {
                    if !in_tree[v] && w[u][v] < dist[v] {
                        dist[v] = w[u][v];
                    }
                }
            }
        }
        total
    }

    #[test]
    fn kruskal_simple_triangle() {
        let edges = vec![
            Edge::new(0, 1, 1.0),
            Edge::new(1, 2, 2.0),
            Edge::new(0, 2, 3.0),
        ];
        let msf = Msf::from_edges(edges, 3);
        assert_eq!(msf.edges().len(), 2);
        assert_eq!(msf.total_weight(), 3.0);
        assert_eq!(msf.components(), 1);
    }

    #[test]
    fn forest_on_disconnected_graph() {
        let edges = vec![Edge::new(0, 1, 1.0), Edge::new(2, 3, 1.0)];
        let msf = Msf::from_edges(edges, 5);
        assert_eq!(msf.edges().len(), 2);
        assert_eq!(msf.components(), 3); // {0,1}, {2,3}, {4}
    }

    #[test]
    fn duplicate_edges_keep_minimum() {
        let edges = vec![
            Edge::new(0, 1, 5.0),
            Edge::new(0, 1, 1.0),
            Edge::new(0, 1, 3.0),
        ];
        let msf = Msf::from_edges(edges, 2);
        assert_eq!(msf.edges().len(), 1);
        assert_eq!(msf.edges()[0].w, 1.0);
    }

    #[test]
    fn prop_msf_weight_matches_prim() {
        check("kruskal-vs-prim", 40, |rng, _| {
            let n = 2 + rng.below(30);
            let m = 1 + rng.below(n * 3);
            let edges = random_graph(rng, n, m);
            let msf = Msf::from_edges(edges.clone(), n);
            let expect = prim_weight(n, &edges);
            assert!(
                (msf.total_weight() - expect).abs() < 1e-9,
                "kruskal {} vs prim {expect}",
                msf.total_weight()
            );
            // acyclic: edges <= n-1, and components consistent
            assert!(msf.edges().len() < n);
        });
    }

    #[test]
    fn prop_incremental_equals_batch() {
        // Eppstein's lemma: folding edges in batches == one-shot Kruskal
        check("incremental-eq-batch", 40, |rng, _| {
            let n = 2 + rng.below(40);
            let m = 1 + rng.below(n * 4);
            let edges = random_graph(rng, n, m);
            let batch = Msf::from_edges(edges.clone(), n);

            let mut inc = Msf::new();
            let mut rest = edges;
            while !rest.is_empty() {
                let take = 1 + rng.below(rest.len());
                let chunk: Vec<Edge> = rest.drain(..take).collect();
                inc.update(chunk, n);
            }
            assert!(
                (inc.total_weight() - batch.total_weight()).abs() < 1e-9,
                "incremental {} vs batch {}",
                inc.total_weight(),
                batch.total_weight()
            );
            assert_eq!(inc.edges().len(), batch.edges().len());
        });
    }

    #[test]
    fn prop_edge_union_equals_oneshot() {
        // Kruskal over concatenated per-part MSFs + extra edges must match
        // one-shot Kruskal over everything (the engine-merge invariant).
        check("edge-union-eq-oneshot", 30, |rng, _| {
            let n = 4 + rng.below(40);
            let all = random_graph(rng, n, 2 + rng.below(n * 3));
            let cut = rng.below(all.len());
            let (left, right) = all.split_at(cut);
            let part_a = Msf::from_edges(left.to_vec(), n);
            let part_b = Msf::from_edges(right.to_vec(), n);
            let bridges = random_graph(rng, n, 1 + rng.below(n));

            let union = Msf::from_edge_lists(
                &[part_a.edges(), part_b.edges(), &bridges],
                n,
            );
            let mut oneshot_edges = all.to_vec();
            oneshot_edges.extend_from_slice(&bridges);
            let oneshot = Msf::from_edges(oneshot_edges, n);
            assert!(
                (union.total_weight() - oneshot.total_weight()).abs() < 1e-9,
                "union {} vs oneshot {}",
                union.total_weight(),
                oneshot.total_weight()
            );
            assert_eq!(union.edges().len(), oneshot.edges().len());
        });
    }

    #[test]
    fn prop_sharded_union_with_compacted_bridges_equals_oneshot() {
        // The engine-merge invariant at full generality (ISSUE 2): split a
        // random graph into S parts (the shards), take each part's MSF, add
        // bridge edges pre-compacted through their own Msf (the α·n flush
        // discipline), and Kruskal over the union must equal the MST of the
        // whole union graph — the UPDATE_MST merge lemma.
        check("sharded-union-eq-oneshot", 30, |rng, _| {
            let n = 4 + rng.below(40);
            let s = 2 + rng.below(4);
            let all = random_graph(rng, n, 2 + rng.below(n * 3));
            let mut parts: Vec<Vec<Edge>> = vec![Vec::new(); s];
            for (i, e) in all.iter().enumerate() {
                parts[i % s].push(*e);
            }
            let part_msfs: Vec<Msf> = parts
                .iter()
                .map(|p| Msf::from_edges(p.clone(), n))
                .collect();
            let bridges = random_graph(rng, n, 1 + rng.below(n));
            let bridge_msf = Msf::from_edges(bridges.clone(), n);

            let mut refs: Vec<&[Edge]> =
                part_msfs.iter().map(|m| m.edges()).collect();
            refs.push(bridge_msf.edges());
            let union = Msf::from_edge_lists(&refs, n);

            let mut oneshot_edges = all.clone();
            oneshot_edges.extend_from_slice(&bridges);
            let oneshot = Msf::from_edges(oneshot_edges, n);
            assert!(
                (union.total_weight() - oneshot.total_weight()).abs() < 1e-9,
                "union {} vs oneshot {} (s={s})",
                union.total_weight(),
                oneshot.total_weight()
            );
            assert_eq!(union.edges().len(), oneshot.edges().len());
        });
    }

    #[test]
    fn prop_cached_global_forest_absorbs_deltas() {
        // The delta-merge invariant: the previous epoch's global MSF is a
        // lossless summary of everything already offered — Kruskal over
        // (cached MSF ∪ delta edges) equals the MST of (everything ∪
        // delta). Cycle property: the union graph only grows, so an edge
        // once evicted can never re-enter an MSF.
        check("cached-forest-delta", 30, |rng, _| {
            let n = 4 + rng.below(40);
            let g1 = random_graph(rng, n, 2 + rng.below(n * 3));
            let g2 = random_graph(rng, n, 1 + rng.below(n * 2));
            let cached = Msf::from_edges(g1.clone(), n);
            let delta = Msf::from_edge_lists(&[cached.edges(), &g2], n);

            let mut all = g1;
            all.extend_from_slice(&g2);
            let oneshot = Msf::from_edges(all, n);
            assert!(
                (delta.total_weight() - oneshot.total_weight()).abs() < 1e-9,
                "delta {} vs oneshot {}",
                delta.total_weight(),
                oneshot.total_weight()
            );
            assert_eq!(delta.edges().len(), oneshot.edges().len());
        });
    }

    #[test]
    fn prop_edges_sorted_after_update() {
        check("msf-sorted", 20, |rng, _| {
            let n = 2 + rng.below(30);
            let mut msf = Msf::new();
            for _ in 0..4 {
                msf.update(random_graph(rng, n, n), n);
                let ws: Vec<f64> = msf.edges().iter().map(|e| e.w).collect();
                for w in ws.windows(2) {
                    assert!(w[0] <= w[1], "forest not sorted");
                }
            }
        });
    }
}
