//! Checkpointing and crash recovery on top of the [`Wal`].
//!
//! # Checkpoint file layout
//!
//! A checkpoint is the *unchanged* `FISHENG` engine container (v3, as
//! written by `Engine::save_with` — fixtures stay byte-identical)
//! followed by a 24-byte trailer:
//!
//! ```text
//! trailer := "FISHCKPT" cut_seq:u64-le watermark:u64-le
//! ```
//!
//! `cut_seq` is the WAL sequence the serialized state covers: every
//! record with `seq <= cut_seq` (ingests *and* removals) is fully
//! reflected in the container, every later record is not. A legacy
//! FISHENG file (v1/v2/v3, written by plain `save`) simply ends at the
//! container: [`read_checkpoint_with`] maps EOF-after-container to
//! `cut_seq = 0`, so old files load byte-identically as "checkpoint
//! covering nothing in the WAL".
//!
//! # Consistent cuts under concurrent ingest
//!
//! [`write_checkpoint`] freezes the WAL mutex, which stops id
//! reservation and removal application, then drives
//! `Engine::save_cut_with` with `required_watermark` = the frozen WAL
//! watermark. The cut loop inside the engine flushes shard queues until
//! the stored id space is dense *and* equal to that watermark — without
//! the second condition a batch that was journaled but not yet enqueued
//! could hold the highest ids while the stored prefix still looks dense,
//! and the cut would silently exclude a batch the WAL believes is below
//! `cut_seq` (lost on the next trim). Once the cut is pinned (shard
//! locks held, `next_global` read) the engine calls back `on_cut` and
//! the WAL mutex is released — serialization of the locked states
//! proceeds concurrently with new appends.
//!
//! # Recovery
//!
//! [`Durable::open`] loads the newest published checkpoint (if any),
//! opens the WAL with torn-tail repair, replays every record with
//! `seq > cut_seq` through the *normal* ingest path — so conformance vs
//! `Engine::reference_cluster` holds by construction — and only then
//! installs the [`DurabilitySink`], so replay never re-journals. Cost is
//! O(records since the last checkpoint), surfaced by the `wal_replayed`
//! counter.

use std::cell::Cell;
use std::fs::{self, File};
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::engine::{Engine, EngineConfig, EngineItem};
use crate::obs::journal::JournalEvent;
use crate::obs::{CounterId, HistId};
use crate::persist::{FrameworkCodec, ItemCodec};
use crate::{Item, Metric, MetricKind};

use super::wal::{Wal, KIND_INGEST};
use super::{atomic_replace, bad, DurabilityConfig, DurabilitySink};

/// Trailer magic appended after the FISHENG container.
pub(crate) const TRAILER_MAGIC: &[u8; 8] = b"FISHCKPT";
/// The published checkpoint's file name inside the WAL directory.
pub const CHECKPOINT_FILE: &str = "checkpoint.fisheng";
/// Scratch name the checkpoint is built under before the atomic publish.
const CHECKPOINT_TMP: &str = "checkpoint.fisheng.tmp";

/// What one [`write_checkpoint`] accomplished.
#[derive(Clone, Copy, Debug)]
pub struct CheckpointStats {
    /// Ingest watermark (= items ever assigned) the checkpoint covers.
    pub watermark: u64,
    /// WAL sequence the checkpoint covers (replay starts after it).
    pub cut_seq: u64,
    /// WAL segments reclaimed by the post-publish trim.
    pub trimmed_segments: usize,
    /// End-to-end wall time in seconds.
    pub secs: f64,
}

/// Serialize a consistent cut of `engine` to a temp file in `dir`,
/// fsync, atomically publish it as [`CHECKPOINT_FILE`], and trim WAL
/// segments below the cut. See the module docs for the cut protocol.
pub fn write_checkpoint<T, M, C>(
    engine: &Engine<T, M>,
    wal: &Wal<T, C>,
    metric_name: &str,
    dir: &Path,
) -> io::Result<CheckpointStats>
where
    T: EngineItem,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T>,
{
    let t0 = Instant::now();
    let tmp = dir.join(CHECKPOINT_TMP);
    let dest = dir.join(CHECKPOINT_FILE);
    let result = (|| -> io::Result<CheckpointStats> {
        let mut w = BufWriter::new(File::create(&tmp)?);

        // Freeze the WAL: no id reservations, no removal applications.
        // `required` is the watermark the cut must reach exactly; the
        // guard is handed to `on_cut`, which records the cut sequence
        // and releases it the moment the shard locks are pinned.
        let mut guard = Some(wal.lock());
        let required = guard.as_ref().expect("guard just set").watermark();
        let cut_seq = Cell::new(0u64);
        let watermark = engine.save_cut_with(
            metric_name,
            wal.codec(),
            &mut w,
            Some(required),
            |_next_global| {
                if let Some(g) = guard.take() {
                    cut_seq.set(g.last_seq());
                }
            },
        )?;
        drop(guard); // no-op on success; releases the freeze on a pre-cut error
        debug_assert_eq!(
            watermark, required,
            "cut watermark must equal the frozen WAL watermark"
        );

        w.write_all(TRAILER_MAGIC)?;
        w.write_all(&cut_seq.get().to_le_bytes())?;
        w.write_all(&watermark.to_le_bytes())?;
        let file = w.into_inner().map_err(|e| e.into_error())?;
        file.sync_all()?;
        drop(file);
        atomic_replace(&tmp, &dest)?;

        let trimmed = wal.trim(cut_seq.get());
        Ok(CheckpointStats {
            watermark,
            cut_seq: cut_seq.get(),
            trimmed_segments: trimmed,
            secs: t0.elapsed().as_secs_f64(),
        })
    })();
    match result {
        Ok(stats) => {
            let obs = engine.registry();
            obs.inc(CounterId::Checkpoints);
            obs.record_secs(HistId::Checkpoint, stats.secs);
            obs.journal.push(obs.uptime_secs(), JournalEvent::CheckpointEnd {
                items: stats.watermark as usize,
                watermark: stats.watermark,
                secs: stats.secs,
                trimmed_segments: stats.trimmed_segments,
            });
            Ok(stats)
        }
        Err(e) => {
            let _ = fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// Read `n <= buf.len()` bytes, stopping early only at EOF.
fn read_up_to<R: Read>(r: &mut R, buf: &mut [u8]) -> io::Result<usize> {
    let mut n = 0;
    while n < buf.len() {
        match r.read(&mut buf[n..]) {
            Ok(0) => break,
            Ok(k) => n += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(n)
}

/// Load a checkpoint (or any legacy `FISHENG` v1/v2/v3 file — both read
/// byte-identically through this one entry point). Returns the engine
/// plus `(cut_seq, watermark)` from the trailer; a legacy file without a
/// trailer yields `cut_seq = 0` and the engine's own item count.
pub fn read_checkpoint_with<T, M, C, F, R>(
    codec: &C,
    resolve: F,
    mut r: R,
) -> io::Result<(Engine<T, M>, u64, u64)>
where
    T: EngineItem,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T>,
    F: FnOnce(&str) -> io::Result<M>,
    R: Read,
{
    // `load_with` consumes exactly the container bytes, leaving `r`
    // positioned at the trailer (or at EOF for a legacy file)
    let engine = Engine::load_with(codec, resolve, &mut r)?;
    let mut magic = [0u8; 8];
    let n = read_up_to(&mut r, &mut magic)?;
    if n == 0 {
        let watermark = engine.len() as u64;
        return Ok((engine, 0, watermark));
    }
    if n < magic.len() || &magic != TRAILER_MAGIC {
        engine.shutdown();
        return Err(bad("bad checkpoint trailer magic"));
    }
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    let cut_seq = u64::from_le_bytes(b);
    r.read_exact(&mut b)?;
    let watermark = u64::from_le_bytes(b);
    if watermark != engine.len() as u64 {
        engine.shutdown();
        return Err(bad("checkpoint trailer watermark disagrees with container"));
    }
    Ok((engine, cut_seq, watermark))
}

struct Ctx<T, M, C> {
    engine: Arc<Engine<T, M>>,
    wal: Arc<Wal<T, C>>,
    metric_name: String,
    dir: PathBuf,
    /// Auto-checkpoint after this many newly journaled items (0 = off).
    every: u64,
    /// Watermark covered by the last completed checkpoint.
    last_ckpt: AtomicU64,
    stop: Mutex<bool>,
    wake: Condvar,
}

fn run_checkpoint<T, M, C>(ctx: &Ctx<T, M, C>) -> io::Result<CheckpointStats>
where
    T: EngineItem,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T>,
{
    let stats =
        write_checkpoint(&ctx.engine, &ctx.wal, &ctx.metric_name, &ctx.dir)?;
    ctx.last_ckpt.store(stats.watermark, Ordering::Relaxed);
    Ok(stats)
}

/// Background policy thread: poll the journaled watermark and checkpoint
/// once `every` new items have accumulated. Errors are surfaced
/// (`wal_errors` counter + sticky `last_error`), never panicked on —
/// mirrors the engine's own `recluster_loop` shape.
fn checkpoint_loop<T, M, C>(ctx: &Ctx<T, M, C>)
where
    T: EngineItem,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T>,
{
    loop {
        {
            let stop = ctx.stop.lock().unwrap_or_else(|e| e.into_inner());
            if *stop {
                return;
            }
            let (stop, _) = ctx
                .wake
                .wait_timeout(stop, Duration::from_millis(50))
                .unwrap_or_else(|e| e.into_inner());
            if *stop {
                return;
            }
        }
        let watermark = ctx.wal.watermark();
        let last = ctx.last_ckpt.load(Ordering::Relaxed);
        if watermark.saturating_sub(last) >= ctx.every {
            if let Err(e) = run_checkpoint(ctx) {
                ctx.wal.note_error(&format!("checkpoint failed: {e}"));
            }
        }
    }
}

/// A durably-persisted engine: WAL-journaled writes, automatic crash
/// recovery on open, and (optionally) background checkpointing. The
/// default type instantiation is the CLI's `Item`/`MetricKind`/
/// [`FrameworkCodec`] stack; any `Engine<T, M>` works with a matching
/// codec.
pub struct Durable<T = Item, M = MetricKind, C = FrameworkCodec>
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T> + Send + Sync + 'static,
{
    ctx: Arc<Ctx<T, M, C>>,
    thread: Option<JoinHandle<()>>,
}

impl<T, M, C> Durable<T, M, C>
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T> + Send + Sync + 'static,
{
    /// Open (or create) a durable engine under `dcfg.wal_dir`:
    ///
    /// 1. load the published checkpoint if one exists (else spawn a
    ///    fresh engine from `metric` + `config`),
    /// 2. open the WAL, repairing any torn tail,
    /// 3. replay every record past the checkpoint's cut through the
    ///    normal `add_batch`/`remove_batch` path (sink not yet
    ///    installed — nothing is re-journaled),
    /// 4. install the WAL as the engine's [`DurabilitySink`] and start
    ///    the background checkpoint thread (if `checkpoint_every > 0`).
    ///
    /// Recovery is idempotent: crashing *during* recovery and reopening
    /// replays the same suffix onto the same checkpoint.
    pub fn open<F>(
        metric: M,
        metric_name: &str,
        config: EngineConfig,
        codec: C,
        dcfg: DurabilityConfig,
        resolve: F,
    ) -> io::Result<Self>
    where
        F: FnOnce(&str) -> io::Result<M>,
    {
        fs::create_dir_all(&dcfg.wal_dir)?;
        let ckpt_path = dcfg.wal_dir.join(CHECKPOINT_FILE);
        let (engine, cut_seq, ckpt_watermark) = if ckpt_path.exists() {
            let f = BufReader::new(File::open(&ckpt_path)?);
            read_checkpoint_with(&codec, resolve, f)?
        } else {
            (Engine::spawn(metric, config), 0, 0)
        };
        let checkpoint_items = engine.len();

        let (wal, records) = Wal::open(
            &dcfg.wal_dir,
            codec,
            dcfg.segment_bytes,
            cut_seq,
            ckpt_watermark,
        )?;

        let mut replayed_batches = 0usize;
        let mut replayed_items = 0usize;
        for rec in records {
            if rec.seq <= cut_seq {
                continue; // already inside the checkpoint
            }
            if rec.kind == KIND_INGEST {
                let base = rec.watermark_after - rec.items.len() as u64;
                if base != engine.len() as u64 {
                    engine.shutdown();
                    return Err(bad("WAL suffix does not continue this checkpoint"));
                }
                replayed_items += rec.items.len();
                engine.add_batch(rec.items);
            } else {
                engine.remove_batch(&rec.items);
            }
            engine.registry().inc(CounterId::WalReplayed);
            replayed_batches += 1;
        }
        engine.flush();

        if wal.watermark() != engine.len() as u64 {
            engine.shutdown();
            return Err(bad("WAL watermark disagrees with recovered engine"));
        }

        if checkpoint_items > 0 || replayed_batches > 0 {
            let obs = engine.registry();
            obs.journal.push(obs.uptime_secs(), JournalEvent::Recovery {
                checkpoint_items,
                replayed_batches,
                replayed_items,
            });
        }

        let engine = Arc::new(engine);
        let wal = Arc::new(wal);
        engine.install_durability(Arc::clone(&wal) as Arc<dyn DurabilitySink<T>>);

        let ctx = Arc::new(Ctx {
            engine,
            wal,
            metric_name: metric_name.to_string(),
            dir: dcfg.wal_dir.clone(),
            every: dcfg.checkpoint_every,
            last_ckpt: AtomicU64::new(ckpt_watermark),
            stop: Mutex::new(false),
            wake: Condvar::new(),
        });
        let thread = if dcfg.checkpoint_every > 0 {
            let ctx2 = Arc::clone(&ctx);
            Some(
                std::thread::Builder::new()
                    .name("fishdbc-ckpt".into())
                    .spawn(move || checkpoint_loop(&ctx2))
                    .expect("spawn checkpoint thread"),
            )
        } else {
            None
        };
        Ok(Durable { ctx, thread })
    }

    /// The recovered (or fresh) engine. Clone the `Arc` to share it with
    /// a server; keep the `Durable` alive for as long as writes should
    /// be journaled.
    pub fn engine(&self) -> &Arc<Engine<T, M>> {
        &self.ctx.engine
    }

    /// Ingest watermark after the last journaled record.
    pub fn watermark(&self) -> u64 {
        self.ctx.wal.watermark()
    }

    /// Fsync the WAL (group commit); returns the durable watermark.
    pub fn sync(&self) -> io::Result<u64> {
        self.ctx.wal.sync_now()
    }

    /// Take a checkpoint right now (also resets the background
    /// accumulation counter).
    pub fn checkpoint(&self) -> io::Result<CheckpointStats> {
        run_checkpoint(&self.ctx)
    }

    fn stop_thread(&mut self) {
        if let Some(h) = self.thread.take() {
            *self.ctx.stop.lock().unwrap_or_else(|e| e.into_inner()) = true;
            self.ctx.wake.notify_all();
            let _ = h.join();
        }
    }

    /// Stop the background thread and fsync the WAL tail, then drop this
    /// handle's engine `Arc` — when the caller holds no clone of
    /// [`Durable::engine`], the engine's own `Drop` joins every shard
    /// worker before this returns. Deliberately *not* a final
    /// checkpoint: shutdown must stay O(tail), and the WAL suffix
    /// replays on the next open anyway.
    pub fn shutdown(mut self) {
        self.stop_thread();
        let _ = self.ctx.wal.sync_now();
    }
}

impl<T, M, C> Drop for Durable<T, M, C>
where
    T: EngineItem + PartialEq,
    M: Metric<T> + Clone + 'static,
    C: ItemCodec<T> + Send + Sync + 'static,
{
    fn drop(&mut self) {
        self.stop_thread();
        let _ = self.ctx.wal.sync_now();
    }
}

impl Durable {
    /// [`Durable::open`] for the framework stack (`Item` under a named
    /// [`MetricKind`], framed by [`FrameworkCodec`]) — what `fishdbc
    /// engine --wal-dir` and `fishdbc serve --wal-dir` use.
    pub fn open_framework(
        metric: MetricKind,
        config: EngineConfig,
        dcfg: DurabilityConfig,
    ) -> io::Result<Self> {
        let name = metric.name();
        Durable::open(metric, name, config, FrameworkCodec, dcfg, |stored| {
            MetricKind::parse(stored)
                .ok_or_else(|| bad(&format!("unknown metric `{stored}` in checkpoint")))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineConfig;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fishdbc_ckpt_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn points(n: usize, off: f32) -> Vec<Item> {
        (0..n)
            .map(|i| Item::Dense(vec![off + (i % 10) as f32, (i / 10) as f32]))
            .collect()
    }

    fn config() -> EngineConfig {
        EngineConfig { shards: 2, ..Default::default() }
    }

    fn dcfg(dir: &Path) -> DurabilityConfig {
        DurabilityConfig::new(dir)
    }

    #[test]
    fn fresh_open_checkpoint_reopen_roundtrip() {
        let dir = tmp_dir("roundtrip");
        {
            let d = Durable::open_framework(
                MetricKind::Euclidean,
                config(),
                dcfg(&dir),
            )
            .unwrap();
            d.engine().add_batch(points(40, 0.0));
            d.engine().flush();
            assert_eq!(d.watermark(), 40, "journaled watermark tracks ingest");
            let stats = d.checkpoint().unwrap();
            assert_eq!(stats.watermark, 40);
            assert!(stats.cut_seq >= 1);
            // post-checkpoint delta, journaled but not checkpointed
            d.engine().add_batch(points(10, 100.0));
            d.sync().unwrap();
            d.shutdown();
        }
        let d =
            Durable::open_framework(MetricKind::Euclidean, config(), dcfg(&dir))
                .unwrap();
        assert_eq!(d.engine().len(), 50, "checkpoint + replayed suffix");
        // O(Δ): only the post-checkpoint batch replays
        let replayed = d
            .engine()
            .registry()
            .counter(CounterId::WalReplayed)
            .get();
        assert_eq!(replayed, 1);
        d.shutdown();
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_trims_wal_and_replay_stays_o_delta() {
        let dir = tmp_dir("trim");
        let mut dc = dcfg(&dir);
        dc.segment_bytes = 256; // force rotation so trim has segments to eat
        {
            let d = Durable::open_framework(
                MetricKind::Euclidean,
                config(),
                dc.clone(),
            )
            .unwrap();
            for chunk in points(60, 0.0).chunks(5) {
                d.engine().add_batch(chunk.to_vec());
            }
            d.engine().flush();
            let stats = d.checkpoint().unwrap();
            assert!(
                stats.trimmed_segments > 0,
                "rotated segments below the cut must be reclaimed"
            );
            d.shutdown();
        }
        let d =
            Durable::open_framework(MetricKind::Euclidean, config(), dc).unwrap();
        assert_eq!(d.engine().len(), 60);
        assert_eq!(
            d.engine().registry().counter(CounterId::WalReplayed).get(),
            0,
            "everything was inside the checkpoint"
        );
        d.shutdown();
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_engine_file_reads_as_cut_zero() {
        let dir = tmp_dir("legacy");
        // a plain Engine::save file (no trailer) *is* a valid checkpoint
        let engine: Engine = Engine::spawn(MetricKind::Euclidean, config());
        engine.add_batch(points(25, 0.0));
        let mut buf = Vec::new();
        engine.save(&mut buf).unwrap();
        engine.shutdown();
        let (reloaded, cut_seq, watermark) = read_checkpoint_with(
            &FrameworkCodec,
            |name| {
                MetricKind::parse(name)
                    .ok_or_else(|| bad(&format!("unknown metric `{name}`")))
            },
            buf.as_slice(),
        )
        .unwrap();
        assert_eq!(cut_seq, 0, "legacy file covers nothing in the WAL");
        assert_eq!(watermark, 25);
        assert_eq!(reloaded.len(), 25);
        reloaded.shutdown();
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn background_thread_checkpoints_on_watermark_accumulation() {
        let dir = tmp_dir("bg");
        let mut dc = dcfg(&dir);
        dc.checkpoint_every = 20;
        let d =
            Durable::open_framework(MetricKind::Euclidean, config(), dc).unwrap();
        d.engine().add_batch(points(30, 0.0));
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if d.engine().registry().counter(CounterId::Checkpoints).get() > 0 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "background checkpoint never fired"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(dir.join(CHECKPOINT_FILE).exists());
        d.shutdown();
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn removals_survive_recovery() {
        let dir = tmp_dir("removes");
        let items = points(30, 0.0);
        {
            let d = Durable::open_framework(
                MetricKind::Euclidean,
                config(),
                dcfg(&dir),
            )
            .unwrap();
            d.engine().add_batch(items.clone());
            let removed = d.engine().remove_batch(&items[..5]);
            assert_eq!(removed, 5);
            d.shutdown();
        }
        let d =
            Durable::open_framework(MetricKind::Euclidean, config(), dcfg(&dir))
                .unwrap();
        assert_eq!(d.engine().len(), 30, "slots are stable across recovery");
        assert_eq!(
            d.engine().deleted_globals().len(),
            5,
            "the journaled removal replayed"
        );
        d.shutdown();
        let _ = fs::remove_dir_all(&dir);
    }
}
