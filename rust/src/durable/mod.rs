//! Durable incremental persistence: a write-ahead log of ingest/remove
//! batches plus background epoch checkpointing, turning `Engine::save`'s
//! full-state rewrite into an O(Δ)-recovery subsystem.
//!
//! # Why this layer exists
//!
//! The paper's incremental axis — lightweight updates when few items are
//! added — stopped at the process boundary: a checkpoint was a monolithic
//! rewrite of the whole engine, and a crash lost everything since the
//! last one. That is unacceptable for the serving layer, which acks
//! ingests over the wire: an acknowledged batch should survive `kill -9`,
//! not just a graceful drain. The same delta-cost principle that makes
//! incremental DBSCAN maintenance cheap (Chakraborty & Nagwani,
//! arXiv 1406.4754) must hold for durability: recovery cost is
//! O(Δ since the last checkpoint), never O(n).
//!
//! # Pieces
//!
//! * [`wal::Wal`] — an append-only log of length-prefixed, checksummed
//!   batch records (through the existing [`ItemCodec`] seam), with
//!   segment rotation, group-commit fsync, and torn-tail truncation on
//!   open. It implements [`DurabilitySink`], the seam the engine's write
//!   path journals through.
//! * [`checkpoint`] — serializes a consistent cut of the engine into the
//!   unchanged `FISHENG` container (plus a small trailer recording the
//!   cut's WAL sequence number), fsyncs, atomically publishes it over the
//!   previous checkpoint, and trims WAL segments below the cut.
//! * [`Durable`] — the controller tying both together: open-or-recover,
//!   replay the WAL suffix through the normal ingest path, install the
//!   sink, and run the background checkpoint thread.
//!
//! # The write-order invariant
//!
//! Correct replay needs WAL order to equal global-id order. Both the id
//! reservation (the engine's `next_global` bump) and the record append
//! happen under one WAL mutex ([`DurabilitySink::log_add`]), so a record
//! at sequence `s` always covers ids strictly after every record before
//! `s`. Removals are journaled *and applied* under the same mutex hold
//! ([`DurabilitySink::log_remove`]), which is what lets a checkpoint cut
//! (taken under that mutex) know that every remove at or below its cut
//! sequence is fully reflected in the serialized state.
//!
//! # Durability modes
//!
//! An `Ok` ingest ack means, in order of increasing strength:
//!
//! * **volatile** (no WAL): ids assigned, batch FIFO-queued — durable
//!   across a graceful drain only.
//! * **journaled** (WAL attached, `--durable` off): the record is in the
//!   OS page cache when the ack is written; a process crash keeps it, a
//!   power loss may not.
//! * **durable** (`--durable`): the ack is written only after the
//!   record's fsync returns — the batch survives `kill -9` and power
//!   loss, bounded by the disk's own write-cache honesty.
//!
//! [`ItemCodec`]: crate::persist::ItemCodec

pub mod checkpoint;
pub mod wal;

pub use checkpoint::{
    read_checkpoint_with, write_checkpoint, CheckpointStats, Durable,
    CHECKPOINT_FILE,
};
pub use wal::{Wal, WalRecord};

use std::fs::File;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::obs::Registry;

pub(crate) fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Configuration for the durability subsystem. Deliberately *not* part of
/// [`EngineConfig`](crate::engine::EngineConfig): that struct is `Copy`,
/// persisted inside every checkpoint header, and constructed exhaustively
/// across the codebase — durability is a property of the deployment
/// (where the log lives), not of the clustering state.
#[derive(Clone, Debug)]
pub struct DurabilityConfig {
    /// Directory holding the WAL segments and the checkpoint file.
    pub wal_dir: PathBuf,
    /// Checkpoint automatically after this many newly journaled items
    /// (0 = only explicit [`Durable::checkpoint`] calls).
    pub checkpoint_every: u64,
    /// Rotate the active WAL segment once it grows past this many bytes.
    pub segment_bytes: u64,
}

impl DurabilityConfig {
    /// Defaults for a WAL under `wal_dir`: no automatic checkpoints,
    /// 64 MiB segments.
    pub fn new(wal_dir: impl Into<PathBuf>) -> Self {
        DurabilityConfig {
            wal_dir: wal_dir.into(),
            checkpoint_every: 0,
            segment_bytes: 64 << 20,
        }
    }
}

/// Atomically publish `tmp` at `dest`: rename, then fsync the parent
/// directory — POSIX only makes the *rename itself* durable once the
/// directory entry is on disk, so skipping the second step can resurrect
/// the old file after a power loss. Every file publish in this module
/// (checkpoints today, any future artifact) goes through here.
pub fn atomic_replace(tmp: &Path, dest: &Path) -> io::Result<()> {
    std::fs::rename(tmp, dest)?;
    sync_parent_dir(dest)
}

/// Fsync the directory containing `path` (no-op target: directories are
/// not a syncable handle everywhere, but they are on the platforms the
/// engine serves from).
pub(crate) fn sync_parent_dir(path: &Path) -> io::Result<()> {
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    File::open(parent)?.sync_all()
}

/// The seam the engine's write path journals through. Installed with
/// [`Engine::install_durability`](crate::engine::Engine::install_durability);
/// [`wal::Wal`] is the only production implementation, but tests stub it.
///
/// Append failures are *absorbed*, not propagated: by the time a record
/// can fail, its ids are already assigned, and dropping the in-memory
/// batch would break the dense-id invariant persistence relies on. They
/// are surfaced instead — a `wal_errors` counter, the sticky
/// [`DurabilitySink::last_error`] message (exported via `EngineStats`),
/// and a failed [`DurabilitySink::sync`] for any ack that depended on the
/// lost record.
pub trait DurabilitySink<T>: Send + Sync {
    /// Late-bind the engine's telemetry registry (called once by
    /// `install_durability`; appends before binding are simply uncounted).
    fn bind_registry(&self, _obs: Arc<Registry>) {}

    /// Journal an ingest batch. `assign` is the engine's id-range
    /// reservation; it runs *under the sink's internal mutex, before the
    /// append*, so WAL order equals id order and a panicking reservation
    /// (id space exhausted) never leaves a phantom record. Returns the
    /// base global id `assign` produced.
    fn log_add(&self, items: &[T], assign: &mut dyn FnMut(usize) -> u64) -> u64;

    /// Journal a removal batch and run `apply` (the engine's tombstoning
    /// pass) under the same mutex hold, so a checkpoint cut that covers
    /// this record's sequence also covers its effects. Returns `apply`'s
    /// removed count.
    fn log_remove(&self, items: &[T], apply: &mut dyn FnMut() -> usize) -> usize;

    /// Flush and fsync everything appended so far (group commit). Returns
    /// the ingest watermark now guaranteed durable; errors if the fsync
    /// failed *or* any append since the previous sync was lost, so a
    /// durable ack can never cover a missing record.
    fn sync(&self) -> io::Result<u64>;

    /// Ingest watermark (global ids assigned) through the last appended
    /// record.
    fn watermark(&self) -> u64;

    /// Most recent append/fsync error, if any (sticky; for
    /// `EngineStats::wal_last_error`).
    fn last_error(&self) -> Option<String> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_replace_publishes_and_survives_reread() {
        let dir = std::env::temp_dir()
            .join(format!("fishdbc_atomic_replace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let dest = dir.join("out.bin");
        std::fs::write(&dest, b"old").unwrap();
        let tmp = dir.join("out.bin.tmp");
        std::fs::write(&tmp, b"new contents").unwrap();
        atomic_replace(&tmp, &dest).unwrap();
        assert_eq!(std::fs::read(&dest).unwrap(), b"new contents");
        assert!(!tmp.exists(), "tmp must be consumed by the rename");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
