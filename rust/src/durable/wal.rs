//! Segmented write-ahead log of ingest/remove batches.
//!
//! # On-disk grammar
//!
//! A WAL directory holds segments named `wal-<start_seq:016x>.log`
//! (hex-padded so lexicographic order equals sequence order) plus the
//! checkpoint file. Each segment is:
//!
//! ```text
//! header  := "FISHWAL\0" version:u32-le start_seq:u64-le      (20 bytes)
//! record  := len:u32-le checksum:u64-le payload[len]
//! payload := seq:u64 kind:u8 watermark_after:u64 count:len items...
//! ```
//!
//! `checksum` is [`FastHasher`] over the payload bytes. The payload is
//! written with the persistence layer's [`BinWriter`] primitives and the
//! items with the engine's [`ItemCodec`] — the WAL never invents its own
//! item encoding. `kind` is 0 for an ingest batch, 1 for a removal
//! batch; `watermark_after` is the engine's ingest watermark (total
//! global ids assigned) *after* the record, so any single record restores
//! the watermark during a scan. Sequence numbers start at 1 and increase
//! by exactly 1 across the whole log (`cut_seq = 0` in a checkpoint
//! trailer therefore means "nothing checkpointed").
//!
//! # Torn tails
//!
//! [`Wal::open`] replays the segments in order and stops at the first
//! invalid record — short frame, over-long length prefix, checksum
//! mismatch, undecodable payload, or sequence discontinuity. The broken
//! segment is truncated in place to its last valid record and every later
//! segment is deleted (they are beyond the logical end of the log). The
//! result is always the longest valid record *prefix*: a half-written
//! batch is never replayed, and recovery never panics on torn bytes.

use std::fs::{self, File, OpenOptions};
use std::hash::Hasher;
use std::io::{self, BufWriter, Read, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::obs::{CounterId, HistId, Registry};
use crate::persist::{BinReader, BinWriter, ItemCodec};
use crate::util::fasthash::FastHasher;

use super::{bad, sync_parent_dir, DurabilitySink};

pub(crate) const SEGMENT_MAGIC: &[u8; 8] = b"FISHWAL\0";
pub(crate) const SEGMENT_VERSION: u32 = 1;
/// Segment header size: magic + version + start_seq.
pub(crate) const SEGMENT_HEADER: usize = 8 + 4 + 8;
/// Frame overhead per record: length prefix + checksum.
const FRAME_OVERHEAD: u64 = 4 + 8;
/// Sanity cap on a record's payload — guards scans against a corrupt
/// length prefix asking for gigabytes.
const MAX_RECORD: u32 = 1 << 30;

/// Record kind: an ingest batch (ids `watermark_after - count ..
/// watermark_after`).
pub const KIND_INGEST: u8 = 0;
/// Record kind: a removal batch (by item value, like
/// `Engine::remove_batch`).
pub const KIND_REMOVE: u8 = 1;

/// One decoded WAL record, as produced by [`Wal::open`]'s scan.
#[derive(Debug)]
pub struct WalRecord<T> {
    /// Log-wide sequence number (first record ever written is 1).
    pub seq: u64,
    /// [`KIND_INGEST`] or [`KIND_REMOVE`].
    pub kind: u8,
    /// Engine ingest watermark after this record's batch.
    pub watermark_after: u64,
    /// The batch itself, decoded through the [`ItemCodec`].
    pub items: Vec<T>,
}

pub(crate) struct WalInner {
    /// Active segment writer — always the file behind `segments.last()`.
    file: BufWriter<File>,
    /// Bytes in the active segment, header included (rotation trigger).
    seg_bytes: u64,
    /// Live segments, ascending by start seq; the last one is active.
    segments: Vec<(u64, PathBuf)>,
    /// Sequence number the next appended record receives.
    next_seq: u64,
    /// Ingest watermark after the last appended record.
    watermark: u64,
    /// Watermark covered by the last successful fsync.
    durable_watermark: u64,
    /// Records appended since the last fsync.
    dirty: bool,
    /// An append since the last sync failed — the next [`Wal::sync`]
    /// must error so no durable ack covers the lost record.
    append_failed: bool,
    /// Most recent append/fsync error message (sticky).
    last_error: Option<String>,
    /// Engine telemetry, bound at `install_durability` time.
    obs: Option<Arc<Registry>>,
}

impl WalInner {
    /// Sequence of the most recently appended record (0 if none yet).
    pub(crate) fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Ingest watermark after the most recently appended record.
    pub(crate) fn watermark(&self) -> u64 {
        self.watermark
    }
}

/// The write-ahead log. See the module docs for the on-disk grammar and
/// [`DurabilitySink`] for how the engine journals through it.
pub struct Wal<T, C> {
    dir: PathBuf,
    segment_bytes: u64,
    codec: C,
    inner: Mutex<WalInner>,
    _items: PhantomData<fn(T)>,
}

fn segment_name(start_seq: u64) -> String {
    format!("wal-{start_seq:016x}.log")
}

/// Create a fresh segment file: header written, fsynced, and its
/// directory entry fsynced, so a later `sync_data` on the file alone is
/// enough to make appended records durable.
fn create_segment(path: &Path, start_seq: u64) -> io::Result<File> {
    let mut f = OpenOptions::new()
        .create(true)
        .write(true)
        .truncate(true)
        .open(path)?;
    f.write_all(SEGMENT_MAGIC)?;
    f.write_all(&SEGMENT_VERSION.to_le_bytes())?;
    f.write_all(&start_seq.to_le_bytes())?;
    f.sync_all()?;
    sync_parent_dir(path)?;
    Ok(f)
}

fn encode_payload<T, C: ItemCodec<T>>(
    codec: &C,
    seq: u64,
    kind: u8,
    watermark_after: u64,
    items: &[T],
) -> io::Result<Vec<u8>> {
    let mut w = BinWriter::new(Vec::with_capacity(64 + items.len() * 16));
    w.u64(seq)?;
    w.u8(kind)?;
    w.u64(watermark_after)?;
    w.len(items.len())?;
    for item in items {
        codec.write_item(&mut w, item)?;
    }
    Ok(w.into_inner())
}

/// Decode one payload, requiring full consumption — trailing bytes mean
/// the frame length lied, which counts as corruption.
fn decode_payload<T, C: ItemCodec<T>>(
    codec: &C,
    payload: &[u8],
) -> io::Result<WalRecord<T>> {
    let mut cursor = payload;
    let mut r = BinReader::new(&mut cursor);
    let seq = r.u64()?;
    let kind = r.u8()?;
    if kind != KIND_INGEST && kind != KIND_REMOVE {
        return Err(bad("unknown WAL record kind"));
    }
    let watermark_after = r.u64()?;
    let count = r.len()?;
    let mut items = Vec::with_capacity(count.min(1 << 16));
    for _ in 0..count {
        items.push(codec.read_item(&mut r)?);
    }
    drop(r);
    if !cursor.is_empty() {
        return Err(bad("WAL record payload has trailing bytes"));
    }
    Ok(WalRecord { seq, kind, watermark_after, items })
}

fn checksum(payload: &[u8]) -> u64 {
    let mut h = FastHasher::default();
    h.write(payload);
    h.finish()
}

/// Outcome of scanning one segment during [`Wal::open`].
enum SegScan {
    /// Every record valid through end-of-file.
    Clean,
    /// Valid prefix up to `valid_len` bytes, then a torn/corrupt tail.
    Torn { valid_len: u64 },
    /// Header unusable (or the file vanished) — the segment carries no
    /// recoverable records.
    Dead,
}

/// Scan one segment, pushing every valid record. `expected_seq` carries
/// the cross-segment continuity requirement: `None` until the first
/// record anywhere in the log fixes the base (a trimmed log may start at
/// any sequence), then strict +1 per record.
fn scan_segment<T, C: ItemCodec<T>>(
    path: &Path,
    codec: &C,
    name_start: u64,
    expected_seq: &mut Option<u64>,
    records: &mut Vec<WalRecord<T>>,
) -> SegScan {
    let mut buf = Vec::new();
    if File::open(path)
        .and_then(|mut f| f.read_to_end(&mut buf))
        .is_err()
    {
        return SegScan::Dead;
    }
    if buf.len() < SEGMENT_HEADER || &buf[..8] != SEGMENT_MAGIC {
        return SegScan::Dead;
    }
    let version = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let hdr_start = u64::from_le_bytes(buf[12..20].try_into().unwrap());
    if version != SEGMENT_VERSION || hdr_start != name_start {
        return SegScan::Dead;
    }
    let mut off = SEGMENT_HEADER;
    loop {
        if off == buf.len() {
            return SegScan::Clean;
        }
        let valid_len = off as u64;
        if buf.len() - off < FRAME_OVERHEAD as usize {
            return SegScan::Torn { valid_len };
        }
        let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
        let sum = u64::from_le_bytes(buf[off + 4..off + 12].try_into().unwrap());
        if len > MAX_RECORD {
            return SegScan::Torn { valid_len };
        }
        let body = off + FRAME_OVERHEAD as usize;
        if buf.len() - body < len as usize {
            return SegScan::Torn { valid_len };
        }
        let payload = &buf[body..body + len as usize];
        if checksum(payload) != sum {
            return SegScan::Torn { valid_len };
        }
        let rec = match decode_payload(codec, payload) {
            Ok(rec) => rec,
            Err(_) => return SegScan::Torn { valid_len },
        };
        if let Some(exp) = *expected_seq {
            if rec.seq != exp {
                return SegScan::Torn { valid_len };
            }
        }
        *expected_seq = Some(rec.seq + 1);
        records.push(rec);
        off = body + len as usize;
    }
}

impl<T, C: ItemCodec<T>> Wal<T, C> {
    /// Open (or create) the WAL under `dir`, recovering the longest valid
    /// record prefix (see the module docs). `floor_seq`/`floor_watermark`
    /// come from the checkpoint the caller loaded first: a fully trimmed
    /// (or fresh) log must continue numbering *after* the checkpoint's
    /// cut, not restart at 1. Returns the appendable WAL plus every
    /// recovered record in order.
    pub fn open(
        dir: &Path,
        codec: C,
        segment_bytes: u64,
        floor_seq: u64,
        floor_watermark: u64,
    ) -> io::Result<(Self, Vec<WalRecord<T>>)> {
        fs::create_dir_all(dir)?;
        let mut found: Vec<(u64, PathBuf)> = Vec::new();
        for entry in fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy().into_owned();
            if let Some(hex) = name
                .strip_prefix("wal-")
                .and_then(|s| s.strip_suffix(".log"))
            {
                if let Ok(start) = u64::from_str_radix(hex, 16) {
                    found.push((start, entry.path()));
                }
            }
        }
        found.sort_unstable();

        let mut records: Vec<WalRecord<T>> = Vec::new();
        let mut expected_seq: Option<u64> = None;
        let mut live: Vec<(u64, PathBuf)> = Vec::new();
        let mut log_ended = false;
        for (start, path) in found {
            if log_ended {
                // beyond the first corruption everything is unreachable
                // dead weight — drop it so it can never resurrect
                let _ = fs::remove_file(&path);
                continue;
            }
            match scan_segment(&path, &codec, start, &mut expected_seq, &mut records) {
                SegScan::Clean => live.push((start, path)),
                SegScan::Torn { valid_len } => {
                    let f = OpenOptions::new().write(true).open(&path)?;
                    f.set_len(valid_len)?;
                    f.sync_all()?;
                    live.push((start, path));
                    log_ended = true;
                }
                SegScan::Dead => {
                    let _ = fs::remove_file(&path);
                    log_ended = true;
                }
            }
        }

        let next_seq = expected_seq.unwrap_or(1).max(floor_seq + 1);
        let watermark = records
            .last()
            .map(|r| r.watermark_after)
            .unwrap_or(0)
            .max(floor_watermark);

        // reopen (or create) the active segment for appending
        let (file, seg_bytes) = match live.last() {
            Some((_, path)) => {
                let f = OpenOptions::new().append(true).open(path)?;
                let len = f.metadata()?.len();
                (f, len)
            }
            None => {
                let start = next_seq;
                let path = dir.join(segment_name(start));
                let f = create_segment(&path, start)?;
                live.push((start, path));
                (f, SEGMENT_HEADER as u64)
            }
        };

        let wal = Wal {
            dir: dir.to_path_buf(),
            segment_bytes: segment_bytes.max(SEGMENT_HEADER as u64 + 1),
            codec,
            inner: Mutex::new(WalInner {
                file: BufWriter::new(file),
                seg_bytes,
                segments: live,
                next_seq,
                watermark,
                durable_watermark: watermark,
                dirty: false,
                append_failed: false,
                last_error: None,
                obs: None,
            }),
            _items: PhantomData,
        };
        Ok((wal, records))
    }

    /// The codec items are framed through (shared with the checkpoint
    /// writer).
    pub fn codec(&self) -> &C {
        &self.codec
    }

    /// Take the WAL mutex. While held, no ids can be reserved and no
    /// removal can apply — the checkpoint's consistent cut depends on
    /// exactly that freeze.
    pub(crate) fn lock(&self) -> MutexGuard<'_, WalInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record an out-of-band durability error (e.g. a failed checkpoint)
    /// against this WAL's sticky error + counter.
    pub(crate) fn note_error(&self, msg: &str) {
        let mut g = self.lock();
        self.record_error(&mut g, msg);
    }

    fn record_error(&self, g: &mut WalInner, msg: &str) {
        g.last_error = Some(msg.to_string());
        if let Some(obs) = &g.obs {
            obs.inc(CounterId::WalErrors);
        }
    }

    /// Start a new segment whose first record will be `start_seq`. The
    /// closing segment is flushed and fsynced first, so [`Wal::sync`]
    /// only ever needs to touch the active file.
    fn rotate(&self, g: &mut WalInner, start_seq: u64) -> io::Result<()> {
        g.file.flush()?;
        g.file.get_ref().sync_data()?;
        let path = self.dir.join(segment_name(start_seq));
        let f = create_segment(&path, start_seq)?;
        g.file = BufWriter::new(f);
        g.seg_bytes = SEGMENT_HEADER as u64;
        g.segments.push((start_seq, path));
        Ok(())
    }

    /// Append one record under an already-held lock. Failures are
    /// absorbed per the [`DurabilitySink`] contract: counted, stickied,
    /// and fused into the next [`Wal::sync`].
    fn append_locked(&self, g: &mut WalInner, kind: u8, items: &[T], watermark_after: u64) {
        let seq = g.next_seq;
        let result = encode_payload(&self.codec, seq, kind, watermark_after, items)
            .and_then(|payload| {
                if g.seg_bytes >= self.segment_bytes {
                    self.rotate(g, seq)?;
                }
                let sum = checksum(&payload);
                g.file.write_all(&(payload.len() as u32).to_le_bytes())?;
                g.file.write_all(&sum.to_le_bytes())?;
                g.file.write_all(&payload)?;
                Ok(payload.len() as u64)
            });
        match result {
            Ok(payload_len) => {
                g.seg_bytes += FRAME_OVERHEAD + payload_len;
                g.next_seq = seq + 1;
                g.watermark = watermark_after;
                g.dirty = true;
                if let Some(obs) = &g.obs {
                    obs.inc(CounterId::WalAppends);
                    obs.counter(CounterId::WalBytes)
                        .add(FRAME_OVERHEAD + payload_len);
                }
            }
            Err(e) => {
                g.append_failed = true;
                self.record_error(g, &format!("wal append failed: {e}"));
            }
        }
    }

    fn sync_impl(&self) -> io::Result<u64> {
        let mut g = self.lock();
        if g.append_failed {
            g.append_failed = false;
            return Err(io::Error::other(
                "a WAL append since the last sync failed; batch not durable",
            ));
        }
        if !g.dirty {
            return Ok(g.durable_watermark);
        }
        let t0 = Instant::now();
        let res = g
            .file
            .flush()
            .and_then(|_| g.file.get_ref().sync_data());
        match res {
            Ok(()) => {
                g.dirty = false;
                g.durable_watermark = g.watermark;
                if let Some(obs) = &g.obs {
                    obs.inc(CounterId::WalFsyncs);
                    obs.record(HistId::WalFsync, t0.elapsed());
                }
                Ok(g.durable_watermark)
            }
            Err(e) => {
                self.record_error(&mut g, &format!("wal fsync failed: {e}"));
                Err(e)
            }
        }
    }

    /// Delete every segment fully covered by a checkpoint at `cut_seq`:
    /// segment *i* goes once segment *i+1* starts at or below
    /// `cut_seq + 1` (all of *i*'s records then have `seq <= cut_seq`).
    /// The active segment always stays. Returns how many were removed.
    pub fn trim(&self, cut_seq: u64) -> usize {
        let mut g = self.lock();
        let mut removed = 0;
        while g.segments.len() >= 2 && g.segments[1].0 <= cut_seq + 1 {
            let (_, path) = g.segments.remove(0);
            let _ = fs::remove_file(&path);
            removed += 1;
        }
        removed
    }

    /// Live segment count (active included).
    pub fn n_segments(&self) -> usize {
        self.lock().segments.len()
    }

    /// Ingest watermark after the last appended record.
    pub fn watermark(&self) -> u64 {
        self.lock().watermark
    }

    /// Sequence of the most recently appended record (0 if none).
    pub fn last_seq(&self) -> u64 {
        self.lock().last_seq()
    }

    /// See [`DurabilitySink::sync`] (also reachable without the trait in
    /// scope).
    pub fn sync_now(&self) -> io::Result<u64> {
        self.sync_impl()
    }
}

impl<T, C> DurabilitySink<T> for Wal<T, C>
where
    T: Send + Sync,
    C: ItemCodec<T> + Send + Sync,
{
    fn bind_registry(&self, obs: Arc<Registry>) {
        self.lock().obs = Some(obs);
    }

    fn log_add(&self, items: &[T], assign: &mut dyn FnMut(usize) -> u64) -> u64 {
        let mut g = self.lock();
        // reserve first: if the id space is exhausted `assign` panics and
        // no phantom record claiming unassigned ids ever hits the log
        let base = assign(items.len());
        debug_assert_eq!(
            base,
            g.watermark,
            "id reservation must continue the journaled watermark"
        );
        let after = base + items.len() as u64;
        self.append_locked(&mut g, KIND_INGEST, items, after);
        base
    }

    fn log_remove(&self, items: &[T], apply: &mut dyn FnMut() -> usize) -> usize {
        let mut g = self.lock();
        let watermark = g.watermark;
        self.append_locked(&mut g, KIND_REMOVE, items, watermark);
        // applied under the same hold: a checkpoint cut covering this
        // record's seq provably covers its tombstones too
        apply()
    }

    fn sync(&self) -> io::Result<u64> {
        self.sync_impl()
    }

    fn watermark(&self) -> u64 {
        self.lock().watermark
    }

    fn last_error(&self) -> Option<String> {
        self.lock().last_error.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::FrameworkCodec;
    use crate::util::proptest;
    use crate::Item;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fishdbc_wal_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn item(x: f32, y: f32) -> Item {
        Item::Dense(vec![x, y])
    }

    fn open(dir: &Path, segment_bytes: u64) -> (Wal<Item, FrameworkCodec>, Vec<WalRecord<Item>>) {
        Wal::open(dir, FrameworkCodec, segment_bytes, 0, 0).unwrap()
    }

    /// Append `batches` through the sink seam (so watermark bookkeeping
    /// matches production) and fsync.
    fn fill(wal: &Wal<Item, FrameworkCodec>, batches: &[Vec<Item>]) {
        let mut next = wal.watermark();
        for b in batches {
            let mut assign = |n: usize| {
                let base = next;
                next += n as u64;
                base
            };
            wal.log_add(b, &mut assign);
        }
        wal.sync_now().unwrap();
    }

    #[test]
    fn roundtrip_records_in_order() {
        let dir = tmp_dir("roundtrip");
        {
            let (wal, recovered) = open(&dir, 64 << 20);
            assert!(recovered.is_empty());
            fill(
                &wal,
                &[
                    vec![item(0.0, 1.0), item(2.0, 3.0)],
                    vec![item(4.0, 5.0)],
                ],
            );
            let mut apply = || 1usize;
            wal.log_remove(&[item(0.0, 1.0)], &mut apply);
            wal.sync_now().unwrap();
        }
        let (wal, recovered) = open(&dir, 64 << 20);
        assert_eq!(recovered.len(), 3);
        assert_eq!(recovered[0].seq, 1);
        assert_eq!(recovered[0].kind, KIND_INGEST);
        assert_eq!(recovered[0].watermark_after, 2);
        assert_eq!(recovered[0].items, vec![item(0.0, 1.0), item(2.0, 3.0)]);
        assert_eq!(recovered[1].watermark_after, 3);
        assert_eq!(recovered[2].kind, KIND_REMOVE);
        assert_eq!(recovered[2].seq, 3);
        assert_eq!(recovered[2].watermark_after, 3, "removes keep the watermark");
        assert_eq!(wal.watermark(), 3);
        assert_eq!(wal.last_seq(), 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_spreads_records_over_segments_and_trim_reclaims() {
        let dir = tmp_dir("rotate");
        let (wal, _) = open(&dir, 128); // tiny budget: rotate almost every record
        let batches: Vec<Vec<Item>> = (0..20)
            .map(|i| vec![item(i as f32, -(i as f32))])
            .collect();
        fill(&wal, &batches);
        assert!(wal.n_segments() > 2, "expected rotation to occur");
        // nothing checkpointed: nothing trimmable
        assert_eq!(wal.trim(0), 0);
        // checkpoint at seq 10: every segment fully below it goes away
        let trimmed = wal.trim(10);
        assert!(trimmed > 0);
        drop(wal);
        let (_, recovered) = open(&dir, 128);
        // the surviving suffix still contains every record past the cut
        assert!(recovered.iter().any(|r| r.seq == 11));
        assert_eq!(recovered.last().unwrap().seq, 20);
        for w in recovered.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1, "suffix must stay contiguous");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fresh_wal_continues_after_checkpoint_floor() {
        let dir = tmp_dir("floor");
        // a checkpoint at cut_seq=7 / watermark=42 with a fully trimmed
        // log: new records must number from 8 and keep the watermark
        let (wal, recovered) =
            Wal::<Item, _>::open(&dir, FrameworkCodec, 64 << 20, 7, 42).unwrap();
        assert!(recovered.is_empty());
        assert_eq!(wal.watermark(), 42);
        let mut assign = |n: usize| {
            assert_eq!(n, 1);
            42
        };
        wal.log_add(&[item(1.0, 2.0)], &mut assign);
        assert_eq!(wal.last_seq(), 8);
        drop(wal);
        // reopening with the same floor still sees the suffix record
        let (_, recovered) =
            Wal::<Item, _>::open(&dir, FrameworkCodec, 64 << 20, 7, 42).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].seq, 8);
        assert_eq!(recovered[0].watermark_after, 43);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sync_is_idempotent_and_reports_watermark() {
        let dir = tmp_dir("sync");
        let (wal, _) = open(&dir, 64 << 20);
        assert_eq!(wal.sync_now().unwrap(), 0, "empty log syncs to watermark 0");
        fill(&wal, &[vec![item(1.0, 1.0)]]);
        assert_eq!(wal.sync_now().unwrap(), 1);
        assert_eq!(wal.sync_now().unwrap(), 1, "clean log: fast path");
        let _ = fs::remove_dir_all(&dir);
    }

    /// Satellite: random prefix truncation of a valid log recovers the
    /// longest valid record prefix — never panics, never yields a half
    /// batch, never resurrects anything past the tear.
    #[test]
    fn torn_tail_property_prefix_truncation() {
        proptest::check("wal_torn_tail", 40, |rng, _case| {
            let dir = tmp_dir(&format!("torn_{}", rng.below(1 << 30)));
            let n_batches = 1 + rng.below(8);
            let batches: Vec<Vec<Item>> = (0..n_batches)
                .map(|b| {
                    (0..1 + rng.below(5))
                        .map(|i| item(b as f32 + i as f32 * 0.25, rng.f32()))
                        .collect()
                })
                .collect();
            // small segment budget so tears land in any segment
            let seg_bytes = if rng.bool(0.5) { 96 } else { 64 << 20 };
            {
                let (wal, _) = open(&dir, seg_bytes);
                fill(&wal, &batches);
            }
            // record where each segment's valid record boundaries are
            let (_, all) = open(&dir, seg_bytes);
            assert_eq!(all.len(), n_batches);

            // truncate a random suffix of a random segment's bytes
            let mut segs: Vec<PathBuf> = fs::read_dir(&dir)
                .unwrap()
                .map(|e| e.unwrap().path())
                .filter(|p| {
                    p.file_name()
                        .unwrap()
                        .to_string_lossy()
                        .starts_with("wal-")
                })
                .collect();
            segs.sort();
            let victim = rng.below(segs.len());
            let len = fs::metadata(&segs[victim]).unwrap().len();
            let cut = rng.below(len as usize + 1) as u64;
            OpenOptions::new()
                .write(true)
                .open(&segs[victim])
                .unwrap()
                .set_len(cut)
                .unwrap();

            let (_, recovered) = open(&dir, seg_bytes);
            // 1. never a half batch: every recovered record is bit-exact
            //    one of the originals, in order, from the start
            assert!(recovered.len() <= n_batches);
            for (i, rec) in recovered.iter().enumerate() {
                assert_eq!(rec.seq, i as u64 + 1, "prefix must stay contiguous");
                assert_eq!(rec.items, batches[i], "record {i} must be intact");
            }
            // 2. longest valid prefix: everything strictly before the
            //    damaged segment must survive
            let (_, check) = open(&dir, seg_bytes);
            assert_eq!(check.len(), recovered.len(), "reopen is idempotent");
            let _ = fs::remove_dir_all(&dir);
        });
    }

    /// Corrupting bytes mid-record (not just truncating) also tears the
    /// log at that record, and reopening after the repair-truncation is
    /// stable.
    #[test]
    fn torn_tail_property_bitflip() {
        proptest::check("wal_bitflip", 30, |rng, _case| {
            let dir = tmp_dir(&format!("flip_{}", rng.below(1 << 30)));
            let batches: Vec<Vec<Item>> = (0..4)
                .map(|b| vec![item(b as f32, 1.0), item(b as f32, 2.0)])
                .collect();
            {
                let (wal, _) = open(&dir, 64 << 20);
                fill(&wal, &batches);
            }
            let seg = dir.join(segment_name(1));
            let mut bytes = fs::read(&seg).unwrap();
            // flip one byte somewhere past the header
            let pos = SEGMENT_HEADER + rng.below(bytes.len() - SEGMENT_HEADER);
            bytes[pos] ^= 1 << rng.below(8);
            fs::write(&seg, &bytes).unwrap();

            let (_, recovered) = open(&dir, 64 << 20);
            assert!(recovered.len() < batches.len(), "a flip must tear the log");
            for (i, rec) in recovered.iter().enumerate() {
                assert_eq!(rec.items, batches[i]);
            }
            let (_, again) = open(&dir, 64 << 20);
            assert_eq!(again.len(), recovered.len());
            let _ = fs::remove_dir_all(&dir);
        });
    }

    #[test]
    fn dead_header_truncates_to_empty_log() {
        let dir = tmp_dir("deadhdr");
        {
            let (wal, _) = open(&dir, 64 << 20);
            fill(&wal, &[vec![item(1.0, 2.0)]]);
        }
        let seg = dir.join(segment_name(1));
        let mut bytes = fs::read(&seg).unwrap();
        bytes[0] = b'X'; // destroy the magic
        fs::write(&seg, &bytes).unwrap();
        let (wal, recovered) = open(&dir, 64 << 20);
        assert!(recovered.is_empty(), "a dead header yields no records");
        // and the log is usable again from scratch
        fill(&wal, &[vec![item(3.0, 4.0)]]);
        drop(wal);
        let (_, recovered) = open(&dir, 64 << 20);
        assert_eq!(recovered.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }
}
