//! Reviews: synthetic food-review texts under Jaro-Winkler distance — the
//! shape of the paper's Finefoods dataset (568 474 Amazon reviews,
//! average 430 chars, expensive string distance, unlabeled). Used for the
//! scalability study (Fig 2) and the big-runtime rows of Tables 7-8.

use super::Dataset;
use crate::distances::{Item, MetricKind};
use crate::util::rng::Rng;

const CATEGORIES: [&str; 5] = ["coffee", "tea", "chocolate", "chips", "sauce"];

const OPENERS: [&str; 6] = [
    "I bought this", "My family loves this", "This is the best",
    "Honestly disappointed with this", "Been ordering this", "Great value for this",
];

const QUALS: [&str; 8] = [
    "rich and smooth", "a bit stale", "absolutely delicious", "way too sweet",
    "perfectly balanced", "kind of bland", "surprisingly fresh", "overpriced but tasty",
];

const CLOSERS: [&str; 6] = [
    "will buy again.", "would not recommend.", "five stars from me.",
    "shipping was fast too.", "my kids ask for it weekly.", "goes great with breakfast.",
];

/// Generate `n` review-like texts (~430 chars, like Finefoods).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut items = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let cat = rng.below(CATEGORIES.len());
        let mut text = String::with_capacity(480);
        // 4-6 sentences built around the category word
        let sentences = 4 + rng.below(3);
        for _ in 0..sentences {
            let opener = OPENERS[rng.below(OPENERS.len())];
            let qual = QUALS[rng.below(QUALS.len())];
            let closer = CLOSERS[rng.below(CLOSERS.len())];
            text.push_str(opener);
            text.push(' ');
            text.push_str(CATEGORIES[cat]);
            text.push_str(", it is ");
            text.push_str(qual);
            text.push_str(" and ");
            text.push_str(closer);
            text.push(' ');
        }
        // char-level noise: typos
        let mut bytes = text.into_bytes();
        for _ in 0..3 {
            let i = rng.below(bytes.len());
            bytes[i] = b'a' + (rng.next_u64() % 26) as u8;
        }
        items.push(Item::Text(String::from_utf8(bytes).unwrap()));
        labels.push(cat);
    }
    Dataset {
        name: format!("reviews(n={n})"),
        items,
        label_sets: vec![("category".into(), labels)],
        labeled: false, // paper: Finefoods is unlabeled
        metric: MetricKind::JaroWinkler,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn text(it: &Item) -> &str {
        match it {
            Item::Text(t) => t,
            _ => panic!(),
        }
    }

    #[test]
    fn review_lengths_are_plausible() {
        let d = generate(100, 1);
        let avg: f64 =
            d.items.iter().map(|t| text(t).len() as f64).sum::<f64>() / 100.0;
        assert!(
            (250.0..650.0).contains(&avg),
            "avg review length {avg} too far from paper's ~430"
        );
    }

    #[test]
    fn texts_are_distinct() {
        let d = generate(50, 2);
        let set: std::collections::HashSet<&str> =
            d.items.iter().map(text).collect();
        assert!(set.len() > 45);
    }
}
