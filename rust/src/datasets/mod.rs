//! Dataset generators reproducing the *shape* of the paper's 8 evaluation
//! datasets (Table 1). Where the paper's data is external/proprietary we
//! generate faithful synthetic equivalents — see DESIGN.md "Data
//! substitutions" for the paper→ours mapping and why each preserves the
//! behaviour the experiment exercises.

pub mod blobs;
pub mod docword;
pub mod fuzzy;
pub mod household;
pub mod loaders;
pub mod reviews;
pub mod synth;
pub mod usps;

use crate::distances::{Item, MetricKind};

/// A generated dataset: items + zero or more label sets.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub items: Vec<Item>,
    /// Ground-truth label sets: (label-name, per-item class). The fuzzy
    /// dataset has five (program/package/version/compiler/options); most
    /// others have one; unlabeled datasets (per the paper) keep their
    /// hidden generator labels for internal validation but the harness
    /// treats them as unlabeled.
    pub label_sets: Vec<(String, Vec<usize>)>,
    /// Whether the paper treats this dataset as labeled (Table 1).
    pub labeled: bool,
    /// Distance function the paper uses for it.
    pub metric: MetricKind,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.items.len()
    }

    pub fn primary_labels(&self) -> Option<&[usize]> {
        self.label_sets.first().map(|(_, l)| l.as_slice())
    }

    /// Validate every item is compatible with the dataset's metric.
    pub fn validate(&self) -> Result<(), String> {
        for (i, it) in self.items.iter().enumerate() {
            if !self.metric.compatible(it) {
                return Err(format!(
                    "item {i} incompatible with metric {}",
                    self.metric.name()
                ));
            }
        }
        for (name, l) in &self.label_sets {
            if l.len() != self.items.len() {
                return Err(format!("label set {name} has wrong length"));
            }
        }
        Ok(())
    }
}

/// Generate a dataset by name with the common (n, dim, seed) knobs.
/// `dim` is interpreted per-dataset (vector dims, vocabulary size, …) and
/// ignored where fixed by the paper (USPS is 16×16).
pub fn generate(name: &str, n: usize, dim: usize, seed: u64) -> Option<Dataset> {
    Some(match name {
        "blobs" => blobs::generate(n, dim.max(2), 10, seed),
        "synth" => synth::generate(n, dim.max(64), 5, seed),
        "usps" => usps::generate(n, seed),
        "fuzzy" => fuzzy::generate(n, seed),
        "docword" => docword::generate(n, dim.max(256), seed),
        "reviews" => reviews::generate(n, seed),
        "household" => household::generate(n, seed),
        _ => return None,
    })
}

/// All generator names (CLI help, benches).
pub const DATASET_NAMES: &[&str] =
    &["blobs", "synth", "usps", "fuzzy", "docword", "reviews", "household"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_generators_produce_valid_datasets() {
        for &name in DATASET_NAMES {
            let d = generate(name, 200, 64, 42).unwrap();
            assert!(d.n() >= 150, "{name}: produced too few items ({})", d.n());
            d.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!d.label_sets.is_empty(), "{name}: keep generator labels");
        }
        assert!(generate("nope", 10, 2, 0).is_none());
    }

    #[test]
    fn generators_are_deterministic() {
        for &name in DATASET_NAMES {
            let a = generate(name, 100, 32, 7).unwrap();
            let b = generate(name, 100, 32, 7).unwrap();
            assert_eq!(a.items, b.items, "{name} not deterministic");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate("blobs", 50, 8, 1).unwrap();
        let b = generate("blobs", 50, 8, 2).unwrap();
        assert_ne!(a.items, b.items);
    }
}
