//! Synth: transactional event-set datasets in the style of Cesario et
//! al.'s generator (paper §4.1): 5 clusters of transactions, no outliers,
//! no overlap, dimensionality 640-2 048, Jaccard distance.

use super::Dataset;
use crate::distances::{Item, MetricKind};
use crate::util::rng::Rng;

/// Generate `n` transactions over a universe of `dim` possible events,
/// grouped in `clusters` non-overlapping clusters.
pub fn generate(n: usize, dim: usize, clusters: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let clusters = clusters.max(1);
    // partition the item universe into disjoint characteristic sets
    let per_cluster = dim / clusters;
    let mut universe: Vec<u32> = (0..dim as u32).collect();
    rng.shuffle(&mut universe);
    let char_sets: Vec<&[u32]> = (0..clusters)
        .map(|c| &universe[c * per_cluster..(c + 1) * per_cluster])
        .collect();

    let mut items = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % clusters;
        let chars = char_sets[c];
        // a transaction contains ~60% of its cluster's characteristic
        // items (bernoulli per item -> Jaccard ≈ const within cluster)
        let mut set: Vec<u32> = chars
            .iter()
            .copied()
            .filter(|_| rng.bool(0.6))
            .collect();
        if set.is_empty() {
            set.push(chars[rng.below(chars.len())]);
        }
        set.sort_unstable();
        items.push(Item::Set(set));
        labels.push(c);
    }
    Dataset {
        name: format!("synth(n={n},dim={dim},k={clusters})"),
        items,
        label_sets: vec![("class".into(), labels)],
        labeled: true,
        metric: MetricKind::Jaccard,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::sparse::jaccard;

    fn set_of(it: &Item) -> &[u32] {
        match it {
            Item::Set(s) => s,
            _ => panic!(),
        }
    }

    #[test]
    fn clusters_disjoint_in_jaccard() {
        let d = generate(200, 640, 5, 1);
        let labels = d.primary_labels().unwrap();
        for i in 0..40 {
            for j in (i + 1)..40 {
                let dd = jaccard(set_of(&d.items[i]), set_of(&d.items[j]));
                if labels[i] == labels[j] {
                    assert!(dd < 0.95, "intra dist {dd} too high");
                } else {
                    // characteristic sets are disjoint => distance 1
                    assert!(dd > 0.999, "inter dist {dd} too low");
                }
            }
        }
    }

    #[test]
    fn sets_sorted_nonempty() {
        let d = generate(100, 320, 5, 2);
        for it in &d.items {
            let s = set_of(it);
            assert!(!s.is_empty());
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
