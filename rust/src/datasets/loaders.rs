//! File-based dataset loaders: run the framework on *real* data, not just
//! the synthetic generators. Formats:
//!
//! * **CSV of dense vectors** — one row per item, optional trailing string
//!   label column, optional header (auto-detected);
//! * **text lines** — one document per line (Jaro-Winkler / custom text
//!   metrics);
//! * **UCI bag-of-words** (the paper's Docword datasets): header lines
//!   `D`, `W`, `NNZ` followed by `docID wordID count` triples, 1-indexed;
//! * **label CSV writer** — persist flat labels next to the input.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use crate::distances::{Item, MetricKind};

use super::Dataset;

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Parse CSV of dense f32 vectors from a reader. If `labeled`, the last
/// column is a class label (arbitrary strings, mapped to dense ids). A
/// first row that fails numeric parsing in every feature column is treated
/// as a header and skipped.
pub fn read_csv_vectors<R: Read>(
    r: R,
    labeled: bool,
) -> std::io::Result<Dataset> {
    let mut items = Vec::new();
    let mut labels: Vec<usize> = Vec::new();
    let mut label_map = std::collections::HashMap::<String, usize>::new();
    let mut width: Option<usize> = None;

    for (lineno, line) in BufReader::new(r).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = t.split(',').map(str::trim).collect();
        let (feat, label) = if labeled {
            if fields.len() < 2 {
                return Err(io_err(format!("line {}: need >=2 columns", lineno + 1)));
            }
            (&fields[..fields.len() - 1], Some(fields[fields.len() - 1]))
        } else {
            (&fields[..], None)
        };
        let parsed: Result<Vec<f32>, _> =
            feat.iter().map(|f| f.parse::<f32>()).collect();
        match parsed {
            Err(_) if items.is_empty() => continue, // header row
            Err(e) => {
                return Err(io_err(format!("line {}: {e}", lineno + 1)));
            }
            Ok(v) => {
                match width {
                    None => width = Some(v.len()),
                    Some(w) if w != v.len() => {
                        return Err(io_err(format!(
                            "line {}: {} columns, expected {w}",
                            lineno + 1,
                            v.len()
                        )));
                    }
                    _ => {}
                }
                items.push(Item::Dense(v));
                if let Some(l) = label {
                    let next = label_map.len();
                    labels.push(*label_map.entry(l.to_string()).or_insert(next));
                }
            }
        }
    }
    let label_sets = if labeled {
        vec![("label".to_string(), labels)]
    } else {
        Vec::new()
    };
    Ok(Dataset {
        name: "csv".into(),
        items,
        label_sets,
        labeled,
        metric: MetricKind::Euclidean,
    })
}

/// Load dense-vector CSV from a path (see [`read_csv_vectors`]).
pub fn load_csv_vectors(
    path: impl AsRef<Path>,
    labeled: bool,
) -> std::io::Result<Dataset> {
    let f = std::fs::File::open(&path)?;
    let mut ds = read_csv_vectors(f, labeled)?;
    ds.name = path.as_ref().display().to_string();
    Ok(ds)
}

/// Read one text document per line (empty lines skipped).
pub fn read_text_lines<R: Read>(r: R) -> std::io::Result<Dataset> {
    let mut items = Vec::new();
    for line in BufReader::new(r).lines() {
        let line = line?;
        if !line.trim().is_empty() {
            items.push(Item::Text(line));
        }
    }
    Ok(Dataset {
        name: "text".into(),
        items,
        label_sets: Vec::new(),
        labeled: false,
        metric: MetricKind::JaroWinkler,
    })
}

/// Load a text-lines file from a path (see [`read_text_lines`]).
pub fn load_text_lines(path: impl AsRef<Path>) -> std::io::Result<Dataset> {
    let f = std::fs::File::open(&path)?;
    let mut ds = read_text_lines(f)?;
    ds.name = path.as_ref().display().to_string();
    Ok(ds)
}

/// Read the UCI bag-of-words format (the paper's DW-\* datasets
/// docword.X.txt): three header lines `D` `W` `NNZ`, then `doc word count`
/// triples (1-indexed). Documents with no words become empty sparse items.
pub fn read_uci_docword<R: Read>(r: R) -> std::io::Result<Dataset> {
    let mut lines = BufReader::new(r).lines();
    let mut header = |what: &str| -> std::io::Result<usize> {
        loop {
            let l = lines
                .next()
                .ok_or_else(|| io_err(format!("missing {what} header")))??;
            let t = l.trim();
            if !t.is_empty() {
                return t
                    .parse::<usize>()
                    .map_err(|_| io_err(format!("bad {what} header {t:?}")));
            }
        }
    };
    let d = header("D")?;
    let _w = header("W")?;
    let nnz = header("NNZ")?;

    let mut docs: Vec<Vec<(u32, f32)>> = vec![Vec::new(); d];
    let mut read = 0usize;
    for l in lines {
        let l = l?;
        let t = l.trim();
        if t.is_empty() {
            continue;
        }
        let mut it = t.split_whitespace();
        let (doc, word, count) = (
            it.next().ok_or_else(|| io_err("short triple".into()))?,
            it.next().ok_or_else(|| io_err("short triple".into()))?,
            it.next().ok_or_else(|| io_err("short triple".into()))?,
        );
        let doc: usize =
            doc.parse().map_err(|_| io_err(format!("bad doc id {doc:?}")))?;
        let word: u32 =
            word.parse().map_err(|_| io_err(format!("bad word id {word:?}")))?;
        let count: f32 =
            count.parse().map_err(|_| io_err(format!("bad count {count:?}")))?;
        if doc == 0 || doc > d || word == 0 {
            return Err(io_err(format!("triple out of range: {t:?}")));
        }
        docs[doc - 1].push((word - 1, count));
        read += 1;
    }
    if read != nnz {
        return Err(io_err(format!("expected {nnz} triples, read {read}")));
    }
    let items = docs
        .into_iter()
        .map(|mut dw| {
            dw.sort_unstable_by_key(|&(w, _)| w);
            dw.dedup_by(|a, b| {
                if a.0 == b.0 {
                    b.1 += a.1;
                    true
                } else {
                    false
                }
            });
            let (idx, val): (Vec<u32>, Vec<f32>) = dw.into_iter().unzip();
            Item::Sparse { idx, val }
        })
        .collect();
    Ok(Dataset {
        name: "docword".into(),
        items,
        label_sets: Vec::new(),
        labeled: false,
        metric: MetricKind::SparseCosine,
    })
}

/// Load UCI bag-of-words from a path (see [`read_uci_docword`]).
pub fn load_uci_docword(path: impl AsRef<Path>) -> std::io::Result<Dataset> {
    let f = std::fs::File::open(&path)?;
    let mut ds = read_uci_docword(f)?;
    ds.name = path.as_ref().display().to_string();
    Ok(ds)
}

/// Write flat labels as `index,label` CSV (noise = -1).
pub fn write_labels_csv<W: Write>(mut w: W, labels: &[i32]) -> std::io::Result<()> {
    writeln!(w, "index,label")?;
    for (i, l) in labels.iter().enumerate() {
        writeln!(w, "{i},{l}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_with_header_and_labels() {
        let csv = "x,y,class\n1.0,2.0,a\n1.5,2.5,a\n9.0,9.0,b\n";
        let ds = read_csv_vectors(csv.as_bytes(), true).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.items[0], Item::Dense(vec![1.0, 2.0]));
        let labels = ds.primary_labels().unwrap();
        assert_eq!(labels, &[0, 0, 1]);
        ds.validate().unwrap();
    }

    #[test]
    fn csv_without_header_or_labels() {
        let csv = "# comment\n1,2,3\n4,5,6\n";
        let ds = read_csv_vectors(csv.as_bytes(), false).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.items[1], Item::Dense(vec![4.0, 5.0, 6.0]));
        assert!(ds.label_sets.is_empty());
    }

    #[test]
    fn csv_errors_on_ragged_rows_and_bad_numbers() {
        assert!(read_csv_vectors("1,2\n3\n".as_bytes(), false).is_err());
        assert!(read_csv_vectors("1,2\n3,zap\n".as_bytes(), false).is_err());
    }

    #[test]
    fn text_lines_roundtrip() {
        let txt = "first doc\n\n  \nsecond doc\n";
        let ds = read_text_lines(txt.as_bytes()).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.items[0], Item::Text("first doc".into()));
        assert_eq!(ds.metric, MetricKind::JaroWinkler);
    }

    #[test]
    fn uci_docword_parses_and_validates() {
        let data = "3\n10\n4\n1 1 2\n1 3 1\n2 5 4\n3 1 1\n";
        let ds = read_uci_docword(data.as_bytes()).unwrap();
        assert_eq!(ds.n(), 3);
        match &ds.items[0] {
            Item::Sparse { idx, val } => {
                assert_eq!(idx, &[0, 2]);
                assert_eq!(val, &[2.0, 1.0]);
            }
            other => panic!("wrong item {other:?}"),
        }
        ds.validate().unwrap();
        // NNZ mismatch
        assert!(read_uci_docword("1\n5\n2\n1 1 1\n".as_bytes()).is_err());
        // out-of-range doc
        assert!(read_uci_docword("1\n5\n1\n2 1 1\n".as_bytes()).is_err());
    }

    #[test]
    fn uci_docword_merges_duplicate_words() {
        let data = "1\n5\n2\n1 2 1\n1 2 3\n";
        let ds = read_uci_docword(data.as_bytes()).unwrap();
        match &ds.items[0] {
            Item::Sparse { idx, val } => {
                assert_eq!(idx, &[1]);
                assert_eq!(val, &[4.0]);
            }
            other => panic!("wrong item {other:?}"),
        }
    }

    #[test]
    fn labels_csv_format() {
        let mut buf = Vec::new();
        write_labels_csv(&mut buf, &[0, -1, 2]).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert_eq!(s, "index,label\n0,0\n1,-1\n2,2\n");
    }

    #[test]
    fn file_roundtrip_via_tempdir() {
        let dir = std::env::temp_dir();
        let p = dir.join("fishdbc_loader_test.csv");
        std::fs::write(&p, "1.0,2.0\n3.0,4.0\n").unwrap();
        let ds = load_csv_vectors(&p, false).unwrap();
        assert_eq!(ds.n(), 2);
        assert!(ds.name.contains("fishdbc_loader_test"));
        let _ = std::fs::remove_file(&p);
    }
}
