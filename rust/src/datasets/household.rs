//! Household: 7-dimensional power-consumption readings under Euclidean
//! distance — the shape of the UCI "Individual household electric power
//! consumption" dataset (2 049 280 × 7-d, unlabeled) used for the
//! low-dimensional scalability rows of Tables 7-8.
//!
//! We simulate a day/night consumption process with distinct usage
//! regimes (night base load / morning peak / daytime / evening peak),
//! which produces the multi-density cluster structure real meter data has.

use super::Dataset;
use crate::distances::{Item, MetricKind};
use crate::util::rng::Rng;

/// (active, reactive, voltage, intensity, sub1, sub2, sub3) per regime.
const REGIMES: [([f64; 7], f64); 4] = [
    // night: low flat load
    ([0.4, 0.1, 241.0, 1.8, 0.0, 0.3, 5.0], 0.08),
    // morning peak: kitchen heavy
    ([2.6, 0.3, 236.0, 11.0, 12.0, 2.0, 7.0], 0.5),
    // daytime: moderate
    ([1.2, 0.2, 239.0, 5.0, 1.0, 1.5, 6.0], 0.3),
    // evening peak: everything on
    ([4.2, 0.5, 233.0, 18.5, 18.0, 6.0, 17.0], 0.9),
];

/// Generate `n` readings.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut items = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        // regime frequencies mimic a day: night 40%, morning 15%,
        // day 25%, evening 20%
        let r = {
            let u = rng.f64();
            if u < 0.40 {
                0
            } else if u < 0.55 {
                1
            } else if u < 0.80 {
                2
            } else {
                3
            }
        };
        let (means, spread) = REGIMES[r];
        let v: Vec<f32> = means
            .iter()
            .map(|&m| (m + rng.normal() * spread * m.max(0.5)) as f32)
            .collect();
        items.push(Item::Dense(v));
        labels.push(r);
    }
    Dataset {
        name: format!("household(n={n})"),
        items,
        label_sets: vec![("regime".into(), labels)],
        labeled: false, // paper: unlabeled (internal metrics only)
        metric: MetricKind::Euclidean,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_dimensional() {
        let d = generate(100, 1);
        for it in &d.items {
            assert_eq!(it.as_dense().len(), 7);
        }
    }

    #[test]
    fn regimes_have_distinct_power_levels() {
        let d = generate(2000, 2);
        let labels = d.primary_labels().unwrap();
        let mut mean_power = [0.0f64; 4];
        let mut counts = [0usize; 4];
        for (it, &l) in d.items.iter().zip(labels) {
            mean_power[l] += it.as_dense()[0] as f64;
            counts[l] += 1;
        }
        for r in 0..4 {
            assert!(counts[r] > 50, "regime {r} undersampled");
            mean_power[r] /= counts[r] as f64;
        }
        assert!(mean_power[0] < mean_power[2]);
        assert!(mean_power[2] < mean_power[3]);
    }
}
