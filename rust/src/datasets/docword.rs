//! Docword: bag-of-words documents as sparse count vectors under cosine
//! distance — the shape of the UCI DW-* datasets (DW-Kos 3 430 × sparse
//! 914-d, DW-Enron 39 861 × 914-d, DW-NYTimes 300 000 × 2 120-d). The
//! paper treats these as unlabeled (internal metrics only); we keep the
//! generator's hidden topic labels for extra validation.

use super::Dataset;
use crate::distances::{Item, MetricKind};
use crate::util::rng::Rng;

const TOPICS: usize = 20;

/// Generate `n` documents over a `vocab`-word vocabulary: each document
/// draws a topic, then samples words from a topic-biased Zipf mixture
/// (80% topic vocabulary, 20% background), giving realistic sparsity.
pub fn generate(n: usize, vocab: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let vocab = vocab.max(64);
    // each topic owns a random permutation bias over the vocabulary
    let topic_offsets: Vec<usize> = (0..TOPICS).map(|_| rng.below(vocab)).collect();

    let mut items = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let topic = rng.below(TOPICS);
        let len = 40 + rng.below(160); // words per doc
        let mut counts: std::collections::HashMap<u32, u32> =
            std::collections::HashMap::new();
        for _ in 0..len {
            let w = if rng.bool(0.8) {
                // topic word: Zipf over a topic-shifted region
                (topic_offsets[topic] + rng.zipf(vocab / 10, 1.2)) % vocab
            } else {
                rng.zipf(vocab, 1.1) // background word
            };
            *counts.entry(w as u32).or_insert(0) += 1;
        }
        let mut pairs: Vec<(u32, u32)> = counts.into_iter().collect();
        pairs.sort_unstable_by_key(|&(w, _)| w);
        let idx: Vec<u32> = pairs.iter().map(|&(w, _)| w).collect();
        let val: Vec<f32> = pairs.iter().map(|&(_, c)| c as f32).collect();
        items.push(Item::Sparse { idx, val });
        labels.push(topic);
    }
    Dataset {
        name: format!("docword(n={n},vocab={vocab})"),
        items,
        label_sets: vec![("topic".into(), labels)],
        labeled: false, // paper: internal metrics only
        metric: MetricKind::SparseCosine,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::sparse::cosine;

    fn sp(it: &Item) -> (&[u32], &[f32]) {
        match it {
            Item::Sparse { idx, val } => (idx, val),
            _ => panic!(),
        }
    }

    #[test]
    fn documents_sparse_and_sorted() {
        let d = generate(200, 1000, 1);
        for it in &d.items {
            let (idx, val) = sp(it);
            assert_eq!(idx.len(), val.len());
            assert!(!idx.is_empty());
            assert!(idx.len() < 300, "doc not sparse: {} terms", idx.len());
            assert!(idx.windows(2).all(|w| w[0] < w[1]));
            assert!(val.iter().all(|&v| v >= 1.0));
        }
    }

    #[test]
    fn same_topic_docs_closer() {
        let d = generate(300, 2000, 2);
        let labels = d.primary_labels().unwrap();
        let (mut intra, mut ni) = (0.0, 0);
        let (mut inter, mut nx) = (0.0, 0);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let (ia, va) = sp(&d.items[i]);
                let (ib, vb) = sp(&d.items[j]);
                let dd = cosine(ia, va, ib, vb);
                if labels[i] == labels[j] {
                    intra += dd;
                    ni += 1;
                } else {
                    inter += dd;
                    nx += 1;
                }
            }
        }
        assert!(ni > 0 && nx > 0);
        let (intra, inter) = (intra / ni as f64, inter / nx as f64);
        assert!(intra < inter, "topics not separable: {intra} vs {inter}");
    }
}
