//! USPS-like dataset: 16×16 bitmaps of handwritten-style digits 0 and 7,
//! discretized at threshold 0.5, keeping only bitmaps with ≥ 20 set
//! pixels, compared with the Simpson score — the paper's §4.2 USPS setup
//! (2 196 elements of the real USPS subset; we render synthetic strokes
//! with jitter, preserving the two-class overlap structure).

use super::Dataset;
use crate::distances::bitmap::Bitmap;
use crate::distances::{Item, MetricKind};
use crate::util::rng::Rng;

const W: usize = 16;

fn render_zero(rng: &mut Rng) -> Vec<f32> {
    // ellipse ring centered with jittered radii
    let cx = 7.5 + rng.normal() * 0.4;
    let cy = 7.5 + rng.normal() * 0.4;
    let rx = 4.0 + rng.normal() * 0.4;
    let ry = 5.5 + rng.normal() * 0.4;
    let thick = 1.8 + rng.f64() * 0.5;
    let mut img = vec![0.0f32; W * W];
    for y in 0..W {
        for x in 0..W {
            let dx = (x as f64 - cx) / rx.max(1.0);
            let dy = (y as f64 - cy) / ry.max(1.0);
            let r = (dx * dx + dy * dy).sqrt();
            if (r - 1.0).abs() < thick / rx.max(1.0) {
                img[y * W + x] = 0.6 + rng.f64() as f32 * 0.4;
            }
        }
    }
    img
}

fn render_seven(rng: &mut Rng) -> Vec<f32> {
    // top horizontal bar + diagonal descender, jittered
    let top = 2.0 + rng.normal() * 0.4;
    let x0 = 2.5 + rng.normal() * 0.4;
    let x1 = 12.5 + rng.normal() * 0.4;
    let slant = 0.55 + rng.f64() * 0.25; // dx per dy of the descender
    let mut img = vec![0.0f32; W * W];
    // bar
    let ty = top.round().clamp(0.0, (W - 2) as f64) as usize;
    for x in x0.max(0.0) as usize..=(x1.min((W - 1) as f64) as usize) {
        img[ty * W + x] = 0.6 + rng.f64() as f32 * 0.4;
        img[(ty + 1) * W + x] = 0.6 + rng.f64() as f32 * 0.4;
    }
    // descender from (x1, top) going down-left
    let mut x = x1;
    for y in ty + 1..W {
        let xi = x.round().clamp(0.0, (W - 1) as f64) as usize;
        img[y * W + xi] = 0.6 + rng.f64() as f32 * 0.4;
        if xi > 0 {
            img[y * W + xi - 1] = 0.5 + rng.f64() as f32 * 0.3;
        }
        x -= slant;
    }
    img
}

/// Generate ~n bitmaps (paper filter: ≥ 20 set pixels after thresholding
/// at 0.5 — rarely rejects our renders, so the output size is close to n).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut items = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let mut attempts = 0;
    while items.len() < n && attempts < n * 3 {
        attempts += 1;
        let zero = items.len() % 2 == 0;
        let img = if zero { render_zero(&mut rng) } else { render_seven(&mut rng) };
        // speckle noise
        let mut img = img;
        for _ in 0..3 {
            let i = rng.below(img.len());
            img[i] = rng.f32();
        }
        let bm = Bitmap::from_grays(&img, 0.5);
        if bm.count() >= 20 {
            items.push(Item::Bits(bm));
            labels.push(usize::from(!zero));
        }
    }
    Dataset {
        name: format!("usps(n={})", items.len()),
        items,
        label_sets: vec![("digit".into(), labels)],
        labeled: true,
        metric: MetricKind::Simpson,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::bitmap::simpson;

    fn bits(it: &Item) -> &Bitmap {
        match it {
            Item::Bits(b) => b,
            _ => panic!(),
        }
    }

    #[test]
    fn all_bitmaps_meet_pixel_filter() {
        let d = generate(300, 1);
        assert!(d.n() >= 290);
        for it in &d.items {
            assert!(bits(it).count() >= 20);
            assert_eq!(bits(it).len(), 256);
        }
    }

    #[test]
    fn same_digit_closer_than_cross_digit() {
        let d = generate(200, 2);
        let labels = d.primary_labels().unwrap();
        let (mut intra, mut ni) = (0.0, 0);
        let (mut inter, mut nx) = (0.0, 0);
        for i in 0..80 {
            for j in (i + 1)..80 {
                let dd = simpson(bits(&d.items[i]), bits(&d.items[j]));
                if labels[i] == labels[j] {
                    intra += dd;
                    ni += 1;
                } else {
                    inter += dd;
                    nx += 1;
                }
            }
        }
        let (intra, inter) = (intra / ni as f64, inter / nx as f64);
        assert!(
            inter > intra + 0.1,
            "digits not distinguishable: intra {intra} inter {inter}"
        );
    }
}
