//! Fuzzy-hash dataset: simulated binary-file corpus with five overlapping
//! label dimensions (program, package, version, compiler, options) — the
//! structure of Pagani et al.'s study used in the paper (Fig 1, Table 2).
//!
//! The real corpus is proprietary; we synthesize "binaries": each program
//! has base content; packages add/remove sections; versions mutate bytes;
//! compilers apply systematic byte transformations; options tweak smaller
//! regions. Each file is digested once (`distances::fuzzy::Digest`) and
//! compared with the lzjd/tlsh/sdhash simulants.

use super::Dataset;
use crate::distances::fuzzy::Digest;
use crate::distances::{Item, MetricKind};
use crate::util::rng::Rng;

const N_PROGRAMS: usize = 8;
const N_PACKAGES: usize = 3;
const N_VERSIONS: usize = 3;
const N_COMPILERS: usize = 2;
const N_OPTIONS: usize = 2;
const BASE_LEN: usize = 3072;

fn random_bytes(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
}

/// Generate ~n simulated binaries with 5 label dimensions.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    // base content per program
    let bases: Vec<Vec<u8>> =
        (0..N_PROGRAMS).map(|_| random_bytes(&mut rng, BASE_LEN)).collect();
    // package-specific extra sections
    let pkg_sections: Vec<Vec<u8>> = (0..N_PROGRAMS * N_PACKAGES)
        .map(|_| random_bytes(&mut rng, BASE_LEN / 4))
        .collect();
    // compiler transformations: byte substitution tables
    let compiler_tables: Vec<[u8; 256]> = (0..N_COMPILERS)
        .map(|c| {
            let mut t = [0u8; 256];
            for (i, e) in t.iter_mut().enumerate() {
                // compiler 0: identity-ish; compiler 1: rotate & xor
                *e = if c == 0 { i as u8 } else { (i as u8).rotate_left(3) ^ 0x5A };
            }
            t
        })
        .collect();

    let mut items = Vec::with_capacity(n);
    let mut l_prog = Vec::with_capacity(n);
    let mut l_pkg = Vec::with_capacity(n);
    let mut l_ver = Vec::with_capacity(n);
    let mut l_comp = Vec::with_capacity(n);
    let mut l_opt = Vec::with_capacity(n);

    for i in 0..n {
        let prog = i % N_PROGRAMS;
        let pkg = (i / N_PROGRAMS) % N_PACKAGES;
        let ver = (i / (N_PROGRAMS * N_PACKAGES)) % N_VERSIONS;
        let comp = (i / (N_PROGRAMS * N_PACKAGES * N_VERSIONS)) % N_COMPILERS;
        let opt = rng.below(N_OPTIONS);

        let mut content = bases[prog].clone();
        content.extend_from_slice(&pkg_sections[prog * N_PACKAGES + pkg]);
        // version: mutate 2% of bytes per version step (deterministic-ish
        // positions derived from rng; versions diverge progressively)
        for _ in 0..(ver * content.len() / 50) {
            let p = rng.below(content.len());
            content[p] = content[p].wrapping_add(17);
        }
        // options: swap a small region
        if opt == 1 {
            let start = content.len() / 3;
            for b in &mut content[start..start + 128] {
                *b ^= 0x0F;
            }
        }
        // compiler: whole-file transformation
        let table = &compiler_tables[comp];
        for b in &mut content {
            *b = table[*b as usize];
        }

        items.push(Item::Digest(Digest::from_bytes(&content)));
        l_prog.push(prog);
        l_pkg.push(pkg);
        l_ver.push(ver);
        l_comp.push(comp);
        l_opt.push(opt);
    }

    Dataset {
        name: format!("fuzzy(n={n})"),
        items,
        label_sets: vec![
            ("program".into(), l_prog),
            ("package".into(), l_pkg),
            ("version".into(), l_ver),
            ("compiler".into(), l_comp),
            ("options".into(), l_opt),
        ],
        labeled: true,
        metric: MetricKind::Lzjd,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::fuzzy::{lzjd, sdhash, tlsh};

    fn digest(it: &Item) -> &Digest {
        match it {
            Item::Digest(d) => d,
            _ => panic!(),
        }
    }

    #[test]
    fn five_label_dimensions() {
        let d = generate(100, 1);
        assert_eq!(d.label_sets.len(), 5);
        let names: Vec<&str> =
            d.label_sets.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["program", "package", "version", "compiler", "options"]);
    }

    #[test]
    fn same_program_same_compiler_is_closer() {
        let d = generate(400, 2);
        let prog = &d.label_sets[0].1;
        let comp = &d.label_sets[3].1;
        let (mut same, mut ns) = (0.0, 0);
        let (mut diff, mut nd) = (0.0, 0);
        for i in 0..120 {
            for j in (i + 1)..120 {
                let dd = lzjd(digest(&d.items[i]), digest(&d.items[j]));
                if prog[i] == prog[j] && comp[i] == comp[j] {
                    same += dd;
                    ns += 1;
                } else if prog[i] != prog[j] {
                    diff += dd;
                    nd += 1;
                }
            }
        }
        assert!(ns > 0 && nd > 0);
        let (same, diff) = (same / ns as f64, diff / nd as f64);
        assert!(same < diff, "lzjd: same-prog {same} !< cross-prog {diff}");
    }

    #[test]
    fn all_three_metrics_work_on_items() {
        let d = generate(50, 3);
        for f in [lzjd, tlsh, sdhash] {
            let v = f(digest(&d.items[0]), digest(&d.items[1]));
            assert!((0.0..=1.0).contains(&v));
        }
        // the MetricKind wrappers dispatch too
        for mk in [MetricKind::Lzjd, MetricKind::Tlsh, MetricKind::Sdhash] {
            let v = mk.dist(&d.items[0], &d.items[1]);
            assert!((0.0..=1.0).contains(&v));
        }
    }
}
