//! Blobs: isotropic Gaussian blobs, matching the paper's sklearn
//! `make_blobs` setup (10 centers, 10 000 samples, 1 000-10 000 dims,
//! Euclidean distance) — the high-dimensional dense benchmark where
//! KD-tree acceleration collapses (Fig 3 / Table 6).

use super::Dataset;
use crate::distances::{Item, MetricKind};
use crate::util::rng::Rng;

/// Generate `n` points across `centers` Gaussian blobs in `dim` dimensions.
/// Box = [-10, 10]^dim, unit std — sklearn's defaults.
pub fn generate(n: usize, dim: usize, centers: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let centers = centers.max(1);
    let centroids: Vec<Vec<f64>> = (0..centers)
        .map(|_| (0..dim).map(|_| rng.range_f64(-10.0, 10.0)).collect())
        .collect();
    let mut items = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % centers; // balanced, like make_blobs
        let p: Vec<f32> = centroids[c]
            .iter()
            .map(|&m| (m + rng.normal()) as f32)
            .collect();
        items.push(Item::Dense(p));
        labels.push(c);
    }
    Dataset {
        name: format!("blobs(n={n},dim={dim},k={centers})"),
        items,
        label_sets: vec![("class".into(), labels)],
        labeled: true,
        metric: MetricKind::Euclidean,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::vector::euclidean;

    #[test]
    fn blobs_are_separated_in_high_dim() {
        let d = generate(300, 100, 3, 1);
        let labels = d.primary_labels().unwrap().to_vec();
        // same-label pairs closer than cross-label pairs on average
        let mut intra = (0.0, 0usize);
        let mut inter = (0.0, 0usize);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let dd = euclidean(d.items[i].as_dense(), d.items[j].as_dense());
                if labels[i] == labels[j] {
                    intra = (intra.0 + dd, intra.1 + 1);
                } else {
                    inter = (inter.0 + dd, inter.1 + 1);
                }
            }
        }
        let intra = intra.0 / intra.1 as f64;
        let inter = inter.0 / inter.1 as f64;
        assert!(
            inter > intra * 1.5,
            "blobs not separated: intra {intra} inter {inter}"
        );
    }

    #[test]
    fn balanced_classes() {
        let d = generate(100, 4, 10, 3);
        let labels = d.primary_labels().unwrap();
        for c in 0..10 {
            assert_eq!(labels.iter().filter(|&&l| l == c).count(), 10);
        }
    }
}
