//! Sparse-vector and set distances (Docword bags-of-words, Synth
//! transactions). Sparse vectors are (sorted unique indices, values);
//! sets are sorted unique indices.

/// Cosine distance between sparse vectors given as sorted index/value pairs.
pub fn cosine(ia: &[u32], va: &[f32], ib: &[u32], vb: &[f32]) -> f64 {
    debug_assert_eq!(ia.len(), va.len());
    debug_assert_eq!(ib.len(), vb.len());
    let mut dot = 0.0f64;
    let (mut i, mut j) = (0usize, 0usize);
    while i < ia.len() && j < ib.len() {
        match ia[i].cmp(&ib[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                dot += va[i] as f64 * vb[j] as f64;
                i += 1;
                j += 1;
            }
        }
    }
    let na: f64 = va.iter().map(|v| *v as f64 * *v as f64).sum();
    let nb: f64 = vb.iter().map(|v| *v as f64 * *v as f64).sum();
    if na == 0.0 || nb == 0.0 {
        return 1.0;
    }
    (1.0 - dot / (na.sqrt() * nb.sqrt())).max(0.0)
}

/// Jaccard distance between sorted index sets: 1 - |A∩B| / |A∪B|.
pub fn jaccard(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 0.0;
    }
    let mut inter = 0usize;
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    let union = a.len() + b.len() - inter;
    1.0 - inter as f64 / union as f64
}

/// Overlap (Simpson) distance between sorted index sets.
pub fn simpson(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() || b.is_empty() {
        return 1.0;
    }
    let mut inter = 0usize;
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    1.0 - inter as f64 / a.len().min(b.len()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_cosine_matches_dense() {
        // a = [1,0,2], b = [0,3,4]
        let d = cosine(&[0, 2], &[1.0, 2.0], &[1, 2], &[3.0, 4.0]);
        let dense = crate::distances::vector::cosine(&[1.0, 0.0, 2.0], &[0.0, 3.0, 4.0]);
        assert!((d - dense).abs() < 1e-12);
    }

    #[test]
    fn sparse_cosine_disjoint_is_one() {
        assert_eq!(cosine(&[0, 1], &[1.0, 1.0], &[2, 3], &[1.0, 1.0]), 1.0);
    }

    #[test]
    fn sparse_cosine_empty_is_one() {
        assert_eq!(cosine(&[], &[], &[0], &[1.0]), 1.0);
    }

    #[test]
    fn jaccard_basics() {
        assert_eq!(jaccard(&[1, 2, 3], &[1, 2, 3]), 0.0);
        assert_eq!(jaccard(&[1, 2], &[3, 4]), 1.0);
        assert!((jaccard(&[1, 2, 3], &[2, 3, 4]) - 0.5).abs() < 1e-12);
        assert_eq!(jaccard(&[], &[]), 0.0);
        assert_eq!(jaccard(&[], &[1]), 1.0);
    }

    #[test]
    fn simpson_subset_is_zero() {
        assert_eq!(simpson(&[1, 2], &[1, 2, 3, 4]), 0.0);
        assert_eq!(simpson(&[], &[1]), 1.0);
        assert_eq!(simpson(&[5], &[6]), 1.0);
    }

    #[test]
    fn symmetry() {
        let a = [1u32, 5, 9, 12];
        let b = [2u32, 5, 12, 30, 31];
        assert_eq!(jaccard(&a, &b), jaccard(&b, &a));
        assert_eq!(simpson(&a, &b), simpson(&b, &a));
    }
}
