//! Distance functions over arbitrary data — the paper's *flexibility* axis.
//!
//! FISHDBC's core is generic over any item type `T` and any symmetric,
//! possibly non-metric distance `Metric<T>` (the paper accepts arbitrary
//! Python callables; we accept arbitrary rust closures or trait impls).
//!
//! For the framework path (CLI / coordinator / benches) we also provide a
//! dynamic [`Item`] value type plus [`MetricKind`] covering every distance
//! the paper evaluates (Table 1): Euclidean & squared Euclidean & cosine on
//! dense vectors, cosine on sparse vectors, Jaccard on sparse boolean sets,
//! Jaro-Winkler on text, Simpson on bitmaps, and the three fuzzy-hash
//! distances (lzjd / tlsh / sdhash simulants).

pub mod bitmap;
pub mod fuzzy;
pub mod sparse;
pub mod text;
pub mod vector;

use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A symmetric (possibly non-metric) distance over items of type `T`.
pub trait Metric<T: ?Sized>: Send + Sync {
    fn dist(&self, a: &T, b: &T) -> f64;

    /// Evaluate one query against many candidates in a single call — the
    /// batching hook the HNSW hot loop drives (ROADMAP item 3): beam
    /// search collects a node's unvisited neighbors and evaluates them
    /// with one `distance_batch` instead of one virtual `dist` per pair.
    ///
    /// Contract (pinned by the conformance property in
    /// `distances::tests`): `out.len() == cands.len()`, and the result
    /// must be **bit-identical** to `out[i] = self.dist(q, cands[i])` for
    /// every `i` — a batch is an amortization, never an approximation.
    /// Outputs are *raw*: hostile values (NaN / -inf) pass through
    /// unmodified; [`sanitize_distance`] is applied per element at the
    /// algorithm's choke points, exactly as on the scalar path.
    ///
    /// The default is the scalar loop. Override when query-side work can
    /// be hoisted out of the pair loop ([`MetricKind`] hoists the dense
    /// query borrow and the cosine query norm) or when a backend can
    /// evaluate many pairs per dispatch (the PJRT adapter in
    /// `hdbscan::exact_pjrt` maps one batch to one device execution).
    fn distance_batch(&self, q: &T, cands: &[&T], out: &mut [f64]) {
        debug_assert_eq!(cands.len(), out.len());
        for (o, c) in out.iter_mut().zip(cands) {
            *o = self.dist(q, c);
        }
    }

    /// Validate an item *before* it enters any index (the sharded engine
    /// calls this in `add_batch`, in the caller's thread). The default
    /// accepts everything — a typed metric cannot receive the wrong shape
    /// by construction; [`MetricKind`] overrides it to reject items its
    /// dynamic dispatch cannot handle, so a bad batch panics before it
    /// consumes global ids.
    fn check_item(&self, _item: &T) {}
}

/// Map a user-supplied distance into the half-open order the algorithm
/// assumes: `NaN` and `-inf` become `+inf` ("unknown / unreachable").
///
/// Arbitrary `Metric<T>` closures are untrusted (paper: "arbitrary
/// distance functions"). A `NaN` flowing into the HNSW neighbor heaps, the
/// core-distance mirror, or Kruskal's `total_cmp` order would silently
/// corrupt results — `total_cmp` sorts `NaN` *greatest*, demoting real
/// edges instead of failing loudly — and a `-inf` would win every
/// min-weight dedup. Mapping both to `+inf` at the single choke point the
/// algorithm reads distances through (see [`crate::hnsw`]) keeps hostile
/// metrics merely useless rather than corrupting: `+inf` is already a
/// legal "not dense enough yet" value that the existing `is_finite`
/// guards in `engine/merge.rs` and `engine/shard.rs` understand.
#[inline]
pub fn sanitize_distance(d: f64) -> f64 {
    if d.is_nan() || d == f64::NEG_INFINITY {
        f64::INFINITY
    } else {
        d
    }
}

/// Any `Fn(&T, &T) -> f64` is a metric — arbitrary user distance functions,
/// exactly like the paper's Python API.
impl<T: ?Sized, F> Metric<T> for F
where
    F: Fn(&T, &T) -> f64 + Send + Sync,
{
    #[inline]
    fn dist(&self, a: &T, b: &T) -> f64 {
        self(a, b)
    }
}

/// Wrapper counting distance evaluations (the paper's key cost model: Fig 1,
/// Fig 2 report runtime dominated by / measured in distance calls).
///
/// The counter lives behind an `Arc`, so **clones share it**: the sharded
/// engine hands each shard (and each frozen snapshot) a clone of one
/// `Counting<M>` and reads a single engine-wide total — every metric
/// evaluation on every thread, insert or search, lands in the same cell.
pub struct Counting<M> {
    inner: M,
    calls: Arc<AtomicU64>,
}

impl<M: Clone> Clone for Counting<M> {
    fn clone(&self) -> Self {
        Counting { inner: self.inner.clone(), calls: Arc::clone(&self.calls) }
    }
}

impl<M> Counting<M> {
    pub fn new(inner: M) -> Self {
        Counting { inner, calls: Arc::new(AtomicU64::new(0)) }
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
    }

    /// The wrapped metric.
    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// Fold `n` prior evaluations into the counter. The engine loader uses
    /// this to resume the counter from a checkpoint's persisted insert-path
    /// totals, keeping `metric_calls >= dist_calls` across restarts
    /// (search-path calls of previous processes are not persisted).
    pub(crate) fn add_calls(&self, n: u64) {
        self.calls.fetch_add(n, Ordering::Relaxed);
    }
}

impl<T: ?Sized, M: Metric<T>> Metric<T> for Counting<M> {
    #[inline]
    fn dist(&self, a: &T, b: &T) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.dist(a, b)
    }

    /// One counter add of `cands.len()` per batch: every pairwise
    /// evaluation still counts exactly once (the paper's cost model is
    /// pairs, not dispatches), clones still share the cell, and the
    /// inner metric's batch kernel is preserved.
    #[inline]
    fn distance_batch(&self, q: &T, cands: &[&T], out: &mut [f64]) {
        self.calls.fetch_add(cands.len() as u64, Ordering::Relaxed);
        self.inner.distance_batch(q, cands, out);
    }

    #[inline]
    fn check_item(&self, item: &T) {
        self.inner.check_item(item)
    }
}

/// Dynamic item value used by the framework layer (CLI, coordinator,
/// datasets, benches). Library users with a single concrete type should use
/// the generic API directly.
#[derive(Clone, Debug, PartialEq)]
pub enum Item {
    /// Dense f32 vector (Blobs, Household).
    Dense(Vec<f32>),
    /// Sparse vector: sorted unique indices + values (Docword).
    Sparse { idx: Vec<u32>, val: Vec<f32> },
    /// Sparse boolean set: sorted unique indices (Synth transactions).
    Set(Vec<u32>),
    /// Text (Finefoods reviews).
    Text(String),
    /// Fixed-size bitmap (USPS 16x16 digits).
    Bits(bitmap::Bitmap),
    /// Fuzzy-hash digest (lzjd/tlsh/sdhash simulants).
    Digest(fuzzy::Digest),
}

/// Content hash (manual: `f32` payloads hash by bit pattern, which the
/// derive cannot do). The write sequence — a `u64` variant tag, then the
/// raw fields, with **no** length prefixes or string terminators — is
/// frozen: it is exactly what the engine's shard router hashed before the
/// [`ShardKey`](crate::engine::ShardKey) refactor, so persisted engines
/// keep partitioning identical streams identically across releases.
/// Pinned by `engine::tests::shard_key_write_sequence_is_frozen`.
///
/// Bit-pattern hashing distinguishes values float `==` conflates
/// (`0.0`/`-0.0`, NaN payloads), so this hash is *not* consistent with the
/// derived `PartialEq`. That is deliberate and safe: `Item` is not `Eq`
/// (floats), so it cannot be a std map key anyway — this impl exists for
/// content routing, where only determinism matters.
impl Hash for Item {
    fn hash<H: Hasher>(&self, h: &mut H) {
        match self {
            Item::Dense(v) => {
                h.write_u64(0);
                for &x in v {
                    h.write_u32(x.to_bits());
                }
            }
            Item::Sparse { idx, val } => {
                h.write_u64(1);
                for &i in idx {
                    h.write_u32(i);
                }
                for &x in val {
                    h.write_u32(x.to_bits());
                }
            }
            Item::Set(s) => {
                h.write_u64(2);
                for &i in s {
                    h.write_u32(i);
                }
            }
            Item::Text(t) => {
                h.write_u64(3);
                h.write(t.as_bytes());
            }
            Item::Bits(b) => {
                h.write_u64(4);
                for &w in b.words() {
                    h.write_u64(w);
                }
            }
            Item::Digest(d) => {
                h.write_u64(5);
                for &m in &d.minhashes {
                    h.write_u64(m);
                }
                h.write(&d.histogram);
                for &w in d.features.words() {
                    h.write_u64(w);
                }
            }
        }
    }
}

impl Item {
    /// Dense payload view (panics if not dense) — used by the PJRT backend.
    pub fn as_dense(&self) -> &[f32] {
        match self {
            Item::Dense(v) => v,
            _ => panic!("Item::as_dense on non-dense item"),
        }
    }

    /// Approximate heap size in bytes (memory accounting / Table 7 notes).
    pub fn approx_bytes(&self) -> usize {
        match self {
            Item::Dense(v) => v.len() * 4,
            Item::Sparse { idx, val } => idx.len() * 4 + val.len() * 4,
            Item::Set(s) => s.len() * 4,
            Item::Text(t) => t.len(),
            Item::Bits(b) => b.words().len() * 8,
            Item::Digest(d) => d.approx_bytes(),
        }
    }
}

/// Every distance function evaluated in the paper (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MetricKind {
    Euclidean,
    SqEuclidean,
    Cosine,
    SparseCosine,
    Jaccard,
    JaroWinkler,
    Simpson,
    Lzjd,
    Tlsh,
    Sdhash,
}

impl MetricKind {
    pub fn parse(s: &str) -> Option<MetricKind> {
        Some(match s {
            "euclidean" => MetricKind::Euclidean,
            "sqeuclidean" => MetricKind::SqEuclidean,
            "cosine" => MetricKind::Cosine,
            "sparse-cosine" | "sparse_cosine" => MetricKind::SparseCosine,
            "jaccard" => MetricKind::Jaccard,
            "jaro-winkler" | "jaro_winkler" | "jw" => MetricKind::JaroWinkler,
            "simpson" => MetricKind::Simpson,
            "lzjd" => MetricKind::Lzjd,
            "tlsh" => MetricKind::Tlsh,
            "sdhash" => MetricKind::Sdhash,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Euclidean => "euclidean",
            MetricKind::SqEuclidean => "sqeuclidean",
            MetricKind::Cosine => "cosine",
            MetricKind::SparseCosine => "sparse-cosine",
            MetricKind::Jaccard => "jaccard",
            MetricKind::JaroWinkler => "jaro-winkler",
            MetricKind::Simpson => "simpson",
            MetricKind::Lzjd => "lzjd",
            MetricKind::Tlsh => "tlsh",
            MetricKind::Sdhash => "sdhash",
        }
    }

    /// Evaluate this metric on two dynamic items. Panics on a type mismatch
    /// (the framework validates dataset/metric pairing at configuration
    /// time; see [`MetricKind::compatible`]).
    pub fn dist(&self, a: &Item, b: &Item) -> f64 {
        match (self, a, b) {
            (MetricKind::Euclidean, Item::Dense(x), Item::Dense(y)) => {
                vector::euclidean(x, y)
            }
            (MetricKind::SqEuclidean, Item::Dense(x), Item::Dense(y)) => {
                vector::sqeuclidean(x, y)
            }
            (MetricKind::Cosine, Item::Dense(x), Item::Dense(y)) => {
                vector::cosine(x, y)
            }
            (
                MetricKind::SparseCosine,
                Item::Sparse { idx: ia, val: va },
                Item::Sparse { idx: ib, val: vb },
            ) => sparse::cosine(ia, va, ib, vb),
            (MetricKind::Jaccard, Item::Set(x), Item::Set(y)) => {
                sparse::jaccard(x, y)
            }
            (MetricKind::JaroWinkler, Item::Text(x), Item::Text(y)) => {
                text::jaro_winkler(x, y)
            }
            (MetricKind::Simpson, Item::Bits(x), Item::Bits(y)) => {
                bitmap::simpson(x, y)
            }
            (MetricKind::Lzjd, Item::Digest(x), Item::Digest(y)) => {
                fuzzy::lzjd(x, y)
            }
            (MetricKind::Tlsh, Item::Digest(x), Item::Digest(y)) => {
                fuzzy::tlsh(x, y)
            }
            (MetricKind::Sdhash, Item::Digest(x), Item::Digest(y)) => {
                fuzzy::sdhash(x, y)
            }
            _ => panic!(
                "metric {:?} incompatible with items {:?}/{:?}",
                self,
                std::mem::discriminant(a),
                std::mem::discriminant(b)
            ),
        }
    }

    /// Whether this metric applies to the given item.
    pub fn compatible(&self, item: &Item) -> bool {
        matches!(
            (self, item),
            (
                MetricKind::Euclidean | MetricKind::SqEuclidean | MetricKind::Cosine,
                Item::Dense(_)
            ) | (MetricKind::SparseCosine, Item::Sparse { .. })
                | (MetricKind::Jaccard, Item::Set(_))
                | (MetricKind::JaroWinkler, Item::Text(_))
                | (MetricKind::Simpson, Item::Bits(_))
                | (
                    MetricKind::Lzjd | MetricKind::Tlsh | MetricKind::Sdhash,
                    Item::Digest(_)
                )
        )
    }
}

/// `MetricKind` is itself a `Metric<Item>`, so the dynamic framework path
/// reuses the exact same generic core as typed users.
impl Metric<Item> for MetricKind {
    #[inline]
    fn dist(&self, a: &Item, b: &Item) -> f64 {
        MetricKind::dist(self, a, b)
    }

    /// Dense kinds resolve the enum dispatch and unwrap the query payload
    /// **once per batch** instead of once per pair, then run the shared
    /// lane cores from [`vector`]; cosine additionally hoists the query
    /// norm ([`vector::cosine_with_qnorm`]). Every other kind takes the
    /// scalar loop. Bit-identical to N scalar [`MetricKind::dist`] calls
    /// either way (conformance-tested per kind).
    fn distance_batch(&self, q: &Item, cands: &[&Item], out: &mut [f64]) {
        debug_assert_eq!(cands.len(), out.len());
        match (self, q) {
            (MetricKind::Euclidean, Item::Dense(x)) => {
                for (o, c) in out.iter_mut().zip(cands) {
                    *o = vector::euclidean(x, c.as_dense());
                }
            }
            (MetricKind::SqEuclidean, Item::Dense(x)) => {
                for (o, c) in out.iter_mut().zip(cands) {
                    *o = vector::sqeuclidean(x, c.as_dense());
                }
            }
            (MetricKind::Cosine, Item::Dense(x)) => {
                let nq = vector::norm_sq(x);
                for (o, c) in out.iter_mut().zip(cands) {
                    *o = vector::cosine_with_qnorm(nq, x, c.as_dense());
                }
            }
            _ => {
                for (o, c) in out.iter_mut().zip(cands) {
                    *o = MetricKind::dist(self, q, c);
                }
            }
        }
    }

    /// The dynamic pair can mismatch at runtime; reject incompatible items
    /// before they enter any index (the engine calls this in the caller's
    /// thread, before assigning global ids).
    fn check_item(&self, item: &Item) {
        assert!(
            self.compatible(item),
            "item incompatible with metric {}",
            self.name()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closures_are_metrics() {
        let m = |a: &i64, b: &i64| (a - b).abs() as f64;
        assert_eq!(m.dist(&3, &7), 4.0);
    }

    #[test]
    fn counting_counts() {
        let m = Counting::new(|a: &f64, b: &f64| (a - b).abs());
        assert_eq!(m.calls(), 0);
        m.dist(&1.0, &2.0);
        m.dist(&1.0, &3.0);
        assert_eq!(m.calls(), 2);
        m.reset();
        assert_eq!(m.calls(), 0);
    }

    #[test]
    fn counting_clones_share_one_counter() {
        // the engine hands each shard a clone; the total must aggregate
        let m = Counting::new(|a: &f64, b: &f64| (a - b).abs());
        let c = m.clone();
        m.dist(&1.0, &2.0);
        c.dist(&3.0, &4.0);
        assert_eq!(m.calls(), 2);
        assert_eq!(c.calls(), 2);
        c.reset();
        assert_eq!(m.calls(), 0);
    }

    #[test]
    fn sanitize_maps_only_nan_and_neg_inf() {
        assert_eq!(sanitize_distance(f64::NAN), f64::INFINITY);
        assert_eq!(sanitize_distance(f64::NEG_INFINITY), f64::INFINITY);
        assert_eq!(sanitize_distance(f64::INFINITY), f64::INFINITY);
        assert_eq!(sanitize_distance(1.5), 1.5);
        assert_eq!(sanitize_distance(0.0), 0.0);
        assert_eq!(sanitize_distance(-2.0), -2.0, "finite values pass through");
    }

    #[test]
    fn metric_kind_parse_roundtrip() {
        for name in [
            "euclidean", "sqeuclidean", "cosine", "sparse-cosine", "jaccard",
            "jaro-winkler", "simpson", "lzjd", "tlsh", "sdhash",
        ] {
            let k = MetricKind::parse(name).unwrap();
            assert_eq!(k.name(), name);
        }
        assert!(MetricKind::parse("nope").is_none());
    }

    #[test]
    fn dynamic_dispatch_matches_typed() {
        let a = Item::Dense(vec![0.0, 3.0]);
        let b = Item::Dense(vec![4.0, 0.0]);
        assert!((MetricKind::Euclidean.dist(&a, &b) - 5.0).abs() < 1e-12);
        assert!((MetricKind::SqEuclidean.dist(&a, &b) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn compatibility_matrix() {
        let dense = Item::Dense(vec![1.0]);
        let text = Item::Text("x".into());
        assert!(MetricKind::Euclidean.compatible(&dense));
        assert!(!MetricKind::Euclidean.compatible(&text));
        assert!(MetricKind::JaroWinkler.compatible(&text));
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn mismatched_items_panic() {
        MetricKind::Euclidean.dist(&Item::Text("a".into()), &Item::Text("b".into()));
    }

    /// All Table 1 metrics, for the batch conformance sweep.
    const ALL_KINDS: [MetricKind; 10] = [
        MetricKind::Euclidean,
        MetricKind::SqEuclidean,
        MetricKind::Cosine,
        MetricKind::SparseCosine,
        MetricKind::Jaccard,
        MetricKind::JaroWinkler,
        MetricKind::Simpson,
        MetricKind::Lzjd,
        MetricKind::Tlsh,
        MetricKind::Sdhash,
    ];

    /// A random item compatible with `kind`.
    fn gen_item(kind: MetricKind, rng: &mut crate::util::rng::Rng) -> Item {
        match kind {
            MetricKind::Euclidean | MetricKind::SqEuclidean | MetricKind::Cosine => {
                let dim = 1 + rng.below(3) * 6; // 1, 7, 13: lanes + tails
                Item::Dense((0..dim).map(|_| rng.f32() - 0.5).collect())
            }
            MetricKind::SparseCosine => {
                let mut idx = Vec::new();
                let mut cur = 0u32;
                for _ in 0..(1 + rng.below(6)) {
                    cur += 1 + rng.below(5) as u32;
                    idx.push(cur);
                }
                let val = idx.iter().map(|_| rng.f32() + 0.1).collect();
                Item::Sparse { idx, val }
            }
            MetricKind::Jaccard => {
                let mut set = Vec::new();
                let mut cur = 0u32;
                for _ in 0..(1 + rng.below(8)) {
                    cur += 1 + rng.below(4) as u32;
                    set.push(cur);
                }
                Item::Set(set)
            }
            MetricKind::JaroWinkler => {
                let len = 1 + rng.below(12);
                Item::Text(
                    (0..len)
                        .map(|_| (b'a' + rng.below(6) as u8) as char)
                        .collect(),
                )
            }
            MetricKind::Simpson => {
                let bools: Vec<bool> = (0..64).map(|_| rng.bool(0.4)).collect();
                Item::Bits(bitmap::Bitmap::from_bools(&bools))
            }
            MetricKind::Lzjd | MetricKind::Tlsh | MetricKind::Sdhash => {
                let content: Vec<u8> =
                    (0..200).map(|_| rng.next_u64() as u8).collect();
                Item::Digest(fuzzy::Digest::from_bytes(&content))
            }
        }
    }

    #[test]
    fn prop_distance_batch_bit_matches_scalar_for_every_kind() {
        // the batch path is an amortization, never an approximation:
        // for every Table 1 metric, one distance_batch call must produce
        // exactly the f64 bits of N scalar dist calls
        crate::util::proptest::check("batch-vs-scalar", 12, |rng, _| {
            for kind in ALL_KINDS {
                let q = gen_item(kind, rng);
                let cands: Vec<Item> =
                    (0..(1 + rng.below(7))).map(|_| gen_item(kind, rng)).collect();
                let refs: Vec<&Item> = cands.iter().collect();
                let mut out = vec![-1.0f64; refs.len()];
                kind.distance_batch(&q, &refs, &mut out);
                for (o, c) in out.iter().zip(&refs) {
                    assert_eq!(
                        o.to_bits(),
                        MetricKind::dist(&kind, &q, c).to_bits(),
                        "{kind:?} batch diverged from scalar"
                    );
                }
                // empty batches are legal no-ops
                kind.distance_batch(&q, &[], &mut []);
            }
        });
    }

    #[test]
    fn closure_metrics_inherit_batch_conformance() {
        // arbitrary user closures get the default loop impl: trivially
        // conformant, so generic code can batch unconditionally
        let m = |a: &i64, b: &i64| (a - b).abs() as f64;
        let q = 5i64;
        let cands = [1i64, -3, 8, 5];
        let refs: Vec<&i64> = cands.iter().collect();
        let mut out = [0.0f64; 4];
        m.distance_batch(&q, &refs, &mut out);
        for (o, c) in out.iter().zip(&refs) {
            assert_eq!(o.to_bits(), m.dist(&q, c).to_bits());
        }
    }

    #[test]
    fn batch_outputs_are_raw_and_sanitized_per_element_downstream() {
        // hostile metrics: the batch itself passes NaN/-inf through
        // bit-identically to the scalar path (raw contract); containment
        // is sanitize_distance applied per element at the choke points
        let hostile = |_a: &f64, b: &f64| {
            if *b < 0.0 {
                f64::NAN
            } else if *b == 0.0 {
                f64::NEG_INFINITY
            } else {
                *b
            }
        };
        let q = 0.5f64;
        let cands = [-1.0f64, 0.0, 2.0];
        let refs: Vec<&f64> = cands.iter().collect();
        let mut out = [0.0f64; 3];
        hostile.distance_batch(&q, &refs, &mut out);
        assert!(out[0].is_nan(), "NaN must pass through raw");
        assert_eq!(out[1], f64::NEG_INFINITY, "-inf must pass through raw");
        assert_eq!(out[2], 2.0);
        let cleaned: Vec<f64> = out.iter().map(|&d| sanitize_distance(d)).collect();
        assert_eq!(cleaned, [f64::INFINITY, f64::INFINITY, 2.0]);
    }

    #[test]
    fn counting_batch_counts_each_pair_once_across_clones() {
        // one counter add of cands.len() per batch, shared cell: the
        // engine's metric_calls stays exact under the batched search loop
        let m = Counting::new(|a: &f64, b: &f64| (a - b).abs());
        let c = m.clone();
        let q = 0.0f64;
        let cands = [1.0f64, 2.0, 4.0];
        let refs: Vec<&f64> = cands.iter().collect();
        let mut out = [0.0f64; 3];
        m.distance_batch(&q, &refs, &mut out);
        assert_eq!(m.calls(), 3, "each pairwise eval counts exactly once");
        assert_eq!(out, [1.0, 2.0, 4.0], "wrapper preserves inner results");
        c.distance_batch(&q, &refs[..2], &mut out[..2]);
        assert_eq!(m.calls(), 5, "clone lands in the same cell");
        assert_eq!(c.calls(), 5);
        m.dist(&q, &1.0);
        assert_eq!(c.calls(), 6, "scalar and batch share the counter");
    }
}
