//! Distance functions over arbitrary data — the paper's *flexibility* axis.
//!
//! FISHDBC's core is generic over any item type `T` and any symmetric,
//! possibly non-metric distance `Metric<T>` (the paper accepts arbitrary
//! Python callables; we accept arbitrary rust closures or trait impls).
//!
//! For the framework path (CLI / coordinator / benches) we also provide a
//! dynamic [`Item`] value type plus [`MetricKind`] covering every distance
//! the paper evaluates (Table 1): Euclidean & squared Euclidean & cosine on
//! dense vectors, cosine on sparse vectors, Jaccard on sparse boolean sets,
//! Jaro-Winkler on text, Simpson on bitmaps, and the three fuzzy-hash
//! distances (lzjd / tlsh / sdhash simulants).

pub mod bitmap;
pub mod fuzzy;
pub mod sparse;
pub mod text;
pub mod vector;

use std::sync::atomic::{AtomicU64, Ordering};

/// A symmetric (possibly non-metric) distance over items of type `T`.
pub trait Metric<T: ?Sized>: Send + Sync {
    fn dist(&self, a: &T, b: &T) -> f64;
}

/// Any `Fn(&T, &T) -> f64` is a metric — arbitrary user distance functions,
/// exactly like the paper's Python API.
impl<T: ?Sized, F> Metric<T> for F
where
    F: Fn(&T, &T) -> f64 + Send + Sync,
{
    #[inline]
    fn dist(&self, a: &T, b: &T) -> f64 {
        self(a, b)
    }
}

/// Wrapper counting distance evaluations (the paper's key cost model: Fig 1,
/// Fig 2 report runtime dominated by / measured in distance calls).
pub struct Counting<M> {
    inner: M,
    calls: AtomicU64,
}

impl<M> Counting<M> {
    pub fn new(inner: M) -> Self {
        Counting { inner, calls: AtomicU64::new(0) }
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
    }
}

impl<T: ?Sized, M: Metric<T>> Metric<T> for Counting<M> {
    #[inline]
    fn dist(&self, a: &T, b: &T) -> f64 {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.dist(a, b)
    }
}

/// Dynamic item value used by the framework layer (CLI, coordinator,
/// datasets, benches). Library users with a single concrete type should use
/// the generic API directly.
#[derive(Clone, Debug, PartialEq)]
pub enum Item {
    /// Dense f32 vector (Blobs, Household).
    Dense(Vec<f32>),
    /// Sparse vector: sorted unique indices + values (Docword).
    Sparse { idx: Vec<u32>, val: Vec<f32> },
    /// Sparse boolean set: sorted unique indices (Synth transactions).
    Set(Vec<u32>),
    /// Text (Finefoods reviews).
    Text(String),
    /// Fixed-size bitmap (USPS 16x16 digits).
    Bits(bitmap::Bitmap),
    /// Fuzzy-hash digest (lzjd/tlsh/sdhash simulants).
    Digest(fuzzy::Digest),
}

impl Item {
    /// Dense payload view (panics if not dense) — used by the PJRT backend.
    pub fn as_dense(&self) -> &[f32] {
        match self {
            Item::Dense(v) => v,
            _ => panic!("Item::as_dense on non-dense item"),
        }
    }

    /// Approximate heap size in bytes (memory accounting / Table 7 notes).
    pub fn approx_bytes(&self) -> usize {
        match self {
            Item::Dense(v) => v.len() * 4,
            Item::Sparse { idx, val } => idx.len() * 4 + val.len() * 4,
            Item::Set(s) => s.len() * 4,
            Item::Text(t) => t.len(),
            Item::Bits(b) => b.words().len() * 8,
            Item::Digest(d) => d.approx_bytes(),
        }
    }
}

/// Every distance function evaluated in the paper (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MetricKind {
    Euclidean,
    SqEuclidean,
    Cosine,
    SparseCosine,
    Jaccard,
    JaroWinkler,
    Simpson,
    Lzjd,
    Tlsh,
    Sdhash,
}

impl MetricKind {
    pub fn parse(s: &str) -> Option<MetricKind> {
        Some(match s {
            "euclidean" => MetricKind::Euclidean,
            "sqeuclidean" => MetricKind::SqEuclidean,
            "cosine" => MetricKind::Cosine,
            "sparse-cosine" | "sparse_cosine" => MetricKind::SparseCosine,
            "jaccard" => MetricKind::Jaccard,
            "jaro-winkler" | "jaro_winkler" | "jw" => MetricKind::JaroWinkler,
            "simpson" => MetricKind::Simpson,
            "lzjd" => MetricKind::Lzjd,
            "tlsh" => MetricKind::Tlsh,
            "sdhash" => MetricKind::Sdhash,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Euclidean => "euclidean",
            MetricKind::SqEuclidean => "sqeuclidean",
            MetricKind::Cosine => "cosine",
            MetricKind::SparseCosine => "sparse-cosine",
            MetricKind::Jaccard => "jaccard",
            MetricKind::JaroWinkler => "jaro-winkler",
            MetricKind::Simpson => "simpson",
            MetricKind::Lzjd => "lzjd",
            MetricKind::Tlsh => "tlsh",
            MetricKind::Sdhash => "sdhash",
        }
    }

    /// Evaluate this metric on two dynamic items. Panics on a type mismatch
    /// (the framework validates dataset/metric pairing at configuration
    /// time; see [`MetricKind::compatible`]).
    pub fn dist(&self, a: &Item, b: &Item) -> f64 {
        match (self, a, b) {
            (MetricKind::Euclidean, Item::Dense(x), Item::Dense(y)) => {
                vector::euclidean(x, y)
            }
            (MetricKind::SqEuclidean, Item::Dense(x), Item::Dense(y)) => {
                vector::sqeuclidean(x, y)
            }
            (MetricKind::Cosine, Item::Dense(x), Item::Dense(y)) => {
                vector::cosine(x, y)
            }
            (
                MetricKind::SparseCosine,
                Item::Sparse { idx: ia, val: va },
                Item::Sparse { idx: ib, val: vb },
            ) => sparse::cosine(ia, va, ib, vb),
            (MetricKind::Jaccard, Item::Set(x), Item::Set(y)) => {
                sparse::jaccard(x, y)
            }
            (MetricKind::JaroWinkler, Item::Text(x), Item::Text(y)) => {
                text::jaro_winkler(x, y)
            }
            (MetricKind::Simpson, Item::Bits(x), Item::Bits(y)) => {
                bitmap::simpson(x, y)
            }
            (MetricKind::Lzjd, Item::Digest(x), Item::Digest(y)) => {
                fuzzy::lzjd(x, y)
            }
            (MetricKind::Tlsh, Item::Digest(x), Item::Digest(y)) => {
                fuzzy::tlsh(x, y)
            }
            (MetricKind::Sdhash, Item::Digest(x), Item::Digest(y)) => {
                fuzzy::sdhash(x, y)
            }
            _ => panic!(
                "metric {:?} incompatible with items {:?}/{:?}",
                self,
                std::mem::discriminant(a),
                std::mem::discriminant(b)
            ),
        }
    }

    /// Whether this metric applies to the given item.
    pub fn compatible(&self, item: &Item) -> bool {
        matches!(
            (self, item),
            (
                MetricKind::Euclidean | MetricKind::SqEuclidean | MetricKind::Cosine,
                Item::Dense(_)
            ) | (MetricKind::SparseCosine, Item::Sparse { .. })
                | (MetricKind::Jaccard, Item::Set(_))
                | (MetricKind::JaroWinkler, Item::Text(_))
                | (MetricKind::Simpson, Item::Bits(_))
                | (
                    MetricKind::Lzjd | MetricKind::Tlsh | MetricKind::Sdhash,
                    Item::Digest(_)
                )
        )
    }
}

/// `MetricKind` is itself a `Metric<Item>`, so the dynamic framework path
/// reuses the exact same generic core as typed users.
impl Metric<Item> for MetricKind {
    #[inline]
    fn dist(&self, a: &Item, b: &Item) -> f64 {
        MetricKind::dist(self, a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closures_are_metrics() {
        let m = |a: &i64, b: &i64| (a - b).abs() as f64;
        assert_eq!(m.dist(&3, &7), 4.0);
    }

    #[test]
    fn counting_counts() {
        let m = Counting::new(|a: &f64, b: &f64| (a - b).abs());
        assert_eq!(m.calls(), 0);
        m.dist(&1.0, &2.0);
        m.dist(&1.0, &3.0);
        assert_eq!(m.calls(), 2);
        m.reset();
        assert_eq!(m.calls(), 0);
    }

    #[test]
    fn metric_kind_parse_roundtrip() {
        for name in [
            "euclidean", "sqeuclidean", "cosine", "sparse-cosine", "jaccard",
            "jaro-winkler", "simpson", "lzjd", "tlsh", "sdhash",
        ] {
            let k = MetricKind::parse(name).unwrap();
            assert_eq!(k.name(), name);
        }
        assert!(MetricKind::parse("nope").is_none());
    }

    #[test]
    fn dynamic_dispatch_matches_typed() {
        let a = Item::Dense(vec![0.0, 3.0]);
        let b = Item::Dense(vec![4.0, 0.0]);
        assert!((MetricKind::Euclidean.dist(&a, &b) - 5.0).abs() < 1e-12);
        assert!((MetricKind::SqEuclidean.dist(&a, &b) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn compatibility_matrix() {
        let dense = Item::Dense(vec![1.0]);
        let text = Item::Text("x".into());
        assert!(MetricKind::Euclidean.compatible(&dense));
        assert!(!MetricKind::Euclidean.compatible(&text));
        assert!(MetricKind::JaroWinkler.compatible(&text));
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn mismatched_items_panic() {
        MetricKind::Euclidean.dist(&Item::Text("a".into()), &Item::Text("b".into()));
    }
}
